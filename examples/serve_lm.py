"""Batched serving example: continuous batching over decode lanes.

  PYTHONPATH=src python examples/serve_lm.py --requests 8 --lanes 4

Builds a small model, submits a queue of ragged-length prompts, and serves
them with the continuous-batching engine (prefill on lane admission, lock-
step decode, immediate refill). Prints per-request outputs + throughput.
"""

import argparse
import dataclasses
import time

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.models import build_lm
from repro.serve import BatchedServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, n_layers=4, d_model=128, d_ff=256, vocab_size=512)
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    print(f"serving {cfg.name}-smoke ({cfg.param_count()/1e6:.2f}M params), "
          f"{args.lanes} lanes")

    srv = BatchedServer(cfg, params, lanes=args.lanes, max_len=128)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        srv.submit(rng.integers(0, cfg.vocab_size, size=(plen,)), max_new_tokens=args.max_new)
    done = srv.run_until_idle()
    dt = time.perf_counter() - t0

    for r in done:
        print(f"req {r.rid}: prompt_len={len(r.prompt)} -> {r.out_tokens[:8]}...")
    toks = srv.stats["tokens_out"]
    print(
        f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
        f"({toks/dt:.1f} tok/s; {srv.stats['prefills']} prefills, "
        f"{srv.stats['decode_steps']} decode steps)"
    )


if __name__ == "__main__":
    main()
