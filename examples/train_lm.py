"""End-to-end LM training: a ~100M-param model for a few hundred steps on
deterministic synthetic data, with checkpoint/restart.

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --steps 300 --arch qwen1.5-0.5b --scale full

Default is a ~100M-param qwen1.5-family config (the brief's "train ~100M
model for a few hundred steps" driver). Loss must drop; checkpoints land in
--ckpt-dir and a rerun resumes from the last one.
"""

import argparse
import dataclasses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--scale", choices=["tiny", "100m", "full"], default="100m")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import DataConfig, SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.train import TrainConfig, train

    cfg = get_config(args.arch)
    if args.scale == "tiny":
        # ~5M params: CPU-friendly evidence run (1-core container); the
        # 100m scale is the real driver for actual hardware.
        cfg = dataclasses.replace(
            cfg, n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=0,
            d_ff=1024, vocab_size=4096, remat=False, learning_rate=1e-3,
        )
    elif args.scale == "100m":
        # ~100M params: 12 layers x 512 wide on the arch's own block family.
        cfg = dataclasses.replace(
            cfg,
            n_layers=12,
            d_model=512,
            n_heads=8,
            n_kv_heads=min(8, max(1, cfg.n_kv_heads)),
            head_dim=0,
            d_ff=2048,
            vocab_size=32768,
            remat=False,
            learning_rate=1e-3,
        )
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M")

    mesh = make_host_mesh()
    ds = SyntheticLM(
        DataConfig(seq_len=args.seq, global_batch=args.batch, vocab_size=cfg.vocab_size)
    )
    tc = TrainConfig(
        steps=args.steps,
        microbatches=args.microbatches,
        log_every=10,
        ckpt_every=100,
        ckpt_dir=args.ckpt_dir,
    )
    _, _, hist = train(cfg, tc, mesh, ds)
    if hist:
        print(
            f"loss: first10={sum(h['loss'] for h in hist[:10])/max(len(hist[:10]),1):.4f} "
            f"last10={sum(h['loss'] for h in hist[-10:])/max(len(hist[-10:]),1):.4f}"
        )


if __name__ == "__main__":
    main()
