"""Quickstart: run the paper's hdiff kernel on the COSMO 256x256x64 domain.

  PYTHONPATH=src python examples/quickstart.py

Shows the three execution policies (staged / fused-XLA / fused-Pallas) and
verifies they agree, then runs a 10-step simulation.
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.hdiff import CONFIG
from repro.core import hdiff, hdiff_staged, make_initial_field, run_simulation
from repro.kernels.hdiff import hdiff_fused


def main() -> None:
    g = CONFIG
    print(f"hdiff on {g.depth}x{g.rows}x{g.cols} (COSMO domain), coeff={g.coeff}")
    psi = make_initial_field(g.depth, g.rows, g.cols, kind="gaussian")

    fused = jax.jit(lambda x: hdiff(x, g.coeff))
    t0 = time.perf_counter()
    out_fused = jax.block_until_ready(fused(psi))
    print(f"fused-xla     first call {time.perf_counter()-t0:.3f}s (includes compile)")

    t0 = time.perf_counter()
    out_staged = jax.block_until_ready(hdiff_staged(psi, g.coeff))
    print(f"staged        {time.perf_counter()-t0:.3f}s")

    t0 = time.perf_counter()
    out_pallas = jax.block_until_ready(hdiff_fused(psi[:4], g.coeff))
    print(f"fused-pallas  {time.perf_counter()-t0:.3f}s (interpret mode, 4 planes)")

    np.testing.assert_allclose(np.asarray(out_fused), np.asarray(out_staged), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(out_fused[:4]), np.asarray(out_pallas), rtol=1e-5, atol=1e-5
    )
    print("all three policies agree ✓")

    final, _ = run_simulation(psi, g.coeff, step_fn=hdiff, n_steps=100)
    peak0 = float(jnp.abs(psi[:, 2:-2, 2:-2]).max())
    peak1 = float(jnp.abs(final[:, 2:-2, 2:-2]).max())
    rough0 = float(jnp.abs(jnp.diff(psi, axis=-1)).mean())
    rough1 = float(jnp.abs(jnp.diff(final, axis=-1)).mean())
    print(f"100-step simulation: interior peak {peak0:.4f} -> {peak1:.4f}, "
          f"roughness {rough0:.5f} -> {rough1:.5f} (diffusion smooths ✓)")
    assert rough1 < rough0


if __name__ == "__main__":
    main()
