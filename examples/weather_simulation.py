"""End-to-end coupled-system weather driver: shallow-water via the IR.

  PYTHONPATH=src python examples/weather_simulation.py [--steps 50] [--devices 8]

A multi-equation model as ONE multi-output IR program: the linearized
shallow-water system evolves three fields per sweep —

    u <- u - g*dt * dh/dx
    v <- v - g*dt * dh/dy
    h <- h - H*dt * (du/dx + dv/dy)

declared once as a dataflow graph (``shallow_water_program``) with
``outputs={u, v, h}``. The §3.1 planner consumes the program's derived
per-output halos to choose the rows x cols partition that minimizes the
MERGED exchange bytes, and ``lower_sharded`` decomposes the whole system
over the device mesh: one fused per-shard kernel writes all three outputs
and ONE stacked halo exchange per sweep moves every evolving field's bands
(8 collective permutes on a 2-D mesh where sequential per-field exchanges
would issue 24). The distributed state dict is verified per field against
the single-device reference lowering.

With --devices N (default 8) the script re-execs itself with N fake host
devices, which is how a real multi-host launch degrades gracefully to one
host for local testing. ``--inner pallas`` composes the fused Pallas kernel
inside each shard (interpret mode off-TPU, so it is a correctness datapoint
on CPU, not a speed claim).

``--health`` arms the numerics watchdog for long forecasts: the time loop
runs in cadence-sized jitted chunks and one ``repro.obs.HealthMonitor``
PER OUTPUT FIELD probes its field (NaN/Inf counts, min/max/mean, global
L2 — on-device reductions, scalars-only host transfer) every
``--health-every`` steps, so the blow-up report names WHICH equation went
bad. All monitors share one checkpoint_fn over the full state dict: on a
blow-up under ``checkpoint-then-abort`` the failing field's monitor first
COMMITs a checkpoint of the last healthy probed {u, v, h} to
``--ckpt-dir``, then halts within one probe cadence; the flight recorder
(JSONL at ``--event-log`` / ``REPRO_EVENT_LOG``) is flushed with the
failing step's per-field stats. ``--inject-nan STEP`` poisons one grid
point of the HEIGHT field mid-forecast — the end-to-end blow-up drill CI
runs. Exit code 3 signals a detected blow-up.
"""

import argparse
import functools
import os
import sys
import time

BLOWUP_EXIT_CODE = 3


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--depth", type=int, default=64)
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument(
        "--inner",
        choices=("reference", "pallas"),
        default="reference",
        help="per-shard compute backend for the IR sharded lowering",
    )
    ap.add_argument("--health", action="store_true",
                    help="probe per-field numerics on a cadence (blow-up-safe loop)")
    ap.add_argument("--health-every", type=int, default=10,
                    help="probe cadence in steps (with --health)")
    ap.add_argument("--health-policy", default="checkpoint-then-abort",
                    choices=("warn", "abort", "checkpoint-then-abort"))
    ap.add_argument("--ckpt-dir", default="weather_ckpt",
                    help="checkpoint root for checkpoint-then-abort")
    ap.add_argument("--event-log", default="",
                    help="flight-recorder JSONL sink (or set REPRO_EVENT_LOG)")
    ap.add_argument("--inject-nan", type=int, default=-1, metavar="STEP",
                    help="poison one height-field point after STEP (blow-up drill)")
    ap.add_argument("--_worker", action="store_true")
    args = ap.parse_args()

    if not args._worker and args.devices > 1:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"
        os.execve(
            sys.executable,
            [sys.executable, __file__, "--_worker", *sys.argv[1:]],
            env,
        )

    import numpy as np
    import jax

    from repro.core import make_initial_field
    from repro.ir import (
        lower_reference,
        lower_sharded,
        plan_partition,
        shallow_water_program,
    )

    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")

    program = shallow_water_program()
    spec = program.spec()
    print(
        f"IR program: {program.name} radius={spec.radius} "
        f"outputs={'+'.join(program.outputs)} "
        f"({spec.macs} MACs + {spec.other_ops} ops, {spec.reads} reads/point)"
    )

    plan = plan_partition(program, args.depth, args.size, args.size, n_dev)
    print(
        f"partition plan: rows x{plan.row_shards} cols x{plan.col_shards} "
        f"(merged-exchange halo={plan.halo}, "
        f"{plan.wire_bytes} wire B/round for all {len(program.outputs)} fields)"
    )

    step = lower_sharded(program, mesh_shape=plan.mesh_shape, inner=args.inner)

    # Initial state: a gaussian height anomaly at rest (u = v = 0) — the
    # classic gravity-wave adjustment problem.
    h0 = make_initial_field(args.depth, args.size, args.size, kind="gaussian")
    state0 = {
        "u": jax.numpy.zeros_like(h0),
        "v": jax.numpy.zeros_like(h0),
        "h": h0,
    }

    if args.health:
        run_with_health(args, program, step, state0)
        return

    # Distributed time-stepping: the {u, v, h} dict is the scan carry, so
    # the whole coupled state stays device-resident between steps.
    @jax.jit
    def run(state):
        def body(s, _):
            return step(s), None
        out, _ = jax.lax.scan(body, state, None, length=args.steps)
        return out

    t0 = time.perf_counter()
    final = jax.block_until_ready(run(state0))
    dt = time.perf_counter() - t0
    print(f"{args.steps} steps in {dt:.2f}s ({dt/args.steps*1e3:.1f} ms/step on CPU)")

    # Verify every output field against the single-device reference.
    ref_step = lower_reference(program)

    @jax.jit
    def run_ref(state):
        def body(s, _):
            return ref_step(s), None
        out, _ = jax.lax.scan(body, state, None, length=args.steps)
        return out

    ref = jax.block_until_ready(run_ref(state0))
    for f in program.outputs:
        np.testing.assert_allclose(
            np.asarray(final[f]), np.asarray(ref[f]),
            rtol=1e-4, atol=1e-5, err_msg=f,
        )
    print("distributed result matches single-device reference ✓ "
          f"({', '.join(program.outputs)})")
    for f in program.outputs:
        a = final[f]
        print(f"  {f} range: [{float(a.min()):.4f}, {float(a.max()):.4f}]")


def run_with_health(args, program, step, state0) -> None:
    """The blow-up-safe forecast loop: cadence-chunked stepping + one
    monitor per output field, all sharing one full-state checkpoint_fn."""
    import jax
    import jax.numpy as jnp

    from repro.checkpoint import save_checkpoint
    from repro.obs import FlightRecorder, HealthMonitor, NumericsError, events

    if args.event_log:
        events.enable(FlightRecorder(sink=args.event_log))
    # (REPRO_EVENT_LOG in the environment already installed a recorder at
    # import time; without either, probes still guard the run — the ring
    # and crash dump are simply unavailable.)

    checkpoint_fn = None
    if args.health_policy == "checkpoint-then-abort":
        def checkpoint_fn(healthy_step, state):
            path = save_checkpoint(
                args.ckpt_dir, healthy_step, dict(state),
                {"step": healthy_step, "fields": list(program.outputs),
                 "reason": "pre-blow-up health snapshot"},
            )
            print(f"committed last-healthy checkpoint: {path}")
            return path

    # One watchdog per evolving field: the blow-up names the equation that
    # went bad. Each healthy probe retains the FULL state dict, so whichever
    # monitor trips first checkpoints a consistent {u, v, h} snapshot.
    monitors = {
        f: HealthMonitor(
            cadence=args.health_every,
            policy=args.health_policy,
            name=f,
            checkpoint_fn=checkpoint_fn,
        )
        for f in program.outputs
    }

    def check_all(done, state, *, force=False):
        for f, monitor in monitors.items():
            monitor.check(done, state[f], state=state, force=force)

    cadence = args.health_every

    @functools.partial(jax.jit, static_argnums=1)
    def run_chunk(state, n):
        def body(s, _):
            return step(s), None
        out, _ = jax.lax.scan(body, state, None, length=n)
        return out

    state = state0
    check_all(0, state)  # step-0 baseline: the initial state is healthy
    events.record("forecast.start", steps=args.steps, cadence=cadence,
                  policy=args.health_policy, fields=list(program.outputs),
                  grid=[args.depth, args.size, args.size])
    t0 = time.perf_counter()
    try:
        done = 0
        while done < args.steps:
            n = min(cadence - done % cadence if done % cadence else cadence,
                    args.steps - done)
            state = run_chunk(state, n)
            done += n
            if 0 <= args.inject_nan <= done and args.inject_nan > done - n:
                # The drill: one poisoned HEIGHT point mid-forecast, as if
                # the dynamics blew up somewhere inside this chunk.
                state = dict(state)
                state["h"] = state["h"].at[
                    0, args.size // 2, args.size // 2
                ].set(jnp.nan)
                print(f"injected NaN into h after step {args.inject_nan}")
            # force on the final boundary: when steps is not a multiple of
            # the cadence the last partial chunk is off-cadence, and a NaN
            # born there must not escape as "forecast healthy".
            check_all(done, state, force=(done == args.steps))
    except NumericsError as e:
        dump = events.crash_dump(reason=str(e))
        print(f"BLOWUP_DETECTED step={e.step} field={e.field} "
              f"nan_count={e.stats['nan_count']:.0f} inf_count={e.stats['inf_count']:.0f}")
        if dump is not None:
            print(f"flight recorder crash dump: {dump}")
        sys.exit(BLOWUP_EXIT_CODE)
    dt = time.perf_counter() - t0
    events.record("forecast.end", steps=args.steps, wall_s=dt)
    probes = sum(m.probes for m in monitors.values())
    blowups = sum(m.blowups for m in monitors.values())
    print(f"{args.steps} steps in {dt:.2f}s with {probes} health probes "
          f"({args.steps / cadence:.0f} cadences x {len(monitors)} fields, "
          f"policy={args.health_policy})")
    print(f"forecast healthy: probes={probes} blowups={blowups} "
          f"fields={'+'.join(monitors)}")


if __name__ == "__main__":
    main()
