"""End-to-end weather-stencil driver: distributed iterative hdiff via the IR.

  PYTHONPATH=src python examples/weather_simulation.py [--steps 100] [--devices 8]

Builds the hdiff step through the ``repro.ir`` compiler path: the stencil is
declared once as a dataflow graph (``hdiff_program``), the §3.1 analytical
planner consumes its graph-derived halo/op counts to choose the partition,
and ``lower_sharded`` decomposes it over the device mesh with the *inferred*
radius-2 halo exchange (the B-block scale-out of §3.4). The distributed
result is verified against the single-device reference kernel.

With --devices N (default 8) the script re-execs itself with N fake host
devices, which is how a real multi-host launch degrades gracefully to one
host for local testing. ``--inner pallas`` composes the fused Pallas kernel
inside each shard (interpret mode off-TPU, so it is a correctness datapoint
on CPU, not a speed claim).
"""

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--depth", type=int, default=64)
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument(
        "--inner",
        choices=("reference", "pallas"),
        default="reference",
        help="per-shard compute backend for the IR sharded lowering",
    )
    ap.add_argument("--_worker", action="store_true")
    args = ap.parse_args()

    if not args._worker and args.devices > 1:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"
        os.execve(
            sys.executable,
            [sys.executable, __file__, "--_worker", *sys.argv[1:]],
            env,
        )

    import numpy as np
    import jax

    from repro.core import hdiff, make_initial_field, plan_partition, run_simulation
    from repro.ir import hdiff_program, lower_sharded
    from repro.launch.mesh import make_mesh

    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")

    program = hdiff_program(coeff=0.025, limit=True)
    spec = program.spec()
    print(
        f"IR program: {program.name} radius={spec.radius} "
        f"({spec.macs} MACs + {spec.other_ops} ops, {spec.reads} reads/point)"
    )

    plan = plan_partition(args.depth, args.size, args.size, n_dev, program=program)
    print(
        f"partition plan: {plan.kind} (depth x{plan.depth_shards}, rows x{plan.row_shards}) "
        f"predicted step terms: compute={plan.compute_s:.2e}s hbm={plan.hbm_s:.2e}s "
        f"ici={plan.ici_s:.2e}s"
    )

    mesh = make_mesh((plan.depth_shards, plan.row_shards), ("data", "model"))
    step = lower_sharded(
        program,
        mesh,
        depth_axis="data",
        row_axis="model" if plan.row_shards > 1 else None,
        inner=args.inner,
    )

    psi0 = make_initial_field(args.depth, args.size, args.size, kind="gaussian")

    # Distributed time-stepping (grid stays device-resident between steps).
    @jax.jit
    def run(psi, n):
        def body(p, _):
            return step(p), None
        out, _ = jax.lax.scan(body, psi, None, length=args.steps)
        return out

    t0 = time.perf_counter()
    final = jax.block_until_ready(run(psi0, args.steps))
    dt = time.perf_counter() - t0
    print(f"{args.steps} steps in {dt:.2f}s ({dt/args.steps*1e3:.1f} ms/step on CPU)")

    # Verify against the single-device reference for a few steps.
    ref, _ = run_simulation(psi0, 0.025, step_fn=hdiff, n_steps=args.steps)
    np.testing.assert_allclose(np.asarray(final), np.asarray(ref), rtol=1e-4, atol=1e-5)
    print("distributed result matches single-device reference ✓")
    print(f"field range: [{float(final.min()):.4f}, {float(final.max()):.4f}]")


if __name__ == "__main__":
    main()
