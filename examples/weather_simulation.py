"""End-to-end weather-stencil driver: distributed iterative hdiff via the IR.

  PYTHONPATH=src python examples/weather_simulation.py [--steps 100] [--devices 8]

Builds the hdiff step through the ``repro.ir`` compiler path: the stencil is
declared once as a dataflow graph (``hdiff_program``), the §3.1 analytical
planner consumes its graph-derived halo/op counts to choose the partition,
and ``lower_sharded`` decomposes it over the device mesh with the *inferred*
radius-2 halo exchange (the B-block scale-out of §3.4). The distributed
result is verified against the single-device reference kernel.

With --devices N (default 8) the script re-execs itself with N fake host
devices, which is how a real multi-host launch degrades gracefully to one
host for local testing. ``--inner pallas`` composes the fused Pallas kernel
inside each shard (interpret mode off-TPU, so it is a correctness datapoint
on CPU, not a speed claim).

``--health`` arms the numerics watchdog for long forecasts: the time loop
runs in cadence-sized jitted chunks and a ``repro.obs.HealthMonitor``
probes the field (NaN/Inf counts, min/max/mean, global L2 — on-device
reductions, scalars-only host transfer) every ``--health-every`` steps. On
a blow-up the run halts within one probe cadence under the chosen
``--health-policy``: the flight recorder (JSONL at ``--event-log`` /
``REPRO_EVENT_LOG``) is flushed with the failing step's field stats, and
``checkpoint-then-abort`` first COMMITs a checkpoint of the last healthy
probed state to ``--ckpt-dir``. ``--inject-nan STEP`` poisons one grid
point mid-forecast — the end-to-end blow-up drill CI runs. Exit code 3
signals a detected blow-up.
"""

import argparse
import functools
import os
import sys
import time

BLOWUP_EXIT_CODE = 3


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--depth", type=int, default=64)
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument(
        "--inner",
        choices=("reference", "pallas"),
        default="reference",
        help="per-shard compute backend for the IR sharded lowering",
    )
    ap.add_argument("--health", action="store_true",
                    help="probe field numerics on a cadence (blow-up-safe loop)")
    ap.add_argument("--health-every", type=int, default=10,
                    help="probe cadence in steps (with --health)")
    ap.add_argument("--health-policy", default="checkpoint-then-abort",
                    choices=("warn", "abort", "checkpoint-then-abort"))
    ap.add_argument("--ckpt-dir", default="weather_ckpt",
                    help="checkpoint root for checkpoint-then-abort")
    ap.add_argument("--event-log", default="",
                    help="flight-recorder JSONL sink (or set REPRO_EVENT_LOG)")
    ap.add_argument("--inject-nan", type=int, default=-1, metavar="STEP",
                    help="poison one grid point after STEP (blow-up drill)")
    ap.add_argument("--_worker", action="store_true")
    args = ap.parse_args()

    if not args._worker and args.devices > 1:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"
        os.execve(
            sys.executable,
            [sys.executable, __file__, "--_worker", *sys.argv[1:]],
            env,
        )

    import numpy as np
    import jax

    from repro.core import hdiff, make_initial_field, plan_partition, run_simulation
    from repro.ir import hdiff_program, lower_sharded
    from repro.launch.mesh import make_mesh

    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")

    program = hdiff_program(coeff=0.025, limit=True)
    spec = program.spec()
    print(
        f"IR program: {program.name} radius={spec.radius} "
        f"({spec.macs} MACs + {spec.other_ops} ops, {spec.reads} reads/point)"
    )

    plan = plan_partition(args.depth, args.size, args.size, n_dev, program=program)
    print(
        f"partition plan: {plan.kind} (depth x{plan.depth_shards}, rows x{plan.row_shards}) "
        f"predicted step terms: compute={plan.compute_s:.2e}s hbm={plan.hbm_s:.2e}s "
        f"ici={plan.ici_s:.2e}s"
    )

    mesh = make_mesh((plan.depth_shards, plan.row_shards), ("data", "model"))
    step = lower_sharded(
        program,
        mesh,
        depth_axis="data",
        row_axis="model" if plan.row_shards > 1 else None,
        inner=args.inner,
    )

    psi0 = make_initial_field(args.depth, args.size, args.size, kind="gaussian")

    if args.health:
        run_with_health(args, step, psi0)
        return

    # Distributed time-stepping (grid stays device-resident between steps).
    @jax.jit
    def run(psi, n):
        def body(p, _):
            return step(p), None
        out, _ = jax.lax.scan(body, psi, None, length=args.steps)
        return out

    t0 = time.perf_counter()
    final = jax.block_until_ready(run(psi0, args.steps))
    dt = time.perf_counter() - t0
    print(f"{args.steps} steps in {dt:.2f}s ({dt/args.steps*1e3:.1f} ms/step on CPU)")

    # Verify against the single-device reference for a few steps.
    ref, _ = run_simulation(psi0, 0.025, step_fn=hdiff, n_steps=args.steps)
    np.testing.assert_allclose(np.asarray(final), np.asarray(ref), rtol=1e-4, atol=1e-5)
    print("distributed result matches single-device reference ✓")
    print(f"field range: [{float(final.min()):.4f}, {float(final.max()):.4f}]")


def run_with_health(args, step, psi0) -> None:
    """The blow-up-safe forecast loop: cadence-chunked stepping + probes."""
    import jax
    import jax.numpy as jnp

    from repro.checkpoint import save_checkpoint
    from repro.obs import FlightRecorder, HealthMonitor, NumericsError, events

    if args.event_log:
        events.enable(FlightRecorder(sink=args.event_log))
    # (REPRO_EVENT_LOG in the environment already installed a recorder at
    # import time; without either, probes still guard the run — the ring
    # and crash dump are simply unavailable.)

    checkpoint_fn = None
    if args.health_policy == "checkpoint-then-abort":
        def checkpoint_fn(healthy_step, psi):
            path = save_checkpoint(
                args.ckpt_dir, healthy_step, {"psi": psi},
                {"step": healthy_step, "reason": "pre-blow-up health snapshot"},
            )
            print(f"committed last-healthy checkpoint: {path}")
            return path

    monitor = HealthMonitor(
        cadence=args.health_every,
        policy=args.health_policy,
        name="psi",
        checkpoint_fn=checkpoint_fn,
    )

    cadence = args.health_every

    @functools.partial(jax.jit, static_argnums=1)
    def run_chunk(psi, n):
        def body(p, _):
            return step(p), None
        out, _ = jax.lax.scan(body, psi, None, length=n)
        return out

    psi = psi0
    monitor.check(0, psi)  # step-0 baseline: the initial field is healthy
    events.record("forecast.start", steps=args.steps, cadence=cadence,
                  policy=args.health_policy, grid=[args.depth, args.size, args.size])
    t0 = time.perf_counter()
    try:
        done = 0
        while done < args.steps:
            n = min(cadence - done % cadence if done % cadence else cadence,
                    args.steps - done)
            psi = run_chunk(psi, n)
            done += n
            if 0 <= args.inject_nan <= done and args.inject_nan > done - n:
                # The drill: one poisoned point mid-forecast, as if the
                # dynamics blew up somewhere inside this chunk.
                psi = psi.at[0, args.size // 2, args.size // 2].set(jnp.nan)
                print(f"injected NaN after step {args.inject_nan}")
            # force on the final boundary: when steps is not a multiple of
            # the cadence the last partial chunk is off-cadence, and a NaN
            # born there must not escape as "forecast healthy".
            monitor.check(done, psi, force=(done == args.steps))
    except NumericsError as e:
        dump = events.crash_dump(reason=str(e))
        print(f"BLOWUP_DETECTED step={e.step} field={e.field} "
              f"nan_count={e.stats['nan_count']:.0f} inf_count={e.stats['inf_count']:.0f}")
        if dump is not None:
            print(f"flight recorder crash dump: {dump}")
        sys.exit(BLOWUP_EXIT_CODE)
    dt = time.perf_counter() - t0
    events.record("forecast.end", steps=args.steps, wall_s=dt)
    print(f"{args.steps} steps in {dt:.2f}s with {monitor.probes} health probes "
          f"({args.steps / cadence:.0f} cadences, policy={args.health_policy})")
    print(f"forecast healthy: l2={monitor.last_healthy and 'ok'} "
          f"probes={monitor.probes} blowups={monitor.blowups}")


if __name__ == "__main__":
    main()
