"""Shared benchmark utilities: timing, CSV rows, the paper's grid."""

from __future__ import annotations

import time
from typing import Callable

import jax

# The paper's evaluation domain (§4.1).
ROWS, COLS, DEPTH = 256, 256, 64

_rows: list[tuple[str, float, str]] = []


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on device)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    _rows.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def all_rows():
    return list(_rows)


def hdiff_gops(us_per_call: float, rows=ROWS, cols=COLS, depth=DEPTH) -> float:
    """GOp/s using the paper's op accounting (Table 2 'Perf. (GOp/s)')."""
    from repro.core import HDIFF_SPEC

    interior = (rows - 4) * (cols - 4) * depth
    ops = interior * HDIFF_SPEC.flops
    return ops / (us_per_call * 1e-6) / 1e9
