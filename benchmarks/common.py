"""Shared benchmark utilities: timing, CSV rows, the paper's grid."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

from repro.obs import events, metrics

# The paper's evaluation domain (§4.1).
ROWS, COLS, DEPTH = 256, 256, 64

_rows: list[tuple[str, float, str, str]] = []


@dataclasses.dataclass(frozen=True)
class Timing:
    """Best-of-N wall-clock stats for one timed callable (microseconds).

    ``median_us`` is the headline (robust to scheduler noise); ``min_us`` is
    the best case (closest to the machine's true capability — what perf
    trajectories should trend on); both are reported so a regression in one
    but not the other distinguishes noise from a real slowdown.
    """

    median_us: float
    min_us: float
    mean_us: float
    iters: int
    warmup: int


def time_stats(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> Timing:
    """Times ``fn(*args)`` with ``block_until_ready`` discipline.

    At least one untimed warmup call ALWAYS runs first, so compilation can
    never land inside a timed iteration — even when the caller has already
    primed the jit cache and asks for ``warmup=0``.
    """
    warmup = max(1, warmup)
    iters = max(1, iters)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return Timing(
        median_us=times[len(times) // 2] * 1e6,
        min_us=times[0] * 1e6,
        mean_us=sum(times) / len(times) * 1e6,
        iters=iters,
        warmup=warmup,
    )


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on device)."""
    return time_stats(fn, *args, warmup=warmup, iters=iters).median_us


def emit(name: str, value: float, derived: str = "", unit: str = "us") -> None:
    """Records one benchmark row.

    ``unit`` tags what ``value`` measures so downstream consumers
    (``scripts/bench_compare.py``) know which comparison rule applies:
    ``"us"`` (wall-clock, lower is better, noise-tolerant), ``"bytes"``
    (deterministic wire/HBM models, tight tolerance), anything else
    (``"x"``, ``"model_us"``, ``"bool"``, ...) is informational and never
    gates.
    """
    _rows.append((name, value, derived, unit))
    metrics.set_gauge(f"bench.{name}", value)
    events.record("bench.row", name=name, value=value, unit=unit)
    print(f"{name},{value:.1f},{derived},{unit}")


def all_rows():
    return list(_rows)


def hdiff_gops(us_per_call: float, rows=ROWS, cols=COLS, depth=DEPTH) -> float:
    """GOp/s using the paper's op accounting (Table 2 'Perf. (GOp/s)')."""
    from repro.core import HDIFF_SPEC

    interior = (rows - 4) * (cols - 4) * depth
    ops = interior * HDIFF_SPEC.flops
    return ops / (us_per_call * 1e-6) / 1e9
