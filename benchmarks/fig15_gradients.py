"""Fig. 15 (repo extension): gradient cost and adjoint wire exactness.

SPARTA's forward claims are cost-model claims (Fig. 10's measured-exact
halo bytes); the autodiff layer (ISSUE 10) extends both to the BACKWARD
pass, and this benchmark records that trajectory:

  * ``fig15/primal_k{k}`` / ``fig15/grad_k{k}`` — jit'd wall-clock of the
    differentiable hdiff lowering's forward vs its value-and-grad (the
    derived adjoint: augmented forward + reverse sweeps), with the
    grad/primal cost multiple in the derived column. The multiple is the
    adjoint's whole story — reverse-mode through a stencil costs a small
    constant factor, not a new algorithm; gradient parity vs jax.grad of
    ``lower_reference`` is asserted IN the run (a mismatch raises and
    fails the bench-smoke gate);
  * ``fig15/grad_8dev_wire_*`` — REAL 8-fake-device rows (subprocess, 2x4
    rows x cols mesh): measured collective-permute bytes of a compiled
    value-and-grad step vs ``gradient_halo_exchange_bytes_per_shard``.
    Because the backward runs through ``lower_sharded(...,
    boundary="zero")`` (zero-extension instead of pad/crop, whose resharding
    would add unmodeled permutes), the model is measured-EXACT: the
    ``ratio=`` in the derived column gates at [0.99, 1.01] in
    scripts/bench_smoke.py and the byte values gate against the committed
    baseline in scripts/bench_compare.py;
  * ``fig15/assimilation_loss_drop`` — the end-to-end consumer: factor by
    which the 3D-Var-style coefficient fit (repro.train.assimilate) drops
    its observation misfit in 40 steps (informational unit ``x``; the
    >=10x floor is asserted in tier-1, not here).
"""

from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

import benchmarks.common as _common
from benchmarks.common import emit, time_stats
from repro.ir import build_backend, hdiff_program, lower_reference, repeat

# Subprocess body for the real 8-fake-device backward-wire measurement (the
# main benchmark process must keep seeing 1 device, exactly like fig10's
# _REAL_CHECK). For each (program, k): gradient parity vs the reference
# oracle, then measured per-chip collective-permute bytes of the compiled
# value-and-grad step against the analytical backward wire model.
_GRAD_CHECK = """
import numpy as np, jax, jax.numpy as jnp
assert len(jax.devices()) == 8, jax.devices()
from repro.dist.halo import (
    gradient_halo_exchange_bytes_per_shard,
    measured_collective_permute_bytes,
)
from repro.ir import build_backend, repeat
from repro.ir import programs as P
from repro.ir.lower_reference import lower_reference

depth, rows, cols = {depth}, {rows}, {cols}
mesh = (2, 4)
rng = np.random.default_rng(0)


def fields_for(p):
    arrs = {{}}
    for f in p.inputs:
        a = rng.standard_normal((depth, rows, cols)).astype(np.float32)
        arrs[f] = jnp.asarray(np.abs(a) * 0.05 + 0.01 if f == "coeff" else a * 0.1)
    return arrs if len(p.inputs) > 1 else arrs[p.inputs[0]]


for label, base, k in (
    ("hdiff_k1", P.hdiff_program(), 1),
    ("hdiff_k2", P.hdiff_program(), 2),
    ("hdiff_coupled_k2", P.hdiff_coupled_program(), 2),
):
    p = repeat(base, k) if k > 1 else base
    x = fields_for(p)
    ref = lower_reference(p)(x)
    w = jax.tree_util.tree_map(
        lambda a: jnp.asarray(rng.standard_normal(a.shape).astype(a.dtype)), ref
    )
    fn = build_backend(p, "sharded-reference", mesh_shape=mesh, differentiable=True)

    def loss_of(f, w=w):
        def loss(x):
            y = f(x)
            if isinstance(y, dict):
                return sum(jnp.vdot(w[o], y[o]) for o in y)
            return jnp.vdot(w, y)
        return loss

    gref = jax.grad(loss_of(lower_reference(p)))(x)
    got = jax.grad(loss_of(fn))(x)
    num = sum(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(gref)))
    den = max(sum(float(jnp.abs(b).max())
                  for b in jax.tree_util.tree_leaves(gref)), 1e-30)
    assert num / den < 1e-5, (label, num / den)

    loss = loss_of(fn)
    measured, count = measured_collective_permute_bytes(
        lambda x: jax.value_and_grad(loss)(x), x)
    model = gradient_halo_exchange_bytes_per_shard(
        p, depth, rows, cols, mesh_shape=mesh)
    print(f"RESULTGRAD label={{label}} measured={{measured:.0f}} "
          f"model={{model:.0f}} permutes={{count}} relerr={{num / den:.2e}}")
"""


def _loss_weights(shape, seed=7):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def run(fast: bool = False) -> None:
    depth, rows, cols = _common.DEPTH, _common.ROWS, _common.COLS
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((depth, rows, cols)).astype(np.float32) * 0.1)
    w = _loss_weights(x.shape)

    # Single-device: forward vs value-and-grad wall-clock, gradient parity
    # vs the reference oracle asserted in-run.
    for k in (1, 2):
        p = repeat(hdiff_program(), k) if k > 1 else hdiff_program()
        fwd = build_backend(p, "reference", differentiable=True)

        def loss(x, fwd=fwd):
            return jnp.vdot(w, fwd(x))

        jf = jax.jit(fwd)
        jvg = jax.jit(jax.value_and_grad(loss))
        tp = time_stats(jf, x)
        tg = time_stats(jvg, x)

        def ref_loss(x, p=p):
            return jnp.vdot(w, lower_reference(p)(x))

        gref = jax.grad(ref_loss)(x)
        _, g = jvg(x)
        rel = float(jnp.abs(g - gref).max()) / float(jnp.abs(gref).max())
        if rel > 1e-5:
            raise AssertionError(f"fig15 grad parity k={k}: relerr {rel:.3e}")
        emit(
            f"fig15/primal_k{k}",
            tp.median_us,
            f"min={tp.min_us:.1f}us grid={depth}x{rows}x{cols}",
            unit="us",
        )
        emit(
            f"fig15/grad_k{k}",
            tg.median_us,
            f"min={tg.min_us:.1f}us grad/primal={tg.median_us / tp.median_us:.2f}x "
            f"relerr={rel:.1e} (derived adjoint: augmented fwd + reverse sweep)",
            unit="us",
        )

    # Real 8-fake-device backward wire bytes, measured vs model (subprocess).
    grad_wire_check(8 if fast else depth, rows, cols)

    # End-to-end consumer: the coefficient-field fit's loss drop.
    from repro.train import AssimilationConfig, fit_coefficient_field
    from repro.train.assimilate import synthetic_observations, true_coefficients

    grid = (2, 16, 16)
    cfg = AssimilationConfig(steps=40)
    u0 = jnp.asarray(rng.standard_normal(grid).astype(np.float32))
    coeff_true = true_coefficients(grid, seed=1)
    obs = synthetic_observations(u0, coeff_true, cfg)
    res = fit_coefficient_field(u0, obs, cfg)
    emit(
        "fig15/assimilation_loss_drop",
        res.loss_ratio,
        f"J0={res.losses[0]:.3e} Jmin={min(res.losses):.3e} "
        f"steps={cfg.steps} spikes={len(res.spikes)} "
        f"(hdiff_coupled coeff fit, AdamW lr={cfg.learning_rate})",
        unit="x",
    )


def grad_wire_check(depth: int, rows: int, cols: int) -> None:
    """Runs _GRAD_CHECK in a child with 8 fake devices and emits one
    measured-vs-model row per (program, k) case."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    env["PYTHONPATH"] = os.pathsep.join(filter(None, [src, env.get("PYTHONPATH")]))
    proc = subprocess.run(
        [sys.executable, "-c", _GRAD_CHECK.format(depth=depth, rows=rows, cols=cols)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    if proc.returncode != 0:
        emit("fig15/grad_8dev", 0.0, f"FAILED: {proc.stderr[-200:]!r}", unit="error")
        raise RuntimeError(f"real 8-device grad run failed:\n{proc.stderr[-2000:]}")
    for line in proc.stdout.splitlines():
        if not line.startswith("RESULTGRAD "):
            continue
        fields = dict(kv.split("=") for kv in line.split()[1:])
        measured, model = float(fields["measured"]), float(fields["model"])
        emit(
            f"fig15/grad_8dev_wire_{fields['label']}",
            measured,
            f"per-chip permute bytes of value_and_grad; model={model:.0f} "
            f"ratio={measured / model if model else float('nan'):.6f} "
            f"permutes={fields['permutes']} grad_relerr={fields['relerr']} "
            f"(2x4 mesh, backward through boundary='zero' sharding — "
            f"adjoint radii == primal radii, same wire plan)",
            unit="bytes",
        )
