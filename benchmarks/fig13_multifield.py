"""Fig. 13 (repo extension): multi-field IR programs — parity + per-field bytes.

NERO pairs hdiff with vertical advection (vadvc) and StencilFlow treats
weather programs as dataflow graphs over many named fields; this benchmark
measures what the multi-field IR stack (ISSUE 5) delivers for the two new
workloads, ``vadvc`` (velocity + scalar, both radius k) and
``hdiff_coupled`` (hdiff with a radius-0 diffusion-coefficient field):

  * single-device parity: the fused multi-input Pallas kernel (interpret
    mode on CPU) vs the composed reference oracle, k in {1, 2} — hard
    failure past 1e-6, like fig10/fig12;
  * graph-derived per-field accounting: reads per field (summing to the
    program total) and compulsory HBM bytes per simulated step (every
    field in once + output once, / k);
  * a REAL 8-fake-device run (subprocess): sharded parity on a 2 x 4
    rows x cols mesh and measured per-chip collective-permute bytes vs the
    per-field wire model ``program_halo_exchange_bytes_per_shard`` —
    hdiff_coupled at k=1 must move ZERO coefficient bytes, and every ratio
    must be exactly 1.000;
  * RESULTMO (ISSUE 8): the multi-OUTPUT coupled shallow-water system on
    the same 2 x 4 mesh, comparing the MERGED halo exchange (one stacked
    collective covering all evolving fields) against the sequential
    per-field baseline (``merge_exchange=False``): same per-chip bytes
    (both at ratio 1.000 vs the summed wire model), 8 vs 24 permutes, and
    the measured wall-clock for each.
"""

from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp

from benchmarks.common import COLS, ROWS, emit, time_stats
from repro.ir import (
    hdiff_coupled_program,
    lower_pallas,
    lower_reference,
    repeat,
    shallow_water_program,
    smagorinsky_coeff,
    vadvc_program,
)

KS = (1, 2)

_REAL_CHECK = """
import numpy as np, jax, jax.numpy as jnp
assert len(jax.devices()) == 8, jax.devices()
from repro.dist import program_halo_exchange_bytes_per_shard
from repro.ir import (
    hdiff_coupled_program, lower_reference, lower_sharded, repeat,
    smagorinsky_coeff, vadvc_program,
)
from repro.launch.dryrun import parse_collective_bytes

depth, rows, cols = {depth}, {rows}, {cols}
R, C = 2, 4
rng = np.random.default_rng(0)
g = lambda: jnp.asarray(rng.standard_normal((depth, rows, cols)).astype(np.float32))
cases = {{
    "vadvc": (vadvc_program(), {{"s": g(), "w": g()}}),
    "hdiff_coupled": (hdiff_coupled_program(), {{
        "u": g(),
        "coeff": jnp.asarray(
            smagorinsky_coeff(rng.standard_normal((depth, rows, cols)))),
    }}),
}}
for name, (prog, arrs) in cases.items():
    for k in (1, 2):
        pk = repeat(prog, k)
        want = np.asarray(lower_reference(pk)(arrs))
        fn = lower_sharded(pk, mesh_shape=(R, C), inner="reference")
        np.testing.assert_allclose(np.asarray(fn(arrs)), want, rtol=1e-6, atol=1e-6)
        coll = parse_collective_bytes(jax.jit(fn).lower(arrs).compile().as_text())
        measured = coll["bytes"].get("collective-permute", 0.0)
        model = program_halo_exchange_bytes_per_shard(
            pk, depth, rows // R, cols // C, row_sharded=True, col_sharded=True)
        print(f"RESULT name={{name}} k={{k}} measured={{measured:.0f}} "
              f"per_chip_model={{model:.0f}} "
              f"permutes={{coll['counts'].get('collective-permute', 0)}} parity=ok")
"""


_REAL_MO_CHECK = """
import numpy as np, jax, jax.numpy as jnp, time
assert len(jax.devices()) == 8, jax.devices()
from repro.dist import program_halo_exchange_bytes_per_shard
from repro.ir import lower_reference, lower_sharded, repeat, shallow_water_program
from repro.launch.dryrun import parse_collective_bytes

depth, rows, cols = {depth}, {rows}, {cols}
R, C = 2, 4
rng = np.random.default_rng(0)
g = lambda: jnp.asarray(rng.standard_normal((depth, rows, cols)).astype(np.float32))
arrs = {{"u": g(), "v": g(), "h": g()}}
for k in (1, 2):
    pk = repeat(shallow_water_program(), k)
    want = lower_reference(pk)(arrs)
    model = program_halo_exchange_bytes_per_shard(
        pk, depth, rows // R, cols // C, row_sharded=True, col_sharded=True)
    for mode, merged in (("merged", True), ("sequential", False)):
        fn = jax.jit(lower_sharded(pk, mesh_shape=(R, C), inner="reference",
                                   merge_exchange=merged))
        got = fn(arrs)
        for f in want:
            np.testing.assert_allclose(np.asarray(got[f]), np.asarray(want[f]),
                                       rtol=1e-6, atol=1e-6, err_msg=f)
        coll = parse_collective_bytes(fn.lower(arrs).compile().as_text())
        jax.block_until_ready(fn(arrs))
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(arrs))
            times.append(time.perf_counter() - t0)
        times.sort()
        print(f"RESULTMO mode={{mode}} k={{k}} "
              f"measured={{coll['bytes'].get('collective-permute', 0.0):.0f}} "
              f"per_chip_model={{model:.0f}} "
              f"permutes={{coll['counts'].get('collective-permute', 0)}} "
              f"median_us={{times[1] * 1e6:.1f}} parity=ok")
"""


def run(fast: bool = False) -> None:
    depth = 2 if fast else 8  # interpret-mode Pallas: keep planes modest
    rng = np.random.default_rng(0)
    g = lambda: jnp.asarray(  # noqa: E731
        rng.standard_normal((depth, ROWS, COLS)).astype(np.float32)
    )
    cases = {
        "vadvc": (vadvc_program(), {"s": g(), "w": g()}),
        "hdiff_coupled": (hdiff_coupled_program(), {
            "u": g(),
            "coeff": jnp.asarray(
                smagorinsky_coeff(rng.standard_normal((depth, ROWS, COLS)))
            ),
        }),
    }
    for name, (prog, arrs) in cases.items():
        points = arrs[prog.passthrough].size
        for k in KS:
            pk = repeat(prog, k)
            fn = lower_pallas(pk, interpret=True)
            want = np.asarray(lower_reference(pk)(arrs))
            got = np.asarray(fn(arrs))
            err = float(np.max(np.abs(got - want)))
            if err > 1e-6:
                raise AssertionError(
                    f"{name} k={k}: fused multi-input Pallas diverges from "
                    f"composed reference: max|d|={err:.1e}"
                )
            ts = time_stats(fn, arrs, warmup=1, iters=3)
            reads = pk.reads_by_field()
            emit(
                f"fig13/{name}_k{k}",
                ts.median_us / k,
                f"min_us={ts.min_us / k:.1f} "
                f"parity=ok(max|d|={err:.1e}) "
                f"hbm_bytes_per_step={pk.fused_bytes_per_step(points):.0f} "
                f"({len(pk.inputs)} fields in + out, /{k}) "
                f"reads_by_field={'+'.join(f'{f}:{n}' for f, n in reads.items())}"
                f"={sum(reads.values())} field_radii={pk.field_radii()}",
            )

    # Multi-OUTPUT single-device rows: the fused kernel writes all three
    # shallow-water outputs in one pass; parity is per output field.
    sw = shallow_water_program()
    sw_arrs = {f: g() for f in sw.inputs}
    points = sw_arrs[sw.passthrough].size
    for k in KS:
        pk = repeat(sw, k)
        fn = lower_pallas(pk, interpret=True)
        want = lower_reference(pk)(sw_arrs)
        got = fn(sw_arrs)
        err = max(
            float(np.max(np.abs(np.asarray(got[f]) - np.asarray(want[f]))))
            for f in want
        )
        if err > 1e-6:
            raise AssertionError(
                f"shallow_water k={k}: fused multi-output Pallas diverges "
                f"from composed reference: max|d|={err:.1e}"
            )
        ts = time_stats(fn, sw_arrs, warmup=1, iters=3)
        emit(
            f"fig13/shallow_water_k{k}",
            ts.median_us / k,
            f"min_us={ts.min_us / k:.1f} "
            f"parity=ok(max|d|={err:.1e}) "
            f"outputs={'+'.join(pk.outputs)} "
            f"hbm_bytes_per_step={pk.fused_bytes_per_step(points):.0f} "
            f"({len(pk.inputs)} fields in + {len(pk.outputs)} out, /{k}) "
            f"output_radii={pk.output_radii()}",
        )

    # REAL 8-fake-device run: sharded parity + measured per-field wire bytes.
    real_multifield_check(depth, ROWS, COLS)

    # RESULTMO: merged vs sequential exchange for the coupled system.
    real_multioutput_check(depth, ROWS, COLS)


def real_multifield_check(depth: int, rows: int, cols: int) -> None:
    """Runs _REAL_CHECK in a child with 8 fake devices; emits measured
    per-chip collective bytes against the per-field model per program/k."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = os.pathsep.join(filter(None, [src, env.get("PYTHONPATH")]))
    proc = subprocess.run(
        [sys.executable, "-c", _REAL_CHECK.format(depth=depth, rows=rows, cols=cols)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    if proc.returncode != 0:
        emit("fig13/real_8dev", 0.0, f"FAILED: {proc.stderr[-200:]!r}", unit="error")
        raise RuntimeError(f"real 8-device multi-field run failed:\n{proc.stderr[-2000:]}")
    for line in proc.stdout.splitlines():
        if not line.startswith("RESULT "):
            continue
        fields = dict(kv.split("=") for kv in line.split()[1:])
        measured, model = float(fields["measured"]), float(fields["per_chip_model"])
        emit(
            f"fig13/real_8dev_{fields['name']}_k{fields['k']}",
            measured,
            f"per-chip permute bytes, per-field sum; model={model:.0f} "
            f"ratio={measured / model if model else float('nan'):.6f} "
            f"permutes={fields['permutes']} parity={fields['parity']} "
            f"(2x4 rows x cols mesh; hdiff_coupled k=1 moves zero coeff bytes)",
            unit="bytes",
        )
        if measured != model:
            raise RuntimeError(
                f"multi-field wire bytes diverged from the per-field model: "
                f"{fields['name']} k={fields['k']} measured={measured} model={model}"
            )


def real_multioutput_parse(stdout: str) -> dict[tuple[str, str], dict[str, str]]:
    """RESULTMO lines as ``{(mode, k): fields}`` — split out for testing."""
    rows = {}
    for line in stdout.splitlines():
        if not line.startswith("RESULTMO "):
            continue
        fields = dict(kv.split("=") for kv in line.split()[1:])
        rows[(fields["mode"], fields["k"])] = fields
    return rows


def real_multioutput_check(depth: int, rows: int, cols: int) -> None:
    """Runs _REAL_MO_CHECK in a child with 8 fake devices: the coupled
    shallow-water system, merged vs sequential halo exchange on the 2 x 4
    mesh. Emits per-chip byte rows (both modes must sit at ratio 1.000
    against the summed per-output wire model — the merged exchange changes
    the PERMUTE COUNT, 8 vs 24, never the bytes) and the measured
    wall-clock row for each mode."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = os.pathsep.join(filter(None, [src, env.get("PYTHONPATH")]))
    proc = subprocess.run(
        [sys.executable, "-c", _REAL_MO_CHECK.format(depth=depth, rows=rows, cols=cols)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    if proc.returncode != 0:
        emit("fig13/real_8dev_multioutput", 0.0, f"FAILED: {proc.stderr[-200:]!r}",
             unit="error")
        raise RuntimeError(
            f"real 8-device multi-output run failed:\n{proc.stderr[-2000:]}"
        )
    parsed = real_multioutput_parse(proc.stdout)
    for (mode, k), fields in sorted(parsed.items()):
        measured, model = float(fields["measured"]), float(fields["per_chip_model"])
        emit(
            f"fig13/real_8dev_shallow_water_{mode}_k{k}",
            measured,
            f"per-chip permute bytes, merged-vs-sequential exchange; "
            f"model={model:.0f} "
            f"ratio={measured / model if model else float('nan'):.6f} "
            f"permutes={fields['permutes']} parity={fields['parity']} "
            f"(2x4 rows x cols mesh, outputs u+v+h)",
            unit="bytes",
        )
        emit(
            f"fig13/real_8dev_shallow_water_{mode}_k{k}_wall",
            float(fields["median_us"]),
            f"median step wall-clock, {mode} exchange, 8 fake CPU devices "
            f"(permutes={fields['permutes']})",
            unit="model_us",
        )
        if measured != model:
            raise RuntimeError(
                f"multi-output wire bytes diverged from the summed model: "
                f"{mode} k={k} measured={measured} model={model}"
            )
    for k in ("1", "2"):
        merged, seq = parsed[("merged", k)], parsed[("sequential", k)]
        if merged["measured"] != seq["measured"]:
            raise RuntimeError(
                f"merged exchange changed wire bytes at k={k}: "
                f"{merged['measured']} != {seq['measured']}"
            )
        if not (int(merged["permutes"]) < int(seq["permutes"])):
            raise RuntimeError(
                f"merged exchange did not reduce permute count at k={k}: "
                f"{merged['permutes']} vs {seq['permutes']}"
            )
