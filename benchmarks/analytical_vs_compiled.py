"""§3.1 validation: the analytical model vs XLA's compiled cost analysis.

The paper validates its Eq. 5-10 model against hardware; we validate ours
against the compiler: flops from `cost_analysis()` of the jitted fused
hdiff must match Eq. 5-7's op counts (as flops), and the compiled bytes
must land between the fused lower bound and the algorithmic upper bound.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import COLS, DEPTH, ROWS, emit
from repro.core import (
    hdiff,
    hdiff_algorithmic_bytes,
    hdiff_flops,
    hdiff_min_bytes,
)


def run(fast: bool = False) -> None:
    depth = 8 if fast else DEPTH
    x = jax.ShapeDtypeStruct((depth, ROWS, COLS), jnp.float32)
    compiled = jax.jit(lambda a: hdiff(a, 0.025)).lower(x).compile()
    cost = compiled.cost_analysis() or {}
    hlo_flops = float(cost.get("flops", 0))
    hlo_bytes = float(cost.get("bytes accessed", 0))

    model_flops = hdiff_flops(depth, ROWS, COLS)
    lo = hdiff_min_bytes(depth, ROWS, COLS)
    hi = hdiff_algorithmic_bytes(depth, ROWS, COLS)

    emit("analytic/flops_model", model_flops, "Eq.5-7 op count as flops",
         unit="flops")
    emit("analytic/flops_hlo", hlo_flops,
         f"ratio hlo/model={hlo_flops/model_flops:.2f}", unit="flops")
    emit("analytic/bytes_hlo", hlo_bytes,
         f"fused_bound={lo:.3e} algorithmic_bound={hi:.3e} "
         f"within_bounds={lo * 0.5 <= hlo_bytes <= hi * 1.5}", unit="bytes")
