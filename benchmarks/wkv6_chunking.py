"""Beyond-paper: RWKV-6 WKV recurrence — sequential scan vs chunked form.

The chunked formulation (kernels/wkv6) is the paper's accumulator-residency
insight applied to a matrix-state recurrence: S/chunk sequential steps with
dense MXU matmuls inside, instead of S elementwise steps. The dry-run's
FLOP counters cannot see sequentiality, so this benchmark measures the real
effect as wall time (CPU here; the structure, S -> S/64 dependent steps, is
hardware-independent).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.wkv6 import wkv6_chunked_ref, wkv6_ref


def run(fast: bool = False) -> None:
    b, t, h, n = (1, 512, 4, 64) if fast else (2, 2048, 8, 64)
    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.standard_normal((b, t, h, n)).astype(np.float32)) * 0.5
    k = jnp.asarray(rng.standard_normal((b, t, h, n)).astype(np.float32)) * 0.5
    v = jnp.asarray(rng.standard_normal((b, t, h, n)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.9, 0.999, (b, t, h, n)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((h, n)).astype(np.float32)) * 0.3

    seq = jax.jit(lambda *a: wkv6_ref(*a)[0])
    us_seq = time_fn(seq, r, k, v, w, u)
    emit("wkv6/sequential_scan", us_seq, f"T={t} sequential steps")

    for chunk in (16, 64, 128):
        ch = jax.jit(lambda *a, c=chunk: wkv6_chunked_ref(*a, chunk=c)[0])
        us_ch = time_fn(ch, r, k, v, w, u)
        emit(
            f"wkv6/chunked_{chunk}",
            us_ch,
            f"{t//chunk} steps; speedup {us_seq/us_ch:.1f}x vs sequential",
        )

    # numerical agreement check rides along
    y_seq, _ = wkv6_ref(r, k, v, w, u)
    y_ch, _ = wkv6_chunked_ref(r, k, v, w, u, chunk=64)
    err = float(jnp.abs(y_seq - y_ch).max())
    emit("wkv6/chunked_max_abs_err", err, "vs sequential oracle", unit="abs_err")
