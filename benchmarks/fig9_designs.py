"""Fig. 9 analogue: hdiff runtime across execution designs.

Paper: single-AIE (f32/i32) vs dual/tri-AIE pipelines — the win comes from
keeping intermediates on-chip and splitting stages across cores.
TPU mapping (DESIGN.md §2): ``staged`` (every stage through HBM, barriered)
is the single-core/load-store baseline; ``fused-xla`` lets the compiler fuse;
``fused-pallas`` is the hand-fused kernel (interpret mode on CPU, so its
wall time here is a CORRECTNESS datapoint, not a speed claim — the TPU-side
claim is the roofline bytes ratio, also printed).

Also reproduces the paper's f32-vs-i32 comparison (fixed-point datapath).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import COLS, DEPTH, ROWS, emit, hdiff_gops, time_fn
from repro.core import (
    hdiff,
    hdiff_algorithmic_bytes,
    hdiff_min_bytes,
    hdiff_simple,
    hdiff_staged,
)
from repro.kernels.hdiff import hdiff_fixed, hdiff_fused


def run(fast: bool = False) -> None:
    depth = 8 if fast else DEPTH
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0, 1, size=(depth, ROWS, COLS)).astype(np.float32))
    xq = jnp.asarray((np.asarray(x) * 2**16).astype(np.int32))

    us = time_fn(lambda a: hdiff_staged(a, 0.025), x)
    emit("fig9/staged_f32", us, f"gops={hdiff_gops(us, depth=depth):.2f}")

    fused = jax.jit(lambda a: hdiff(a, 0.025))
    us_fused = time_fn(fused, x)
    emit("fig9/fused_xla_f32", us_fused, f"gops={hdiff_gops(us_fused, depth=depth):.2f}")

    simple = jax.jit(lambda a: hdiff_simple(a, 0.025))
    us_s = time_fn(simple, x)
    emit("fig9/fused_xla_f32_nolimit", us_s, f"gops={hdiff_gops(us_s, depth=depth):.2f}")

    # Pallas fused kernel, interpret mode (correctness-path timing only).
    pall = lambda a: hdiff_fused(a, 0.025, interpret=True)  # noqa: E731
    us_p = time_fn(pall, x, warmup=1, iters=3)
    emit("fig9/fused_pallas_interpret_f32", us_p, "interpret-mode; not a TPU speed claim")

    # i32 fixed-point datapath (paper §5.1.1 compares f32 vs i32).
    fixed = lambda a: hdiff_fixed(a, interpret=True)  # noqa: E731
    us_q = time_fn(fixed, xq, warmup=1, iters=3)
    emit("fig9/fused_pallas_interpret_i32", us_q, "fixed-point datapath")

    # The structural claim, hardware-independent: fused moves ~11x fewer
    # HBM bytes than the staged/algorithmic traffic model. THIS is what the
    # paper's multi-AIE design buys on a bandwidth-bound accelerator; a
    # cache-hierarchy CPU absorbs the staged traffic, so the CPU wall-clock
    # ratio below is NOT the paper's claim — the bytes ratio is.
    algo = hdiff_algorithmic_bytes(depth, ROWS, COLS)
    fmin = hdiff_min_bytes(depth, ROWS, COLS)
    emit("fig9/bytes_staged_over_fused", algo / fmin,
         f"staged={algo/1e6:.1f}MB fused={fmin/1e6:.1f}MB (x{algo/fmin:.1f} reuse)",
         unit="x")
    emit("fig9/tpu_projected_speedup_staged_to_fused", algo / fmin,
         "v5e projection: both policies are HBM-bound, so speedup ~= bytes "
         "ratio (paper's tri-AIE speedup is 3.5x, pipeline-limited)", unit="x")
    emit("fig9/cpu_walltime_ratio_staged_to_fused", us / us_fused,
         "CPU caches hide staged traffic; informational only", unit="x")

    # Temporal blocking (beyond-paper, from the paper's own §1 insight):
    # two timesteps per HBM pass halves compulsory traffic per step.
    from repro.kernels.hdiff.multistep import hdiff_twostep

    us_2 = time_fn(lambda a: hdiff_twostep(a, 0.025, interpret=True), x,
                   warmup=1, iters=3)
    emit("fig9/twostep_pallas_interpret", us_2,
         "2 steps/HBM-pass: compulsory bytes per step halve (interpret timing)")
