"""Fig. 10 analogue: hdiff scaling across compute shards (B-block scaling).

Paper: 1 -> 32 B-blocks scales 32.6x (each block owns a shimDMA channel;
depth-parallel planes -> no contention). TPU mapping: depth-parallel
shard_map over the data axis (zero collectives) and row-decomposition with
halo exchange over the model axis.

On this 1-core CPU container, real multi-device wall time cannot show
speedup, so this benchmark reports:
  * the §3.1-style analytical step time per shard count (what Fig. 10
    measures on hardware), via `plan_partition`,
  * a REAL 8-fake-device correctness + collective-structure run (subprocess),
    recording measured halo bytes vs the model.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import COLS, DEPTH, ROWS, emit
from repro.core import TPUV5E, hdiff_flops, plan_partition


def run(fast: bool = False) -> None:
    shard_counts = [1, 2, 4, 8, 16, 32]
    t1 = None
    for n in shard_counts:
        plan = plan_partition(DEPTH, ROWS, COLS, n)
        if t1 is None:
            t1 = plan.step_s
        speedup = t1 / plan.step_s
        emit(
            f"fig10/shards_{n:02d}",
            plan.step_s * 1e6,
            f"kind={plan.kind} speedup={speedup:.1f}x ici_s={plan.ici_s:.2e}",
        )
    # The paper's headline: 32 blocks -> 32.6x over 1 block (linear).
    plan32 = plan_partition(DEPTH, ROWS, COLS, 32)
    emit("fig10/speedup_at_32", t1 / plan32.step_s,
         f"paper reports 32.6x at 32 B-blocks; depth-parallel model gives "
         f"{t1/plan32.step_s:.1f}x (linear, no collectives)")

    # Halo traffic model when forced to row-decompose (beyond 64 shards the
    # paper's plane-parallel strategy runs out of planes; ours does too).
    for n in [64, 128, 256]:
        plan = plan_partition(DEPTH, ROWS, COLS, n)
        emit(
            f"fig10/shards_{n:03d}",
            plan.step_s * 1e6,
            f"kind={plan.kind} rows/shard={ROWS//plan.row_shards} "
            f"ici_s={plan.ici_s:.2e} (halo exchange appears)",
        )
