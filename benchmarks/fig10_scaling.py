"""Fig. 10 analogue: hdiff scaling across compute shards (B-block scaling).

Paper: 1 -> 32 B-blocks scales 32.6x (each block owns a shimDMA channel;
depth-parallel planes -> no contention). TPU mapping: depth-parallel
shard_map over the data axis (zero collectives) and row-decomposition with
halo exchange over the model axis.

On this 1-core CPU container, real multi-device wall time cannot show
speedup, so this benchmark reports:
  * the §3.1-style analytical step time per shard count (what Fig. 10
    measures on hardware), via `plan_partition`,
  * a REAL 8-fake-device correctness + collective-structure run (subprocess),
    recording measured halo bytes vs the model.
"""

from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import COLS, DEPTH, ROWS, emit
from repro.core import plan_partition

# Subprocess body for the REAL run: the main benchmark process must keep
# seeing 1 device (dry-run contract), so the 8-fake-device mesh lives in a
# child. Verifies sharded == single-device on the paper's grid and measures
# the per-chip collective-permute (halo) bytes from compiled HLO against
# the analytical model.
_REAL_CHECK = """
import numpy as np, jax, jax.numpy as jnp
assert len(jax.devices()) == 8, jax.devices()  # locks backend BEFORE dryrun import
from repro.core import HALO, hdiff
from repro.dist import halo_exchange_bytes, make_sharded_hdiff
from repro.launch.dryrun import parse_collective_bytes
from repro.launch.mesh import make_mesh

depth, rows, cols, dshards, rshards = {depth}, {rows}, {cols}, 2, 4
mesh = make_mesh((dshards, rshards), ("data", "model"))
fn = make_sharded_hdiff(mesh, depth_axis="data", row_axis="model")

rng = np.random.default_rng(0)
psi = jnp.asarray(rng.standard_normal((depth, rows, cols)).astype(np.float32))
np.testing.assert_allclose(
    np.asarray(fn(psi)), np.asarray(hdiff(psi, 0.025)), rtol=1e-6, atol=1e-6
)

coll = parse_collective_bytes(jax.jit(fn).lower(psi).compile().as_text())
measured = coll["bytes"].get("collective-permute", 0.0)
# parse_collective_bytes reports PER-CHIP bytes (SPMD program, interior
# chip: both halos); halo_exchange_bytes totals the mesh.
per_chip_model = 2 * (depth // dshards) * HALO * cols * 4
print(f"RESULT measured={{measured:.0f}} per_chip_model={{per_chip_model:.0f}} "
      f"mesh_total_model={{halo_exchange_bytes(depth, rows, cols, rshards):.0f}} "
      f"permutes={{coll['counts'].get('collective-permute', 0)}}")

# Temporal blocking: k=2 fused sweeps exchange a depth-2*HALO band ONCE.
from repro.ir import hdiff_program, lower_sharded, repeat
k = 2
fn2 = lower_sharded(repeat(hdiff_program(), k), mesh,
                    depth_axis="data", row_axis="model", inner="reference")
np.testing.assert_allclose(
    np.asarray(fn2(psi)), np.asarray(hdiff(hdiff(psi, 0.025), 0.025)),
    rtol=1e-6, atol=1e-6,
)
coll2 = parse_collective_bytes(jax.jit(fn2).lower(psi).compile().as_text())
measured2 = coll2["bytes"].get("collective-permute", 0.0)
per_chip_model2 = 2 * (depth // dshards) * k * HALO * cols * 4
print(f"RESULT2 measured={{measured2:.0f}} per_chip_model={{per_chip_model2:.0f}} "
      f"mesh_total_model={{halo_exchange_bytes(depth, rows, cols, rshards, steps=k):.0f}} "
      f"permutes={{coll2['counts'].get('collective-permute', 0)}}")

# 2-D rows x cols decomposition (ISSUE 4): row bands + col bands + diagonal
# corners, measured per-chip against the 2-axis model — and overlap=True
# must BIT-match overlap=False at identical wire bytes.
from repro.dist import halo_exchange_bytes_per_shard
from repro.ir import plan_partition
prog = hdiff_program()
plan = plan_partition(prog, depth, rows, cols, 8)
R, C = plan.mesh_shape
fn2d = lower_sharded(prog, mesh_shape=(R, C), inner="reference")
got2d = np.asarray(fn2d(psi))
np.testing.assert_allclose(got2d, np.asarray(hdiff(psi, 0.025)), rtol=1e-6, atol=1e-6)
coll2d = parse_collective_bytes(jax.jit(fn2d).lower(psi).compile().as_text())
measured2d = coll2d["bytes"].get("collective-permute", 0.0)
model2d = halo_exchange_bytes_per_shard(
    depth, rows // R, cols // C, halo=HALO, row_sharded=R > 1, col_sharded=C > 1)
row_m = 2 * depth * HALO * (cols // C) * 4 if R > 1 else 0
col_m = 2 * depth * (rows // R) * HALO * 4 if C > 1 else 0
corner_m = 4 * depth * HALO * HALO * 4 if (R > 1 and C > 1) else 0
assert row_m + col_m + corner_m == model2d, (row_m, col_m, corner_m, model2d)
fo2d = lower_sharded(prog, mesh_shape=(R, C), inner="reference", overlap=True)
ov = np.asarray(fo2d(psi))
bit_match = bool((ov == got2d).all())
collov = parse_collective_bytes(jax.jit(fo2d).lower(psi).compile().as_text())
measured_ov = collov["bytes"].get("collective-permute", 0.0)
assert measured_ov == measured2d, (measured_ov, measured2d)  # overlap moves the same bytes
print(f"RESULT2D mesh={{R}}x{{C}} measured={{measured2d:.0f}} per_chip_model={{model2d:.0f}} "
      f"row_model={{row_m}} col_model={{col_m}} corner_model={{corner_m}} "
      f"mesh_total_model={{halo_exchange_bytes(depth, rows, cols, R, col_shards=C):.0f}} "
      f"permutes={{coll2d['counts'].get('collective-permute', 0)}} "
      f"overlap_bitmatch={{bit_match}} overlap_measured={{measured_ov:.0f}}")

# Multi-field per-field wire sum (ISSUE 5): vadvc exchanges BOTH its fields'
# radius-1 bands on the depth x rows mesh — the per-field model must stay
# measured-exact, like the single-field lines above.
from repro.dist import program_halo_exchange_bytes_per_shard
from repro.ir import vadvc_program
vprog = vadvc_program()
fnmf = lower_sharded(vprog, mesh, depth_axis="data", row_axis="model",
                     inner="reference")
varrs = {{"s": psi, "w": jnp.asarray(rng.standard_normal(psi.shape).astype(np.float32))}}
from repro.ir import lower_reference
np.testing.assert_allclose(
    np.asarray(fnmf(varrs)), np.asarray(lower_reference(vprog)(varrs)),
    rtol=1e-6, atol=1e-6,
)
collmf = parse_collective_bytes(jax.jit(fnmf).lower(varrs).compile().as_text())
measured_mf = collmf["bytes"].get("collective-permute", 0.0)
model_mf = program_halo_exchange_bytes_per_shard(
    vprog, depth // dshards, rows // rshards, cols, row_sharded=True)
print(f"RESULTMF measured={{measured_mf:.0f}} per_chip_model={{model_mf:.0f}} "
      f"permutes={{collmf['counts'].get('collective-permute', 0)}}")
"""


def run(fast: bool = False) -> None:
    shard_counts = [1, 2, 4, 8, 16, 32]
    t1 = None
    for n in shard_counts:
        plan = plan_partition(DEPTH, ROWS, COLS, n)
        if t1 is None:
            t1 = plan.step_s
        speedup = t1 / plan.step_s
        emit(
            f"fig10/shards_{n:02d}",
            plan.step_s * 1e6,
            f"kind={plan.kind} speedup={speedup:.1f}x ici_s={plan.ici_s:.2e}",
            unit="model_us",
        )
    # The paper's headline: 32 blocks -> 32.6x over 1 block (linear).
    plan32 = plan_partition(DEPTH, ROWS, COLS, 32)
    emit("fig10/speedup_at_32", t1 / plan32.step_s,
         f"paper reports 32.6x at 32 B-blocks; depth-parallel model gives "
         f"{t1/plan32.step_s:.1f}x (linear, no collectives)", unit="x")

    # Halo traffic model when forced to row-decompose (beyond 64 shards the
    # paper's plane-parallel strategy runs out of planes; ours does too).
    for n in [64, 128, 256]:
        plan = plan_partition(DEPTH, ROWS, COLS, n)
        emit(
            f"fig10/shards_{n:03d}",
            plan.step_s * 1e6,
            f"kind={plan.kind} rows/shard={ROWS//plan.row_shards} "
            f"ici_s={plan.ici_s:.2e} (halo exchange appears)",
            unit="model_us",
        )

    # 2-D rows x cols factorization: wire bytes per exchange round for every
    # factorization of 8 devices, and the planner's pick (the balanced split
    # minimizes boundary surface — the paper's workload-balance point).
    from repro.dist import halo_exchange_bytes
    from repro.ir import hdiff_program, plan_partition as plan_2d

    prog = hdiff_program()
    for r_sh, c_sh in [(8, 1), (4, 2), (2, 4), (1, 8)]:
        wire = halo_exchange_bytes(
            DEPTH, ROWS, COLS, r_sh, halo=prog.radius, col_shards=c_sh
        )
        emit(
            f"fig10/wire_2d_{r_sh}x{c_sh}",
            wire,
            "mesh-total halo bytes/round, 2-axis model (bands + corners)",
            unit="bytes",
        )
    pick = plan_2d(prog, DEPTH, ROWS, COLS, 8)
    emit(
        "fig10/wire_2d_planned",
        pick.wire_bytes,
        f"plan_partition pick {pick.row_shards}x{pick.col_shards} "
        f"(<= 1-D row baseline "
        f"{halo_exchange_bytes(DEPTH, ROWS, COLS, 8, halo=prog.radius)})",
        unit="bytes",
    )

    # REAL 8-fake-device run: correctness + measured halo bytes vs model.
    depth = 8 if fast else DEPTH
    real_halo_check(depth, ROWS, COLS)


def real_halo_check(depth: int, rows: int, cols: int) -> None:
    """Runs _REAL_CHECK in a child with 8 fake devices and emits the
    measured collective-permute bytes against the analytical model."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = os.pathsep.join(filter(None, [src, env.get("PYTHONPATH")]))
    proc = subprocess.run(
        [sys.executable, "-c", _REAL_CHECK.format(depth=depth, rows=rows, cols=cols)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    if proc.returncode != 0:
        emit("fig10/real_8dev", 0.0, f"FAILED: {proc.stderr[-200:]!r}", unit="error")
        raise RuntimeError(f"real 8-device halo run failed:\n{proc.stderr[-2000:]}")
    line = next(l for l in proc.stdout.splitlines() if l.startswith("RESULT "))
    fields = dict(kv.split("=") for kv in line.split()[1:])
    measured, model = float(fields["measured"]), float(fields["per_chip_model"])
    emit(
        "fig10/real_8dev_halo_bytes",
        measured,
        f"per-chip permute bytes; model={model:.0f} "
        f"ratio={measured / model if model else float('nan'):.6f} "
        f"mesh_total_model={fields['mesh_total_model']} "
        f"permutes={fields['permutes']} (2x4 mesh, depth x row decomposition, "
        f"sharded==single-device verified)",
        unit="bytes",
    )
    line2 = next(l for l in proc.stdout.splitlines() if l.startswith("RESULT2 "))
    fields2 = dict(kv.split("=") for kv in line2.split()[1:])
    measured2, model2 = float(fields2["measured"]), float(fields2["per_chip_model"])
    emit(
        "fig10/real_8dev_halo_bytes_k2",
        measured2,
        f"per-chip permute bytes for ONE exchange serving k=2 fused sweeps; "
        f"model={model2:.0f} ratio={measured2 / model2 if model2 else float('nan'):.6f} "
        f"mesh_total_model={fields2['mesh_total_model']} "
        f"permutes={fields2['permutes']} (exchange ROUNDS per simulated step "
        f"halve; repeat(hdiff,2)==hdiff∘hdiff verified)",
        unit="bytes",
    )
    line3 = next(l for l in proc.stdout.splitlines() if l.startswith("RESULT2D "))
    fields3 = dict(kv.split("=") for kv in line3.split()[1:])
    measured3, model3 = float(fields3["measured"]), float(fields3["per_chip_model"])
    emit(
        "fig10/real_8dev_2d_halo_bytes",
        measured3,
        f"per-chip permute bytes on the planner-chosen {fields3['mesh']} "
        f"rows x cols mesh; model={model3:.0f} "
        f"ratio={measured3 / model3 if model3 else float('nan'):.6f} "
        f"(row_bands={fields3['row_model']} col_bands={fields3['col_model']} "
        f"corners={fields3['corner_model']}) "
        f"mesh_total_model={fields3['mesh_total_model']} "
        f"permutes={fields3['permutes']} (2-D decomposition verified vs "
        f"single-device)",
        unit="bytes",
    )
    emit(
        "fig10/real_8dev_2d_overlap",
        1.0 if fields3["overlap_bitmatch"] == "True" else 0.0,
        f"overlap=True bit-matches overlap=False on the {fields3['mesh']} mesh "
        f"(interior compute issued concurrently with the edge exchange); "
        f"overlap wire bytes {fields3['overlap_measured']} == "
        f"{measured3:.0f} non-overlap",
        unit="bool",
    )
    if fields3["overlap_bitmatch"] != "True":
        raise RuntimeError("overlap=True did not bit-match overlap=False")
    line4 = next(l for l in proc.stdout.splitlines() if l.startswith("RESULTMF "))
    fields4 = dict(kv.split("=") for kv in line4.split()[1:])
    measured4, model4 = float(fields4["measured"]), float(fields4["per_chip_model"])
    emit(
        "fig10/real_8dev_multifield_halo_bytes",
        measured4,
        f"per-chip permute bytes for vadvc (BOTH fields' radius-1 bands, "
        f"per-field sum model); model={model4:.0f} "
        f"ratio={measured4 / model4 if model4 else float('nan'):.6f} "
        f"permutes={fields4['permutes']} (depth x rows mesh, parity verified)",
        unit="bytes",
    )
