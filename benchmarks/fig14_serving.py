"""Fig. 14 (repo extension): forecast-serving throughput vs ensemble batch.

SPARTA's scale-out argument is throughput per resource; the serving layer
(ISSUE 9) makes the same argument at the request level: N compatible
forecast requests dispatched as ONE vmapped kernel (``lower_batched``
through the fingerprint-keyed compile cache) vs N sequential dispatches of
the unbatched lowering. This benchmark measures that curve end-to-end
through :class:`repro.serve.ForecastServer` — submit + admission grouping
+ cached batched execution — for batch sizes 1 / 2 / 4 / 8 on the k=2
temporally-blocked hdiff program:

  * ``fig14/sequential`` — the baseline: N=8 forecasts, one unbatched
    dispatch each (the server capped at max_batch=1), in forecasts/sec;
  * ``fig14/batch{N}`` — the same 8 forecasts admitted in waves of N
    members, in forecasts/sec, with ``speedup=`` vs sequential in the
    derived column. Throughput rows are tagged ``rate_info`` —
    informational, never gated (CPU wall-clock noise);

Requests are NOWCAST-TILE sized — ``(1, ROWS/4, COLS/4)`` of the ambient
benchmark grid — deliberately smaller than the fig10-13 stencil grids:
the serving curve measures what admission + batched dispatch amortise
(scheduler steps, cache lookups, kernel launches — per-batch costs), and
that is visible exactly where per-request compute does not drown it. At
compute-bound grids on a serial CPU the curve flattens to ~1x by
construction (the flops are the flops); kernel-level scaling is
fig10-13's business.
  * ``fig14/cache_hit_rate`` — the hit rate of a DETERMINISTIC request
    schedule (two identical waves over four batch shapes: 4 misses then 4
    hits = 0.5 exactly) against a fresh cache, tagged ``rate`` — this row
    IS gated by scripts/bench_compare.py (machine-independent, so any
    drift means the admission/caching logic changed);
  * ``fig14/warm_traces`` — jax traces performed by the warm half of that
    schedule, ``rate``-gated at exactly 0: the zero-retrace invariant as a
    trajectory row, not just a test assertion.

Parity is verified IN the same run, like fig10/12/13: every served result
must be bit-identical to the unbatched lowering applied to that request's
fields — a mismatch raises and fails the bench-smoke gate.
"""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

import benchmarks.common as _common
from benchmarks.common import emit
from repro.ir import hdiff_program, repeat
from repro.serve import CompileCache, ForecastServer

K = 2
N_FORECASTS = 8
BATCH_SIZES = (1, 2, 4, 8)


def _serve_grid():
    """The per-request nowcast tile (see module docstring): depth-1, a
    quarter of the ambient benchmark rows/cols each way, floored so the
    k=2 hdiff halo (radius 4) always fits. Reads the ambient grid at CALL
    time, so scripts/bench_smoke.py's reduced-grid patch applies no matter
    the import order."""
    return (1, max(32, _common.ROWS // 4), max(32, _common.COLS // 4))


def _member_fields(n, seed=2024):
    rng = np.random.default_rng(seed)
    return [
        jnp.asarray(rng.standard_normal(_serve_grid()).astype(np.float32))
        for _ in range(n)
    ]


def _drain(srv, prog, fields):
    """Serve ``len(fields)`` forecasts through ``srv`` (submit + admission
    + batched execution + unstack); returns the drain's wall seconds."""
    t0 = time.perf_counter()
    for f in fields:
        srv.submit(prog, f)
    done = srv.run_until_idle()
    dt = time.perf_counter() - t0
    assert len(done) == len(fields) and not any(r.failed for r in done)
    return dt


def _assert_parity(srv, prog, fields):
    """Every served result must BIT-match the unbatched lowering on the
    same fields — the batched-vs-unbatched contract, checked in-run."""
    rids = [srv.submit(prog, f) for f in fields]
    done = {r.rid: r for r in srv.run_until_idle()}
    base = srv.cache.get(prog, grid=_serve_grid())
    for rid, f in zip(rids, fields):
        np.testing.assert_array_equal(
            np.asarray(done[rid].result), np.asarray(base(f)),
            err_msg=f"fig14 parity: batched rid={rid} != unbatched",
        )


def _deterministic_cache_rows():
    """The gated rows: a fixed schedule (two identical waves across the
    four batch shapes) against a fresh cache has EXACTLY 4 misses + 4 hits
    (rate 0.5) and a trace-free second wave — on any machine."""
    prog = repeat(hdiff_program(), K)
    cache = CompileCache(capacity=16)
    fields = _member_fields(max(BATCH_SIZES), seed=7)
    for wave in range(2):
        for n in BATCH_SIZES:
            srv = ForecastServer(max_batch=n, cache=cache)
            for f in fields[:n]:
                srv.submit(prog, f)
            srv.run_until_idle()
    stats = cache.stats()
    assert stats == {
        "hits": 4, "misses": 4, "evictions": 0, "size": 4, "capacity": 16,
    }, f"fig14 cache schedule drifted: {stats}"
    emit(
        "fig14/cache_hit_rate",
        cache.hit_rate,
        f"hits={stats['hits']} misses={stats['misses']} "
        f"schedule=2x{list(BATCH_SIZES)}",
        unit="rate",
    )
    warm_traces = cache.total_traces() - stats["misses"]
    emit(
        "fig14/warm_traces",
        float(warm_traces),
        f"total_traces={cache.total_traces()} (one per miss; warm wave adds 0)",
        unit="rate",
    )


def run(fast: bool = False):
    # Drains are small (tile-sized requests), so even fast mode can afford
    # many rounds. Rounds INTERLEAVE the batch sizes — every round drains
    # the queue once per configuration back-to-back — so a slow system
    # phase (shared CI runner, GC) taxes every point of the curve, not
    # whichever configuration happened to be measuring; each point then
    # reports its best-of-rounds (common.Timing.min_us rationale:
    # scheduling noise only ever adds time).
    warmup, rounds = (2, 12) if fast else (3, 20)
    prog = repeat(hdiff_program(), K)
    fields = _member_fields(N_FORECASTS)

    # One shared cache across the whole curve: the batch-size axis is part
    # of the compile key, so every max_batch gets its own entry and the
    # timed drains all run warm.
    cache = CompileCache(capacity=16)
    servers = {n: ForecastServer(max_batch=n, cache=cache) for n in BATCH_SIZES}

    for _ in range(warmup):  # traces land here, never in a timed round
        for srv in servers.values():
            _drain(srv, prog, fields)
    best = {n: float("inf") for n in BATCH_SIZES}
    for _ in range(rounds):
        for n, srv in servers.items():
            best[n] = min(best[n], _drain(srv, prog, fields))

    seq_rate = N_FORECASTS / best[1]
    d, r, c = _serve_grid()
    emit(
        "fig14/sequential",
        seq_rate,
        f"forecasts/s n={N_FORECASTS} k={K} grid={d}x{r}x{c}",
        unit="rate_info",
    )
    for n in BATCH_SIZES[1:]:
        rate = N_FORECASTS / best[n]
        emit(
            f"fig14/batch{n}",
            rate,
            f"forecasts/s speedup={rate / seq_rate:.2f}x vs sequential",
            unit="rate_info",
        )
        _assert_parity(servers[n], prog, fields[:n])

    _deterministic_cache_rows()


if __name__ == "__main__":
    run()
