"""Fig. 1 analogue: roofline placement of hdiff on current platforms + TPU.

The paper's Fig. 1 shows hdiff far below the roofline on POWER9 / V100 /
AD9H7 because of low arithmetic intensity and irregular access. We compute
hdiff's AI under (a) the paper's algorithmic traffic model (every stencil
read goes to memory — the load-store-architecture position) and (b) the
fused/compulsory traffic model (the SPARTA/B-block position), and place
both on the TPU v5e roofline.
"""

from __future__ import annotations

from benchmarks.common import COLS, DEPTH, ROWS, emit
from repro.core import (
    TPUV5E,
    aie_hdiff_cycles,
    arithmetic_intensity,
    hdiff_algorithmic_bytes,
    hdiff_flops,
    hdiff_min_bytes,
)


def run(fast: bool = False) -> None:
    flops = hdiff_flops(DEPTH, ROWS, COLS)
    algo = hdiff_algorithmic_bytes(DEPTH, ROWS, COLS)
    fused = hdiff_min_bytes(DEPTH, ROWS, COLS)

    ai_algo = arithmetic_intensity(flops, algo)
    ai_fused = arithmetic_intensity(flops, fused)
    ridge_vpu = TPUV5E.peak_flops_vpu_f32 / TPUV5E.hbm_bw

    emit("fig1/ai_algorithmic", ai_algo,
         f"every-read-to-memory model; attainable={min(TPUV5E.peak_flops_vpu_f32, TPUV5E.hbm_bw*ai_algo)/1e9:.0f}GFLOP/s",
         unit="flops/byte")
    emit("fig1/ai_fused", ai_fused,
         f"compulsory-traffic model; attainable={min(TPUV5E.peak_flops_vpu_f32, TPUV5E.hbm_bw*ai_fused)/1e9:.0f}GFLOP/s",
         unit="flops/byte")
    emit("fig1/ridge_point_vpu", ridge_vpu,
         f"v5e VPU ridge at {ridge_vpu:.2f} flops/B; hdiff sits "
         f"{'left (memory-bound)' if ai_fused < ridge_vpu else 'right (compute-bound)'}",
         unit="flops/byte")

    # Faithful §3.1 reproduction: the paper's AIE cycle counts (Eq. 5-10).
    cyc = aie_hdiff_cycles(ROWS, COLS, DEPTH)
    emit("fig1/aie_compute_cycles_eq7", cyc["hdiff_compute_cycles"],
         "paper Eq.5-7 (verbatim model)", unit="cycles")
    emit("fig1/aie_memory_cycles_eq10", cyc["hdiff_memory_cycles"],
         f"paper Eq.8-10; compute/memory={cyc['hdiff_compute_cycles']/cyc['hdiff_memory_cycles']:.2f} "
         "(>1 for flux per paper's §3.1 discussion)", unit="cycles")
