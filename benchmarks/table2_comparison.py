"""Table 2 analogue: cross-platform hdiff comparison.

The paper's Table 2 rows (verbatim, from real hardware) next to this
repo's numbers: measured CPU wall time (what this container can measure)
and the TPU v5e roofline PROJECTION for the fused kernel (clearly labelled
projection — no TPU is attached here; the projection methodology is the
same roofline arithmetic the paper's 'Ach. Roof.' column uses).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import COLS, DEPTH, ROWS, emit, hdiff_gops, time_fn
from repro.core import (
    TPUV5E,
    arithmetic_intensity,
    hdiff,
    hdiff_flops,
    hdiff_min_bytes,
    roofline_fraction,
)

# Paper Table 2, verbatim: (work, year, platform, device, peak TFLOPS,
# peak BW GB/s, achieved GOp/s, achieved roofline %).
PAPER_TABLE2 = [
    ("NARMADA[80]", 2019, "FPGA", "XCVU3P", 0.97, 25.6, 129.9, 13.3),
    ("StencilFlow[33]", 2021, "CPU", "Xeon E5-2690V3", 0.67, 68.0, 32.0, 10.1),
    ("StencilFlow[33]", 2021, "GPU", "NVIDIA V100", 14.1, 900.0, 849.0, 5.9),
    ("StencilFlow[33]", 2021, "FPGA", "Stratix 10", 9.2, 76.8, 145.0, 1.6),
    ("NERO[79]", 2021, "FPGA", "XCVU37P", 3.6, 410.0, 485.4, 13.5),
    ("SPARTA", 2023, "AIE", "XCVC1902", 3.1, 25.6, 995.7, 31.4),
]


def run(fast: bool = False) -> None:
    depth = 8 if fast else DEPTH
    for work, year, platform, device, tflops, bw, gops, roof in PAPER_TABLE2:
        emit(
            f"table2/paper/{work}_{platform}",
            0.0,
            f"device={device} peak={tflops}TFLOPS bw={bw}GB/s "
            f"perf={gops}GOp/s roofline={roof}%",
            unit="info",
        )

    # Our measured row (this container's CPU, XLA-fused f32).
    x = jnp.asarray(
        np.random.default_rng(0).uniform(0, 1, (depth, ROWS, COLS)).astype(np.float32)
    )
    fn = jax.jit(lambda a: hdiff(a, 0.025))
    us = time_fn(fn, x)
    emit("table2/ours_cpu_xla", us, f"gops={hdiff_gops(us, depth=depth):.2f} (measured, 1-core CPU)")

    # TPU v5e projection: attainable = min(VPU peak, BW * AI) on the fused
    # kernel's compulsory traffic; reported as projection, not measurement.
    flops = hdiff_flops(DEPTH, ROWS, COLS)
    bts = hdiff_min_bytes(DEPTH, ROWS, COLS)
    ai = arithmetic_intensity(flops, bts)
    attain_mem = TPUV5E.hbm_bw * ai
    attain = min(TPUV5E.peak_flops_vpu_f32, attain_mem)
    emit(
        "table2/ours_tpu_v5e_projected",
        flops / attain * 1e6,
        f"AI={ai:.2f}flops/B attainable={attain/1e9:.0f}GOp/s "
        f"bound={'memory' if attain == attain_mem else 'compute'} "
        f"(projection from roofline, single chip)",
        unit="model_us",
    )
    # Roofline fraction if the kernel achieves the memory-bound ceiling
    # (fused kernel moves compulsory bytes only):
    frac = roofline_fraction(attain, flops, bts)
    emit("table2/ours_tpu_v5e_roofline_fraction", frac * 100,
         f"{frac*100:.0f}% of attainable roofline at compulsory traffic "
         f"(paper achieves 31.4% of peak)", unit="%")
