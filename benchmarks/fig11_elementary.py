"""Fig. 11 analogue: elementary stencil runtimes (§3.5 suite).

Paper: jacobi-1d / jacobi-2d-3pt / laplacian / jacobi-2d-9pt / seidel-2d on
CPU vs GPU vs 32 AIEs. Here: XLA-fused jnp implementations (the CPU row)
plus the Pallas kernels in interpret mode (correctness datapoint), on the
paper's 256x256x64 domain.

Each stencil additionally runs through the ``repro.ir`` compiler path —
hand-written vs IR-lowered (reference and fused-Pallas backends) — and the
row reports parity plus whether the graph-DERIVED op counts agree with the
hand-written analytical model (``ELEMENTARY_SPECS``).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import COLS, DEPTH, ROWS, emit, time_fn
from repro.core import ELEMENTARY_FNS, ELEMENTARY_SPECS
from repro.ir import ELEMENTARY_PROGRAMS, lower_pallas, lower_reference
from repro.kernels.stencil2d import jacobi1d as jacobi1d_kernel
from repro.kernels.stencil2d import stencil2d

NAMES_2D = ["jacobi2d_3pt", "laplacian", "jacobi2d_5pt", "jacobi2d_9pt", "seidel2d"]


def _parity(got, want, tol: float = 1e-6) -> str:
    err = float(jnp.max(jnp.abs(got - want)))
    return f"parity={'ok' if err <= tol else 'FAIL'}(max|d|={err:.1e})"


def _spec_agreement(name: str) -> str:
    derived = ELEMENTARY_PROGRAMS[name]().spec()
    hand = ELEMENTARY_SPECS[name]
    agree = (derived.macs, derived.other_ops, derived.reads, derived.radius) == (
        hand.macs,
        hand.other_ops,
        hand.reads,
        hand.radius,
    )
    return (
        f"ops={'agree' if agree else 'MISMATCH'}"
        f"({derived.macs}mac+{derived.other_ops}op r={derived.radius})"
    )


def run(fast: bool = False) -> None:
    depth = 8 if fast else DEPTH
    rng = np.random.default_rng(0)
    x3 = jnp.asarray(rng.standard_normal((depth, ROWS, COLS)).astype(np.float32))
    x1 = jnp.asarray(rng.standard_normal((depth * ROWS, COLS)).astype(np.float32))

    us = time_fn(jax.jit(ELEMENTARY_FNS["jacobi1d"]), x1)
    pts = x1.size
    emit("fig11/jacobi1d_xla", us,
         f"gops={pts * ELEMENTARY_SPECS['jacobi1d'].flops / us / 1e3:.2f}")

    for name in NAMES_2D:
        fn = jax.jit(ELEMENTARY_FNS[name if name != "seidel2d" else "seidel2d"])
        us = time_fn(fn, x3)
        spec = ELEMENTARY_SPECS[name]
        interior = (ROWS - 2) * (COLS - 2) * depth
        emit(f"fig11/{name}_xla", us,
             f"gops={interior * spec.flops / us / 1e3:.2f}")

    # IR-lowered reference backend vs hand-written, full domain: parity plus
    # derived-vs-analytical op-count agreement per stencil.
    want1 = ELEMENTARY_FNS["jacobi1d"](x1)
    ir1 = lower_reference(ELEMENTARY_PROGRAMS["jacobi1d"]())
    us = time_fn(ir1, x1)
    emit("fig11/jacobi1d_ir_ref", us,
         f"{_parity(ir1(x1), want1)} {_spec_agreement('jacobi1d')}")
    for name in NAMES_2D:
        want = ELEMENTARY_FNS[name](x3)
        ir_fn = lower_reference(ELEMENTARY_PROGRAMS[name]())
        us = time_fn(ir_fn, x3)
        emit(f"fig11/{name}_ir_ref", us,
             f"{_parity(ir_fn(x3), want)} {_spec_agreement(name)}")

    # Pallas kernels (interpret mode, correctness-path timing).
    small = x3[:2]
    for name in ["jacobi2d_3pt", "laplacian", "jacobi2d_9pt"]:
        us = time_fn(lambda a, n=name: stencil2d(a, n, interpret=True), small,
                     warmup=1, iters=3)
        emit(f"fig11/{name}_pallas_interpret", us, "interpret mode (depth=2)")
    us = time_fn(lambda a: jacobi1d_kernel(a, interpret=True), x1[:8], warmup=1, iters=3)
    emit("fig11/jacobi1d_pallas_interpret", us, "interpret mode (8 rows)")

    # IR fused-Pallas backend (generic codegen), interpret mode.
    for name in ["jacobi2d_3pt", "laplacian", "jacobi2d_9pt"]:
        ir_pl = lower_pallas(ELEMENTARY_PROGRAMS[name](), interpret=True)
        want = ELEMENTARY_FNS[name](small)
        us = time_fn(ir_pl, small, warmup=1, iters=3)
        emit(f"fig11/{name}_ir_pallas_interpret", us,
             f"{_parity(ir_pl(small), want)} (depth=2)")
    ir_pl1 = lower_pallas(ELEMENTARY_PROGRAMS["jacobi1d"](), interpret=True)
    us = time_fn(ir_pl1, x1[:8], warmup=1, iters=3)
    emit("fig11/jacobi1d_ir_pallas_interpret", us,
         f"{_parity(ir_pl1(x1[:8]), ELEMENTARY_FNS['jacobi1d'](x1[:8]))} (8 rows)")
