"""Fig. 11 analogue: elementary stencil runtimes (§3.5 suite).

Paper: jacobi-1d / jacobi-2d-3pt / laplacian / jacobi-2d-9pt / seidel-2d on
CPU vs GPU vs 32 AIEs. Here: XLA-fused jnp implementations (the CPU row)
plus the Pallas kernels in interpret mode (correctness datapoint), on the
paper's 256x256x64 domain.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import COLS, DEPTH, ROWS, emit, time_fn
from repro.core import ELEMENTARY_FNS, ELEMENTARY_SPECS
from repro.kernels.stencil2d import jacobi1d as jacobi1d_kernel
from repro.kernels.stencil2d import stencil2d

NAMES_2D = ["jacobi2d_3pt", "laplacian", "jacobi2d_5pt", "jacobi2d_9pt", "seidel2d"]


def run(fast: bool = False) -> None:
    depth = 8 if fast else DEPTH
    rng = np.random.default_rng(0)
    x3 = jnp.asarray(rng.standard_normal((depth, ROWS, COLS)).astype(np.float32))
    x1 = jnp.asarray(rng.standard_normal((depth * ROWS, COLS)).astype(np.float32))

    us = time_fn(jax.jit(ELEMENTARY_FNS["jacobi1d"]), x1)
    pts = x1.size
    emit("fig11/jacobi1d_xla", us,
         f"gops={pts * ELEMENTARY_SPECS['jacobi1d'].flops / us / 1e3:.2f}")

    for name in NAMES_2D:
        fn = jax.jit(ELEMENTARY_FNS[name if name != "seidel2d" else "seidel2d"])
        us = time_fn(fn, x3)
        spec = ELEMENTARY_SPECS[name]
        interior = (ROWS - 2) * (COLS - 2) * depth
        emit(f"fig11/{name}_xla", us,
             f"gops={interior * spec.flops / us / 1e3:.2f}")

    # Pallas kernels (interpret mode, correctness-path timing).
    small = x3[:2]
    for name in ["jacobi2d_3pt", "laplacian", "jacobi2d_9pt"]:
        us = time_fn(lambda a, n=name: stencil2d(a, n, interpret=True), small,
                     warmup=1, iters=3)
        emit(f"fig11/{name}_pallas_interpret", us, "interpret mode (depth=2)")
    us = time_fn(lambda a: jacobi1d_kernel(a, interpret=True), x1[:8], warmup=1, iters=3)
    emit("fig11/jacobi1d_pallas_interpret", us, "interpret mode (8 rows)")
