"""Fig. 12 (repo extension): temporal blocking — traffic and time vs k.

SPARTA's §1 insight is that spatial dataflow pipelines *timesteps*, not just
stages; the IR makes that a transform (``repeat(p, k)``), and this benchmark
measures what it buys: for hdiff and the five §3.5 elementary stencils,
``lower_pallas(repeat(p, k))`` applies k sweeps per VMEM residency, so

  * compulsory HBM bytes per SIMULATED step divide by k
    (``fused_bytes_per_step``, the graph-derived model), and
  * wall-clock per simulated step amortises the tile load/store round-trip
    (interpret mode on CPU here, so the wall-clock column is a
    correctness-path datapoint, not hardware speedup).

Each row also verifies the fused k-sweep against k composed single-step
reference applications. The wire-side amortisation (one depth-k*r halo
exchange per k sweeps) is measured for real in fig10_scaling.py.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import COLS, ROWS, emit, time_stats
from repro.ir import (
    ELEMENTARY_PROGRAMS,
    hdiff_program,
    lower_pallas,
    lower_reference,
    repeat,
)

KS = (1, 2, 4)
NAMES_2D = ["jacobi2d_3pt", "laplacian", "jacobi2d_5pt", "jacobi2d_9pt", "seidel2d"]


def _parity(got, want, k) -> str:
    """Max |fused k-sweep - k composed reference sweeps|; hard-fails the
    benchmark run past the 1e-6 acceptance bound (like fig10's assert)."""
    err = float(jnp.max(jnp.abs(got - want)))
    if err > 1e-6:
        raise AssertionError(f"k={k} fused sweep diverges from composed "
                             f"reference: max|d|={err:.1e}")
    return f"parity=ok(max|d|={err:.1e})"


def run(fast: bool = False) -> None:
    depth = 2 if fast else 8  # interpret-mode Pallas: keep planes modest
    rng = np.random.default_rng(0)
    x2 = jnp.asarray(rng.standard_normal((depth, ROWS, COLS)).astype(np.float32))
    x1 = jnp.asarray(
        rng.standard_normal((8 if fast else 64, COLS)).astype(np.float32)
    )

    programs = [("hdiff", hdiff_program())]
    programs += [(n, ELEMENTARY_PROGRAMS[n]()) for n in ["jacobi1d"] + NAMES_2D]

    for name, prog in programs:
        x = x1 if prog.ndim == 1 else x2
        points = x.size
        base_us = None
        # The composed-reference oracle accumulates across k (1, 2, 4 sweeps
        # share prefixes) and the parity call doubles as time_fn's warmup.
        ref = lower_reference(prog)
        want, sweeps_done = x, 0
        for k in KS:
            prog_k = repeat(prog, k)
            fn = lower_pallas(prog_k, interpret=True)
            while sweeps_done < k:
                want, sweeps_done = ref(want), sweeps_done + 1
            parity = _parity(fn(x), want, k)  # also compiles fn's jit cache
            ts = time_stats(fn, x, warmup=0, iters=3)
            us_per_step = ts.median_us / k
            if base_us is None:
                base_us = us_per_step
            emit(
                f"fig12/{name}_k{k}",
                us_per_step,
                f"min_us={ts.min_us / k:.1f} "
                f"hbm_bytes_per_step={prog_k.fused_bytes_per_step(points):.0f} "
                f"(/{k} of one residency) "
                f"per_step_speedup={base_us / us_per_step:.2f}x "
                f"radius={prog_k.radius} {parity}",
            )
