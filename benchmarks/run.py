"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived,unit`` CSV rows. ``--fast`` shrinks the grid
depth for quick CI-style runs; full runs use the paper's 256x256x64 domain.

Set ``REPRO_TRACE_DIR=/some/dir`` to capture a ``jax.profiler`` trace per
benchmark (one subdirectory each, viewable in Perfetto / TensorBoard).
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced depth for quick runs")
    ap.add_argument("--only", default="", help="comma-separated benchmark names")
    args = ap.parse_args()

    from benchmarks import (
        analytical_vs_compiled,
        fig1_roofline,
        fig9_designs,
        fig10_scaling,
        fig11_elementary,
        fig12_temporal,
        fig13_multifield,
        table2_comparison,
        wkv6_chunking,
    )

    benches = {
        "fig1": fig1_roofline.run,
        "fig9": fig9_designs.run,
        "fig10": fig10_scaling.run,
        "fig11": fig11_elementary.run,
        "fig12": fig12_temporal.run,
        "fig13": fig13_multifield.run,
        "table2": table2_comparison.run,
        "analytic": analytical_vs_compiled.run,
        "wkv6": wkv6_chunking.run,
    }
    only = {s for s in args.only.split(",") if s}

    from repro.obs import maybe_trace

    print("name,value,derived,unit")
    failed = []
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            with maybe_trace(name):
                fn(fast=args.fast)
        except Exception as e:
            failed.append(name)
            print(f"{name}/ERROR,0,{e!r}", file=sys.stderr)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
