"""repro.serve — batched serving engines + the forecast-serving layer.

Two schedulers share one telemetry vocabulary (queue latency, occupancy,
items/sec): :class:`BatchedServer` continuous-batches LM decode lanes;
:class:`ForecastServer` admission-groups compatible stencil forecasts into
one vmapped step per batch (see ``repro.ir.lower_batched``), executed
through a fingerprint-keyed LRU :class:`CompileCache` whose hit path
provably never re-traces (``cache.{hits,misses,evictions}`` counters +
per-entry trace probes).
"""

from repro.serve.cache import CacheEntry, CompileCache, CompileKey, compile_key
from repro.serve.engine import BatchedServer, Request, make_serve_fns
from repro.serve.forecast import ForecastRequest, ForecastServer
