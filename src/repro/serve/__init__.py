from repro.serve.engine import BatchedServer, Request, make_serve_fns
