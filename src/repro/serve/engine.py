"""Serving: prefill/decode steps + a batched continuous-batching scheduler.

``make_serve_fns`` builds the jitted prefill and decode steps the dry-run
lowers (decode_32k / long_500k cells lower ``serve_step`` = one decode step
with a seq_len-deep cache, per the brief).

``BatchedServer`` is a minimal continuous-batching engine: fixed B decode
lanes, each lane holds one request; finished lanes are refilled from the
queue with a prefill that writes that lane's cache slice. Greedy sampling
(argmax) for determinism in tests/examples.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import build_cache, lm_decode, lm_prefill
from repro.obs import events, metrics

Array = jax.Array


def make_serve_fns(cfg: ModelConfig, *, batch: int, max_len: int):
    """Returns (prefill_fn, decode_fn, cache_init_fn).

    prefill_fn(params, tokens, cache)        -> (last_logits, cache)
    decode_fn(params, token, cache, pos)     -> (logits, cache)
    """
    prefill = jax.jit(lambda p, t, c, m=None: lm_prefill(cfg, p, t, c, memory=m))
    decode = jax.jit(lambda p, t, c, pos, m=None: lm_decode(cfg, p, t, c, pos, memory=m))

    def cache_init():
        cache, _ = build_cache(cfg, batch, max_len)
        return cache

    return prefill, decode, cache_init


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (p,) int32
    max_new_tokens: int
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # Telemetry (repro.obs): stamped by the server as the request moves
    # through the queue; exposed on the result object so callers get
    # per-request latency without touching the registry.
    submitted_ts: float | None = None   # perf_counter at submit()
    prefill_ts: float | None = None     # perf_counter when a lane picked it up
    done_ts: float | None = None        # perf_counter at completion
    queue_latency_s: float | None = None   # prefill_ts - submitted_ts
    items_per_sec: float | None = None     # decode throughput of THIS request
    # (workload-neutral: tokens for the LM server, forecast members for the
    # stencil server; ``tokens_per_sec`` below is the back-compat alias)

    @property
    def tokens_per_sec(self) -> float | None:
        """Alias of :attr:`items_per_sec` — the pre-forecast name, kept so
        existing dashboards and callers keep reading (and writing)."""
        return self.items_per_sec

    @tokens_per_sec.setter
    def tokens_per_sec(self, value: float | None) -> None:
        self.items_per_sec = value


class BatchedServer:
    """Continuous batching over ``lanes`` decode slots with a shared-step
    decode loop. Lanes run in lock-step (one jitted decode per step for the
    whole batch); finished lanes are immediately refilled.

    Note: per-lane positions. The model's decode step takes a SCALAR pos
    (uniform benchmark shapes); the server therefore tracks a per-lane
    offset and left-aligns every prompt at pos 0 of its own lane by keeping
    one cache PER LANE (batch=1 caches), trading a little throughput for
    correct ragged batching on CPU. On TPU the same scheduler runs with a
    batched cache and vectorised positions.
    """

    def __init__(self, cfg: ModelConfig, params, *, lanes: int = 4, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.lanes = lanes
        self.max_len = max_len
        self.prefill, self.decode, _ = make_serve_fns(cfg, batch=1, max_len=max_len)
        self._lane_cache: list[Any] = [None] * lanes
        self._lane_req: list[Request | None] = [None] * lanes
        self._lane_pos: list[int] = [0] * lanes
        self._queue: list[Request] = []
        self._next_rid = 0
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens_out": 0}

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new_tokens)
        req.submitted_ts = time.perf_counter()
        self._queue.append(req)
        metrics.inc("serve.requests_submitted")
        events.record("serve.submit", rid=rid, prompt_len=len(req.prompt),
                      max_new_tokens=max_new_tokens)
        return rid

    def _fill_lanes(self):
        for i in range(self.lanes):
            if self._lane_req[i] is None and self._queue:
                req = self._queue.pop(0)
                req.prefill_ts = time.perf_counter()
                if req.submitted_ts is not None:
                    req.queue_latency_s = req.prefill_ts - req.submitted_ts
                    metrics.observe("serve.queue_latency", req.queue_latency_s)
                cache, _ = build_cache(self.cfg, 1, self.max_len)
                tokens = jnp.asarray(req.prompt[None, :])
                with metrics.timer("serve.prefill"):
                    logits, cache = self.prefill(self.params, tokens, cache)
                    logits = jax.block_until_ready(logits)
                tok = int(jnp.argmax(logits[0]))
                req.out_tokens.append(tok)
                self._lane_req[i] = req
                self._lane_cache[i] = cache
                self._lane_pos[i] = len(req.prompt)
                self.stats["prefills"] += 1
                metrics.inc("serve.prefills")
                events.record("serve.prefill", rid=req.rid, lane=i,
                              queue_latency_s=req.queue_latency_s)

    def step(self) -> bool:
        """One scheduler step: refill lanes, decode one token per active
        lane. Returns False when idle."""
        self._fill_lanes()
        active = [i for i in range(self.lanes) if self._lane_req[i] is not None]
        if not active:
            # An idle server is 0% occupied — without this the gauge froze
            # at the last busy step's value after the queue drained.
            metrics.set_gauge("serve.batch_occupancy", 0.0)
            return False
        metrics.set_gauge("serve.batch_occupancy", len(active) / self.lanes)
        events.record("serve.decode", active_lanes=len(active), lanes=self.lanes)
        with metrics.timer("serve.decode_step"):
            for i in active:
                req = self._lane_req[i]
                last = jnp.asarray([req.out_tokens[-1]], jnp.int32)
                logits, cache = self.decode(
                    self.params, last, self._lane_cache[i], jnp.int32(self._lane_pos[i])
                )
                self._lane_cache[i] = cache
                self._lane_pos[i] += 1
                tok = int(jnp.argmax(logits[0]))
                req.out_tokens.append(tok)
                self.stats["decode_steps"] += 1
                self.stats["tokens_out"] += 1
                metrics.inc("serve.decode_steps")
                metrics.inc("serve.tokens_out")
                if (
                    len(req.out_tokens) >= req.max_new_tokens
                    or self._lane_pos[i] >= self.max_len - 1
                ):
                    req.done = True
                    req.done_ts = time.perf_counter()
                    if req.prefill_ts is not None and req.done_ts > req.prefill_ts:
                        req.items_per_sec = len(req.out_tokens) / (
                            req.done_ts - req.prefill_ts
                        )
                    self._lane_req[i] = None
                    self._lane_cache[i] = None
                    events.record("serve.retire", rid=req.rid, lane=i,
                                  tokens_out=len(req.out_tokens),
                                  items_per_sec=req.items_per_sec,
                                  tokens_per_sec=req.items_per_sec)
        # Lanes freed by the retires above are empty NOW — restate the
        # gauge so a scrape between steps never reads the pre-retire value.
        occupied = sum(1 for r in self._lane_req if r is not None)
        metrics.set_gauge("serve.batch_occupancy", occupied / self.lanes)
        return True

    def run_until_idle(self, max_steps: int = 10_000) -> list[Request]:
        finished: list[Request] = []
        seen: set[int] = set()
        all_reqs: list[Request] = list(self._queue)
        t0 = time.perf_counter()
        tokens0 = self.stats["tokens_out"]
        for _ in range(max_steps):
            if not self.step():
                break
        elapsed = time.perf_counter() - t0
        if elapsed > 0:
            rate = (self.stats["tokens_out"] - tokens0) / elapsed
            metrics.set_gauge("serve.items_per_sec", rate)
            metrics.set_gauge("serve.tokens_per_sec", rate)  # back-compat alias
        for r in all_reqs:
            if r.done and r.rid not in seen:
                finished.append(r)
                seen.add(r.rid)
        return finished

    def metrics_text(self) -> str:
        """Prometheus-style exposition of the active metrics registry —
        serve counters/timers plus any health gauges a monitor maintains.
        A scrape endpoint in front of this server returns exactly this
        string; with metrics disabled it is a single well-formed comment."""
        from repro.obs.export import prometheus_text

        return prometheus_text()
