"""Fingerprint-keyed LRU compile cache for the forecast-serving layer.

StencilFlow treats whole stencil programs as cacheable, schedulable units;
this module is that idea applied to serving: heterogeneous forecast
requests must never pay a re-trace when an equivalent program has already
been lowered. The key is everything that determines the traced computation
and nothing else:

    (program.fingerprint(), grid shape, dtype, mesh shape, k, backend,
     batch size)

``StencilProgram.fingerprint()`` is the content-addressed structural hash
(display-name-blind), so two tenants submitting structurally-equal programs
under different names share one entry, while a program differing in one
coefficient tap hashes — and therefore compiles — separately.

Accounting is exact and observable: ``hits`` / ``misses`` / ``evictions``
counts on the cache object, mirrored into the ``repro.obs`` metrics
registry as the ``cache.hits`` / ``cache.misses`` / ``cache.evictions``
counter trio (plus ``cache.traces``). Eviction is LRU at ``capacity``
entries.

The zero-retrace invariant is *assertable*, not aspirational: every cached
callable is wrapped in a trace-count probe — a closure whose Python body
runs only while jax traces it — so ``entry.traces`` counts actual traces.
A cache hit reuses the jitted callable at an already-seen (shape, dtype,
structure) signature (the key pins all of them), so a hit performs ZERO
retraces; the property suite drives arbitrary request sequences against
this.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable

import numpy as np

from repro.obs import events, metrics


@dataclasses.dataclass(frozen=True)
class CompileKey:
    """Everything that determines one lowered computation's trace.

    ``fingerprint`` is the program's canonical structural hash; ``k`` is
    its chain length (``program.steps`` — redundant with the fingerprint,
    kept explicit so cache introspection / eviction logs read well);
    ``batch`` is the ensemble-member count (None = unbatched single
    forecast); ``mesh`` is the (R, C) device-mesh factorization for the
    sharded backends (None = single device)."""

    fingerprint: str
    grid: tuple[int, ...]
    dtype: str
    mesh: tuple[int, int] | None
    k: int
    backend: str
    batch: int | None


def compile_key(
    program,
    *,
    grid: tuple[int, ...],
    dtype: Any = np.float32,
    backend: str = "reference",
    mesh_shape: tuple[int, int] | None = None,
    batch: int | None = None,
) -> CompileKey:
    """The :class:`CompileKey` of one request shape."""
    return CompileKey(
        fingerprint=program.fingerprint(),
        grid=tuple(int(g) for g in grid),
        dtype=np.dtype(dtype).name,
        mesh=tuple(int(m) for m in mesh_shape) if mesh_shape is not None else None,
        k=program.steps,
        backend=backend,
        batch=int(batch) if batch is not None else None,
    )


@dataclasses.dataclass
class CacheEntry:
    """One cached lowered callable + its trace-count probe state."""

    key: CompileKey
    fn: Callable
    program_name: str
    traces: int = 0
    hits: int = 0


class CompileCache:
    """LRU cache of lowered (and trace-probed) program callables.

    ``get`` is the whole API surface the engine uses: key the request,
    return the cached callable or build-and-insert it, evicting the least
    recently used entry past ``capacity``. Like the metrics registry it is
    deliberately not thread-safe — one Python scheduler drives it.

    ``builder(program, key, **lower_kwargs) -> callable`` constructs a
    lowered callable on a miss; the default dispatches to
    :func:`repro.ir.lower_batched` (``key.batch`` set) or the matching
    single lowering (``key.batch is None``). Tests inject stub builders to
    drive the LRU bookkeeping without paying for real lowerings.
    """

    def __init__(
        self,
        capacity: int = 16,
        *,
        builder: Callable[..., Callable] | None = None,
        trace_probe: bool = True,
    ) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.builder = builder if builder is not None else _default_builder
        self.trace_probe = trace_probe
        self._entries: OrderedDict[CompileKey, CacheEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- introspection -----------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: CompileKey) -> bool:
        return key in self._entries

    def keys(self) -> list[CompileKey]:
        """Keys in LRU order: least recently used first."""
        return list(self._entries)

    def lookup(self, key: CompileKey) -> CacheEntry | None:
        """The entry for ``key`` with NO accounting and NO recency bump —
        for tests/diagnostics only; the serving path goes through
        :meth:`get`."""
        return self._entries.get(key)

    def stats(self) -> dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "size": len(self._entries),
            "capacity": self.capacity,
        }

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def total_traces(self) -> int:
        """Traces across LIVE entries — evicted entries' counts are gone,
        which is exactly right: re-building an evicted entry is a miss, and
        its fresh trace is the miss's cost, not a hit's."""
        return sum(e.traces for e in self._entries.values())

    # -- the cache ---------------------------------------------------------
    def get(
        self,
        program,
        *,
        grid: tuple[int, ...],
        dtype: Any = np.float32,
        backend: str = "reference",
        mesh_shape: tuple[int, int] | None = None,
        batch: int | None = None,
        **lower_kwargs,
    ) -> Callable:
        """The lowered callable for one request shape (cached)."""
        key = compile_key(
            program, grid=grid, dtype=dtype, backend=backend,
            mesh_shape=mesh_shape, batch=batch,
        )
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
            entry.hits += 1
            self.hits += 1
            metrics.inc("cache.hits")
            return entry.fn
        self.misses += 1
        metrics.inc("cache.misses")
        built = self.builder(program, key, **lower_kwargs)
        entry = CacheEntry(key=key, fn=built, program_name=program.name)
        if self.trace_probe:
            entry.fn = _with_trace_probe(built, entry)
        self._entries[key] = entry
        events.record(
            "cache.insert", program=program.name, backend=backend,
            k=key.k, batch=key.batch, size=len(self._entries),
        )
        while len(self._entries) > self.capacity:
            old_key, old = self._entries.popitem(last=False)
            self.evictions += 1
            metrics.inc("cache.evictions")
            events.record(
                "cache.evict", program=old.program_name,
                backend=old_key.backend, k=old_key.k, batch=old_key.batch,
            )
        return entry.fn


def _with_trace_probe(fn: Callable, entry: CacheEntry) -> Callable:
    """Wraps ``fn`` so every TRACE (not call) bumps ``entry.traces``.

    The closure body executes exactly when jax traces it — once per novel
    (structure, shape, dtype) signature of the outer jit — so the counter
    is a ground-truth retrace probe: a cache hit at an already-traced
    signature leaves it unchanged, which the conformance/property suites
    assert. The wrapped computation is untouched (the probe's side effect
    is host-only and trace-time-only).
    """
    import jax

    def probed(x):
        entry.traces += 1
        metrics.inc("cache.traces")
        return fn(x)

    probed.__name__ = f"cached_{getattr(fn, '__name__', 'lowering')}"
    return jax.jit(probed)


def _default_builder(program, key: CompileKey, **lower_kwargs) -> Callable:
    """Build the lowering ``key`` describes (the real, non-stub builder)."""
    from repro.ir import build_backend, lower_batched

    if key.batch is None:
        return build_backend(
            program, key.backend, mesh_shape=key.mesh, **lower_kwargs
        )
    return lower_batched(
        program, backend=key.backend, mesh_shape=key.mesh, **lower_kwargs
    )
