"""Forecast serving: admission, vmap-batched execution, per-request health.

The stencil-side counterpart of :class:`repro.serve.engine.BatchedServer`:
instead of token lanes, the schedulable unit is a *forecast request* — one
IR program applied to one set of initial-condition fields. The scheduler
groups compatible pending requests (same :class:`repro.serve.cache
.CompileKey` modulo batch size: same program fingerprint, grid, dtype,
mesh, k, backend) into ONE vmapped step over the member axis, so N
tenants' scenarios — or N perturbed members of one ensemble — share one
compiled kernel per step. The compile cache guarantees a repeat batch
shape never re-traces.

Fault isolation rides the vmap bit-exactness guarantee: members do not
mix, so a request whose fields blow up (NaN/Inf, caught by a
``HealthMonitor`` post-step check) fails ALONE — its batchmates complete
with results identical to unbatched runs. The stress suite injects exactly
this.

Telemetry mirrors the token server's, workload-neutrally named:

  * ``serve.forecast.queue_latency`` — per-request submit-to-dispatch wait;
  * ``serve.forecast.member_occupancy`` — members in the last batch /
    ``max_batch`` (0.0 when idle — same staleness rule as the lane gauge);
  * ``serve.forecast.steps_per_sec`` / ``serve.forecast.members_per_sec``
    — batched-step and member throughput over a ``run_until_idle`` drain;
  * counters ``serve.forecast.{requests_submitted,batches,members,
    completed,failed}``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping

import numpy as np

import jax
import jax.numpy as jnp

from repro.obs import events, metrics
from repro.obs.health import HealthMonitor, NumericsError
from repro.serve.cache import CompileCache, CompileKey, compile_key

Array = jax.Array


@dataclasses.dataclass
class ForecastRequest:
    """One tenant's forecast: a program + its initial-condition fields.

    The server stamps the same telemetry trio the token server stamps on
    :class:`repro.serve.engine.Request` — submit / dispatch / done
    timestamps, queue latency, and per-request throughput
    (``items_per_sec``, where the item is one completed forecast)."""

    rid: int
    program: Any                      # StencilProgram
    fields: dict[str, Array]          # {input: (depth, rows, cols)}
    result: Any = None                # array, or {field: array} (multi-output)
    error: Exception | None = None
    done: bool = False
    submitted_ts: float | None = None
    dispatch_ts: float | None = None
    done_ts: float | None = None
    queue_latency_s: float | None = None
    items_per_sec: float | None = None

    @property
    def failed(self) -> bool:
        return self.error is not None

    @property
    def group_key(self) -> CompileKey:
        """The admission key: everything the compile key pins EXCEPT the
        batch size — requests sharing it can ride one vmapped step."""
        return self._group_key

    _group_key: CompileKey = dataclasses.field(init=False, repr=False, default=None)


class ForecastServer:
    """Admission control + vmap-batched execution over a compile cache.

    One ``step()`` = one batched forecast: pop the oldest pending request,
    sweep the queue for up to ``max_batch - 1`` more requests with the SAME
    group key (FIFO within the group; incompatible requests keep their
    place for a later step), stack their fields along a fresh member axis,
    run the cached batched lowering once, and unstack per-member results.
    Heterogeneous tenants therefore interleave safely: each step is
    homogeneous, and no request is starved because group sweeps always
    start from the queue head.

    ``monitor`` (optional, a :class:`HealthMonitor`) is applied PER MEMBER
    post-step: each member's output fields are force-checked, and a member
    that trips the monitor retires with ``error`` set while its batchmates
    complete normally — the vmap path computes members independently, so a
    blown-up member cannot contaminate the others.
    """

    def __init__(
        self,
        *,
        backend: str = "reference",
        mesh_shape: tuple[int, int] | None = None,
        max_batch: int = 8,
        cache: CompileCache | None = None,
        cache_capacity: int = 16,
        monitor: HealthMonitor | None = None,
        lower_kwargs: Mapping[str, Any] | None = None,
    ) -> None:
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.backend = backend
        self.mesh_shape = tuple(mesh_shape) if mesh_shape is not None else None
        self.max_batch = max_batch
        self.cache = cache if cache is not None else CompileCache(cache_capacity)
        self.monitor = monitor
        self.lower_kwargs = dict(lower_kwargs or {})
        self._queue: list[ForecastRequest] = []
        self._next_rid = 0
        self.completed: list[ForecastRequest] = []
        self.stats = {"batches": 0, "members": 0, "completed": 0, "failed": 0}

    # -- admission ---------------------------------------------------------
    def submit(
        self,
        program,
        fields: Array | Mapping[str, Array],
    ) -> int:
        """Enqueue one forecast. ``fields`` is a ``{input: (D, R, C)}``
        mapping (or the bare array for single-input programs); shapes and
        dtypes join the admission key, so mixed grids never co-batch."""
        if not isinstance(fields, Mapping):
            if len(program.inputs) != 1:
                raise ValueError(
                    f"program {program.name!r} has inputs "
                    f"{program.inputs}; pass a mapping"
                )
            fields = {program.inputs[0]: fields}
        missing = [f for f in program.inputs if f not in fields]
        if missing:
            raise ValueError(
                f"program {program.name!r} request is missing input(s) "
                f"{missing}; declared inputs are {list(program.inputs)}"
            )
        arrays = {f: jnp.asarray(fields[f]) for f in program.inputs}
        shapes = {tuple(a.shape) for a in arrays.values()}
        if len(shapes) != 1:
            raise ValueError(
                f"all fields of one forecast must share a grid, got {shapes}"
            )
        grid = shapes.pop()
        if len(grid) != program.ndim + 1:
            raise ValueError(
                f"program {program.name!r} wants a (depth, rows, cols) grid "
                f"({program.ndim + 1}-D), got shape {grid}"
            )
        rid = self._next_rid
        self._next_rid += 1
        req = ForecastRequest(rid=rid, program=program, fields=arrays)
        req._group_key = compile_key(
            program,
            grid=grid,
            dtype=next(iter(arrays.values())).dtype,
            backend=self.backend,
            mesh_shape=self.mesh_shape,
            batch=None,
        )
        req.submitted_ts = time.perf_counter()
        self._queue.append(req)
        metrics.inc("serve.forecast.requests_submitted")
        events.record(
            "serve.forecast.submit", rid=rid, program=program.name,
            grid=list(grid), k=program.steps,
        )
        return rid

    def pending(self) -> int:
        return len(self._queue)

    # -- execution ---------------------------------------------------------
    def _admit_group(self) -> list[ForecastRequest]:
        """Head-of-queue request plus every same-group-key follower, FIFO,
        up to ``max_batch``. Skipped requests keep their queue position."""
        head = self._queue[0]
        group = [head]
        for req in self._queue[1:]:
            if len(group) >= self.max_batch:
                break
            if req.group_key == head.group_key:
                group.append(req)
        picked = {id(r) for r in group}
        self._queue = [r for r in self._queue if id(r) not in picked]
        return group

    def step(self) -> bool:
        """One batched forecast step. Returns False when idle."""
        if not self._queue:
            metrics.set_gauge("serve.forecast.member_occupancy", 0.0)
            return False
        group = self._admit_group()
        now = time.perf_counter()
        for req in group:
            req.dispatch_ts = now
            if req.submitted_ts is not None:
                req.queue_latency_s = now - req.submitted_ts
                metrics.observe("serve.forecast.queue_latency", req.queue_latency_s)
        key = group[0].group_key
        program = group[0].program
        n = len(group)
        metrics.set_gauge("serve.forecast.member_occupancy", n / self.max_batch)
        fn = self.cache.get(
            program,
            grid=key.grid,
            dtype=key.dtype,
            backend=key.backend,
            mesh_shape=key.mesh,
            batch=n,
            **self.lower_kwargs,
        )
        batched = {
            f: jnp.stack([req.fields[f] for req in group])
            for f in program.inputs
        }
        with metrics.timer("serve.forecast.step"):
            out = fn(batched)
            out = jax.block_until_ready(out)
        self.stats["batches"] += 1
        self.stats["members"] += n
        metrics.inc("serve.forecast.batches")
        metrics.inc("serve.forecast.members", n)
        done = time.perf_counter()
        for i, req in enumerate(group):
            member = (
                {f: v[i] for f, v in out.items()}
                if isinstance(out, Mapping)
                else out[i]
            )
            req.done_ts = done
            if req.dispatch_ts is not None and done > req.dispatch_ts:
                req.items_per_sec = 1.0 / (done - req.dispatch_ts)
            try:
                self._check_member(req, member)
            except NumericsError as err:
                req.error = err
                self.stats["failed"] += 1
                metrics.inc("serve.forecast.failed")
                events.record(
                    "serve.forecast.fail", rid=req.rid,
                    program=program.name, field=err.field,
                )
            else:
                req.result = member
                self.stats["completed"] += 1
                metrics.inc("serve.forecast.completed")
            req.done = True
            self.completed.append(req)
            events.record(
                "serve.forecast.retire", rid=req.rid, batch=n,
                failed=req.failed, queue_latency_s=req.queue_latency_s,
                items_per_sec=req.items_per_sec,
            )
        return True

    def _check_member(self, req: ForecastRequest, member) -> None:
        """Force-check every output field of ONE member's result against
        the monitor — this is where a NaN-injected request dies alone."""
        if self.monitor is None:
            return
        outputs = member if isinstance(member, Mapping) else {"out": member}
        for fname, arr in outputs.items():
            self.monitor.check(
                req.program.steps, arr,
                name=f"{req.program.name}[{req.rid}].{fname}", force=True,
            )

    def run_until_idle(self, max_steps: int = 10_000) -> list[ForecastRequest]:
        """Drain the queue; returns the requests retired by THIS drain (in
        retirement order) and stamps the throughput gauges."""
        start = len(self.completed)
        steps0 = self.stats["batches"]
        members0 = self.stats["members"]
        t0 = time.perf_counter()
        for _ in range(max_steps):
            if not self.step():
                break
        elapsed = time.perf_counter() - t0
        if elapsed > 0:
            metrics.set_gauge(
                "serve.forecast.steps_per_sec",
                (self.stats["batches"] - steps0) / elapsed,
            )
            metrics.set_gauge(
                "serve.forecast.members_per_sec",
                (self.stats["members"] - members0) / elapsed,
            )
        return self.completed[start:]

    def metrics_text(self) -> str:
        """Prometheus-style exposition (see ``BatchedServer.metrics_text``)."""
        from repro.obs.export import prometheus_text

        return prometheus_text()
