from repro.data.pipeline import (
    DataConfig,
    Prefetcher,
    SyntheticLM,
    TokenFileDataset,
    make_dataset,
    pack_documents,
)
