"""Data pipeline: deterministic synthetic streams + binary file-backed
token datasets, with host-side sharding, packing, and prefetch.

Production posture:
  * Every batch is addressed by (step, host_shard) so a restart reproduces
    the exact stream from a checkpointed step — data-parallel restore needs
    no separate data checkpoint.
  * ``TokenFileDataset`` memory-maps a flat uint16/uint32 token file and
    serves fixed-length windows (the standard pre-tokenised LM format).
  * ``pack_documents`` packs ragged documents into fixed (seq_len,) rows
    with EOS separators — loss masking uses the -100 convention.
  * ``Prefetcher`` overlaps host batch assembly with device compute via a
    background thread (depth-N queue) — the data-side analogue of the
    paper's shimDMA double buffering.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np



@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    kind: str = "synthetic"          # synthetic | file
    path: str = ""                   # for kind="file"
    eos_id: int = 0
    prefetch: int = 2


class SyntheticLM:
    """Deterministic synthetic LM stream: tokens drawn from a fixed-seed
    Philox counter keyed by (seed, step), labels = next-token shift.

    A "zipfian" skew makes the distribution non-uniform so losses actually
    decrease during the example runs (a uniform stream is unlearnable).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        probs = 1.0 / np.arange(1, cfg.vocab_size + 1) ** 1.1
        self._probs = probs / probs.sum()

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1) -> dict[str, np.ndarray]:
        cfg = self.cfg
        local = cfg.global_batch // n_shards
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step, shard]))
        toks = rng.choice(cfg.vocab_size, size=(local, cfg.seq_len + 1), p=self._probs)
        toks = toks.astype(np.int32)
        # Inject learnable structure: every token at odd position repeats the
        # previous token with p=0.5 (so next-token prediction is learnable).
        rep = rng.random((local, cfg.seq_len + 1)) < 0.5
        for j in range(1, cfg.seq_len + 1, 2):
            toks[:, j] = np.where(rep[:, j], toks[:, j - 1], toks[:, j])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class TokenFileDataset:
    """Flat binary token file (uint16 or uint32), served as fixed windows.

    Window w of step s for shard h is deterministic in (seed, s, h): restart
    = replay. Windows stride by seq_len with a seeded offset shuffle.
    """

    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self._data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self.n_windows = (len(self._data) - 1) // cfg.seq_len
        if self.n_windows <= 0:
            raise ValueError(f"{cfg.path} too small for seq_len={cfg.seq_len}")

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1) -> dict[str, np.ndarray]:
        cfg = self.cfg
        local = cfg.global_batch // n_shards
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step, shard]))
        idx = rng.integers(0, self.n_windows, size=(local,))
        rows = np.stack(
            [self._data[i * cfg.seq_len : i * cfg.seq_len + cfg.seq_len + 1] for i in idx]
        ).astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:].copy()}


def pack_documents(
    docs: list[np.ndarray], seq_len: int, eos_id: int, pad_label: int = -100
) -> dict[str, np.ndarray]:
    """Packs ragged docs into (n_rows, seq_len) with EOS separators.
    Labels are next-token; positions crossing a document boundary get
    ``pad_label`` so loss never spans documents."""
    stream: list[int] = []
    boundaries: list[int] = []
    for d in docs:
        stream.extend(int(t) for t in d)
        stream.append(eos_id)
        boundaries.append(len(stream) - 1)
    n_rows = max(len(stream) // (seq_len + 1), 1)
    usable = n_rows * (seq_len + 1)
    while len(stream) < usable + 1:
        stream.append(eos_id)
    arr = np.asarray(stream[: usable + 1], np.int32)
    bset = set(boundaries)
    tokens = np.empty((n_rows, seq_len), np.int32)
    labels = np.empty((n_rows, seq_len), np.int32)
    for r in range(n_rows):
        base = r * (seq_len + 1)
        tokens[r] = arr[base : base + seq_len]
        labels[r] = arr[base + 1 : base + seq_len + 1]
        for j in range(seq_len):
            if base + j in bset:  # token j is an EOS: next-token crosses docs
                labels[r, j] = pad_label
    return {"tokens": tokens, "labels": labels}


class Prefetcher:
    """Depth-N background prefetch of host batches."""

    def __init__(self, make_batch, depth: int = 2, start_step: int = 0):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self._make(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


def make_dataset(cfg: DataConfig):
    if cfg.kind == "synthetic":
        return SyntheticLM(cfg)
    if cfg.kind == "file":
        return TokenFileDataset(cfg)
    raise ValueError(cfg.kind)
