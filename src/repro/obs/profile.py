"""Optional ``jax.profiler`` trace capture, env-gated.

Set ``REPRO_TRACE_DIR=/some/dir`` and every benchmark entry point that
wraps its work in :func:`maybe_trace` writes an XLA/Perfetto trace there
(one subdirectory per label), viewable in ``xprof``/TensorBoard or
``ui.perfetto.dev``. Because ``repro.ir.evaluate`` tags every IR op with
``jax.named_scope``, the captured timelines carry stencil-op names
(``ir/<program>/<op>``) instead of anonymous fusions.

Unset (the default) this module is a no-op — no profiler import, no
overhead. Capture failures (profiler already active, missing profiler
backend pieces) degrade to a warning + no-op: tracing must never take a
benchmark run down.
"""

from __future__ import annotations

import os
import sys
from contextlib import contextmanager, nullcontext
from pathlib import Path

TRACE_DIR_ENV = "REPRO_TRACE_DIR"


def trace_dir_from_env() -> str | None:
    """The configured capture directory, or None when capture is off."""
    d = os.environ.get(TRACE_DIR_ENV, "").strip()
    return d or None


@contextmanager
def profiler_trace(trace_dir: str | Path):
    """Capture a ``jax.profiler`` trace of the enclosed block into
    ``trace_dir`` (created if needed). Degrades to a no-op on failure."""
    import jax

    path = Path(trace_dir)
    started = False
    try:
        path.mkdir(parents=True, exist_ok=True)
        jax.profiler.start_trace(str(path))
        started = True
    except Exception as e:  # pragma: no cover - backend-dependent
        print(f"repro.obs.profile: trace capture unavailable ({e!r}); "
              f"continuing without", file=sys.stderr)
    try:
        yield path if started else None
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception as e:  # pragma: no cover - backend-dependent
                print(f"repro.obs.profile: stop_trace failed ({e!r})",
                      file=sys.stderr)


def maybe_trace(label: str | None = None):
    """Env-gated capture: a :func:`profiler_trace` into
    ``$REPRO_TRACE_DIR[/label]`` when the env var is set, else a shared
    no-op context manager."""
    base = trace_dir_from_env()
    if base is None:
        return nullcontext(None)
    return profiler_trace(Path(base) / label if label else Path(base))
