"""Structured JSON run reports + runtime environment metadata.

``runtime_metadata()`` is the one home of the "what ran this" record every
perf artifact carries (``scripts/bench_smoke.py`` stamps it into each
``BENCH_fig*.json``): jax version, backend, device kind/count, python and
platform, plus the commit SHA when one is discoverable. The perf-trajectory
gate (``scripts/bench_compare.py``) matches on it so wall-clock numbers are
only ever compared like-for-like.

``RunReport`` is the generic container for any instrumented run: metadata +
a metrics snapshot + named free-form sections, serialised to plain JSON.
"""

from __future__ import annotations

import dataclasses
import json
import os
import platform
import subprocess
import time
from pathlib import Path
from typing import Any

from repro.obs import metrics

# The metadata keys a trajectory comparison must agree on before wall-clock
# rows are comparable at all (bench_compare's default match keys).
MATCH_KEYS = ("backend", "device_kind", "device_count")


def git_commit(cwd: str | None = None) -> str | None:
    """Best-effort commit SHA: ``GITHUB_SHA`` (CI) or ``git rev-parse``.
    Returns None outside a repo / without git — metadata must never make a
    benchmark run fail."""
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10, cwd=cwd,
        )
    except (OSError, subprocess.SubprocessError):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def runtime_metadata(cwd: str | None = None) -> dict[str, Any]:
    """Device/platform metadata for perf records. Importing this must never
    lock a backend the caller didn't already initialise — jax's device query
    does initialise the backend, which is fine for benchmark entry points
    (they query devices anyway) but means library code should call this
    lazily, not at import time."""
    import jax

    devices = jax.devices()
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devices[0].device_kind if devices else None,
        "device_count": len(devices),
        "python_version": platform.python_version(),
        "platform": platform.platform(),
        "commit": git_commit(cwd),
        "recorded_at_unix": time.time(),
    }


@dataclasses.dataclass
class RunReport:
    """A structured record of one instrumented run."""

    name: str
    metadata: dict[str, Any] = dataclasses.field(default_factory=dict)
    sections: dict[str, Any] = dataclasses.field(default_factory=dict)
    metrics_snapshot: dict[str, Any] | None = None

    @classmethod
    def begin(cls, name: str, *, with_metadata: bool = True) -> "RunReport":
        return cls(name=name, metadata=runtime_metadata() if with_metadata else {})

    def add_section(self, name: str, payload: Any) -> "RunReport":
        self.sections[name] = payload
        return self

    def attach_metrics(
        self, registry: metrics.MetricsRegistry | None = None
    ) -> "RunReport":
        """Snapshots ``registry`` (or the active one) into the report."""
        reg = registry if registry is not None else metrics.current()
        if reg is not None:
            self.metrics_snapshot = reg.snapshot()
        return self

    def to_dict(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "metadata": self.metadata,
            "sections": self.sections,
            "metrics": self.metrics_snapshot,
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True, default=str)

    def write(self, path: str | Path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json() + "\n")
        return path
