"""Model-vs-measured drift detection.

The repo's central correctness claim about its wire models is analytical
exactness: measured collective bytes / modelled bytes == 1.000 (§3.1-style
accounting, fig10/fig13). This module makes that comparison a standing
runtime property instead of a figure-script one: any instrumented layer can
record a ``(measured, model)`` pair and get a flagged :class:`DriftResult`
when the ratio leaves tolerance, with the pair and the verdict mirrored
into the active metrics registry.
"""

from __future__ import annotations

import dataclasses

from repro.obs import events, metrics

# The benchmark gate's band (scripts/bench_smoke.py uses the same one): the
# models are exact, so anything past 1% is a real accounting bug, not noise.
DEFAULT_TOLERANCE = 0.01


@dataclasses.dataclass(frozen=True)
class DriftResult:
    """One model-vs-measured comparison."""

    name: str
    measured: float
    model: float
    tolerance: float

    @property
    def ratio(self) -> float:
        if self.model == 0:
            # Exact-zero model (e.g. 1x1 mesh: no collectives): measured
            # must be zero too; encode agreement as ratio 1.
            return 1.0 if self.measured == 0 else float("inf")
        return self.measured / self.model

    @property
    def ok(self) -> bool:
        return abs(self.ratio - 1.0) <= self.tolerance

    def describe(self) -> str:
        verdict = "ok" if self.ok else "DRIFT"
        return (
            f"{self.name}: measured={self.measured:.0f} model={self.model:.0f} "
            f"ratio={self.ratio:.6f} tol={self.tolerance} [{verdict}]"
        )


def check_drift(
    name: str,
    measured: float,
    model: float,
    tolerance: float = DEFAULT_TOLERANCE,
    *,
    registry: metrics.MetricsRegistry | None = None,
) -> DriftResult:
    """Builds a :class:`DriftResult` and records it into ``registry`` (or
    the active registry; silently skipped when neither exists):

      * counters ``<name>.measured_bytes`` / ``<name>.model_bytes`` — the
        raw pair, accumulated so repeated rounds sum;
      * gauge   ``<name>.ratio`` — the latest measured/model ratio;
      * counter ``<name>.drift_flags`` — bumped only when out of tolerance.

    An out-of-tolerance result additionally lands in the flight recorder
    as a ``drift.flagged`` event (no event on clean checks — the recorder
    keeps *notable* history, the registry keeps aggregates).
    """
    result = DriftResult(name=name, measured=float(measured), model=float(model),
                         tolerance=tolerance)
    reg = registry if registry is not None else metrics.current()
    if reg is not None:
        reg.inc(f"{name}.measured_bytes", result.measured)
        reg.inc(f"{name}.model_bytes", result.model)
        reg.set_gauge(f"{name}.ratio", result.ratio)
        if not result.ok:
            reg.inc(f"{name}.drift_flags")
    if not result.ok:
        events.record("drift.flagged", name=name, measured=result.measured,
                      model=result.model, ratio=result.ratio,
                      tolerance=tolerance)
    return result
