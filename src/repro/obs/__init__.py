"""repro.obs — runtime telemetry + numerics health for the whole stack.

Seven small pieces (see README "Observability"):

  * :mod:`repro.obs.metrics` — counters / gauges / nested wall-clock timers
    with ``block_until_ready`` discipline; zero-overhead no-op when
    disabled, enabled via ``enable()`` / ``using()`` / ``REPRO_METRICS=1``.
  * :mod:`repro.obs.health`  — jit-safe on-device field probes
    (``field_stats``: NaN/Inf counts, min/max/mean, global L2, mesh-aware
    via ``axis_names``) and the cadence/policy ``HealthMonitor`` that makes
    long forecasts blow-up-safe.
  * :mod:`repro.obs.events`  — the flight recorder: bounded ring of
    structured events, span helpers, ``REPRO_EVENT_LOG`` JSONL sink and a
    crash dump that flushes the ring on abort.
  * :mod:`repro.obs.export`  — Prometheus-style text exposition of the
    metrics snapshot (health gauges included).
  * :mod:`repro.obs.drift`   — model-vs-measured drift detection (the
    standing form of the repo's measured/model == 1.000 wire claims).
  * :mod:`repro.obs.report`  — structured JSON run reports + the
    ``runtime_metadata()`` stamp every ``BENCH_fig*.json`` carries.
  * :mod:`repro.obs.profile` — env-gated ``jax.profiler`` trace capture
    (``REPRO_TRACE_DIR``), with per-IR-op ``named_scope`` labels.

Everything downstream (``ir`` lowerings, ``dist.halo``, ``serve.engine``,
``train.loop``, ``checkpoint.store``, the benchmark suite) reports through
this package; it imports jax lazily and nothing here initialises a backend
at import time.
"""

from repro.obs import events, metrics
from repro.obs.drift import DEFAULT_TOLERANCE, DriftResult, check_drift
from repro.obs.events import EVENT_LOG_ENV, Event, FlightRecorder
from repro.obs.export import prometheus_text, sanitize_metric_name
from repro.obs.health import (
    HealthMonitor,
    NumericsError,
    field_stats,
    host_stats,
    is_healthy,
)
from repro.obs.metrics import (
    METRICS_ENV,
    MetricsRegistry,
    TimerStat,
    instrument_call,
)
from repro.obs.profile import TRACE_DIR_ENV, maybe_trace, profiler_trace
from repro.obs.report import MATCH_KEYS, RunReport, git_commit, runtime_metadata

__all__ = [
    "DEFAULT_TOLERANCE",
    "DriftResult",
    "EVENT_LOG_ENV",
    "Event",
    "FlightRecorder",
    "HealthMonitor",
    "MATCH_KEYS",
    "METRICS_ENV",
    "MetricsRegistry",
    "NumericsError",
    "RunReport",
    "TRACE_DIR_ENV",
    "TimerStat",
    "check_drift",
    "events",
    "field_stats",
    "git_commit",
    "host_stats",
    "instrument_call",
    "is_healthy",
    "maybe_trace",
    "metrics",
    "profiler_trace",
    "prometheus_text",
    "runtime_metadata",
    "sanitize_metric_name",
]
