"""repro.obs — runtime telemetry for the whole stack.

Four small pieces (see README "Observability"):

  * :mod:`repro.obs.metrics` — counters / gauges / nested wall-clock timers
    with ``block_until_ready`` discipline; zero-overhead no-op when
    disabled, enabled via ``enable()`` / ``using()`` / ``REPRO_METRICS=1``.
  * :mod:`repro.obs.drift`   — model-vs-measured drift detection (the
    standing form of the repo's measured/model == 1.000 wire claims).
  * :mod:`repro.obs.report`  — structured JSON run reports + the
    ``runtime_metadata()`` stamp every ``BENCH_fig*.json`` carries.
  * :mod:`repro.obs.profile` — env-gated ``jax.profiler`` trace capture
    (``REPRO_TRACE_DIR``), with per-IR-op ``named_scope`` labels.

Everything downstream (``ir`` lowerings, ``dist.halo``, ``serve.engine``,
the benchmark suite) reports through this package; it imports jax lazily
and nothing here initialises a backend at import time.
"""

from repro.obs import metrics
from repro.obs.drift import DEFAULT_TOLERANCE, DriftResult, check_drift
from repro.obs.metrics import (
    METRICS_ENV,
    MetricsRegistry,
    TimerStat,
    instrument_call,
)
from repro.obs.profile import TRACE_DIR_ENV, maybe_trace, profiler_trace
from repro.obs.report import MATCH_KEYS, RunReport, git_commit, runtime_metadata

__all__ = [
    "DEFAULT_TOLERANCE",
    "DriftResult",
    "MATCH_KEYS",
    "METRICS_ENV",
    "MetricsRegistry",
    "RunReport",
    "TRACE_DIR_ENV",
    "TimerStat",
    "check_drift",
    "git_commit",
    "instrument_call",
    "maybe_trace",
    "metrics",
    "profiler_trace",
    "runtime_metadata",
]
