"""Numerics-health probes: on-device field statistics + blow-up policies.

A forecast that goes NaN on step 4,000 of a long run burns everything after
it silently — the perf telemetry (:mod:`repro.obs.metrics`) never notices
because the wall-clock of garbage is indistinguishable from the wall-clock
of weather. This module watches the *numbers*:

  * :func:`field_stats` — NaN/Inf counts, finite min/max/mean and the
    global L2 norm, computed with on-device ``jnp`` reductions (jit-safe:
    only scalars ever cross to the host, and only when the caller asks).
    Pass ``axis_names=("rows", "cols")`` inside a ``shard_map`` body and
    the partial moments are combined across the mesh axes with
    ``psum``/``pmin``/``pmax`` — global stats over a sharded field equal
    the single-device stats (tested to 1e-6 on the paper grid).
  * :class:`HealthMonitor` — cadence-gated probing (every ``cadence``
    steps, so a million-step loop pays for ~1/cadence probes) with one of
    three policies when a probe is unhealthy:

      - ``"warn"``              log + count, keep running;
      - ``"abort"``             flush the flight recorder, raise
                                :class:`NumericsError`;
      - ``"checkpoint-then-abort"``  first hand the *last healthy* probed
                                state to ``checkpoint_fn`` (a COMMITted
                                checkpoint of the pre-blow-up state), then
                                abort as above.

    Like ``instrument_call``, :meth:`HealthMonitor.check` steps aside on
    tracer arguments — a monitor wired into a step function that later gets
    jitted never pollutes the trace, so compiled execution stays
    byte-identical with probes on (the conformance matrix enforces this).

Probes report through both observability channels when they are enabled:
``health.<field>.<stat>`` gauges + ``health.probes``/``health.blowups``
counters in the metrics registry, and ``health.probe`` / ``health.blowup``
/ ``health.checkpoint`` events in the flight recorder. Neither channel is
required: the monitor functions (and aborts) with both disabled.
"""

from __future__ import annotations

from typing import Any, Callable, Mapping, Sequence

from repro.obs import events, metrics

STAT_KEYS = ("size", "nan_count", "inf_count", "min", "max", "mean", "l2")

POLICIES = ("warn", "abort", "checkpoint-then-abort")


def _host_snapshot(tree: Any) -> Any:
    """Device->host copy of an arbitrary pytree of arrays (np.asarray per
    leaf). Used to decouple retained state from buffers the caller may
    donate/delete."""
    import jax
    import numpy as np

    return jax.tree.map(np.asarray, tree)


def field_stats(x, *, axis_names: Sequence[str] = ()) -> dict[str, Any]:
    """On-device health statistics of one array (any shape/dtype).

    Returns a dict of 0-d jnp arrays: ``size``, ``nan_count``,
    ``inf_count``, ``min``, ``max``, ``mean``, ``l2``. Counts
    (``size``/``nan_count``/``inf_count``) accumulate in int32, so they
    are exact up to 2^31-1 elements per (sharded) field — a float32
    accumulator would silently lose exactness past 2^24 (~16.7M), below a
    full ERA5-scale field. Min/max/mean/L2 are over the FINITE values only
    (a single NaN must not erase the signal of where the rest of the field
    sits); with no finite values min/max are +/-inf and mean/L2 are 0 —
    ``nan_count``/``inf_count`` carry the alarm.

    ``axis_names`` names enclosing ``shard_map``/``pmap`` mesh axes to
    reduce across (``psum`` for counts and moments, ``pmin``/``pmax`` for
    extrema), so each shard returns the GLOBAL stats of the sharded field.
    Jit-safe: pure jnp reductions, no host sync — compose freely, convert
    with :func:`host_stats` when a Python-side decision is needed.
    """
    import jax
    import jax.numpy as jnp

    x = jnp.asarray(x)
    finite = jnp.isfinite(x)
    nan_count = jnp.sum(jnp.isnan(x), dtype=jnp.int32)
    inf_count = jnp.sum(jnp.isinf(x), dtype=jnp.int32)
    n_finite = jnp.sum(finite, dtype=jnp.int32)
    xf = jnp.where(finite, x, 0).astype(jnp.float32)
    total = jnp.sum(xf)
    sumsq = jnp.sum(xf * xf)
    mn = jnp.min(jnp.where(finite, x, jnp.inf).astype(jnp.float32))
    mx = jnp.max(jnp.where(finite, x, -jnp.inf).astype(jnp.float32))
    size = jnp.asarray(x.size, jnp.int32)

    if axis_names:
        ax = tuple(axis_names)
        nan_count = jax.lax.psum(nan_count, ax)
        inf_count = jax.lax.psum(inf_count, ax)
        n_finite = jax.lax.psum(n_finite, ax)
        total = jax.lax.psum(total, ax)
        sumsq = jax.lax.psum(sumsq, ax)
        size = jax.lax.psum(size, ax)
        mn = jax.lax.pmin(mn, ax)
        mx = jax.lax.pmax(mx, ax)

    mean = total / jnp.maximum(n_finite, 1).astype(jnp.float32)
    return {
        "size": size,
        "nan_count": nan_count,
        "inf_count": inf_count,
        "min": mn,
        "max": mx,
        "mean": mean,
        "l2": jnp.sqrt(sumsq),
    }


def host_stats(stats: Mapping[str, Any]) -> dict[str, float]:
    """:func:`field_stats` output as plain Python floats (one tiny host
    transfer per scalar — the only device->host traffic a probe costs)."""
    return {k: float(v) for k, v in stats.items()}


def is_healthy(stats: Mapping[str, float], *, max_abs: float | None = None) -> bool:
    """Healthy = no NaN, no Inf, and (when ``max_abs`` is set) every finite
    value within ``[-max_abs, max_abs]`` — the early-warning bound for a
    field that is *about* to overflow."""
    if stats["nan_count"] > 0 or stats["inf_count"] > 0:
        return False
    if max_abs is not None:
        if max(abs(stats["min"]), abs(stats["max"])) > max_abs:
            return False
    return True


class NumericsError(RuntimeError):
    """A health probe found a blow-up and the policy said abort.

    Carries the failing ``step``, ``field`` name and the host-side
    ``stats`` dict so callers (and the flight-recorder crash dump) can
    report exactly what went bad without re-probing."""

    def __init__(self, message: str, *, step: int, field: str,
                 stats: dict[str, float]):
        super().__init__(message)
        self.step = step
        self.field = field
        self.stats = stats


class HealthMonitor:
    """Cadence-gated numerics watchdog for a long step loop.

    ``check(step, x)`` probes every ``cadence`` steps (and whenever
    ``force=True``); off-cadence calls return None having done NO device
    work. A healthy probe remembers ``(step, state)`` as the last healthy
    point (``state`` defaults to ``x``; pass the full model state
    explicitly when ``x`` is a cheap proxy like the loss). Note the
    retained reference keeps that state alive until the next healthy probe
    replaces it — the memory cost of ``checkpoint-then-abort``.

    ``snapshot_state=True`` copies the retained state to host
    (``np.asarray`` over the tree) at probe time. REQUIRED when the step
    function donates its state buffers (``jax.jit(..., donate_argnums)``):
    the device arrays a probe retains are deleted by the very next step,
    so without a snapshot ``checkpoint_fn`` would read dead buffers and
    the advertised last-healthy checkpoint could never be written. The
    host-copy cost is paid only on cadence probes, never off-cadence.

    Tracer arguments (probe called while being traced inside jit /
    shard_map / scan) step aside entirely, exactly like
    ``metrics.instrument_call``: the traced computation is byte-identical
    with the monitor attached.
    """

    def __init__(
        self,
        cadence: int = 10,
        policy: str = "warn",
        *,
        max_abs: float | None = None,
        name: str = "field",
        checkpoint_fn: Callable[[int, Any], Any] | None = None,
        snapshot_state: bool = False,
        log_fn: Callable[[str], Any] = print,
    ) -> None:
        if cadence < 1:
            raise ValueError(f"cadence must be >= 1, got {cadence}")
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        if policy == "checkpoint-then-abort" and checkpoint_fn is None:
            raise ValueError("policy 'checkpoint-then-abort' needs checkpoint_fn")
        self.cadence = cadence
        self.policy = policy
        self.max_abs = max_abs
        self.name = name
        self.checkpoint_fn = checkpoint_fn
        self.snapshot_state = snapshot_state
        self.log_fn = log_fn
        self.probes = 0
        self.blowups = 0
        self.last_healthy: tuple[int, Any] | None = None
        self._auto_step = 0  # wrap()'s call counter

    def due(self, step: int) -> bool:
        return step % self.cadence == 0

    def check(self, step: int, x, *, name: str | None = None,
              state: Any = None, force: bool = False) -> dict[str, float] | None:
        """Probe ``x`` if due. Returns the host stats dict when a probe ran
        (healthy or not, under ``warn``), None when skipped. Raises
        :class:`NumericsError` on a blow-up under the abort policies."""
        if metrics.has_tracer(x):
            return None
        if not force and not self.due(step):
            return None
        name = name or self.name
        stats = host_stats(field_stats(x))
        self.probes += 1
        metrics.inc("health.probes")
        for k, v in stats.items():
            metrics.set_gauge(f"health.{name}.{k}", v)
        events.record("health.probe", step=step, field=name, **stats)
        if is_healthy(stats, max_abs=self.max_abs):
            keep = x if state is None else state
            if self.snapshot_state:
                keep = _host_snapshot(keep)
            self.last_healthy = (step, keep)
            return stats
        self.blowups += 1
        metrics.inc("health.blowups")
        events.record("health.blowup", step=step, field=name,
                      policy=self.policy, **stats)
        msg = (
            f"numerics blow-up in {name!r} at step {step}: "
            f"nan={stats['nan_count']:.0f} inf={stats['inf_count']:.0f} "
            f"min={stats['min']:.3e} max={stats['max']:.3e} l2={stats['l2']:.3e}"
            f" [policy={self.policy}]"
        )
        if self.policy == "warn":
            self.log_fn(msg)
            return stats
        if self.policy == "checkpoint-then-abort":
            if self.last_healthy is not None:
                ck_step, ck_state = self.last_healthy
                out = self.checkpoint_fn(ck_step, ck_state)
                events.record("health.checkpoint", step=ck_step,
                              path=str(out) if out is not None else None)
                self.log_fn(f"health: checkpointed last healthy state "
                            f"(step {ck_step}) before abort")
            else:
                self.log_fn("health: no healthy probe recorded yet — "
                            "aborting without a checkpoint")
        events.crash_dump(reason=msg)
        raise NumericsError(msg, step=step, field=name, stats=stats)

    def wrap(self, fn: Callable, *, name: str | None = None) -> Callable:
        """Wraps a step function so every call counts as one step and the
        OUTPUT is probed on cadence. The output is returned unchanged
        whether or not a probe ran (and the probe itself steps aside under
        tracers), so a wrapped step is bit-identical to the bare one."""

        def wrapped(*args, **kwargs):
            out = fn(*args, **kwargs)
            step = self._auto_step
            self._auto_step += 1
            self.check(step, out, name=name)
            return out

        wrapped.__name__ = getattr(fn, "__name__", "wrapped")
        wrapped.__wrapped__ = fn
        return wrapped
