"""Lightweight metrics registry: counters, gauges, wall-clock timers.

The observability substrate every instrumented layer reports through
(``repro.ir`` lowerings, ``repro.dist.halo``, ``repro.serve.engine``,
``benchmarks/common``). Design constraints, in order:

  * **Zero overhead when disabled.** No registry is installed by default;
    every instrumentation hook checks ``current() is None`` (one module
    attribute read) and falls straight through. Timers hand back a shared
    no-op context manager, so a disabled hot loop allocates nothing.
  * **``block_until_ready`` discipline.** Timing JAX work without draining
    the async dispatch queue measures dispatch, not compute.
    :func:`MetricsRegistry.time_call` blocks on the call's result before
    stopping the clock; :func:`instrument_call` applies the same rule to a
    whole lowered step function. Blocking is a no-op on tracers, so an
    instrumented callable can still be traced inside an enclosing ``jit`` /
    ``shard_map`` (the wrapper detects tracer arguments and steps aside
    entirely — trace-time work must not pollute wall-clock stats).
  * **Nesting is visible.** Active timers form a stack; a timer opened
    inside another records under ``"outer/inner"``, so a per-op scope
    nested in a per-call scope reads as a path, not a name collision.

Enable explicitly (``enable()`` / ``using(reg)``) or via the environment:
``REPRO_METRICS=1`` installs a registry at import time, which is how the
conformance matrix and the multidev suites run fully instrumented.
"""

from __future__ import annotations

import dataclasses
import os
import time
from contextlib import contextmanager
from typing import Any, Callable

METRICS_ENV = "REPRO_METRICS"

_TRUTHY = ("1", "true", "yes", "on")


@dataclasses.dataclass
class TimerStat:
    """Aggregated wall-clock stats for one timer name."""

    count: int = 0
    total_s: float = 0.0
    min_s: float = float("inf")
    max_s: float = 0.0

    def record(self, dt: float) -> None:
        self.count += 1
        self.total_s += dt
        self.min_s = min(self.min_s, dt)
        self.max_s = max(self.max_s, dt)

    def as_dict(self) -> dict[str, float]:
        return {
            "count": self.count,
            "total_s": self.total_s,
            "min_s": self.min_s if self.count else 0.0,
            "max_s": self.max_s,
            "mean_s": self.total_s / self.count if self.count else 0.0,
        }


class MetricsRegistry:
    """Counters, gauges and nested wall-clock timers.

    Not thread-safe by design: the instrumented paths are single-threaded
    (one Python caller driving jitted steps); a per-thread registry is the
    caller's job if they ever need one.
    """

    def __init__(self) -> None:
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.timers: dict[str, TimerStat] = {}
        self._stack: list[str] = []

    # -- counters / gauges -------------------------------------------------
    def inc(self, name: str, n: float = 1.0) -> float:
        new = self.counters.get(name, 0.0) + n
        self.counters[name] = new
        return new

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    # -- timers ------------------------------------------------------------
    @contextmanager
    def timer(self, name: str):
        """Times a ``with`` block. Nested timers record under the joined
        path of every active timer (``"outer/inner"``)."""
        self._stack.append(name)
        path = "/".join(self._stack)
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            dt = time.perf_counter() - t0
            popped = self._stack.pop()
            assert popped == name
            self.timers.setdefault(path, TimerStat()).record(dt)

    def observe(self, name: str, dt: float) -> None:
        """Records an externally-measured duration (seconds) under ``name``.

        For latencies whose start/stop points live on different call paths
        (e.g. queue latency: stamped at submit, resolved at prefill), where
        a ``with`` block can't bracket the interval."""
        self.timers.setdefault(name, TimerStat()).record(dt)

    def time_call(self, name: str, fn: Callable, *args, **kwargs) -> Any:
        """Calls ``fn`` under ``timer(name)``, blocking on the result (the
        ``block_until_ready`` discipline) before the clock stops."""
        import jax

        with self.timer(name):
            out = fn(*args, **kwargs)
            jax.block_until_ready(out)
        return out

    # -- lifecycle ---------------------------------------------------------
    def reset(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.timers.clear()
        self._stack.clear()

    def snapshot(self) -> dict[str, Any]:
        """A JSON-serialisable copy of everything recorded so far."""
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "timers": {k: v.as_dict() for k, v in self.timers.items()},
        }


# --- module-level switchboard --------------------------------------------

_REGISTRY: MetricsRegistry | None = None


class _NullTimer:
    """Shared no-op context manager: the disabled-path timer."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_TIMER = _NullTimer()


def current() -> MetricsRegistry | None:
    """The active registry, or None when metrics are disabled."""
    return _REGISTRY


def enabled() -> bool:
    return _REGISTRY is not None


def enable(registry: MetricsRegistry | None = None) -> MetricsRegistry:
    """Installs ``registry`` (or a fresh one) as the active registry."""
    global _REGISTRY
    _REGISTRY = registry if registry is not None else MetricsRegistry()
    return _REGISTRY


def disable() -> None:
    global _REGISTRY
    _REGISTRY = None


@contextmanager
def using(registry: MetricsRegistry | None = None):
    """Scoped ``enable()``: restores the previous registry on exit."""
    global _REGISTRY
    prev = _REGISTRY
    reg = registry if registry is not None else MetricsRegistry()
    _REGISTRY = reg
    try:
        yield reg
    finally:
        _REGISTRY = prev


# -- zero-overhead convenience hooks (the instrumented layers call these) --


def inc(name: str, n: float = 1.0) -> None:
    if _REGISTRY is not None:
        _REGISTRY.inc(name, n)


def set_gauge(name: str, value: float) -> None:
    if _REGISTRY is not None:
        _REGISTRY.set_gauge(name, value)


def timer(name: str):
    """A timer for the active registry, or the shared no-op when disabled."""
    if _REGISTRY is None:
        return _NULL_TIMER
    return _REGISTRY.timer(name)


def observe(name: str, dt: float) -> None:
    if _REGISTRY is not None:
        _REGISTRY.observe(name, dt)


def _tracer_type():
    import jax

    try:
        return jax.core.Tracer
    except AttributeError:  # pragma: no cover - very old/new jax layouts
        from jax._src.core import Tracer

        return Tracer


def has_tracer(x) -> bool:
    """True when any pytree leaf of ``x`` is a jax tracer — i.e. the caller
    is being traced inside an enclosing transformation and instrumentation
    side effects must step aside."""
    import jax

    tracer = _tracer_type()
    return any(isinstance(leaf, tracer) for leaf in jax.tree_util.tree_leaves(x))


def instrument_call(fn: Callable, name: str) -> Callable:
    """Wraps a lowered step function with a per-call timer + counter.

    When metrics are disabled the wrapper is a single attribute check; when
    any argument is a tracer (the callable is being traced inside an
    enclosing ``jit`` / ``shard_map`` / Pallas body) it also steps aside,
    so trace-time work never lands in wall-clock stats and the traced
    computation is byte-identical to the uninstrumented one.
    """

    def wrapped(*args, **kwargs):
        reg = _REGISTRY
        if reg is None or has_tracer(args) or has_tracer(kwargs):
            return fn(*args, **kwargs)
        reg.inc(f"{name}.calls")
        return reg.time_call(name, fn, *args, **kwargs)

    wrapped.__name__ = getattr(fn, "__name__", "wrapped")
    wrapped.__wrapped__ = fn
    wrapped.metric_name = name
    return wrapped


if os.environ.get(METRICS_ENV, "").lower() in _TRUTHY:
    enable()
