"""Flight recorder: a bounded ring of structured run events + JSONL sink.

The run-health counterpart of :mod:`repro.obs.metrics`: where the registry
aggregates (counters/gauges/timers), the recorder keeps the *sequence* —
what happened, in what order, right up to the moment a long forecast blew
up. Design constraints mirror the metrics switchboard:

  * **Zero overhead when disabled.** No recorder installed means every
    module hook (:func:`record`, :func:`span`, :func:`crash_dump`) is one
    attribute check; ``span`` hands back a shared no-op context manager.
  * **Bounded memory.** The ring holds the last ``capacity`` events
    (``deque(maxlen=...)``); older events are dropped (and counted in
    ``dropped``) — a million-step forecast can record every probe without
    growing without bound.
  * **Crash-survivable.** With a sink configured (``REPRO_EVENT_LOG=path``
    or ``FlightRecorder(sink=...)``) every event is appended to the JSONL
    file *as it is recorded* (line-buffered + flushed), so a hard crash
    still leaves the log on disk. The first line of the sink is a ``meta``
    event carrying :func:`repro.obs.report.runtime_metadata`. On a managed
    abort, :meth:`FlightRecorder.crash_dump` additionally writes the whole
    ring (plus metadata and the abort reason) as one JSON document.

Event timestamps are ``time.monotonic()`` (ordering/durations are immune
to wall-clock steps) plus ``time.time()`` for cross-run correlation.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import deque
from contextlib import contextmanager, nullcontext
from pathlib import Path
from typing import Any

EVENT_LOG_ENV = "REPRO_EVENT_LOG"
DEFAULT_CAPACITY = 4096


@dataclasses.dataclass(frozen=True)
class Event:
    """One structured run event."""

    seq: int              # recorder-local sequence number (total order)
    ts: float             # time.monotonic() at record time
    wall: float           # time.time() at record time
    kind: str             # dotted event name, e.g. "health.blowup"
    data: dict[str, Any]  # free-form JSON-serialisable payload

    def as_dict(self) -> dict[str, Any]:
        return {
            "seq": self.seq,
            "ts": self.ts,
            "wall": self.wall,
            "kind": self.kind,
            "data": self.data,
        }


class FlightRecorder:
    """Bounded ring buffer of :class:`Event` with an optional JSONL sink."""

    def __init__(
        self,
        capacity: int = DEFAULT_CAPACITY,
        sink: str | Path | None = None,
    ) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.sink = Path(sink) if sink else None
        self.dropped = 0
        self._ring: deque[Event] = deque(maxlen=capacity)
        self._seq = 0
        self._file = None  # lazily opened append handle
        self._header_written = False  # once per recorder, even across close/reopen

    # -- recording ---------------------------------------------------------
    def record(self, kind: str, **data: Any) -> Event:
        ev = Event(seq=self._seq, ts=time.monotonic(), wall=time.time(),
                   kind=kind, data=data)
        self._seq += 1
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(ev)
        if self.sink is not None:
            self._write_line(ev)
        return ev

    @contextmanager
    def span(self, kind: str, **data: Any):
        """Times a ``with`` block and records ONE event on exit with the
        measured ``duration_s`` (single-event spans keep the sink small;
        the start instant is recoverable as ``ts - duration_s``)."""
        t0 = time.monotonic()
        try:
            yield self
        finally:
            self.record(kind, duration_s=time.monotonic() - t0, **data)

    # -- inspection --------------------------------------------------------
    def events(self, kind: str | None = None) -> list[Event]:
        """A snapshot of the ring, optionally filtered by exact kind."""
        if kind is None:
            return list(self._ring)
        return [e for e in self._ring if e.kind == kind]

    def __len__(self) -> int:
        return len(self._ring)

    # -- sink / dump -------------------------------------------------------
    def _metadata(self) -> dict[str, Any]:
        """Best-effort runtime stamp: recorder I/O must never take the run
        down (and must not force a jax backend if one can't initialise)."""
        try:
            from repro.obs.report import runtime_metadata

            return runtime_metadata()
        except Exception as e:  # pragma: no cover - backend-dependent
            return {"error": f"runtime_metadata unavailable: {e!r}"}

    def _write_line(self, ev: Event) -> None:
        if self._file is None:
            self.sink.parent.mkdir(parents=True, exist_ok=True)
            self._file = self.sink.open("a", buffering=1)
            if not self._header_written:
                header = {"seq": -1, "ts": time.monotonic(), "wall": time.time(),
                          "kind": "meta", "data": self._metadata()}
                self._file.write(json.dumps(header, default=str) + "\n")
                self._header_written = True
        self._file.write(json.dumps(ev.as_dict(), default=str) + "\n")
        self._file.flush()

    def crash_dump(self, path: str | Path | None = None, *,
                   reason: str = "") -> Path | None:
        """Flushes the whole ring (+ metadata + ``reason``) as one JSON
        document — the abort-path artifact. Default target: the sink path
        with ``.crash.json`` appended; returns None (no-op) when neither a
        path nor a sink is configured (the in-memory ring remains
        inspectable via :meth:`events`)."""
        if path is None:
            if self.sink is None:
                return None
            path = self.sink.with_name(self.sink.name + ".crash.json")
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "reason": reason,
            "metadata": self._metadata(),
            "dropped": self.dropped,
            "events": [e.as_dict() for e in self._ring],
        }
        path.write_text(json.dumps(payload, indent=2, default=str) + "\n")
        return path

    def close(self) -> None:
        """Closes the sink file handle. Safe to keep using the recorder:
        the next sink write lazily reopens in append mode (without
        duplicating the ``meta`` header)."""
        if self._file is not None:
            self._file.close()
            self._file = None


# --- module-level switchboard (mirrors repro.obs.metrics) ------------------

_RECORDER: FlightRecorder | None = None


def current() -> FlightRecorder | None:
    """The active recorder, or None when event logging is disabled."""
    return _RECORDER


def enabled() -> bool:
    return _RECORDER is not None


def enable(recorder: FlightRecorder | None = None) -> FlightRecorder:
    """Installs ``recorder`` (or a fresh sink-less one) as active. A
    different recorder being replaced has its sink handle closed — the
    switchboard owns the fd of whatever it installed (re-enabling the old
    recorder later is safe: the sink lazily reopens)."""
    global _RECORDER
    rec = recorder if recorder is not None else FlightRecorder()
    if _RECORDER is not None and _RECORDER is not rec:
        _RECORDER.close()
    _RECORDER = rec
    return _RECORDER


def disable() -> None:
    """Uninstalls (and closes the sink handle of) the active recorder."""
    global _RECORDER
    if _RECORDER is not None:
        _RECORDER.close()
    _RECORDER = None


@contextmanager
def using(recorder: FlightRecorder | None = None):
    """Scoped :func:`enable`: restores the previous recorder on exit and
    closes the scoped one's sink handle (its ring stays inspectable)."""
    global _RECORDER
    prev = _RECORDER
    rec = recorder if recorder is not None else FlightRecorder()
    _RECORDER = rec
    try:
        yield rec
    finally:
        _RECORDER = prev
        if rec is not prev:
            rec.close()


# -- zero-overhead convenience hooks (instrumented layers call these) -------


def record(kind: str, **data: Any) -> Event | None:
    if _RECORDER is not None:
        return _RECORDER.record(kind, **data)
    return None


def span(kind: str, **data: Any):
    """A span on the active recorder, or a shared no-op when disabled."""
    if _RECORDER is None:
        return nullcontext(None)
    return _RECORDER.span(kind, **data)


def crash_dump(path: str | Path | None = None, *, reason: str = "") -> Path | None:
    if _RECORDER is not None:
        return _RECORDER.crash_dump(path, reason=reason)
    return None


if os.environ.get(EVENT_LOG_ENV, "").strip():
    enable(FlightRecorder(sink=os.environ[EVENT_LOG_ENV].strip()))
