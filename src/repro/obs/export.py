"""Prometheus-style text exposition of the metrics registry.

Turns a :class:`repro.obs.metrics.MetricsRegistry` snapshot — counters,
gauges (including the ``health.<field>.<stat>`` gauges the
:class:`~repro.obs.health.HealthMonitor` maintains) and timers — into the
Prometheus text exposition format, so a scrape endpoint in front of
``serve.engine.BatchedServer`` (or any instrumented run) is one
``metrics_text()`` call away. No HTTP server lives here: serving bytes is
the caller's framework's job; this module only owns the wire format.

Mapping rules:

  * counter ``serve.prefills``      -> ``repro_serve_prefills_total``
  * gauge   ``health.psi.nan_count``-> ``repro_health_psi_nan_count``
  * timer   ``serve.decode_step``   -> summary ``repro_serve_decode_step_
    seconds`` (``_count`` + ``_sum``) plus ``_seconds_min``/``_seconds_max``
    gauges (min/max aren't part of the summary type but are too useful to
    drop).

Metric names are sanitised to ``[a-zA-Z_:][a-zA-Z0-9_:]*``; every exported
family carries ``# TYPE`` (and the original dotted name in ``# HELP``).
"""

from __future__ import annotations

import re
from typing import Any, Mapping

from repro.obs import metrics

_INVALID = re.compile(r"[^a-zA-Z0-9_:]")


def sanitize_metric_name(name: str) -> str:
    """A dotted/free-form metric name as a valid Prometheus identifier."""
    out = _INVALID.sub("_", name)
    if not out or out[0].isdigit():
        out = "_" + out
    return out


def _fmt(value: float) -> str:
    f = float(value)
    if f != f:
        return "NaN"
    if f in (float("inf"), float("-inf")):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f)


def prometheus_text(
    source: metrics.MetricsRegistry | Mapping[str, Any] | None = None,
    *,
    prefix: str = "repro",
) -> str:
    """The Prometheus exposition of ``source``.

    ``source`` may be a registry, an already-taken ``snapshot()`` dict, or
    None for the active registry. With metrics disabled (no registry) the
    exposition is a single comment line — a scrape endpoint must always
    have *something* well-formed to serve.
    """
    if source is None:
        source = metrics.current()
    if source is None:
        return "# repro metrics disabled (no registry installed)\n"
    snap = source.snapshot() if isinstance(source, metrics.MetricsRegistry) else source

    lines: list[str] = []

    for name in sorted(snap.get("counters", {})):
        m = f"{prefix}_{sanitize_metric_name(name)}_total"
        lines.append(f"# HELP {m} counter {name!r}")
        lines.append(f"# TYPE {m} counter")
        lines.append(f"{m} {_fmt(snap['counters'][name])}")

    for name in sorted(snap.get("gauges", {})):
        m = f"{prefix}_{sanitize_metric_name(name)}"
        lines.append(f"# HELP {m} gauge {name!r}")
        lines.append(f"# TYPE {m} gauge")
        lines.append(f"{m} {_fmt(snap['gauges'][name])}")

    for name in sorted(snap.get("timers", {})):
        stat = snap["timers"][name]
        base = f"{prefix}_{sanitize_metric_name(name)}_seconds"
        lines.append(f"# HELP {base} wall-clock summary of timer {name!r}")
        lines.append(f"# TYPE {base} summary")
        lines.append(f"{base}_count {_fmt(stat['count'])}")
        lines.append(f"{base}_sum {_fmt(stat['total_s'])}")
        for suffix, key in (("min", "min_s"), ("max", "max_s")):
            g = f"{base}_{suffix}"
            lines.append(f"# TYPE {g} gauge")
            lines.append(f"{g} {_fmt(stat[key])}")

    return "\n".join(lines) + "\n"
