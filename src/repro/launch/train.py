"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b \
      --steps 1000 --ckpt-dir /ckpts/glm4 [--smoke]

On a real TPU slice this process runs per host under `jax.distributed`
(initialize() is called when JAX_COORDINATOR_ADDRESS is set); on this CPU
container use --smoke for the reduced config. XLA collective/compute
overlap flags for the latency-hiding scheduler are set here — they are the
"overlap memory operations with arithmetic" discipline of §3.2 at pod scale.
"""

import os

# Latency-hiding scheduler: overlap collectives with compute (TPU).
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_enable_async_collective_fusion=true",
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--grad-compression", choices=["none", "bf16"], default="none")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--data", default="synthetic", help="synthetic | path to token file")
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR_ADDRESS"):
        import jax

        jax.distributed.initialize()

    from repro.configs import get_config, get_smoke_config
    from repro.data import DataConfig, make_dataset
    from repro.launch.mesh import make_host_mesh
    from repro.train import TrainConfig, train

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, remat=False)
    mesh = make_host_mesh()

    dc = DataConfig(
        seq_len=args.seq,
        global_batch=args.global_batch,
        vocab_size=cfg.vocab_size,
        kind="synthetic" if args.data == "synthetic" else "file",
        path="" if args.data == "synthetic" else args.data,
    )
    tc = TrainConfig(
        steps=args.steps,
        microbatches=args.microbatches,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        grad_compression=args.grad_compression,
    )
    train(cfg, tc, mesh, make_dataset(dc))


if __name__ == "__main__":
    main()
