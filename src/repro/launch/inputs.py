"""input_specs: ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

For each (arch, shape) cell this module builds:
  * the function to lower (train_step / prefill_step / serve_step),
  * abstract inputs (params, optimizer state, batch / cache / token),
  * in/out shardings from the logical-axis rules.

No device allocation happens anywhere here (weak-type-correct structs only).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import sharding_for, tree_shardings
from repro.models import build_cache, build_lm, lm_decode, lm_prefill
from repro.optim.optimizers import (
    make_optimizer,
    opt_state_axes,
    optimizer_config_from_model,
)

Struct = jax.ShapeDtypeStruct


@dataclasses.dataclass
class LoweringSpec:
    """Everything jit().lower() needs for one cell."""

    name: str
    fn: Callable
    args: tuple            # abstract args (pytrees of ShapeDtypeStruct)
    in_shardings: tuple
    out_shardings: Any
    meta: dict
    donate: tuple = ()     # donated arg indices (train: params+opt alias)


def _param_shardings(cfg: ModelConfig, mesh: Mesh, mode: str):
    params_abs, axes = build_lm(cfg, key=None)
    shapes = jax.tree.map(lambda s: s.shape, params_abs)
    return params_abs, tree_shardings(axes, mesh, shapes, mode=mode)


def _batch_structs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   microbatches: int = 1):
    b, s = shape.global_batch, shape.seq_len

    def mk(shp, dtype):
        if microbatches > 1:
            shp = (microbatches, shp[0] // microbatches) + shp[1:]
            ax = (None, "batch") + (None,) * (len(shp) - 2)
        else:
            ax = ("batch",) + (None,) * (len(shp) - 1)
        return Struct(shp, dtype), sharding_for(ax, mesh, shp)

    if cfg.frontend == "audio":
        toks, t_sh = mk((b, s, cfg.d_model), jnp.float32)
    else:
        toks, t_sh = mk((b, s), jnp.int32)
    labels, l_sh = mk((b, s), jnp.int32)
    batch = {"tokens": toks, "labels": labels}
    shard = {"tokens": t_sh, "labels": l_sh}
    if cfg.frontend == "vision":
        m, m_sh = mk((b, cfg.num_media_tokens, cfg.d_model), jnp.float32)
        batch["memory"] = m
        shard["memory"] = m_sh
    return batch, shard


def _cache_structs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    cache_abs, cache_axes = build_cache(cfg, shape.global_batch, shape.seq_len, abstract=True)
    shapes = jax.tree.map(lambda s: s.shape, cache_abs)
    shardings = tree_shardings(cache_axes, mesh, shapes, mode="decode")
    return cache_abs, shardings


def microbatches_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     budget_bytes: float = 4e9) -> int:
    """Gradient-accumulation factor so remat-saved activations
    (B_micro_local x S x D x 2B x n_layers) fit the per-chip budget —
    the standard production lever for deep stacks at 16 GiB/chip."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("pod", 1) * sizes.get("data", 1)
    b_loc = max(shape.global_batch // dp, 1)
    # RWKV's time-mix runs in f32 (decay/state numerics): 2x the bytes.
    act_bytes = 4 if cfg.family == "ssm" else 2
    per_sample = shape.seq_len * cfg.d_model * act_bytes * cfg.n_layers
    mb = 1
    while mb < b_loc and (b_loc // mb) * per_sample > budget_bytes:
        mb *= 2
    return mb


def make_lowering_spec(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       *, microbatches: int | None = None) -> LoweringSpec:
    meta = {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
    }

    if shape.kind == "train":
        from repro.train.loop import make_train_step

        if microbatches is None:
            microbatches = microbatches_for(cfg, shape, mesh)
        meta["microbatches"] = microbatches
        opt_cfg = optimizer_config_from_model(cfg)
        params_abs, p_sh = _param_shardings(cfg, mesh, "train")
        opt_init, _ = make_optimizer(opt_cfg)
        opt_abs = jax.eval_shape(opt_init, params_abs)
        o_axes = opt_state_axes(opt_cfg, build_lm(cfg, key=None)[1], params_abs)
        o_sh = tree_shardings(o_axes, mesh, jax.tree.map(lambda s: s.shape, opt_abs))
        batch_abs, b_sh = _batch_structs(cfg, shape, mesh, microbatches)
        step = make_train_step(cfg, opt_cfg, microbatches=microbatches)
        return LoweringSpec(
            name=f"{cfg.name}:{shape.name}:train_step",
            fn=step,
            args=(params_abs, opt_abs, batch_abs),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            meta=meta,
            donate=(0, 1),
        )

    if shape.kind == "prefill":
        params_abs, p_sh = _param_shardings(cfg, mesh, "decode")
        batch_abs, b_sh = _batch_structs(cfg, shape, mesh)
        cache_abs, c_sh = _cache_structs(cfg, shape, mesh)

        def prefill_step(params, tokens, cache, memory=None):
            return lm_prefill(cfg, params, tokens, cache, memory=memory)

        args = [params_abs, batch_abs["tokens"], cache_abs]
        in_sh = [p_sh, b_sh["tokens"], c_sh]
        if cfg.frontend == "vision":
            args.append(batch_abs["memory"])
            in_sh.append(b_sh["memory"])
        logits_sh = sharding_for(("batch", None), mesh,
                                 (shape.global_batch, cfg.vocab_size))
        return LoweringSpec(
            name=f"{cfg.name}:{shape.name}:prefill_step",
            fn=prefill_step,
            args=tuple(args),
            in_shardings=tuple(in_sh),
            out_shardings=(logits_sh, c_sh),
            meta=meta,
        )

    if shape.kind == "decode":
        params_abs, p_sh = _param_shardings(cfg, mesh, "decode")
        cache_abs, c_sh = _cache_structs(cfg, shape, mesh)
        b = shape.global_batch
        token = Struct((b,), jnp.int32)
        t_sh = sharding_for(("batch",), mesh, (b,))
        pos = Struct((), jnp.int32)
        pos_sh = NamedSharding(mesh, P())

        def serve_step(params, token, cache, pos):
            return lm_decode(cfg, params, token, cache, pos)

        logits_sh = sharding_for(("batch", None), mesh, (b, cfg.vocab_size))
        return LoweringSpec(
            name=f"{cfg.name}:{shape.name}:serve_step",
            fn=serve_step,
            args=(params_abs, token, cache_abs, pos),
            in_shardings=(p_sh, t_sh, c_sh, pos_sh),
            out_shardings=(logits_sh, c_sh),
            meta=meta,
        )

    raise ValueError(shape.kind)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """The brief's entry point: ShapeDtypeStruct stand-ins for every model
    input of the given cell (without shardings; see make_lowering_spec for
    the mesh-aware version)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.frontend == "audio":
            out = {"tokens": Struct((b, s, cfg.d_model), jnp.float32)}
        else:
            out = {"tokens": Struct((b, s), jnp.int32)}
        out["labels"] = Struct((b, s), jnp.int32)
        if cfg.frontend == "vision":
            out["memory"] = Struct((b, cfg.num_media_tokens, cfg.d_model), jnp.float32)
        return out
    if shape.kind == "prefill":
        out = input_specs(cfg, dataclasses.replace(shape, kind="train"))
        out.pop("labels")
        out["cache"], _ = build_cache(cfg, b, s, abstract=True)
        return out
    if shape.kind == "decode":
        cache, _ = build_cache(cfg, b, s, abstract=True)
        return {
            "token": Struct((b,), jnp.int32),
            "cache": cache,
            "pos": Struct((), jnp.int32),
        }
    raise ValueError(shape.kind)
