"""Analytic FLOP/byte model for every (arch x shape) cell.

This is the LM-side counterpart of the paper's §3.1 analytical modeling
(Eq. 5-10 count MACs and streamed words per hdiff output point; here we
count them per token per layer). Used to (a) cross-validate the compiled
cost analysis — XLA's cost model ignores `while` trip counts, so the
dry-run extrapolates from unrolled variants and checks against this — and
(b) provide honest totals for cells whose inner time-scans (RWKV/RG-LRU
prefill) can't be unrolled.

All counts are GLOBAL (whole step, all devices): divide by n_devices for
per-chip terms. FLOPs are dense-matmul convention (2 * M * N * K).
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig


def _attn_flops_per_token(cfg: ModelConfig, ctx: int, *, causal: bool, window: int) -> float:
    """One attention layer, one token, forward."""
    d, h, k, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2 * d * (h + 2 * k) * dh + 2 * h * dh * d       # qkv + out proj
    eff = min(ctx, window) if window else ctx
    if causal and not window:
        eff = ctx / 2  # average causal context during a full forward
    elif causal and window:
        eff = min(ctx / 2, window) if ctx <= 2 * window else window
    score_ctx = 2 * h * dh * eff * 2                        # qk^T + pv
    return proj + score_ctx


def _ffn_flops_per_token(cfg: ModelConfig, kind: str) -> float:
    d, f = cfg.d_model, cfg.d_ff
    if kind == "rwkv6":
        # channel mix: wk (d->f), wv (f->d), wr (d->d)
        return 2 * d * f * 2 + 2 * d * d
    if cfg.n_experts:
        moe = 2 * d * cfg.n_experts + cfg.top_k * 3 * 2 * d * f
        if cfg.moe_dense_residual:
            moe += 3 * 2 * d * f
        return moe
    mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
    return mult * 2 * d * f


def _mixer_flops_per_token(cfg: ModelConfig, kind: str, ctx: int) -> float:
    d = cfg.d_model
    if kind in ("attn", "local_attn"):
        return _attn_flops_per_token(cfg, ctx, causal=cfg.causal, window=cfg.window)
    if kind == "cross_attn":
        d_, h, kk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        proj_q = 2 * d_ * h * dh + 2 * h * dh * d_
        # media K/V projected once per sequence; amortised per token below
        score = 2 * h * dh * cfg.num_media_tokens * 2
        return proj_q + score
    if kind == "rglru":
        w = cfg.rnn_width
        return (
            3 * 2 * d * w          # gate, branch, out projections
            + 2 * cfg.conv_width * w
            + 2 * 2 * w * w        # r/i gates (full-rank)
            + 12 * w               # recurrence pointwise
        )
    if kind == "rwkv6":
        hs = cfg.rwkv_head_size
        lora = 64
        return (
            5 * 2 * d * d          # r,k,v,g,o projections
            + 5 * 2 * 2 * d * lora # ddlerp loras
            + 8 * d * hs           # wkv state update + readout
        )
    raise ValueError(kind)


def forward_flops(cfg: ModelConfig, n_tokens: float, ctx: int) -> float:
    """Forward-pass FLOPs for n_tokens tokens with context length ctx."""
    per_tok = 0.0
    for kind in cfg.layer_kinds:
        per_tok += _mixer_flops_per_token(cfg, kind, ctx)
        per_tok += _ffn_flops_per_token(cfg, kind)
    per_tok += 2 * cfg.d_model * cfg.vocab_size  # lm head
    return per_tok * n_tokens


def cell_flops(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, float]:
    """Global FLOPs for one step of the cell, plus the 6ND/2ND reference."""
    b, s = shape.global_batch, shape.seq_len
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        fwd = forward_flops(cfg, b * s, s)
        # remat: bwd = 2x fwd + ~1x recompute -> compiled ~= 4x fwd
        total = fwd * (4.0 if cfg.remat else 3.0)
        ref = 6 * n_act * b * s
    elif shape.kind == "prefill":
        total = forward_flops(cfg, b * s, s)
        ref = 2 * n_act * b * s
    else:  # decode: one token at full context
        total = forward_flops(cfg, b, s) * _decode_ctx_scale(cfg, s)
        ref = 2 * n_act * b
    return {"analytic": total, "reference_nd": ref}


def _decode_ctx_scale(cfg: ModelConfig, s: int) -> float:
    # forward_flops already uses ctx=s; decode reads the FULL cache (not the
    # causal average), handled inside _attn_flops (causal avg only applies
    # to full forwards) — here ctx is exact, so no extra scale.
    return 1.0


def cell_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Minimum global HBM traffic per step (params + optimizer + major
    activations/caches), the fused-kernel-style compulsory-traffic bound."""
    pbytes = {"float32": 4, "bfloat16": 2}[cfg.param_dtype]
    mbytes = {"float32": 4, "bfloat16": 2}[cfg.moment_dtype]
    n_params = cfg.param_count()
    b, s = shape.global_batch, shape.seq_len
    act_bytes = 2  # bf16 activations

    if shape.kind == "train":
        # params read + grad write + adam moments r/w (adafactor ~= 1x read)
        opt_mult = 4 * mbytes if cfg.optimizer == "adamw" else mbytes
        param_traffic = n_params * (pbytes + 4 + opt_mult)
        act_traffic = b * s * cfg.d_model * cfg.n_layers * act_bytes * 4
        return param_traffic + act_traffic
    if shape.kind == "prefill":
        cache_w = _cache_bytes(cfg, b, s)
        return n_params * pbytes + cache_w + b * s * cfg.d_model * cfg.n_layers * act_bytes * 2
    # decode: read all ACTIVE params + read cache once
    n_active = cfg.active_param_count()
    return n_active * pbytes + _cache_bytes(cfg, b, s)


def _cache_bytes(cfg: ModelConfig, b: int, s: int) -> float:
    total = 0.0
    for kind in cfg.layer_kinds:
        if kind in ("attn", "local_attn"):
            eff = min(s, cfg.window) if cfg.window else s
            total += b * eff * cfg.n_kv_heads * cfg.head_dim * 2 * 2
        elif kind == "cross_attn":
            total += b * cfg.num_media_tokens * cfg.n_kv_heads * cfg.head_dim * 2 * 2
        elif kind == "rglru":
            total += b * cfg.rnn_width * (cfg.conv_width + 1) * 2
        elif kind == "rwkv6":
            hs = cfg.rwkv_head_size
            total += b * (cfg.d_model // hs) * hs * hs * 4 + 2 * b * cfg.d_model * 4
    return total
