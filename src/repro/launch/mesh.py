"""Production device meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required because the
dry-run overrides the device count via XLA_FLAGS before first jax init,
while tests and benchmarks must see the real single CPU device.

Mesh axes:
  single-pod:  (16, 16)      over ("data", "model")     = 256 chips
  multi-pod:   (2, 16, 16)   over ("pod", "data", "model") = 512 chips

"pod" extends the data-parallel/FSDP dimension across the inter-pod links
(DCN or pod-to-pod ICI); "model" carries tensor/expert/sequence parallelism
inside a pod where ICI is fastest. See repro.dist.sharding for the logical-
axis -> mesh-axis rules.
"""

from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh for tests/small runs (e.g. (4, 2) on 8 host devices)."""
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (CPU tests, single-host runs)."""
    n = len(jax.devices())
    if n % model:
        raise ValueError(f"{n} devices not divisible by model={model}")
    return jax.make_mesh((n // model, model), ("data", "model"), axis_types=_auto(2))


def mesh_axis_names(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The axes that carry batch/data parallelism (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_devices(mesh: jax.sharding.Mesh) -> int:
    size = 1
    for s in mesh.devices.shape:
        size *= s
    return size
