import os

if __name__ == "__main__":  # `python -m repro.launch.dryrun` only: library
    # importers (parse_collective_bytes) must NOT have their device count
    # clobbered — they may be running under their own fake-device flags.
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
    ).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST run in a fresh process (``python -m repro.launch.dryrun``): jax locks
the device count on first BACKEND INIT, and the XLA_FLAGS line above
executes before anything can trigger one. (Under ``python -m`` the
``repro`` package — and via repro.compat, ``import jax`` — runs before
this module body; that is safe because the backend initialises lazily,
but nothing imported at package scope may touch device state, e.g. call
``jax.devices()``.)

Per cell this produces artifacts/dryrun/<arch>__<shape>__<mesh>.json with:

  * memory_analysis of the FULL model compile (proves the cell fits),
  * cost_analysis, corrected for XLA's while-loop trip-count blindness by
    extrapolating from two UNROLLED variants (1-superblock and
    2-superblock models): per_super = cost(2P) - cost(P);
    total = cost(P) + per_super * (n_super - 1 + tail/P),
  * collective bytes parsed from the unrolled variants' post-SPMD HLO and
    extrapolated the same way,
  * the analytic §3.1-style model (launch/analytic.py) as cross-check,
  * the three roofline terms + dominant bottleneck + useful-FLOPs ratio.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --skip-existing
"""

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from pathlib import Path

import numpy as np

import jax

# TPU v5e constants (per the brief).
PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_F32 = PEAK_FLOPS_BF16 / 2
HBM_BW = 819e9
ICI_BW = 50e9

OP_RE = re.compile(
    r"=\s*(?:\()?\s*(?P<shapes>(?:\w+\[[0-9,]*\][^)]*?)?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|s32|u32|s16|u16|s8|u8|pred|s64|u64)\[([0-9,]*)\]")
GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [n_groups, group_size]<=[total]
    m = GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-chip wire bytes per collective kind, from post-SPMD HLO.

    Result types appear on the LHS of each instruction; ring cost model:
      all-reduce(B, g):        2B(g-1)/g
      all-gather(out B):        B(g-1)/g
      reduce-scatter(out B,g):  B(g-1)       (input = B*g)
      all-to-all(B, g):         B(g-1)/g
      collective-permute(B):    B
    """
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "= " not in line:
            continue
        m = re.search(
            r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
            r"(?:-start|-done)?\(", line)
        if not m:
            continue
        if "-done(" in line:
            continue  # paired with -start; count once
        kind = m.group(1)
        lhs = line.split(m.group(0))[0]
        res_shapes = SHAPE_RE.findall(lhs)
        if "-start(" in line:
            # Async form: the LHS tuple holds the aliased input AND the
            # output plus u32[] scalar contexts — summing double-counts.
            # The OUTPUT is what the cost model wants: the largest tensor
            # entry for permute/all-reduce/all-to-all (in==out) and
            # all-gather (out is bigger); the smallest for reduce-scatter
            # (out is 1/g of the input). Scalar contexts are dropped.
            tensors = [_shape_bytes(d, s) for d, s in res_shapes if s]
            pick = min if kind == "reduce-scatter" else max
            res_bytes = pick(tensors) if tensors else 0
        else:
            res_bytes = sum(_shape_bytes(d, s) for d, s in res_shapes)
        g = _group_size(line)
        if kind == "collective-permute":
            # Point-to-point: moves its result bytes; no replica_groups
            # (HLO encodes source_target_pairs instead, so g is meaningless).
            moved = float(res_bytes) if "source_target_pairs" in line else 0.0
        elif g <= 1:
            moved = 0.0
        elif kind == "all-reduce":
            moved = 2.0 * res_bytes * (g - 1) / g
        elif kind == "all-gather":
            moved = res_bytes * (g - 1) / g
        elif kind == "reduce-scatter":
            moved = float(res_bytes) * (g - 1)
        else:  # all-to-all
            moved = res_bytes * (g - 1) / g
        totals[kind] = totals.get(kind, 0.0) + moved
        counts[kind] = counts.get(kind, 0) + 1
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return {"bytes": totals, "counts": counts}


def _compile_cell(cfg, shape, mesh, microbatches=None):
    from repro.launch.inputs import make_lowering_spec

    spec = make_lowering_spec(cfg, shape, mesh, microbatches=microbatches)
    jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                     out_shardings=spec.out_shardings,
                     donate_argnums=spec.donate)
    with jax.set_mesh(mesh):
        t0 = time.time()
        lowered = jitted.lower(*spec.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return spec, compiled, t_lower, t_compile


def _cost_of(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             *, overrides=None, tag_suffix: str = "") -> dict:
    from repro.configs import get_config, get_shape
    from repro.launch.analytic import cell_flops, cell_hbm_bytes
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(mesh.devices.shape))

    # ---- 1. full model: compile proof + memory analysis --------------------
    spec, compiled, t_lower, t_compile = _compile_cell(cfg, shape, mesh)
    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    raw_cost = _cost_of(compiled)
    hlo_len = len(compiled.as_text())
    del compiled

    # ---- 2. unrolled variants for trip-count-corrected cost ----------------
    plen = len(cfg.block_pattern)
    tail_len = cfg.n_layers - cfg.n_super * plen
    var = dict(unroll_layers=True, flash_unroll=True)
    cfg_a = dataclasses.replace(cfg, n_layers=plen, **var)
    cfg_b = dataclasses.replace(cfg, n_layers=2 * plen, **var)
    # Variants run microbatches=1: gradient accumulation is a fori_loop
    # (trip-blind in cost analysis) and total flops/bytes are identical.
    _, comp_a, _, t_a = _compile_cell(cfg_a, shape, mesh, microbatches=1)
    cost_a = _cost_of(comp_a)
    coll_a = parse_collective_bytes(comp_a.as_text())
    del comp_a
    _, comp_b, _, t_b = _compile_cell(cfg_b, shape, mesh, microbatches=1)
    cost_b = _cost_of(comp_b)
    coll_b = parse_collective_bytes(comp_b.as_text())
    del comp_b

    reps = cfg.n_super - 1 + tail_len / plen
    flops_dev = cost_a["flops"] + max(cost_b["flops"] - cost_a["flops"], 0.0) * reps
    bytes_dev = cost_a["bytes"] + max(cost_b["bytes"] - cost_a["bytes"], 0.0) * reps
    coll_dev = (
        coll_a["bytes"]["total"]
        + max(coll_b["bytes"]["total"] - coll_a["bytes"]["total"], 0.0) * reps
    )
    coll_detail = {
        k: coll_a["bytes"].get(k, 0.0)
        + max(coll_b["bytes"].get(k, 0.0) - coll_a["bytes"].get(k, 0.0), 0.0) * reps
        for k in set(coll_a["bytes"]) | set(coll_b["bytes"])
    }

    # ---- 3. analytic cross-check -------------------------------------------
    ana = cell_flops(cfg, shape)
    ana_bytes = cell_hbm_bytes(cfg, shape)

    # ---- 4. roofline terms ---------------------------------------------------
    peak = PEAK_FLOPS_BF16 if cfg.compute_dtype == "bfloat16" else PEAK_FLOPS_F32
    compute_s = flops_dev / peak
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
        key=lambda kv: kv[1],
    )[0]
    model_flops = ana["reference_nd"]
    hlo_total = flops_dev * n_dev
    result = {
        "cell": f"{arch}__{shape_name}__{mesh_kind}{tag_suffix}",
        "meta": spec.meta,
        "status": "ok",
        "timings_s": {"lower": round(t_lower, 2), "compile": round(t_compile, 2),
                      "variant_a_compile": round(t_a, 2), "variant_b_compile": round(t_b, 2)},
        "memory_analysis": mem_d,
        "cost_analysis": {
            "raw_flops_per_device": raw_cost["flops"],
            "raw_bytes_per_device": raw_cost["bytes"],
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "extrapolation_reps": reps,
        },
        "collectives": {"bytes_per_device": coll_detail, "total": coll_dev,
                        "counts_variant_b": coll_b["counts"]},
        "analytic": {"flops_global": ana["analytic"], "hbm_bytes_global": ana_bytes,
                     "flops_per_device": ana["analytic"] / n_dev,
                     "hlo_over_analytic": (hlo_total / ana["analytic"]) if ana["analytic"] else None},
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "model_flops": model_flops,
            "hlo_flops_total": hlo_total,
            "useful_flops_ratio": model_flops / hlo_total if hlo_total else None,
        },
        "hlo_bytes": hlo_len,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}__{shape_name}__{mesh_kind}{tag_suffix}.json").write_text(
        json.dumps(result, indent=1)
    )
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import all_cells

    out_dir = Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = all_cells() if args.all else [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            tag = f"{arch}__{shape}__{mk}"
            path = out_dir / f"{tag}.json"
            if args.skip_existing and path.exists():
                try:
                    if json.loads(path.read_text()).get("status") == "ok":
                        print(f"[dryrun] {tag}: exists, skipping", flush=True)
                        continue
                except Exception:
                    pass
            try:
                r = run_cell(arch, shape, mk, out_dir)
                rf = r["roofline"]
                print(
                    f"[dryrun] {tag}: OK compile={r['timings_s']['compile']}s "
                    f"compute={rf['compute_s']:.3e}s memory={rf['memory_s']:.3e}s "
                    f"coll={rf['collective_s']:.3e}s dom={rf['dominant']} "
                    f"useful={rf['useful_flops_ratio']:.2f} "
                    f"temp={r['memory_analysis']['temp_bytes']/2**30:.1f}GiB",
                    flush=True,
                )
            except Exception as e:
                failures += 1
                err = {"cell": tag, "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()}
                out_dir.mkdir(parents=True, exist_ok=True)
                path.write_text(json.dumps(err, indent=1))
                print(f"[dryrun] {tag}: FAIL {e!r}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
