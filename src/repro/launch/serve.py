"""Serving launcher: builds a model and runs the continuous-batching engine
over a synthetic request stream (or stdin token prompts).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --requests 16 --lanes 4
"""

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, get_smoke_config
    from repro.models import build_lm
    from repro.serve import BatchedServer

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    params, _ = build_lm(cfg, jax.random.PRNGKey(args.seed))
    srv = BatchedServer(cfg, params, lanes=args.lanes, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        plen = int(rng.integers(4, args.max_len // 4))
        srv.submit(rng.integers(0, cfg.vocab_size, size=(plen,)), args.max_new)
    done = srv.run_until_idle()
    dt = time.perf_counter() - t0
    print(
        f"{len(done)}/{args.requests} requests, {srv.stats['tokens_out']} tokens, "
        f"{dt:.2f}s ({srv.stats['tokens_out']/dt:.1f} tok/s)"
    )


if __name__ == "__main__":
    main()
