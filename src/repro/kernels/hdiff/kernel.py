"""Fused hdiff Pallas TPU kernel — the SPARTA multi-AIE/B-block analogue.

Design (see DESIGN.md §2 for the AIE->TPU mapping):

  * Grid = ``(depth, row_tiles)``. One program instance owns one row-tile of
    one plane — the analogue of one B-block *lane* owning one output-row
    offset of one plane (§3.4).
  * The radius-2 halo is provided by the **three-slab trick**: the input is
    passed three times with block index maps ``i-1 / i / i+1`` (clamped at
    the edges). The kernel concatenates ``prev[-2:] ++ cur ++ next[:2]`` in
    VMEM, giving each tile its halo without any overlapping-BlockSpec
    support. Clamped edge blocks contribute garbage rows that are only ever
    consumed by boundary outputs, which are overwritten by the passthrough
    mask — verified against the oracle in tests.
  * Laplacian, flux (with limiter), and output update all happen in one
    kernel body: intermediates live in VMEM/VREGs only. This is the paper's
    "keep data in the accumulator registers / cascade forwarding" discipline;
    HBM sees exactly one read of psi (+coeff) and one write of the output —
    the compulsory-traffic lower bound (`hdiff_min_bytes`).
  * The Pallas grid pipeline double-buffers the HBM->VMEM block fetches,
    which is the shimDMA ping-pong of §3.2.1.

Supported dtypes: f32 / bf16 (compute in f32), and int32 fixed-point
(the paper's i32 datapath) via ``hdiff_fixed_kernel``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

HALO = 2


def _hdiff_tile_math(x: Array, coeff: Array | float, *, limit: bool) -> Array:
    """hdiff interior math on a (rows+4, cols) f32 tile -> (rows, cols-4)."""
    lap = (
        4.0 * x[1:-1, 1:-1]
        - x[2:, 1:-1]
        - x[:-2, 1:-1]
        - x[1:-1, 2:]
        - x[1:-1, :-2]
    )
    lap_c = lap[1:-1, 1:-1]
    flx_r = lap[2:, 1:-1] - lap_c
    flx_rm = lap_c - lap[:-2, 1:-1]
    flx_c = lap[1:-1, 2:] - lap_c
    flx_cm = lap_c - lap[1:-1, :-2]

    if limit:
        psi_c = x[2:-2, 2:-2]
        zero = jnp.zeros_like(flx_r)
        flx_r = jnp.where(flx_r * (x[3:-1, 2:-2] - psi_c) <= 0, flx_r, zero)
        flx_rm = jnp.where(flx_rm * (psi_c - x[1:-3, 2:-2]) <= 0, flx_rm, zero)
        flx_c = jnp.where(flx_c * (x[2:-2, 3:-1] - psi_c) <= 0, flx_c, zero)
        flx_cm = jnp.where(flx_cm * (psi_c - x[2:-2, 1:-3]) <= 0, flx_cm, zero)

    return x[2:-2, 2:-2] - coeff * ((flx_r - flx_rm) + (flx_c - flx_cm))


def _hdiff_kernel(
    prev_ref, cur_ref, next_ref, coeff_ref, out_ref, *, block_rows: int, rows: int, limit: bool
):
    """Kernel body. Block shapes: inputs (1, block_rows, C); out (1, block_rows, C)."""
    i = pl.program_id(1)
    cur = cur_ref[0].astype(jnp.float32)
    halo_top = prev_ref[0, -HALO:, :].astype(jnp.float32)
    halo_bot = next_ref[0, :HALO, :].astype(jnp.float32)
    x = jnp.concatenate([halo_top, cur, halo_bot], axis=0)  # (block_rows+4, C)

    coeff = coeff_ref[0, 0]
    interior = _hdiff_tile_math(x, coeff, limit=limit)  # (block_rows, C-4)

    out = cur
    # Column passthrough: embed interior into the full-width tile.
    out = out.at[:, HALO:-HALO].set(interior.astype(out.dtype))
    # Row passthrough mask: global rows < 2 or >= rows-2 keep the input.
    gl_row = i * block_rows + jax.lax.broadcasted_iota(jnp.int32, (block_rows, 1), 0)
    keep = (gl_row < HALO) | (gl_row >= rows - HALO)
    out = jnp.where(keep, cur, out)
    out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "limit", "interpret")
)
def hdiff_pallas(
    psi: Array,
    coeff: float | Array = 0.025,
    *,
    block_rows: int = 128,
    limit: bool = True,
    interpret: bool = False,
) -> Array:
    """Fused hdiff over a ``(depth, rows, cols)`` grid.

    ``block_rows`` is the VMEM row-tile size (multiples of 8 for f32 TPU
    sublane alignment; cols should be a multiple of 128 lanes for peak
    efficiency — both are *performance* knobs, any size is correct).
    """
    depth, rows, cols = psi.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        raise ValueError(f"rows={rows} not divisible by block_rows={block_rows}")
    row_tiles = rows // block_rows
    if 2 * HALO > block_rows:
        raise ValueError("block_rows must be >= 4")

    coeff_arr = jnp.full((1, 1), coeff, jnp.float32)

    grid = (depth, row_tiles)
    in_spec_prev = pl.BlockSpec(
        (1, block_rows, cols), lambda d, i: (d, jnp.maximum(i - 1, 0), 0)
    )
    in_spec_cur = pl.BlockSpec((1, block_rows, cols), lambda d, i: (d, i, 0))
    in_spec_next = pl.BlockSpec(
        (1, block_rows, cols), lambda d, i: (d, jnp.minimum(i + 1, row_tiles - 1), 0)
    )
    coeff_spec = pl.BlockSpec((1, 1), lambda d, i: (0, 0), memory_space=pltpu.MemorySpace.SMEM)
    out_spec = pl.BlockSpec((1, block_rows, cols), lambda d, i: (d, i, 0))

    kernel = functools.partial(
        _hdiff_kernel, block_rows=block_rows, rows=rows, limit=limit
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[in_spec_prev, in_spec_cur, in_spec_next, coeff_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(psi.shape, psi.dtype),
        interpret=interpret,
    )(psi, psi, psi, coeff_arr)


# ---------------------------------------------------------------------------
# int32 fixed-point datapath (the paper's i32 variant).
# ---------------------------------------------------------------------------


def _hdiff_fixed_kernel(
    prev_ref, cur_ref, next_ref, out_ref, *, block_rows: int, rows: int,
    coeff_num: int, coeff_shift: int
):
    i = pl.program_id(1)
    cur = cur_ref[0]
    x = jnp.concatenate([prev_ref[0, -HALO:, :], cur, next_ref[0, :HALO, :]], axis=0)

    lap = 4 * x[1:-1, 1:-1] - x[2:, 1:-1] - x[:-2, 1:-1] - x[1:-1, 2:] - x[1:-1, :-2]
    lap_c = lap[1:-1, 1:-1]
    flx_r = lap[2:, 1:-1] - lap_c
    flx_rm = lap_c - lap[:-2, 1:-1]
    flx_c = lap[1:-1, 2:] - lap_c
    flx_cm = lap_c - lap[1:-1, :-2]

    # Sign-based limiter (int32 product of flux * gradient overflows).
    def _keep(a, b):
        return (a == 0) | (b == 0) | ((a > 0) != (b > 0))

    psi_c = x[2:-2, 2:-2]
    zero = jnp.zeros_like(flx_r)
    flx_r = jnp.where(_keep(flx_r, x[3:-1, 2:-2] - psi_c), flx_r, zero)
    flx_rm = jnp.where(_keep(flx_rm, psi_c - x[1:-3, 2:-2]), flx_rm, zero)
    flx_c = jnp.where(_keep(flx_c, x[2:-2, 3:-1] - psi_c), flx_c, zero)
    flx_cm = jnp.where(_keep(flx_cm, psi_c - x[2:-2, 1:-3]), flx_cm, zero)

    total = (flx_r - flx_rm) + (flx_c - flx_cm)
    interior = psi_c - ((total * coeff_num) >> coeff_shift)

    out = cur.at[:, HALO:-HALO].set(interior)
    gl_row = i * block_rows + jax.lax.broadcasted_iota(jnp.int32, (block_rows, 1), 0)
    keep = (gl_row < HALO) | (gl_row >= rows - HALO)
    out_ref[0] = jnp.where(keep, cur, out)


@functools.partial(jax.jit, static_argnames=("coeff_num", "coeff_shift", "block_rows", "interpret"))
def hdiff_fixed_pallas(
    psi_q: Array,
    *,
    coeff_num: int = 26,          # 26/1024 ~= 0.0254
    coeff_shift: int = 10,
    block_rows: int = 128,
    interpret: bool = False,
) -> Array:
    depth, rows, cols = psi_q.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        raise ValueError(f"rows={rows} not divisible by block_rows={block_rows}")
    row_tiles = rows // block_rows

    kernel = functools.partial(
        _hdiff_fixed_kernel,
        block_rows=block_rows,
        rows=rows,
        coeff_num=coeff_num,
        coeff_shift=coeff_shift,
    )
    spec = lambda fn: pl.BlockSpec((1, block_rows, cols), fn)  # noqa: E731
    return pl.pallas_call(
        kernel,
        grid=(depth, row_tiles),
        in_specs=[
            spec(lambda d, i: (d, jnp.maximum(i - 1, 0), 0)),
            spec(lambda d, i: (d, i, 0)),
            spec(lambda d, i: (d, jnp.minimum(i + 1, row_tiles - 1), 0)),
        ],
        out_specs=spec(lambda d, i: (d, i, 0)),
        out_shape=jax.ShapeDtypeStruct(psi_q.shape, psi_q.dtype),
        interpret=interpret,
    )(psi_q, psi_q, psi_q)
