from repro.kernels.hdiff.multistep import hdiff_twostep
from repro.kernels.hdiff.ops import hdiff_fixed, hdiff_fused, hdiff_fused_ad
from repro.kernels.hdiff.ref import hdiff_fixed_point_ref, hdiff_ref, hdiff_simple_ref
