"""Temporal-blocked hdiff: TWO timesteps per HBM round-trip.

The paper's §1 insight — "their dataflow design provides an intuitive way to
take advantage of both spatial and temporal locality in iterative stencil
processing by pipelining different timesteps" — as a TPU kernel: the tile
(with a radius-4 row halo) is loaded into VMEM once, hdiff is applied twice
while the data stays resident, and only the final result returns to HBM.
Compulsory traffic per simulated step halves (the kernel-side analogue of
chaining two tri-AIE pipelines back-to-back).

Boundary semantics match two applications of the boundary-passthrough hdiff
exactly: each internal step applies the global passthrough ring using
absolute row indices, so ``hdiff_twostep(x) == hdiff(hdiff(x))`` bit-tight —
verified against the composed oracle in tests/test_kernels_hdiff_multistep.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.hdiff.kernel import HALO, _hdiff_tile_math

Array = jax.Array


def _apply_step(x: Array, coeff, rows_global: Array, rows_total: int, limit: bool) -> Array:
    """One hdiff step on a (n, C) tile with absolute row ids ``rows_global``
    for the n-4 interior rows produced; returns (n-4, C) incl. passthrough."""
    interior = _hdiff_tile_math(x, coeff, limit=limit)       # (n-4, C-4)
    out = x[HALO:-HALO, :]
    out = out.at[:, HALO:-HALO].set(interior.astype(out.dtype))
    keep = (rows_global < HALO) | (rows_global >= rows_total - HALO)
    return jnp.where(keep[:, None], x[HALO:-HALO, :], out)


def _twostep_kernel(prev_ref, cur_ref, next_ref, coeff_ref, out_ref, *,
                    block_rows: int, rows: int, limit: bool):
    i = pl.program_id(1)
    cur = cur_ref[0].astype(jnp.float32)
    top = prev_ref[0, -2 * HALO:, :].astype(jnp.float32)
    bot = next_ref[0, :2 * HALO, :].astype(jnp.float32)
    x = jnp.concatenate([top, cur, bot], axis=0)             # (block+8, C)
    coeff = coeff_ref[0, 0]

    base = i * block_rows
    rows1 = base - HALO + jax.lax.broadcasted_iota(jnp.int32, (block_rows + 2 * HALO,), 0)
    x1 = _apply_step(x, coeff, rows1, rows, limit)           # (block+4, C)
    rows2 = base + jax.lax.broadcasted_iota(jnp.int32, (block_rows,), 0)
    x2 = _apply_step(x1, coeff, rows2, rows, limit)          # (block, C)
    out_ref[0] = x2.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "limit", "interpret"))
def hdiff_twostep_pallas(
    psi: Array,
    coeff: float | Array = 0.025,
    *,
    block_rows: int = 128,
    limit: bool = True,
    interpret: bool = False,
) -> Array:
    """Two fused hdiff timesteps over ``(depth, rows, cols)``.

    Requires block_rows >= 2*HALO*2 = 8 (the two-step halo must fit inside a
    neighbouring block) and rows % block_rows == 0.
    """
    depth, rows, cols = psi.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        raise ValueError(f"rows={rows} not divisible by block_rows={block_rows}")
    if block_rows < 4 * HALO:
        raise ValueError(f"block_rows must be >= {4 * HALO} for two-step halos")
    row_tiles = rows // block_rows
    coeff_arr = jnp.full((1, 1), coeff, jnp.float32)

    spec = lambda fn: pl.BlockSpec((1, block_rows, cols), fn)  # noqa: E731
    kernel = functools.partial(_twostep_kernel, block_rows=block_rows, rows=rows,
                               limit=limit)
    return pl.pallas_call(
        kernel,
        grid=(depth, row_tiles),
        in_specs=[
            spec(lambda d, i: (d, jnp.maximum(i - 1, 0), 0)),
            spec(lambda d, i: (d, i, 0)),
            spec(lambda d, i: (d, jnp.minimum(i + 1, row_tiles - 1), 0)),
            pl.BlockSpec((1, 1), lambda d, i: (0, 0), memory_space=pltpu.MemorySpace.SMEM),
        ],
        out_specs=spec(lambda d, i: (d, i, 0)),
        out_shape=jax.ShapeDtypeStruct(psi.shape, psi.dtype),
        interpret=interpret,
    )(psi, psi, psi, coeff_arr)


def hdiff_twostep(psi: Array, coeff: float | Array = 0.025, *,
                  block_rows: int | None = None, limit: bool = True,
                  interpret: bool | None = None) -> Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if block_rows is None:
        from repro.kernels.hdiff.ops import _pick_block_rows

        block_rows = max(_pick_block_rows(psi.shape), 4 * HALO)
    return hdiff_twostep_pallas(psi, coeff, block_rows=block_rows, limit=limit,
                                interpret=interpret)
