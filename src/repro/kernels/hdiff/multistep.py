"""Temporal-blocked hdiff: TWO timesteps per HBM round-trip (thin wrapper).

The paper's §1 insight — "their dataflow design provides an intuitive way to
take advantage of both spatial and temporal locality in iterative stencil
processing by pipelining different timesteps" — originally lived here as a
hand-coded two-step Pallas kernel. Temporal blocking is now a first-class IR
transform (``repro.ir.repeat`` + the chain-aware ``lower_pallas``), so this
module is a thin wrapper: ``hdiff_twostep`` builds ``repeat(hdiff, 2)`` and
hands it to the generic k-step fused kernel — the tile (with a radius-4 row
halo) is loaded into VMEM once, hdiff is applied twice with the global
boundary ring re-applied at absolute row indices between the sweeps, and
only the final result returns to HBM. Compulsory traffic per simulated step
halves, and ``hdiff_twostep(x) == hdiff(hdiff(x))`` stays bit-tight —
verified against the composed oracle in tests/test_kernels_hdiff_multistep.py.

``block_rows`` resolves exactly like the other kernel entry points
(``hdiff_fused`` / ``hdiff_fixed``): explicit argument, else the shared VMEM
tile planner with the two-step structural floor, honouring ``vmem_budget`` /
``REPRO_VMEM_BUDGET``. An explicit ``block_rows`` is validated as given —
never silently clamped to ``rows`` first — so a call that passes cannot
flip to an error when ``rows`` changes.
"""

from __future__ import annotations

import functools

import jax

from repro.ir import hdiff_multistep_program, lower_pallas
from repro.ir.plan import pick_block_rows
from repro.kernels.hdiff.kernel import HALO

Array = jax.Array

# Two fused sweeps need a 2*HALO halo from EACH neighbouring block plus the
# block's own rows — the documented structural floor of the original
# hand-written kernel, kept as this wrapper's contract.
MIN_TWOSTEP_BLOCK_ROWS = 4 * HALO


def hdiff_twostep_pallas(
    psi: Array,
    coeff: float = 0.025,
    *,
    block_rows: int | None = None,
    limit: bool = True,
    interpret: bool = False,
    vmem_budget: int | None = None,
) -> Array:
    """Two fused hdiff timesteps over ``(depth, rows, cols)``.

    Requires ``block_rows >= 4*HALO = 8`` (the two-step halo must fit inside
    a neighbouring block) and ``rows % block_rows == 0``; ``block_rows=None``
    resolves via the shared VMEM tile planner (``vmem_budget`` arg >
    ``REPRO_VMEM_BUDGET`` env > 4 MiB).

    ``coeff`` must be a CONCRETE scalar: the IR path bakes it into the
    program graph (one compiled kernel per coefficient, cached). The old
    hand-written kernel threaded a traced coeff through SMEM; runtime
    scalars in IR programs would restore that and are future work.
    """
    if psi.ndim != 3:
        raise ValueError(f"expected (depth, rows, cols), got shape {psi.shape}")
    try:
        coeff = float(coeff)
    except TypeError as e:
        raise ValueError(
            "coeff must be a concrete Python/NumPy scalar — the IR-based "
            "kernel bakes it into the program graph; don't pass a traced "
            "value (call hdiff_twostep outside jit, or close over a "
            "constant)"
        ) from e
    _, rows, cols = psi.shape
    if block_rows is None:
        block_rows = pick_block_rows(
            rows, cols, budget_bytes=vmem_budget, min_rows=MIN_TWOSTEP_BLOCK_ROWS
        )
    if rows % block_rows:
        raise ValueError(f"rows={rows} not divisible by block_rows={block_rows}")
    if block_rows < MIN_TWOSTEP_BLOCK_ROWS:
        raise ValueError(
            f"block_rows must be >= {MIN_TWOSTEP_BLOCK_ROWS} for two-step halos"
        )
    return _lowered_twostep(coeff, limit, block_rows, interpret)(psi)


@functools.lru_cache(maxsize=64)
def _lowered_twostep(coeff: float, limit: bool, block_rows: int, interpret: bool):
    """Caches the lowered kernel so repeat calls reuse the jitted closure
    (lower_pallas returns a fresh jax.jit wrapper per lowering — without
    this, every call would retrace and recompile)."""
    prog = hdiff_multistep_program(2, coeff, limit=limit)
    return lower_pallas(prog, block_rows=block_rows, interpret=interpret)


def hdiff_twostep(psi: Array, coeff: float = 0.025, *,
                  block_rows: int | None = None, limit: bool = True,
                  interpret: bool | None = None,
                  vmem_budget: int | None = None) -> Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return hdiff_twostep_pallas(psi, coeff, block_rows=block_rows, limit=limit,
                                interpret=interpret, vmem_budget=vmem_budget)
