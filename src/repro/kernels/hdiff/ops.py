"""Public jit'd entry points for the hdiff kernels.

On CPU (this container) the Pallas TPU kernel runs in ``interpret=True``
mode; on a real TPU backend it compiles through Mosaic. ``auto_interpret``
resolves that automatically so callers never pass the flag.
"""

from __future__ import annotations

import functools

import jax

from repro.core.hdiff import HALO
from repro.ir.plan import pick_block_rows
from repro.kernels.hdiff.kernel import hdiff_fixed_pallas, hdiff_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def hdiff_fused(
    psi: Array,
    coeff: float | Array = 0.025,
    *,
    block_rows: int | None = None,
    limit: bool = True,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
) -> Array:
    """Fused hdiff (Laplacian+flux+output in one VMEM-resident kernel).

    Args:
      psi: ``(depth, rows, cols)`` f32/bf16 field.
      coeff: scalar diffusion coefficient.
      block_rows: VMEM row-tile; default picks the largest divisor of rows
        that keeps the tile under the VMEM budget (leaving headroom for the
        pipeline's double buffers).
      limit: apply the Eq. 2-3 flux limiter (the production COSMO form).
      interpret: force interpreter mode; default = interpret iff not on TPU.
      vmem_budget: per-block byte budget for the tile planner (default: the
        ``REPRO_VMEM_BUDGET`` env var, else 4 MiB).
    """
    if interpret is None:
        interpret = not _on_tpu()
    if block_rows is None:
        block_rows = _pick_block_rows(psi.shape, budget_bytes=vmem_budget)
    return hdiff_pallas(
        psi, coeff, block_rows=block_rows, limit=limit, interpret=interpret
    )


def hdiff_fixed(
    psi_q: Array,
    *,
    coeff_num: int = 26,
    coeff_shift: int = 10,
    block_rows: int | None = None,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
) -> Array:
    """int32 fixed-point hdiff (the paper's i32 datapath)."""
    if interpret is None:
        interpret = not _on_tpu()
    if block_rows is None:
        block_rows = _pick_block_rows(psi_q.shape, budget_bytes=vmem_budget)
    return hdiff_fixed_pallas(
        psi_q,
        coeff_num=coeff_num,
        coeff_shift=coeff_shift,
        block_rows=block_rows,
        interpret=interpret,
    )


# -- differentiable wrapper ---------------------------------------------------
#
# The Pallas kernel has no hand-written backward pass (and `pl.program_id`
# cannot be traced under JVP in interpret mode), so the differentiable entry
# point pairs the kernel FORWARD with the DERIVED-ADJOINT backward of the IR
# twin (`hdiff_coupled_program`) via custom_vjp: one `repro.ir.autodiff`
# reverse sweep, the same math every `build_backend(...,
# differentiable=True)` lowering runs — no duplicated vjp code here. The
# adjoint's linearization recompute costs one extra hdiff sweep, the same
# tradeoff as remat.


@functools.lru_cache(maxsize=None)
def _coupled_vjp(limit: bool):
    from repro.ir.autodiff import make_vjp
    from repro.ir.lower_reference import lower_reference
    from repro.ir.programs import hdiff_coupled_program

    return make_vjp(hdiff_coupled_program(limit=limit), lower_reference)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def hdiff_fused_ad(psi: Array, coeff: Array, limit: bool = True) -> Array:
    return hdiff_fused(psi, coeff, limit=limit)


def _hdiff_ad_fwd(psi, coeff, limit):
    return hdiff_fused(psi, coeff, limit=limit), (psi, coeff)


def _hdiff_ad_bwd(limit, res, g):
    psi, coeff = res
    # The IR twin takes a coefficient FIELD; a scalar coeff broadcasts in
    # and its cotangent pulls back through the same broadcast.
    def bcast(c):
        return jax.numpy.broadcast_to(jax.numpy.asarray(c, psi.dtype), psi.shape)

    cot = _coupled_vjp(limit)({"u": psi, "coeff": bcast(coeff)}, g)
    _, pull = jax.vjp(bcast, coeff)
    (gcoeff,) = pull(cot["coeff"])
    return cot["u"], gcoeff


hdiff_fused_ad.defvjp(_hdiff_ad_fwd, _hdiff_ad_bwd)


def _pick_block_rows(shape: tuple[int, ...], budget_bytes: int | None = None) -> int:
    """Largest divisor of ``rows`` whose (rows x cols) f32 tile fits budget.

    The pipeline keeps ~3 input blocks + 1 output block live (prev/cur/next
    + out) and double-buffers them, so the per-block budget is set well under
    VMEM/8. The budget is shared with the IR planner (``repro.ir.plan``):
    explicit ``budget_bytes`` > ``REPRO_VMEM_BUDGET`` env var > 4 MiB.
    """
    _, rows, cols = shape
    # The three-slab halo trick needs block_rows >= 2*HALO (kernel validates).
    return pick_block_rows(
        rows, cols, budget_bytes=budget_bytes, min_rows=min(2 * HALO, rows)
    )
