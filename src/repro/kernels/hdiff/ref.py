"""Pure-jnp oracle for the fused hdiff Pallas kernel.

Thin re-export of the core implementation so the kernel test harness has a
single canonical reference, plus the fixed-point (int32) variant that mirrors
the paper's ``i32`` datapath (§5.1.1, Fig. 9).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hdiff import hdiff as hdiff_ref  # noqa: F401  (canonical f32 oracle)
from repro.core.hdiff import hdiff_simple as hdiff_simple_ref  # noqa: F401

Array = jax.Array


def hdiff_fixed_point_ref(psi_q: Array, coeff_num: int, coeff_shift: int) -> Array:
    """int32 fixed-point hdiff oracle (the paper's i32 datapath).

    ``coeff = coeff_num / 2**coeff_shift``. All arithmetic is exact int32;
    the final coefficient multiply is a multiply + arithmetic right shift,
    matching an AIE fixed-point MAC + srs() round.
    """
    assert psi_q.dtype == jnp.int32
    lap = (
        4 * psi_q[..., 1:-1, 1:-1]
        - psi_q[..., 2:, 1:-1]
        - psi_q[..., :-2, 1:-1]
        - psi_q[..., 1:-1, 2:]
        - psi_q[..., 1:-1, :-2]
    )
    lap_c = lap[..., 1:-1, 1:-1]
    flx_r = lap[..., 2:, 1:-1] - lap_c
    flx_rm = lap_c - lap[..., :-2, 1:-1]
    flx_c = lap[..., 1:-1, 2:] - lap_c
    flx_cm = lap_c - lap[..., 1:-1, :-2]

    # Sign-based limiter: ``a * b <= 0`` without the (overflowing) int32
    # product — true iff either operand is zero or the signs differ.
    def _keep(a, b):
        return (a == 0) | (b == 0) | ((a > 0) != (b > 0))

    psi_c = psi_q[..., 2:-2, 2:-2]
    zero = jnp.zeros_like(flx_r)
    flx_r = jnp.where(_keep(flx_r, psi_q[..., 3:-1, 2:-2] - psi_c), flx_r, zero)
    flx_rm = jnp.where(_keep(flx_rm, psi_c - psi_q[..., 1:-3, 2:-2]), flx_rm, zero)
    flx_c = jnp.where(_keep(flx_c, psi_q[..., 2:-2, 3:-1] - psi_c), flx_c, zero)
    flx_cm = jnp.where(_keep(flx_cm, psi_c - psi_q[..., 2:-2, 1:-3]), flx_cm, zero)

    total = (flx_r - flx_rm) + (flx_c - flx_cm)
    interior = psi_c - ((total * coeff_num) >> coeff_shift)
    return psi_q.at[..., 2:-2, 2:-2].set(interior)
