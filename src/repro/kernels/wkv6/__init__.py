from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_chunked_ref, wkv6_ref
