"""RWKV-6 WKV Pallas TPU kernel.

Grid = (B, H): each program owns one (batch, head) stream. The (N, N)
state lives in VMEM scratch for the whole sequence — the direct analogue
of SPARTA keeping the Laplacian in the accumulator registers while flux
consumes it (§3.2): HBM sees r/k/v/w streamed in once and y streamed out
once; the O(T) state round-trips never happen.

Within the kernel the sequence is processed in CHUNKS of ``chunk`` steps:
the inter-chunk contribution is a dense (C,N)x(N,N) matmul (MXU), and the
intra-chunk part uses the decay-factored attention form (two (C,C)/(C,N)
matmuls) — the same chunked formulation as ref.wkv6_chunked_ref, validated
against the sequential oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                 *, chunk: int, t: int):
    n = r_ref.shape[-1]
    c = chunk
    nch = t // c
    state0 = s0_ref[0, 0].astype(jnp.float32)  # (N, N)

    def chunk_body(i, state):
        sl = pl.ds(i * c, c)
        rc = r_ref[0, sl, 0, :].astype(jnp.float32)   # (C, N)
        kc = k_ref[0, sl, 0, :].astype(jnp.float32)
        vc = v_ref[0, sl, 0, :].astype(jnp.float32)
        wc = w_ref[0, sl, 0, :].astype(jnp.float32)

        logw = jnp.log(jnp.maximum(wc, 1e-30))
        cum = jnp.cumsum(logw, axis=0)                # (C, N)
        total = cum[-1:]
        r_dec = rc * jnp.exp(cum - logw)
        k_dec = kc * jnp.exp(-cum)

        y_inter = r_dec @ state                       # (C, N)
        att = r_dec @ k_dec.T                         # (C, C)
        mask = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) > \
            jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
        att = jnp.where(mask, att, 0.0)
        y_intra = att @ vc
        bonus = jnp.sum(rc * u_ref[0].astype(jnp.float32) * kc, axis=-1,
                        keepdims=True)                # (C, 1)
        y_bonus = bonus * vc

        k_tail = kc * jnp.exp(total - cum)
        state = jnp.exp(total[0])[:, None] * state + k_tail.T @ vc
        y_ref[0, sl, 0, :] = (y_inter + y_intra + y_bonus).astype(y_ref.dtype)
        return state

    state = jax.lax.fori_loop(0, nch, chunk_body, state0)
    sout_ref[0, 0] = state.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(
    r: Array, k: Array, v: Array, w: Array, u: Array, state0: Array,
    *, chunk: int = 64, interpret: bool = False,
) -> tuple[Array, Array]:
    """r/k/v/w: (B, T, H, N); u: (H, N); state0: (B, H, N, N).
    Returns (y (B,T,H,N) f32, final state (B,H,N,N) f32)."""
    b, t, h, n = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)

    seq_spec = pl.BlockSpec((1, t, 1, n), lambda bi, hi: (bi, 0, hi, 0))
    u_spec = pl.BlockSpec((1, n), lambda bi, hi: (hi, 0))
    st_spec = pl.BlockSpec((1, 1, n, n), lambda bi, hi: (bi, hi, 0, 0))

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, t=t)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, u_spec, st_spec],
        out_specs=[seq_spec, st_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, n), jnp.float32),
            jax.ShapeDtypeStruct((b, h, n, n), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, w, u, state0)
    return y, s_out
