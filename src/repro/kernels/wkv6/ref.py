"""Pure-jnp oracle for the RWKV-6 WKV recurrence.

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Shapes: r/k/v/w (B, T, H, N) with head size N; u (H, N);
state (B, H, N, N) keyed as state[k_dim, v_dim].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def wkv6_ref(r: Array, k: Array, v: Array, w: Array, u: Array,
             state0: Array | None = None) -> tuple[Array, Array]:
    b, t, h, n = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[..., :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), state


def wkv6_chunked_ref(r, k, v, w, u, state0=None, chunk: int = 32):
    """Chunked parallel form (GLA-style): identical math, O(T/chunk)
    sequential steps with dense intra-chunk matmuls. The pure-JAX
    optimization used by the rwkv6 perf pass; oracle for the kernel too."""
    b, t, h, n = r.shape
    assert t % chunk == 0
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), jnp.float32)
    c = chunk
    nch = t // c
    rs = r.astype(jnp.float32).reshape(b, nch, c, h, n)
    ks = k.astype(jnp.float32).reshape(b, nch, c, h, n)
    vs = v.astype(jnp.float32).reshape(b, nch, c, h, n)
    ws = w.astype(jnp.float32).reshape(b, nch, c, h, n)

    def chunk_step(state, inp):
        rc, kc, vc, wc = inp  # (B, C, H, N)
        logw = jnp.log(jnp.maximum(wc, 1e-30))
        cum = jnp.cumsum(logw, axis=1)              # prod_{tau<=t} w
        total = cum[:, -1:]                          # (B,1,H,N)
        # inter-chunk: y_inter[t] = (r_t * prod_{tau<t} w) @ S_in
        r_dec = rc * jnp.exp(cum - logw)            # r_t * prod_{tau<t}
        y_inter = jnp.einsum("bchk,bhkv->bchv", r_dec, state)
        # intra-chunk (strictly earlier positions s < t):
        #   A[t,s] = r_t . (k_s * prod_{s<tau<t} w) = (r_t*cum_t/w_t).(k_s/cum_s)
        k_dec = kc * jnp.exp(-cum)                  # k_s / prod_{tau<=s}
        att = jnp.einsum("bchk,bshk->bhcs", r_dec, k_dec)
        mask = jnp.tril(jnp.ones((c, c), bool), -1)
        att = jnp.where(mask[None, None], att, 0.0)
        y_intra = jnp.einsum("bhcs,bshv->bchv", att, vc)
        # current-step bonus: r_t . (u * k_t) v_t
        bonus = jnp.einsum("bchk,bshk->bhcs", rc * u[None, None], kc)
        diag = jnp.eye(c, dtype=bool)
        bonus = jnp.where(diag[None, None], bonus, 0.0)
        y_bonus = jnp.einsum("bhcs,bshv->bchv", bonus, vc)
        # state update: S_out = (prod w) * S_in + sum_s (prod_{tau>s} w) k_s v_s
        k_tail = kc * jnp.exp(total - cum)          # k_s * prod_{tau>s}
        s_new = jnp.exp(total)[:, 0, :, :, None] * state + jnp.einsum(
            "bshk,bshv->bhkv", k_tail, vc
        )
        return s_new, y_inter + y_intra + y_bonus

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rs, ks, vs, ws))
    state, ys = jax.lax.scan(chunk_step, state0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, n)
    return y, state
