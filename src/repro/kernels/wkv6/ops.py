"""Public entry point for the WKV-6 kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6_pallas

Array = jax.Array


def wkv6(
    r: Array, k: Array, v: Array, w: Array, u: Array,
    state0: Array | None = None, *, chunk: int = 64, interpret: bool | None = None,
) -> tuple[Array, Array]:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, t, h, n = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), jnp.float32)
    return wkv6_pallas(r, k, v, w, u, state0, chunk=chunk, interpret=interpret)
