"""Generic 2-D elementary-stencil Pallas kernel (radius-1, 3x3 mask).

Single-core streaming design per §3.5/Fig. 8: one program instance owns a
row-tile of one plane; rows stream through VMEM with the same three-slab
halo trick as the hdiff kernel (radius 1 here). The 3x3 weight mask lives
in SMEM, so one kernel serves the whole suite — the paper's observation
that elementary stencils "apply a single stencil pattern throughout the
grid" becomes a data-driven kernel instead of per-stencil codegen.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

R = 1  # radius


def _stencil2d_kernel(prev_ref, cur_ref, next_ref, w_ref, out_ref, *, block_rows, rows):
    i = pl.program_id(1)
    cur = cur_ref[0].astype(jnp.float32)
    x = jnp.concatenate(
        [prev_ref[0, -R:, :].astype(jnp.float32), cur, next_ref[0, :R, :].astype(jnp.float32)],
        axis=0,
    )  # (block_rows + 2, C)

    cols = cur.shape[-1]
    acc = jnp.zeros((block_rows, cols - 2 * R), jnp.float32)
    for dr in range(3):
        for dc in range(3):
            acc = acc + w_ref[dr, dc] * x[dr : dr + block_rows, dc : cols - 2 * R + dc]

    out = cur.at[:, R:-R].set(acc)
    gl_row = i * block_rows + jax.lax.broadcasted_iota(jnp.int32, (block_rows, 1), 0)
    keep = (gl_row < R) | (gl_row >= rows - R)
    out_ref[0] = jnp.where(keep, cur, out).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stencil2d_pallas(
    x: Array, weights: Array, *, block_rows: int = 128, interpret: bool = False
) -> Array:
    """Applies a 3x3 stencil mask to ``(depth, rows, cols)`` with boundary
    passthrough."""
    depth, rows, cols = x.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        raise ValueError(f"rows={rows} not divisible by block_rows={block_rows}")
    row_tiles = rows // block_rows

    kernel = functools.partial(_stencil2d_kernel, block_rows=block_rows, rows=rows)
    spec = lambda fn: pl.BlockSpec((1, block_rows, cols), fn)  # noqa: E731
    return pl.pallas_call(
        kernel,
        grid=(depth, row_tiles),
        in_specs=[
            spec(lambda d, i: (d, jnp.maximum(i - 1, 0), 0)),
            spec(lambda d, i: (d, i, 0)),
            spec(lambda d, i: (d, jnp.minimum(i + 1, row_tiles - 1), 0)),
            pl.BlockSpec((3, 3), lambda d, i: (0, 0), memory_space=pltpu.MemorySpace.SMEM),
        ],
        out_specs=spec(lambda d, i: (d, i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, x, x, weights.astype(jnp.float32))


def _jacobi1d_kernel(x_ref, out_ref, *, coeff):
    x = x_ref[0].astype(jnp.float32)
    interior = coeff * (x[:-2] + x[1:-1] + x[2:])
    out = x.at[1:-1].set(interior)
    out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("coeff", "interpret"))
def jacobi1d_pallas(x: Array, *, coeff: float = 1.0 / 3.0, interpret: bool = False) -> Array:
    """1-D 3-point Jacobi over ``(batch, n)``; one batch row per program."""
    batch, n = x.shape
    return pl.pallas_call(
        functools.partial(_jacobi1d_kernel, coeff=coeff),
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, n), lambda b: (b, 0))],
        out_specs=pl.BlockSpec((1, n), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
