"""Pure-jnp oracle for the generic 2-D elementary-stencil kernel.

A stencil is defined by a (2R+1, 2R+1) weight mask; output = correlation of
the input with the mask on the interior, boundary passthrough. This covers
the whole §3.5 suite: jacobi2d_3pt (column of 1/3), laplacian (star,
4/-1s), jacobi2d_5pt (star of 0.2), jacobi2d_9pt / seidel sweep (box 1/9).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

Array = jax.Array


def stencil2d_ref(x: Array, weights: Array) -> Array:
    """Correlation with ``weights`` ((2R+1, 2R+1)) on the interior."""
    k = weights.shape[0]
    assert weights.shape == (k, k) and k % 2 == 1
    r = k // 2
    rows, cols = x.shape[-2], x.shape[-1]
    acc = jnp.zeros_like(x[..., r : rows - r, r : cols - r], dtype=jnp.float32)
    for dr in range(-r, r + 1):
        for dc in range(-r, r + 1):
            w = weights[dr + r, dc + r]
            acc = acc + w * x[
                ..., r + dr : rows - r + dr, r + dc : cols - r + dc
            ].astype(jnp.float32)
    return x.at[..., r:-r, r:-r].set(acc.astype(x.dtype))


# Canonical weight masks for the §3.5 suite.
def weights_for(name: str) -> np.ndarray:
    w = np.zeros((3, 3), np.float32)
    if name == "jacobi2d_3pt":
        w[:, 1] = 1.0 / 3.0
    elif name == "laplacian":
        w[1, 1] = 4.0
        w[0, 1] = w[2, 1] = w[1, 0] = w[1, 2] = -1.0
    elif name == "jacobi2d_5pt":
        w[1, 1] = w[0, 1] = w[2, 1] = w[1, 0] = w[1, 2] = 0.2
    elif name in ("jacobi2d_9pt", "seidel2d"):
        w[:] = 1.0 / 9.0
    else:
        raise ValueError(f"unknown elementary stencil {name!r}")
    return w


def jacobi1d_ref(x: Array, coeff: float = 1.0 / 3.0) -> Array:
    interior = coeff * (
        x[..., :-2].astype(jnp.float32)
        + x[..., 1:-1].astype(jnp.float32)
        + x[..., 2:].astype(jnp.float32)
    )
    return x.at[..., 1:-1].set(interior.astype(x.dtype))
