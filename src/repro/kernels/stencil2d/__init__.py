from repro.kernels.stencil2d.ops import jacobi1d, stencil2d
from repro.kernels.stencil2d.ref import jacobi1d_ref, stencil2d_ref, weights_for
