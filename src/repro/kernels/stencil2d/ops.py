"""Public entry points for the elementary-stencil kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.stencil2d.kernel import jacobi1d_pallas, stencil2d_pallas
from repro.kernels.stencil2d.ref import weights_for

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def stencil2d(
    x: Array,
    name_or_weights: str | Array,
    *,
    block_rows: int | None = None,
    interpret: bool | None = None,
) -> Array:
    """Applies a named §3.5 stencil (or an explicit 3x3 mask) to
    ``(depth, rows, cols)``."""
    if interpret is None:
        interpret = not _on_tpu()
    if isinstance(name_or_weights, str):
        weights = jnp.asarray(weights_for(name_or_weights))
    else:
        weights = name_or_weights
    if block_rows is None:
        rows = x.shape[-2]
        block_rows = rows
        for cand in range(min(rows, 256), 0, -1):
            if rows % cand == 0 and cand * x.shape[-1] * 4 <= 4 * 1024 * 1024:
                block_rows = cand
                break
    return stencil2d_pallas(x, weights, block_rows=block_rows, interpret=interpret)


def jacobi1d(x: Array, *, coeff: float = 1.0 / 3.0, interpret: bool | None = None) -> Array:
    if interpret is None:
        interpret = not _on_tpu()
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    out = jacobi1d_pallas(x, coeff=coeff, interpret=interpret)
    return out[0] if squeeze else out
