"""Pure-jnp oracle for the RG-LRU linear recurrence.

    h_t = a_t * h_{t-1} + b_t        (elementwise over width)

Shapes: a, b (B, T, W); h0 (B, W). Gate/conv math stays outside the kernel
(dense matmuls the MXU already handles); the kernel owns the sequential
part — the recurrence itself.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rglru_scan_ref(a: Array, b: Array, h0: Array) -> tuple[Array, Array]:
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    # Fold h0 into the first step: h_1 = a_1 h_0 + b_1.
    b32 = b32.at[:, 0].add(a32[:, 0] * h0.astype(jnp.float32))
    _, h = jax.lax.associative_scan(combine, (a32, b32), axis=1)
    return h, h[:, -1]
