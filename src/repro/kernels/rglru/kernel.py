"""RG-LRU recurrence Pallas TPU kernel.

Grid = (B, W/block_w): one program owns one width-lane tile of one batch
row for the WHOLE sequence. The hidden state is a (block_w,) vector that
never leaves VMEM/VREGs (SPARTA's accumulator-residency discipline); the
time loop is sequential but each step is a full-width VPU vector op, so
the datapath stays busy — the TPU-native layout of a per-timestep
recurrence (DESIGN.md §2's "adapt, don't port" rule applied to Griffin).

block_w should be a multiple of 128 (VPU lanes) on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, hout_ref, *, t: int):
    h = h0_ref[0].astype(jnp.float32)  # (block_w,)

    def step(i, h):
        h = a_ref[0, i, :].astype(jnp.float32) * h + b_ref[0, i, :].astype(jnp.float32)
        y_ref[0, i, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, t, step, h)
    hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def rglru_scan_pallas(
    a: Array, b: Array, h0: Array, *, block_w: int = 128, interpret: bool = False
) -> tuple[Array, Array]:
    """a, b: (B, T, W); h0: (B, W) -> (h (B,T,W) f32, h_last (B,W) f32)."""
    bsz, t, w = a.shape
    block_w = min(block_w, w)
    if w % block_w:
        raise ValueError(f"width {w} not divisible by block_w {block_w}")

    seq_spec = pl.BlockSpec((1, t, block_w), lambda bi, wi: (bi, 0, wi))
    vec_spec = pl.BlockSpec((1, block_w), lambda bi, wi: (bi, wi))
    kernel = functools.partial(_rglru_kernel, t=t)
    return pl.pallas_call(
        kernel,
        grid=(bsz, w // block_w),
        in_specs=[seq_spec, seq_spec, vec_spec],
        out_specs=[seq_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, w), jnp.float32),
            jax.ShapeDtypeStruct((bsz, w), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, h0)
