"""Public entry point for the RG-LRU scan kernel."""

from __future__ import annotations

import jax

from repro.kernels.rglru.kernel import rglru_scan_pallas

Array = jax.Array


def rglru_scan(a: Array, b: Array, h0: Array, *, block_w: int = 128,
               interpret: bool | None = None) -> tuple[Array, Array]:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return rglru_scan_pallas(a, b, h0, block_w=block_w, interpret=interpret)
