from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_scan_ref
