"""Pallas TPU kernels for the paper's compute hot-spots.

hdiff/      fused compound stencil (the SPARTA contribution)
stencil2d/  generic 3x3 elementary stencil + jacobi1d (the §3.5 suite)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with auto interpret-mode), ref.py (pure-jnp oracle). Validated by
shape/dtype sweeps in tests/test_kernels_*.py.
"""
