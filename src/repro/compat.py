"""JAX version-compatibility layer.

The codebase is written against the unified sharding API of recent JAX
(``jax.set_mesh``, ``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.sharding.get_abstract_mesh``, ``jax.make_mesh(..., axis_types=...)``).
The pinned container toolchain ships jax 0.4.x, where those names either do
not exist or live under ``jax.experimental``. Importing :mod:`repro`
installs the missing names onto the ``jax`` namespace so the SAME source
runs on both. Every patch is gated on ``hasattr`` — on a new-enough JAX
this module is a no-op.

Nothing here changes behaviour that already exists; it only backfills:

  * ``jax.shard_map``            <- ``jax.experimental.shard_map.shard_map``
    (keyword-only calling convention, ``check_vma`` -> ``check_rep``).
  * ``jax.set_mesh(mesh)``       -> context manager recording the ambient
    mesh consulted by :func:`repro.dist.sharding._ambient_mesh` (and hence
    ``constrain`` / the MoE shard_map path).
  * ``jax.sharding.get_abstract_mesh()`` -> returns the ambient mesh.
  * ``jax.sharding.AxisType``    -> minimal Auto/Explicit/Manual enum.
  * ``jax.make_mesh``            -> wrapper accepting (and dropping) the
    ``axis_types=`` keyword on versions whose signature predates it.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax
import jax.sharding as _jsharding


def _ambient():  # late import: repro.dist owns the context variable
    from repro.dist import sharding as _s

    return _s


# --- jax.shard_map -----------------------------------------------------------

if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    @functools.wraps(_shard_map_impl)
    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                   check_rep=None, **kwargs):
        if check_vma is not None:  # new-API spelling of check_rep
            kwargs["check_rep"] = check_vma
        elif check_rep is not None:
            kwargs["check_rep"] = check_rep
        return _shard_map_impl(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )

    jax.shard_map = _shard_map


# --- jax.set_mesh ------------------------------------------------------------

if not hasattr(jax, "set_mesh"):

    def _set_mesh(mesh):
        return _ambient().use_mesh(mesh)  # one ambient-mesh protocol, one home

    jax.set_mesh = _set_mesh


# --- jax.sharding.get_abstract_mesh ------------------------------------------

if not hasattr(_jsharding, "get_abstract_mesh"):

    def _get_abstract_mesh():
        return _ambient()._ambient_mesh()

    # _ambient_mesh falls back to the NATIVE get_abstract_mesh when its
    # ContextVar is unset; this flag stops it recursing into the backfill.
    _get_abstract_mesh._repro_compat = True
    _jsharding.get_abstract_mesh = _get_abstract_mesh


# --- jax.sharding.AxisType ---------------------------------------------------

if not hasattr(_jsharding, "AxisType"):

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _jsharding.AxisType = AxisType


# --- Compiled.cost_analysis: list[dict] -> dict ------------------------------

def _normalize_cost_analysis() -> None:
    import jax.stages as _stages

    probe = _stages.Compiled.cost_analysis
    if getattr(probe, "_repro_normalized", False):
        return
    _orig_cost = probe

    def cost_analysis(self):
        out = _orig_cost(self)
        if isinstance(out, (list, tuple)):  # old JAX: one dict per program
            out = out[0] if out else {}
        return out

    cost_analysis._repro_normalized = True
    _stages.Compiled.cost_analysis = cost_analysis


try:
    _normalize_cost_analysis()
except (ImportError, AttributeError):
    pass


# --- pallas: MemorySpace rename ----------------------------------------------

try:
    import jax.experimental.pallas.tpu as _pltpu

    if not hasattr(_pltpu, "MemorySpace") and hasattr(_pltpu, "TPUMemorySpace"):
        _pltpu.MemorySpace = _pltpu.TPUMemorySpace
except Exception:  # best-effort: a broken/absent pallas must not take down
    pass           # `import repro` for users who never touch the kernels


# --- jax.make_mesh(..., axis_types=...) --------------------------------------

if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
    _make_mesh_impl = jax.make_mesh

    @functools.wraps(_make_mesh_impl)
    def _make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types  # pre-AxisType JAX: every axis behaves as Auto
        return _make_mesh_impl(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = _make_mesh
