"""The LM model zoo: one composable decoder/encoder covering all 10 assigned
architectures via ``ModelConfig.block_pattern``.

Layer stacking uses scan-over-SUPERBLOCKS: the block pattern (e.g.
recurrentgemma's ("rglru", "rglru", "local_attn")) forms one superblock whose
params are stacked ``(n_super, ...)`` and scanned with ``jax.lax.scan`` —
keeping the traced HLO size O(pattern) instead of O(n_layers), which is what
makes the 100-layer 90B dry-run compile in minutes on one CPU core.
Remainder layers (n_layers % len(pattern)) get their own unstacked params,
applied after the scan.

Three entry points (all pure functions of (cfg, params, ...)):
  * ``lm_loss``      — train: tokens/labels -> (loss, aux)
  * ``lm_prefill``   — forward + cache build (serving prefill)
  * ``lm_decode``    — one-token step with cache (serving decode)

Abstract mode: ``build_lm(cfg, key=None)`` / ``build_cache(..., abstract=True)``
produce ShapeDtypeStruct pytrees for the multi-pod dry-run — no allocation.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import recurrent as R

Array = jax.Array


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, kind: str, key: Array | None):
    b = L.PBuilder(key, L.dt(cfg))
    b.sub("norm1", L.init_norm(cfg, b.key()))
    if kind in ("attn", "local_attn"):
        b.sub("mixer", L.init_attention(cfg, b.key()))
    elif kind == "cross_attn":
        b.sub("mixer", L.init_attention(cfg, b.key(), cross=True))
    elif kind == "rglru":
        b.sub("mixer", R.init_rglru(cfg, b.key()))
    elif kind == "rwkv6":
        b.sub("mixer", R.init_rwkv_tmix(cfg, b.key()))
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    b.sub("norm2", L.init_norm(cfg, b.key()))
    if kind == "rwkv6":
        b.sub("ffn", R.init_rwkv_cmix(cfg, b.key()))
    elif cfg.n_experts:
        b.sub("ffn", L.init_moe(cfg, b.key()))
    else:
        b.sub("ffn", L.init_ffn(cfg, b.key()))
    return b.build()


def _init_superblock(cfg: ModelConfig, key: Array | None):
    b = L.PBuilder(key, L.dt(cfg))
    for i, kind in enumerate(cfg.block_pattern):
        b.sub(f"b{i}", _init_block(cfg, kind, b.key()))
    return b.build()


def build_lm(cfg: ModelConfig, key: Array | None = None):
    """Returns (params, logical_axes). ``key=None`` -> abstract structs."""
    abstract = key is None
    b = L.PBuilder(key, L.dt(cfg))
    b.add("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "fsdp"), scale=1.0,
          fan_axes=(1,))
    n_super = cfg.n_super
    if n_super:
        if abstract:
            one_p, one_ax = _init_superblock(cfg, None)
            stacked = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_super,) + s.shape, s.dtype), one_p
            )
        else:
            keys = jax.random.split(b.key(), n_super)
            stacked = jax.vmap(lambda k: _init_superblock(cfg, k)[0])(keys)
            _, one_ax = _init_superblock(cfg, None)
        b.params["scan"] = stacked
        b.axes["scan"] = jax.tree.map(
            lambda ax: ("layers",) + ax,
            one_ax,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
        )
    tail_kinds = cfg.layer_kinds[n_super * len(cfg.block_pattern) :]
    tail_p, tail_ax = [], []
    for kind in tail_kinds:
        p, ax = _init_block(cfg, kind, b.key())
        tail_p.append(p)
        tail_ax.append(ax)
    b.params["tail"] = tail_p
    b.axes["tail"] = tail_ax
    b.sub("final_norm", L.init_norm(cfg, b.key()))
    if not cfg.tied_embeddings:
        b.add("head", (cfg.d_model, cfg.vocab_size), ("fsdp", "vocab"))
    return b.build()


# ---------------------------------------------------------------------------
# Cache init (serving).
# ---------------------------------------------------------------------------


def _block_cache(cfg: ModelConfig, kind: str, batch: int, length: int, *, abstract: bool):
    if kind == "attn":
        cap = min(length, cfg.window) if cfg.window else length
        return L.init_cache(cfg, batch, cap, abstract=abstract)
    if kind == "local_attn":
        cap = min(length, cfg.window or length)
        return L.init_cache(cfg, batch, cap, abstract=abstract)
    if kind == "cross_attn":
        # cross K/V over media tokens, filled at prefill, static afterwards
        return {
            "k": L.make_buf((batch, cfg.num_media_tokens, cfg.n_kv_heads, cfg.head_dim),
                            L.dt(cfg, "compute"), abstract),
            "v": L.make_buf((batch, cfg.num_media_tokens, cfg.n_kv_heads, cfg.head_dim),
                            L.dt(cfg, "compute"), abstract),
        }
    if kind == "rglru":
        return R.rglru_cache_init(cfg, batch, abstract=abstract)
    if kind == "rwkv6":
        return R.rwkv_cache_init(cfg, batch, abstract=abstract)
    raise ValueError(kind)


def _block_cache_axes(cfg: ModelConfig, kind: str):
    if kind in ("attn", "local_attn"):
        return L.cache_axes(cfg)
    if kind == "cross_attn":
        ax = L.cache_axes(cfg)
        return {"k": ax["k"], "v": ax["v"]}
    if kind == "rglru":
        return R.rglru_cache_axes(cfg)
    if kind == "rwkv6":
        return R.rwkv_cache_axes(cfg)
    raise ValueError(kind)


def build_cache(cfg: ModelConfig, batch: int, length: int, *, abstract: bool = False):
    """Returns (cache, logical_axes) for serving. ``length`` is the max
    context (full-attn cache size; window archs clamp to their window)."""
    n_super = cfg.n_super
    pattern = cfg.block_pattern
    one = {f"b{i}": _block_cache(cfg, k, batch, length, abstract=abstract)
           for i, k in enumerate(pattern)}
    one_ax = {f"b{i}": _block_cache_axes(cfg, k) for i, k in enumerate(pattern)}

    def stack(s):
        if abstract:
            return jax.ShapeDtypeStruct((n_super,) + s.shape, s.dtype)
        return jnp.broadcast_to(s[None], (n_super,) + s.shape).copy()

    cache = {"scan": jax.tree.map(stack, one)} if n_super else {}
    axes: dict[str, Any] = {}
    if n_super:
        axes["scan"] = jax.tree.map(
            lambda ax: ("layers",) + ax,
            one_ax,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
        )
    tail_kinds = cfg.layer_kinds[n_super * len(pattern):]
    cache["tail"] = [
        _block_cache(cfg, k, batch, length, abstract=abstract) for k in tail_kinds
    ]
    axes["tail"] = [_block_cache_axes(cfg, k) for k in tail_kinds]
    return cache, axes


# ---------------------------------------------------------------------------
# Apply.
# ---------------------------------------------------------------------------


def _apply_block(cfg, kind, p, x, *, memory, cache, pos, prefill):
    """One block. Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(cfg, p["norm1"], x)
    new_cache = cache

    if kind in ("attn", "local_attn"):
        window = cfg.window
        if cache is not None and not prefill:
            y, new_cache = L.attention_apply(cfg, p["mixer"], h, window=window,
                                             cache=cache, pos=pos)
        else:
            y, _ = L.attention_apply(cfg, p["mixer"], h, window=window)
            if prefill:
                q, k, v = L._project_qkv(cfg, p["mixer"], h)
                if cfg.rope:
                    k = L.rope_rotate(k, jnp.arange(h.shape[1]), cfg.rope_theta)
                new_cache = L.cache_fill_from_prefill(cfg, cache, k, v)
    elif kind == "cross_attn":
        if cache is not None and not prefill:
            y, _ = _cross_attn_cached(cfg, p["mixer"], h, cache)
        else:
            y, _ = L.attention_apply(cfg, p["mixer"], h, cross=True, memory=memory)
            if prefill:
                _, k, v = L._project_qkv(cfg, p["mixer"], h, memory)
                new_cache = {"k": k.astype(cache["k"].dtype), "v": v.astype(cache["v"].dtype)}
    elif kind == "rglru":
        y, c2 = R.apply_rglru(cfg, p["mixer"], h, cache=None if prefill else cache)
        if cache is not None:
            new_cache = c2
    elif kind == "rwkv6":
        y, c2 = R.apply_rwkv_tmix(
            cfg, p["mixer"], h,
            cache=None if (prefill or cache is None) else cache["tmix"],
        )
        if cache is not None:
            new_cache = dict(cache)
            new_cache["tmix"] = c2
    else:
        raise ValueError(kind)
    x = x + y

    h = L.apply_norm(cfg, p["norm2"], x)
    if kind == "rwkv6":
        y, c3 = R.apply_rwkv_cmix(
            cfg, p["ffn"], h,
            cache=None if (prefill or cache is None) else cache["cmix"],
        )
        if cache is not None:
            new_cache = dict(new_cache)
            new_cache["cmix"] = c3
    elif cfg.n_experts:
        y, aux = L.apply_moe(cfg, p["ffn"], h)
    else:
        y = L.apply_ffn(cfg, p["ffn"], h)
    return x + y, new_cache, aux


def _cross_attn_cached(cfg, p, x, cache):
    """Decode-time cross attention against the prefill-built media K/V."""
    import math as _math

    cdt = L.dt(cfg, "compute")
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cdt), p["wq"].astype(cdt))
    if "bq" in p:
        q = q + p["bq"].astype(cdt)
    scale = 1.0 / _math.sqrt(cfg.head_dim)
    scores = L._gqa_scores(q, cache["k"].astype(cdt)).astype(jnp.float32) * scale
    w = jax.nn.softmax(scores, axis=-1).astype(cdt)
    out = L._gqa_out(w, cache["v"].astype(cdt))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
    if "gate" in p:
        y = jnp.tanh(p["gate"].astype(jnp.float32)).astype(cdt) * y
    return y, cache


def _apply_superblock(cfg, p, x, *, memory, cache, pos, prefill):
    aux = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None
    for i, kind in enumerate(cfg.block_pattern):
        c = cache[f"b{i}"] if cache is not None else None
        x, c2, a = _apply_block(cfg, kind, p[f"b{i}"], x, memory=memory,
                                cache=c, pos=pos, prefill=prefill)
        if cache is not None:
            new_cache[f"b{i}"] = c2
        aux = aux + a
    return x, new_cache, aux


def _run_blocks(cfg, params, x, *, memory=None, cache=None, pos=None, prefill=False):
    """Scan over superblocks + tail. Returns (x, new_cache, aux)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}

    if cfg.n_super:
        from repro.dist.sharding import constrain

        def body(carry, xs):
            xc, aux = carry
            if cache is not None:
                p, c = xs
            else:
                p, c = xs, None
            xc = constrain(xc, ("batch", "seq", "embed") if xc.ndim == 3 else ("batch", "embed"))
            xc, c2, a = _apply_superblock(cfg, p, xc, memory=memory, cache=c,
                                          pos=pos, prefill=prefill)
            out = c2 if cache is not None else None
            return (xc, aux + a), out

        def _ckpt(fn):
            if cfg.remat_policy == "dots":
                return jax.checkpoint(
                    fn, policy=jax.checkpoint_policies.dots_saveable
                )
            return jax.checkpoint(fn)

        if cfg.unroll_layers:
            # Unrolled path: used by the dry-run's cost-extrapolation variants
            # (XLA cost analysis ignores `while` trip counts). Keep the remat
            # wrapper so recompute FLOPs match the scanned path.
            ubody = _ckpt(body) if (cfg.remat and cache is None) else body
            outs = []
            for i in range(cfg.n_super):
                take = lambda t: jax.tree.map(lambda l: l[i], t)  # noqa: E731
                xs = (take(params["scan"]), take(cache["scan"])) if cache is not None else take(params["scan"])
                (x, aux_total), o = ubody((x, aux_total), xs)
                outs.append(o)
            scan_out = jax.tree.map(lambda *ls: jnp.stack(ls), *outs) if cache is not None else None
        else:
            body_fn = _ckpt(body) if (cfg.remat and cache is None) else body
            xs = (params["scan"], cache["scan"]) if cache is not None else params["scan"]
            (x, aux_total), scan_out = jax.lax.scan(body_fn, (x, aux_total), xs)
        if cache is not None:
            new_cache["scan"] = scan_out

    tail_kinds = cfg.layer_kinds[cfg.n_super * len(cfg.block_pattern):]
    tail_cache = []
    for i, kind in enumerate(tail_kinds):
        c = cache["tail"][i] if cache is not None else None
        x, c2, a = _apply_block(cfg, kind, params["tail"][i], x, memory=memory,
                                cache=c, pos=pos, prefill=prefill)
        tail_cache.append(c2)
        aux_total = aux_total + a
    if cache is not None:
        new_cache["tail"] = tail_cache
    return x, (new_cache if cache is not None else None), aux_total


def _embed(cfg, params, tokens_or_frames):
    from repro.dist.sharding import constrain

    cdt = L.dt(cfg, "compute")
    if cfg.frontend == "audio":
        x = tokens_or_frames.astype(cdt)  # stub: precomputed frame embeddings
    else:
        x = params["embed"].astype(cdt)[tokens_or_frames]
    axes = ("batch", "seq", "embed") if x.ndim == 3 else ("batch", "embed")
    return constrain(x, axes[: x.ndim])


def _logits(cfg, params, x):
    from repro.dist.sharding import constrain

    cdt = L.dt(cfg, "compute")
    if cfg.tied_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cdt))
    else:
        out = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(cdt))
    # Keep the vocab dim sharded: a replicated (B, S, V) f32 logits tensor is
    # the single biggest memory hazard at train shapes (tens of GiB/device).
    return constrain(out, ("batch", "seq", "vocab"))


def lm_forward(cfg: ModelConfig, params, tokens, *, memory=None):
    """Plain forward (no cache): logits (B, S, V) + aux loss."""
    x = _embed(cfg, params, tokens)
    x, _, aux = _run_blocks(cfg, params, x, memory=memory)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return _logits(cfg, params, x), aux


def lm_loss(cfg: ModelConfig, params, batch) -> tuple[Array, dict]:
    """Cross-entropy train loss. batch: {"tokens", "labels", optional
    "memory", optional "mask"}. Labels use -100 padding convention.

    The cross-entropy is written as ``logsumexp - onehot-contraction`` so
    every (B, S, V) intermediate reduces over the SHARDED vocab axis (SPMD
    inserts a cheap psum over `model`); ``take_along_axis`` on a
    vocab-sharded tensor would instead force an all-gather of the logits."""
    logits, aux = lm_forward(cfg, params, batch["tokens"], memory=batch.get("memory"))
    labels = batch["labels"]
    valid = labels >= 0
    labels_safe = jnp.maximum(labels, 0)

    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)                       # (B, S)
    onehot = jax.nn.one_hot(labels_safe, cfg.vocab_size, dtype=logits.dtype)
    from repro.dist.sharding import constrain

    onehot = constrain(onehot, ("batch", "seq", "vocab"))
    label_logit = jnp.einsum("bsv,bsv->bs", logits32, onehot.astype(jnp.float32))
    nll = jnp.where(valid, lse - label_logit, 0.0)
    denom = jnp.maximum(valid.sum(), 1)
    loss = nll.sum() / denom
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux, "ntokens": denom}


def lm_prefill(cfg: ModelConfig, params, tokens, cache, *, memory=None):
    """Prefill: runs the full prompt, fills the cache. Returns
    (last_logits (B, V), cache)."""
    x = _embed(cfg, params, tokens)
    x, cache, _ = _run_blocks(cfg, params, x, memory=memory, cache=cache, prefill=True)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return _logits(cfg, params, x[:, -1:, :])[:, 0], cache


def lm_decode(cfg: ModelConfig, params, token, cache, pos, *, memory=None):
    """One decode step. token: (B,) int32 (or (B, D) frames), pos: scalar
    absolute position. Returns (logits (B, V), new cache)."""
    tok = token[:, None] if token.ndim == 1 else token[:, None, :]
    x = _embed(cfg, params, tok)
    x, cache, _ = _run_blocks(cfg, params, x, memory=memory, cache=cache, pos=pos)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return _logits(cfg, params, x)[:, 0], cache
