"""Transformer building blocks: norms, RoPE, GQA attention, FFN, MoE.

Conventions
-----------
* Params are plain dicts; every ``init_*`` returns ``(params, axes)`` where
  ``axes`` mirrors the params pytree with tuples of *logical* axis names
  (see repro.dist.sharding). ``None`` entries mean replicated.
* Params are stored in ``cfg.param_dtype`` and cast to ``cfg.compute_dtype``
  at use; reductions (softmax, norms, router) run in f32.
* Attention caches are dicts ``{"k","v"}`` of shape ``(B, L, K, Dh)`` plus a
  shared ``slot_pos (L,)`` table of absolute positions (-1 = empty). The
  same mechanism serves full caches (L = max context) and sliding-window
  ring buffers (L = window, slot = pos % window).
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Array = jax.Array


def dt(cfg: ModelConfig, kind: str = "param"):
    return jnp.dtype(cfg.param_dtype if kind == "param" else cfg.compute_dtype)


# ---------------------------------------------------------------------------
# Param builder.
# ---------------------------------------------------------------------------


class PBuilder:
    """Accumulates (params, logical_axes) pairs with fan-in scaled init.

    ``abstract=True`` produces ``jax.ShapeDtypeStruct`` leaves instead of
    arrays (no RNG, no allocation) — the dry-run path. The same init code
    serves both modes so shapes/axes can never diverge.
    """

    def __init__(self, key: Array | None, dtype, *, abstract: bool = False):
        self._key = key
        self.dtype = dtype
        self.abstract = abstract or key is None
        self.params: dict[str, Any] = {}
        self.axes: dict[str, Any] = {}

    def key(self) -> Array | None:
        if self.abstract:
            return None
        self._key, sub = jax.random.split(self._key)
        return sub

    def add(self, name, shape, axes, *, init="fan_in", scale=1.0, fan_axes=None):
        assert len(shape) == len(axes), (name, shape, axes)
        if self.abstract:
            val = jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        elif init == "zeros":
            val = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            val = jnp.ones(shape, self.dtype)
        elif init == "const":
            val = jnp.full(shape, scale, self.dtype)
        else:
            fan_in = 1
            for i in (fan_axes if fan_axes is not None else range(len(shape) - 1)):
                fan_in *= shape[i]
            std = scale / math.sqrt(max(fan_in, 1))
            val = (jax.random.normal(self.key(), shape, jnp.float32) * std).astype(self.dtype)
        self.params[name] = val
        self.axes[name] = tuple(axes)
        return val

    def sub(self, name, builder_out):
        params, axes = builder_out
        self.params[name] = params
        self.axes[name] = axes

    def build(self):
        return self.params, self.axes


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, key: Array):
    b = PBuilder(key, dt(cfg))
    b.add("scale", (cfg.d_model,), (None,), init="ones")
    if cfg.norm == "layernorm":
        b.add("bias", (cfg.d_model,), (None,), init="zeros")
    return b.build()


def apply_norm(cfg: ModelConfig, p, x: Array) -> Array:
    x32 = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = x32.mean(-1, keepdims=True)
        var = ((x32 - mean) ** 2).mean(-1, keepdims=True)
        y = (x32 - mean) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = (x32**2).mean(-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------


def rope_rotate(x: Array, positions: Array, theta: float) -> Array:
    """Applies rotary embedding. x: (B, S, H, Dh); positions: (S,)."""
    half = x.shape[-1] // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs       # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]                       # (1, S, 1, half)
    sin = jnp.sin(ang)[None, :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., :half], x32[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (self / cross, full / sliding window, GQA, cache).
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key: Array, *, cross: bool = False):
    d, h, k, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.pad_heads_to:
        # TP-divisibility padding: extra heads init at 0 (wq AND wo), so
        # they contribute exactly nothing while letting `heads` shard.
        h = max(h, cfg.pad_heads_to)
    b = PBuilder(key, dt(cfg))
    b.add("wq", (d, h, dh), ("fsdp", "heads", "head_dim"))
    b.add("wk", (d, k, dh), ("fsdp", "kv_heads", "head_dim"))
    b.add("wv", (d, k, dh), ("fsdp", "kv_heads", "head_dim"))
    b.add("wo", (h, dh, d), ("heads", "head_dim", "fsdp"))
    if cfg.qkv_bias:
        b.add("bq", (h, dh), ("heads", "head_dim"), init="zeros")
        b.add("bk", (k, dh), ("kv_heads", "head_dim"), init="zeros")
        b.add("bv", (k, dh), ("kv_heads", "head_dim"), init="zeros")
    if cross:
        b.add("gate", (), (), init="zeros")  # tanh-gated cross-attn (llama-vision)
    return b.build()


def _project_qkv(cfg, p, x, memory=None):
    cdt = dt(cfg, "compute")
    xq = x.astype(cdt)
    src = (memory if memory is not None else x).astype(cdt)
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(cdt))
    kk = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(cdt))
    if "bq" in p:
        q = q + p["bq"].astype(cdt)
        kk = kk + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    return q, kk, v


def _gqa_scores(q: Array, k: Array) -> Array:
    """q: (B,S,H,Dh), k: (B,L,K,Dh) -> scores (B, H, S, L) with GQA groups."""
    b_, s, h, dh = q.shape
    kheads = k.shape[2]
    g = h // kheads
    qg = q.reshape(b_, s, kheads, g, dh)
    sc = jnp.einsum("bskgd,blkd->bkgsl", qg, k)
    return sc.reshape(b_, h, s, k.shape[1])


def _gqa_out(w: Array, v: Array) -> Array:
    """w: (B,H,S,L), v: (B,L,K,Dh) -> (B,S,H,Dh)."""
    b_, h, s, _ = w.shape
    kheads = v.shape[2]
    g = h // kheads
    wg = w.reshape(b_, kheads, g, s, w.shape[-1])
    out = jnp.einsum("bkgsl,blkd->bskgd", wg, v)
    return out.reshape(b_, s, h, v.shape[-1])


# Sequence length above which the no-cache path switches to the chunked
# online-softmax (flash-style) formulation. Pure XLA (lax.scan over KV
# blocks), so it lowers for the CPU dry-run AND keeps prefill memory at
# O(S * chunk) instead of O(S^2).
FLASH_THRESHOLD = 2048
FLASH_CHUNK = 512


def _chunk_mask(rows: Array, i, chunk: int, causal: bool, window: int) -> Array:
    cols = i * chunk + jnp.arange(chunk)
    mask = jnp.ones((rows.shape[0], chunk), bool)
    if causal:
        mask &= cols[None, :] <= rows[:, None]
    if window:
        mask &= cols[None, :] > rows[:, None] - window
    return mask


def _flash_fwd_scan(q, k, v, *, causal, window, scale, chunk, unroll, row_offset=0):
    """Returns (out (B,H,Sq,Dh) f32, lse (B,H,Sq) f32).

    ``k``/``v`` may be longer than ``q`` (Sq != Skv); ``row_offset`` places
    q's rows at absolute positions ``row_offset + arange(Sq)`` within the kv
    axis — how the blocked sliding-window path expresses "this Q block sits
    after its halo block".
    """
    b_, s, h, dh = q.shape
    s_kv = k.shape[1]
    n_chunks = s_kv // chunk
    rows = row_offset + jnp.arange(s)

    def body(carry, i):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, 1)
        sc = jnp.einsum("bshd,bchd->bhsc", q, ks).astype(jnp.float32) * scale
        mask = _chunk_mask(rows, i, chunk, causal, window)
        sc = jnp.where(mask[None, None], sc, -1e30)
        m_new = jnp.maximum(m, sc.max(-1))
        p = jnp.where(mask[None, None], jnp.exp(sc - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhsc,bchd->bhsd", p.astype(vs.dtype), vs
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b_, h, s), -1e30, jnp.float32),
        jnp.zeros((b_, h, s), jnp.float32),
        jnp.zeros((b_, h, s, dh), jnp.float32),
    )
    if unroll:
        carry = init
        for i in range(n_chunks):
            carry, _ = body(carry, jnp.int32(i))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    l = jnp.maximum(l, 1e-30)
    out = acc / l[..., None]
    lse = m + jnp.log(l)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_core(q, k, v, causal, window, scale, chunk, unroll, row_offset=0):
    """Flash attention with recompute-based backward (memory O(S*chunk)).

    The transformer analogue of the paper's fused Laplacian->flux chain:
    the S x S score matrix never exists in HBM; each KV tile is streamed
    once and folded into running (max, denom, acc) registers — the
    accumulator-residency discipline of §3.2, in both directions of AD.
    q: (B,Sq,H,Dh); k/v: (B,Skv,H,Dh) with KV already repeated to H heads.
    """
    out, _ = _flash_fwd_scan(q, k, v, causal=causal, window=window, scale=scale,
                             chunk=chunk, unroll=unroll, row_offset=row_offset)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B,Sq,H,Dh)


def _flash_core_fwd(q, k, v, causal, window, scale, chunk, unroll, row_offset=0):
    out, lse = _flash_fwd_scan(q, k, v, causal=causal, window=window, scale=scale,
                               chunk=chunk, unroll=unroll, row_offset=row_offset)
    out_bshd = jnp.moveaxis(out, 1, 2).astype(q.dtype)
    return out_bshd, (q, k, v, out_bshd, lse)


def _flash_core_bwd(causal, window, scale, chunk, unroll, row_offset, res, g):
    q, k, v, out, lse = res
    b_, s, h, dh = q.shape
    s_kv = k.shape[1]
    rows = row_offset + jnp.arange(s)
    n_chunks = s_kv // chunk
    g32 = g.astype(jnp.float32)
    # delta[b,h,s] = sum_d dOut * Out  (rowwise correction term)
    delta = jnp.einsum("bshd,bshd->bhs", g32, out.astype(jnp.float32))

    def body(dq, i):
        ks = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, 1)
        sc = jnp.einsum("bshd,bchd->bhsc", q, ks).astype(jnp.float32) * scale
        mask = _chunk_mask(rows, i, chunk, causal, window)
        p = jnp.where(mask[None, None], jnp.exp(sc - lse[..., None]), 0.0)  # (B,H,S,C)
        dv_c = jnp.einsum("bhsc,bshd->bchd", p, g32)
        dp = jnp.einsum("bshd,bchd->bhsc", g32, vs.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bhsc,bchd->bshd", ds, ks.astype(jnp.float32))
        dk_c = jnp.einsum("bhsc,bshd->bchd", ds, q.astype(jnp.float32))
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((b_, s, h, dh), jnp.float32)
    if unroll:
        dq, dks, dvs = dq0, [], []
        for i in range(n_chunks):
            dq, (dk_c, dv_c) = body(dq, jnp.int32(i))
            dks.append(dk_c)
            dvs.append(dv_c)
        dk = jnp.concatenate(dks, axis=1)
        dv = jnp.concatenate(dvs, axis=1)
    else:
        dq, (dks, dvs) = jax.lax.scan(body, dq0, jnp.arange(n_chunks))
        dk = jnp.moveaxis(dks, 0, 1).reshape(b_, s_kv, h, dh)
        dv = jnp.moveaxis(dvs, 0, 1).reshape(b_, s_kv, h, dh)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def _flash_attention(
    q: Array, k: Array, v: Array, *, causal: bool, window: int, scale: float,
    chunk: int = FLASH_CHUNK, unroll: bool = False,
) -> Array:
    """Flash attention wrapper: repeats GQA KV heads to H then runs the
    custom-VJP core. Causal sliding-window attention at S >> window takes
    the BLOCKED LOCAL path instead (see _local_attention_blocked)."""
    b_, s, h, dh = q.shape
    kheads = k.shape[2]
    if kheads != h:
        k = jnp.repeat(k, h // kheads, axis=2)
        v = jnp.repeat(v, h // kheads, axis=2)
    if causal and window and _pick_block_size(s, window):
        return _local_attention_blocked(q, k, v, window=window, scale=scale)
    assert s % chunk == 0, (s, chunk)
    return _flash_core(q, k, v, causal, window, scale, chunk, unroll)


def _pick_block_size(s: int, window: int, target_blocks: int = 16) -> int | None:
    """Sub-block size for windowed attention: the largest divisor of both
    ``window`` and ``s`` that still yields >= target_blocks blocks (so the
    block axis fills the model mesh axis); falls back to the smallest
    feasible divisor, or None if blocking is impossible/pointless."""
    min_bs = min(128, max(window // 2, 1))
    cands = [b for b in range(min_bs, window + 1)
             if window % b == 0 and s % b == 0 and s // b >= 2]
    if not cands:
        return None
    good = [b for b in cands if s // b >= target_blocks]
    return max(good) if good else min(cands)


def _local_attention_blocked(
    q: Array, k: Array, v: Array, *, window: int, scale: float
) -> Array:
    """Causal sliding-window attention via sub-block + halo — the paper's
    B-block decomposition applied to the sequence axis.

    The sequence is tiled into sub-blocks of ``window // 2``; each Q block
    attends to (2 previous blocks ++ own block) — its radius-2 halo, like
    hdiff's radius-2 stencil. Compute is O(S * 1.5*window) instead of the
    O(S^2) a masked full pass costs (8x FLOP cut for starcoder2 prefill).

    Crucially the BLOCK axis is a free batch dim, constrained to shard over
    `model`: this is sequence parallelism that works for ANY head count —
    starcoder2 (24 heads) and recurrentgemma (10 heads) cannot shard heads
    16-way, and without this their attention replicates across the model
    axis (16x redundant compute, the baseline's worst useful-FLOPs cell).
    """
    from repro.dist.sharding import constrain

    b_, s, h, dh = q.shape
    bs = _pick_block_size(s, window)
    r = window // bs           # halo radius in blocks
    nb = s // bs
    qb = q.reshape(b_, nb, bs, h, dh)
    kb = k.reshape(b_, nb, bs, h, dh)
    vb = v.reshape(b_, nb, bs, h, dh)
    qb = constrain(qb, ("batch", "blocks", None, None, None))
    kb = constrain(kb, ("batch", "blocks", None, None, None))
    vb = constrain(vb, ("batch", "blocks", None, None, None))

    def shift(x, by):
        pad = jnp.zeros_like(x[:, :by])
        return jnp.concatenate([pad, x[:, :-by]], axis=1) if by else x

    # context = (prev_r ++ ... ++ prev_1 ++ cur): (B, nb, (r+1)*bs, H, Dh)
    kk = jnp.concatenate([shift(kb, i) for i in range(r, -1, -1)], axis=2)
    vv = jnp.concatenate([shift(vb, i) for i in range(r, -1, -1)], axis=2)
    kk = constrain(kk, ("batch", "blocks", None, None, None))
    vv = constrain(vv, ("batch", "blocks", None, None, None))

    sc = jnp.einsum("bnqhd,bnkhd->bnhqk", qb, kk).astype(jnp.float32) * scale
    rows = jnp.arange(bs)[:, None]            # q position within block
    cols = jnp.arange((r + 1) * bs)[None, :]  # position within halo context
    rel = cols - r * bs - rows                # kv offset relative to q
    mask = (rel <= 0) & (rel > -window)
    # first blocks: zero-padded halo entries are at global positions < 0
    blk = jnp.arange(nb)[:, None, None]
    glob_col = (blk - r) * bs + cols[None]
    m = mask[None] & (glob_col >= 0)
    sc = jnp.where(m[None, :, None], sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", w.astype(vv.dtype), vv)
    out = constrain(out, ("batch", "blocks", None, None, None))
    return out.reshape(b_, s, h, dh)


def attention_apply(
    cfg: ModelConfig,
    p,
    x: Array,
    *,
    window: int = 0,
    cross: bool = False,
    memory: Array | None = None,
    cache: dict | None = None,
    pos: Array | None = None,
    force_flash: bool | None = None,
):
    """Self/cross attention.

    Train/prefill: ``x (B,S,D)``, cache=None -> returns (y, new_cache-or-None).
    Decode: ``x (B,1,D)`` with ``cache`` and scalar ``pos`` (current absolute
    position) -> (y, updated cache).
    """
    cdt = dt(cfg, "compute")
    b_, s, d = x.shape
    scale = 1.0 / math.sqrt(cfg.head_dim)

    q, k, v = _project_qkv(cfg, p, x, memory if cross else None)

    if cross:
        # No positional rotation, no mask (memory is a set of media tokens).
        scores = _gqa_scores(q, k).astype(jnp.float32) * scale
        w = jax.nn.softmax(scores, axis=-1).astype(cdt)
        out = _gqa_out(w, v)
    elif cache is None:
        positions = jnp.arange(s)
        if cfg.rope:
            q = rope_rotate(q, positions, cfg.rope_theta)
            k = rope_rotate(k, positions, cfg.rope_theta)
        use_flash = force_flash if force_flash is not None else s > FLASH_THRESHOLD
        if use_flash:
            out = _flash_attention(q, k, v, causal=cfg.causal, window=window,
                                   scale=scale, chunk=min(FLASH_CHUNK, s),
                                   unroll=cfg.flash_unroll)
        else:
            scores = _gqa_scores(q, k).astype(jnp.float32) * scale
            mask = _self_mask(s, causal=cfg.causal, window=window)
            scores = jnp.where(mask, scores, -1e30)
            w = jax.nn.softmax(scores, axis=-1).astype(cdt)
            out = _gqa_out(w, v)
    else:
        assert s == 1 and pos is not None
        if cfg.rope:
            q = rope_rotate(q, jnp.full((1,), pos), cfg.rope_theta)
            k = rope_rotate(k, jnp.full((1,), pos), cfg.rope_theta)
        cache = cache_write(cache, k[:, 0], v[:, 0], pos)
        ck, cv, slot_pos = cache["k"], cache["v"], cache["slot_pos"]
        scores = _gqa_scores(q, ck.astype(cdt)).astype(jnp.float32) * scale
        valid = (slot_pos >= 0) & (slot_pos <= pos)
        if window:
            valid &= slot_pos > pos - window
        scores = jnp.where(valid[None, None, None, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(cdt)
        out = _gqa_out(w, cv.astype(cdt))

    if cfg.pad_heads_to and cfg.pad_heads_to > cfg.n_heads:
        # Kill padded heads exactly (zero fwd AND zero grads to their
        # params). Layout is group-major: each of the n_kv groups carries
        # g_new = pad/kv heads of which the last g_new - g_real are dead —
        # this keeps every real head attached to its original KV group.
        h_pad = out.shape[2]
        g_new = h_pad // cfg.n_kv_heads
        g_real = cfg.n_heads // cfg.n_kv_heads
        head_mask = ((jnp.arange(h_pad) % g_new) < g_real).astype(out.dtype)
        out = out * head_mask[None, None, :, None]
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
    if cross and "gate" in p:
        y = jnp.tanh(p["gate"].astype(jnp.float32)).astype(cdt) * y
    return y, cache


@functools.lru_cache(maxsize=64)
def _self_mask_np(s: int, causal: bool, window: int):
    import numpy as np

    i = np.arange(s)[:, None]
    j = np.arange(s)[None, :]
    mask = np.ones((s, s), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= j > i - window
    return mask


def _self_mask(s: int, *, causal: bool, window: int) -> Array:
    return jnp.asarray(_self_mask_np(s, causal, window))


# -- cache ---------------------------------------------------------------


def make_buf(shape, dtype, abstract: bool, fill=0):
    """jnp buffer or ShapeDtypeStruct (dry-run inputs), one code path."""
    if abstract:
        return jax.ShapeDtypeStruct(tuple(shape), dtype)
    return jnp.full(shape, fill, dtype) if fill else jnp.zeros(shape, dtype)


def init_cache(cfg: ModelConfig, batch: int, length: int, dtype=None, *, abstract: bool = False):
    """Empty attention cache of ``length`` slots (window ring or full)."""
    k = cfg.n_kv_heads
    dh = cfg.head_dim
    dtype = dtype or dt(cfg, "compute")
    return {
        "k": make_buf((batch, length, k, dh), dtype, abstract),
        "v": make_buf((batch, length, k, dh), dtype, abstract),
        "slot_pos": make_buf((length,), jnp.int32, abstract, fill=-1),
    }


def cache_axes(cfg: ModelConfig):
    return {
        "k": ("batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
        "slot_pos": (None,),
    }


def cache_write(cache, k_t: Array, v_t: Array, pos: Array):
    """Writes one timestep (B,K,Dh) at slot pos % L."""
    length = cache["k"].shape[1]
    slot = jnp.asarray(pos, jnp.int32) % length
    k = jax.lax.dynamic_update_slice(cache["k"], k_t[:, None].astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_t[:, None].astype(cache["v"].dtype), (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], jnp.asarray(pos, jnp.int32)[None], (slot,)
    )
    return {"k": k, "v": v, "slot_pos": slot_pos}


def cache_fill_from_prefill(cfg: ModelConfig, cache, k: Array, v: Array):
    """Writes a full prefill (B,S,K,Dh) into the cache (keeping the last
    ``L`` tokens when S > L, i.e. window semantics)."""
    length = cache["k"].shape[1]
    s = k.shape[1]
    if s <= length:
        kk = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        vv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        slot_pos = cache["slot_pos"].at[:s].set(jnp.arange(s, dtype=jnp.int32))
        return {"k": kk, "v": vv, "slot_pos": slot_pos}
    # keep last `length` tokens, ring-aligned so slot = pos % length
    start = s - length
    ktail, vtail = k[:, start:], v[:, start:]
    positions = jnp.arange(start, s, dtype=jnp.int32)
    slots = positions % length
    order = jnp.argsort(slots)
    kk = ktail[:, order].astype(cache["k"].dtype)
    vv = vtail[:, order].astype(cache["v"].dtype)
    return {"k": kk, "v": vv, "slot_pos": positions[order]}


# ---------------------------------------------------------------------------
# FFN.
# ---------------------------------------------------------------------------


def init_ffn(cfg: ModelConfig, key: Array, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    b = PBuilder(key, dt(cfg))
    gated = cfg.activation in ("swiglu", "geglu")
    b.add("w1", (d, f), ("fsdp", "mlp"))
    if gated:
        b.add("w3", (d, f), ("fsdp", "mlp"))
    b.add("w2", (f, d), ("mlp", "fsdp"))
    return b.build()


def apply_ffn(cfg: ModelConfig, p, x: Array) -> Array:
    cdt = dt(cfg, "compute")
    x = x.astype(cdt)
    h = x @ p["w1"].astype(cdt)
    if cfg.activation == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"].astype(cdt))
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(h) * (x @ p["w3"].astype(cdt))
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(h)
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(cfg.activation)
    return h @ p["w2"].astype(cdt)


# ---------------------------------------------------------------------------
# MoE: top-k routing with sort-based capacity dispatch (no T*E*C one-hots).
# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key: Array):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    b = PBuilder(key, dt(cfg))
    b.add("router", (d, e), ("fsdp", "experts"), scale=0.1)
    b.add("w1", (e, d, f), ("experts", "fsdp", "mlp"))
    b.add("w3", (e, d, f), ("experts", "fsdp", "mlp"))
    b.add("w2", (e, f, d), ("experts", "mlp", "fsdp"))
    if cfg.moe_dense_residual:
        b.sub("dense", init_ffn(cfg, b.key()))
    return b.build()


def apply_moe(cfg: ModelConfig, p, x: Array) -> tuple[Array, Array]:
    """MoE dispatcher. Under an ambient mesh with a ``model`` axis, TRAIN/
    PREFILL shapes take the SHARD_MAP expert-parallel path (local routing,
    per-shard experts, one psum combine — weights stay put, tokens are
    plentiful). DECODE (seq len 1, a handful of tokens per chip) keeps the
    GSPMD path: gathering ~GiB of expert weights per layer to serve 8
    tokens inverts the traffic equation, so there tokens move instead."""
    from repro.dist.sharding import _ambient_mesh

    mesh = _ambient_mesh()
    if mesh is not None and "model" in mesh.axis_names and x.shape[1] > 1:
        return apply_moe_sharded(cfg, p, x)
    return _apply_moe_local(cfg, p, x)


def _apply_moe_local(cfg: ModelConfig, p, x: Array) -> tuple[Array, Array]:
    """x: (B, S, D) -> (y, aux_loss). Sort-based dropping dispatch:

    tokens are argsorted by assigned expert; each expert processes up to
    ``capacity`` tokens in a dense (E, C, D) buffer (overflow tokens are
    dropped — GShard-style). Memory is O(E*C*D), never O(T*E*C).
    """
    cdt = dt(cfg, "compute")
    b_, s, d = x.shape
    t = b_ * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32)) @ p["router"].astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch): E * sum_e f_e * P_e.
    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    capacity = max(int(math.ceil(k * t / e * cfg.capacity_factor)), 4)
    capacity = min(capacity, t)

    flat_e = idx.reshape(-1)                      # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(t), k)       # token id per assignment
    flat_gate = gate.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, stok, sgate = flat_e[order], flat_tok[order], flat_gate[order]
    group_start = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos_in_grp = jnp.arange(t * k) - group_start[se]
    keep = pos_in_grp < capacity
    slot = jnp.where(keep, se * capacity + pos_in_grp, e * capacity)  # overflow -> sentinel

    buf_tok = jnp.full((e * capacity + 1,), t, jnp.int32).at[slot].set(stok.astype(jnp.int32))
    buf_gate = jnp.zeros((e * capacity + 1,), jnp.float32).at[slot].set(sgate)
    buf_tok, buf_gate = buf_tok[:-1], buf_gate[:-1]

    xpad = jnp.concatenate([xt.astype(cdt), jnp.zeros((1, d), cdt)], axis=0)
    xe = xpad[buf_tok].reshape(e, capacity, d)    # (E, C, D)

    h = jnp.einsum("ecd,edf->ecf", xe, p["w1"].astype(cdt))
    g = jnp.einsum("ecd,edf->ecf", xe, p["w3"].astype(cdt))
    h = jax.nn.silu(h) * g
    yexp = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(cdt))
    yflat = (yexp.reshape(e * capacity, d).astype(jnp.float32)) * buf_gate[:, None]

    y = jnp.zeros((t + 1, d), jnp.float32).at[buf_tok].add(yflat)[:t]
    y = y.astype(cdt)

    if cfg.moe_dense_residual:
        y = y + apply_ffn(cfg, p["dense"], xt)
    return y.reshape(b_, s, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel MoE under shard_map.
#
# The GSPMD partitioner handles the dense expert einsums well but falls over
# on the dispatch (a global argsort over tokens forces giant all-gathers).
# Here the paper's B-block lesson — provision compute per memory channel and
# keep routing local — becomes: every (data, model) device routes ITS tokens
# to ITS 1/mp slice of the experts, computes locally, and one psum over
# `model` combines. Wire cost per MoE layer = one (T_loc, D) psum + the
# usual FSDP weight gathers, independent of n_experts.
# ---------------------------------------------------------------------------


def _dispatch_local(xt, gate, idx, e_lo, e_hi, capacity, e_loc, cdt):
    """Builds (E_loc, C, D) buffers + gate/token maps for MY experts only."""
    t, d = xt.shape
    k = idx.shape[-1]
    flat_e = idx.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate.reshape(-1)
    mine = (flat_e >= e_lo) & (flat_e < e_hi)
    key = jnp.where(mine, flat_e - e_lo, e_loc)  # foreign -> overflow group
    order = jnp.argsort(key, stable=True)
    se, stok, sgate = key[order], flat_tok[order], flat_gate[order]
    group_start = jnp.searchsorted(se, jnp.arange(e_loc), side="left")
    pos_in_grp = jnp.arange(t * k) - group_start[jnp.minimum(se, e_loc - 1)]
    keep = (se < e_loc) & (pos_in_grp < capacity)
    slot = jnp.where(keep, se * capacity + pos_in_grp, e_loc * capacity)

    buf_tok = jnp.full((e_loc * capacity + 1,), t, jnp.int32).at[slot].set(stok.astype(jnp.int32))
    buf_gate = jnp.zeros((e_loc * capacity + 1,), jnp.float32).at[slot].set(sgate)
    buf_tok, buf_gate = buf_tok[:-1], buf_gate[:-1]
    xpad = jnp.concatenate([xt.astype(cdt), jnp.zeros((1, d), cdt)], axis=0)
    xe = xpad[buf_tok].reshape(e_loc, capacity, d)
    return xe, buf_tok, buf_gate


def apply_moe_sharded(cfg: ModelConfig, p, x: Array) -> tuple[Array, Array]:
    """shard_map expert-parallel MoE. Requires the ambient mesh (set_mesh)
    with a ``model`` axis; params sharded by the standard rules."""
    from repro.dist.sharding import _ambient_mesh, spec_for
    from jax.sharding import PartitionSpec as P

    mesh = _ambient_mesh()
    amesh = jax.sharding.get_abstract_mesh()
    cdt = dt(cfg, "compute")
    b_, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    mp_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    e_loc = e // mp_size if e % mp_size == 0 else 0
    if e_loc == 0:
        return _apply_moe_local(cfg, p, x)

    sp = lambda axes, shape: spec_for(axes, mesh, shape, mode="train")  # noqa: E731
    in_specs = (
        P(dp if dp else None, None, None),                       # x
        sp(("fsdp", "experts"), p["router"].shape),              # router
        sp(("experts", "fsdp", "mlp"), p["w1"].shape),           # w1
        sp(("experts", "fsdp", "mlp"), p["w3"].shape),           # w3
        sp(("experts", "mlp", "fsdp"), p["w2"].shape),           # w2
    )
    dense_args = ()
    if cfg.moe_dense_residual:
        dense_args = (p["dense"]["w1"], p["dense"]["w3"], p["dense"]["w2"])
        in_specs = in_specs + (
            sp(("fsdp", "mlp"), p["dense"]["w1"].shape),
            sp(("fsdp", "mlp"), p["dense"]["w3"].shape),
            sp(("mlp", "fsdp"), p["dense"]["w2"].shape),
        )

    def _gather(arr, spec, dtype, keep_model: bool = True):
        """All-gathers sharded dims back (in compute dtype). By default the
        expert (`model`) dim stays local; the router needs it gathered too
        (routing scores span ALL experts)."""
        out = arr.astype(dtype)
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                if a != "model" or not keep_model:
                    out = jax.lax.all_gather(out, a, axis=dim, tiled=True)
        return out

    def local_moe(x_loc, router, w1, w3, w2, *dense):
        t_loc = x_loc.shape[0] * x_loc.shape[1]
        xt = x_loc.reshape(t_loc, d)
        router_f = _gather(router, in_specs[1], jnp.float32, keep_model=False)
        logits = xt.astype(jnp.float32) @ router_f
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        # Globalise the per-expert stats BEFORE the product so the aux loss
        # equals the unsharded estimator (mean-of-products != product-of-means).
        me = probs.mean(0)
        ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (t_loc * k)
        if dp:
            me = jax.lax.pmean(me, dp)
            ce = jax.lax.pmean(ce, dp)
        aux = e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))

        j = jax.lax.axis_index("model")
        capacity = max(int(math.ceil(k * t_loc / e * cfg.capacity_factor)), 4)
        capacity = min(capacity, t_loc)
        xe, buf_tok, buf_gate = _dispatch_local(
            xt, gate, idx, j * e_loc, (j + 1) * e_loc, capacity, e_loc, cdt
        )
        w1_f = _gather(w1, in_specs[2], cdt)
        w3_f = _gather(w3, in_specs[3], cdt)
        w2_f = _gather(w2, in_specs[4], cdt)
        h = jnp.einsum("ecd,edf->ecf", xe, w1_f)
        g = jnp.einsum("ecd,edf->ecf", xe, w3_f)
        yexp = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, w2_f)
        yflat = yexp.reshape(e_loc * capacity, d).astype(jnp.float32) * buf_gate[:, None]
        y = jnp.zeros((t_loc + 1, d), jnp.float32).at[buf_tok].add(yflat)[:t_loc]

        if dense:
            dw1, dw3, dw2 = dense
            # TP dense branch: mlp dim stays sharded over `model`; the same
            # psum that combines experts combines the dense partials.
            dw1 = _gather(dw1, in_specs[5], cdt)
            dw3 = _gather(dw3, in_specs[6], cdt)
            dw2 = _gather(dw2, in_specs[7], cdt)
            hd = jax.nn.silu(xt.astype(cdt) @ dw1) * (xt.astype(cdt) @ dw3)
            y = y + (hd @ dw2).astype(jnp.float32)

        y = jax.lax.psum(y.astype(cdt), "model")
        return y.reshape(x_loc.shape), aux

    fn = jax.shard_map(
        local_moe,
        mesh=amesh,
        in_specs=in_specs,
        out_specs=(P(dp if dp else None, None, None), P()),
        check_vma=False,
    )
    y, aux = fn(x, p["router"], p["w1"], p["w3"], p["w2"], *dense_args)
    return y.astype(cdt), aux
