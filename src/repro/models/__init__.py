"""Model zoo: composable LM blocks covering the 10 assigned architectures."""

from repro.models.lm import (
    build_cache,
    build_lm,
    lm_decode,
    lm_forward,
    lm_loss,
    lm_prefill,
)
