"""Recurrent token mixers: Griffin RG-LRU (recurrentgemma) and RWKV-6 "Finch".

Both are linear recurrences, so train/prefill uses a PARALLEL form:
  * RG-LRU: ``h_t = a_t * h_{t-1} + b_t`` via ``jax.lax.associative_scan``
    (log-depth, the TPU-friendly form of the paper's "pipeline timesteps
    through the array" insight applied to sequence instead of simulation
    time).
  * RWKV-6: matrix-valued state ``S_t = diag(w_t) S_{t-1} + k_t v_t^T``;
    implemented as a CHUNKED scan: within a chunk the contribution of the
    incoming state and the intra-chunk outer products are computed with
    dense einsums (MXU-friendly), and the sequential ``lax.scan`` only runs
    over S/chunk steps.

Decode is the single-step recurrence with an explicit state cache.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import PBuilder, dt

Array = jax.Array


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma) recurrent block.
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def init_rglru(cfg: ModelConfig, key: Array):
    d, w = cfg.d_model, cfg.rnn_width
    b = PBuilder(key, dt(cfg))
    b.add("w_gate", (d, w), ("fsdp", "mlp"))        # GeLU gate branch
    b.add("w_branch", (d, w), ("fsdp", "mlp"))      # recurrent branch input
    b.add("conv_k", (cfg.conv_width, w), (None, "mlp"))  # depthwise temporal conv
    b.add("conv_b", (w,), ("mlp",), init="zeros")
    b.add("w_a", (w, w), ("mlp", None))             # recurrence gate
    b.add("b_a", (w,), (None,), init="zeros")
    b.add("w_x", (w, w), ("mlp", None))             # input gate
    b.add("b_x", (w,), (None,), init="zeros")
    # Lambda init so a = sigmoid(L) in [0.9, 0.999] (Griffin appendix).
    lam0 = math.log(0.95 / (1 - 0.95))
    b.add("lam", (w,), (None,), init="const", scale=lam0)
    b.add("w_out", (w, d), ("mlp", "fsdp"))
    return b.build()


def _rglru_gates(p, bx: Array, cdt):
    r = jax.nn.sigmoid(bx.astype(jnp.float32) @ p["w_a"].astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(bx.astype(jnp.float32) @ p["w_x"].astype(jnp.float32) + p["b_x"].astype(jnp.float32))
    log_a = -_RGLRU_C * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bterm = mult * (i * bx.astype(jnp.float32))
    return a, bterm


def apply_rglru(
    cfg: ModelConfig,
    p,
    x: Array,
    *,
    cache: dict | None = None,
    pos: Any = None,
):
    """x: (B, S, D). cache = {"h": (B, W), "conv": (B, conv_width-1, W)}."""
    cdt = dt(cfg, "compute")
    x = x.astype(cdt)
    b_, s, _ = x.shape
    w = cfg.rnn_width

    gate = jax.nn.gelu(x @ p["w_gate"].astype(cdt))
    bx = x @ p["w_branch"].astype(cdt)  # (B, S, W)

    # Depthwise causal conv, width conv_width.
    cw = cfg.conv_width
    if cache is None:
        prevs = jnp.zeros((b_, cw - 1, w), cdt)
    else:
        prevs = cache["conv"].astype(cdt)
    bx_pad = jnp.concatenate([prevs, bx], axis=1)  # (B, S+cw-1, W)
    conv = sum(
        bx_pad[:, i : i + s, :] * p["conv_k"].astype(cdt)[i]
        for i in range(cw)
    ) + p["conv_b"].astype(cdt)

    a, bterm = _rglru_gates(p, conv, cdt)  # (B, S, W) f32 each

    if cache is None:
        # associative scan over time: h_t = a_t h_{t-1} + b_t
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
        new_cache = None
    else:
        h0 = cache["h"].astype(jnp.float32)
        h = a[:, 0] * h0 + bterm[:, 0]
        new_cache = {
            "h": h.astype(cdt),
            "conv": bx_pad[:, -(cw - 1) :, :].astype(cdt),
        }
        h = h[:, None, :]

    y = (h.astype(cdt) * gate) @ p["w_out"].astype(cdt)
    if cache is None and s >= 1:
        # expose final state for prefill -> decode handoff
        new_cache = {"h": h[:, -1].astype(cdt), "conv": bx_pad[:, -(cw - 1) :, :].astype(cdt)}
    return y, new_cache


def rglru_cache_init(cfg: ModelConfig, batch: int, *, abstract: bool = False):
    from repro.models.layers import make_buf

    cdt = dt(cfg, "compute")
    return {
        "h": make_buf((batch, cfg.rnn_width), cdt, abstract),
        "conv": make_buf((batch, cfg.conv_width - 1, cfg.rnn_width), cdt, abstract),
    }


def rglru_cache_axes(cfg: ModelConfig):
    return {"h": ("batch", "mlp"), "conv": ("batch", None, "mlp")}


# ---------------------------------------------------------------------------
# RWKV-6 (Finch): data-dependent decay time-mix + channel-mix.
# ---------------------------------------------------------------------------


def init_rwkv_tmix(cfg: ModelConfig, key: Array):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    lora = 64
    b = PBuilder(key, dt(cfg))
    for nm in ("mu_x", "mu_w", "mu_k", "mu_v", "mu_r", "mu_g"):
        b.add(nm, (d,), (None,), init="const", scale=0.5)
    for nm in ("w", "k", "v", "r", "g"):
        b.add(f"lora_a_{nm}", (d, lora), ("fsdp", None), scale=0.1)
        b.add(f"lora_b_{nm}", (lora, d), (None, "fsdp"), init="zeros")
    b.add("decay_base", (d,), (None,), init="const", scale=-2.0)  # w0
    b.add("bonus", (nh, hs), (None, None), init="const", scale=0.5)  # u
    b.add("wr", (d, d), ("fsdp", None))
    b.add("wk", (d, d), ("fsdp", None))
    b.add("wv", (d, d), ("fsdp", None))
    b.add("wg", (d, d), ("fsdp", None))
    b.add("wo", (d, d), (None, "fsdp"))
    b.add("ln_scale", (d,), (None,), init="ones")  # per-head groupnorm
    return b.build()


def _ddlerp(p, nm: str, x, xprev, mix_base):
    mu = p[f"mu_{nm}"].astype(jnp.float32)
    lo = jnp.tanh(mix_base @ p[f"lora_a_{nm}"].astype(jnp.float32)) @ p[
        f"lora_b_{nm}"
    ].astype(jnp.float32)
    return x + (xprev - x) * (mu + lo)


def apply_rwkv_tmix(
    cfg: ModelConfig,
    p,
    x: Array,
    *,
    cache: dict | None = None,
    chunk: int = 128,
):
    """RWKV-6 time mix. x: (B, S, D).

    cache = {"state": (B, H, hs, hs), "x_prev": (B, D)} for decode;
    prefill/train starts from zeros and returns the final state.
    """
    b_, s, d = x.shape
    hs = cfg.rwkv_head_size
    nh = d // hs
    x32 = x.astype(jnp.float32)

    if cache is None:
        xprev = jnp.concatenate([jnp.zeros((b_, 1, d), jnp.float32), x32[:, :-1]], axis=1)
        state0 = jnp.zeros((b_, nh, hs, hs), jnp.float32)
    else:
        xprev = cache["x_prev"].astype(jnp.float32)[:, None, :]
        state0 = cache["state"].astype(jnp.float32)

    mix_base = x32 + (xprev - x32) * p["mu_x"].astype(jnp.float32)
    xw = _ddlerp(p, "w", x32, xprev, mix_base)
    xk = _ddlerp(p, "k", x32, xprev, mix_base)
    xv = _ddlerp(p, "v", x32, xprev, mix_base)
    xr = _ddlerp(p, "r", x32, xprev, mix_base)
    xg = _ddlerp(p, "g", x32, xprev, mix_base)

    # Data-dependent per-channel decay in (0, 1): w = exp(-exp(w0 + lora)).
    dec = jnp.exp(
        -jnp.exp(
            p["decay_base"].astype(jnp.float32)
            + jnp.tanh(xw @ p["lora_a_w"].astype(jnp.float32)) @ p["lora_b_w"].astype(jnp.float32)
        )
    )  # (B, S, D)

    r = (xr @ p["wr"].astype(jnp.float32)).reshape(b_, s, nh, hs)
    k = (xk @ p["wk"].astype(jnp.float32)).reshape(b_, s, nh, hs)
    v = (xv @ p["wv"].astype(jnp.float32)).reshape(b_, s, nh, hs)
    g = xg @ p["wg"].astype(jnp.float32)
    w = dec.reshape(b_, s, nh, hs)
    u = p["bonus"].astype(jnp.float32)

    if cfg.rwkv_chunk and s > 1 and s % cfg.rwkv_chunk == 0:
        # Chunked parallel form (see kernels/wkv6): O(S/chunk) sequential
        # steps with dense intra-chunk matmuls — the MXU-friendly path used
        # for train/prefill (§Perf rwkv6 hillclimb).
        from repro.kernels.wkv6.ref import wkv6_chunked_ref

        y4, state = wkv6_chunked_ref(r, k, v, w, u, state0, chunk=cfg.rwkv_chunk)
        y = y4.reshape(b_, s, d)
    else:
        def step(state, inp):
            r_t, k_t, v_t, w_t = inp  # (B, H, hs) each
            kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,hs,hs)
            y = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[..., :, None] * kv)
            state = w_t[..., :, None] * state + kv
            return state, y

        xs = (
            jnp.moveaxis(r, 1, 0),
            jnp.moveaxis(k, 1, 0),
            jnp.moveaxis(v, 1, 0),
            jnp.moveaxis(w, 1, 0),
        )
        state, ys = jax.lax.scan(step, state0, xs)
        y = jnp.moveaxis(ys, 0, 1).reshape(b_, s, d)  # (B,S,D)

    # Per-head groupnorm, then silu(g) gate and output projection.
    yh = y.reshape(b_, s, nh, hs)
    mean = yh.mean(-1, keepdims=True)
    var = ((yh - mean) ** 2).mean(-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 1e-6)
    y = yh.reshape(b_, s, d) * p["ln_scale"].astype(jnp.float32)
    y = y * jax.nn.silu(g)
    out = y @ p["wo"].astype(jnp.float32)

    new_cache = {"state": state.astype(jnp.float32), "x_prev": x32[:, -1]}
    return out.astype(x.dtype), new_cache


def init_rwkv_cmix(cfg: ModelConfig, key: Array):
    d, f = cfg.d_model, cfg.d_ff
    b = PBuilder(key, dt(cfg))
    b.add("mu_k", (d,), (None,), init="const", scale=0.5)
    b.add("mu_r", (d,), (None,), init="const", scale=0.5)
    b.add("wk", (d, f), ("fsdp", "mlp"))
    b.add("wv", (f, d), ("mlp", "fsdp"))
    b.add("wr", (d, d), ("fsdp", None))
    return b.build()


def apply_rwkv_cmix(cfg: ModelConfig, p, x: Array, *, cache: dict | None = None):
    """RWKV channel mix (the FFN analogue). cache = {"x_prev": (B, D)}."""
    b_, s, d = x.shape
    x32 = x.astype(jnp.float32)
    if cache is None:
        xprev = jnp.concatenate([jnp.zeros((b_, 1, d), jnp.float32), x32[:, :-1]], axis=1)
    else:
        xprev = cache["x_prev"].astype(jnp.float32)[:, None, :]
    xk = x32 + (xprev - x32) * p["mu_k"].astype(jnp.float32)
    xr = x32 + (xprev - x32) * p["mu_r"].astype(jnp.float32)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(jnp.float32)))
    kv = k @ p["wv"].astype(jnp.float32)
    y = jax.nn.sigmoid(xr @ p["wr"].astype(jnp.float32)) * kv
    return y.astype(x.dtype), {"x_prev": x32[:, -1]}


def rwkv_cache_init(cfg: ModelConfig, batch: int, *, abstract: bool = False):
    from repro.models.layers import make_buf

    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    return {
        "tmix": {
            "state": make_buf((batch, nh, hs, hs), jnp.float32, abstract),
            "x_prev": make_buf((batch, d), jnp.float32, abstract),
        },
        "cmix": {"x_prev": make_buf((batch, d), jnp.float32, abstract)},
    }


def rwkv_cache_axes(cfg: ModelConfig):
    return {
        "tmix": {"state": ("batch", None, None, None), "x_prev": ("batch", None)},
        "cmix": {"x_prev": ("batch", None)},
    }
