"""Combinator library: the reusable node builders programs are made of.

Each combinator constructs a :class:`~repro.ir.graph.StencilOp` whose
``compute`` is a pure elementwise jnp function over aligned shifted views and
whose :class:`~repro.ir.graph.OpCost` is intrinsic to the combinator (an
instruction-cost table, following the paper's Eq. 5-6 conventions) — op
counts for a *program* are then derived by the graph analysis, never written
per kernel.

Cost conventions (matching SPARTA §3.1):
  * ``affine``            — one MAC per tap (Eq. 5 counts a 5-point Laplacian
                            as 5 MACs).
  * ``flux``              — 1 sub for the stencil difference, plus 3 ops
                            (mul, cmp, select) when the Eq. 2-3 limiter is on.
                            The limiter's *gradient* difference rides free, as
                            in the paper's Eq. 6 accounting (4 ops per flux).
  * ``scaled_residual``   — one accumulate per term plus a single MAC for the
                            shared scale against the base field.
  * ``product``           — one MAC (elementwise field x field multiply, the
                            velocity x gradient term of an advection sweep).
  * ``weighted_residual`` — ``scaled_residual`` with the scale promoted from
                            a baked-in scalar to a *field* read at offset
                            zero (the Smagorinsky-style spatially-varying
                            diffusion coefficient): same cost shape, one MAC
                            for the weight plus one accumulate per term.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax.numpy as jnp

from repro.ir.graph import Offset, OpCost, Read, StencilOp


def _tree_sum(vals):
    """Balanced pairwise sum — matches the hand-written kernels' grouping
    of ``(a + b) + (c + d)`` so lowered programs stay bitwise-comparable."""
    vals = list(vals)
    while len(vals) > 1:
        vals = [
            vals[i] + vals[i + 1] if i + 1 < len(vals) else vals[i]
            for i in range(0, len(vals), 2)
        ]
    return vals[0]


def affine(name: str, field: str, taps: Mapping[Offset, float]) -> StencilOp:
    """Weighted stencil sum: ``out = sum_k w_k * field[offset_k]``.

    Tap order is preserved (it fixes floating-point association, so the
    lowerings reproduce the hand-written kernels bit-for-bit). A uniform-
    weight stencil is factored as ``w * (v_0 + v_1 + ...)``, the form the
    jacobi family uses.
    """
    offsets = tuple(taps)
    weights = tuple(float(taps[o]) for o in offsets)
    uniform = len(set(weights)) == 1

    def compute(*views):
        if uniform:
            acc = views[0]
            for v in views[1:]:
                acc = acc + v
            return weights[0] * acc
        acc = weights[0] * views[0]
        for w, v in zip(weights[1:], views[1:]):
            acc = acc + w * v
        return acc

    reads = tuple(Read(field, o) for o in offsets)
    tag = "affine:" + ",".join(f"{o}={w!r}" for o, w in zip(offsets, weights))
    return StencilOp(name, reads, compute, OpCost(macs=len(offsets)), tag=tag)


def flux(
    name: str,
    of: str,
    lo: Offset,
    hi: Offset,
    *,
    limiter: str | None = None,
) -> StencilOp:
    """Finite difference ``of[hi] - of[lo]``, optionally flux-limited.

    With ``limiter=g`` the result is zeroed when it points up-gradient of
    ``g`` across the same pair of points (Eq. 2-3):
    ``F = d if d * (g[hi] - g[lo]) <= 0 else 0``.
    """
    reads = [Read(of, hi), Read(of, lo)]
    if limiter is not None:
        reads += [Read(limiter, hi), Read(limiter, lo)]

    def compute(a_hi, a_lo, *grad):
        d = a_hi - a_lo
        if not grad:
            return d
        g = grad[0] - grad[1]
        return jnp.where(d * g <= 0, d, jnp.zeros_like(d))

    cost = OpCost(other_ops=1 + (3 if limiter is not None else 0))
    tag = f"flux:lo={lo},hi={hi},limited={limiter is not None}"
    return StencilOp(name, tuple(reads), compute, cost, tag=tag)


def product(
    name: str,
    a: str,
    b: str,
    *,
    a_offset: Offset | None = None,
    b_offset: Offset | None = None,
    ndim: int = 2,
) -> StencilOp:
    """Elementwise field product ``out = a[a_offset] * b[b_offset]``.

    The coupling op multi-field programs are made of (velocity x gradient in
    an advection sweep). Offsets default to zero — the fields are usually
    co-located after any destaggering ``affine``.
    """
    zero = (0,) * ndim
    reads = (
        Read(a, a_offset if a_offset is not None else zero),
        Read(b, b_offset if b_offset is not None else zero),
    )

    def compute(va, vb):
        return va * vb

    return StencilOp(name, reads, compute, OpCost(macs=1), tag="product")


def weighted_residual(
    name: str,
    base: str,
    weight: str,
    terms: Sequence[tuple[str, int]],
    *,
    ndim: int = 2,
) -> StencilOp:
    """``out = base - weight * sum(sign_i * term_i)`` with a *field* weight.

    The multi-field form of :func:`scaled_residual`: the scale is a source
    field sampled at offset zero (a spatially-varying diffusion coefficient,
    COSMO's Smagorinsky pattern) instead of a scalar baked into the graph.
    Term grouping matches :func:`scaled_residual` exactly, so a constant
    weight field reproduces the scalar kernel bit-for-bit.
    """
    for f, s in terms:
        if s not in (1, -1):
            raise ValueError(f"sign for {f!r} must be +1/-1, got {s}")

    def compute(b, w, *ts):
        signed = [t if s > 0 else -t for t, (_, s) in zip(ts, terms)]
        return b - w * _tree_sum(signed)

    zero = (0,) * ndim
    reads = (Read(base, zero), Read(weight, zero)) + tuple(
        Read(f, zero) for f, _ in terms
    )
    tag = "weighted_residual:signs=" + ",".join(str(s) for _, s in terms)
    return StencilOp(
        name, reads, compute, OpCost(macs=1, other_ops=len(terms)), tag=tag
    )


def scaled_residual(
    name: str,
    base: str,
    terms: Sequence[tuple[str, int]],
    scale: float,
    *,
    ndim: int = 2,
) -> StencilOp:
    """``out = base - scale * sum(sign_i * term_i)`` at offset zero.

    The hdiff output stage (Eq. 4) and any explicit-Euler update take this
    shape. ``terms`` is a sequence of ``(field, sign)`` with sign in {+1,-1}.
    The signed terms are combined pairwise, matching the hand-written
    ``(F_r - F_rm) + (G_c - G_cm)`` grouping.
    """
    for f, s in terms:
        if s not in (1, -1):
            raise ValueError(f"sign for {f!r} must be +1/-1, got {s}")

    def compute(b, *ts):
        signed = [t if s > 0 else -t for t, (_, s) in zip(ts, terms)]
        return b - scale * _tree_sum(signed)

    zero = (0,) * ndim
    reads = (Read(base, zero),) + tuple(Read(f, zero) for f, _ in terms)
    tag = (
        f"scaled_residual:scale={float(scale)!r},signs="
        + ",".join(str(s) for _, s in terms)
    )
    return StencilOp(
        name, reads, compute, OpCost(macs=1, other_ops=len(terms)), tag=tag
    )
