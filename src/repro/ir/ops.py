"""Combinator library: the reusable node builders programs are made of.

Each combinator constructs a :class:`~repro.ir.graph.StencilOp` whose
``compute`` is a pure elementwise jnp function over aligned shifted views and
whose :class:`~repro.ir.graph.OpCost` is intrinsic to the combinator (an
instruction-cost table, following the paper's Eq. 5-6 conventions) — op
counts for a *program* are then derived by the graph analysis, never written
per kernel.

Cost conventions (matching SPARTA §3.1):
  * ``affine``            — one MAC per tap (Eq. 5 counts a 5-point Laplacian
                            as 5 MACs).
  * ``flux``              — 1 sub for the stencil difference, plus 3 ops
                            (mul, cmp, select) when the Eq. 2-3 limiter is on.
                            The limiter's *gradient* difference rides free, as
                            in the paper's Eq. 6 accounting (4 ops per flux).
  * ``scaled_residual``   — one accumulate per term plus a single MAC for the
                            shared scale against the base field.
  * ``product``           — one MAC (elementwise field x field multiply, the
                            velocity x gradient term of an advection sweep).
  * ``weighted_residual`` — ``scaled_residual`` with the scale promoted from
                            a baked-in scalar to a *field* read at offset
                            zero (the Smagorinsky-style spatially-varying
                            diffusion coefficient): same cost shape, one MAC
                            for the weight plus one accumulate per term.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax.numpy as jnp

from repro.ir.graph import Offset, OpCost, Read, StencilOp


def _tree_sum(vals):
    """Balanced pairwise sum — matches the hand-written kernels' grouping
    of ``(a + b) + (c + d)`` so lowered programs stay bitwise-comparable."""
    vals = list(vals)
    while len(vals) > 1:
        vals = [
            vals[i] + vals[i + 1] if i + 1 < len(vals) else vals[i]
            for i in range(0, len(vals), 2)
        ]
    return vals[0]


def _neg(o: Offset) -> Offset:
    return tuple(-c for c in o)


def _sub(a: Offset, b: Offset) -> Offset:
    return tuple(x - y for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# Adjoint (vjp) rules.
#
# Each combinator attaches a rule ``rule(op, gbar, fresh) -> [(field, term)]``
# to its StencilOp (see repro.ir.autodiff): ``op`` is the op INSTANCE at
# derivation time (field names are taken from ``op.reads``, never from the
# builder closure — compose() renames fields), ``gbar`` is the field holding
# the op's output cotangent, and ``fresh`` mints unique op names. Each
# ``term`` is a StencilOp whose value at a point is that read field's
# cotangent contribution there, or a bare field name contributing directly
# (identity). The transposition convention: a read of field f at offset o
# contributes to f's cotangent at offset -o — "adjoint offsets are the
# negated primal offsets".
# ---------------------------------------------------------------------------


def affine(name: str, field: str, taps: Mapping[Offset, float]) -> StencilOp:
    """Weighted stencil sum: ``out = sum_k w_k * field[offset_k]``.

    Tap order is preserved (it fixes floating-point association, so the
    lowerings reproduce the hand-written kernels bit-for-bit). A uniform-
    weight stencil is factored as ``w * (v_0 + v_1 + ...)``, the form the
    jacobi family uses.
    """
    offsets = tuple(taps)
    weights = tuple(float(taps[o]) for o in offsets)
    uniform = len(set(weights)) == 1

    def compute(*views):
        if uniform:
            acc = views[0]
            for v in views[1:]:
                acc = acc + v
            return weights[0] * acc
        acc = weights[0] * views[0]
        for w, v in zip(weights[1:], views[1:]):
            acc = acc + w * v
        return acc

    def rule(op, gbar, fresh):
        # Linear: the adjoint is the same affine stencil with every tap
        # offset negated (weights unchanged). A pure identity tap passes the
        # cotangent field straight through — no op at all.
        src = op.reads[0].field
        adj_taps = {_neg(r.offset): w for r, w in zip(op.reads, weights)}
        if adj_taps == {_neg(op.reads[0].offset): 1.0} and not any(
            c for c in op.reads[0].offset
        ):
            return [(src, gbar)]
        return [(src, affine(fresh(f"{op.name}.d_{src}"), gbar, adj_taps))]

    reads = tuple(Read(field, o) for o in offsets)
    tag = "affine:" + ",".join(f"{o}={w!r}" for o, w in zip(offsets, weights))
    return StencilOp(
        name, reads, compute, OpCost(macs=len(offsets)), tag=tag, vjp=rule
    )


def flux(
    name: str,
    of: str,
    lo: Offset,
    hi: Offset,
    *,
    limiter: str | None = None,
) -> StencilOp:
    """Finite difference ``of[hi] - of[lo]``, optionally flux-limited.

    With ``limiter=g`` the result is zeroed when it points up-gradient of
    ``g`` across the same pair of points (Eq. 2-3):
    ``F = d if d * (g[hi] - g[lo]) <= 0 else 0``.
    """
    reads = [Read(of, hi), Read(of, lo)]
    if limiter is not None:
        reads += [Read(limiter, hi), Read(limiter, lo)]

    def compute(a_hi, a_lo, *grad):
        d = a_hi - a_lo
        if not grad:
            return d
        g = grad[0] - grad[1]
        return jnp.where(d * g <= 0, d, jnp.zeros_like(d))

    def rule(op, gbar, fresh):
        src = op.reads[0].field  # the differenced field (post-compose name)
        if hi == lo:  # degenerate: d == 0 identically, derivative cancels
            return []
        if len(op.reads) == 2:
            # Unlimited: linear difference -> transposed affine on gbar.
            return [
                (src, affine(fresh(f"{op.name}.d_{src}"),
                             gbar, {_neg(hi): 1.0, _neg(lo): -1.0}))
            ]
        # Limited: the where-condition carries no gradient (matching jax.vjp
        # of jnp.where), so the limiter field gets NO contribution and the
        # cotangent of the difference is gbar gated by the mask re-evaluated
        # around the saved primal. Evaluating that gate ONCE at the flux
        # position (a helper op with no target field) and distributing it
        # with a transposed affine keeps the adjoint's access bandwidth
        # identical to the primal's — per-consumer terms would compose the
        # hi/lo reads with the recompute chain and widen every footprint.
        lim = op.reads[2].field
        zero = tuple(0 for _ in hi)
        gate_reads = (
            Read(gbar, zero),
            Read(src, hi), Read(src, lo),
            Read(lim, hi), Read(lim, lo),
        )

        def gate(g, a_hi, a_lo, l_hi, l_lo):
            d = a_hi - a_lo
            gg = l_hi - l_lo
            return jnp.where(d * gg <= 0, g, jnp.zeros_like(g))

        def gate_rule(gop, gbar2, fresh2):
            # The gate is its own linearization: linear in the cotangent
            # slot, zero-derivative in the mask operands (jnp.where
            # semantics) — so the double adjoint re-gates with the same
            # mask and stays at the primal bandwidth.
            reads2 = (Read(gbar2, gop.reads[0].offset),) + gop.reads[1:]
            return [(gop.reads[0].field, StencilOp(
                fresh2(f"{gop.name}.d"), reads2, gate, gop.cost,
                tag=gop.tag, vjp=gate_rule,
            ))]

        gate_op = StencilOp(
            fresh(f"{op.name}.dgate"), gate_reads, gate,
            OpCost(other_ops=4), tag=f"adj:{op.tag}:gate", vjp=gate_rule,
        )
        return [
            (None, gate_op),
            (src, affine(fresh(f"{op.name}.d_{src}"),
                         gate_op.name, {_neg(hi): 1.0, _neg(lo): -1.0})),
        ]

    cost = OpCost(other_ops=1 + (3 if limiter is not None else 0))
    tag = f"flux:lo={lo},hi={hi},limited={limiter is not None}"
    return StencilOp(name, tuple(reads), compute, cost, tag=tag, vjp=rule)


def product(
    name: str,
    a: str,
    b: str,
    *,
    a_offset: Offset | None = None,
    b_offset: Offset | None = None,
    ndim: int = 2,
) -> StencilOp:
    """Elementwise field product ``out = a[a_offset] * b[b_offset]``.

    The coupling op multi-field programs are made of (velocity x gradient in
    an advection sweep). Offsets default to zero — the fields are usually
    co-located after any destaggering ``affine``.
    """
    zero = (0,) * ndim
    reads = (
        Read(a, a_offset if a_offset is not None else zero),
        Read(b, b_offset if b_offset is not None else zero),
    )

    def compute(va, vb):
        return va * vb

    def rule(op, gbar, fresh):
        # Bilinear: each factor's cotangent is the OTHER factor (saved
        # primal) times the output cotangent, both re-aligned to the
        # factor's own grid position.
        (ra, rb) = op.reads
        out = []
        for mine, other, label in ((ra, rb, "a"), (rb, ra, "b")):
            reads_t = (
                Read(gbar, _neg(mine.offset)),
                Read(other.field, _sub(other.offset, mine.offset)),
            )
            out.append((mine.field, StencilOp(
                fresh(f"{op.name}.d_{mine.field}.{label}"), reads_t,
                lambda g, v: g * v, OpCost(macs=1),
                tag=f"adj:product:{label}",
            )))
        return out

    return StencilOp(name, reads, compute, OpCost(macs=1), tag="product", vjp=rule)


def weighted_residual(
    name: str,
    base: str,
    weight: str,
    terms: Sequence[tuple[str, int]],
    *,
    ndim: int = 2,
) -> StencilOp:
    """``out = base - weight * sum(sign_i * term_i)`` with a *field* weight.

    The multi-field form of :func:`scaled_residual`: the scale is a source
    field sampled at offset zero (a spatially-varying diffusion coefficient,
    COSMO's Smagorinsky pattern) instead of a scalar baked into the graph.
    Term grouping matches :func:`scaled_residual` exactly, so a constant
    weight field reproduces the scalar kernel bit-for-bit.
    """
    for f, s in terms:
        if s not in (1, -1):
            raise ValueError(f"sign for {f!r} must be +1/-1, got {s}")

    def compute(b, w, *ts):
        signed = [t if s > 0 else -t for t, (_, s) in zip(ts, terms)]
        return b - w * _tree_sum(signed)

    signs = tuple(s for _, s in terms)

    def rule(op, gbar, fresh):
        # out = b - w * S with S = tree_sum(sign_i * t_i), all at offset 0:
        # b_bar += g; w_bar += -S * g (S recomputed from the saved primal
        # terms); t_i_bar += -sign_i * w * g.
        base_f, w_f = op.reads[0].field, op.reads[1].field
        t_fields = tuple(r.field for r in op.reads[2:])
        zero_o = op.reads[0].offset
        out = [(base_f, gbar)]

        def w_term(g, *ts):
            signed = [t if s > 0 else -t for t, s in zip(ts, signs)]
            return -g * _tree_sum(signed)

        out.append((w_f, StencilOp(
            fresh(f"{op.name}.d_{w_f}"),
            (Read(gbar, zero_o),) + tuple(Read(f, zero_o) for f in t_fields),
            w_term, OpCost(macs=1, other_ops=len(signs)),
            tag=f"adj:{op.tag}:w",
        )))
        for i, (tf, s) in enumerate(zip(t_fields, signs)):
            out.append((tf, StencilOp(
                fresh(f"{op.name}.d_{tf}"),
                (Read(gbar, zero_o), Read(w_f, zero_o)),
                (lambda g, w: -(w * g)) if s > 0 else (lambda g, w: w * g),
                OpCost(macs=1), tag=f"adj:{op.tag}:t{i}",
            )))
        return out

    zero = (0,) * ndim
    reads = (Read(base, zero), Read(weight, zero)) + tuple(
        Read(f, zero) for f, _ in terms
    )
    tag = "weighted_residual:signs=" + ",".join(str(s) for _, s in terms)
    return StencilOp(
        name, reads, compute, OpCost(macs=1, other_ops=len(terms)), tag=tag,
        vjp=rule,
    )


def scaled_residual(
    name: str,
    base: str,
    terms: Sequence[tuple[str, int]],
    scale: float,
    *,
    ndim: int = 2,
) -> StencilOp:
    """``out = base - scale * sum(sign_i * term_i)`` at offset zero.

    The hdiff output stage (Eq. 4) and any explicit-Euler update take this
    shape. ``terms`` is a sequence of ``(field, sign)`` with sign in {+1,-1}.
    The signed terms are combined pairwise, matching the hand-written
    ``(F_r - F_rm) + (G_c - G_cm)`` grouping.
    """
    for f, s in terms:
        if s not in (1, -1):
            raise ValueError(f"sign for {f!r} must be +1/-1, got {s}")

    def compute(b, *ts):
        signed = [t if s > 0 else -t for t, (_, s) in zip(ts, terms)]
        return b - scale * _tree_sum(signed)

    signs = tuple(s for _, s in terms)

    def rule(op, gbar, fresh):
        # out = b - scale * sum(sign_i * t_i): b_bar += g and
        # t_i_bar += (-scale * sign_i) * g, all at offset 0.
        base_f = op.reads[0].field
        zero_o = op.reads[0].offset
        out = [(base_f, gbar)]
        for i, (r, s) in enumerate(zip(op.reads[1:], signs)):
            out.append((r.field, affine(
                fresh(f"{op.name}.d_{r.field}"),
                gbar, {zero_o: -float(scale) * s},
            )))
        return out

    zero = (0,) * ndim
    reads = (Read(base, zero),) + tuple(Read(f, zero) for f, _ in terms)
    tag = (
        f"scaled_residual:scale={float(scale)!r},signs="
        + ",".join(str(s) for _, s in terms)
    )
    return StencilOp(
        name, reads, compute, OpCost(macs=1, other_ops=len(terms)), tag=tag,
        vjp=rule,
    )
