"""Derived adjoint stencils: autodiff as a graph transform on the IR.

The adjoint of a stencil is another stencil with transposed access offsets:
a read of field ``f`` at offset ``o`` contributes to ``f``'s cotangent at
``-o``. :func:`adjoint` derives that program from a
:class:`~repro.ir.graph.StencilProgram`'s DAG — reverse the op chain,
negate every linear tap, and linearize the nonlinear combinators (flux
limiters, products) around the saved primal — so the backward pass of every
lowering is ITSELF an IR program: it goes through ``lower_pallas`` as its
own fused kernel and through ``lower_sharded`` with the same
``exchange_radii()``-driven halo exchange as the forward sweep (the
adjoint's radii equal the primal's for the whole combinator roster, so the
same wire model applies).

Structure of ``adjoint(p)`` for a single sweep ``p``:

  * inputs — one ``g~f`` cotangent seed per output field, every primal
    input, one ``c~op`` SAVED-VALUE slot per primal intermediate the
    linearization needs (recomputing e.g. a Laplacian inside the adjoint
    DAG would compose its taps onto every consumer footprint and widen the
    adjoint past the primal's radius; reading the saved value — produced by
    :func:`augmented_forward` — keeps every adjoint access a mirrored
    primal access, so adjoint radii EQUAL primal radii), and one ``d~c``
    running-cotangent accumulator per non-evolving input;
  * ops — walking the primal DAG in reverse, a cotangent-sum per primal op
    followed by the op's per-read adjoint terms (the
    :attr:`~repro.ir.graph.StencilOp.vjp` rule, or the generic
    ``jax.vjp``-per-point fallback for custom ops);
  * outputs — ``{g~f: ...}`` (the cotangent of each evolving input) and
    ``{d~c: d~c + contributions}`` (aux cotangents accumulate across
    sweeps), so the adjoint of a composed chain is the reversed chain of
    per-sweep adjoints and composes through the ordinary
    :meth:`~repro.ir.graph.StencilProgram.compose` threading convention.

Boundary exactness (``jax.grad`` of ``lower_reference`` is the contract):
a full-shape application computes the square radius-``r`` interior and
passes the ring through, so the true input cotangent is ``ring_mask * g +
f^T(interior_mask * g)`` — and ``f^T`` must be evaluated AT ring points
too, with zero extension beyond the grid. Two equivalent evaluation
strategies provide that extension:

  * single-device (``build``): mask the ring of the output cotangent,
    zero-PAD every adjoint input by the radius per side, run the standard
    ring-semantics lowering of the adjoint program on the padded grid,
    CROP back, add the ring passthrough term. Any pad >= r is exact —
    padded points only ever multiply masked-zero cotangents.
  * sharded (``build_zero``): lower the adjoint with
    ``lower_sharded(..., boundary="zero")``, which computes every owned
    point with zero extension DIRECTLY from the exchanged block — the
    zero bands ``ppermute`` already delivers at uncovered grid edges.
    No pad, no crop: global padding would migrate shard boundaries and
    GSPMD inserts its own collective-permutes for that, breaking the
    measured-exact wire model. The backward's only collectives are the
    modeled halo exchanges (ring masks are elementwise iota compares).

Temporal blocking reverses sweep by sweep: the forward pass saves only the
INPUT arrays, the backward recomputes the k-1 intermediate states with the
per-sweep forward lowerings, then runs the k adjoint sweeps in reverse —
all through the same backend the caller picked
(``build_backend(..., differentiable=True)``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Mapping

import jax
import jax.numpy as jnp

from repro.ir.evaluate import resolve_field_arrays
from repro.ir.graph import Read, StencilOp, StencilProgram
from repro.ir.ops import _neg, _sub, _tree_sum, affine
from repro.ir.graph import OpCost

Array = jax.Array

#: Prefixes of the derived cotangent/cache fields. "~" cannot appear in any
#: name the combinator builders or compose() mint, so collisions with
#: primal fields are impossible unless a user names a field "g~..."
#: themselves (rejected in adjoint()).
_SEED = "g~"
_ACC = "d~"
_CACHE = "c~"


def seed_field(field: str) -> str:
    """The adjoint program's input holding ``field``'s output cotangent
    (and its output holding ``field``'s input cotangent)."""
    return _SEED + field


def acc_field(field: str) -> str:
    """The adjoint program's running-cotangent accumulator for a
    non-evolving input ``field``."""
    return _ACC + field


def cache_field(op_name: str) -> str:
    """The adjoint program's input holding primal op ``op_name``'s saved
    value (the linearization point of the nonlinear combinators)."""
    return _CACHE + op_name


def cache_fields(program: StencilProgram) -> tuple[str, ...]:
    """Primal op names whose values single-sweep ``program``'s adjoint
    linearizes around — the fields :func:`augmented_forward` must save."""
    return tuple(
        f[len(_CACHE):]
        for f in adjoint(program).inputs
        if f.startswith(_CACHE)
    )


@functools.lru_cache(maxsize=None)
def augmented_forward(program: StencilProgram) -> StencilProgram:
    """Single-sweep ``program`` with its adjoint's linearization caches as
    EXTRA OUTPUTS (``c~op``): the same op DAG, same per-input radii, same
    halo exchange — the cache slots are declared as zero-read dummy inputs
    purely to give the extra outputs a base ring. Returns ``program``
    itself when the adjoint is linear (nothing to cache)."""
    caches = cache_fields(program)
    if not caches:
        return program
    inputs = list(program.inputs)
    outputs = dict(program.outputs)
    for n in caches:
        inputs.append(cache_field(n))
        outputs[cache_field(n)] = n
    return StencilProgram(
        f"{program.name}.aug",
        inputs,
        program.ops,
        ndim=program.ndim,
        passthrough=program.passthrough,
        outputs=outputs,
    )


def _sum_fields(name: str, fields, zero) -> StencilOp:
    """Offset-0 sum of cotangent contribution fields (balanced pairwise,
    like every combinator). Its own adjoint is the identity fan-out."""
    reads = tuple(Read(f, zero) for f in fields)

    def compute(*views):
        return _tree_sum(views)

    def rule(op, gbar, fresh):
        return [(r.field, gbar) for r in op.reads]

    return StencilOp(
        name, reads, compute, OpCost(other_ops=max(len(reads) - 1, 0)),
        tag="adj:sum", vjp=rule,
    )


def _generic_rule(op: StencilOp, gbar: str, fresh) -> list:
    """Fallback adjoint rule for ops without an explicit
    :attr:`~repro.ir.graph.StencilOp.vjp`: one term per read, evaluating
    ``jax.vjp`` of the op's elementwise compute at the consumer position
    (offset ``-o_j``), with every primal read re-aligned to ``o_i - o_j``.
    Always correct for elementwise combinators; reads every primal field of
    the op, so footprints are looser than the explicit rules'."""
    out = []
    for j, rj in enumerate(op.reads):
        reads = (Read(gbar, _neg(rj.offset)),) + tuple(
            Read(r.field, _sub(r.offset, rj.offset)) for r in op.reads
        )

        def term(g, *views, _j=j, _f=op.compute):
            _, pullback = jax.vjp(lambda *vs: _f(*vs), *views)
            return pullback(g)[_j]

        out.append((rj.field, StencilOp(
            fresh(f"{op.name}.d{j}"), reads, term, op.cost,
            tag=f"adj:generic:{j}:{op.tag or op.name}",
        )))
    return out


def _adjoint_single(p: StencilProgram) -> StencilProgram:
    nd = p.ndim
    zero = (0,) * nd
    aux = tuple(f for f in p.inputs if f not in p.outputs)
    seeds = {f: seed_field(f) for f in p.outputs}
    accs = {c: acc_field(c) for c in aux}
    taken = set(p.inputs) | {op.name for op in p.ops}
    minted = list(seeds.values()) + list(accs.values())
    clash = [n for n in minted if n in taken]
    if clash or len(set(minted)) != len(minted):
        raise ValueError(
            f"program {p.name!r} has fields colliding with the adjoint "
            f"name mangling: {clash or minted}"
        )
    inputs = (
        [seeds[f] for f in p.outputs] + list(p.inputs) + [accs[c] for c in aux]
    )

    used = set(inputs) | {op.name for op in p.ops}

    def fresh(base: str) -> str:
        n, i = base, 0
        while n in used:
            i += 1
            n = f"{base}~{i}"
        used.add(n)
        return n

    seed_of_op = {op_name: seeds[f] for f, op_name in p.outputs.items()}
    contribs: dict[str, list[str]] = {}
    adj_ops: list[StencilOp] = []

    def add(field: str | None, term) -> None:
        # A rule may emit (None, op) helpers — ops shared by later terms in
        # the same rule (e.g. a flux gate) that contribute to no field
        # directly. Strings contribute an EXISTING field at offset zero.
        if field is None:
            adj_ops.append(term)
        elif isinstance(term, str):
            contribs.setdefault(field, []).append(term)
        else:
            adj_ops.append(term)
            contribs.setdefault(field, []).append(term.name)

    # Reverse sweep over the primal DAG: when op X is visited, every
    # consumer of X was already processed, so X's full output cotangent is
    # the sum of the terms they emitted (plus the seed if X is an output).
    for op in reversed(p.ops):
        cs: list[str] = []
        if op.name in seed_of_op:
            cs.append(seed_of_op[op.name])
        cs.extend(contribs.get(op.name, ()))
        if not cs:
            continue  # op does not influence any output: no adjoint work
        if len(cs) == 1:
            gbar = cs[0]
        else:
            sop = _sum_fields(fresh(f"{op.name}.gsum"), cs, zero)
            adj_ops.append(sop)
            gbar = sop.name
        rule = op.vjp if op.vjp is not None else _generic_rule
        for field, term in rule(op, gbar, fresh):
            add(field, term)

    out_ops: list[StencilOp] = []
    outputs: dict[str, str] = {}
    for f in p.outputs:
        cs = contribs.get(f, [])
        name = fresh(f"{f}.dsum")
        if cs:
            out_ops.append(_sum_fields(name, cs, zero))
        else:  # output never differentiably reads this state: zero cotangent
            out_ops.append(affine(name, seeds[f], {zero: 0.0}))
        outputs[seeds[f]] = name
    for c in aux:
        name = fresh(f"{c}.dsum")
        out_ops.append(_sum_fields(name, [accs[c]] + contribs.get(c, []), zero))
        outputs[accs[c]] = name

    # Primal intermediates the linearization needs become CACHE INPUTS
    # (``c~op``), not recompute ops: recomputing e.g. a Laplacian inside the
    # adjoint DAG would compose its taps onto every consumer footprint and
    # widen the adjoint's radius past the primal's, while reading the saved
    # value keeps every adjoint access a mirrored primal access — adjoint
    # radii equal primal radii, field by field. :func:`augmented_forward`
    # is the program that produces these caches.
    adj_all = adj_ops + out_ops
    primal_order = {op.name: i for i, op in enumerate(p.ops)}
    roots = sorted(
        {
            r.field
            for op in adj_all
            for r in op.reads
            if r.field in primal_order
        },
        key=primal_order.__getitem__,
    )
    rename = {n: cache_field(n) for n in roots}
    adj_all = [
        dataclasses.replace(
            op,
            reads=tuple(
                Read(rename.get(r.field, r.field), r.offset) for r in op.reads
            ),
        )
        if any(r.field in rename for r in op.reads)
        else op
        for op in adj_all
    ]
    inputs = (
        [seeds[f] for f in p.outputs]
        + list(p.inputs)
        + [rename[n] for n in roots]
        + [accs[c] for c in aux]
    )

    return StencilProgram(
        f"{p.name}.adj",
        inputs,
        adj_all,
        ndim=nd,
        passthrough=seeds[p.passthrough],
        outputs=outputs,
    )


@functools.lru_cache(maxsize=None)
def adjoint(program: StencilProgram) -> StencilProgram:
    """The adjoint IR program of ``program``.

    For a single sweep this is the transposed-offset reverse DAG (see the
    module docstring). For a composed chain it is the REVERSED chain of
    per-sweep adjoints (``adjoint(p_k) >> ... >> adjoint(p_1)``), composed
    through the ordinary threading convention: the cotangent seeds and aux
    accumulators evolve sweep to sweep while the primal inputs are shared.
    The composed object carries the chain's exact radii/footprints/wire
    accounting; numerically each reverse sweep must be linearized at ITS
    OWN primal state, which :func:`make_vjp` feeds per sweep (heterogeneous
    chains whose sweeps declare different aux inputs cannot compose and
    raise — differentiate them through :func:`make_vjp`, which never builds
    the composed object)."""
    if program.steps == 1:
        return _adjoint_single(program)
    parts = [adjoint(q) for q in reversed(program.chain)]
    acc = parts[0]
    for i, q in enumerate(parts[1:]):
        name = f"{program.name}.adj" if i == len(parts) - 2 else None
        acc = acc.compose(q, name=name)
    return acc


def pad_widths(
    program: StencilProgram,
    grid: tuple[int, ...],
) -> tuple[tuple[int, int], ...]:
    """Per-trailing-dim ``(lo, hi)`` zero-pad for one SINGLE-DEVICE
    backward sweep of single-sweep ``program`` on ``grid``.

    The exact requirement is ``pad >= max(radius, adjoint radius)`` per
    side, so the whole original grid (ring included) lands in the padded
    evaluation's computed interior; any LARGER pad is equally exact (padded
    points only ever multiply masked-zero cotangents). The sharded backward
    never pads — it lowers with ``boundary="zero"`` instead (see the module
    docstring)."""
    pr = max(program.radius, adjoint(program).radius)
    return tuple((pr, pr) for _ in grid)


def _interior_mask(shape: tuple[int, ...], r: int) -> Array:
    """Boolean mask of ``shape`` that is True on the radius-``r`` interior.
    Built from elementwise iota compares so it stays shard-local under
    GSPMD (a slice-and-scatter formulation reshards on sharded dims)."""
    ok = None
    for d, s in enumerate(shape):
        i = jax.lax.broadcasted_iota(jnp.int32, shape, d)
        c = (i >= r) & (i < s - r)
        ok = c if ok is None else ok & c
    return ok


def _mask_interior(g: Array, r: int, nd: int) -> Array:
    """Zeroes the square radius-``r`` boundary ring of ``g``."""
    if r == 0:
        return g
    m = _interior_mask(g.shape[-nd:], r)
    return jnp.where(m, g, jnp.zeros_like(g))


def _ring_swap(prev: Array, new: Array, r: int, nd: int) -> Array:
    """``new`` on the radius-``r`` interior, ``prev`` on the ring — the
    full-shape sweep convention, reconstructed elementwise."""
    if r == 0:
        return new
    m = _interior_mask(new.shape[-nd:], r)
    return jnp.where(m, new, prev)


def _pad(a: Array, pads, nd: int) -> Array:
    if all(lo == 0 and hi == 0 for lo, hi in pads):
        return a
    return jnp.pad(a, [(0, 0)] * (a.ndim - nd) + list(pads))


def _crop(a: Array, pads, nd: int, grid) -> Array:
    idx = (Ellipsis,) + tuple(
        slice(lo, lo + s) for (lo, _hi), s in zip(pads, grid)
    )
    return a[idx]


def _apply_sweep(q: StencilProgram, step, state, shared):
    """One forward chain entry, mirroring ``thread_chain``'s convention."""
    if isinstance(state, Mapping):
        sub = {f: shared[f] for f in q.inputs if f not in q.outputs}
        sub.update(state)
        return dict(step(sub))
    if len(q.inputs) == 1:
        return step(state)
    sub = {f: shared[f] for f in q.inputs if f != q.passthrough}
    sub[q.passthrough] = state
    return step(sub)


def _sweep_bwd(q, adj_fn, state, shared, gbar, acc, cache, zero):
    """One reverse sweep: mask ring, run the lowered adjoint, re-add the
    ring passthrough. ``state``/``gbar`` are ``{field: array}`` over
    ``q.outputs``; ``acc`` holds the running aux cotangents; ``cache`` maps
    primal op name -> saved value in the sweep's layout (or None). With
    ``zero=False`` (single-device) every adjoint input is zero-padded and
    the result cropped; with ``zero=True`` ``adj_fn`` is a
    ``boundary="zero"`` sharded lowering and arrays pass through unpadded
    (no reshard-inducing pad/crop — see the module docstring)."""
    nd = q.ndim
    r = q.radius
    adj = adjoint(q)
    grid = next(iter(gbar.values())).shape[-nd:]
    pads = None if zero else pad_widths(q, grid)

    def lift(a):
        return a if zero else _pad(a, pads, nd)

    def unlift(a):
        return a if zero else _crop(a, pads, nd, grid)

    g_int = {f: _mask_interior(g, r, nd) for f, g in gbar.items()}
    args = {}
    for f in q.outputs:
        args[seed_field(f)] = lift(g_int[f])
        args[f] = lift(state[f])
    q_aux = tuple(f for f in q.inputs if f not in q.outputs)
    for c in q_aux:
        args[c] = lift(shared[c])
        args[acc_field(c)] = lift(acc[c])
    if cache:
        for n, a in cache.items():
            args[cache_field(n)] = a
    res = adj_fn(args if len(adj.inputs) > 1 else args[adj.inputs[0]])
    if not isinstance(res, Mapping):
        res = {adj.passthrough: res}
    new_g = {
        f: unlift(res[seed_field(f)]) + (gbar[f] - g_int[f])
        for f in q.outputs
    }
    new_acc = dict(acc)
    for c in q_aux:
        new_acc[c] = unlift(res[acc_field(c)])
    return new_g, new_acc


def make_vjp(
    program: StencilProgram,
    build: Callable[[StencilProgram], Callable],
    *,
    build_zero: Callable[[StencilProgram], Callable] | None = None,
) -> Callable:
    """``(x, g) -> input cotangents`` for ``program``, with every sweep —
    forward recompute and reverse adjoint — lowered through ``build`` (a
    ``StencilProgram -> callable`` factory, e.g. a ``build_backend``
    partial). The cotangent pytree mirrors ``x``: bare array in, bare array
    out; ``{field: array}`` in, a cotangent per declared input out.

    ``build_zero`` switches the adjoint/augmented sweeps to zero-boundary
    evaluation on the UNPADDED grid: pass a ``lower_sharded(...,
    boundary="zero")`` factory for sharded backends (pad/crop on sharded
    dims would migrate shard boundaries through GSPMD's own collectives);
    leave it ``None`` for single-device backends, which emulate the zero
    boundary by local pad + ring-semantics lowering + crop. Forward
    state-recompute sweeps always use ``build`` (true per-sweep ring
    threading)."""
    chain = program.chain
    multi = len(program.outputs) > 1
    nd = program.ndim
    zero = build_zero is not None
    zbuild = build_zero if zero else build
    adj_fns: dict[str, Callable] = {}
    aug_fns: dict[str, Callable] = {}
    fwd_fns: dict[str, Callable] = {}
    for q in chain:
        fp = q.fingerprint()
        if fp not in adj_fns:
            adj_fns[fp] = zbuild(adjoint(q))
            if cache_fields(q):
                aug_fns[fp] = zbuild(augmented_forward(q))
    # Whether the backward needs the primal state at all: linear sweeps
    # cache nothing and their adjoints never read a primal field, so the
    # whole forward-recompute pass is skipped (the adjoint args still carry
    # a state array for signature uniformity — it is dead and exchanges no
    # halo, since its adjoint access radius is 0).
    needs_state = any(
        cache_fields(q)
        or any(
            r.field in q.inputs for op in adjoint(q).ops for r in op.reads
        )
        for q in chain
    )
    if needs_state:
        for q in chain[:-1]:
            fp = q.fingerprint()
            if fp not in aug_fns and fp not in fwd_fns:
                fwd_fns[fp] = build(q)

    def vjp_fn(x, g):
        arrays = resolve_field_arrays(program, x)
        env = dict(zip(program.inputs, arrays))
        shared = {f: env[f] for f in program.inputs if f not in program.outputs}
        if multi:
            state = {f: env[f] for f in program.outputs}
        else:
            state = env[program.passthrough]
        # Forward: thread the chain, saving the (unpadded) entry state of
        # every sweep plus the linearization caches. Sweeps with caches run
        # the augmented forward in the SAME layout the adjoint consumes —
        # zero-boundary on the unpadded grid (sharded), or ring-semantics
        # on the locally padded grid (single-device) — and recover the next
        # true state by swapping the computed interior into the entry
        # state's ring (identical to the plain sweep: the full true
        # interior lands in the evaluation's computed region either way).
        states, caches = [], []
        for i, q in enumerate(chain):
            states.append(state)
            fp = q.fingerprint()
            cf = cache_fields(q)
            if needs_state and cf:
                sd = state if multi else {q.passthrough: state}
                grid = next(iter(sd.values())).shape[-nd:]
                pads = None if zero else pad_widths(q, grid)

                def lift(a):
                    return a if zero else _pad(a, pads, nd)

                args = {f: lift(sd[f]) for f in q.outputs}
                for c in q.inputs:
                    if c not in q.outputs:
                        args[c] = lift(shared[c])
                for n in cf:
                    args[cache_field(n)] = jnp.zeros_like(
                        args[q.passthrough]
                    )
                out = aug_fns[fp](args)
                caches.append({n: out[cache_field(n)] for n in cf})
                if i < len(chain) - 1:
                    new = {}
                    for f in q.outputs:
                        swept = (
                            out[f] if zero else _crop(out[f], pads, nd, grid)
                        )
                        new[f] = _ring_swap(sd[f], swept, q.radius, nd)
                    state = new if multi else new[q.passthrough]
            else:
                caches.append(None)
                if needs_state and i < len(chain) - 1:
                    state = _apply_sweep(q, fwd_fns[fp], state, shared)
        gbar = dict(g) if multi else g
        acc = {c: jnp.zeros_like(a) for c, a in shared.items()}
        for i in range(len(chain) - 1, -1, -1):
            q = chain[i]
            st = states[i]
            g_d, acc = _sweep_bwd(
                q,
                adj_fns[q.fingerprint()],
                st if multi else {q.passthrough: st},
                shared,
                gbar if multi else {q.passthrough: gbar},
                acc,
                caches[i],
                zero,
            )
            gbar = g_d if multi else g_d[q.passthrough]
        if isinstance(x, Mapping):
            out = {}
            for f in program.inputs:
                if f in program.outputs:
                    out[f] = gbar[f] if multi else gbar
                else:
                    out[f] = acc[f]
            return out
        return gbar

    return vjp_fn


def differentiable_lowering(
    program: StencilProgram,
    fwd_fn: Callable,
    build: Callable[[StencilProgram], Callable],
    *,
    build_zero: Callable[[StencilProgram], Callable] | None = None,
) -> Callable:
    """Attaches the derived adjoint as a ``jax.custom_vjp`` to a lowered
    forward callable. The primal path is ``fwd_fn`` unchanged (and is also
    the residual-free custom_vjp forward — only the input arrays are
    saved); the backward is :func:`make_vjp` through the same backend
    (``build_zero`` as in :func:`make_vjp`: the sharded backends' adjoint
    factory)."""
    vjp_fn = make_vjp(program, build, build_zero=build_zero)

    @jax.custom_vjp
    def fn(x):
        return fwd_fn(x)

    def fwd(x):
        return fwd_fn(x), x

    def bwd(res, g):
        return (vjp_fn(res, g),)

    fn.defvjp(fwd, bwd)
    return fn
