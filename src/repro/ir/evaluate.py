"""Shared program evaluator: the one place offset arithmetic becomes slices.

``interior_eval`` computes a program's output on its maximal valid interior
by materialising each field on its own margin-inset region and feeding each
op aligned shifted views — all slice bounds are static Python ints, so the
same evaluator runs under ``jit``, inside a Pallas kernel body, and inside a
``shard_map`` shard. ``apply_program`` re-embeds the interior into the
full-shape grid with the paper's boundary passthrough.
"""

from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.ir.graph import StencilProgram

Array = jax.Array


def _window(arr: Array, starts, sizes) -> Array:
    idx = (Ellipsis,) + tuple(slice(s, s + z) for s, z in zip(starts, sizes))
    return arr[idx]


def resolve_field_arrays(program: StencilProgram, x, *, ndim: int | None = None):
    """Validates a lowering input and returns one array per declared field,
    in ``program.inputs`` order — the single home of the field-mapping
    contract every backend shares.

    ``x`` is a bare array (single-input programs only) or a
    ``{field: array}`` mapping covering every declared input; all arrays
    must share one grid, and ``ndim`` (when given) pins the expected array
    rank (e.g. 3 for the ``(depth, rows, cols)`` kernels).
    """
    fields = program.inputs
    if isinstance(x, Mapping):
        missing = [f for f in fields if f not in x]
        if missing:
            raise ValueError(
                f"program {program.name!r} field mapping is missing "
                f"input(s) {missing}; declared inputs are {list(fields)}"
            )
        arrays = tuple(x[f] for f in fields)
    else:
        if len(fields) != 1:
            raise ValueError(
                f"program {program.name!r} has inputs {fields}; pass a mapping"
            )
        arrays = (x,)
    for f, a in zip(fields, arrays):
        if ndim is not None and a.ndim != ndim:
            raise ValueError(
                f"expected {'(depth, rows, cols)' if ndim == 3 else f'{ndim}-D'} "
                f"for field {f!r}, got shape {a.shape}"
            )
        if a.shape != arrays[0].shape:
            raise ValueError(
                f"all input fields must share one grid; {f!r} has shape "
                f"{a.shape} vs {fields[0]!r} {arrays[0].shape}"
            )
    return arrays


def thread_chain(program: StencilProgram, x, steps):
    """Runs a composed program's per-sweep callables with the shared-field
    threading convention: the evolving (:attr:`~repro.ir.graph
    .StencilProgram.outputs`) fields evolve sweep-to-sweep, every other
    input feeds each sweep unchanged. ``steps`` pairs each chain entry with
    its executor: ``[(sub_program, callable), ...]``.

    Single-output programs thread one array (and return one array, the
    legacy contract); multi-output programs thread the ``{field: array}``
    state dict — each sweep's executor receives shared fields plus the
    current states and must return the updated ``{field: array}`` dict
    (outputs bind by field name, the compose convention).

    The one home of the convention — ``apply_program`` and the staged
    reference lowering both run through here, so their error behaviour and
    semantics cannot drift apart.
    """
    arrays = resolve_field_arrays(program, x)
    shared = dict(zip(program.inputs, arrays))
    if len(program.outputs) > 1:
        states = {f: shared[f] for f in program.outputs}
        for p, step in steps:
            sub = {f: shared[f] for f in p.inputs if f not in p.outputs}
            sub.update(states)
            states = dict(step(sub))
        return states
    arr = shared[program.passthrough] if isinstance(x, Mapping) else arrays[0]
    for p, step in steps:
        if len(p.inputs) == 1:
            arr = step(arr)
        else:
            sub = {f: shared[f] for f in p.inputs if f != p.passthrough}
            sub[p.passthrough] = arr
            arr = step(sub)
    return arr


def op_views(op, env: Mapping[str, Array], margins, grid: tuple[int, ...], nd: int):
    """Aligned shifted views for one op — the single home of the
    margin/offset-to-slice arithmetic (used by every evaluator/lowering).

    ``env`` maps each read field to its materialised array (inset by that
    field's margins); ``grid`` is the source-grid extent of the trailing
    ``nd`` dims. Returns one view per declared read, all of the op's output
    shape.
    """
    lo_out, hi_out = margins[op.name]
    sizes = tuple(grid[d] - lo_out[d] - hi_out[d] for d in range(nd))
    if any(s <= 0 for s in sizes):
        raise ValueError(
            f"grid {grid} too small for program margins lo={lo_out} hi={hi_out}"
        )
    views = []
    for read in op.reads:
        in_lo, _ = margins[read.field]
        starts = tuple(lo_out[d] + read.offset[d] - in_lo[d] for d in range(nd))
        views.append(_window(env[read.field], starts, sizes))
    return views


def interior_eval_multi(
    program: StencilProgram, arrays: Mapping[str, Array]
) -> dict[str, Array]:
    """Evaluates ``program`` over source fields given on a common grid.

    ``arrays`` maps each program input to an array whose trailing ``ndim``
    dims are the grid (leading dims are batch). Returns every output field's
    interior in one DAG evaluation — ``{field: array}`` with each array on
    that OUTPUT's own maximal valid region (trailing dims shrink by its
    producing op's (lo + hi) margins, which differ per output when the
    coupled equations have different depths)."""
    nd = program.ndim
    for f in program.inputs:
        if f not in arrays:
            raise ValueError(f"missing input field {f!r}")
    grid = arrays[program.inputs[0]].shape[-nd:]
    margins = program.margins()

    env: dict[str, Array] = dict(arrays)
    for op in program.ops:
        # Per-op named_scope: XLA/Perfetto traces (repro.obs.profile) carry
        # stencil-op names instead of anonymous fusions. Trace-time only —
        # zero runtime cost and no effect on the compiled computation.
        with jax.named_scope(f"ir/{program.name}/{op.name}"):
            env[op.name] = op.compute(*op_views(op, env, margins, grid, nd))
    return {f: env[op_name] for f, op_name in program.outputs.items()}


def interior_eval(program: StencilProgram, arrays: Mapping[str, Array]) -> Array:
    """The :attr:`~repro.ir.graph.StencilProgram.passthrough` output's
    interior — the single-output view of :func:`interior_eval_multi` (the
    whole DAG is still evaluated once)."""
    return interior_eval_multi(program, arrays)[program.passthrough]


def interior_region(program: StencilProgram, grid: tuple[int, ...]) -> tuple[slice, ...]:
    """Trailing-dim slices selecting the program's interior of a full grid.

    Per the paper's convention the boundary ring is *square*: width
    ``program.radius`` in every dim (e.g. jacobi2d_3pt reads no column
    neighbours but still passes a 1-wide column ring through), matching the
    hand-written kernels in ``repro.core``.
    """
    r = program.radius
    return tuple(slice(r, grid[d] - r) for d in range(program.ndim))


def ring_crop(program: StencilProgram, interior: Array, *, output: str | None = None) -> Array:
    """Crops an exact-margin interior (as produced by :func:`interior_eval`
    / :func:`interior_eval_multi`) to the square radius-``r`` ring region.
    The ring region is contained in the valid region (``r >= margin`` per
    dim/side by construction — ``r`` is the program-wide max). ``output``
    names which output field's interior is being cropped (its own margins
    set the alignment); defaults to the passthrough output."""
    r = program.radius
    lo, hi = program.output_margins(output or program.passthrough)
    nd = program.ndim
    idx = []
    for d in range(nd):
        size = interior.shape[-nd + d] - (r - lo[d]) - (r - hi[d])
        idx.append(slice(r - lo[d], r - lo[d] + size))
    return interior[(Ellipsis,) + tuple(idx)]


def slab_step(
    program: StencilProgram,
    slab: Array | Mapping[str, Array],
    row_ids: Array,
    rows_total,
    col_ids: Array | None = None,
    cols_total=None,
    extras: Mapping[str, Array] | None = None,
):
    """One sweep of a (single-sweep) program over a slab — the per-step body
    of every temporal-blocked lowering.

    ``slab`` carries the program's *evolving* state: a bare ``(..., n, m)``
    array for the :attr:`~repro.ir.graph.StencilProgram.passthrough` field,
    or a ``{field: array}`` dict covering every
    :attr:`~repro.ir.graph.StencilProgram.outputs` field (the coupled-system
    form — all on one grid). The return mirrors the input: bare array in,
    bare array out; dict in, dict out (one updated slab per evolving field).
    ``row_ids`` gives the GLOBAL row index of each of the ``n - 2r`` rows
    produced, shaped ``(n - 2r,)`` or ``(n - 2r, 1)``. Rows whose global
    index falls in the radius-``r`` boundary ring keep each slab's current
    value (the per-sweep passthrough that makes k fused sweeps bit-match k
    full-shape applications); ``r = program.radius`` is shared by all
    evolving fields so the slabs stay grid-aligned through a chain.

    ``extras`` supplies the program's non-evolving input fields (diffusion
    coefficients, velocities), each on the SAME grid as ``slab``. They are
    read, never written: the boundary ring applies to the evolving fields
    only, and extras pass between sweeps unchanged (``slab_sweep`` slices
    them to follow the shrinking state slabs).

    Columns come in two modes, mirroring how the caller decomposed them:

      * ``col_ids is None`` — full-width mode: the slab carries the whole
        global column extent, so the radius-``r`` column ring is local
        (first/last ``r`` columns kept in place). Returns
        ``(..., n - 2r, m)`` — only rows shrink.
      * ``col_ids`` given (``(m - 2r,)`` or ``(1, m - 2r)``, with
        ``cols_total``) — column-slab mode for 2-D domain decomposition:
        the slab carries a column halo too, the slab shrinks by ``r`` in
        BOTH dims, and the global column ring is applied by absolute column
        index exactly like rows. Returns ``(..., n - 2r, m - 2r)``.
    """
    r = program.radius
    is_multi = isinstance(slab, Mapping)
    if is_multi:
        missing = [f for f in program.outputs if f not in slab]
        if missing:
            raise ValueError(
                f"slab dict is missing evolving field(s) {missing} of "
                f"program {program.name!r} (outputs: {tuple(program.outputs)})"
            )
        states = {f: slab[f] for f in program.outputs}
    else:
        states = {program.passthrough: slab}
    # States LAST, like thread_chain: a chain entry's evolving-field name may
    # collide with a composed program's shared field (compose renames the
    # merged DAG but the chain keeps original names), and the evolving slabs
    # must win that collision or the sweep runs on the wrong array.
    arrays = dict(extras) if extras else {}
    arrays.update(states)
    interiors = interior_eval_multi(program, arrays)
    vals = {
        f: ring_crop(program, interiors[f], output=f) for f in program.outputs
    }
    if r == 0:
        out = {f: vals[f].astype(states[f].dtype) for f in states}
        return out if is_multi else out[program.passthrough]
    keep_r = (row_ids < r) | (row_ids >= rows_total - r)
    if keep_r.ndim == 1:
        keep_r = keep_r[:, None]
    if col_ids is None:
        out = {}
        for f, s in states.items():
            cols = s.shape[-1]
            cur = s[..., r:-r, :]
            upd = cur.at[..., :, r : cols - r].set(vals[f].astype(s.dtype))
            out[f] = jnp.where(keep_r, cur, upd)
        return out if is_multi else out[program.passthrough]
    keep_c = (col_ids < r) | (col_ids >= cols_total - r)
    if keep_c.ndim == 1:
        keep_c = keep_c[None, :]
    out = {}
    for f, s in states.items():
        cur = s[..., r:-r, r:-r]
        out[f] = jnp.where(keep_r | keep_c, cur, vals[f].astype(s.dtype))
    return out if is_multi else out[program.passthrough]


def _any_state(slab):
    """One representative array of an Array-or-``{field: Array}`` slab (all
    evolving slabs share one grid, so any leaf carries the shape)."""
    return next(iter(slab.values())) if isinstance(slab, Mapping) else slab


def slab_sweep(
    program: StencilProgram,
    slab: Array | Mapping[str, Array],
    row_offset,
    rows_total,
    col_offset=None,
    cols_total=None,
    extras: Mapping[str, Array] | None = None,
):
    """Runs ``program``'s whole chain over ``slab`` via :func:`slab_step`.

    ``slab`` is a bare array (single-output programs) or the
    ``{field: array}`` evolving-state dict (multi-output programs — the
    chain threads the whole dict, each sweep's outputs feeding the matching
    evolving fields of the next by name). ``row_offset`` is the global row
    index of the slabs' first row (may be a traced scalar, e.g. derived
    from ``axis_index`` inside a shard). The slabs must carry the full
    chain halo: output has ``2 * program.radius`` fewer rows than the
    input. With ``col_offset`` / ``cols_total`` given the slab is
    column-decomposed too (2-D domain decomposition): columns shrink and
    ring-pass-through by ABSOLUTE index exactly like rows.

    ``extras`` maps the program's non-evolving inputs to slabs on the SAME
    initial grid as ``slab`` (values only needed within each field's
    composed radius of the kept region — callers zero-pad the rest). They
    are constant across sweeps; each sweep reads them through a view inset
    by the state's cumulative shrink so all fields stay grid-aligned.
    """
    base_r = row_offset
    base_c = col_offset
    n0 = _any_state(slab).shape[-2]
    m0 = _any_state(slab).shape[-1]
    inset = 0  # cumulative state shrink vs the extras' (initial) grid
    for sweep_i, prog in enumerate(program.chain):
        # Per-sweep named_scope: temporal-blocked traces show which of the
        # k fused sweeps a fusion belongs to (trace-time metadata only).
        with jax.named_scope(f"ir/{program.name}/sweep{sweep_i}"):
            r = prog.radius
            n = _any_state(slab).shape[-2]
            ex = None
            if extras:
                if col_offset is None:
                    ex = {f: a[..., inset : n0 - inset, :] for f, a in extras.items()}
                else:
                    ex = {
                        f: a[..., inset : n0 - inset, inset : m0 - inset]
                        for f, a in extras.items()
                    }
            # 2-D iota: 1-D iota is unsupported by the TPU Mosaic lowering.
            ids = base_r + r + jax.lax.broadcasted_iota(jnp.int32, (n - 2 * r, 1), 0)
            if col_offset is None:
                slab = slab_step(prog, slab, ids, rows_total, extras=ex)
            else:
                m = _any_state(slab).shape[-1]
                cids = base_c + r + jax.lax.broadcasted_iota(
                    jnp.int32, (1, m - 2 * r), 1
                )
                slab = slab_step(prog, slab, ids, rows_total, cids, cols_total, extras=ex)
                base_c = base_c + r
            base_r = base_r + r
            inset += r
    return slab


def apply_program(program: StencilProgram, x: Array | Mapping[str, Array]):
    """Full-shape application: interior computed, boundary ring passed
    through from each evolving source field (matches the hand-written
    kernels' contract). Single-output programs return one array;
    multi-output programs return ``{field: array}`` — one full-shape updated
    state per :attr:`~repro.ir.graph.StencilProgram.outputs` field, each
    with ITS OWN boundary ring passed through (the uniform square radius-r
    ring). A composed program applies its chain sweep by sweep, re-applying
    the ring passthrough between sweeps — the oracle semantics of
    ``repeat(p, k)``. For a multi-field chain the evolving fields advance
    while the shared inputs (coefficients, velocities) feed every sweep
    unchanged."""
    if program.steps > 1:
        return thread_chain(
            program, x, [(p, functools.partial(apply_program, p)) for p in program.chain]
        )
    if isinstance(x, Mapping):
        arrays = dict(x)
    else:
        if len(program.inputs) != 1:
            raise ValueError(
                f"program {program.name!r} has inputs {program.inputs}; pass a mapping"
            )
        arrays = {program.inputs[0]: x}
    interiors = interior_eval_multi(program, arrays)
    if len(program.outputs) > 1:
        return {
            f: embed_interior(program, arrays[f], interiors[f], output=f)
            for f in program.outputs
        }
    base = arrays[program.passthrough]
    return embed_interior(program, base, interiors[program.passthrough])


def embed_interior(
    program: StencilProgram, base: Array, interior: Array, *, output: str | None = None
) -> Array:
    """Embeds an exact-margin interior into ``base`` with the square-ring
    boundary passthrough — the single home of the embedding convention.
    ``output`` names which output field's interior this is (its margins set
    the crop alignment; the embedded region is the shared radius-r square)."""
    cropped = ring_crop(program, interior, output=output)
    region = interior_region(program, base.shape[-program.ndim :])
    return base.at[(Ellipsis,) + region].set(cropped.astype(base.dtype))
