"""Stencil program IR: a dataflow DAG of stencil ops with offset analysis.

This is the repo's analogue of SPARTA's MLIR dataflow lowering (§3.2-§3.4)
and StencilFlow's program graphs: a compound stencil is expressed ONCE as a
DAG of :class:`StencilOp` nodes, each declaring the *access offsets* it reads
from its input fields, and everything the hand-written paths used to hard-code
is derived from the graph:

  * **halo / radius** — forward-composed per-dimension margins
    (:meth:`StencilProgram.margins`, :meth:`StencilProgram.halo`); composed
    radii add, which the property tests check.
  * **op / byte accounting** — the paper's §3.1 streaming model
    (:meth:`StencilProgram.spec`): each op is charged once per *distinct
    composed offset* at which the output consumes it (e.g. hdiff's Laplacian
    is consumed at the 5 star offsets, hence "5 Laplacians x 5 MACs" in
    Eq. 5), and ``reads`` is the size of the program's composed access
    footprint on its source fields.
  * **per-field analysis** — every input field's composed access radius and
    footprint size derive separately (:meth:`StencilProgram.field_radii`,
    :meth:`StencilProgram.reads_by_field`) and SUM to the program totals,
    so multi-field programs (velocity + scalar advection, coefficient-field
    diffusion) get per-field halos and per-field wire accounting for free.
  * **temporal blocking** — :meth:`StencilProgram.compose` / :func:`repeat`
    fuse k sequential sweeps into one program (the §1 "pipelining different
    timesteps" insight): the merged DAG drives the analysis (radii add, so
    ``repeat(p, k).radius == k * p.radius``), while :attr:`chain` records the
    per-sweep decomposition the lowerings execute with the boundary-ring
    passthrough applied between sweeps. HBM / wire traffic per *simulated*
    step then divides by k (:meth:`fused_bytes_per_step`).

The package is self-contained: nothing under ``repro.ir`` imports other
``repro`` modules, so ``repro.core`` / ``repro.kernels`` can derive their
constants from the IR without import cycles. The lowerings to the three
execution backends live in the sibling ``lower_*`` modules.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

Offset = tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Per-evaluation cost of one op, in the paper's Eq. 5-7 accounting.

    ``macs`` counts multiply-accumulates (one per stencil tap, the Eq. 5
    convention); ``other_ops`` counts non-MAC vector ops (add/sub/cmp/select).
    Costs are attached by the combinator builders in :mod:`repro.ir.ops` —
    they are properties of the *combinator*, never of a particular program.
    """

    macs: int = 0
    other_ops: int = 0

    @property
    def flops(self) -> int:
        return 2 * self.macs + self.other_ops


@dataclasses.dataclass(frozen=True)
class Read:
    """One access: ``field`` sampled at relative grid ``offset``."""

    field: str
    offset: Offset


@dataclasses.dataclass(frozen=True)
class StencilOp:
    """One node of the DAG: produces field ``name`` from its reads.

    ``compute`` is an elementwise combinator: it receives one aligned array
    per entry of ``reads`` (all the same shape — the op's output region) and
    returns the output array. All spatial structure lives in the offsets, so
    every lowering can evaluate the op by slicing differently-shifted views.
    """

    name: str
    reads: tuple[Read, ...]
    compute: Callable[..., object]
    cost: OpCost

    def fields(self) -> tuple[str, ...]:
        """Distinct fields read, in first-read order."""
        seen: dict[str, None] = {}
        for r in self.reads:
            seen.setdefault(r.field, None)
        return tuple(seen)


@dataclasses.dataclass(frozen=True)
class ProgramSpec:
    """Graph-derived per-output-point accounting (mirrors core's StencilSpec)."""

    name: str
    macs: int
    other_ops: int
    reads: int
    radius: int
    ndim: int = 2

    @property
    def flops(self) -> int:
        return 2 * self.macs + self.other_ops


class StencilProgram:
    """An ordered DAG of :class:`StencilOp` over named fields.

    ``ops`` must be topologically ordered: each op may read only source
    ``inputs`` or earlier ops' outputs. The last op is the program output.
    ``passthrough`` names the source field whose boundary ring the lowered
    kernels carry through unchanged (the paper computes interior points only).
    """

    def __init__(
        self,
        name: str,
        inputs: Sequence[str],
        ops: Sequence[StencilOp],
        *,
        ndim: int = 2,
        passthrough: str | None = None,
    ):
        if not ops:
            raise ValueError("program needs at least one op")
        self.name = name
        self.inputs = tuple(inputs)
        self.ops = tuple(ops)
        self.ndim = ndim
        self.passthrough = passthrough if passthrough is not None else self.inputs[0]
        if self.passthrough not in self.inputs:
            raise ValueError(f"passthrough {self.passthrough!r} is not a program input")

        known = set(self.inputs)
        for op in self.ops:
            if op.name in known:
                raise ValueError(f"duplicate field name {op.name!r}")
            for read in op.reads:
                if read.field not in known:
                    raise ValueError(
                        f"op {op.name!r} reads {read.field!r} before it is defined"
                    )
                if len(read.offset) != ndim:
                    raise ValueError(
                        f"op {op.name!r} offset {read.offset} is not {ndim}-D"
                    )
            known.add(op.name)
        self.output = self.ops[-1].name

    # -- analysis: composed footprints (reverse) ------------------------------

    def footprints(self) -> dict[str, frozenset[Offset]]:
        """For every field, the set of composed offsets (relative to one
        output point) at which the output depends on it. Composition is the
        Minkowski sum of per-op offset sets along each consumer path, unioned
        over paths — StencilFlow's access-footprint inference."""
        fp: dict[str, set[Offset]] = {f: set() for f in self.inputs}
        fp.update({op.name: set() for op in self.ops})
        fp[self.output].add((0,) * self.ndim)
        for op in reversed(self.ops):
            at = fp[op.name]
            for read in op.reads:
                fp[read.field].update(
                    tuple(a + b for a, b in zip(o, read.offset)) for o in at
                )
        return {f: frozenset(s) for f, s in fp.items()}

    def evaluations(self) -> dict[str, int]:
        """Streaming-model evaluation count per op: one evaluation per
        distinct composed offset the output consumes it at (§3.1)."""
        fp = self.footprints()
        return {op.name: len(fp[op.name]) for op in self.ops}

    # -- analysis: materialisation margins (forward) --------------------------

    def margins(self) -> dict[str, tuple[Offset, Offset]]:
        """Per-field ``(lo, hi)`` margins: how far the field's valid region
        is inset from the source grid on the low/high side of each dim when
        every field is materialised on its maximal valid region."""
        m: dict[str, tuple[Offset, Offset]] = {
            f: ((0,) * self.ndim, (0,) * self.ndim) for f in self.inputs
        }
        for op in self.ops:
            lo = [0] * self.ndim
            hi = [0] * self.ndim
            for read in op.reads:
                in_lo, in_hi = m[read.field]
                for d in range(self.ndim):
                    lo[d] = max(lo[d], in_lo[d] + max(0, -read.offset[d]))
                    hi[d] = max(hi[d], in_hi[d] + max(0, read.offset[d]))
            m[op.name] = (tuple(lo), tuple(hi))
        return m

    def halo(self) -> tuple[Offset, Offset]:
        """The program's ``(lo, hi)`` boundary margins: the inferred halo."""
        return self.margins()[self.output]

    @property
    def radius(self) -> int:
        lo, hi = self.halo()
        return max(max(lo, default=0), max(hi, default=0))

    # -- analysis: per-field access radii / reads -----------------------------

    def field_radii(self) -> dict[str, int]:
        """Per-input composed access radius: the max |component| over the
        field's composed footprint (0 for an input the output never reads).

        This is what sizes each field's halo independently: a coefficient
        field read only at offset zero needs NO halo exchange even when the
        state field's radius is 2, and under ``repeat(p, k)`` the per-field
        radii compose separately (the state grows by r per sweep; a
        zero-offset auxiliary grows by r per *earlier* sweep, i.e. to
        ``(k-1) * r``). ``max(field_radii().values()) == radius`` — the
        program radius is the widest field's reach.
        """
        fp = self.footprints()
        return {
            f: max((max(abs(c) for c in o) for o in fp[f]), default=0)
            for f in self.inputs
        }

    def field_radius(self, field: str) -> int:
        if field not in self.inputs:
            raise ValueError(
                f"{field!r} is not an input of program {self.name!r} "
                f"(inputs: {self.inputs})"
            )
        return self.field_radii()[field]

    def exchange_radii(self) -> dict[str, int]:
        """Per-field EXCHANGED halo depth — the ONE home of the rule every
        lowering and wire model shares: the evolving :attr:`passthrough`
        field moves the program's full chain radius (its ring rows must
        carry true passthrough values), every other input only its own
        composed access radius (0 means no exchange at all)."""
        radii = self.field_radii()
        radii[self.passthrough] = self.radius
        return radii

    def reads_by_field(self) -> dict[str, int]:
        """Per-input composed footprint size — the §3.1 ``reads`` term,
        split per field. ``sum(reads_by_field().values()) == spec().reads``
        (the property tests pin this): multi-field op/byte accounting is
        the per-field sum, and a single-input program degenerates to the
        scalar accounting exactly."""
        fp = self.footprints()
        return {f: len(fp[f]) for f in self.inputs}

    # -- temporal composition -------------------------------------------------

    @property
    def chain(self) -> tuple["StencilProgram", ...]:
        """The sequential-sweep decomposition of this program.

        A directly-constructed program is its own 1-chain. A program built by
        :meth:`compose` / :func:`repeat` chains the single-sweep programs that
        are applied in order, with the boundary-ring passthrough applied
        *between* sweeps (the convention of every full-shape lowering). The
        merged DAG this object holds is the analysis view — exact on points
        at least :attr:`radius` from the boundary; near the boundary the
        lowerings follow the chain, not the DAG.
        """
        return getattr(self, "_chain", (self,))

    @property
    def steps(self) -> int:
        """Number of simulated timesteps one application performs."""
        return len(self.chain)

    def compose(self, other: "StencilProgram", *, name: str | None = None) -> "StencilProgram":
        """Sequential composition: apply ``self``, then feed its output to
        ``other``'s *evolving* field (same ndim).

        The evolving field is ``other``'s :attr:`passthrough` input — the
        state the sweep updates. Every other input of ``other`` is a SHARED
        field (a coefficient, a velocity): it must also be an input of
        ``self`` and is read from the same source array in both sweeps. For
        single-input programs this degenerates to the classic rule (the
        sole input is the passthrough, there is nothing to share).

        The returned program's DAG inlines ``other`` after ``self`` with
        the evolving input bound to ``self``'s output (op fields renamed to
        stay unique), so offsets compose by Minkowski sum and the inferred
        radii ADD — per field: the state's radii sum, while a shared
        field's composed radius grows by the *downstream* sweeps' radii
        (see :meth:`field_radii`). Its :attr:`chain` concatenates both
        chains — the lowerings use it to apply the per-sweep boundary
        passthrough to the evolving field only.
        """
        if self.ndim != other.ndim:
            raise ValueError(f"ndim mismatch: {self.ndim} vs {other.ndim}")
        shared = [f for f in other.inputs if f != other.passthrough]
        missing = [f for f in shared if f not in self.inputs]
        if missing:
            raise ValueError(
                f"compose: {other.name!r} reads shared field(s) {missing} that "
                f"are not inputs of {self.name!r} (inputs: {self.inputs}); "
                "shared (non-evolving) fields must be common source inputs"
            )
        if self.passthrough in shared:
            # The slab lowerings overwrite the evolving field in place
            # sweep-to-sweep, so a later sweep cannot also read its ORIGINAL
            # (pre-sweep) values as a shared input — reject rather than let
            # backends disagree (the full-shape reference could thread it,
            # the slab/Pallas/sharded paths cannot).
            raise ValueError(
                f"compose: {other.name!r} reads the evolving field "
                f"{self.passthrough!r} as a shared (non-evolving) input; a "
                "downstream sweep only sees the UPDATED state, never the "
                "original field — restructure the program so the original "
                "values flow through a distinct source input"
            )
        taken = {*self.inputs, *(op.name for op in self.ops)}
        tag = self.steps
        while any(f"{op.name}@{tag}" in taken for op in other.ops):
            tag += 1
        rename = {other.passthrough: self.output}
        rename.update({op.name: f"{op.name}@{tag}" for op in other.ops})
        appended = tuple(
            StencilOp(
                name=rename[op.name],
                reads=tuple(Read(rename.get(r.field, r.field), r.offset) for r in op.reads),
                compute=op.compute,
                cost=op.cost,
            )
            for op in other.ops
        )
        prog = StencilProgram(
            name if name is not None else f"{self.name}>>{other.name}",
            self.inputs,
            self.ops + appended,
            ndim=self.ndim,
            passthrough=self.passthrough,
        )
        prog._chain = self.chain + other.chain
        return prog

    # -- derived accounting ---------------------------------------------------

    def spec(self) -> ProgramSpec:
        """Per-output-point op/byte accounting, fully derived from the graph
        (replaces the hand-written ``StencilSpec`` constants)."""
        fp = self.footprints()
        evals = self.evaluations()
        return ProgramSpec(
            name=self.name,
            macs=sum(evals[op.name] * op.cost.macs for op in self.ops),
            other_ops=sum(evals[op.name] * op.cost.other_ops for op in self.ops),
            reads=sum(len(fp[f]) for f in self.inputs),
            radius=self.radius,
            ndim=self.ndim,
        )

    def staged_bytes(self, points: int, itemsize: int = 4) -> int:
        """HBM traffic when every op materialises to memory (Eq. 8-9
        analogue): each op reads one element per declared access and writes
        its output once, per grid point."""
        return sum((len(op.reads) + 1) * points * itemsize for op in self.ops)

    def fused_bytes(self, points: int, itemsize: int = 4) -> int:
        """Compulsory traffic under fusion: each source in once, output once
        (the VMEM-residency / B-block broadcast analogue). For a composed
        program this is the traffic of one fused k-sweep application."""
        return (len(self.inputs) + 1) * points * itemsize

    def fused_bytes_per_step(self, points: int, itemsize: int = 4) -> float:
        """Compulsory HBM traffic per *simulated* timestep under the fused
        k-sweep lowering — :meth:`fused_bytes` amortised over the chain, the
        ~k-fold cut temporal blocking buys."""
        return self.fused_bytes(points, itemsize) / self.steps

    def __repr__(self) -> str:
        return (
            f"StencilProgram({self.name!r}, inputs={self.inputs}, "
            f"ops={[op.name for op in self.ops]}, radius={self.radius}, "
            f"steps={self.steps})"
        )


def repeat(program: StencilProgram, k: int) -> StencilProgram:
    """``k`` fused sequential sweeps of ``program`` (temporal blocking).

    ``repeat(p, k)`` composes ``p`` with itself ``k`` times: the merged DAG
    gives the analysis (``repeat(p, k).radius == k * p.radius``) and the
    chain gives the lowerings their per-sweep structure — one HBM / wire
    round-trip then serves ``k`` simulated timesteps. ``k == 1`` returns
    ``program`` unchanged.

    Multi-field programs repeat too: the :attr:`StencilProgram.passthrough`
    field evolves sweep-to-sweep while the remaining inputs (coefficients,
    velocities) are shared across sweeps, so e.g. a zero-offset coefficient
    field's composed radius grows to ``(k-1) * p.radius`` (read through
    ``k-1`` downstream sweeps) while the state's grows to ``k * p.radius``.
    """
    if not isinstance(k, int) or isinstance(k, bool) or k < 1:
        raise ValueError(f"k must be a positive int, got {k!r}")
    out = program
    for i in range(2, k + 1):
        out = out.compose(program, name=f"{program.name}_x{i}")
    return out
