"""Stencil program IR: a dataflow DAG of stencil ops with offset analysis.

This is the repo's analogue of SPARTA's MLIR dataflow lowering (§3.2-§3.4)
and StencilFlow's program graphs: a compound stencil is expressed ONCE as a
DAG of :class:`StencilOp` nodes, each declaring the *access offsets* it reads
from its input fields, and everything the hand-written paths used to hard-code
is derived from the graph:

  * **halo / radius** — forward-composed per-dimension margins
    (:meth:`StencilProgram.margins`, :meth:`StencilProgram.halo`); composed
    radii add, which the property tests check.
  * **op / byte accounting** — the paper's §3.1 streaming model
    (:meth:`StencilProgram.spec`): each op is charged once per *distinct
    composed offset* at which an output consumes it (e.g. hdiff's Laplacian
    is consumed at the 5 star offsets, hence "5 Laplacians x 5 MACs" in
    Eq. 5), and ``reads`` is the size of the program's composed access
    footprint on its source fields.
  * **per-field analysis** — every input field's composed access radius and
    footprint size derive separately (:meth:`StencilProgram.field_radii`,
    :meth:`StencilProgram.reads_by_field`) and SUM to the program totals,
    so multi-field programs (velocity + scalar advection, coefficient-field
    diffusion) get per-field halos and per-field wire accounting for free.
  * **multi-OUTPUT programs** — a program may declare
    ``outputs={field: op_name, ...}``: several evolving fields per sweep
    (the coupled-PDE systems real weather timesteps run — shallow-water's
    {u, v, h}). Each output gets its own derived margins / radius
    (:meth:`output_radii`, :meth:`output_footprints`); the program-level
    ``halo``/``radius`` are the elementwise/overall max over outputs, and
    every evolving field exchanges the full chain radius
    (:meth:`exchange_radii`) because the fused sweeps advance all evolving
    slabs together. A single-output program is the strict degenerate case
    (``outputs == {passthrough: ops[-1].name}`` by default — identical
    analysis, identical fingerprint).
  * **temporal blocking** — :meth:`StencilProgram.compose` / :func:`repeat`
    fuse k sequential sweeps into one program (the §1 "pipelining different
    timesteps" insight): the merged DAG drives the analysis (radii add, so
    ``repeat(p, k).radius == k * p.radius``), while :attr:`chain` records the
    per-sweep decomposition the lowerings execute with the boundary-ring
    passthrough applied between sweeps. For multi-output programs each
    output op feeds the MATCHING evolving input of the next sweep (outputs
    bind by field name). HBM / wire traffic per *simulated* step then
    divides by k (:meth:`fused_bytes_per_step`).
  * **structural identity** — :meth:`StencilProgram.fingerprint` is a
    canonical SHA-256 over the graph structure (inputs, outputs, per-op
    reads/offsets/costs and the combinator :attr:`StencilOp.tag`), stable
    across processes/sessions — the compile-cache key the serving path
    needs. ``__eq__``/``__hash__`` delegate to it.

The package is self-contained: nothing under ``repro.ir`` imports other
``repro`` modules, so ``repro.core`` / ``repro.kernels`` can derive their
constants from the IR without import cycles. The lowerings to the three
execution backends live in the sibling ``lower_*`` modules.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Callable, Mapping, Sequence

Offset = tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Per-evaluation cost of one op, in the paper's Eq. 5-7 accounting.

    ``macs`` counts multiply-accumulates (one per stencil tap, the Eq. 5
    convention); ``other_ops`` counts non-MAC vector ops (add/sub/cmp/select).
    Costs are attached by the combinator builders in :mod:`repro.ir.ops` —
    they are properties of the *combinator*, never of a particular program.
    """

    macs: int = 0
    other_ops: int = 0

    @property
    def flops(self) -> int:
        return 2 * self.macs + self.other_ops


@dataclasses.dataclass(frozen=True)
class Read:
    """One access: ``field`` sampled at relative grid ``offset``."""

    field: str
    offset: Offset


@dataclasses.dataclass(frozen=True)
class StencilOp:
    """One node of the DAG: produces field ``name`` from its reads.

    ``compute`` is an elementwise combinator: it receives one aligned array
    per entry of ``reads`` (all the same shape — the op's output region) and
    returns the output array. All spatial structure lives in the offsets, so
    every lowering can evaluate the op by slicing differently-shifted views.

    ``tag`` is a canonical description of the combinator INCLUDING its baked
    numeric parameters (tap weights, scales) — the part of the op's identity
    that lives inside the ``compute`` closure and is invisible to the read
    structure. The :mod:`repro.ir.ops` builders always set it; it feeds
    :meth:`StencilProgram.fingerprint` so two programs differing only in a
    coefficient hash differently.

    ``vjp`` is the op's adjoint rule (see :mod:`repro.ir.autodiff`): called
    as ``vjp(op, gbar_field, fresh)`` it returns ``[(read_field, term)]``
    where each ``term`` is a :class:`StencilOp` computing that read field's
    cotangent contribution (or a bare field name contributing directly).
    ``None`` falls back to the generic ``jax.vjp``-per-point rule, which is
    always correct but reads every primal field of the op — the explicit
    rules keep adjoint footprints tight (negated offsets only). Like
    ``compute`` it is excluded from the fingerprint: the rule is derived
    from the combinator the ``tag`` already names.
    """

    name: str
    reads: tuple[Read, ...]
    compute: Callable[..., object]
    cost: OpCost
    tag: str | None = None
    vjp: Callable[..., object] | None = dataclasses.field(
        default=None, compare=False
    )

    def fields(self) -> tuple[str, ...]:
        """Distinct fields read, in first-read order."""
        seen: dict[str, None] = {}
        for r in self.reads:
            seen.setdefault(r.field, None)
        return tuple(seen)


@dataclasses.dataclass(frozen=True)
class ProgramSpec:
    """Graph-derived per-output-point accounting (mirrors core's StencilSpec)."""

    name: str
    macs: int
    other_ops: int
    reads: int
    radius: int
    ndim: int = 2

    @property
    def flops(self) -> int:
        return 2 * self.macs + self.other_ops


class StencilProgram:
    """An ordered DAG of :class:`StencilOp` over named fields.

    ``ops`` must be topologically ordered: each op may read only source
    ``inputs`` or earlier ops' outputs.

    ``outputs`` maps each EVOLVING input field to the op that produces its
    next value — the coupled-system schema (shallow-water updates
    ``{u: "u_new", v: "v_new", h: "h_new"}`` in one sweep). When omitted the
    program is single-output: the :attr:`passthrough` field evolves into the
    last op, exactly the pre-multi-output convention. Every lowering carries
    each evolving field's boundary ring through unchanged (the paper
    computes interior points only) on the UNIFORM square radius-``r`` ring,
    ``r = self.radius`` — one shared ring keeps all evolving slabs on one
    aligned grid through the chain's sweeps.

    ``passthrough`` names the primary evolving field (must be one of the
    ``outputs`` keys); it defaults to the first declared input that evolves.
    Single-output code paths keep reading :attr:`passthrough` /
    :attr:`output` and see exactly the old behaviour.
    """

    def __init__(
        self,
        name: str,
        inputs: Sequence[str],
        ops: Sequence[StencilOp],
        *,
        ndim: int = 2,
        passthrough: str | None = None,
        outputs: Mapping[str, str] | None = None,
    ):
        if not ops:
            raise ValueError("program needs at least one op")
        self.name = name
        self.inputs = tuple(inputs)
        self.ops = tuple(ops)
        self.ndim = ndim

        known = set(self.inputs)
        op_names = set()
        for op in self.ops:
            if op.name in self.inputs:
                # The silently-renamed-DAG hazard: an op named like a source
                # field would shadow it for every later reader (and compose's
                # rename map would pick up the wrong identity). Name BOTH
                # colliding identities so the fix is obvious.
                raise ValueError(
                    f"op {op.name!r} collides with source input {op.name!r}: "
                    f"op names and input field names share one namespace — "
                    f"rename the op (program {name!r}, inputs {self.inputs})"
                )
            if op.name in op_names:
                raise ValueError(f"duplicate field name {op.name!r}")
            for read in op.reads:
                if read.field not in known:
                    raise ValueError(
                        f"op {op.name!r} reads {read.field!r} before it is defined"
                    )
                if len(read.offset) != ndim:
                    raise ValueError(
                        f"op {op.name!r} offset {read.offset} is not {ndim}-D"
                    )
            known.add(op.name)
            op_names.add(op.name)

        if outputs is None:
            self.passthrough = (
                passthrough if passthrough is not None else self.inputs[0]
            )
            if self.passthrough not in self.inputs:
                raise ValueError(
                    f"passthrough {self.passthrough!r} is not a program input"
                )
            self.outputs: dict[str, str] = {self.passthrough: self.ops[-1].name}
        else:
            if not outputs:
                raise ValueError("outputs mapping must not be empty")
            cleaned: dict[str, str] = {}
            for f in self.inputs:  # canonical order: declared input order
                if f in outputs:
                    cleaned[f] = outputs[f]
            unknown = [f for f in outputs if f not in self.inputs]
            if unknown:
                raise ValueError(
                    f"outputs key(s) {unknown} are not program inputs "
                    f"(inputs: {self.inputs}); each output evolves one input field"
                )
            for f, op_name in cleaned.items():
                if op_name not in op_names:
                    raise ValueError(
                        f"outputs[{f!r}] = {op_name!r} names no op of program "
                        f"{name!r} (ops: {[op.name for op in self.ops]})"
                    )
            vals = list(cleaned.values())
            if len(set(vals)) != len(vals):
                raise ValueError(
                    f"outputs {dict(outputs)} map two evolving fields to one "
                    f"op; each output field needs its own producing op"
                )
            self.outputs = cleaned
            self.passthrough = (
                passthrough if passthrough is not None else next(iter(cleaned))
            )
            if self.passthrough not in self.outputs:
                raise ValueError(
                    f"passthrough {self.passthrough!r} must be one of the "
                    f"evolving output fields {tuple(self.outputs)}"
                )

    @property
    def output(self) -> str:
        """The op producing the :attr:`passthrough` field's next value (the
        sole output op for single-output programs — the legacy accessor)."""
        return self.outputs[self.passthrough]

    # -- analysis: composed footprints (reverse) ------------------------------

    def _footprints_from(self, seeds) -> dict[str, frozenset[Offset]]:
        fp: dict[str, set[Offset]] = {f: set() for f in self.inputs}
        fp.update({op.name: set() for op in self.ops})
        for s in seeds:
            fp[s].add((0,) * self.ndim)
        for op in reversed(self.ops):
            at = fp[op.name]
            for read in op.reads:
                fp[read.field].update(
                    tuple(a + b for a, b in zip(o, read.offset)) for o in at
                )
        return {f: frozenset(s) for f, s in fp.items()}

    def footprints(self) -> dict[str, frozenset[Offset]]:
        """For every field, the set of composed offsets (relative to one
        output point) at which ANY output depends on it. Composition is the
        Minkowski sum of per-op offset sets along each consumer path, unioned
        over paths (and over the program's outputs) — StencilFlow's
        access-footprint inference."""
        return self._footprints_from(set(self.outputs.values()))

    def output_footprints(self, field: str) -> dict[str, frozenset[Offset]]:
        """:meth:`footprints` seeded from ONE output field's producing op:
        what that output alone reads, at which composed offsets."""
        if field not in self.outputs:
            raise ValueError(
                f"{field!r} is not an output of program {self.name!r} "
                f"(outputs: {tuple(self.outputs)})"
            )
        return self._footprints_from({self.outputs[field]})

    def evaluations(self) -> dict[str, int]:
        """Streaming-model evaluation count per op: one evaluation per
        distinct composed offset the outputs consume it at (§3.1)."""
        fp = self.footprints()
        return {op.name: len(fp[op.name]) for op in self.ops}

    # -- analysis: materialisation margins (forward) --------------------------

    def margins(self) -> dict[str, tuple[Offset, Offset]]:
        """Per-field ``(lo, hi)`` margins: how far the field's valid region
        is inset from the source grid on the low/high side of each dim when
        every field is materialised on its maximal valid region."""
        m: dict[str, tuple[Offset, Offset]] = {
            f: ((0,) * self.ndim, (0,) * self.ndim) for f in self.inputs
        }
        for op in self.ops:
            lo = [0] * self.ndim
            hi = [0] * self.ndim
            for read in op.reads:
                in_lo, in_hi = m[read.field]
                for d in range(self.ndim):
                    lo[d] = max(lo[d], in_lo[d] + max(0, -read.offset[d]))
                    hi[d] = max(hi[d], in_hi[d] + max(0, read.offset[d]))
            m[op.name] = (tuple(lo), tuple(hi))
        return m

    def halo(self) -> tuple[Offset, Offset]:
        """The program's ``(lo, hi)`` boundary margins: the inferred halo —
        the elementwise max over the output ops' margins (a single-output
        program reduces to its sole output's margins exactly)."""
        m = self.margins()
        per_out = [m[op_name] for op_name in self.outputs.values()]
        lo = tuple(max(p[0][d] for p in per_out) for d in range(self.ndim))
        hi = tuple(max(p[1][d] for p in per_out) for d in range(self.ndim))
        return lo, hi

    def output_margins(self, field: str) -> tuple[Offset, Offset]:
        """One output field's own ``(lo, hi)`` margins (its producing op's
        valid-region inset) — what :func:`~repro.ir.evaluate.ring_crop`
        aligns per output."""
        if field not in self.outputs:
            raise ValueError(
                f"{field!r} is not an output of program {self.name!r} "
                f"(outputs: {tuple(self.outputs)})"
            )
        return self.margins()[self.outputs[field]]

    @property
    def radius(self) -> int:
        lo, hi = self.halo()
        return max(max(lo, default=0), max(hi, default=0))

    def output_radii(self) -> dict[str, int]:
        """Per-OUTPUT derived radius: each evolving field's own producing-op
        margin radius. ``max(output_radii().values()) == radius``; under
        ``repeat(p, k)`` each output's radius scales as ``k * r_out``
        (property-tested). The §3.1 accounting per coupled equation."""
        m = self.margins()
        out = {}
        for f, op_name in self.outputs.items():
            lo, hi = m[op_name]
            out[f] = max(max(lo, default=0), max(hi, default=0))
        return out

    # -- analysis: per-field access radii / reads -----------------------------

    def field_radii(self) -> dict[str, int]:
        """Per-input composed access radius: the max |component| over the
        field's composed footprint (0 for an input no output ever reads).

        This is what sizes each field's halo independently: a coefficient
        field read only at offset zero needs NO halo exchange even when the
        state field's radius is 2, and under ``repeat(p, k)`` the per-field
        radii compose separately (the state grows by r per sweep; a
        zero-offset auxiliary grows by r per *earlier* sweep, i.e. to
        ``(k-1) * r``). ``max(field_radii().values()) == radius`` — the
        program radius is the widest field's reach.
        """
        fp = self.footprints()
        return {
            f: max((max(abs(c) for c in o) for o in fp[f]), default=0)
            for f in self.inputs
        }

    def field_radius(self, field: str) -> int:
        if field not in self.inputs:
            raise ValueError(
                f"{field!r} is not an input of program {self.name!r} "
                f"(inputs: {self.inputs})"
            )
        return self.field_radii()[field]

    def exchange_radii(self) -> dict[str, int]:
        """Per-field EXCHANGED halo depth — the ONE home of the rule every
        lowering and wire model shares: every EVOLVING (``outputs``) field
        moves the program's full chain radius (its ring rows must carry true
        passthrough values, and all evolving slabs advance together through
        the chain's sweeps on one aligned grid), every other input only its
        own composed access radius (0 means no exchange at all). The merged
        multi-output wire model — ``program_halo_exchange_bytes`` — is the
        sum over these values."""
        radii = self.field_radii()
        for f in self.outputs:
            radii[f] = self.radius
        return radii

    def reads_by_field(self) -> dict[str, int]:
        """Per-input composed footprint size — the §3.1 ``reads`` term,
        split per field. ``sum(reads_by_field().values()) == spec().reads``
        (the property tests pin this): multi-field op/byte accounting is
        the per-field sum, and a single-input program degenerates to the
        scalar accounting exactly."""
        fp = self.footprints()
        return {f: len(fp[f]) for f in self.inputs}

    # -- temporal composition -------------------------------------------------

    @property
    def chain(self) -> tuple["StencilProgram", ...]:
        """The sequential-sweep decomposition of this program.

        A directly-constructed program is its own 1-chain. A program built by
        :meth:`compose` / :func:`repeat` chains the single-sweep programs that
        are applied in order, with the boundary-ring passthrough applied
        *between* sweeps (the convention of every full-shape lowering). The
        merged DAG this object holds is the analysis view — exact on points
        at least :attr:`radius` from the boundary; near the boundary the
        lowerings follow the chain, not the DAG.
        """
        return getattr(self, "_chain", (self,))

    @property
    def steps(self) -> int:
        """Number of simulated timesteps one application performs."""
        return len(self.chain)

    def compose(self, other: "StencilProgram", *, name: str | None = None) -> "StencilProgram":
        """Sequential composition: apply ``self``, then feed its outputs to
        ``other``'s *evolving* fields (same ndim).

        The evolving fields are ``other``'s :attr:`outputs` keys — the state
        the sweep updates. Every other input of ``other`` is a SHARED field
        (a coefficient, a velocity): it must also be an input of ``self``
        and is read from the same source array in both sweeps.

        Output-to-input binding: when both programs are single-output the
        classic positional rule applies (the sole output feeds the sole
        evolving input; names may differ — ``hdiff`` composes with
        ``vadvc``-shaped sweeps). When either side is multi-output the
        outputs bind BY FIELD NAME — ``other`` must evolve exactly the same
        field set, and each field's producing op in ``self`` feeds the
        matching evolving input of ``other`` (shallow-water's u update reads
        the PREVIOUS sweep's u, v reads v, h reads h).

        The returned program's DAG inlines ``other`` after ``self`` with
        the evolving inputs bound to ``self``'s output ops (op fields
        renamed to stay unique), so offsets compose by Minkowski sum and the
        inferred radii ADD — per field AND per output (see
        :meth:`field_radii` / :meth:`output_radii`). Its :attr:`chain`
        concatenates both chains — the lowerings use it to apply the
        per-sweep boundary passthrough to the evolving fields only.
        """
        if self.ndim != other.ndim:
            raise ValueError(f"ndim mismatch: {self.ndim} vs {other.ndim}")
        shared = [f for f in other.inputs if f not in other.outputs]
        missing = [f for f in shared if f not in self.inputs]
        if missing:
            raise ValueError(
                f"compose: {other.name!r} reads shared field(s) {missing} that "
                f"are not inputs of {self.name!r} (inputs: {self.inputs}); "
                "shared (non-evolving) fields must be common source inputs"
            )
        shadowed = [f for f in shared if f in self.outputs]
        if shadowed:
            # The slab lowerings overwrite the evolving fields in place
            # sweep-to-sweep, so a later sweep cannot also read their ORIGINAL
            # (pre-sweep) values as shared inputs — reject rather than let
            # backends disagree (the full-shape reference could thread it,
            # the slab/Pallas/sharded paths cannot).
            raise ValueError(
                f"compose: {other.name!r} reads the evolving field(s) "
                f"{shadowed} as shared (non-evolving) input(s); a "
                "downstream sweep only sees the UPDATED state, never the "
                "original field — restructure the program so the original "
                "values flow through a distinct source input"
            )
        if len(self.outputs) == 1 and len(other.outputs) == 1:
            # Classic positional rule: sole output feeds sole evolving input.
            pairs = [(self.passthrough, next(iter(other.outputs)))]
        else:
            if set(other.outputs) != set(self.outputs):
                raise ValueError(
                    f"compose: multi-output programs bind outputs by FIELD "
                    f"NAME, but {self.name!r} evolves {sorted(self.outputs)} "
                    f"while {other.name!r} evolves {sorted(other.outputs)}; "
                    "each sweep must update the same evolving field set"
                )
            pairs = [(f, f) for f in self.outputs]
        taken = {*self.inputs, *(op.name for op in self.ops)}
        tag = self.steps
        while any(f"{op.name}@{tag}" in taken for op in other.ops):
            tag += 1
        rename = {f_other: self.outputs[f_self] for f_self, f_other in pairs}
        rename.update({op.name: f"{op.name}@{tag}" for op in other.ops})
        appended = tuple(
            StencilOp(
                name=rename[op.name],
                reads=tuple(Read(rename.get(r.field, r.field), r.offset) for r in op.reads),
                compute=op.compute,
                cost=op.cost,
                tag=op.tag,
                vjp=op.vjp,
            )
            for op in other.ops
        )
        merged_outputs = {
            f_self: rename[other.outputs[f_other]] for f_self, f_other in pairs
        }
        prog = StencilProgram(
            name if name is not None else f"{self.name}>>{other.name}",
            self.inputs,
            self.ops + appended,
            ndim=self.ndim,
            passthrough=self.passthrough,
            outputs=merged_outputs,
        )
        prog._chain = self.chain + other.chain
        return prog

    # -- structural identity --------------------------------------------------

    def fingerprint(self) -> str:
        """Canonical structural SHA-256 of the program, stable across
        sessions — the compile-cache key groundwork (ROADMAP).

        Covers ndim, input order, the outputs binding, passthrough, and
        every op's (name, combinator :attr:`~StencilOp.tag`, reads with
        offsets, cost), plus the per-sweep chain fingerprints for composed
        programs (two programs with one merged DAG but different sweep
        decompositions evaluate differently near the boundary, so they must
        hash differently). The display ``name`` is cosmetic and excluded.
        No Python ``hash()``/``id()`` anywhere, so the digest is
        reproducible across processes and sessions.

        Ops built outside :mod:`repro.ir.ops` may carry ``tag=None``; their
        numeric closure parameters are then invisible to the hash (structure
        only) — set :attr:`StencilOp.tag` to restore full identity.
        """
        cached = getattr(self, "_fingerprint", None)
        if cached is not None:
            return cached
        payload = {
            "ndim": self.ndim,
            "inputs": list(self.inputs),
            "outputs": [[f, self.outputs[f]] for f in self.outputs],
            "passthrough": self.passthrough,
            "ops": [
                [
                    op.name,
                    op.tag or "",
                    [[r.field, list(r.offset)] for r in op.reads],
                    [op.cost.macs, op.cost.other_ops],
                ]
                for op in self.ops
            ],
        }
        if self.steps > 1:
            payload["chain"] = [p.fingerprint() for p in self.chain]
        digest = hashlib.sha256(
            json.dumps(payload, separators=(",", ":")).encode()
        ).hexdigest()
        self._fingerprint = digest
        return digest

    def __eq__(self, other) -> bool:
        if not isinstance(other, StencilProgram):
            return NotImplemented
        return self.fingerprint() == other.fingerprint()

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return eq if eq is NotImplemented else not eq

    def __hash__(self) -> int:
        return int(self.fingerprint()[:16], 16)

    # -- derived accounting ---------------------------------------------------

    def spec(self) -> ProgramSpec:
        """Per-output-point op/byte accounting, fully derived from the graph
        (replaces the hand-written ``StencilSpec`` constants). Multi-output
        programs charge each op once per distinct composed offset ANY output
        consumes it at, and ``reads`` sums the per-field footprints."""
        fp = self.footprints()
        evals = self.evaluations()
        return ProgramSpec(
            name=self.name,
            macs=sum(evals[op.name] * op.cost.macs for op in self.ops),
            other_ops=sum(evals[op.name] * op.cost.other_ops for op in self.ops),
            reads=sum(len(fp[f]) for f in self.inputs),
            radius=self.radius,
            ndim=self.ndim,
        )

    def staged_bytes(self, points: int, itemsize: int = 4) -> int:
        """HBM traffic when every op materialises to memory (Eq. 8-9
        analogue): each op reads one element per declared access and writes
        its output once, per grid point."""
        return sum((len(op.reads) + 1) * points * itemsize for op in self.ops)

    def fused_bytes(self, points: int, itemsize: int = 4) -> int:
        """Compulsory traffic under fusion: each source in once, each output
        once (the VMEM-residency / B-block broadcast analogue). For a
        composed program this is the traffic of one fused k-sweep
        application."""
        return (len(self.inputs) + len(self.outputs)) * points * itemsize

    def fused_bytes_per_step(self, points: int, itemsize: int = 4) -> float:
        """Compulsory HBM traffic per *simulated* timestep under the fused
        k-sweep lowering — :meth:`fused_bytes` amortised over the chain, the
        ~k-fold cut temporal blocking buys."""
        return self.fused_bytes(points, itemsize) / self.steps

    def __repr__(self) -> str:
        outs = (
            f"outputs={self.outputs}"
            if len(self.outputs) > 1
            else f"ops={[op.name for op in self.ops]}"
        )
        return (
            f"StencilProgram({self.name!r}, inputs={self.inputs}, "
            f"{outs}, radius={self.radius}, "
            f"steps={self.steps})"
        )


def repeat(program: StencilProgram, k: int) -> StencilProgram:
    """``k`` fused sequential sweeps of ``program`` (temporal blocking).

    ``repeat(p, k)`` composes ``p`` with itself ``k`` times: the merged DAG
    gives the analysis (``repeat(p, k).radius == k * p.radius``) and the
    chain gives the lowerings their per-sweep structure — one HBM / wire
    round-trip then serves ``k`` simulated timesteps. ``k == 1`` returns
    ``program`` unchanged.

    Multi-field programs repeat too: the :attr:`StencilProgram.outputs`
    fields evolve sweep-to-sweep (each output op feeding the matching
    evolving input of the next sweep, by name) while the remaining inputs
    (coefficients, velocities) are shared across sweeps, so e.g. a
    zero-offset coefficient field's composed radius grows to ``(k-1) *
    p.radius`` (read through ``k-1`` downstream sweeps) while each evolving
    field's grows to ``k * p.radius``.
    """
    if not isinstance(k, int) or isinstance(k, bool) or k < 1:
        raise ValueError(f"k must be a positive int, got {k!r}")
    out = program
    for i in range(2, k + 1):
        out = out.compose(program, name=f"{program.name}_x{i}")
    return out
