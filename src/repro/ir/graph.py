"""Stencil program IR: a dataflow DAG of stencil ops with offset analysis.

This is the repo's analogue of SPARTA's MLIR dataflow lowering (§3.2-§3.4)
and StencilFlow's program graphs: a compound stencil is expressed ONCE as a
DAG of :class:`StencilOp` nodes, each declaring the *access offsets* it reads
from its input fields, and everything the hand-written paths used to hard-code
is derived from the graph:

  * **halo / radius** — forward-composed per-dimension margins
    (:meth:`StencilProgram.margins`, :meth:`StencilProgram.halo`); composed
    radii add, which the property tests check.
  * **op / byte accounting** — the paper's §3.1 streaming model
    (:meth:`StencilProgram.spec`): each op is charged once per *distinct
    composed offset* at which the output consumes it (e.g. hdiff's Laplacian
    is consumed at the 5 star offsets, hence "5 Laplacians x 5 MACs" in
    Eq. 5), and ``reads`` is the size of the program's composed access
    footprint on its source fields.
  * **temporal blocking** — :meth:`StencilProgram.compose` / :func:`repeat`
    fuse k sequential sweeps into one program (the §1 "pipelining different
    timesteps" insight): the merged DAG drives the analysis (radii add, so
    ``repeat(p, k).radius == k * p.radius``), while :attr:`chain` records the
    per-sweep decomposition the lowerings execute with the boundary-ring
    passthrough applied between sweeps. HBM / wire traffic per *simulated*
    step then divides by k (:meth:`fused_bytes_per_step`).

The package is self-contained: nothing under ``repro.ir`` imports other
``repro`` modules, so ``repro.core`` / ``repro.kernels`` can derive their
constants from the IR without import cycles. The lowerings to the three
execution backends live in the sibling ``lower_*`` modules.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

Offset = tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Per-evaluation cost of one op, in the paper's Eq. 5-7 accounting.

    ``macs`` counts multiply-accumulates (one per stencil tap, the Eq. 5
    convention); ``other_ops`` counts non-MAC vector ops (add/sub/cmp/select).
    Costs are attached by the combinator builders in :mod:`repro.ir.ops` —
    they are properties of the *combinator*, never of a particular program.
    """

    macs: int = 0
    other_ops: int = 0

    @property
    def flops(self) -> int:
        return 2 * self.macs + self.other_ops


@dataclasses.dataclass(frozen=True)
class Read:
    """One access: ``field`` sampled at relative grid ``offset``."""

    field: str
    offset: Offset


@dataclasses.dataclass(frozen=True)
class StencilOp:
    """One node of the DAG: produces field ``name`` from its reads.

    ``compute`` is an elementwise combinator: it receives one aligned array
    per entry of ``reads`` (all the same shape — the op's output region) and
    returns the output array. All spatial structure lives in the offsets, so
    every lowering can evaluate the op by slicing differently-shifted views.
    """

    name: str
    reads: tuple[Read, ...]
    compute: Callable[..., object]
    cost: OpCost

    def fields(self) -> tuple[str, ...]:
        """Distinct fields read, in first-read order."""
        seen: dict[str, None] = {}
        for r in self.reads:
            seen.setdefault(r.field, None)
        return tuple(seen)


@dataclasses.dataclass(frozen=True)
class ProgramSpec:
    """Graph-derived per-output-point accounting (mirrors core's StencilSpec)."""

    name: str
    macs: int
    other_ops: int
    reads: int
    radius: int
    ndim: int = 2

    @property
    def flops(self) -> int:
        return 2 * self.macs + self.other_ops


class StencilProgram:
    """An ordered DAG of :class:`StencilOp` over named fields.

    ``ops`` must be topologically ordered: each op may read only source
    ``inputs`` or earlier ops' outputs. The last op is the program output.
    ``passthrough`` names the source field whose boundary ring the lowered
    kernels carry through unchanged (the paper computes interior points only).
    """

    def __init__(
        self,
        name: str,
        inputs: Sequence[str],
        ops: Sequence[StencilOp],
        *,
        ndim: int = 2,
        passthrough: str | None = None,
    ):
        if not ops:
            raise ValueError("program needs at least one op")
        self.name = name
        self.inputs = tuple(inputs)
        self.ops = tuple(ops)
        self.ndim = ndim
        self.passthrough = passthrough if passthrough is not None else self.inputs[0]
        if self.passthrough not in self.inputs:
            raise ValueError(f"passthrough {self.passthrough!r} is not a program input")

        known = set(self.inputs)
        for op in self.ops:
            if op.name in known:
                raise ValueError(f"duplicate field name {op.name!r}")
            for read in op.reads:
                if read.field not in known:
                    raise ValueError(
                        f"op {op.name!r} reads {read.field!r} before it is defined"
                    )
                if len(read.offset) != ndim:
                    raise ValueError(
                        f"op {op.name!r} offset {read.offset} is not {ndim}-D"
                    )
            known.add(op.name)
        self.output = self.ops[-1].name

    # -- analysis: composed footprints (reverse) ------------------------------

    def footprints(self) -> dict[str, frozenset[Offset]]:
        """For every field, the set of composed offsets (relative to one
        output point) at which the output depends on it. Composition is the
        Minkowski sum of per-op offset sets along each consumer path, unioned
        over paths — StencilFlow's access-footprint inference."""
        fp: dict[str, set[Offset]] = {f: set() for f in self.inputs}
        fp.update({op.name: set() for op in self.ops})
        fp[self.output].add((0,) * self.ndim)
        for op in reversed(self.ops):
            at = fp[op.name]
            for read in op.reads:
                fp[read.field].update(
                    tuple(a + b for a, b in zip(o, read.offset)) for o in at
                )
        return {f: frozenset(s) for f, s in fp.items()}

    def evaluations(self) -> dict[str, int]:
        """Streaming-model evaluation count per op: one evaluation per
        distinct composed offset the output consumes it at (§3.1)."""
        fp = self.footprints()
        return {op.name: len(fp[op.name]) for op in self.ops}

    # -- analysis: materialisation margins (forward) --------------------------

    def margins(self) -> dict[str, tuple[Offset, Offset]]:
        """Per-field ``(lo, hi)`` margins: how far the field's valid region
        is inset from the source grid on the low/high side of each dim when
        every field is materialised on its maximal valid region."""
        m: dict[str, tuple[Offset, Offset]] = {
            f: ((0,) * self.ndim, (0,) * self.ndim) for f in self.inputs
        }
        for op in self.ops:
            lo = [0] * self.ndim
            hi = [0] * self.ndim
            for read in op.reads:
                in_lo, in_hi = m[read.field]
                for d in range(self.ndim):
                    lo[d] = max(lo[d], in_lo[d] + max(0, -read.offset[d]))
                    hi[d] = max(hi[d], in_hi[d] + max(0, read.offset[d]))
            m[op.name] = (tuple(lo), tuple(hi))
        return m

    def halo(self) -> tuple[Offset, Offset]:
        """The program's ``(lo, hi)`` boundary margins: the inferred halo."""
        return self.margins()[self.output]

    @property
    def radius(self) -> int:
        lo, hi = self.halo()
        return max(max(lo, default=0), max(hi, default=0))

    # -- temporal composition -------------------------------------------------

    @property
    def chain(self) -> tuple["StencilProgram", ...]:
        """The sequential-sweep decomposition of this program.

        A directly-constructed program is its own 1-chain. A program built by
        :meth:`compose` / :func:`repeat` chains the single-sweep programs that
        are applied in order, with the boundary-ring passthrough applied
        *between* sweeps (the convention of every full-shape lowering). The
        merged DAG this object holds is the analysis view — exact on points
        at least :attr:`radius` from the boundary; near the boundary the
        lowerings follow the chain, not the DAG.
        """
        return getattr(self, "_chain", (self,))

    @property
    def steps(self) -> int:
        """Number of simulated timesteps one application performs."""
        return len(self.chain)

    def compose(self, other: "StencilProgram", *, name: str | None = None) -> "StencilProgram":
        """Sequential composition: apply ``self``, then feed its output to
        ``other`` (both single-input, same ndim).

        The returned program's DAG inlines ``other`` after ``self`` with
        ``other``'s input bound to ``self``'s output (fields renamed to stay
        unique), so offsets compose by Minkowski sum and the inferred radii
        ADD. Its :attr:`chain` concatenates both chains — the lowerings use
        it to apply the per-sweep boundary passthrough.
        """
        if self.ndim != other.ndim:
            raise ValueError(f"ndim mismatch: {self.ndim} vs {other.ndim}")
        if len(self.inputs) != 1 or len(other.inputs) != 1:
            raise ValueError(
                "compose needs single-input programs, got "
                f"{self.inputs} and {other.inputs}"
            )
        taken = {self.inputs[0], *(op.name for op in self.ops)}
        tag = self.steps
        while any(f"{op.name}@{tag}" in taken for op in other.ops):
            tag += 1
        rename = {other.inputs[0]: self.output}
        rename.update({op.name: f"{op.name}@{tag}" for op in other.ops})
        appended = tuple(
            StencilOp(
                name=rename[op.name],
                reads=tuple(Read(rename[r.field], r.offset) for r in op.reads),
                compute=op.compute,
                cost=op.cost,
            )
            for op in other.ops
        )
        prog = StencilProgram(
            name if name is not None else f"{self.name}>>{other.name}",
            self.inputs,
            self.ops + appended,
            ndim=self.ndim,
            passthrough=self.passthrough,
        )
        prog._chain = self.chain + other.chain
        return prog

    # -- derived accounting ---------------------------------------------------

    def spec(self) -> ProgramSpec:
        """Per-output-point op/byte accounting, fully derived from the graph
        (replaces the hand-written ``StencilSpec`` constants)."""
        fp = self.footprints()
        evals = self.evaluations()
        return ProgramSpec(
            name=self.name,
            macs=sum(evals[op.name] * op.cost.macs for op in self.ops),
            other_ops=sum(evals[op.name] * op.cost.other_ops for op in self.ops),
            reads=sum(len(fp[f]) for f in self.inputs),
            radius=self.radius,
            ndim=self.ndim,
        )

    def staged_bytes(self, points: int, itemsize: int = 4) -> int:
        """HBM traffic when every op materialises to memory (Eq. 8-9
        analogue): each op reads one element per declared access and writes
        its output once, per grid point."""
        return sum((len(op.reads) + 1) * points * itemsize for op in self.ops)

    def fused_bytes(self, points: int, itemsize: int = 4) -> int:
        """Compulsory traffic under fusion: each source in once, output once
        (the VMEM-residency / B-block broadcast analogue). For a composed
        program this is the traffic of one fused k-sweep application."""
        return (len(self.inputs) + 1) * points * itemsize

    def fused_bytes_per_step(self, points: int, itemsize: int = 4) -> float:
        """Compulsory HBM traffic per *simulated* timestep under the fused
        k-sweep lowering — :meth:`fused_bytes` amortised over the chain, the
        ~k-fold cut temporal blocking buys."""
        return self.fused_bytes(points, itemsize) / self.steps

    def __repr__(self) -> str:
        return (
            f"StencilProgram({self.name!r}, inputs={self.inputs}, "
            f"ops={[op.name for op in self.ops]}, radius={self.radius}, "
            f"steps={self.steps})"
        )


def repeat(program: StencilProgram, k: int) -> StencilProgram:
    """``k`` fused sequential sweeps of ``program`` (temporal blocking).

    ``repeat(p, k)`` composes ``p`` with itself ``k`` times: the merged DAG
    gives the analysis (``repeat(p, k).radius == k * p.radius``) and the
    chain gives the lowerings their per-sweep structure — one HBM / wire
    round-trip then serves ``k`` simulated timesteps. ``k == 1`` returns
    ``program`` unchanged.
    """
    if not isinstance(k, int) or isinstance(k, bool) or k < 1:
        raise ValueError(f"k must be a positive int, got {k!r}")
    if len(program.inputs) != 1:
        raise ValueError(
            f"repeat needs a single-input program, got inputs {program.inputs}"
        )
    out = program
    for i in range(2, k + 1):
        out = out.compose(program, name=f"{program.name}_x{i}")
    return out
