"""VMEM tile planning shared by the hand-written kernels and the IR lowerer.

The Pallas grid pipeline keeps ~3 input blocks + 1 output block live and
double-buffers them (the shimDMA ping-pong of §3.2.1), so the per-block
budget sits well under VMEM/8. The budget defaults to 4 MiB and is
configurable per call (``budget_bytes``) or process-wide via the
``REPRO_VMEM_BUDGET`` environment variable (bytes).
"""

from __future__ import annotations

import os

DEFAULT_VMEM_TILE_BUDGET = 4 * 1024 * 1024
VMEM_BUDGET_ENV = "REPRO_VMEM_BUDGET"


def vmem_tile_budget(budget_bytes: int | None = None) -> int:
    """Resolves the per-block VMEM budget: explicit arg > env var > 4 MiB.

    A non-positive budget is a configuration error, not a request for
    1-row tiles — it raises instead of silently degrading every kernel."""
    if budget_bytes is not None:
        budget, source = int(budget_bytes), "budget_bytes"
    else:
        env = os.environ.get(VMEM_BUDGET_ENV)
        if not env:
            return DEFAULT_VMEM_TILE_BUDGET
        try:
            budget = int(env)
        except ValueError as e:
            raise ValueError(
                f"{VMEM_BUDGET_ENV} must be an integer byte count, got {env!r}"
            ) from e
        source = VMEM_BUDGET_ENV
    if budget <= 0:
        raise ValueError(
            f"{source} must be a positive byte count, got {budget}"
        )
    return budget


def pick_block_rows(
    rows: int,
    cols: int,
    *,
    itemsize: int = 4,
    budget_bytes: int | None = None,
    min_rows: int = 1,
) -> int:
    """Largest divisor of ``rows`` whose (rows x cols) tile fits the budget.

    ``min_rows`` is the kernel's structural floor (e.g. the three-slab halo
    trick needs ``block_rows >= halo``). If no divisor fits the budget, the
    smallest divisor >= ``min_rows`` is returned (correctness over budget).
    If ``min_rows`` exceeds every divisor of ``rows`` (i.e. ``rows``
    itself), no tiling can satisfy the kernel's floor — that raises rather
    than silently handing back an undersized tile.
    """
    budget = vmem_tile_budget(budget_bytes)
    if min_rows > rows:
        raise ValueError(
            f"min_rows={min_rows} exceeds every divisor of rows={rows}: the "
            f"grid is too shallow for this kernel's structural floor (halo)"
        )
    fallback = rows
    for cand in range(rows, 0, -1):
        if rows % cand or cand < min_rows:
            continue
        fallback = cand
        if cand * cols * itemsize <= budget:
            return cand
    return fallback
