"""VMEM tile planning shared by the hand-written kernels and the IR lowerer,
plus the 2-D mesh-factorization planner for ``lower_sharded``.

The Pallas grid pipeline keeps ~3 input blocks + 1 output block live and
double-buffers them (the shimDMA ping-pong of §3.2.1), so the per-block
budget sits well under VMEM/8. The budget defaults to 4 MiB and is
configurable per call (``budget_bytes``) or process-wide via the
``REPRO_VMEM_BUDGET`` environment variable (bytes).

:func:`plan_partition` is the SPARTA §3.4 placement question for the 2-D
decomposition: given a device count, which rows x cols factorization
balances the workload at the least wire traffic? It enumerates the feasible
factorizations and minimizes the exact 2-axis ``halo_exchange_bytes`` model
(the one ``benchmarks/fig10_scaling.py`` verifies against measured HLO
collective bytes).
"""

from __future__ import annotations

import dataclasses
import os

DEFAULT_VMEM_TILE_BUDGET = 4 * 1024 * 1024
VMEM_BUDGET_ENV = "REPRO_VMEM_BUDGET"


def vmem_tile_budget(budget_bytes: int | None = None) -> int:
    """Resolves the per-block VMEM budget: explicit arg > env var > 4 MiB.

    A non-positive budget is a configuration error, not a request for
    1-row tiles — it raises instead of silently degrading every kernel."""
    if budget_bytes is not None:
        budget, source = int(budget_bytes), "budget_bytes"
    else:
        env = os.environ.get(VMEM_BUDGET_ENV)
        if not env:
            return DEFAULT_VMEM_TILE_BUDGET
        try:
            budget = int(env)
        except ValueError as e:
            raise ValueError(
                f"{VMEM_BUDGET_ENV} must be an integer byte count, got {env!r}"
            ) from e
        source = VMEM_BUDGET_ENV
    if budget <= 0:
        raise ValueError(
            f"{source} must be a positive byte count, got {budget}"
        )
    return budget


def pick_block_rows(
    rows: int,
    cols: int,
    *,
    itemsize: int = 4,
    budget_bytes: int | None = None,
    min_rows: int = 1,
) -> int:
    """Largest divisor of ``rows`` whose (rows x cols) tile fits the budget.

    ``min_rows`` is the kernel's structural floor (e.g. the three-slab halo
    trick needs ``block_rows >= halo``). If no divisor fits the budget, the
    smallest divisor >= ``min_rows`` is returned (correctness over budget).
    If ``min_rows`` exceeds every divisor of ``rows`` (i.e. ``rows``
    itself), no tiling can satisfy the kernel's floor — that raises rather
    than silently handing back an undersized tile.
    """
    budget = vmem_tile_budget(budget_bytes)
    if min_rows > rows:
        raise ValueError(
            f"min_rows={min_rows} exceeds every divisor of rows={rows}: the "
            f"grid is too shallow for this kernel's structural floor (halo)"
        )
    fallback = rows
    for cand in range(rows, 0, -1):
        if rows % cand or cand < min_rows:
            continue
        fallback = cand
        if cand * cols * itemsize <= budget:
            return cand
    return fallback


# ---------------------------------------------------------------------------
# 2-D (rows x cols) mesh factorization for lower_sharded.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Partition2D:
    """A rows x cols shard factorization chosen by :func:`plan_partition`.

    ``wire_bytes`` is the whole-mesh traffic of ONE halo-exchange round
    under the exact 2-axis model (row bands + col bands + diagonal
    corners), summed per input field for multi-field programs; ``halo`` is
    the deepest exchanged band (the program's full chain radius — k*r for
    ``repeat(p, k)``, one round per k sweeps)."""

    row_shards: int
    col_shards: int
    halo: int
    wire_bytes: int

    @property
    def mesh_shape(self) -> tuple[int, int]:
        """Directly usable as ``lower_sharded(..., mesh_shape=...)``."""
        return (self.row_shards, self.col_shards)


def plan_partition(
    program,
    depth: int,
    rows: int,
    cols: int,
    n_devices: int,
    *,
    itemsize: int = 4,
) -> Partition2D:
    """Picks the rows x cols factorization of ``n_devices`` that minimizes
    the modeled wire bytes per exchange round for ``program`` on a
    (depth, rows, cols) grid.

    A factorization (R, C) is feasible when both grid dims divide evenly
    and each shard keeps at least the program's chain radius of rows/cols
    (the single-neighbour exchange floor ``lower_sharded`` enforces). Ties
    break toward fewer column shards (rows are the paper's native lane
    decomposition; columns are the contiguous/vectorised dim). The result
    never models more traffic than the 1-D row baseline (R=n, C=1) when
    that baseline is feasible — and covers meshes the 1-D baseline cannot
    reach at all (rows/n < halo), the remedy for the fine-mesh error.

    Distinct from ``repro.core.compound.plan_partition`` (depth x rows via
    the three-term roofline): this is the pure wire-traffic question for
    the 2-D spatial decomposition, answered with the byte model that
    ``fig10`` measures exactly.
    """
    # Lazy: repro.dist imports repro.core, which derives constants from
    # this package — importing it at module scope would be a cycle.
    from repro.dist.halo import program_halo_exchange_bytes

    halo = program.radius
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    best: Partition2D | None = None
    for r_sh in range(1, n_devices + 1):
        if n_devices % r_sh:
            continue
        c_sh = n_devices // r_sh
        if rows % r_sh or cols % c_sh:
            continue
        if halo > 0 and (
            (r_sh > 1 and rows // r_sh < halo) or (c_sh > 1 and cols // c_sh < halo)
        ):
            continue
        # Per-field wire sum: for single-input programs this is exactly the
        # old halo_exchange_bytes(halo=radius); multi-field programs add
        # each extra field's own (possibly zero) composed-radius traffic.
        wire = program_halo_exchange_bytes(
            program, depth, rows, cols, r_sh, itemsize=itemsize, col_shards=c_sh
        )
        cand = Partition2D(r_sh, c_sh, halo, wire)
        if (
            best is None
            or cand.wire_bytes < best.wire_bytes
            or (cand.wire_bytes == best.wire_bytes and c_sh < best.col_shards)
        ):
            best = cand
    if best is None:
        raise ValueError(
            f"no rows x cols factorization of {n_devices} devices fits grid "
            f"({rows}, {cols}) with halo {halo} (program {program.name!r}): "
            f"every split leaves a shard thinner than the halo band"
        )
    return best
