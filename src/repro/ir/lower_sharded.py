"""Sharded lowering: shard_map + inferred-radius halo exchange per program.

The B-block scale-out of §3.4, driven entirely by the graph analysis: the
row halo each shard pushes to its neighbours is the program's *inferred*
chain radius (``dist.halo.exchange_row_halos`` with ``halo=r`` — k*r for a
temporally-blocked ``repeat(p, k)``), not a hard-coded constant, and the
per-shard compute composes either the reference evaluator or the fused
Pallas kernel inside the shard — the ROADMAP's
"Pallas-kernel-inside-shard_map" item: VMEM-fused B-block residency *and*
domain decomposition in one step function.

Temporal blocking amortises the wire: a composed program exchanges its
depth-``k*r`` halo ONCE per k fused sweeps, so halo-exchange *rounds* (the
latency term) per simulated step drop k-fold while the exchanged bytes per
round match ``halo_exchange_bytes(..., steps=k)`` exactly.

Global-boundary correctness uses absolute row indexing exactly like
``repro.dist.halo.make_sharded_hdiff``, applied PER SWEEP: every sweep of
the chain re-applies the global boundary ring at true global row indices
(``slab_sweep`` with the shard's row offset), so the zero halos ppermute
delivers at the grid edges are never read into an owned output row and the
k-sweep result bit-matches k single-device applications.

``repro.dist`` is imported lazily (it depends on ``repro.core``, which
derives its constants from this package).
"""

from __future__ import annotations

from typing import Callable

import jax
from jax.sharding import PartitionSpec as P

from repro.ir.evaluate import slab_sweep
from repro.ir.graph import StencilProgram
from repro.ir.lower_pallas import lower_pallas
from repro.ir.lower_reference import lower_reference

Array = jax.Array


def lower_sharded(
    program: StencilProgram,
    mesh,
    *,
    depth_axis: str | None = "data",
    row_axis: str | None = None,
    inner: str = "pallas",
    interpret: bool | None = None,
    vmem_budget: int | None = None,
) -> Callable[[Array], Array]:
    """Builds a jitted ``x (D, R, C) -> x'`` matching the single-device
    program application (all ``program.steps`` sweeps of it) while
    domain-decomposed over ``mesh``.

    Args:
      program: single-input 2-D IR program; a composed program fuses its k
        sweeps behind one depth-``k*r`` halo exchange.
      mesh: device mesh; axes named by ``depth_axis`` / ``row_axis``.
      depth_axis: mesh axis sharding dim 0 (planes, zero collectives), or None.
      row_axis: mesh axis sharding dim 1 (rows, halo exchange at the
        program's inferred chain radius), or None for pure depth parallelism.
      inner: per-shard compute — "pallas" (fused VMEM kernel inside the
        shard) or "reference" (jnp evaluator).
      interpret / vmem_budget: forwarded to the Pallas lowering.
    """
    from repro.dist.halo import exchange_row_halos
    from repro.dist.sharding import _mesh_sizes

    if program.ndim != 2 or len(program.inputs) != 1:
        raise ValueError("sharded lowering needs a single-input 2-D program")
    if inner not in ("pallas", "reference"):
        raise ValueError(f"unknown inner backend {inner!r}")

    sizes = _mesh_sizes(mesh)
    for ax in (depth_axis, row_axis):
        if ax is not None and ax not in sizes:
            raise ValueError(f"mesh {tuple(sizes)} has no axis {ax!r}")
    if depth_axis is not None and depth_axis == row_axis:
        raise ValueError("depth_axis and row_axis must be distinct mesh axes")
    n_row = sizes[row_axis] if row_axis is not None else 1
    n_depth = sizes[depth_axis] if depth_axis is not None else 1

    halo = program.radius  # full chain radius; exchanged once per k sweeps

    if inner == "pallas":
        apply_full = lower_pallas(program, interpret=interpret, vmem_budget=vmem_budget)
    else:
        apply_full = lower_reference(program, mode="fused")

    spec = P(depth_axis, row_axis if n_row > 1 else None, None)

    def local_step(block: Array) -> Array:
        if row_axis is None or n_row == 1 or halo == 0:
            # Full rows present locally (or no row coupling at all): the
            # single-device lowering's boundary handling is already correct.
            return apply_full(block)
        r_loc = block.shape[-2]
        r_glob = r_loc * n_row
        padded = exchange_row_halos(block, row_axis, n_row, halo=halo)
        # Global row index of the padded block's first row: the per-sweep
        # ring passthrough runs at TRUE global indices, so ring rows owned
        # by this shard hold exactly what k stepped applications leave
        # there, and the zero halos at the grid edges are never read into
        # an owned row. No post-hoc ownership mask is needed.
        off = jax.lax.axis_index(row_axis) * r_loc - halo

        if inner == "pallas":
            # Fused k-sweep kernel on the padded block with global row ids;
            # the owned rows are the exact interior of the padded result.
            vals = apply_full(padded, row_offset=off, rows_global=r_glob)
            vals = vals[..., halo : halo + r_loc, :]
        else:
            vals = slab_sweep(program, padded, off, r_glob)  # (..., r_loc, C)
        return vals.astype(block.dtype)

    mapped = jax.shard_map(
        local_step, mesh=mesh, in_specs=(spec,), out_specs=spec, check_vma=False
    )

    @jax.jit
    def step(x: Array) -> Array:
        if x.ndim != 3:
            raise ValueError(f"expected (depth, rows, cols), got shape {x.shape}")
        d, r, _ = x.shape
        if n_depth > 1 and d % n_depth:
            raise ValueError(f"depth {d} not divisible by {n_depth} {depth_axis!r} shards")
        if n_row > 1:
            if r % n_row:
                raise ValueError(f"rows {r} not divisible by {n_row} {row_axis!r} shards")
            if r // n_row < halo:
                raise ValueError(
                    f"rows/shard {r // n_row} < inferred halo {halo} (chain "
                    f"radius of {program.name!r}): too many row shards for "
                    f"the single-neighbour halo exchange"
                )
        return mapped(x)

    return step
