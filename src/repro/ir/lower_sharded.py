"""Sharded lowering: shard_map + inferred-radius halo exchange per program.

The B-block scale-out of §3.4, driven entirely by the graph analysis: the
halo each shard pushes to its neighbours is the program's *inferred* chain
radius (k*r for a temporally-blocked ``repeat(p, k)``), not a hard-coded
constant, and the per-shard compute composes either the reference evaluator
or the fused Pallas kernel inside the shard — the ROADMAP's
"Pallas-kernel-inside-shard_map" item: VMEM-fused B-block residency *and*
domain decomposition in one step function.

Multi-field programs shard every declared input identically and exchange
halos PER FIELD at each field's composed radius (``field_radii``): the
evolving state moves the full chain radius, a velocity field its own reach,
and a radius-0 coefficient field moves NOTHING — zero wire bytes, which
``dist.halo.program_halo_exchange_bytes`` models exactly (measured-exact in
fig10/fig13). Exchanged aux fields are zero-padded up to the state's halo
grid so every field shares one coordinate system inside the shard; the pads
are never read into a kept output point.

Multi-OUTPUT programs (coupled systems — shallow-water's {u, v, h}) issue
ONE MERGED halo exchange covering all evolving fields per k sweeps: fields
needing the same band depth and dtype are stacked along a fresh leading
axis so each ppermute carries every field's band in a single message
(``merge_exchange=True``, the default) — same wire BYTES as per-field
exchanges (``program_halo_exchange_bytes`` sums the per-field terms either
way, still measured-exact) but one permute family instead of N, cutting the
per-round message count / latency term N-fold. ``merge_exchange=False``
keeps the sequential per-field exchanges (the comparison baseline
``benchmarks/fig13_multifield.py`` measures). The step returns ``{field:
array}`` with every output's updated full-shape state.

Domain decomposition is 2-D (rows x cols), like the paper's 2-D AIE array:
``row_axis`` and/or ``col_axis`` name mesh axes (or pass ``mesh_shape=(R,
C)`` to build a ("rows", "cols") mesh over the default devices), and
``dist.halo.exchange_halos_2d`` moves the row/col bands plus the four
diagonal corners. A grid too fine for row sharding (rows/shard < halo) can
therefore shard columns instead — the remedy the 1-D fine-mesh error now
points at.

``overlap=True`` splits every shard's work into interior compute — which
needs NO halo and is issued concurrently with the edge exchange, so XLA's
latency-hiding scheduler can run the ppermutes behind it — and the
radius-halo edge bands computed from the padded block afterwards. Both
pieces run the same ``slab_sweep`` slices over the same values (the edge
bands upcast to float32 when the inner is Pallas, mirroring the kernel),
so ``overlap=True`` bit-matches ``overlap=False`` — verified exactly on
the CPU/interpret test paths; on real TPU hardware the Mosaic-compiled
kernel and the XLA-fused edge bands may differ at the last ulp.

Temporal blocking amortises the wire: a composed program exchanges its
depth-``k*r`` halo ONCE per k fused sweeps, so halo-exchange *rounds* (the
latency term) per simulated step drop k-fold while the exchanged bytes per
round match ``halo_exchange_bytes(..., steps=k)`` exactly.

Global-boundary correctness uses absolute row AND column indexing, applied
PER SWEEP: every sweep of the chain re-applies the global boundary ring at
true global indices (``slab_sweep`` with the shard's row/col offsets), so
the zero halos ppermute delivers at the grid edges are never read into an
owned output point and the k-sweep result bit-matches k single-device
applications.

``repro.dist`` is imported lazily (it depends on ``repro.core``, which
derives its constants from this package).
"""

from __future__ import annotations

from typing import Callable, Mapping

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.ir.evaluate import resolve_field_arrays, slab_sweep
from repro.ir.graph import StencilProgram
from repro.ir.lower_pallas import lower_pallas
from repro.ir.lower_reference import lower_reference
from repro.obs import events, metrics

Array = jax.Array

# Sentinel: distinguishes "caller did not pass depth_axis" (defaults to
# "data", or to None when mesh_shape builds the mesh) from an explicit one.
_DEPTH_DEFAULT = "__default_depth_axis__"


def lower_sharded(
    program: StencilProgram,
    mesh=None,
    *,
    depth_axis: str | None = _DEPTH_DEFAULT,
    row_axis: str | None = None,
    col_axis: str | None = None,
    mesh_shape: tuple[int, int] | None = None,
    overlap: bool = False,
    inner: str = "pallas",
    interpret: bool | None = None,
    vmem_budget: int | None = None,
    merge_exchange: bool = True,
    boundary: str = "ring",
) -> Callable[[Array], Array]:
    """Builds a jitted ``x (D, R, C) -> x'`` matching the single-device
    program application (all ``program.steps`` sweeps of it) while
    domain-decomposed over ``mesh``. Multi-output programs return
    ``{field: array}`` — one updated full-shape state per evolving field,
    exactly like the single-device lowerings.

    Args:
      program: 2-D IR program; a composed program fuses its k sweeps behind
        one depth-``k*r`` halo exchange.
      mesh: device mesh; axes named by ``depth_axis`` / ``row_axis`` /
        ``col_axis``. Mutually exclusive with ``mesh_shape``.
      depth_axis: mesh axis sharding dim 0 (planes, zero collectives), or None.
      row_axis: mesh axis sharding dim 1 (rows, halo exchange at the
        program's inferred chain radius), or None.
      col_axis: mesh axis sharding dim 2 (cols, symmetric halo exchange +
        diagonal corner traffic when rows are sharded too), or None.
      mesh_shape: ``(R, C)`` — build a rows x cols mesh over the first
        ``R * C`` default devices (axes named "rows"/"cols", no depth
        sharding) instead of passing ``mesh``; the factorization
        :func:`repro.ir.plan.plan_partition` picks.
      overlap: issue interior compute (halo-free) concurrently with the
        edge exchange, then fill the radius-halo edge bands — async
        halo/compute overlap. Bit-matches ``overlap=False``. The split
        activates only when the shard interior is non-empty (rows/shard >
        2*halo, and cols/shard > 2*halo when columns are sharded); thinner
        shards fall back to the serialized exchange-then-compute path
        (identical results, nothing left to overlap).
      inner: per-shard compute — "pallas" (fused VMEM kernel inside the
        shard) or "reference" (jnp evaluator). Under ``overlap=True`` the
        inner backend computes the interior; the thin edge bands always use
        the jnp evaluator.
      interpret / vmem_budget: forwarded to the Pallas lowering.
      merge_exchange: stack same-(radius, dtype) fields into ONE halo
        exchange per round (default) instead of one exchange per field —
        identical wire bytes, N-fold fewer permute messages for an N-field
        coupled system. Results are bit-identical either way (the stacked
        bands hold exactly the per-field bands).
      boundary: "ring" (default) applies the program's absolute-index
        global boundary ring — the forward semantics every lowering
        matches. "zero" instead computes EVERY owned point with ZERO
        extension beyond the global grid: the zero bands ``ppermute``
        already delivers at uncovered grid edges ARE the extension, so the
        mode costs exactly the same exchange round and no extra
        collectives. This is the evaluation the derived adjoint sweeps
        need (``repro.ir.autodiff``): cotangents exist at ring points too,
        and padding the sharded global grid instead would migrate shard
        boundaries — GSPMD inserts its own collective-permutes for that,
        polluting the measured-exact wire model. Single-sweep programs
        only.
    """
    from repro.dist.halo import (
        exchange_halos_2d,
        exchange_row_halos,
        program_exchange_radii,
    )
    from repro.dist.sharding import _mesh_sizes

    if program.ndim != 2:
        raise ValueError("sharded lowering needs a 2-D program")
    if inner not in ("pallas", "reference"):
        raise ValueError(f"unknown inner backend {inner!r}")
    if boundary not in ("ring", "zero"):
        raise ValueError(f"unknown boundary mode {boundary!r}")
    if boundary == "zero" and program.steps != 1:
        raise ValueError(
            "boundary='zero' evaluates one merged DAG with zero extension; "
            "chains thread per-sweep rings — lower each chain entry "
            "separately (repro.ir.autodiff does)"
        )

    if mesh_shape is not None:
        if mesh is not None:
            raise ValueError("pass either mesh or mesh_shape, not both")
        if depth_axis != _DEPTH_DEFAULT or row_axis is not None or col_axis is not None:
            raise ValueError(
                "mesh_shape fixes the mesh axes to (rows, cols) with no depth "
                "sharding; don't pass depth_axis/row_axis/col_axis with it — "
                "build the mesh yourself to name axes"
            )
        from repro.launch.mesh import make_mesh

        r_sh, c_sh = mesh_shape
        mesh = make_mesh((int(r_sh), int(c_sh)), ("rows", "cols"))
        depth_axis, row_axis, col_axis = None, "rows", "cols"
    else:
        if mesh is None:
            raise ValueError("lower_sharded needs a mesh (or mesh_shape=(R, C))")
        if depth_axis == _DEPTH_DEFAULT:
            depth_axis = "data"

    sizes = _mesh_sizes(mesh)
    axis_names = tuple(sizes)  # mesh declaration order (corner pair numbering)
    axes = {"depth_axis": depth_axis, "row_axis": row_axis, "col_axis": col_axis}
    for role, ax in axes.items():
        if ax is not None and ax not in sizes:
            raise ValueError(f"mesh {tuple(sizes)} has no axis {ax!r} ({role})")
    named = [ax for ax in axes.values() if ax is not None]
    if len(set(named)) != len(named):
        raise ValueError("depth_axis, row_axis and col_axis must be distinct mesh axes")
    n_row = sizes[row_axis] if row_axis is not None else 1
    n_col = sizes[col_axis] if col_axis is not None else 1
    n_depth = sizes[depth_axis] if depth_axis is not None else 1

    halo = program.radius  # full chain radius; exchanged once per k sweeps
    fields = program.inputs
    state_f = program.passthrough
    out_fields = tuple(program.outputs)
    n_out = len(out_fields)
    aux_fields = tuple(f for f in fields if f not in program.outputs)
    # Per-field exchanged halo (shared rule with the byte models): every
    # evolving field moves the full chain radius, every other field only
    # its own composed access radius — a radius-0 coefficient field is
    # exchanged NOT AT ALL (zero wire bytes for it, matching
    # dist.halo.program_halo_exchange_bytes exactly).
    fhalos = program_exchange_radii(program)

    if inner == "pallas":
        apply_full = lower_pallas(program, interpret=interpret, vmem_budget=vmem_budget)
    else:
        apply_full = lower_reference(program, mode="fused")

    spec = P(
        depth_axis,
        row_axis if n_row > 1 else None,
        col_axis if n_col > 1 else None,
    )

    def _as_dict(vals):
        """Normalises an inner-backend result to {output_field: array}."""
        return dict(vals) if isinstance(vals, Mapping) else {state_f: vals}

    def _full_input(states, aux):
        """The apply_full argument: bare array or field mapping."""
        if n_out == 1 and not aux_fields:
            return states[state_f]
        return {**aux, **states}

    def _offsets(block: Array):
        """Global index of the shard block's first row/col (pre-padding)."""
        r_loc, c_loc = block.shape[-2], block.shape[-1]
        off_r = jax.lax.axis_index(row_axis) * r_loc if n_row > 1 else 0
        off_c = jax.lax.axis_index(col_axis) * c_loc if n_col > 1 else 0
        return off_r, off_c, r_loc * n_row, c_loc * n_col

    def _exchange(a: Array, hf: int) -> Array:
        if n_col > 1:
            return exchange_halos_2d(
                a, row_axis, col_axis, n_row, n_col, hf,
                mesh_axis_names=axis_names,
            )
        return exchange_row_halos(a, row_axis, n_row, halo=hf)

    def _exchange_all(env):
        """One round of halo exchange for every field with a nonzero
        exchanged radius -> {field: exchanged block}.

        ``merge_exchange=True`` groups fields by (band depth, dtype) and
        stacks each group along a fresh leading axis, so ONE exchange (one
        ppermute per band/corner direction) carries every stacked field's
        band in a single message — the merged coupled-system exchange. The
        stacked bands are exactly the per-field bands, so unstacking
        reproduces the sequential exchanges bit-for-bit, and the wire BYTES
        are identical (``program_halo_exchange_bytes`` stays measured-exact
        under either mode)."""
        need = [f for f in fields if fhalos[f]]
        out = {}
        if not merge_exchange:
            for f in need:
                out[f] = _exchange(env[f], fhalos[f])
            return out
        groups: dict[tuple, list[str]] = {}
        for f in need:
            groups.setdefault((fhalos[f], env[f].dtype), []).append(f)
        for (hf, _dt), grp in groups.items():
            if len(grp) == 1:
                out[grp[0]] = _exchange(env[grp[0]], hf)
            else:
                stacked = _exchange(jnp.stack([env[f] for f in grp]), hf)
                for j, f in enumerate(grp):
                    out[f] = stacked[j]
        return out

    def _pad_to_halo(a: Array, hf: int) -> Array:
        """Zero-pads a radius-``hf``-exchanged block out to the state's
        ``halo`` grid so all fields stay aligned (rows always; cols too when
        columns are sharded). The zero pad is never read into a kept output
        point: reads reach at most ``hf`` past the kept region, which the
        exchange covered with true values."""
        pw = halo - hf
        if pw == 0:
            return a
        pad = [(0, 0)] * (a.ndim - 2)
        pad.append((pw, pw))
        pad.append((pw, pw) if n_col > 1 else (0, 0))
        return jnp.pad(a, pad)

    def _inner_padded(padded_states, padded_aux, off_r, off_c, r_glob, c_glob,
                      r_loc, c_loc):
        """Whole-shard compute on the halo-padded blocks ->
        {output field: (r_loc, c_loc) block}."""
        if inner == "pallas":
            if n_col > 1:
                vals = _as_dict(apply_full(
                    _full_input(padded_states, padded_aux),
                    row_offset=off_r - halo, rows_global=r_glob,
                    col_offset=off_c - halo, cols_global=c_glob,
                ))
                return {
                    f: v[..., halo : halo + r_loc, halo : halo + c_loc]
                    for f, v in vals.items()
                }
            vals = _as_dict(apply_full(
                _full_input(padded_states, padded_aux),
                row_offset=off_r - halo, rows_global=r_glob,
            ))
            return {f: v[..., halo : halo + r_loc, :] for f, v in vals.items()}
        extras = padded_aux or None
        state = padded_states[state_f] if n_out == 1 else padded_states
        if n_col > 1:
            vals = slab_sweep(program, state, off_r - halo, r_glob,
                              off_c - halo, c_glob, extras=extras)
        else:
            vals = slab_sweep(program, state, off_r - halo, r_glob, extras=extras)
        return _as_dict(vals)

    def _inner_interior(states, aux, off_r, off_c, r_glob, c_glob):
        """Halo-free interior compute on the UNPADDED blocks: output rows
        [halo, r_loc-halo) (and cols likewise when columns are sharded) —
        no data dependency on the exchange, so it can overlap it. Returns
        {output field: interior block}."""
        block = states[state_f]
        r_loc, c_loc = block.shape[-2], block.shape[-1]
        if inner == "pallas":
            if n_col > 1:
                vals = _as_dict(apply_full(
                    _full_input(states, aux),
                    row_offset=off_r, rows_global=r_glob,
                    col_offset=off_c, cols_global=c_glob,
                ))
                return {
                    f: v[..., halo : r_loc - halo, halo : c_loc - halo]
                    for f, v in vals.items()
                }
            vals = _as_dict(apply_full(
                _full_input(states, aux), row_offset=off_r, rows_global=r_glob
            ))
            return {f: v[..., halo : r_loc - halo, :] for f, v in vals.items()}
        extras = aux or None
        state = states[state_f] if n_out == 1 else states
        if n_col > 1:
            vals = slab_sweep(program, state, off_r, r_glob, off_c, c_glob,
                              extras=extras)
        else:
            vals = slab_sweep(program, state, off_r, r_glob, extras=extras)
        return _as_dict(vals)

    def _edge_bands(padded_states, padded_aux, off_r, off_c, r_glob, c_glob,
                    r_loc, c_loc):
        """The four radius-``halo`` edge bands of the shard's output, each
        one ``inner``-backend sweep over a static slice of the padded blocks
        (top/bottom span all owned cols; left/right cover the remaining
        interior rows). Aux fields ride the SAME slices — they live on the
        same padded grid, so one slicer keeps every field aligned. Each
        band is a {output field: block} dict."""
        h = halo

        def sweep(rows_sl, cols_sl, row0, col0):
            slabs = {
                f: a[..., rows_sl, cols_sl] for f, a in padded_states.items()
            }
            ex = {f: a[..., rows_sl, cols_sl] for f, a in padded_aux.items()}
            if inner == "pallas":
                # Bands go through the SAME Pallas kernel as the interior:
                # XLA may contract mul+add chains (FMA) differently per
                # compiled graph, so a jnp-evaluated band next to a
                # Pallas-computed interior breaks the overlap bit-match
                # contract for product-bearing programs (the advection
                # term u*dc/dx + v*dc/dy of advection_diffusion).
                if n_col > 1:
                    vals = _as_dict(apply_full(
                        _full_input(slabs, ex),
                        row_offset=row0, rows_global=r_glob,
                        col_offset=col0, cols_global=c_glob,
                    ))
                    return {f: v[..., h:-h, h:-h] for f, v in vals.items()}
                vals = _as_dict(apply_full(
                    _full_input(slabs, ex), row_offset=row0, rows_global=r_glob
                ))
                return {f: v[..., h:-h, :] for f, v in vals.items()}
            ex = ex or None
            state = slabs[state_f] if n_out == 1 else slabs
            if n_col > 1:
                return _as_dict(slab_sweep(program, state, row0, r_glob, col0,
                                           c_glob, extras=ex))
            return _as_dict(slab_sweep(program, state, row0, r_glob, extras=ex))

        full = slice(None)
        top = sweep(slice(None, 3 * h), full, off_r - h, off_c - h)
        bottom = sweep(slice(-3 * h, None), full, off_r + r_loc - 2 * h, off_c - h)
        if n_col == 1:
            return top, bottom, None, None
        left = sweep(slice(h, h + r_loc), slice(None, 3 * h), off_r, off_c - h)
        right = sweep(
            slice(h, h + r_loc), slice(-3 * h, None), off_r, off_c + c_loc - 2 * h
        )
        return top, bottom, left, right

    def _ret(vals):
        """shard_map return: bare array (single-output) or tuple in
        ``out_fields`` order (multi-output)."""
        if n_out == 1:
            return vals[state_f]
        return tuple(vals[f] for f in out_fields)

    def local_step(*blocks: Array):
        env = dict(zip(fields, blocks))
        states = {f: env[f] for f in out_fields}
        aux = {f: env[f] for f in aux_fields}
        if halo == 0 or (boundary == "ring" and n_row == 1 and n_col == 1):
            # Full grid present locally (or no spatial coupling at all): the
            # single-device lowering's boundary handling is already correct.
            # (Zero mode with halo > 0 still needs its zero extension, which
            # the general path's single-shard zero pads provide for free.)
            return _ret(_as_dict(apply_full(_full_input(states, aux))))
        block = states[state_f]
        r_loc, c_loc = block.shape[-2], block.shape[-1]

        if boundary == "zero":
            # Every owned point computed from the exchanged block; the zero
            # bands at uncovered grid edges (ppermute fill / single-shard
            # pads) are the wanted extension, so the single-device kernel's
            # OWN ring — evaluated on garbage halo-edge data — lands
            # entirely in the sliced-off frame. Columns get a local zero pad
            # when unsharded (free: no collective), keeping the kept region
            # at [halo:halo+r_loc, halo:halo+c_loc] either way.
            exchanged = _exchange_all(env)
            zs = {f: exchanged[f] for f in out_fields}
            za = {
                f: _pad_to_halo(exchanged.get(f, aux[f]), fhalos[f])
                for f in aux_fields
            }
            if n_col == 1:
                cp = [(0, 0)] * (block.ndim - 1) + [(halo, halo)]
                zs = {f: jnp.pad(a, cp) for f, a in zs.items()}
                za = {f: jnp.pad(a, cp) for f, a in za.items()}
            vals = _as_dict(apply_full(_full_input(zs, za)))
            return _ret({
                f: vals[f][..., halo : halo + r_loc, halo : halo + c_loc]
                .astype(states[f].dtype)
                for f in out_fields
            })

        off_r, off_c, r_glob, c_glob = _offsets(block)

        # overlap needs a non-empty interior after shaving the halo bands.
        can_overlap = overlap and r_loc > 2 * halo and (n_col == 1 or c_loc > 2 * halo)
        if can_overlap:
            # Interior first in program order: it reads only the unpadded
            # blocks, so the exchange's ppermutes have no consumers before it
            # and the latency-hiding scheduler is free to run them behind it.
            interior = _inner_interior(states, aux, off_r, off_c, r_glob, c_glob)

        # ONE merged exchange round covers every field that moves (all
        # evolving fields at the chain radius, aux fields at their own).
        exchanged = _exchange_all(env)
        padded_states = {f: exchanged[f] for f in out_fields}
        padded_aux = {
            f: _pad_to_halo(exchanged.get(f, aux[f]), fhalos[f])
            for f in aux_fields
        }

        if not can_overlap:
            vals = _inner_padded(
                padded_states, padded_aux, off_r, off_c, r_glob, c_glob,
                r_loc, c_loc,
            )
            return _ret({f: vals[f].astype(states[f].dtype) for f in out_fields})

        top, bottom, left, right = _edge_bands(
            padded_states, padded_aux, off_r, off_c, r_glob, c_glob, r_loc, c_loc
        )
        out = {}
        for f in out_fields:
            mid = interior[f]
            if n_col > 1:
                mid = jnp.concatenate([left[f], mid, right[f]], axis=-1)
            vals = jnp.concatenate([top[f], mid, bottom[f]], axis=-2)
            out[f] = vals.astype(states[f].dtype)
        return _ret(out)

    mapped = jax.shard_map(
        local_step,
        mesh=mesh,
        in_specs=(spec,) * len(fields),
        out_specs=spec if n_out == 1 else (spec,) * n_out,
        check_vma=False,
    )

    @jax.jit
    def _run(arrays):
        return mapped(*arrays)

    def _record_halo_model(arrays) -> None:
        """Per-field PER-CHIP model bytes for the exchange this call issues
        — the ``halo.model_bytes.<field>`` counters the drift detector
        compares against measured collective-permute bytes
        (``repro.dist.halo.wire_drift_report``) — plus a ``halo.exchange``
        flight-recorder event per round. Skipped while tracing: a
        lowered-but-instrumented step must not count trace-time calls."""
        reg = metrics.current()
        if (reg is None and events.current() is None) or metrics.has_tracer(arrays):
            return
        events.record(
            "halo.exchange", program=program.name, halo=halo,
            fields=[f for f in fields if fhalos[f]], merged=merge_exchange,
        )
        if reg is None:
            return
        from repro.dist.halo import halo_exchange_bytes_per_shard

        d, r, c = arrays[0].shape
        reg.inc("halo.exchange_rounds")
        for f, a in zip(fields, arrays):
            hf = fhalos[f]
            if hf:
                reg.inc(
                    f"halo.model_bytes.{f}",
                    halo_exchange_bytes_per_shard(
                        d // n_depth, r // n_row, c // n_col,
                        itemsize=a.dtype.itemsize, halo=hf,
                        row_sharded=n_row > 1, col_sharded=n_col > 1,
                    ),
                )

    def step(x: Array | Mapping[str, Array]) -> Array:
        arrays = resolve_field_arrays(program, x, ndim=3)
        d, r, c = arrays[0].shape
        if n_depth > 1 and d % n_depth:
            raise ValueError(f"depth {d} not divisible by {n_depth} {depth_axis!r} shards")
        for extent, n_sh, ax, what, remedy in (
            (r, n_row, row_axis, "rows", "columns (col_axis=...)"),
            (c, n_col, col_axis, "cols", "rows (row_axis=...)"),
        ):
            if n_sh > 1:
                if extent % n_sh:
                    raise ValueError(
                        f"{what} {extent} not divisible by {n_sh} {ax!r} shards"
                    )
                if extent // n_sh < halo:
                    raise ValueError(
                        f"{what}/shard {extent // n_sh} < inferred halo {halo} "
                        f"(chain radius of {program.name!r}): too many {what} "
                        f"shards for the single-neighbour halo exchange — use "
                        f"fewer, or shard {remedy} instead"
                    )
        if halo > 0 and (n_row > 1 or n_col > 1):
            _record_halo_model(arrays)
        out = _run(arrays)
        if n_out == 1:
            return out
        return dict(zip(out_fields, out))

    return metrics.instrument_call(step, f"ir.lower_sharded.{program.name}")
