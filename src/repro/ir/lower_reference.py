"""Reference lowering: stage-at-a-time jnp execution of an IR program.

Two modes, matching the execution policies of ``repro.core.compound``:

  * ``fused``  — one jitted function; XLA fuses the whole DAG (the paper's
    algorithm on the default compiler path).
  * ``staged`` — every op is a separately jitted function with
    ``block_until_ready`` barriers, so each intermediate field round-trips
    through HBM (the single-AIE / load-store baseline of Fig. 9).
"""

from __future__ import annotations

from typing import Callable, Mapping

import jax

from repro.ir.evaluate import apply_program, embed_interior, op_views, thread_chain
from repro.ir.graph import StencilProgram
from repro.obs import metrics

Array = jax.Array


def lower_reference(
    program: StencilProgram, *, mode: str = "fused"
) -> Callable[[Array | Mapping[str, Array]], Array]:
    # instrument_call: per-call wall-clock timer + call counter under the
    # repro.obs registry (no-op when metrics are disabled; steps aside when
    # traced inside an enclosing jit/shard_map, e.g. by lower_sharded).
    name = f"ir.lower_reference.{program.name}.{mode}"
    if mode == "fused":
        # apply_program is chain-aware: a composed program applies its
        # sweeps in sequence with the ring passthrough between them.
        return metrics.instrument_call(jax.jit(lambda x: apply_program(program, x)), name)
    if mode == "staged":
        if program.steps == 1:
            return metrics.instrument_call(_lower_staged(program), name)
        runs = [(p, _lower_staged(p)) for p in program.chain]
        # thread_chain owns the multi-field sweep-threading convention
        # (evolving passthrough field, shared inputs), shared verbatim with
        # evaluate.apply_program so the two backends cannot drift.
        return metrics.instrument_call(lambda x: thread_chain(program, x, runs), name)
    raise ValueError(f"unknown mode {mode!r} (want 'fused' or 'staged')")


def _lower_staged(program: StencilProgram):
    nd = program.ndim
    margins = program.margins()

    def make_stage(op):
        reads = op.reads

        @jax.jit
        def stage(*arrays):
            # Recover the source-grid extent from the first read's array
            # (each field is stored inset by its own margins).
            f0 = reads[0].field
            lo0, hi0 = margins[f0]
            grid = tuple(
                arrays[0].shape[-nd + d] + lo0[d] + hi0[d] for d in range(nd)
            )
            env = {read.field: arr for read, arr in zip(reads, arrays)}
            return op.compute(*op_views(op, env, margins, grid, nd))

        return stage

    stages = [(op, make_stage(op)) for op in program.ops]

    embeds = {
        f: jax.jit(
            lambda base, interior, _f=f: embed_interior(
                program, base, interior, output=_f
            )
        )
        for f in program.outputs
    }

    def run(x):
        if isinstance(x, Mapping):
            env = dict(x)
        else:
            if len(program.inputs) != 1:
                raise ValueError(
                    f"program {program.name!r} has inputs {program.inputs}; "
                    "pass a mapping"
                )
            env = {program.inputs[0]: x}
        for op, stage in stages:
            args = tuple(env[r.field] for r in op.reads)
            env[op.name] = jax.block_until_ready(stage(*args))
        out = {
            f: embeds[f](env[f], env[op_name])
            for f, op_name in program.outputs.items()
        }
        if len(out) > 1:
            return out
        return out[program.passthrough]

    return run
