"""Fused-Pallas lowering: generic VMEM-resident tile codegen for IR programs.

Generalises the hand-fused hdiff kernel (``repro.kernels.hdiff.kernel``) to
any 2-D program: one program instance owns one row-tile of one plane; the
row halo is provided by the three-slab trick (each input passed with block
index maps ``i-1 / i / i+1``, clamped at the edges), and the whole DAG is
evaluated in VMEM by ``interior_eval`` — intermediates never touch HBM, the
paper's accumulator-residency discipline. Block shape comes from the shared
VMEM budget planner (``repro.ir.plan``).

Multi-field programs get N input refs, one per declared field, each with a
three-slab halo sized by THAT field's composed radius (``field_radii``): the
evolving state carries the full chain radius, a destaggered velocity its own
smaller reach, and a radius-0 coefficient field streams exactly one block
per tile with no neighbour fetches at all. Shallower-halo fields are
zero-padded up to the common state grid inside the kernel — pad rows are
never read into a kept output point, which is what keeps the padding free.

Temporal blocking is first-class: a composed program (``repeat(p, k)``)
loads its tile ONCE with a depth-``k*r`` halo and applies the chain's k
sweeps while the data stays VMEM-resident, re-applying the global boundary
ring between sweeps with ABSOLUTE row indices (``slab_sweep``) so the k-step
kernel bit-matches k full-shape applications. Compulsory HBM traffic per
simulated step drops ~k-fold — the generalisation of the hard-coded
two-step trick that ``kernels/hdiff/multistep.py`` now wraps.

The absolute indexing takes a traced ``(row_offset, rows_global,
col_offset, cols_global)`` tuple through SMEM, so the same kernel runs
standalone (offsets 0) and inside a ``shard_map`` shard (offsets from
``axis_index``; see ``lower_sharded``) — including column slabs of a 2-D
rows x cols domain decomposition, where the global column ring is applied
by absolute index exactly like rows.

1-D programs (jacobi1d) lower to a row-per-program kernel with the column
halo handled in-tile, mirroring ``kernels.stencil2d.jacobi1d_pallas``.
"""

from __future__ import annotations

import functools
from typing import Callable, Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.ir.evaluate import (
    interior_eval,
    resolve_field_arrays,
    ring_crop,
    slab_sweep,
)
from repro.ir.graph import StencilProgram
from repro.ir.plan import pick_block_rows, vmem_tile_budget
from repro.obs import metrics

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _embed_cols(cur: Array, interior: Array, r: int) -> Array:
    """Writes ``interior`` into ``cur``'s column ring interior [r, C-r)."""
    if r == 0:
        return interior
    cols = cur.shape[-1]
    return cur.at[..., r : cols - r].set(interior)


def _generic_kernel(
    *refs, program, block_rows, halo, col_sharded, field_halos,
):
    """Kernel body: blocks are (1, block_rows, C); grid is (depth, row_tiles).

    ``refs`` lays out, per input field in ``program.inputs`` order, a
    ``(prev, cur, next)`` three-slab triple when that field's halo is
    nonzero or a lone ``cur`` when it is zero, followed by ``meta_ref`` and
    one OUTPUT ref per evolving field (``program.outputs`` order — a
    coupled system writes all its updated fields from the one fused VMEM
    residency). ``field_halos[f]`` is the field's composed chain radius —
    every evolving field carries the program's full chain radius ``halo``
    (its ring rows must hold true values for the passthrough), every other
    field only the rows it is actually read at (a radius-0 coefficient
    fetches ONE block, no neighbours). Fields with a shallower halo are
    zero-padded up to the common ``halo`` grid — the pad rows are provably
    never read into a kept output point (reads reach at most the field's
    composed radius past the kept region).

    Each of the chain's sweeps shrinks the state slab by its own radius
    while re-applying the global radius-r ring at ABSOLUTE row indices
    (``meta_ref`` holds the traced ``(row_offset, rows_global, col_offset,
    cols_global)`` tuple — ``(0, rows, 0, cols)`` standalone, the shard's
    global placement under ``lower_sharded``); non-evolving fields feed
    every sweep through grid-aligned views (``slab_sweep`` extras).

    ``col_sharded`` (static) selects the column mode: False keeps the
    full-width sweep (columns never tiled — the array carries the whole
    global column extent, local column ring); True runs the column-slab
    sweep for 2-D domain decomposition — the array's outer ``halo`` columns
    are the shard's column halo, the sweep shrinks them away, and the
    result is re-embedded so the output block keeps the input width (the
    caller slices the stale halo columns off).
    """
    out_fields = tuple(program.outputs)
    n_out = len(out_fields)
    out_refs = refs[-n_out:]
    meta_ref = refs[-n_out - 1]
    i = pl.program_id(1)
    it = iter(refs[: -n_out - 1])
    slabs: dict[str, jax.Array] = {}
    state_curs: dict[str, jax.Array] = {}
    for f in program.inputs:
        hf = field_halos[f]
        if hf:
            prev_ref, cur_ref, next_ref = next(it), next(it), next(it)
            cur = cur_ref[0].astype(jnp.float32)
            x = jnp.concatenate(
                [
                    prev_ref[0, -hf:, :].astype(jnp.float32),
                    cur,
                    next_ref[0, :hf, :].astype(jnp.float32),
                ],
                axis=0,
            )  # (block_rows + 2*hf, C)
        else:
            cur = next(it)[0].astype(jnp.float32)
            x = cur
        if hf < halo:
            pad = jnp.zeros((halo - hf, x.shape[-1]), jnp.float32)
            x = jnp.concatenate([pad, x, pad], axis=0)
        slabs[f] = x
        if f in program.outputs:
            state_curs[f] = cur
    states = {f: slabs.pop(f) for f in out_fields}
    # Single-output programs sweep the bare state array (the legacy,
    # bit-tested path); coupled systems thread the {field: slab} dict.
    state = states[program.passthrough] if n_out == 1 else states
    extras = slabs or None
    base = meta_ref[0, 0] + i * block_rows - halo  # global id of states' first row
    if not col_sharded or halo == 0:
        vals = slab_sweep(program, state, base, meta_ref[0, 1], extras=extras)
        if n_out == 1:
            vals = {program.passthrough: vals}
        for f, out_ref in zip(out_fields, out_refs):
            out_ref[0] = vals[f].astype(out_ref.dtype)
        return
    vals = slab_sweep(
        program, state, base, meta_ref[0, 1], meta_ref[0, 2], meta_ref[0, 3],
        extras=extras,
    )  # (block_rows, C - 2*halo) per output
    if n_out == 1:
        vals = {program.passthrough: vals}
    for f, out_ref in zip(out_fields, out_refs):
        cur = state_curs[f]
        width = cur.shape[-1]
        out_ref[0] = cur.at[:, halo : width - halo].set(vals[f]).astype(out_ref.dtype)


def _kernel_1d(x_ref, out_ref, *, program):
    x = x_ref[0].astype(jnp.float32)
    for prog in program.chain:
        vals = ring_crop(prog, interior_eval(prog, {prog.inputs[0]: x}))
        x = _embed_cols(x, vals, prog.radius)
    out_ref[0] = x.astype(out_ref.dtype)


def lower_pallas(
    program: StencilProgram,
    *,
    block_rows: int | None = None,
    vmem_budget: int | None = None,
    interpret: bool | None = None,
) -> Callable[[Array | Mapping[str, Array]], Array]:
    """Builds ``x -> program(x)`` as a fused Pallas kernel.

    For a composed program (``program.steps > 1``) the kernel applies all k
    sweeps per VMEM residency — one HBM round-trip per k simulated steps.

    Args:
      program: a 2-D IR program (scalars baked into the graph). Multi-field
        programs are first-class: pass a ``{field: array}`` mapping (all
        arrays the same shape); the kernel takes one input ref per field
        with a per-field three-slab halo sized by that field's composed
        radius (``field_radii``), so a radius-0 coefficient field streams
        exactly one block per tile and no neighbour blocks.
      block_rows: VMEM row-tile override; default picks the largest divisor
        of rows fitting the shared VMEM budget (>= the inferred chain halo).
      vmem_budget: per-block byte budget for the planner (arg > env > 4 MiB).
      interpret: force interpreter mode; default = interpret iff not on TPU.

    The returned function also accepts keyword-only ``row_offset`` /
    ``rows_global`` (possibly traced) so ``lower_sharded`` can run the same
    kernel on a halo-padded shard block with true global row indices, and
    ``col_offset`` / ``cols_global`` for 2-D (rows x cols) decomposition:
    passing ``cols_global`` marks the arrays as column slabs whose outer
    chain-radius columns are halo (the sweep consumes them and the global
    column ring is applied by absolute index, mirroring rows).
    """
    if program.ndim == 1:
        if len(program.inputs) != 1:
            raise ValueError(
                "1-D pallas lowering supports single-input programs only, "
                f"got {program.inputs}"
            )
        return _lower_pallas_1d(program, interpret=interpret)
    if program.ndim != 2:
        raise ValueError(f"unsupported ndim {program.ndim}")

    fields = program.inputs
    halo = program.radius  # full chain radius: k*r for repeat(p, k)
    # Shared per-field halo rule (state at full chain radius, other fields
    # at their own composed radius) — same home as the sharded exchange
    # and the wire-byte models.
    field_halos = program.exchange_radii()
    min_block = max(halo, 1)

    @functools.partial(jax.jit, static_argnames=("br", "interp", "col_sharded"))
    def _call(arrays, row_offset, rows_global, col_offset, cols_global, br, interp,
              col_sharded):
        depth, rows, cols = arrays[0].shape
        row_tiles = rows // br
        meta = jnp.stack(
            [
                jnp.asarray(row_offset, jnp.int32),
                jnp.asarray(rows_global, jnp.int32),
                jnp.asarray(col_offset, jnp.int32),
                jnp.asarray(cols_global, jnp.int32),
            ]
        ).reshape(1, 4)
        kernel = functools.partial(
            _generic_kernel,
            program=program,
            block_rows=br,
            halo=halo,
            col_sharded=col_sharded,
            field_halos=field_halos,
        )
        spec = lambda fn: pl.BlockSpec((1, br, cols), fn)  # noqa: E731
        in_specs = []
        operands = []
        for f, x in zip(fields, arrays):
            if field_halos[f]:
                in_specs += [
                    spec(lambda d, i: (d, jnp.maximum(i - 1, 0), 0)),
                    spec(lambda d, i: (d, i, 0)),
                    spec(lambda d, i: (d, jnp.minimum(i + 1, row_tiles - 1), 0)),
                ]
                operands += [x, x, x]
            else:
                in_specs.append(spec(lambda d, i: (d, i, 0)))
                operands.append(x)
        in_specs.append(
            pl.BlockSpec(
                (1, 4), lambda d, i: (0, 0), memory_space=pltpu.MemorySpace.SMEM
            )
        )
        out_fields = tuple(program.outputs)
        if len(out_fields) == 1:
            state = arrays[fields.index(program.passthrough)]
            return pl.pallas_call(
                kernel,
                grid=(depth, row_tiles),
                in_specs=in_specs,
                out_specs=spec(lambda d, i: (d, i, 0)),
                out_shape=jax.ShapeDtypeStruct(state.shape, state.dtype),
                interpret=interp,
            )(*operands, meta)
        # Coupled systems: one fused kernel writes every evolving field's
        # updated block — N output refs, one VMEM residency.
        outs = pl.pallas_call(
            kernel,
            grid=(depth, row_tiles),
            in_specs=in_specs,
            out_specs=[spec(lambda d, i: (d, i, 0)) for _ in out_fields],
            out_shape=[
                jax.ShapeDtypeStruct(
                    arrays[fields.index(f)].shape, arrays[fields.index(f)].dtype
                )
                for f in out_fields
            ],
            interpret=interp,
        )(*operands, meta)
        return dict(zip(out_fields, outs))

    def fn(x: Array | Mapping[str, Array], *, row_offset=0, rows_global=None,
           col_offset=0, cols_global=None) -> Array:
        arrays = resolve_field_arrays(program, x, ndim=3)
        _, rows, cols = arrays[0].shape
        br = block_rows
        if br is None:
            # The budget models ONE resident tile; this kernel keeps one
            # slab per input field live plus one output slab per evolving
            # field, so the budget divides across all of them — otherwise
            # the planner would pick tiles whose true VMEM residency
            # overflows the budget N-fold. (Single-output keeps the legacy
            # len(fields) divisor: the lone output was never charged.)
            n_resident = len(fields) + len(program.outputs) - 1
            per_field = vmem_tile_budget(vmem_budget) // n_resident
            br = pick_block_rows(
                rows, cols, budget_bytes=max(per_field, 1),
                min_rows=min(min_block, rows),
            )
        if rows % br:
            raise ValueError(f"rows={rows} not divisible by block_rows={br}")
        if br < min_block:
            raise ValueError(
                f"block_rows={br} < inferred row halo {min_block} for "
                f"program {program.name!r}"
            )
        interp = interpret if interpret is not None else not _on_tpu()
        if rows_global is None:
            rows_global = rows
        # cols_global given => the arrays are column slabs of a wider grid
        # (2-D domain decomposition): static mode switch for the kernel.
        col_sharded = cols_global is not None
        if cols_global is None:
            cols_global = cols
        return _call(
            arrays, row_offset, rows_global, col_offset, cols_global, br, interp,
            col_sharded,
        )

    # Per-call timer/counter under the repro.obs registry (no-op when
    # disabled; steps aside when traced inside lower_sharded's shard_map).
    return metrics.instrument_call(fn, f"ir.lower_pallas.{program.name}")


def _lower_pallas_1d(program, *, interpret):
    @functools.partial(jax.jit, static_argnames=("interp",))
    def _call(x, interp):
        batch, n = x.shape
        kernel = functools.partial(_kernel_1d, program=program)
        return pl.pallas_call(
            kernel,
            grid=(batch,),
            in_specs=[pl.BlockSpec((1, n), lambda b: (b, 0))],
            out_specs=pl.BlockSpec((1, n), lambda b: (b, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interp,
        )(x)

    def fn(x: Array) -> Array:
        if x.ndim != 2:
            raise ValueError(f"expected (batch, n), got shape {x.shape}")
        interp = interpret if interpret is not None else not _on_tpu()
        return _call(x, interp)

    return metrics.instrument_call(fn, f"ir.lower_pallas.{program.name}")
