"""Fused-Pallas lowering: generic VMEM-resident tile codegen for IR programs.

Generalises the hand-fused hdiff kernel (``repro.kernels.hdiff.kernel``) to
any single-input program: one program instance owns one row-tile of one
plane; the inferred row halo is provided by the same three-slab trick (the
input passed with block index maps ``i-1 / i / i+1``, clamped at the edges),
and the whole DAG is evaluated in VMEM by ``interior_eval`` — intermediates
never touch HBM, the paper's accumulator-residency discipline. Block shape
comes from the shared VMEM budget planner (``repro.ir.plan``).

1-D programs (jacobi1d) lower to a row-per-program kernel with the column
halo handled in-tile, mirroring ``kernels.stencil2d.jacobi1d_pallas``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.ir.evaluate import interior_eval, ring_crop
from repro.ir.graph import StencilProgram
from repro.ir.plan import pick_block_rows

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _embed_cols(cur: Array, interior: Array, r: int) -> Array:
    """Writes ``interior`` into ``cur``'s column ring interior [r, C-r)."""
    if r == 0:
        return interior
    cols = cur.shape[-1]
    return cur.at[..., r : cols - r].set(interior)


def _generic_kernel(
    prev_ref, cur_ref, next_ref, out_ref, *, program, block_rows, rows, r
):
    """Kernel body: blocks are (1, block_rows, C); grid is (depth, row_tiles).

    ``r`` is the inferred program radius: the three-slab halo is ``r`` rows
    from each neighbour block, and the square radius-``r`` ring of the
    global grid passes through.
    """
    i = pl.program_id(1)
    cur = cur_ref[0].astype(jnp.float32)
    if r:
        x = jnp.concatenate(
            [
                prev_ref[0, -r:, :].astype(jnp.float32),
                cur,
                next_ref[0, :r, :].astype(jnp.float32),
            ],
            axis=0,
        )  # (block_rows + 2r, C)
    else:
        x = cur

    # Evaluate the whole DAG in VMEM; crop the exact-margin interior to the
    # ring region of the padded tile: rows [r, r+block_rows), cols [r, C-r).
    vals = ring_crop(program, interior_eval(program, {program.inputs[0]: x}))
    out = _embed_cols(cur, vals, r)

    if r:
        # Row passthrough: global boundary rows keep the input (the clamped
        # edge slabs feed garbage only into rows this mask overwrites).
        gl_row = i * block_rows + jax.lax.broadcasted_iota(
            jnp.int32, (block_rows, 1), 0
        )
        keep = (gl_row < r) | (gl_row >= rows - r)
        out = jnp.where(keep, cur, out)
    out_ref[0] = out.astype(out_ref.dtype)


def _kernel_1d(x_ref, out_ref, *, program, r):
    x = x_ref[0].astype(jnp.float32)
    vals = ring_crop(program, interior_eval(program, {program.inputs[0]: x}))
    out = _embed_cols(x, vals, r)
    out_ref[0] = out.astype(out_ref.dtype)


def lower_pallas(
    program: StencilProgram,
    *,
    block_rows: int | None = None,
    vmem_budget: int | None = None,
    interpret: bool | None = None,
) -> Callable[[Array], Array]:
    """Builds ``x -> program(x)`` as a fused Pallas kernel.

    Args:
      program: a single-input IR program (scalars baked into the graph).
      block_rows: VMEM row-tile override; default picks the largest divisor
        of rows fitting the shared VMEM budget (>= the inferred halo).
      vmem_budget: per-block byte budget for the planner (arg > env > 4 MiB).
      interpret: force interpreter mode; default = interpret iff not on TPU.
    """
    if len(program.inputs) != 1:
        raise ValueError(
            f"pallas lowering needs a single-input program, got {program.inputs}"
        )
    if program.ndim == 1:
        return _lower_pallas_1d(program, interpret=interpret)
    if program.ndim != 2:
        raise ValueError(f"unsupported ndim {program.ndim}")

    r = program.radius
    min_block = max(r, 1)

    @functools.partial(jax.jit, static_argnames=("br", "interp"))
    def _call(x, br, interp):
        depth, rows, cols = x.shape
        row_tiles = rows // br
        kernel = functools.partial(
            _generic_kernel,
            program=program,
            block_rows=br,
            rows=rows,
            r=r,
        )
        spec = lambda fn: pl.BlockSpec((1, br, cols), fn)  # noqa: E731
        return pl.pallas_call(
            kernel,
            grid=(depth, row_tiles),
            in_specs=[
                spec(lambda d, i: (d, jnp.maximum(i - 1, 0), 0)),
                spec(lambda d, i: (d, i, 0)),
                spec(lambda d, i: (d, jnp.minimum(i + 1, row_tiles - 1), 0)),
            ],
            out_specs=spec(lambda d, i: (d, i, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interp,
        )(x, x, x)

    def fn(x: Array) -> Array:
        if x.ndim != 3:
            raise ValueError(f"expected (depth, rows, cols), got shape {x.shape}")
        _, rows, cols = x.shape
        br = block_rows
        if br is None:
            br = pick_block_rows(
                rows, cols, budget_bytes=vmem_budget, min_rows=min_block
            )
        br = min(br, rows)
        if rows % br:
            raise ValueError(f"rows={rows} not divisible by block_rows={br}")
        if br < min_block:
            raise ValueError(
                f"block_rows={br} < inferred row halo {min_block} for "
                f"program {program.name!r}"
            )
        interp = interpret if interpret is not None else not _on_tpu()
        return _call(x, br, interp)

    return fn


def _lower_pallas_1d(program, *, interpret):
    @functools.partial(jax.jit, static_argnames=("interp",))
    def _call(x, interp):
        batch, n = x.shape
        kernel = functools.partial(_kernel_1d, program=program, r=program.radius)
        return pl.pallas_call(
            kernel,
            grid=(batch,),
            in_specs=[pl.BlockSpec((1, n), lambda b: (b, 0))],
            out_specs=pl.BlockSpec((1, n), lambda b: (b, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=interp,
        )(x)

    def fn(x: Array) -> Array:
        if x.ndim != 2:
            raise ValueError(f"expected (batch, n), got shape {x.shape}")
        interp = interpret if interpret is not None else not _on_tpu()
        return _call(x, interp)

    return fn
