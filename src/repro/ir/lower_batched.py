"""Batched lowering: vmap any backend over a leading ensemble-member axis.

The forecast-serving analogue of the paper's balanced scale-out: N perturbed
initial conditions (ensemble members, or N tenants' scenarios on one grid)
share ONE compiled kernel instead of N dispatches. ``lower_batched`` builds
the requested single-program lowering — reference jnp, fused Pallas, or
shard_map + halo exchange — and wraps it in ``jax.vmap`` over a fresh
leading member axis, jitted once for the whole batch:

  * every member sees exactly the per-member computation, so the batched
    output is BIT-identical to N independent applications on the same
    backend (the batched conformance cells assert this, including on the
    2x4 rows x cols mesh);
  * the member axis composes with the (R, C) device mesh: inside the
    ``shard_map`` shard the batch dim is just another unsharded leading
    dim, the per-field halo exchange moves each member's bands in the same
    collectives, and temporal blocking (``repeat(p, k)``) still amortises
    the wire k-fold per member;
  * one trace serves the whole batch — the compile-cache key the serving
    layer uses (``repro.serve.cache``) includes the batch size, so a warm
    cache never re-traces for a repeat batch shape.

Multi-field programs take ``{field: (N, D, R, C)}`` mappings (all fields
share one batched grid); multi-output programs return ``{field: (N, D, R,
C)}`` per evolving field. Single-input programs may pass the bare batched
array, mirroring the single-device lowerings' contract.
"""

from __future__ import annotations

from typing import Callable, Mapping

import jax

from repro.ir.graph import StencilProgram
from repro.ir.lower_pallas import lower_pallas
from repro.ir.lower_reference import lower_reference
from repro.ir.lower_sharded import lower_sharded
from repro.obs import metrics

Array = jax.Array

#: The backends a batched lowering can wrap — the conformance matrix's
#: backend names minus "staged" (whose per-op host sync is meaningless
#: under vmap: the stages would serialise per member anyway).
BATCHED_BACKENDS = ("reference", "pallas", "sharded-reference", "sharded-pallas")


def build_backend(
    program: StencilProgram,
    backend: str,
    *,
    mesh_shape: tuple[int, int] | None = None,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
    overlap: bool = False,
    merge_exchange: bool = True,
    differentiable: bool = False,
) -> Callable:
    """One UNBATCHED lowered callable for a conformance-style backend name
    — the single dispatch point ``lower_batched`` and the serving compile
    cache share (so a cache miss and a test cell build identical
    callables).

    With ``differentiable=True`` the callable carries a derived
    ``jax.custom_vjp`` whose backward runs the program's ADJOINT IR
    (:mod:`repro.ir.autodiff`) through the same backend: the pallas
    backward is its own fused kernel, the sharded backward reuses the
    ``exchange_radii()``-driven halo exchange. The one asymmetry is
    ``staged``: its per-op-jitted forward pairs with a fused reference
    backward (per-op dispatch of an adjoint DAG would be all overhead and
    the gradient contract — match ``jax.grad`` of the reference — is
    backend-independent anyway)."""
    if backend == "reference":
        fwd = lower_reference(program)
    elif backend == "staged":
        fwd = lower_reference(program, mode="staged")
    elif backend == "pallas":
        fwd = lower_pallas(program, interpret=interpret, vmem_budget=vmem_budget)
    elif backend in ("sharded-reference", "sharded-pallas"):
        if mesh_shape is None:
            raise ValueError(
                f"backend {backend!r} needs mesh_shape=(R, C) — the rows x "
                "cols device-mesh factorization the shards map onto"
            )
        fwd = lower_sharded(
            program,
            mesh_shape=mesh_shape,
            inner=backend.removeprefix("sharded-"),
            overlap=overlap,
            interpret=interpret,
            vmem_budget=vmem_budget,
            merge_exchange=merge_exchange,
        )
    else:
        raise ValueError(
            f"unknown backend {backend!r} (want one of {BATCHED_BACKENDS})"
        )
    if not differentiable:
        return fwd
    from repro.ir.autodiff import differentiable_lowering

    if backend in ("sharded-reference", "sharded-pallas"):
        # The adjoint/augmented sweeps lower with boundary="zero": every
        # owned point computed from the exchanged block, no pad/crop on
        # sharded dims (GSPMD would implement those with its own
        # collective-permutes and break the measured-exact wire model).
        # Forward state-recompute sweeps keep the ring lowering.
        def build_ring(p):
            return build_backend(
                p, backend, mesh_shape=mesh_shape, interpret=interpret,
                vmem_budget=vmem_budget, overlap=overlap,
                merge_exchange=merge_exchange,
            )

        def build_zero(p):
            return lower_sharded(
                p,
                mesh_shape=mesh_shape,
                inner=backend.removeprefix("sharded-"),
                interpret=interpret,
                vmem_budget=vmem_budget,
                merge_exchange=merge_exchange,
                boundary="zero",
            )

        return differentiable_lowering(
            program, fwd, build_ring, build_zero=build_zero
        )
    bwd_backend = "reference" if backend == "staged" else backend
    return differentiable_lowering(
        program,
        fwd,
        lambda p: build_backend(
            p,
            bwd_backend,
            interpret=interpret,
            vmem_budget=vmem_budget,
        ),
    )


def lower_batched(
    program: StencilProgram,
    *,
    backend: str = "reference",
    mesh_shape: tuple[int, int] | None = None,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
    overlap: bool = False,
    merge_exchange: bool = True,
) -> Callable:
    """Builds ``x (N, D, R, C) -> program(x)`` vmapped over leading axis 0.

    Args:
      program: a 2-D IR program (the forecast workloads; 1-D programs have
        their own batch convention in the Pallas lowering already).
      backend: one of :data:`BATCHED_BACKENDS`. The sharded backends need
        ``mesh_shape``; the member axis rides UNSHARDED through the mesh.
      mesh_shape / interpret / vmem_budget / overlap / merge_exchange:
        forwarded to the wrapped lowering (see :func:`build_backend`).

    The returned callable takes one batched array per declared input —
    ``{field: (N, *grid)}`` mapping, or the bare array for single-input
    programs — and returns the batched output(s): a ``(N, *grid)`` array,
    or ``{field: (N, *grid)}`` for multi-output programs. The whole batch
    is one jitted computation (vmap under one ``jax.jit``), so a second
    same-shape call never re-traces.
    """
    if program.ndim != 2:
        raise ValueError(
            f"lower_batched supports 2-D programs, got ndim={program.ndim}"
        )
    if backend not in BATCHED_BACKENDS:
        raise ValueError(
            f"unknown batched backend {backend!r} (want one of {BATCHED_BACKENDS})"
        )
    if backend in ("reference", "pallas") and mesh_shape is not None:
        raise ValueError(
            f"backend {backend!r} is single-device; mesh_shape only applies "
            "to the sharded backends"
        )
    base = build_backend(
        program,
        backend,
        mesh_shape=mesh_shape,
        interpret=interpret,
        vmem_budget=vmem_budget,
        overlap=overlap,
        merge_exchange=merge_exchange,
    )
    vfn = jax.jit(jax.vmap(base))

    fields = program.inputs
    grid_ndim = program.ndim + 1  # (depth, rows, cols) for 2-D programs

    def fn(x: Array | Mapping[str, Array]):
        if isinstance(x, Mapping):
            missing = [f for f in fields if f not in x]
            if missing:
                raise ValueError(
                    f"program {program.name!r} batched field mapping is "
                    f"missing input(s) {missing}; declared inputs are "
                    f"{list(fields)}"
                )
            arrays = [x[f] for f in fields]
        else:
            if len(fields) != 1:
                raise ValueError(
                    f"program {program.name!r} has inputs {fields}; pass a mapping"
                )
            arrays = [x]
        for f, a in zip(fields, arrays):
            if a.ndim != grid_ndim + 1:
                raise ValueError(
                    f"batched field {f!r} must be (members, depth, rows, cols)"
                    f" — {grid_ndim + 1}-D — got shape {tuple(a.shape)}; "
                    "members lead, grid trails"
                )
            if a.shape != arrays[0].shape:
                raise ValueError(
                    f"all batched fields must share one (members, *grid) "
                    f"shape; {f!r} has {tuple(a.shape)} vs {fields[0]!r} "
                    f"{tuple(arrays[0].shape)}"
                )
        return vfn(x)

    return metrics.instrument_call(
        fn, f"ir.lower_batched.{program.name}.{backend}"
    )
