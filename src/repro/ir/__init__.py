"""repro.ir — stencil dataflow-graph IR and mini-compiler.

One program, three backends (see README "The IR subsystem"):

    graph (StencilOp DAG) --> analysis (halo / op counts, derived)
        --> lower_reference   (jnp, fused or stage-at-a-time)
        --> lower_pallas      (generic fused VMEM tile kernel)
        --> lower_sharded     (shard_map + inferred-radius halo exchange,
                               Pallas kernel composed inside the shard)

Temporal blocking rides the same pipeline: ``repeat(p, k)`` /
``StencilProgram.compose`` fuse k sweeps into one program whose chain every
backend executes per-sweep (absolute-row ring passthrough), amortising HBM
and wire round-trips k-fold per simulated step.

Multi-field programs are first-class: declare extra inputs (coefficients,
velocities) and every backend takes a ``{field: array}`` mapping. Halos,
reads and wire bytes derive PER FIELD (``field_radii`` / ``reads_by_field``)
and sum — the Pallas kernel sizes each field's three-slab halo by its own
radius, and the sharded lowering skips the exchange for radius-0 fields.
``vadvc_program`` / ``hdiff_coupled_program`` are the shipped workloads.

Multi-OUTPUT programs (coupled PDE systems) declare ``outputs={field:
op_name, ...}`` — several fields evolve per sweep, each with its own
derived radius/footprint, and every backend returns ``{field: array}``.
One fused kernel writes all outputs; the sharded lowering moves all
evolving halos in ONE merged exchange per k sweeps.
``shallow_water_program`` (u, v, h gravity-wave coupling) and
``advection_diffusion_program`` (evolving u, c over a shared v) are the
shipped coupled systems.

Ensemble batching rides every backend: ``lower_batched`` vmaps a lowering
over a leading member axis (one compiled kernel for N perturbed initial
conditions, bit-identical to N independent applications), composing with
the (R, C) mesh of the sharded backends — the forecast-serving layer's
execution path (``repro.serve``).

Autodiff is one more graph transform: ``adjoint(p)`` derives the cotangent
program from the same DAG (transposed access offsets, reversed op chain,
nonlinear combinators linearized around ``c~``-cached primal values —
adjoint radii equal primal radii, field by field) and ``build_backend(...,
differentiable=True)`` attaches it as a ``jax.custom_vjp`` through the SAME
backend: the Pallas backward is its own fused kernel, the sharded backward
reuses the ``exchange_radii()``-driven halo exchange (``repro.ir.autodiff``).

This package is self-contained (no imports from other ``repro`` modules at
import time), so ``repro.core`` and ``repro.kernels`` derive their specs and
tile plans from it without cycles.
"""

from repro.ir.graph import (
    Offset,
    OpCost,
    ProgramSpec,
    Read,
    StencilOp,
    StencilProgram,
    repeat,
)
from repro.ir.ops import affine, flux, product, scaled_residual, weighted_residual
from repro.ir.programs import (
    ELEMENTARY_PROGRAMS,
    MULTIFIELD_PROGRAMS,
    MULTIOUTPUT_PROGRAMS,
    advection_diffusion_program,
    hdiff_coupled_program,
    hdiff_multistep_program,
    hdiff_program,
    jacobi1d_program,
    jacobi2d_3pt_program,
    jacobi2d_5pt_program,
    jacobi2d_9pt_program,
    laplacian_program,
    seidel2d_program,
    shallow_water_program,
    smagorinsky_coeff,
    vadvc_program,
)
from repro.ir.evaluate import (
    apply_program,
    embed_interior,
    interior_eval,
    interior_eval_multi,
    interior_region,
    resolve_field_arrays,
    ring_crop,
    slab_step,
    slab_sweep,
    thread_chain,
)
from repro.ir.plan import (
    DEFAULT_VMEM_TILE_BUDGET,
    VMEM_BUDGET_ENV,
    Partition2D,
    pick_block_rows,
    plan_partition,
    vmem_tile_budget,
)
from repro.ir.lower_reference import lower_reference
from repro.ir.lower_pallas import lower_pallas
from repro.ir.lower_sharded import lower_sharded
from repro.ir.lower_batched import BATCHED_BACKENDS, build_backend, lower_batched
from repro.ir.autodiff import (
    acc_field,
    adjoint,
    augmented_forward,
    cache_field,
    cache_fields,
    differentiable_lowering,
    make_vjp,
    pad_widths,
    seed_field,
)
