"""The paper's stencils expressed as IR programs.

``hdiff_program`` is the compound COSMO horizontal diffusion (Eq. 1-4): a
5-point Laplacian, four limited fluxes, and the coefficient update — six ops
over two source-consumed fields. The five elementary §3.5 stencils are each
a single affine op. Halo, op counts, and footprints for all of them are
*derived* by the graph analysis; parity against the hand-written kernels in
``repro.core`` is enforced by ``tests/test_ir_lowering.py``.

``MULTIFIELD_PROGRAMS`` holds the multi-input workloads (the larger-dycore
fragments NERO/StencilFlow motivate): ``vadvc_program`` (vertical advection,
velocity + scalar fields) and ``hdiff_coupled_program`` (hdiff with a
diffusion-coefficient *field*). Per-field halos, reads and wire bytes are
derived per field and summed; the cross-backend conformance matrix
(``tests/conformance.py``) covers them on every backend/mesh/k cell.

``MULTIOUTPUT_PROGRAMS`` holds the coupled PDE systems (whole-model
timesteps): ``shallow_water_program`` evolves {u, v, h} together through
the gravity-wave coupling, ``advection_diffusion_program`` evolves {c, u}
over a shared velocity field — several ``outputs`` per sweep, one merged
halo exchange, same conformance coverage.
"""

from __future__ import annotations

from typing import Callable

from repro.ir.graph import StencilProgram, repeat
from repro.ir.ops import affine, flux, product, scaled_residual, weighted_residual

# Tap orders deliberately mirror the hand-written kernels' evaluation order
# (see repro/core/{hdiff,stencils}.py) so lowered outputs are bit-identical.
_LAP_TAPS = {(0, 0): 4.0, (1, 0): -1.0, (-1, 0): -1.0, (0, 1): -1.0, (0, -1): -1.0}


def hdiff_program(coeff: float = 0.025, *, limit: bool = True) -> StencilProgram:
    """COSMO horizontal diffusion as a 6-op DAG (Eq. 1-4 / Alg. 1).

    ``limit=True`` is the production flux-limited kernel; ``limit=False`` is
    Algorithm 1's unlimited polynomial form (NERO/NARMADA baseline).
    """
    lim = "psi" if limit else None
    ops = [
        affine("lap", "psi", _LAP_TAPS),
        flux("flx_r", "lap", lo=(0, 0), hi=(1, 0), limiter=lim),
        flux("flx_rm", "lap", lo=(-1, 0), hi=(0, 0), limiter=lim),
        flux("flx_c", "lap", lo=(0, 0), hi=(0, 1), limiter=lim),
        flux("flx_cm", "lap", lo=(0, -1), hi=(0, 0), limiter=lim),
        scaled_residual(
            "out",
            "psi",
            [("flx_r", 1), ("flx_rm", -1), ("flx_c", 1), ("flx_cm", -1)],
            coeff,
        ),
    ]
    return StencilProgram("hdiff" if limit else "hdiff_simple", ["psi"], ops)


def hdiff_multistep_program(
    k: int, coeff: float = 0.025, *, limit: bool = True
) -> StencilProgram:
    """``k`` temporally-blocked hdiff sweeps: ``repeat(hdiff_program(), k)``.

    One fused application simulates ``k`` timesteps per HBM (and, sharded,
    per wire) round-trip; radius is ``2 * k``. The k=2 instance is what
    ``kernels.hdiff.multistep.hdiff_twostep`` wraps.
    """
    return repeat(hdiff_program(coeff, limit=limit), k)


def hdiff_coupled_program(*, limit: bool = True) -> StencilProgram:
    """hdiff with a spatially-varying diffusion coefficient *field*.

    The COSMO/Smagorinsky pattern NERO couples hdiff with: the Eq. 4 update
    scales the flux divergence by a per-point coefficient (derived from the
    local deformation in the full model) instead of the baked-in scalar —
    two source fields, ``u`` (the evolving state, radius 2) and ``coeff``
    (read at offset zero only, radius 0, so it exchanges NO halo at k=1;
    under ``repeat(p, k)`` its composed radius grows to ``2 (k-1)`` while
    ``u``'s grows to ``2 k`` — both derived, both tested).
    """
    lim = "u" if limit else None
    ops = [
        affine("lap", "u", _LAP_TAPS),
        flux("flx_r", "lap", lo=(0, 0), hi=(1, 0), limiter=lim),
        flux("flx_rm", "lap", lo=(-1, 0), hi=(0, 0), limiter=lim),
        flux("flx_c", "lap", lo=(0, 0), hi=(0, 1), limiter=lim),
        flux("flx_cm", "lap", lo=(0, -1), hi=(0, 0), limiter=lim),
        weighted_residual(
            "out",
            "u",
            "coeff",
            [("flx_r", 1), ("flx_rm", -1), ("flx_c", 1), ("flx_cm", -1)],
        ),
    ]
    return StencilProgram(
        "hdiff_coupled" if limit else "hdiff_coupled_simple",
        ["u", "coeff"],
        ops,
        passthrough="u",
    )


def vadvc_program(dt: float = 0.25) -> StencilProgram:
    """NERO-style vertical-advection fragment: 2 fields, level-offset reads.

    The vertical dimension maps to the IR's leading stencil dim (``rows`` of
    the ``(batch, levels, columns)`` grid — depth planes are hdiff's
    embarrassingly-parallel dim, but vadvc couples *along* the column, so
    levels take the halo-carrying axis). One explicit advection sweep of a
    scalar ``s`` by a face-staggered vertical velocity ``w``:

      wbar = (w[k] + w[k+1]) / 2          destagger to cell centres
      grad = (s[k+1] - s[k-1]) / 2        centered level gradient
      out  = s - dt * wbar * grad

    Per-field radii: ``s`` 1 (the gradient), ``w`` 1 (the destagger) —
    BOTH fields exchange a halo when sharded, unlike ``hdiff_coupled``'s
    radius-0 coefficient, so the two workloads cover both sides of the
    per-field exchange logic.
    """
    ops = [
        affine("wbar", "w", {(0, 0): 0.5, (1, 0): 0.5}),
        affine("grad", "s", {(1, 0): 0.5, (-1, 0): -0.5}),
        product("adv", "wbar", "grad"),
        scaled_residual("out", "s", [("adv", 1)], dt),
    ]
    return StencilProgram("vadvc", ["s", "w"], ops, passthrough="s")


def smagorinsky_coeff(noise):
    """Deterministic positive diffusion-coefficient field from unit noise:
    0.025 modulated +-25% through tanh. The ONE generator every
    hdiff_coupled test/benchmark feeds the ``coeff`` input with, so the
    conformance oracle, the paper-grid acceptance and fig13 all stress the
    same coefficient regime (works on numpy and jax arrays alike)."""
    import numpy as np

    return np.asarray(0.025 * (1.0 + 0.25 * np.tanh(np.asarray(noise))), np.float32)


MULTIFIELD_PROGRAMS: dict[str, Callable[[], StencilProgram]] = {
    "vadvc": vadvc_program,
    "hdiff_coupled": hdiff_coupled_program,
}


def shallow_water_program(
    g_dt: float = 0.2, h_dt: float = 0.2
) -> StencilProgram:
    """Linearised shallow-water gravity-wave step: the canonical coupled
    system a weather timestep runs — THREE evolving fields in one sweep.

    One explicit (Jacobi-style, simultaneous) update on an unstaggered grid:

      u' = u - g_dt * dh/dx          momentum, pressure-gradient force
      v' = v - g_dt * dh/dy
      h' = h - h_dt * (du/dx + dv/dy)   continuity, divergence of OLD (u, v)

    with centered differences (radius 1 per sweep, all three outputs).
    ``outputs={"u": ..., "v": ..., "h": ...}`` makes it one multi-output IR
    program: one fused kernel computes all three updates from one VMEM
    residency, the sharded lowering moves all three halos in ONE merged
    exchange per k sweeps, and ``repeat(p, k)`` couples the sweeps so each
    output's radius composes to ``k`` (u' at sweep 2 reads sweep 1's h,
    which read sweep 1's... — the gravity-wave coupling the per-output
    footprint analysis has to get right).

    Defaults keep the scheme comfortably inside the CFL bound on unit-noise
    fields, so k<=3 conformance stays in a tame numeric range.
    """
    ops = [
        affine("dhdx", "h", {(1, 0): 0.5, (-1, 0): -0.5}),
        affine("dhdy", "h", {(0, 1): 0.5, (0, -1): -0.5}),
        scaled_residual("u_new", "u", [("dhdx", 1)], g_dt),
        scaled_residual("v_new", "v", [("dhdy", 1)], g_dt),
        affine("dudx", "u", {(1, 0): 0.5, (-1, 0): -0.5}),
        affine("dvdy", "v", {(0, 1): 0.5, (0, -1): -0.5}),
        scaled_residual("h_new", "h", [("dudx", 1), ("dvdy", 1)], h_dt),
    ]
    return StencilProgram(
        "shallow_water",
        ["u", "v", "h"],
        ops,
        outputs={"u": "u_new", "v": "v_new", "h": "h_new"},
    )


def advection_diffusion_program(
    nu: float = 0.05, dt: float = 0.1, kappa: float = 0.05
) -> StencilProgram:
    """Passive scalar advected by a self-diffusing flow: TWO evolving fields
    plus one SHARED (non-evolving) field in a single sweep.

    ``c`` (the scalar) and ``u`` (the row-velocity) both evolve; ``v`` (the
    column-velocity) is a shared input read at offset zero:

      u' = u - nu * lap(u)                     the carrier diffuses
      c' = (c - dt * (u * dc/dx + v * dc/dy)) - kappa * lap(c)

    Radii per sweep: both outputs 1; shared ``v`` radius 0 at k=1, growing
    to ``k - 1`` under ``repeat`` (read through the downstream sweeps) —
    the multi-output analogue of ``hdiff_coupled``'s radius-0 coefficient,
    so the merged sharded exchange gets a radius-0 shared field AND a
    two-field evolving group in one program.
    """
    ops = [
        affine("lap_u", "u", _LAP_TAPS),
        scaled_residual("u_new", "u", [("lap_u", 1)], nu),
        affine("gcr", "c", {(1, 0): 0.5, (-1, 0): -0.5}),
        affine("gcc", "c", {(0, 1): 0.5, (0, -1): -0.5}),
        product("advr", "u", "gcr"),
        product("advc", "v", "gcc"),
        scaled_residual("cadv", "c", [("advr", 1), ("advc", 1)], dt),
        affine("lap_c", "c", _LAP_TAPS),
        scaled_residual("c_new", "cadv", [("lap_c", 1)], kappa),
    ]
    return StencilProgram(
        "advection_diffusion",
        ["c", "u", "v"],
        ops,
        outputs={"c": "c_new", "u": "u_new"},
    )


MULTIOUTPUT_PROGRAMS: dict[str, Callable[[], StencilProgram]] = {
    "shallow_water": shallow_water_program,
    "advection_diffusion": advection_diffusion_program,
}


def jacobi1d_program(coeff: float = 1.0 / 3.0) -> StencilProgram:
    taps = {(-1,): coeff, (0,): coeff, (1,): coeff}
    return StencilProgram("jacobi1d", ["x"], [affine("out", "x", taps)], ndim=1)


def jacobi2d_3pt_program(coeff: float = 1.0 / 3.0) -> StencilProgram:
    taps = {(-1, 0): coeff, (0, 0): coeff, (1, 0): coeff}
    return StencilProgram("jacobi2d_3pt", ["x"], [affine("out", "x", taps)])


def laplacian_program() -> StencilProgram:
    return StencilProgram("laplacian", ["x"], [affine("out", "x", _LAP_TAPS)])


def jacobi2d_5pt_program(coeff: float = 0.2) -> StencilProgram:
    taps = {
        (0, 0): coeff,
        (1, 0): coeff,
        (-1, 0): coeff,
        (0, 1): coeff,
        (0, -1): coeff,
    }
    return StencilProgram("jacobi2d_5pt", ["x"], [affine("out", "x", taps)])


def jacobi2d_9pt_program(coeff: float = 1.0 / 9.0) -> StencilProgram:
    taps = {(dr, dc): coeff for dr in (-1, 0, 1) for dc in (-1, 0, 1)}
    return StencilProgram("jacobi2d_9pt", ["x"], [affine("out", "x", taps)])


def seidel2d_program(coeff: float = 1.0 / 9.0) -> StencilProgram:
    """Parallel (Jacobi-style) 9-point sweep — the throughput form the
    streaming spatial mapping pipelines (see ``core.stencils.seidel2d_sweep``)."""
    taps = {(dr, dc): coeff for dr in (-1, 0, 1) for dc in (-1, 0, 1)}
    return StencilProgram("seidel2d", ["x"], [affine("out", "x", taps)])


ELEMENTARY_PROGRAMS: dict[str, Callable[[], StencilProgram]] = {
    "jacobi1d": jacobi1d_program,
    "jacobi2d_3pt": jacobi2d_3pt_program,
    "laplacian": laplacian_program,
    "jacobi2d_5pt": jacobi2d_5pt_program,
    "jacobi2d_9pt": jacobi2d_9pt_program,
    "seidel2d": seidel2d_program,
}
