from repro.optim.optimizers import (
    OptimizerConfig,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    make_optimizer,
    opt_state_axes,
    optimizer_config_from_model,
    schedule_lr,
)
