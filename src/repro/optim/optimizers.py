"""Optimizers: AdamW (configurable moment dtypes) and Adafactor.

Implemented from scratch (no optax in this environment), pytree-native, with
the state-sharding posture the dry-run needs:

  * AdamW moments inherit the PARAM's sharding (same logical axes), so ZeRO
    style FSDP falls out of the sharding rules for free.
  * ``moment_dtype="bfloat16"`` halves optimizer HBM for the 100B+ configs.
  * Adafactor (Shazeer & Stern 2018) keeps a FACTORED second moment (row +
    col vectors) and no first moment — O(params) extra memory becomes
    O(params/d) — required for arctic-480b on the single-pod mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    # schedule
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # adafactor
    factored_threshold: int = 2 * 128 * 128


def schedule_lr(cfg: OptimizerConfig, step: Array) -> Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * warm * decay


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


# ---------------------------------------------------------------------------
# AdamW.
# ---------------------------------------------------------------------------


class AdamWState(NamedTuple):
    step: Array
    mu: Any
    nu: Any


def adamw_init(cfg: OptimizerConfig, params: Any) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)  # noqa: E731
    return AdamWState(jnp.zeros((), jnp.int32), jax.tree.map(zeros, params), jax.tree.map(zeros, params))


def adamw_update(cfg: OptimizerConfig, grads: Any, state: AdamWState, params: Any):
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(step, new_m, new_v)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment, update clipping).
# ---------------------------------------------------------------------------


class AdafactorState(NamedTuple):
    step: Array
    vr: Any   # row second-moment (or full v for small/1D params)
    vc: Any   # col second-moment (or () sentinel)


def _factored(p: Array, threshold: int) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2 and p.size >= threshold


def adafactor_init(cfg: OptimizerConfig, params: Any) -> AdafactorState:
    mdt = jnp.dtype(cfg.moment_dtype)

    def vr_init(p):
        if _factored(p, cfg.factored_threshold):
            return jnp.zeros(p.shape[:-1], mdt)
        return jnp.zeros(p.shape, mdt)

    def vc_init(p):
        if _factored(p, cfg.factored_threshold):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], mdt)
        return jnp.zeros((1,), mdt)

    return AdafactorState(
        jnp.zeros((), jnp.int32),
        jax.tree.map(vr_init, params),
        jax.tree.map(vc_init, params),
    )


def adafactor_update(cfg: OptimizerConfig, grads: Any, state: AdafactorState, params: Any):
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-0.8)  # Shazeer-Stern decay schedule
    eps = 1e-30

    def upd(g, vr, vc, p):
        g32 = jnp.square(g.astype(jnp.float32)) + eps
        if _factored(p, cfg.factored_threshold):
            vr32 = beta2 * vr.astype(jnp.float32) + (1 - beta2) * g32.mean(-1)
            vc32 = beta2 * vc.astype(jnp.float32) + (1 - beta2) * g32.mean(-2)
            denom = (
                vr32[..., :, None]
                / jnp.maximum(vr32.mean(-1, keepdims=True), eps)[..., :, None]
            ) * vc32[..., None, :]
            precond = g.astype(jnp.float32) * jax.lax.rsqrt(jnp.maximum(denom, eps))
        else:
            vr32 = beta2 * vr.astype(jnp.float32) + (1 - beta2) * g32
            vc32 = vc.astype(jnp.float32)
            precond = g.astype(jnp.float32) * jax.lax.rsqrt(jnp.maximum(vr32, eps))
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + eps)
        precond = precond / jnp.maximum(1.0, rms)
        new_p = p.astype(jnp.float32) - lr * (precond + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), vr32.astype(vr.dtype), vc32.astype(vc.dtype)

    out = jax.tree.map(upd, grads, state.vr, state.vc, params)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_vr = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_vc = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdafactorState(step, new_vr, new_vc)


# ---------------------------------------------------------------------------
# Uniform interface.
# ---------------------------------------------------------------------------


def make_optimizer(cfg: OptimizerConfig):
    """Returns (init_fn, update_fn). update(grads, state, params) ->
    (new_params, new_state)."""
    if cfg.name == "adamw":
        return (lambda p: adamw_init(cfg, p)), (lambda g, s, p: adamw_update(cfg, g, s, p))
    if cfg.name == "adafactor":
        return (lambda p: adafactor_init(cfg, p)), (lambda g, s, p: adafactor_update(cfg, g, s, p))
    raise ValueError(f"unknown optimizer {cfg.name!r}")


def opt_state_axes(cfg: OptimizerConfig, param_axes: Any, params_abstract: Any) -> Any:
    """Logical axes for optimizer state, mirroring the params' axes so FSDP
    shards moments identically to weights. ``params_abstract`` (shapes) is
    needed to distinguish factored vs full Adafactor leaves."""
    is_ax = lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)  # noqa: E731
    if cfg.name == "adamw":
        return AdamWState((), param_axes, param_axes)
    if cfg.name == "adafactor":
        def vr_ax(ax, p):
            return ax[:-1] if _factored(p, cfg.factored_threshold) else ax

        def vc_ax(ax, p):
            return ax[:-2] + ax[-1:] if _factored(p, cfg.factored_threshold) else (None,)

        vr = jax.tree.map(vr_ax, param_axes, params_abstract, is_leaf=is_ax)
        vc = jax.tree.map(vc_ax, param_axes, params_abstract, is_leaf=is_ax)
        return AdafactorState((), vr, vc)
    raise ValueError(cfg.name)


def optimizer_config_from_model(model_cfg) -> OptimizerConfig:
    return OptimizerConfig(
        name=model_cfg.optimizer,
        learning_rate=model_cfg.learning_rate,
        weight_decay=model_cfg.weight_decay,
        grad_clip=model_cfg.grad_clip,
        moment_dtype=model_cfg.moment_dtype,
    )
