"""Iterative time-stepping driver for stencil simulations.

The paper pipelines *timesteps* through the spatial array ("their dataflow
design provides an intuitive way to take advantage of both spatial and
temporal locality in iterative stencil processing by pipelining different
timesteps", §1). On TPU the analogue is a ``lax.scan`` over steps with the
whole step fused — the grid stays on-device (in HBM) for the entire run and
only boundary/diagnostic data leaves.

Double-buffering semantics: ``lax.scan`` carries the grid as loop state, so
XLA's buffer donation gives the classic ping-pong pair for free.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("step_fn", "n_steps", "collect_every"))
def run_simulation(
    psi0: Array,
    coeff: Array | float,
    *,
    step_fn: Callable[[Array, Array | float], Array],
    n_steps: int,
    collect_every: int = 0,
) -> tuple[Array, Array | None]:
    """Runs ``n_steps`` of ``psi <- step_fn(psi, coeff)``.

    Returns the final field and, if ``collect_every > 0``, a stacked history
    of (max, mean-abs) diagnostics every ``collect_every`` steps.
    """

    def body(psi, _):
        nxt = step_fn(psi, coeff)
        if collect_every:
            diag = jnp.stack([jnp.max(jnp.abs(nxt)), jnp.mean(jnp.abs(nxt))])
        else:
            diag = jnp.zeros((2,), nxt.dtype)
        return nxt, diag

    final, diags = jax.lax.scan(body, psi0, None, length=n_steps)
    if collect_every:
        return final, diags[::collect_every]
    return final, None


def make_initial_field(
    depth: int, rows: int, cols: int, *, kind: str = "gaussian", seed: int = 0, dtype=jnp.float32
) -> Array:
    """Deterministic initial conditions for tests/benchmarks.

    ``gaussian``: a smooth bump (physically plausible for diffusion);
    ``random``: uniform noise (stress test for the limiter);
    ``checker``: worst case for diffusion smoothing.
    """
    if kind == "random":
        key = jax.random.PRNGKey(seed)
        return jax.random.uniform(key, (depth, rows, cols), dtype=dtype)
    r = jnp.arange(rows, dtype=dtype)
    c = jnp.arange(cols, dtype=dtype)
    d = jnp.arange(depth, dtype=dtype)
    if kind == "gaussian":
        rr = (r[:, None] - rows / 2.0) / (rows / 8.0)
        cc = (c[None, :] - cols / 2.0) / (cols / 8.0)
        plane = jnp.exp(-(rr**2 + cc**2))
        scale = 1.0 + 0.1 * d / max(depth - 1, 1)
        return plane[None] * scale[:, None, None]
    if kind == "checker":
        plane = ((r[:, None].astype(jnp.int32) + c[None, :].astype(jnp.int32)) % 2).astype(dtype)
        return jnp.broadcast_to(plane[None], (depth, rows, cols))
    raise ValueError(f"unknown initial-condition kind {kind!r}")
