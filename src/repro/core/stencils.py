"""Elementary stencil kernels (pure JAX reference implementations).

These are the five elementary stencils SPARTA implements in §3.5 as
cross-platform benchmarks (all from PolyBench [69] except the COSMO
Laplacian [37]):

  * ``jacobi1d``      — 3-point 1-D Jacobi
  * ``jacobi2d_3pt``  — 3-point 2-D Jacobi (three rows, one column; Fig. 8)
  * ``laplacian``     — 5-point COSMO Laplacian (Eq. 1)
  * ``jacobi2d_9pt``  — 9-point 2-D Jacobi (3x3 box)
  * ``seidel2d``      — 9-point Gauss-Seidel (sequential dependency; we
                        provide both the exact doubly-sequential version and
                        the parallel Jacobi-style sweep used for throughput
                        benchmarking, mirroring how a streaming spatial
                        mapping pipelines it)

All stencils operate on the trailing two dims (or one dim for jacobi1d) of an
array, preserve shape, and leave the boundary ring equal to the input (the
paper computes interior points only; borders pass through).

Conventions: grids are indexed ``(..., row, col)``; the "depth" /plane
dimension of the 3-D COSMO grid is a leading batch dimension and is
embarrassingly parallel (§2.1: "we can parallelize hdiff in the vertical
dimension").
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Stencil op-count metadata, used by core.analytical (paper §3.1).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """Static description of a stencil's per-output-point cost.

    Mirrors the accounting in the paper's Eq. 5-10: ``macs`` counts
    multiply-accumulate ops, ``other_ops`` counts non-MAC vector ops
    (add/sub/compare/select), ``reads`` counts distinct input elements
    touched per output, ``radius`` is the halo width needed.
    """

    name: str
    macs: int
    other_ops: int
    reads: int
    radius: int
    ndim: int = 2

    @property
    def flops(self) -> int:
        # A MAC is 2 flops (mul + add).
        return 2 * self.macs + self.other_ops


ELEMENTARY_SPECS: dict[str, StencilSpec] = {
    "jacobi1d": StencilSpec("jacobi1d", macs=3, other_ops=0, reads=3, radius=1, ndim=1),
    "jacobi2d_3pt": StencilSpec("jacobi2d_3pt", macs=3, other_ops=0, reads=3, radius=1),
    "laplacian": StencilSpec("laplacian", macs=5, other_ops=0, reads=5, radius=1),
    "jacobi2d_5pt": StencilSpec("jacobi2d_5pt", macs=5, other_ops=0, reads=5, radius=1),
    "jacobi2d_9pt": StencilSpec("jacobi2d_9pt", macs=9, other_ops=0, reads=9, radius=1),
    "seidel2d": StencilSpec("seidel2d", macs=9, other_ops=0, reads=9, radius=1),
}


def _interior_update_2d(x: Array, new_interior: Array, radius: int) -> Array:
    """Writes ``new_interior`` into the interior of ``x`` (trailing 2 dims)."""
    r = radius
    return x.at[..., r:-r, r:-r].set(new_interior)


# ---------------------------------------------------------------------------
# Elementary stencils.
# ---------------------------------------------------------------------------


def jacobi1d(x: Array, coeff: float = 1.0 / 3.0) -> Array:
    """PolyBench jacobi-1d: ``out[i] = c * (x[i-1] + x[i] + x[i+1])``."""
    interior = coeff * (x[..., :-2] + x[..., 1:-1] + x[..., 2:])
    return x.at[..., 1:-1].set(interior.astype(x.dtype))


def jacobi2d_3pt(x: Array, coeff: float = 1.0 / 3.0) -> Array:
    """3-point 2-D Jacobi (Fig. 8): three rows, same column.

    ``out[i,j] = c * (x[i-1,j] + x[i,j] + x[i+1,j])``
    """
    interior = coeff * (x[..., :-2, 1:-1] + x[..., 1:-1, 1:-1] + x[..., 2:, 1:-1])
    return _interior_update_2d(x, interior.astype(x.dtype), 1)


def laplacian(x: Array) -> Array:
    """COSMO 5-point Laplacian (Eq. 1), computed on the interior.

    ``L[i,j] = 4*x[i,j] - x[i+1,j] - x[i-1,j] - x[i,j+1] - x[i,j-1]``
    """
    interior = lap_field(x)
    return _interior_update_2d(x, interior.astype(x.dtype), 1)


def lap_field(x: Array) -> Array:
    """Raw Laplacian values on the interior (shape shrinks by 2 per dim).

    This is the building block hdiff composes five of; returned *without*
    re-embedding into the full grid so compound stencils can chain it.
    """
    return (
        4.0 * x[..., 1:-1, 1:-1]
        - x[..., 2:, 1:-1]
        - x[..., :-2, 1:-1]
        - x[..., 1:-1, 2:]
        - x[..., 1:-1, :-2]
    )


def jacobi2d_5pt(x: Array, coeff: float = 0.2) -> Array:
    """PolyBench jacobi-2d: 5-point star average."""
    interior = coeff * (
        x[..., 1:-1, 1:-1]
        + x[..., 2:, 1:-1]
        + x[..., :-2, 1:-1]
        + x[..., 1:-1, 2:]
        + x[..., 1:-1, :-2]
    )
    return _interior_update_2d(x, interior.astype(x.dtype), 1)


def jacobi2d_9pt(x: Array, coeff: float = 1.0 / 9.0) -> Array:
    """9-point box Jacobi: mean of the 3x3 neighbourhood."""
    acc = jnp.zeros_like(x[..., 1:-1, 1:-1])
    for dr in (-1, 0, 1):
        for dc in (-1, 0, 1):
            acc = acc + x[..., 1 + dr : x.shape[-2] - 1 + dr, 1 + dc : x.shape[-1] - 1 + dc]
    return _interior_update_2d(x, (coeff * acc).astype(x.dtype), 1)


def seidel2d_sweep(x: Array, coeff: float = 1.0 / 9.0) -> Array:
    """Parallel (Jacobi-style) 9-point sweep — the throughput-benchmark form.

    The streaming spatial mapping in the paper pipelines seidel-2d row by
    row; the dependence-free per-sweep form is what maps onto one AIE core.
    """
    return jacobi2d_9pt(x, coeff)


def seidel2d_exact(x: Array, coeff: float = 1.0 / 9.0) -> Array:
    """Exact PolyBench seidel-2d: in-place Gauss-Seidel, row-major order.

    Doubly sequential (each point reads already-updated west and north
    neighbours). Implemented with nested ``lax.fori_loop`` for the oracle;
    O(R*C) sequential steps, so use small grids in tests.
    """
    if x.ndim != 2:
        return jax.vmap(lambda p: seidel2d_exact(p, coeff))(x.reshape((-1,) + x.shape[-2:])).reshape(x.shape)

    rows, cols = x.shape

    def col_body(j, row_state):
        i, grid = row_state
        s = (
            grid[i - 1, j - 1] + grid[i - 1, j] + grid[i - 1, j + 1]
            + grid[i, j - 1] + grid[i, j] + grid[i, j + 1]
            + grid[i + 1, j - 1] + grid[i + 1, j] + grid[i + 1, j + 1]
        )
        return (i, grid.at[i, j].set((coeff * s).astype(grid.dtype)))

    def row_body(i, grid):
        _, grid = jax.lax.fori_loop(1, cols - 1, col_body, (i, grid))
        return grid

    return jax.lax.fori_loop(1, rows - 1, row_body, x)


ELEMENTARY_FNS: dict[str, Callable[..., Array]] = {
    "jacobi1d": jacobi1d,
    "jacobi2d_3pt": jacobi2d_3pt,
    "laplacian": laplacian,
    "jacobi2d_5pt": jacobi2d_5pt,
    "jacobi2d_9pt": jacobi2d_9pt,
    "seidel2d": seidel2d_sweep,
}
