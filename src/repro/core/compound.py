"""Compound-stencil composition with explicit execution policies.

The paper's central systems idea is that a *compound* stencil (a DAG of
elementary stages with producer/consumer dependencies) should be executed so
that intermediates never round-trip through external memory, and so that the
compute provisioned per stage matches that stage's compute/byte ratio
(§3.1-§3.2). Since the ``repro.ir`` subsystem landed, this module is a thin
policy layer over the IR lowerings:

  * :class:`CompoundStencil` — wraps a :class:`repro.ir.StencilProgram` and
    dispatches its three execution policies to the compiler backends:
      - ``staged``        ``ir.lower_reference(mode="staged")`` — every stage
                          materialised + barriered (single-AIE / load-store
                          baseline; reproduces the slow side of Fig. 9),
      - ``fused-xla``     ``ir.lower_reference(mode="fused")`` — one jitted
                          function (XLA fusion on the default compiler path),
      - ``fused-pallas``  ``ir.lower_pallas`` — generic fused VMEM tile
                          codegen (the multi-AIE/B-block analogue; fast side
                          of Fig. 9). No longer hdiff-only.
  * :class:`StencilStage` — per-op accounting view derived from the graph
    (§3.1-style op counts), kept for the analytical reports.
  * :func:`plan_partition` — the B-block planner: given a grid and a device
    mesh, chooses depth-parallel vs halo row-decomposition by evaluating the
    analytical model's three terms (compute / HBM / ICI seconds) for each
    candidate, exactly how §3.4 sizes lanes per shimDMA channel. Pass an IR
    ``program`` to drive it from graph-inferred halo and op counts.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from repro.core import hdiff as hdiff_mod
from repro.core.analytical import TPUV5E, MachineModel, roofline_terms
from repro.ir import (
    StencilProgram,
    hdiff_program,
    lower_pallas,
    lower_reference,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class StencilStage:
    """Accounting view of one stage of a compound stencil (metadata only —
    execution goes through the IR lowerings, not through this class).

    ``macs`` / ``other_ops`` follow the paper's Eq. 5-6 accounting per
    evaluation; ``reads`` counts declared accesses per evaluation (Eq. 8-9);
    ``evaluations`` is how many times one output point consumes this stage
    under the streaming model (derived from the composed offsets).
    """

    name: str
    inputs: tuple[str, ...]
    macs: int
    other_ops: int
    reads: int
    evaluations: int = 1

    @property
    def flops(self) -> int:
        return 2 * self.macs + self.other_ops


class CompoundStencil:
    """An IR program plus the three named execution policies."""

    POLICIES = ("staged", "fused-xla", "fused-pallas")

    def __init__(self, name: str, program: StencilProgram):
        self.name = name
        self.program = program
        self.radius = program.radius
        evals = program.evaluations()
        self.stages = tuple(
            StencilStage(
                name=op.name,
                inputs=op.fields(),
                macs=op.cost.macs,
                other_ops=op.cost.other_ops,
                reads=len(op.reads),
                evaluations=evals[op.name],
            )
            for op in program.ops
        )
        self._fused = lower_reference(program, mode="fused")
        self._staged = lower_reference(program, mode="staged")
        # Built lazily: kernel codegen is the expensive lowering, and many
        # callers only ever use the reference policies.
        self._pallas: Callable[[Array], Array] | None = None

    # -- execution policies ------------------------------------------------

    def apply(self, x: Array, policy: str = "fused-xla") -> Array:
        if policy == "fused-xla":
            return self._fused(x)
        if policy == "staged":
            return self._staged(x)
        if policy == "fused-pallas":
            if self._pallas is None:
                self._pallas = lower_pallas(self.program)
            return self._pallas(x)
        raise ValueError(f"unknown policy {policy!r} (want one of {self.POLICIES})")

    # -- analytical accounting (§3.1), graph-derived -------------------------

    def total_flops(self, interior_points: int) -> int:
        """Streaming-model flops per sweep (each stage charged once per
        composed offset the output consumes it at — Eq. 5-7)."""
        return interior_points * self.program.spec().flops

    def staged_bytes(self, interior_points: int, itemsize: int = 4) -> int:
        """HBM traffic under the staged policy: every stage reads its
        operands and writes its output through memory (Eq. 8-9 analogue)."""
        return self.program.staged_bytes(interior_points, itemsize)

    def fused_bytes(self, total_points: int, itemsize: int = 4) -> int:
        """Compulsory HBM traffic under fusion: inputs once in, output once
        out (the B-block broadcast/VMEM-reuse analogue)."""
        return self.program.fused_bytes(total_points, itemsize)


def make_hdiff_compound(coeff: float = 0.025, limit: bool = True) -> CompoundStencil:
    """hdiff as an explicit compound DAG (Laplacian -> fluxes -> output)."""
    return CompoundStencil("hdiff", hdiff_program(coeff, limit=limit))


# ---------------------------------------------------------------------------
# The B-block planner: partition choice driven by the analytical model.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """A chosen domain decomposition for a (grid, mesh) pair."""

    kind: str              # "depth" | "rows" | "depth+rows"
    depth_shards: int
    row_shards: int
    halo: int
    # Predicted per-device roofline terms (seconds) for one sweep.
    compute_s: float
    hbm_s: float
    ici_s: float

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.hbm_s, self.ici_s)


def plan_partition(
    depth: int,
    rows: int,
    cols: int,
    n_devices: int,
    *,
    halo: int | None = None,
    itemsize: int = 4,
    machine: MachineModel = TPUV5E,
    flops_per_point: int | None = None,
    program: StencilProgram | None = None,
) -> PartitionPlan:
    """Chooses how to shard a (depth, rows, cols) grid over ``n_devices``.

    Mirrors §3.4: the paper assigns one plane per B-block (depth-parallel,
    zero inter-block traffic) until B-blocks outnumber planes, then splits
    planes across lanes (which costs halo traffic). We enumerate candidate
    (depth_shards x row_shards) factorisations, evaluate the three roofline
    terms per device, and pick the minimum bottleneck term.

    With ``program`` given, halo and flops/point come from the graph
    analysis; otherwise they default to the (IR-derived) hdiff constants.
    """
    if program is not None:
        spec = program.spec()
        halo = spec.radius if halo is None else halo
        flops_per_point = spec.flops if flops_per_point is None else flops_per_point
    if halo is None:
        halo = hdiff_mod.HALO
    if flops_per_point is None:
        flops_per_point = hdiff_mod.HDIFF_SPEC.flops
    best: PartitionPlan | None = None
    for d_sh in _divisors(n_devices):
        r_sh = n_devices // d_sh
        if depth % d_sh or d_sh > depth:
            continue
        if (rows - 2 * halo) // r_sh < 2 * halo + 1:
            continue  # shards thinner than the halo make no sense
        local_depth = depth // d_sh
        local_rows = rows // r_sh + (2 * halo if r_sh > 1 else 0)
        points = local_depth * local_rows * cols
        flops = points * flops_per_point
        hbm_bytes = 3 * points * itemsize  # in + coeff + out, fused policy
        # Halo exchange: 2 faces x halo rows x cols x depth, both directions.
        ici_bytes = 0 if r_sh == 1 else 2 * halo * cols * local_depth * itemsize * 2
        comp_s, hbm_s, ici_s = roofline_terms(flops, hbm_bytes, ici_bytes, machine)
        kind = "depth" if r_sh == 1 else ("rows" if d_sh == 1 else "depth+rows")
        cand = PartitionPlan(kind, d_sh, r_sh, halo, comp_s, hbm_s, ici_s)
        if best is None or cand.step_s < best.step_s:
            best = cand
    if best is None:
        # Grid too small to fill every device (row shards would be thinner
        # than the halo): degrade gracefully — underfill the mesh with the
        # largest depth-parallel plan instead of failing. The idle devices
        # are reported via depth_shards * row_shards < n_devices.
        d_sh = max(d for d in _divisors(depth) if d <= n_devices)
        points = (depth // d_sh) * rows * cols
        comp_s, hbm_s, ici_s = roofline_terms(
            points * flops_per_point, 3 * points * itemsize, 0, machine
        )
        return PartitionPlan("depth-underfilled", d_sh, 1, halo, comp_s, hbm_s, ici_s)
    return best


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]
