"""Compound-stencil composition with explicit execution policies.

The paper's central systems idea is that a *compound* stencil (a DAG of
elementary stages with producer/consumer dependencies) should be executed so
that intermediates never round-trip through external memory, and so that the
compute provisioned per stage matches that stage's compute/byte ratio
(§3.1-§3.2). This module makes that idea a first-class, reusable feature:

  * :class:`StencilStage` — one stage: a jnp function plus its §3.1-style op
    accounting.
  * :class:`CompoundStencil` — an ordered DAG of stages with three execution
    policies:
      - ``staged``        every stage materialised + barriered (single-AIE /
                          load-store baseline; reproduces the slow side of
                          Fig. 9),
      - ``fused-xla``     one jitted function (XLA fusion; paper-faithful
                          algorithm on the default compiler path),
      - ``fused-pallas``  the hand-fused Pallas TPU kernel from
                          ``repro.kernels`` (the multi-AIE/B-block analogue;
                          fast side of Fig. 9).
  * :func:`plan_partition` — the B-block planner: given a grid and a device
    mesh, chooses depth-parallel vs halo row-decomposition by evaluating the
    analytical model's three terms (compute / HBM / ICI seconds) for each
    candidate, exactly how §3.4 sizes lanes per shimDMA channel.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import hdiff as hdiff_mod
from repro.core.analytical import TPUV5E, MachineModel, roofline_terms

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class StencilStage:
    """One stage of a compound stencil.

    ``fn`` maps (dict of named inputs) -> named output array. ``macs`` /
    ``other_ops`` follow the paper's Eq. 5-6 accounting per interior output
    point; ``reads`` counts distinct input elements per point (Eq. 8-9).
    """

    name: str
    fn: Callable[..., Array]
    inputs: tuple[str, ...]
    macs: int
    other_ops: int
    reads: int

    @property
    def flops(self) -> int:
        return 2 * self.macs + self.other_ops


class CompoundStencil:
    """An ordered sequence of stages forming a compound stencil DAG."""

    def __init__(self, name: str, stages: Sequence[StencilStage], radius: int):
        self.name = name
        self.stages = tuple(stages)
        self.radius = radius
        by_name = {}
        for s in self.stages:
            for dep in s.inputs:
                if dep not in by_name and dep != "input":
                    raise ValueError(f"stage {s.name} depends on unknown {dep!r}")
            by_name[s.name] = s
        self._fused = jax.jit(self._run)

    # -- execution policies ------------------------------------------------

    def _run(self, x: Array) -> Array:
        env: dict[str, Array] = {"input": x}
        out = x
        for stage in self.stages:
            out = stage.fn(*(env[k] for k in stage.inputs))
            env[stage.name] = out
        return out

    def apply(self, x: Array, policy: str = "fused-xla") -> Array:
        if policy == "fused-xla":
            return self._fused(x)
        if policy == "staged":
            env: dict[str, Array] = {"input": x}
            out = x
            for stage in self.stages:
                fn = jax.jit(stage.fn)
                out = jax.block_until_ready(fn(*(env[k] for k in stage.inputs)))
                env[stage.name] = out
            return out
        if policy == "fused-pallas":
            raise NotImplementedError(
                "fused-pallas policy is provided per-kernel via repro.kernels "
                "(see kernels/hdiff/ops.py); generic DAG->Pallas codegen is out "
                "of scope."
            )
        raise ValueError(f"unknown policy {policy!r}")

    # -- analytical accounting (§3.1) ---------------------------------------

    def total_flops(self, interior_points: int) -> int:
        return interior_points * sum(s.flops for s in self.stages)

    def staged_bytes(self, interior_points: int, itemsize: int = 4) -> int:
        """HBM traffic under the staged policy: every stage reads its
        operands and writes its output through memory (Eq. 8-9 analogue)."""
        total = 0
        for s in self.stages:
            total += (s.reads + 1) * interior_points * itemsize
        return total

    def fused_bytes(self, total_points: int, itemsize: int = 4, n_inputs: int = 1) -> int:
        """Compulsory HBM traffic under fusion: inputs once in, output once
        out (the B-block broadcast/VMEM-reuse analogue)."""
        return (n_inputs + 1) * total_points * itemsize


def make_hdiff_compound(coeff: float = 0.025, limit: bool = True) -> CompoundStencil:
    """hdiff as an explicit 3-stage compound (Laplacian -> flux -> output)."""

    def lap_stage(x):
        return hdiff_mod._staged_lap(x)

    def flux_stage(x, lap):
        return jnp.stack(hdiff_mod._staged_flux(x, lap, limit=limit))

    def out_stage(x, flx):
        return hdiff_mod._staged_out(x, coeff, flx[0], flx[1], flx[2], flx[3])

    stages = (
        StencilStage("lap", lap_stage, ("input",), macs=5 * 5, other_ops=0, reads=5 * 5),
        StencilStage("flux", flux_stage, ("input", "lap"), macs=4 * 1, other_ops=4 * 3, reads=2 * 4),
        StencilStage("out", out_stage, ("input", "flux"), macs=1, other_ops=4, reads=6),
    )
    return CompoundStencil("hdiff", stages, radius=hdiff_mod.HALO)


# ---------------------------------------------------------------------------
# The B-block planner: partition choice driven by the analytical model.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """A chosen domain decomposition for a (grid, mesh) pair."""

    kind: str              # "depth" | "rows" | "depth+rows"
    depth_shards: int
    row_shards: int
    halo: int
    # Predicted per-device roofline terms (seconds) for one sweep.
    compute_s: float
    hbm_s: float
    ici_s: float

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.hbm_s, self.ici_s)


def plan_partition(
    depth: int,
    rows: int,
    cols: int,
    n_devices: int,
    *,
    halo: int = hdiff_mod.HALO,
    itemsize: int = 4,
    machine: MachineModel = TPUV5E,
    flops_per_point: int = hdiff_mod.HDIFF_SPEC.flops,
) -> PartitionPlan:
    """Chooses how to shard a (depth, rows, cols) grid over ``n_devices``.

    Mirrors §3.4: the paper assigns one plane per B-block (depth-parallel,
    zero inter-block traffic) until B-blocks outnumber planes, then splits
    planes across lanes (which costs halo traffic). We enumerate candidate
    (depth_shards x row_shards) factorisations, evaluate the three roofline
    terms per device, and pick the minimum bottleneck term.
    """
    best: PartitionPlan | None = None
    for d_sh in _divisors(n_devices):
        r_sh = n_devices // d_sh
        if depth % d_sh or d_sh > depth:
            continue
        if (rows - 2 * halo) // r_sh < 2 * halo + 1:
            continue  # shards thinner than the halo make no sense
        local_depth = depth // d_sh
        local_rows = rows // r_sh + (2 * halo if r_sh > 1 else 0)
        points = local_depth * local_rows * cols
        flops = points * flops_per_point
        hbm_bytes = 3 * points * itemsize  # in + coeff + out, fused policy
        # Halo exchange: 2 faces x halo rows x cols x depth, both directions.
        ici_bytes = 0 if r_sh == 1 else 2 * halo * cols * local_depth * itemsize * 2
        comp_s, hbm_s, ici_s = roofline_terms(flops, hbm_bytes, ici_bytes, machine)
        kind = "depth" if r_sh == 1 else ("rows" if d_sh == 1 else "depth+rows")
        cand = PartitionPlan(kind, d_sh, r_sh, halo, comp_s, hbm_s, ici_s)
        if best is None or cand.step_s < best.step_s:
            best = cand
    if best is None:
        # Grid too small to fill every device (row shards would be thinner
        # than the halo): degrade gracefully — underfill the mesh with the
        # largest depth-parallel plan instead of failing. The idle devices
        # are reported via depth_shards * row_shards < n_devices.
        d_sh = max(d for d in _divisors(depth) if d <= n_devices)
        points = (depth // d_sh) * rows * cols
        comp_s, hbm_s, ici_s = roofline_terms(
            points * flops_per_point, 3 * points * itemsize, 0, machine
        )
        return PartitionPlan("depth-underfilled", d_sh, 1, halo, comp_s, hbm_s, ici_s)
    return best


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]
