"""Horizontal diffusion (hdiff) — the paper's compound stencil (Eq. 1-4, Alg. 1).

Two variants, both reproduced bit-for-bit against NumPy loop oracles in
``tests/test_hdiff.py``:

  * :func:`hdiff` — the full COSMO kernel with the *flux limiter*
    (Eq. 2-3: a flux is zeroed when it points up-gradient). This is the
    production kernel; it is nonlinear due to the compare/select.
  * :func:`hdiff_simple` — Algorithm 1's unlimited polynomial form (the
    version used by the prior FPGA accelerators NERO/NARMADA the paper
    compares against). Linear in the input, which the property tests
    exploit.

Grid convention: ``(depth, rows, cols)`` (the paper's ``D x R x C``,
evaluated on 64 x 256 x 256). Depth is embarrassingly parallel. All
computation happens on the interior ``[2 : -2]`` ring in rows and cols —
a radius-2 halo, because flux reads the Laplacian of a neighbour which in
turn reads the neighbour's neighbour. Boundary cells pass through.

Stage structure (what the multi-AIE mapping splits across cores):

  stage 1 (Laplacian core):  L = lap(psi)              5-pt, 5 MACs
  stage 2 (flux core):       F = limit(dL_r, dpsi_r)   diff + cmp + select
                             G = limit(dL_c, dpsi_c)
  stage 3 (output):          out = psi - C * (F_r - F_rm + G_c - G_cm)

The *fused* execution policies in :mod:`repro.core.compound` keep L, F, G
in VMEM (the TPU analogue of the paper's accumulator-register residency /
cascade forwarding); the *staged* policy materialises each to HBM (the
single-core / CPU-baseline analogue).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stencils import StencilSpec
from repro.ir.programs import hdiff_program

Array = jax.Array

# Per-output-point op counts for the analytical model (§3.1), DERIVED from
# the IR dataflow graph (repro.ir.programs.hdiff_program) rather than
# hand-counted: 5 Laplacians x 5 MACs (the lap op is consumed at the 5 star
# offsets); 4 fluxes x (1 sub + 1 mul [limiter product] + 1 cmp + 1 select);
# output: 4 adds + 1 MAC (coeff); 13 distinct reads (the composed star-of-
# star footprint); radius 2. tests/test_ir_graph.py pins the paper's
# literal numbers (26 MACs / 20 ops / 13 reads / r=2) against this.
_DERIVED = hdiff_program().spec()
HDIFF_SPEC = StencilSpec(
    name="hdiff",
    macs=_DERIVED.macs,
    other_ops=_DERIVED.other_ops,
    reads=_DERIVED.reads,
    radius=_DERIVED.radius,
)

# Radius of the compound stencil (flux-of-laplacian): 2 cells, inferred.
HALO = HDIFF_SPEC.radius


def _limit(dlap: Array, dpsi: Array) -> Array:
    """Flux limiter (Eq. 2-3): keep the flux only if it is down-gradient.

    ``F = dL if dL * dpsi <= 0 else 0``
    """
    return jnp.where(dlap * dpsi <= 0, dlap, jnp.zeros_like(dlap))


def _hdiff_interior(psi: Array, coeff: Array | float, *, limit: bool) -> Array:
    """Computes hdiff output on the interior (shape shrinks by 2*HALO).

    ``psi``: ``(..., R, C)``. Returns ``(..., R-4, C-4)``.
    """
    # Laplacian on the radius-1 interior: shape (..., R-2, C-2).
    lap = (
        4.0 * psi[..., 1:-1, 1:-1]
        - psi[..., 2:, 1:-1]
        - psi[..., :-2, 1:-1]
        - psi[..., 1:-1, 2:]
        - psi[..., 1:-1, :-2]
    )

    # Indexing guide: lap[..., i, j] corresponds to psi[..., i+1, j+1].
    # We need, for output point (r, c) with r,c in [2, N-2):
    #   row-fluxes  F(r, c)   = limit(L[r+1,c] - L[r,c],  psi[r+1,c]-psi[r,c])
    #               F(r-1, c) = limit(L[r,c] - L[r-1,c],  psi[r,c]-psi[r-1,c])
    #   col-fluxes  G(r, c), G(r, c-1) analogously.
    # Slices of `lap` covering output rows [2, R-2) => lap rows [1, R-3).
    lap_c = lap[..., 1:-1, 1:-1]   # L[r, c]
    lap_rp = lap[..., 2:, 1:-1]    # L[r+1, c]
    lap_rm = lap[..., :-2, 1:-1]   # L[r-1, c]
    lap_cp = lap[..., 1:-1, 2:]    # L[r, c+1]
    lap_cm = lap[..., 1:-1, :-2]   # L[r, c-1]

    psi_c = psi[..., 2:-2, 2:-2]
    psi_rp = psi[..., 3:-1, 2:-2]
    psi_rm = psi[..., 1:-3, 2:-2]
    psi_cp = psi[..., 2:-2, 3:-1]
    psi_cm = psi[..., 2:-2, 1:-3]

    flx_r = lap_rp - lap_c   # F at (r+1/2, c)
    flx_rm = lap_c - lap_rm  # F at (r-1/2, c)
    flx_c = lap_cp - lap_c   # G at (r, c+1/2)
    flx_cm = lap_c - lap_cm  # G at (r, c-1/2)

    if limit:
        flx_r = _limit(flx_r, psi_rp - psi_c)
        flx_rm = _limit(flx_rm, psi_c - psi_rm)
        flx_c = _limit(flx_c, psi_cp - psi_c)
        flx_cm = _limit(flx_cm, psi_c - psi_cm)

    if isinstance(coeff, jax.Array) and coeff.ndim >= 2:
        coeff = coeff[..., 2:-2, 2:-2]
    return psi_c - coeff * ((flx_r - flx_rm) + (flx_c - flx_cm))


def hdiff(psi: Array, coeff: Array | float = 0.025) -> Array:
    """Full COSMO horizontal diffusion with flux limiter (Eq. 1-4).

    Args:
      psi: input field ``(..., R, C)`` — typically ``(D, R, C)``.
      coeff: diffusion coefficient ``C^n_{r,c,d}`` — scalar or a field
        broadcastable to ``psi`` (the paper parameterises per grid point).

    Returns:
      Same shape as ``psi``; interior diffused, radius-2 border unchanged.
    """
    interior = _hdiff_interior(psi, coeff, limit=True)
    return psi.at[..., HALO:-HALO, HALO:-HALO].set(interior.astype(psi.dtype))


def hdiff_simple(psi: Array, coeff: Array | float = 0.025) -> Array:
    """Unlimited hdiff (Algorithm 1 / NERO-NARMADA form). Linear in ``psi``
    up to the constant passthrough of the boundary."""
    interior = _hdiff_interior(psi, coeff, limit=False)
    return psi.at[..., HALO:-HALO, HALO:-HALO].set(interior.astype(psi.dtype))


def hdiff_staged(psi: Array, coeff: Array | float = 0.025, *, limit: bool = True) -> Array:
    """Stage-materialising hdiff: every stage is forced to HBM.

    This is the single-AIE / load-store-architecture baseline analogue used
    by ``benchmarks/fig9_designs.py``: the Laplacian field, the four flux
    fields, and the output are each produced by a separately jitted function
    with ``jax.block_until_ready`` barriers between them, so XLA cannot fuse
    across stages. Numerically identical to :func:`hdiff`.
    """
    lap_fn = jax.jit(_staged_lap)
    flux_fn = jax.jit(_staged_flux, static_argnames=("limit",))
    out_fn = jax.jit(_staged_out)

    lap = jax.block_until_ready(lap_fn(psi))
    flx = jax.block_until_ready(flux_fn(psi, lap, limit=limit))
    out = out_fn(psi, coeff, *flx)
    return out


def _staged_lap(psi: Array) -> Array:
    return (
        4.0 * psi[..., 1:-1, 1:-1]
        - psi[..., 2:, 1:-1]
        - psi[..., :-2, 1:-1]
        - psi[..., 1:-1, 2:]
        - psi[..., 1:-1, :-2]
    )


def _staged_flux(psi: Array, lap: Array, *, limit: bool):
    lap_c = lap[..., 1:-1, 1:-1]
    flx_r = lap[..., 2:, 1:-1] - lap_c
    flx_rm = lap_c - lap[..., :-2, 1:-1]
    flx_c = lap[..., 1:-1, 2:] - lap_c
    flx_cm = lap_c - lap[..., 1:-1, :-2]
    if limit:
        psi_c = psi[..., 2:-2, 2:-2]
        flx_r = _limit(flx_r, psi[..., 3:-1, 2:-2] - psi_c)
        flx_rm = _limit(flx_rm, psi_c - psi[..., 1:-3, 2:-2])
        flx_c = _limit(flx_c, psi[..., 2:-2, 3:-1] - psi_c)
        flx_cm = _limit(flx_cm, psi_c - psi[..., 2:-2, 1:-3])
    return flx_r, flx_rm, flx_c, flx_cm


def _staged_out(psi, coeff, flx_r, flx_rm, flx_c, flx_cm):
    if isinstance(coeff, jax.Array) and coeff.ndim >= 2:
        coeff = coeff[..., 2:-2, 2:-2]
    interior = psi[..., 2:-2, 2:-2] - coeff * ((flx_r - flx_rm) + (flx_c - flx_cm))
    return psi.at[..., 2:-2, 2:-2].set(interior.astype(psi.dtype))


def hdiff_flops(depth: int, rows: int, cols: int) -> int:
    """Total flops for one hdiff sweep (paper Eq. 5-7 op counts, as flops)."""
    interior = (rows - 2 * HALO) * (cols - 2 * HALO) * depth
    return interior * HDIFF_SPEC.flops


def hdiff_min_bytes(depth: int, rows: int, cols: int, itemsize: int = 4) -> int:
    """Minimum HBM traffic for one sweep: read grid + coeff once, write once.

    The paper's Eq. 8-9 count *algorithmic* element touches (25 + 8 per
    point) because an AIE core streams rows without a reuse cache; the TPU
    fused-kernel lower bound is compulsory traffic only — each input element
    is loaded into VMEM once and reused there (the B-block broadcast
    analogue). Reported both ways in benchmarks.
    """
    return (3 * depth * rows * cols) * itemsize


def hdiff_algorithmic_bytes(depth: int, rows: int, cols: int, itemsize: int = 4) -> int:
    """Paper Eq. 8-9 traffic model: every stencil read hits memory."""
    interior = (rows - 2 * HALO) * (cols - 2 * HALO) * depth
    reads = 5 * 5 * interior + 2 * 4 * interior  # Laplacian + flux streams
    writes = interior
    return (reads + writes) * itemsize
