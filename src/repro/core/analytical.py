"""Analytical performance model — the paper's §3.1, ported to TPU constants.

The paper derives per-kernel *compute cycles* (Eq. 5-7) and *memory cycles*
(Eq. 8-10) for an AIE core (8 fp32 MACs/cycle, 2x256-bit loads/cycle) and
uses the ratio to decide how to split hdiff across cores. We reproduce that
model verbatim (:func:`aie_cycles`) for the faithful-reproduction benchmarks,
and generalise it to the three-term roofline the dry-run reports:

    compute_s    = flops / (chips * peak_flops)
    hbm_s        = bytes / (chips * hbm_bw)
    collective_s = coll_bytes / (chips * ici_bw)

Hardware constants per the brief: TPU v5e — 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI. fp32 MXU throughput is modelled at half
the bf16 number; VPU-bound (non-matmul) stencil math is modelled separately
because stencils run on the VPU, not the MXU.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MachineModel:
    name: str
    peak_flops_bf16: float      # FLOP/s per chip (MXU)
    peak_flops_f32: float       # FLOP/s per chip (MXU, fp32)
    peak_flops_vpu_f32: float   # FLOP/s per chip (vector unit; stencil path)
    hbm_bw: float               # bytes/s per chip
    ici_bw: float               # bytes/s per link
    hbm_gib: float              # HBM capacity per chip
    vmem_bytes: int             # VMEM per core


# TPU v5e (brief constants; VPU estimated at 8 lanes x 128 sublanes x 2 flops
# x 940MHz-class clock ~= 2 TFLOP/s f32 -- order-of-magnitude for planning).
TPUV5E = MachineModel(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    peak_flops_f32=98.5e12,
    peak_flops_vpu_f32=2.0e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_gib=16.0,
    vmem_bytes=128 * 1024 * 1024,
)

# The paper's AIE core (for the faithful §3.1 reproduction): 8 fp32 MACs/cycle,
# two 256-bit loads/cycle, 1 GHz.
AIE_MACS_PER_CYCLE = 8
AIE_LOAD_BITS_PER_CYCLE = 2 * 256
AIE_CLOCK_HZ = 1.0e9


def aie_hdiff_cycles(rows: int, cols: int, depth: int) -> dict[str, float]:
    """Paper Eq. 5-10, verbatim: min compute & memory cycles for one sweep."""
    interior = (rows - 4) * (cols - 4) * depth
    lap_comp = 5 * interior * 5 / AIE_MACS_PER_CYCLE                      # Eq. 5
    flux_comp = (2 * interior * 4) / AIE_MACS_PER_CYCLE + (
        3 * (1 * interior * 4)
    ) / AIE_MACS_PER_CYCLE                                                # Eq. 6
    lap_mem = 5 * interior * 5 * 32 / AIE_LOAD_BITS_PER_CYCLE             # Eq. 8
    flux_mem = 2 * interior * 4 * 32 / AIE_LOAD_BITS_PER_CYCLE            # Eq. 9
    return {
        "laplacian_compute_cycles": lap_comp,
        "flux_compute_cycles": flux_comp,
        "hdiff_compute_cycles": lap_comp + flux_comp,                     # Eq. 7
        "laplacian_memory_cycles": lap_mem,
        "flux_memory_cycles": flux_mem,
        "hdiff_memory_cycles": lap_mem + flux_mem,                        # Eq. 10
    }


def aie_stencil_cycles(
    spec, rows: int, cols: int, depth: int, *, itemsize_bits: int = 32
) -> dict[str, float]:
    """AIE cycle estimate for ANY stencil from its (graph-derived) spec.

    ``spec`` is anything with ``macs`` / ``other_ops`` / ``reads`` / ``radius``
    per-output-point fields (``repro.ir.ProgramSpec`` or ``StencilSpec``).
    Compute charges one cycle per ``AIE_MACS_PER_CYCLE`` ops (MAC and non-MAC
    vector ops issue at the same rate on the AIE VLIW slots); memory charges
    ``spec.reads`` — the composed *distinct-element* footprint, i.e. WITH
    register reuse. This is deliberately NOT the same accounting as
    :func:`aie_hdiff_cycles`, which reproduces Eq. 5-10 verbatim (every
    stage re-streams its operands — 33 reads/point for hdiff vs 13 here, and
    Eq. 7 excludes the output stage — 45 ops vs this model's 46). Use
    ``aie_hdiff_cycles`` for paper-faithful hdiff numbers and this function
    for planning new graph-defined stencils.
    """
    side = 2 * spec.radius
    interior = max(rows - side, 0) * max(cols - side, 0) * depth
    compute = interior * (spec.macs + spec.other_ops) / AIE_MACS_PER_CYCLE
    memory = interior * spec.reads * itemsize_bits / AIE_LOAD_BITS_PER_CYCLE
    return {
        "compute_cycles": compute,
        "memory_cycles": memory,
        "bound": "memory" if memory > compute else "compute",
        "seconds": max(compute, memory) / AIE_CLOCK_HZ,
    }


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    ici_bytes: float,
    machine: MachineModel = TPUV5E,
    *,
    dtype: str = "f32",
    unit: str = "vpu",
) -> tuple[float, float, float]:
    """Three-term roofline (seconds) for ONE chip's share of work.

    ``unit`` selects the compute peak: "mxu" for matmul-dominated work,
    "vpu" for elementwise/stencil work (stencils never touch the MXU).
    """
    if unit == "vpu":
        peak = machine.peak_flops_vpu_f32
    elif dtype == "bf16":
        peak = machine.peak_flops_bf16
    else:
        peak = machine.peak_flops_f32
    return (
        flops / peak,
        hbm_bytes / machine.hbm_bw,
        ici_bytes / machine.ici_bw if ici_bytes else 0.0,
    )


def dominant_term(compute_s: float, hbm_s: float, ici_s: float) -> str:
    terms = {"compute": compute_s, "memory": hbm_s, "collective": ici_s}
    return max(terms, key=terms.get)  # type: ignore[arg-type]


def arithmetic_intensity(flops: float, hbm_bytes: float) -> float:
    return flops / max(hbm_bytes, 1)


def roofline_fraction(
    achieved_flops_per_s: float,
    flops: float,
    hbm_bytes: float,
    machine: MachineModel = TPUV5E,
    *,
    unit: str = "vpu",
    dtype: str = "f32",
) -> float:
    """Fraction of the *attainable* roofline (min of compute peak and
    bandwidth * AI), the paper's 'Ach. Roof.' column in Table 2."""
    if unit == "vpu":
        peak = machine.peak_flops_vpu_f32
    elif dtype == "bf16":
        peak = machine.peak_flops_bf16
    else:
        peak = machine.peak_flops_f32
    attainable = min(peak, machine.hbm_bw * arithmetic_intensity(flops, hbm_bytes))
    return achieved_flops_per_s / attainable
