"""Core library: the paper's compound-stencil contribution in JAX.

Public API:
  hdiff, hdiff_simple, hdiff_staged       -- the COSMO horizontal-diffusion kernel
  elementary stencils (jacobi1d, ...)     -- §3.5 benchmark suite
  CompoundStencil / make_hdiff_compound   -- staged/fused execution policies
  plan_partition                          -- B-block-style partition planner
  run_simulation                          -- iterative timestep driver
  aie_hdiff_cycles / roofline_terms       -- §3.1 analytical model (AIE + TPU)
"""

from repro.core.analytical import (
    TPUV5E,
    MachineModel,
    aie_hdiff_cycles,
    aie_stencil_cycles,
    arithmetic_intensity,
    dominant_term,
    roofline_fraction,
    roofline_terms,
)
from repro.core.compound import (
    CompoundStencil,
    PartitionPlan,
    StencilStage,
    make_hdiff_compound,
    plan_partition,
)
from repro.core.hdiff import (
    HALO,
    HDIFF_SPEC,
    hdiff,
    hdiff_algorithmic_bytes,
    hdiff_flops,
    hdiff_min_bytes,
    hdiff_simple,
    hdiff_staged,
)
from repro.core.stencils import (
    ELEMENTARY_FNS,
    ELEMENTARY_SPECS,
    StencilSpec,
    jacobi1d,
    jacobi2d_3pt,
    jacobi2d_5pt,
    jacobi2d_9pt,
    lap_field,
    laplacian,
    seidel2d_exact,
    seidel2d_sweep,
)
from repro.core.timestep import make_initial_field, run_simulation
