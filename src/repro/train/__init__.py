from repro.train.loop import (
    SpikeDetector,
    StepWatchdog,
    TrainConfig,
    batch_sharding,
    init_train_state,
    make_train_step,
    train,
)
from repro.train.loop import shape_for_microbatches
from repro.train.assimilate import (
    AssimilationConfig,
    FitResult,
    fit_coefficient_field,
    forward_model,
    synthetic_observations,
    true_coefficients,
)
