from repro.train.loop import (
    SpikeDetector,
    StepWatchdog,
    TrainConfig,
    batch_sharding,
    init_train_state,
    make_train_step,
    train,
)
from repro.train.loop import shape_for_microbatches
