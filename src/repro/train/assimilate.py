"""Variational data assimilation on the IR: fit a coefficient field.

The first end-to-end consumer of the derived adjoints
(:mod:`repro.ir.autodiff`): recover ``hdiff_coupled_program``'s
spatially-varying diffusion coefficient from observations of the diffused
state. The forward model is any ``build_backend(..., differentiable=True)``
lowering — reference for CI, Pallas or the sharded mesh for scale — so the
fit exercises exactly the gradient path the conformance matrix certifies,
and the optimizer is the shipped :mod:`repro.optim` stack (no separate
"training" codepath: the same AdamW/Adafactor, global-norm clip and
:class:`~repro.train.loop.SpikeDetector` the LLM loop uses).

The 3D-Var-style setup: observations ``y = M(u0, c*)`` of a known prior
state ``u0`` under the true coefficients ``c*``, minimise ``J(c) = mean((M(
u0, c) - y)^2)`` from a flat first guess. The coefficient only enters at
interior points (the boundary ring passes through), so ring gradients are
exactly zero and the ring keeps its first-guess values — the interior
converges, which is what the >=10x loss-drop acceptance asserts.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.ir.graph import repeat
from repro.ir.lower_batched import build_backend
from repro.ir.programs import hdiff_coupled_program, smagorinsky_coeff
from repro.optim import OptimizerConfig, clip_by_global_norm, make_optimizer
from repro.train.loop import SpikeDetector

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AssimilationConfig:
    """One coefficient-field fit.

    ``backend`` / ``mesh_shape`` / ``interpret`` choose the differentiable
    lowering of the forward model (any conformance backend name);
    ``k`` temporally blocks it (``repeat(p, k)`` — the observation operator
    then spans k sweeps and the adjoint reverses all of them)."""

    steps: int = 80
    learning_rate: float = 3e-3
    optimizer: str = "adamw"
    grad_clip: float = 1.0
    backend: str = "reference"
    mesh_shape: tuple[int, int] | None = None
    interpret: bool | None = None
    k: int = 1
    limit: bool = True


@dataclasses.dataclass
class FitResult:
    coeff: Array
    losses: list[float]
    spikes: list[tuple[int, float]]

    @property
    def loss_ratio(self) -> float:
        """First-to-best loss improvement factor (the acceptance metric)."""
        return self.losses[0] / min(self.losses)


def forward_model(cfg: AssimilationConfig) -> Callable:
    """The differentiable observation operator ``{u, coeff} -> u_k``."""
    p = hdiff_coupled_program(limit=cfg.limit)
    if cfg.k > 1:
        p = repeat(p, cfg.k)
    return build_backend(
        p,
        cfg.backend,
        mesh_shape=cfg.mesh_shape,
        interpret=cfg.interpret,
        differentiable=True,
    )


def synthetic_observations(
    u0: Array, coeff_true: Array, cfg: AssimilationConfig
) -> Array:
    """Noise-free observations of the true-coefficient forward model."""
    return forward_model(cfg)({"u": u0, "coeff": coeff_true})


def true_coefficients(shape: Sequence[int], seed: int = 0) -> Array:
    """The Smagorinsky-style target field every test/benchmark fits
    (:func:`repro.ir.programs.smagorinsky_coeff` over unit noise)."""
    noise = jax.random.normal(jax.random.PRNGKey(seed), tuple(shape))
    return jnp.asarray(smagorinsky_coeff(noise))


def fit_coefficient_field(
    u0: Array,
    observations: Array,
    cfg: AssimilationConfig = AssimilationConfig(),
    coeff_init: Array | None = None,
) -> FitResult:
    """Minimise the observation misfit over the coefficient field.

    Plain full-batch gradient descent with the shipped optimizer stack:
    ``jax.value_and_grad`` through the differentiable lowering (the derived
    adjoint sweeps), global-norm clip, AdamW/Adafactor update, every loss
    through a :class:`~repro.train.loop.SpikeDetector` so a diverging fit
    lands in the flight recorder like any training run."""
    fwd = forward_model(cfg)
    if coeff_init is None:
        coeff_init = jnp.full(u0.shape, 0.025, u0.dtype)

    def loss_fn(coeff):
        out = fwd({"u": u0, "coeff": coeff})
        return jnp.mean(jnp.square(out - observations))

    loss_and_grad = jax.jit(jax.value_and_grad(loss_fn))
    opt_cfg = OptimizerConfig(
        name=cfg.optimizer,
        learning_rate=cfg.learning_rate,
        weight_decay=0.0,  # shrinking coefficients toward 0 is not a prior
        grad_clip=cfg.grad_clip,
        warmup_steps=0,
        total_steps=cfg.steps,
    )
    init_fn, update_fn = make_optimizer(opt_cfg)
    coeff = coeff_init
    state = init_fn(coeff)
    detector = SpikeDetector()
    losses: list[float] = []
    for step in range(cfg.steps):
        loss, grad = loss_and_grad(coeff)
        losses.append(float(loss))
        detector.record(step, float(loss))
        grad, _gnorm = clip_by_global_norm(grad, cfg.grad_clip)
        coeff, state = update_fn(grad, state, coeff)
    return FitResult(coeff=coeff, losses=losses, spikes=detector.spikes)
