"""Distributed training loop: pjit train_step, microbatch gradient
accumulation, preemption-safe checkpointing, step-time watchdog.

Fault-tolerance posture (1000+-node):
  * Checkpoint every ``ckpt_every`` steps (async) + a final sync save; a
    SIGTERM (TPU preemption notice) triggers an immediate synchronous save
    before exit. Restart resumes from the latest COMMITted step, and the
    data pipeline replays deterministically from that step (see
    repro.data.pipeline).
  * Elastic: restore re-shards onto whatever mesh the relaunch built
    (checkpoints are mesh-agnostic; see repro.checkpoint.store).
  * Straggler stance: TPU SPMD steps are globally synchronous, so per-step
    straggler dodging (the GPU-world trick) does not apply; what remains is
    (a) host input stalls — hidden by the Prefetcher, (b) a persistently
    slow/failed host — detected by the step-time watchdog here and resolved
    by checkpoint-restart ejection at the cluster layer.
  * Collective/compute overlap: gradient accumulation psums ONCE per step
    (not per microbatch) and XLA's latency-hiding scheduler overlaps the
    FSDP all-gathers with layer compute under scan (flags in
    launch/train.py).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.dist.sharding import sharding_for, tree_shardings
from repro.models import build_lm, lm_loss
from repro.obs import events, metrics
from repro.obs.health import HealthMonitor
from repro.optim.optimizers import (
    OptimizerConfig,
    clip_by_global_norm,
    make_optimizer,
    opt_state_axes,
    optimizer_config_from_model,
)

Array = jax.Array
_IS_AX = lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)  # noqa: E731


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1            # gradient accumulation
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = ""
    keep_last: int = 3
    watchdog_factor: float = 3.0     # flag steps slower than factor * median
    grad_compression: str = "none"   # none | bf16 (cross-pod reduce)
    spike_factor: float = 5.0        # flag losses above factor * running median
    health_every: int = 0            # probe loss health every N steps (0 = off)
    health_policy: str = "abort"     # warn | abort | checkpoint-then-abort


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    *,
    microbatches: int = 1,
) -> Callable:
    """Builds train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Pure; jit/pjit-able. Batch: {"tokens": (B, S), "labels": ...}."""
    _, update = make_optimizer(opt_cfg)
    cdt = jnp.dtype(cfg.compute_dtype)

    def loss_fn(params, mb):
        # Master-weight cast: params are cast to the compute dtype HERE,
        # while still sharded, so FSDP all-gathers (and the matching
        # gradient reduce-scatters) move bf16 on the wire — the f32 masters
        # never leave their home chip. The optimizer below updates the f32
        # masters with the (locally re-cast) f32 grads.
        params_c = jax.tree.map(
            lambda p: p.astype(cdt) if jnp.issubdtype(p.dtype, jnp.floating) else p,
            params,
        )
        return lm_loss(cfg, params_c, mb)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            # Batch arrives PRE-SHAPED (mb, B/mb, ...) with dim 1 sharded
            # over the data axes (see shape_for_microbatches) so microbatch
            # indexing never slices across shards. Grads psum once per STEP,
            # not per microbatch (collective/compute overlap posture).
            def acc_body(i, carry):
                gacc, lacc = carry
                mb = jax.tree.map(lambda t: t[i], batch)
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (
                    jax.tree.map(lambda a, b: a + b, gacc, g),
                    lacc + l,
                )

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, loss = jax.lax.fori_loop(
                0, microbatches, acc_body, (zeros, jnp.zeros((), jnp.float32))
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics_aux = {}
        else:
            (loss, metrics_aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )

        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state = update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm}
        if isinstance(metrics_aux, dict):
            metrics.update({k: v for k, v in metrics_aux.items() if k != "loss"})
        return params, opt_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, mesh: Mesh, seed: int = 0):
    """Initialises (params, opt_state) SHARDED on the mesh, plus shardings.

    Init happens under jit with out_shardings so no host ever materialises
    the full parameter set (required for the 100B+ configs)."""
    opt_cfg = optimizer_config_from_model(cfg)
    params_abs, axes = build_lm(cfg, key=None)
    p_sh = tree_shardings(axes, mesh, jax.tree.map(lambda s: s.shape, params_abs))
    opt_init, _ = make_optimizer(opt_cfg)
    opt_abs = jax.eval_shape(opt_init, params_abs)
    opt_axes = opt_state_axes(opt_cfg, axes, params_abs)
    o_sh = tree_shardings(opt_axes, mesh, jax.tree.map(lambda s: s.shape, opt_abs))

    with jax.set_mesh(mesh):
        params = jax.jit(
            lambda k: build_lm(cfg, k)[0], out_shardings=p_sh
        )(jax.random.PRNGKey(seed))
        opt_state = jax.jit(opt_init, out_shardings=o_sh)(params)
    return params, opt_state, p_sh, o_sh, axes


def batch_sharding(mesh: Mesh, batch_abs: Any, *, microbatches: int = 1):
    def spec(s):
        if microbatches > 1:
            ax = (None, "batch") + (None,) * (len(s.shape) - 2)
        else:
            ax = ("batch",) + (None,) * (len(s.shape) - 1)
        return sharding_for(ax, mesh, s.shape)

    return jax.tree.map(lambda s: spec(s), batch_abs)


def shape_for_microbatches(batch: Any, microbatches: int) -> Any:
    """Host-side reshape (B, ...) -> (mb, B/mb, ...)."""
    if microbatches <= 1:
        return batch
    return jax.tree.map(
        lambda t: t.reshape((microbatches, t.shape[0] // microbatches) + t.shape[1:]),
        batch,
    )


class SpikeDetector:
    """Flags loss spikes through ``repro.obs``: a loss is a spike when it
    is non-finite, or exceeds ``factor`` x the running median of the last
    ``window`` recorded losses (after ``warmup`` steps — the first losses
    of a fresh run legitimately swing). Every spike bumps the
    ``train.loss_spikes`` counter and records a structured
    ``train.loss_spike`` event carrying step/loss/threshold, so a loss
    excursion at step 40k is in the flight recorder with its context, not
    just a line lost in stdout. Finite spikes still enter the history, so
    a genuine regime change re-centres the median instead of flagging
    forever."""

    def __init__(self, factor: float = 5.0, warmup: int = 5, window: int = 50):
        self.factor = factor
        self.warmup = warmup
        self.window = window
        self.losses: list[float] = []
        self.spikes: list[tuple[int, float]] = []

    def record(self, step: int, loss: float) -> bool:
        loss = float(loss)
        finite = np.isfinite(loss)
        threshold = None
        spike = not finite
        if finite and len(self.losses) > self.warmup:
            med = float(np.median(self.losses[-self.window:]))
            if med > 0:
                threshold = self.factor * med
                spike = loss > threshold
        if finite:
            self.losses.append(loss)
        if spike:
            self.spikes.append((step, loss))
            metrics.inc("train.loss_spikes")
            events.record("train.loss_spike", step=step, loss=loss,
                          threshold=threshold, factor=self.factor)
        return spike


class StepWatchdog:
    """Flags steps slower than ``factor`` x running median (straggler/
    interference detection signal for the cluster layer)."""

    def __init__(self, factor: float = 3.0, warmup: int = 5):
        self.factor = factor
        self.warmup = warmup
        self.times: list[float] = []
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) <= self.warmup:
            return False
        med = float(np.median(self.times[-50:]))
        if dt > self.factor * med:
            self.flagged.append((step, dt))
            return True
        return False


def train(
    cfg: ModelConfig,
    train_cfg: TrainConfig,
    mesh: Mesh,
    dataset,
    *,
    seed: int = 0,
    log_fn=print,
):
    """End-to-end training driver (used by examples/train_lm.py)."""
    from repro.checkpoint.store import (
        CheckpointManager,
        latest_step,
        restore_checkpoint,
        save_checkpoint,
    )

    opt_cfg = optimizer_config_from_model(cfg)
    params, opt_state, p_sh, o_sh, _ = init_train_state(cfg, mesh, seed)

    start_step = 0
    manager = None
    if train_cfg.ckpt_dir:
        manager = CheckpointManager(train_cfg.ckpt_dir, keep_last=train_cfg.keep_last)
        last = latest_step(train_cfg.ckpt_dir)
        if last is not None:
            (params, opt_state), extra = restore_checkpoint(
                train_cfg.ckpt_dir, last, (params, opt_state), (p_sh, o_sh)
            )
            start_step = int(extra.get("step", last)) + 1
            log_fn(f"[train] restored step {last}, resuming at {start_step}")

    mb = train_cfg.microbatches
    step_fn = make_train_step(cfg, opt_cfg, microbatches=mb)
    batch0 = shape_for_microbatches(dataset.batch_at(start_step), mb)
    b_sh = batch_sharding(
        mesh,
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch0),
        microbatches=mb,
    )
    jit_step = jax.jit(
        step_fn,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )

    # Preemption handling: SIGTERM -> synchronous save + exit.
    preempted = {"flag": False}

    def _on_sigterm(signum, frame):
        preempted["flag"] = True

    old_handler = signal.signal(signal.SIGTERM, _on_sigterm)

    watchdog = StepWatchdog(train_cfg.watchdog_factor)
    spike_det = SpikeDetector(train_cfg.spike_factor)
    monitor = None
    if train_cfg.health_every > 0:
        # Loss-health probe on cadence: a NaN/Inf loss halts the run within
        # health_every steps instead of burning the rest of it. Under
        # checkpoint-then-abort the LAST HEALTHY (params, opt_state) is
        # committed before the raise (requires ckpt_dir).
        ckpt_fn = None
        if train_cfg.health_policy == "checkpoint-then-abort":
            if not train_cfg.ckpt_dir:
                raise ValueError(
                    "health_policy='checkpoint-then-abort' needs ckpt_dir"
                )
            ckpt_fn = lambda s, state: save_checkpoint(  # noqa: E731
                train_cfg.ckpt_dir, s, state, {"step": s, "reason": "health-abort"}
            )
        monitor = HealthMonitor(
            cadence=train_cfg.health_every,
            policy=train_cfg.health_policy,
            name="train.loss",
            checkpoint_fn=ckpt_fn,
            # jit_step donates (params, opt_state): the buffers a probe
            # retains are deleted by the NEXT step, so last_healthy must be
            # a host snapshot or checkpoint_fn would read dead arrays.
            snapshot_state=True,
            log_fn=log_fn,
        )
    history = []
    try:
        with jax.set_mesh(mesh):
            for step in range(start_step, train_cfg.steps):
                t0 = time.perf_counter()
                batch = jax.tree.map(
                    jnp.asarray, shape_for_microbatches(dataset.batch_at(step), mb)
                )
                params, opt_state, step_metrics = jit_step(params, opt_state, batch)
                loss = float(step_metrics["loss"])
                dt = time.perf_counter() - t0
                slow = watchdog.record(step, dt)
                spiked = spike_det.record(step, loss)
                if monitor is not None:
                    monitor.check(step, loss, state=(params, opt_state))
                history.append({"step": step, "loss": loss, "dt": dt})
                if step % train_cfg.log_every == 0 or slow or spiked:
                    flag = (" [SLOW-STEP]" if slow else "") + (
                        " [LOSS-SPIKE]" if spiked else ""
                    )
                    log_fn(
                        f"[train] step {step} loss {loss:.4f} "
                        f"gnorm {float(step_metrics['grad_norm']):.3f} "
                        f"{dt*1e3:.0f}ms{flag}"
                    )
                if manager and step and step % train_cfg.ckpt_every == 0:
                    manager.save_async(step, (params, opt_state), {"step": step})
                if preempted["flag"]:
                    log_fn(f"[train] SIGTERM at step {step}: sync checkpoint + exit")
                    if manager:
                        manager.wait()
                        manager.save_async(step, (params, opt_state), {"step": step})
                        manager.wait()
                    break
            else:
                if manager:
                    manager.wait()
                    manager.save_async(train_cfg.steps - 1, (params, opt_state),
                                       {"step": train_cfg.steps - 1})
                    manager.wait()
    finally:
        signal.signal(signal.SIGTERM, old_handler)
    return params, opt_state, history
