"""Sharded checkpointing with atomic commits, async save, and elastic
re-mesh restore.

Format: one directory per step:

    <root>/step_000123/
        meta.json            -- step, pytree structure, shapes/dtypes, mesh
        arrays.npz           -- flat {index -> np.ndarray} (host-gathered)
        COMMIT               -- written LAST; absence = incomplete/corrupt

Design points for the 1000-node posture:
  * Atomic: save writes to ``step_X.tmp`` then renames; readers only trust
    directories containing COMMIT. A preemption mid-save can never corrupt
    the latest good checkpoint.
  * Async: ``CheckpointManager.save_async`` snapshots to host memory
    synchronously (cheap) and writes to disk on a background thread, so the
    train loop blocks only for the device->host copy.
  * ELASTIC: arrays are saved UNSHARDED (host-gathered); restore takes any
    mesh and re-shards with the current sharding rules — a 512-chip
    checkpoint restores onto 256 chips (or 8 CPU devices) unchanged. At real
    scale this becomes per-shard tensorstore writes; the commit/manifest
    protocol is the part that carries over.
  * Retention: keep_last N, never deleting the newest COMMITted step.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import numpy as np

import jax


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def tree_health(host_leaves: list[np.ndarray]) -> dict:
    """Aggregate numerics-health snapshot of a checkpoint's host leaves:
    NaN/Inf counts and the global L2 norm (float64 accumulation, so the
    save-time and restore-time computations agree bit-for-bit on identical
    bytes). Embedded in ``meta.json`` at save and recomputed at restore —
    a bit-rotted ``arrays.npz`` whose shapes still line up fails HERE, not
    three layers later as a mysteriously diverging forecast."""
    nan = inf = n = 0
    sumsq = 0.0
    for a in host_leaves:
        n += a.size
        if np.issubdtype(a.dtype, np.floating) or np.issubdtype(a.dtype, np.complexfloating):
            nan += int(np.isnan(a).sum())
            inf += int(np.isinf(a).sum())
            finite = np.asarray(a)[np.isfinite(a)]
            # |z|^2 — np.abs is exact for real floats (sign-bit clear, so
            # the square is bit-identical) and makes complex leaves work:
            # np.square(complex, dtype=f64) raises UFuncTypeError.
            sumsq += float(np.sum(np.square(np.abs(finite), dtype=np.float64)))
        else:
            sumsq += float(np.sum(np.square(a.astype(np.float64))))
    return {
        "n_elements": int(n),
        "nan_count": int(nan),
        "inf_count": int(inf),
        "l2": float(np.sqrt(sumsq)),
    }


def save_checkpoint(root: str | Path, step: int, tree: Any, extra: dict | None = None) -> Path:
    """Synchronous atomic save. ``tree``: pytree of arrays."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(l) for l in leaves]
    np.savez(tmp / "arrays.npz", **{f"a{i}": a for i, a in enumerate(host_leaves)})
    meta = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(host_leaves),
        "shapes": [list(a.shape) for a in host_leaves],
        "dtypes": [str(a.dtype) for a in host_leaves],
        "health": tree_health(host_leaves),
        "extra": extra or {},
        "time": time.time(),
    }
    (tmp / "meta.json").write_text(json.dumps(meta))
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(root: str | Path) -> int | None:
    root = Path(root)
    if not root.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in root.iterdir()
        if p.is_dir() and p.name.startswith("step_") and (p / "COMMIT").exists()
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    root: str | Path,
    step: int | None,
    tree_like: Any,
    shardings: Any | None = None,
    *,
    verify_health: bool = True,
) -> tuple[Any, dict]:
    """Restores into the structure of ``tree_like``. With ``shardings`` (a
    matching pytree of NamedSharding), arrays are placed sharded on the
    CURRENT mesh — this is the elastic re-mesh path.

    ``arrays.npz`` is never trusted blindly: every leaf's shape/dtype is
    validated against what ``meta.json`` recorded at save time (a clear
    ``ValueError`` naming the mismatching leaf, instead of a failure deep
    in re-sharding), and with ``verify_health`` the meta's numerics-health
    snapshot (NaN/Inf counts, global L2) is recomputed and compared — a
    corrupted payload fails at load."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {root}")
    d = root / f"step_{step:08d}"
    if not (d / "COMMIT").exists():
        raise FileNotFoundError(f"checkpoint {d} has no COMMIT marker")
    meta = json.loads((d / "meta.json").read_text())
    with np.load(d / "arrays.npz") as z:
        missing = [f"a{i}" for i in range(meta["n_leaves"]) if f"a{i}" not in z]
        if missing:
            raise ValueError(
                f"checkpoint {d}: arrays.npz is missing leaves {missing} "
                f"recorded in meta.json — the payload is corrupt or truncated"
            )
        host_leaves = [z[f"a{i}"] for i in range(meta["n_leaves"])]

    # arrays.npz vs meta.json: the payload must match what save recorded.
    for i, a in enumerate(host_leaves):
        want_shape = tuple(meta["shapes"][i])
        want_dtype = meta["dtypes"][i]
        if tuple(a.shape) != want_shape or str(a.dtype) != want_dtype:
            raise ValueError(
                f"checkpoint {d} leaf a{i}: arrays.npz has shape "
                f"{tuple(a.shape)} dtype {a.dtype} but meta.json recorded "
                f"shape {want_shape} dtype {want_dtype} — the checkpoint "
                f"payload is corrupt (or meta.json was tampered with)"
            )
    if verify_health and "health" in meta:
        want, got = meta["health"], tree_health(host_leaves)
        counts_ok = all(got[k] == want[k]
                        for k in ("n_elements", "nan_count", "inf_count"))
        l2_ok = np.isclose(got["l2"], want["l2"], rtol=1e-9, atol=0.0)
        if not (counts_ok and l2_ok):
            raise ValueError(
                f"checkpoint {d}: health snapshot mismatch — meta.json "
                f"recorded {want} but arrays.npz recomputes to {got}; the "
                f"payload bytes changed since save (bit rot / partial write)"
            )

    ref_leaves, treedef = _flatten(tree_like)
    if len(ref_leaves) != len(host_leaves):
        raise ValueError(
            f"checkpoint has {len(host_leaves)} leaves, target structure has {len(ref_leaves)}"
        )
    for i, (h, r) in enumerate(zip(host_leaves, ref_leaves)):
        if tuple(h.shape) != tuple(np.shape(r)):
            raise ValueError(f"leaf {i}: checkpoint shape {h.shape} != target {np.shape(r)}")

    if shardings is not None:
        shard_leaves, _ = _flatten(shardings)
        dev_leaves = [
            jax.device_put(h.astype(r.dtype), s)
            for h, r, s in zip(host_leaves, ref_leaves, shard_leaves)
        ]
    else:
        dev_leaves = [jax.device_put(h.astype(np.dtype(r.dtype))) for h, r in zip(host_leaves, ref_leaves)]
    return jax.tree.unflatten(treedef, dev_leaves), meta["extra"]


class CheckpointManager:
    """Async, retention-managed checkpointing for the train loop."""

    def __init__(self, root: str | Path, keep_last: int = 3):
        self.root = Path(root)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save_async(self, step: int, tree: Any, extra: dict | None = None):
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # sync snapshot

        def _write():
            try:
                save_checkpoint(self.root, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            p for p in self.root.iterdir()
            if p.is_dir() and p.name.startswith("step_") and (p / "COMMIT").exists()
        )
        for p in steps[: -self.keep_last]:
            shutil.rmtree(p, ignore_errors=True)
