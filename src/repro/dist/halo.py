"""Domain-decomposed hdiff: depth-parallel planes + row halo exchange.

The paper's B-block scale-out (§3.4, Fig. 10) maps each depth plane to its
own compute resource (embarrassingly parallel — depth never enters the
stencil) and, past 64 shards, decomposes rows with a radius-2 halo. The
TPU analogue here is a ``shard_map`` over the device mesh:

  * ``depth_axis``: the (D, R, C) grid's depth dim is split over a mesh
    axis with ZERO collectives per step.
  * ``row_axis``: rows are split; each step every shard pushes its edge
    rows (HALO=2 of them — flux-of-Laplacian radius) to both neighbours
    with ``ppermute``, computes the stencil on the padded block, and keeps
    the rows it owns.

Global-boundary correctness uses ABSOLUTE row indexing: a shard knows its
row offset from ``axis_index``, so the radius-2 passthrough ring of the
global grid is preserved exactly, even when it falls entirely inside the
first/last shard — the zero halos ppermute delivers at the grid edges are
never read into an owned output row. Columns are not decomposed (they are
the contiguous/vectorised dim), so the column ring is handled locally.

Per-step wire traffic matches :func:`halo_exchange_bytes`, the analytical
model benchmarked by ``benchmarks/fig10_scaling.py``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.hdiff import HALO, _hdiff_interior, hdiff, hdiff_simple
from repro.dist.sharding import _mesh_sizes


def exchange_row_halos(block: jax.Array, row_axis: str, n_shards: int, halo: int = HALO):
    """Pads ``block`` (..., R_loc, C) with ``halo`` rows from each row
    neighbour via two ``ppermute`` pushes. Edge shards receive zeros on
    their outward side (ppermute's fill for uncovered targets); callers
    must not emit output rows computed from them (see absolute-row mask).
    Returns (..., R_loc + 2*halo, C).

    Requires ``R_loc >= halo``: each push sources from the IMMEDIATE row
    neighbour only, so a shard owning fewer than ``halo`` rows cannot
    provide a full halo band — on such a fine mesh the slices silently
    shorten and interiors compute from the wrong rows, so this raises
    instead (regression-tested in tests/multidev/_ir_check.py)."""
    r_loc = block.shape[-2]
    if r_loc < halo:
        raise ValueError(
            f"rows/shard {r_loc} < halo {halo}: the single-neighbour "
            f"ppermute exchange cannot deliver a depth-{halo} halo band; "
            f"use fewer row shards (or a smaller halo / fewer fused steps)"
        )
    down = [(j, j + 1) for j in range(n_shards - 1)]   # my bottom rows -> next shard's top halo
    up = [(j + 1, j) for j in range(n_shards - 1)]     # my top rows -> prev shard's bottom halo
    top_halo = jax.lax.ppermute(block[..., -halo:, :], row_axis, down)
    bot_halo = jax.lax.ppermute(block[..., :halo, :], row_axis, up)
    return jnp.concatenate([top_halo, block, bot_halo], axis=-2)


def owned_rows_mask(shard_index, rows_local: int, rows_global: int, halo: int = HALO):
    """Boolean (rows_local,): which of my rows are GLOBAL interior rows
    (the radius-``halo`` global boundary ring passes through)."""
    g = shard_index * rows_local + jnp.arange(rows_local)
    return (g >= halo) & (g < rows_global - halo)


def halo_exchange_bytes(
    depth: int,
    rows: int,
    cols: int,
    row_shards: int,
    itemsize: int = 4,
    halo: int = HALO,
    steps: int = 1,
) -> int:
    """Total bytes on the wire for ONE halo-exchange round, summed over the
    whole mesh: every internal shard boundary moves ``halo * steps`` rows
    in each direction. Independent of depth sharding (depth planes are
    disjoint; the per-device blocks are smaller but more numerous).

    ``steps`` models temporal blocking (``repeat(p, steps)`` lowered via
    ``lower_sharded``): the exchanged band deepens to ``steps * halo`` rows
    but one round serves ``steps`` fused sweeps, so exchange ROUNDS — the
    latency term — per simulated step drop ``steps``-fold while bytes per
    simulated step stay constant. Divide by ``steps`` for per-step bytes."""
    if row_shards <= 1:
        return 0
    return 2 * (row_shards - 1) * depth * halo * steps * cols * itemsize


def make_sharded_hdiff(
    mesh,
    *,
    depth_axis: str | None = "data",
    row_axis: str | None = None,
    limit: bool = True,
    coeff: float = 0.025,
) -> Callable[[jax.Array], jax.Array]:
    """Builds a jitted ``psi (D, R, C) -> psi'`` matching single-device
    :func:`repro.core.hdiff` (or ``hdiff_simple`` with ``limit=False``)
    while domain-decomposed over ``mesh``.

    Args:
      mesh: the device mesh; axes named by ``depth_axis`` / ``row_axis``.
      depth_axis: mesh axis sharding dim 0 (planes), or None.
      row_axis: mesh axis sharding dim 1 (rows, with halo exchange), or
        None for pure depth parallelism.
      limit: apply the COSMO flux limiter (Eq. 2-3).
      coeff: scalar diffusion coefficient.
    """
    sizes = _mesh_sizes(mesh)
    for ax in (depth_axis, row_axis):
        if ax is not None and ax not in sizes:
            raise ValueError(f"mesh {tuple(sizes)} has no axis {ax!r}")
    if depth_axis is not None and depth_axis == row_axis:
        raise ValueError("depth_axis and row_axis must be distinct mesh axes")
    n_row = sizes[row_axis] if row_axis is not None else 1
    n_depth = sizes[depth_axis] if depth_axis is not None else 1

    spec = P(depth_axis, row_axis if n_row > 1 else None, None)
    single = hdiff if limit else hdiff_simple

    def local_step(block: jax.Array) -> jax.Array:
        if row_axis is None or n_row == 1:
            # Full rows present locally: the single-device kernel's own
            # boundary handling is already correct.
            return single(block, coeff)
        padded = exchange_row_halos(block, row_axis, n_row)
        interior = _hdiff_interior(padded, coeff, limit=limit)  # rows: R_loc, cols: C-2H
        r_loc = block.shape[-2]
        mask = owned_rows_mask(jax.lax.axis_index(row_axis), r_loc, r_loc * n_row)
        cur = block[..., :, HALO:-HALO]
        out = jnp.where(mask[:, None], interior.astype(block.dtype), cur)
        return block.at[..., :, HALO:-HALO].set(out)

    mapped = jax.shard_map(
        local_step, mesh=mesh, in_specs=(spec,), out_specs=spec, check_vma=False
    )

    @jax.jit
    def step(psi: jax.Array) -> jax.Array:
        if psi.ndim != 3:
            raise ValueError(f"expected (depth, rows, cols), got shape {psi.shape}")
        d, r, _ = psi.shape
        if n_depth > 1 and d % n_depth:
            raise ValueError(f"depth {d} not divisible by {n_depth} {depth_axis!r} shards")
        if n_row > 1:
            if r % n_row:
                raise ValueError(f"rows {r} not divisible by {n_row} {row_axis!r} shards")
            if r // n_row < HALO:
                raise ValueError(
                    f"rows/shard {r // n_row} < halo {HALO}: too many row shards"
                )
        return mapped(psi)

    return step
