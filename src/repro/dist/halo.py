"""Domain-decomposed hdiff: depth-parallel planes + row halo exchange.

The paper's B-block scale-out (§3.4, Fig. 10) maps each depth plane to its
own compute resource (embarrassingly parallel — depth never enters the
stencil) and, past 64 shards, decomposes rows with a radius-2 halo. The
TPU analogue here is a ``shard_map`` over the device mesh:

  * ``depth_axis``: the (D, R, C) grid's depth dim is split over a mesh
    axis with ZERO collectives per step.
  * ``row_axis``: rows are split; each step every shard pushes its edge
    rows (HALO=2 of them — flux-of-Laplacian radius) to both neighbours
    with ``ppermute``, computes the stencil on the padded block, and keeps
    the rows it owns.

Global-boundary correctness uses ABSOLUTE row indexing: a shard knows its
row offset from ``axis_index``, so the radius-2 passthrough ring of the
global grid is preserved exactly, even when it falls entirely inside the
first/last shard — the zero halos ppermute delivers at the grid edges are
never read into an owned output row.

Columns decompose too (:func:`exchange_halos_2d`): the 2-D rows x cols
exchange adds a column band ppermute pair and four single-hop *diagonal*
corner ppermutes over the flattened ``(row_axis, col_axis)`` mesh pair —
the paper's 2-D AIE-array neighbour pattern. An axis with a single shard
skips its permutes entirely (zero pad, zero wire bytes).

Per-step wire traffic matches :func:`halo_exchange_bytes`, the analytical
model benchmarked by ``benchmarks/fig10_scaling.py``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.hdiff import HALO, _hdiff_interior, hdiff, hdiff_simple
from repro.dist.sharding import _mesh_sizes


def _check_band(extent: int, halo: int, what: str) -> None:
    """The single-neighbour ppermute sources each halo band from the
    IMMEDIATE neighbour only: a shard owning fewer than ``halo`` rows/cols
    cannot provide a full band — the slices would silently shorten and
    interiors would compute from the wrong data, so raise instead."""
    if extent < halo:
        raise ValueError(
            f"{what}/shard {extent} < halo {halo}: the single-neighbour "
            f"ppermute exchange cannot deliver a depth-{halo} halo band; "
            f"use fewer {what} shards, shard the other grid axis instead, "
            f"or use a smaller halo / fewer fused steps"
        )


def _band_halos(block: jax.Array, axis_name, n_shards: int, halo: int, dim: int):
    """(lo_halo, hi_halo) bands of width ``halo`` along ``dim`` (-2 rows or
    -1 cols), fetched from the two axis neighbours. With a single shard the
    permutes are SKIPPED entirely — both halos are explicit ``halo``-wide
    zero pads (the axis has no neighbours to source from, so even an extent
    thinner than ``halo`` is fine), matching the zeros ``ppermute`` delivers
    at uncovered grid edges but costing zero collective bytes
    (regression-tested via ``parse_collective_bytes``)."""
    if n_shards == 1:
        shape = list(block.shape)
        shape[dim] = halo
        z = jnp.zeros(tuple(shape), block.dtype)
        return z, z
    _check_band(block.shape[dim], halo, "rows" if dim == -2 else "cols")
    lo_src = block[..., -halo:, :] if dim == -2 else block[..., :, -halo:]
    hi_src = block[..., :halo, :] if dim == -2 else block[..., :, :halo]
    fwd = [(j, j + 1) for j in range(n_shards - 1)]  # my hi band -> next's lo halo
    bwd = [(j + 1, j) for j in range(n_shards - 1)]  # my lo band -> prev's hi halo
    return (
        jax.lax.ppermute(lo_src, axis_name, fwd),
        jax.lax.ppermute(hi_src, axis_name, bwd),
    )


def exchange_row_halos(block: jax.Array, row_axis: str, n_shards: int, halo: int = HALO):
    """Pads ``block`` (..., R_loc, C) with ``halo`` rows from each row
    neighbour via two ``ppermute`` pushes. Edge shards receive zeros on
    their outward side (ppermute's fill for uncovered targets); callers
    must not emit output rows computed from them (see absolute-row mask).
    With a single row shard the permutes are skipped (pure zero padding,
    zero collective bytes). Returns (..., R_loc + 2*halo, C).

    Sharded axes require ``R_loc >= halo`` (see :func:`_check_band`;
    regression-tested in tests/multidev/_ir_check.py)."""
    top_halo, bot_halo = _band_halos(block, row_axis, n_shards, halo, dim=-2)
    return jnp.concatenate([top_halo, block, bot_halo], axis=-2)


def exchange_halos_2d(
    block: jax.Array,
    row_axis,
    col_axis,
    n_row: int,
    n_col: int,
    halo: int = HALO,
    *,
    mesh_axis_names=None,
):
    """2-D halo exchange: pads ``block`` (..., R_loc, C_loc) with ``halo``
    rows, cols, AND corners from its 8 mesh neighbours. Returns
    (..., R_loc + 2*halo, C_loc + 2*halo).

    Three permute families, each skipped when its axis has 1 shard (a
    1-shard axis gets explicit zero pads and may even be thinner than the
    halo — only SHARDED axes must satisfy the extent >= halo band-sourcing
    floor, matching ``plan_partition``'s feasibility rule):

      * row bands  — 2 ppermutes along ``row_axis`` (halo x C_loc each);
      * col bands  — 2 ppermutes along ``col_axis`` (R_loc x halo each);
      * corners    — 4 ppermutes of halo x halo patches routed DIAGONALLY in
        one hop over the flattened (row_axis, col_axis) axis pair
        (source/target pairs enumerate internal mesh vertices only), so the
        wire model stays symmetric under (rows, cols) transpose and grid-edge
        shards send nothing. ``jax.lax.ppermute`` numbers the flattened pair
        indices in the MESH's axis declaration order (not the tuple order
        passed), so ``mesh_axis_names`` — the full ordered axis-name tuple of
        the enclosing mesh — is REQUIRED whenever both axes are sharded; a
        wrong assumption here silently corrupts the corner points.

    Edge shards receive zeros on outward sides (ppermute's fill), exactly as
    in the 1-D exchange; the absolute row/col ring passthrough guarantees
    they are never read into an owned output point.
    """
    top, bot = _band_halos(block, row_axis, n_row, halo, dim=-2)
    left, right = _band_halos(block, col_axis, n_col, halo, dim=-1)

    h = halo
    if n_row > 1 and n_col > 1:
        if mesh_axis_names is None:
            raise ValueError(
                "exchange_halos_2d needs mesh_axis_names (the mesh's ordered "
                "axis-name tuple) when both grid axes are sharded: the "
                "diagonal corner ppermute numbers shards in mesh declaration "
                "order, and guessing it wrong corrupts corners silently"
            )
        order = [a for a in mesh_axis_names if a in (row_axis, col_axis)]
        if order != [row_axis, col_axis] and order != [col_axis, row_axis]:
            raise ValueError(
                f"mesh axes {tuple(mesh_axis_names)} do not contain exactly "
                f"{row_axis!r} and {col_axis!r}"
            )
        row_major = order[0] == row_axis
        # Flatten (row i, col j) the way ppermute numbers the axis pair:
        # leading declared axis varies slowest.
        axes = (row_axis, col_axis) if row_major else (col_axis, row_axis)
        if row_major:
            flat = lambda i, j: i * n_col + j  # noqa: E731
        else:
            flat = lambda i, j: j * n_row + i  # noqa: E731

        def corner(src, pairs):
            return jax.lax.ppermute(src, axes, pairs)

        rng_i, rng_j = range(n_row - 1), range(n_col - 1)
        # My top-left halo corner = (i-1, j-1)'s bottom-right block corner, etc.
        tl = corner(block[..., -h:, -h:],
                    [(flat(i, j), flat(i + 1, j + 1)) for i in rng_i for j in rng_j])
        tr = corner(block[..., -h:, :h],
                    [(flat(i, j + 1), flat(i + 1, j)) for i in rng_i for j in rng_j])
        bl = corner(block[..., :h, -h:],
                    [(flat(i + 1, j), flat(i, j + 1)) for i in rng_i for j in rng_j])
        br = corner(block[..., :h, :h],
                    [(flat(i + 1, j + 1), flat(i, j)) for i in rng_i for j in rng_j])
    else:
        # A 1-shard axis has no diagonal neighbours: corners are grid-edge
        # pads on at least one side, i.e. zeros — no wire bytes.
        zc = jnp.zeros(block.shape[:-2] + (h, h), block.dtype)
        tl = tr = bl = br = zc

    left_col = jnp.concatenate([tl, left, bl], axis=-2)
    right_col = jnp.concatenate([tr, right, br], axis=-2)
    mid = jnp.concatenate([top, block, bot], axis=-2)
    return jnp.concatenate([left_col, mid, right_col], axis=-1)


def owned_rows_mask(shard_index, rows_local: int, rows_global: int, halo: int = HALO):
    """Boolean (rows_local,): which of my rows are GLOBAL interior rows
    (the radius-``halo`` global boundary ring passes through)."""
    g = shard_index * rows_local + jnp.arange(rows_local)
    return (g >= halo) & (g < rows_global - halo)


def halo_exchange_bytes(
    depth: int,
    rows: int,
    cols: int,
    row_shards: int,
    itemsize: int = 4,
    halo: int = HALO,
    steps: int = 1,
    col_shards: int = 1,
) -> int:
    """Total bytes on the wire for ONE halo-exchange round, summed over the
    whole mesh. Independent of depth sharding (depth planes are disjoint;
    the per-device blocks are smaller but more numerous).

    2-axis model (matches :func:`exchange_halos_2d` exactly; ``h`` is the
    exchanged band depth ``halo * steps``):

      * row bands:  every internal row boundary moves ``h`` full-width rows
        each direction — ``2 (R-1) * depth * h * cols`` elements (the
        per-strip width is ``cols / C`` but there are ``C`` strips);
      * col bands:  symmetrically ``2 (C-1) * depth * h * rows``;
      * corners:    4 diagonal ``h x h`` patches across each of the
        ``(R-1)(C-1)`` internal mesh vertices — ``4 (R-1)(C-1) * depth *
        h^2``. Quadratic in ``h``: deep temporal-blocked halos pay a
        growing (but tiny) corner tax.

    The model is symmetric under (rows, R) <-> (cols, C) transpose, and
    ``col_shards=1`` reduces exactly to the 1-D row formula.

    ``steps`` models temporal blocking (``repeat(p, steps)`` lowered via
    ``lower_sharded``): the exchanged band deepens to ``steps * halo`` but
    one round serves ``steps`` fused sweeps, so exchange ROUNDS — the
    latency term — per simulated step drop ``steps``-fold. Divide by
    ``steps`` for per-step bytes."""
    h = halo * steps
    total = 0
    if row_shards > 1:
        total += 2 * (row_shards - 1) * depth * h * cols
    if col_shards > 1:
        total += 2 * (col_shards - 1) * depth * h * rows
    if row_shards > 1 and col_shards > 1:
        total += 4 * (row_shards - 1) * (col_shards - 1) * depth * h * h
    return total * itemsize


def halo_exchange_bytes_per_shard(
    local_depth: int,
    local_rows: int,
    local_cols: int,
    itemsize: int = 4,
    halo: int = HALO,
    steps: int = 1,
    row_sharded: bool = True,
    col_sharded: bool = False,
) -> int:
    """Per-chip collective-permute RESULT bytes for one exchange round — what
    ``parse_collective_bytes`` measures on the compiled SPMD program (every
    chip executes the same permutes; an interior chip receives them all).

    Row bands 2 x (D_loc, h, C_loc), col bands 2 x (D_loc, R_loc, h), and
    4 diagonal corners (D_loc, h, h) when both axes are sharded."""
    h = halo * steps
    total = 0
    if row_sharded:
        total += 2 * local_depth * h * local_cols
    if col_sharded:
        total += 2 * local_depth * local_rows * h
    if row_sharded and col_sharded:
        total += 4 * local_depth * h * h
    return total * itemsize


def program_exchange_radii(program) -> dict[str, int]:
    """Per-field EXCHANGED halo depth: delegates to
    :meth:`repro.ir.graph.StencilProgram.exchange_radii`, the one home of
    the rule, so the byte models here, ``lower_sharded``'s exchange and
    ``lower_pallas``'s in-tile halos can never drift apart."""
    return program.exchange_radii()


def program_halo_exchange_bytes(
    program,
    depth: int,
    rows: int,
    cols: int,
    row_shards: int,
    itemsize: int = 4,
    col_shards: int = 1,
) -> int:
    """Whole-mesh wire bytes for ONE exchange round of a (possibly
    multi-field, possibly temporally-composed) IR program: the per-field
    sum of :func:`halo_exchange_bytes`.

    The evolving (:attr:`~repro.ir.graph.StencilProgram.passthrough`) field
    exchanges the program's full chain radius; every other input exchanges
    its own composed access radius (``field_radii``), so a radius-0
    coefficient field contributes ZERO bytes. Temporal blocking is already
    baked into the composed radii (``repeat(p, k)``'s state radius is k*r),
    so no ``steps`` factor appears — one round still serves the whole
    chain. For a single-input program this reduces exactly to
    ``halo_exchange_bytes(..., halo=program.radius)``.

    Matches what ``repro.ir.lower_sharded`` puts on the wire exactly
    (measured per-chip in fig10/fig13 via ``parse_collective_bytes``).
    """
    return sum(
        halo_exchange_bytes(
            depth, rows, cols, row_shards,
            itemsize=itemsize, halo=r, col_shards=col_shards,
        )
        for r in program_exchange_radii(program).values()
    )


def program_halo_exchange_bytes_per_shard(
    program,
    local_depth: int,
    local_rows: int,
    local_cols: int,
    itemsize: int = 4,
    row_sharded: bool = True,
    col_sharded: bool = False,
) -> int:
    """Per-chip collective-permute RESULT bytes for one multi-field exchange
    round — the per-field sum of :func:`halo_exchange_bytes_per_shard`
    (what ``parse_collective_bytes`` measures on the compiled program)."""
    return sum(
        halo_exchange_bytes_per_shard(
            local_depth, local_rows, local_cols,
            itemsize=itemsize, halo=r,
            row_sharded=row_sharded, col_sharded=col_sharded,
        )
        for r in program_exchange_radii(program).values()
    )


def measured_collective_permute_bytes(step_fn, x) -> tuple[float, int]:
    """PER-CHIP collective-permute result bytes of ``step_fn`` compiled on
    input ``x`` — the *measured* side of the wire-model claims, parsed from
    the post-SPMD HLO (``repro.launch.dryrun.parse_collective_bytes``).
    Returns ``(bytes, permute_count)``. Compiles (does not execute) the
    step."""
    import jax

    from repro.launch.dryrun import parse_collective_bytes

    coll = parse_collective_bytes(jax.jit(step_fn).lower(x).compile().as_text())
    return (
        coll["bytes"].get("collective-permute", 0.0),
        int(coll["counts"].get("collective-permute", 0)),
    )


def wire_drift_report(
    program,
    step_fn,
    x,
    *,
    local_depth: int,
    local_rows: int,
    local_cols: int,
    row_sharded: bool = True,
    col_sharded: bool = False,
    tolerance: float | None = None,
    name: str = "halo.wire",
):
    """Measured-vs-model drift check for one sharded lowering: compiles
    ``step_fn`` on ``x``, parses the per-chip collective-permute bytes, and
    compares them against :func:`program_halo_exchange_bytes_per_shard`.

    Records through :func:`repro.obs.drift.check_drift` into the active
    metrics registry (counters ``<name>.measured_bytes`` /
    ``<name>.model_bytes``, gauge ``<name>.ratio``, counter
    ``<name>.drift_flags`` when out of tolerance) and returns the
    :class:`~repro.obs.drift.DriftResult`. This is the standing form of the
    fig10/fig13 "ratio=1.000" lines: any accounting drift between what
    ``lower_sharded`` puts on the wire and what the byte model predicts
    flags immediately, on every instrumented run.
    """
    from repro.obs import events
    from repro.obs.drift import DEFAULT_TOLERANCE, check_drift

    itemsize = next(iter(x.values())).dtype.itemsize if isinstance(x, dict) else x.dtype.itemsize
    measured, _count = measured_collective_permute_bytes(step_fn, x)
    model = program_halo_exchange_bytes_per_shard(
        program, local_depth, local_rows, local_cols,
        itemsize=itemsize, row_sharded=row_sharded, col_sharded=col_sharded,
    )
    tol = DEFAULT_TOLERANCE if tolerance is None else tolerance
    result = check_drift(name, measured, model, tol)
    # The full report (clean or not) goes to the flight recorder: one event
    # per wire measurement, so a long run's event log carries the standing
    # measured==model evidence alongside its health probes.
    events.record("drift.report", name=name, program=program.name,
                  measured=result.measured, model=result.model,
                  ratio=result.ratio, ok=result.ok)
    return result


def gradient_halo_exchange_bytes_per_shard(
    program,
    local_depth: int,
    rows: int,
    cols: int,
    *,
    mesh_shape: tuple[int, int],
    itemsize: int = 4,
) -> int:
    """Per-chip collective-permute bytes of one VALUE-AND-GRAD step of a
    sharded differentiable lowering (``build_backend(..., "sharded-*",
    differentiable=True)``) — the backward-pass extension of
    :func:`program_halo_exchange_bytes_per_shard`.

    ``rows`` / ``cols`` are GLOBAL grid extents; ``local_depth`` is
    per-chip as in the forward model (depth is never padded or exchanged).
    Every backward sweep runs on the UNPADDED shards — the adjoint and
    augmented-forward sweeps lower with ``boundary="zero"``, whose zero
    extension rides the same exchange round (no pad/crop collectives) — so
    the model is a pure sum of per-program exchange rounds at the primal
    shard extents, mirroring ``repro.ir.autodiff.make_vjp`` sweep by sweep
    with the same per-field ``exchange_radii()`` rule the forward model
    uses:

      * the primal forward: one full-chain round;
      * per sweep with caches: one round of the AUGMENTED forward
        (:func:`~repro.ir.autodiff.augmented_forward`) — the plain sweep's
        radii plus one full-radius band per ``c~`` cache slot (cache slots
        are OUTPUTS, and the shared ``exchange_radii()`` rule moves every
        evolving field at the chain radius);
      * per non-final sweep without caches (adjoint reads the primal state
        but nothing cached — product-of-inputs shapes): one plain per-sweep
        round;
      * per sweep: one round of the ADJOINT program — adjoint radii equal
        primal radii, so this mirrors the forward exchange exactly.

    Linear chains skip every state-recompute term (their adjoints never
    read the primal). Measured-vs-model is asserted at ratio 1.000 by
    ``tests/multidev/_grad_check.py`` and ``benchmarks/fig15_gradients.py``.
    """
    from repro.ir.autodiff import adjoint, augmented_forward, cache_fields

    n_row, n_col = int(mesh_shape[0]), int(mesh_shape[1])
    row_sh, col_sh = n_row > 1, n_col > 1
    r_loc, c_loc = rows // n_row, cols // n_col

    def one_round(p):
        return program_halo_exchange_bytes_per_shard(
            p, local_depth, r_loc, c_loc,
            itemsize=itemsize, row_sharded=row_sh, col_sharded=col_sh,
        )

    total = one_round(program)
    chain = program.chain
    needs_state = any(
        cache_fields(q)
        or any(r.field in q.inputs for op in adjoint(q).ops for r in op.reads)
        for q in chain
    )
    for i, q in enumerate(chain):
        if cache_fields(q):
            total += one_round(augmented_forward(q))
        elif needs_state and i < len(chain) - 1:
            total += one_round(q)
        total += one_round(adjoint(q))
    return total


def gradient_wire_drift_report(
    program,
    grad_step_fn,
    x,
    *,
    local_depth: int,
    rows: int,
    cols: int,
    mesh_shape: tuple[int, int],
    tolerance: float | None = None,
    name: str = "halo.grad_wire",
):
    """Measured-vs-model drift check for a sharded BACKWARD pass: compiles
    ``grad_step_fn`` (any pytree-in callable that returns the primal AND
    the cotangents — returning only gradients lets XLA dead-code the
    forward and undercounts) on ``x``, parses the per-chip
    collective-permute bytes, and compares against
    :func:`gradient_halo_exchange_bytes_per_shard`. Records through
    ``repro.obs.drift.check_drift`` exactly like :func:`wire_drift_report`
    (the standing "ratio=1.000" evidence, gradient edition)."""
    from repro.obs import events
    from repro.obs.drift import DEFAULT_TOLERANCE, check_drift

    leaves = jax.tree_util.tree_leaves(x)
    itemsize = leaves[0].dtype.itemsize
    measured, _count = measured_collective_permute_bytes(grad_step_fn, x)
    model = gradient_halo_exchange_bytes_per_shard(
        program, local_depth, rows, cols,
        mesh_shape=mesh_shape, itemsize=itemsize,
    )
    tol = DEFAULT_TOLERANCE if tolerance is None else tolerance
    result = check_drift(name, measured, model, tol)
    events.record("drift.report", name=name, program=program.name,
                  measured=result.measured, model=result.model,
                  ratio=result.ratio, ok=result.ok)
    return result


def make_sharded_hdiff(
    mesh,
    *,
    depth_axis: str | None = "data",
    row_axis: str | None = None,
    limit: bool = True,
    coeff: float = 0.025,
) -> Callable[[jax.Array], jax.Array]:
    """Builds a jitted ``psi (D, R, C) -> psi'`` matching single-device
    :func:`repro.core.hdiff` (or ``hdiff_simple`` with ``limit=False``)
    while domain-decomposed over ``mesh``.

    Args:
      mesh: the device mesh; axes named by ``depth_axis`` / ``row_axis``.
      depth_axis: mesh axis sharding dim 0 (planes), or None.
      row_axis: mesh axis sharding dim 1 (rows, with halo exchange), or
        None for pure depth parallelism.
      limit: apply the COSMO flux limiter (Eq. 2-3).
      coeff: scalar diffusion coefficient.
    """
    sizes = _mesh_sizes(mesh)
    for ax in (depth_axis, row_axis):
        if ax is not None and ax not in sizes:
            raise ValueError(f"mesh {tuple(sizes)} has no axis {ax!r}")
    if depth_axis is not None and depth_axis == row_axis:
        raise ValueError("depth_axis and row_axis must be distinct mesh axes")
    n_row = sizes[row_axis] if row_axis is not None else 1
    n_depth = sizes[depth_axis] if depth_axis is not None else 1

    spec = P(depth_axis, row_axis if n_row > 1 else None, None)
    single = hdiff if limit else hdiff_simple

    def local_step(block: jax.Array) -> jax.Array:
        if row_axis is None or n_row == 1:
            # Full rows present locally: the single-device kernel's own
            # boundary handling is already correct.
            return single(block, coeff)
        padded = exchange_row_halos(block, row_axis, n_row)
        interior = _hdiff_interior(padded, coeff, limit=limit)  # rows: R_loc, cols: C-2H
        r_loc = block.shape[-2]
        mask = owned_rows_mask(jax.lax.axis_index(row_axis), r_loc, r_loc * n_row)
        cur = block[..., :, HALO:-HALO]
        out = jnp.where(mask[:, None], interior.astype(block.dtype), cur)
        return block.at[..., :, HALO:-HALO].set(out)

    mapped = jax.shard_map(
        local_step, mesh=mesh, in_specs=(spec,), out_specs=spec, check_vma=False
    )

    @jax.jit
    def step(psi: jax.Array) -> jax.Array:
        if psi.ndim != 3:
            raise ValueError(f"expected (depth, rows, cols), got shape {psi.shape}")
        d, r, _ = psi.shape
        if n_depth > 1 and d % n_depth:
            raise ValueError(f"depth {d} not divisible by {n_depth} {depth_axis!r} shards")
        if n_row > 1:
            if r % n_row:
                raise ValueError(f"rows {r} not divisible by {n_row} {row_axis!r} shards")
            if r // n_row < HALO:
                raise ValueError(
                    f"rows/shard {r // n_row} < halo {HALO}: too many row shards"
                )
        return mapped(psi)

    return step
