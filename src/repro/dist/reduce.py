"""Cross-shard gradient reduction with optional wire compression.

``reduce_gradients`` is the data-parallel all-reduce used inside
``shard_map``-style per-shard code (train/loop's pjit path lets XLA insert
the psums itself; this is the explicit-collective path for shard_map
regions and for cross-pod reduces where the wire is the bottleneck).

Compression (``method="bf16"``): gradients are cast to bfloat16 BEFORE the
psum so the all-reduce moves half the bytes over the slowest links (DCN /
pod-to-pod), then the mean is finished in the gradient's original dtype.
bf16 keeps f32's exponent range, so there is no overflow cliff — only
~3 relative decimal digits of mantissa, which gradient noise dwarfs (the
tolerance story mirrors the master-weight cast in train/loop.py).
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

_METHODS = ("none", "bf16")


def compress_bf16(x: jax.Array) -> jax.Array:
    """The wire format of the bf16 path (exposed for unit tests)."""
    return x.astype(jnp.bfloat16)


def decompress_bf16(x: jax.Array, dtype) -> jax.Array:
    return x.astype(dtype)


def reduce_gradients(
    grads: Any,
    axes: Sequence[str],
    method: str = "none",
    mean: bool = True,
) -> Any:
    """All-reduces every leaf of ``grads`` over the named mesh ``axes``.

    Must be called inside a ``shard_map`` (or other context where ``axes``
    are bound). Returns the mean by default (sum with ``mean=False``).

    Args:
      grads: pytree of per-shard gradient arrays.
      axes: mesh axis names to reduce over, e.g. ``("data",)`` or
        ``("pod", "data")``.
      method: "none" (full-precision psum) or "bf16" (compressed wire).
    """
    if method not in _METHODS:
        raise ValueError(f"unknown reduction method {method!r}; pick from {_METHODS}")
    axes = tuple(axes)
    if not axes:
        return grads
    # psum of a Python literal folds to the static axis-size product at
    # trace time — no extra collective rides the wire for the count.
    n = jax.lax.psum(1, axes)

    def red(g):
        dtype = g.dtype
        if method == "bf16" and jnp.issubdtype(dtype, jnp.floating):
            total = decompress_bf16(jax.lax.psum(compress_bf16(g), axes), dtype)
        else:
            total = jax.lax.psum(g, axes)
        if not mean:
            return total
        if jnp.issubdtype(dtype, jnp.floating):
            return total / jnp.asarray(n, dtype)
        return total // jnp.asarray(n, total.dtype)

    return jax.tree.map(red, grads)
