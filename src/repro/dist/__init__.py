"""repro.dist: the distribution layer.

Three pieces (see ROADMAP / §3.4 of the paper):

  * :mod:`repro.dist.sharding` — logical-axis -> mesh-axis rule engine
    (``spec_for`` / ``sharding_for`` / ``tree_shardings``), the ambient
    mesh, and in-graph ``constrain`` annotations.
  * :mod:`repro.dist.halo` — ``make_sharded_hdiff``: shard_map domain
    decomposition of the COSMO hdiff (depth-parallel planes + radius-2
    row halo exchange), matching the single-device kernels exactly; plus
    ``exchange_halos_2d``, the rows x cols band + diagonal-corner exchange
    behind ``repro.ir.lower_sharded``'s 2-D decomposition, and the 2-axis
    ``halo_exchange_bytes`` wire model.
  * :mod:`repro.dist.reduce` — ``reduce_gradients``: cross-shard
    all-reduce with a bf16-compressed wire path.
"""

from repro.dist.halo import (
    exchange_halos_2d,
    exchange_row_halos,
    halo_exchange_bytes,
    halo_exchange_bytes_per_shard,
    make_sharded_hdiff,
    measured_collective_permute_bytes,
    owned_rows_mask,
    program_exchange_radii,
    program_halo_exchange_bytes,
    program_halo_exchange_bytes_per_shard,
    wire_drift_report,
)
from repro.dist.reduce import compress_bf16, decompress_bf16, reduce_gradients
from repro.dist.sharding import (
    constrain,
    sharding_for,
    spec_for,
    tree_shardings,
    use_mesh,
)

__all__ = [
    "constrain",
    "compress_bf16",
    "decompress_bf16",
    "exchange_halos_2d",
    "exchange_row_halos",
    "halo_exchange_bytes",
    "halo_exchange_bytes_per_shard",
    "make_sharded_hdiff",
    "measured_collective_permute_bytes",
    "owned_rows_mask",
    "program_exchange_radii",
    "program_halo_exchange_bytes",
    "program_halo_exchange_bytes_per_shard",
    "reduce_gradients",
    "sharding_for",
    "spec_for",
    "tree_shardings",
    "use_mesh",
    "wire_drift_report",
]
