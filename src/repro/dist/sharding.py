"""Logical-axis -> mesh-axis sharding rules.

Everything in models/ and train/ names tensor dimensions with *logical*
axes (``batch``, ``seq``, ``embed``, ``heads``, ``mlp``, ``fsdp``, ...);
this module is the single place where logical names meet a physical mesh
(``("data", "model")`` single-pod, ``("pod", "data", "model")`` multi-pod
— see :mod:`repro.launch.mesh`).

Rules (``mode`` is "train" or "decode"):

  batch     -> the data axes, pod folded in: ``("pod", "data")`` on a
               multi-pod mesh, ``"data"`` on a single-pod one.
  fsdp      -> parameter sharding spanning the data axes. Divisibility is
               checked *partially*: a dim divisible by ``data`` but not by
               ``pod*data`` shards over ``("data",)`` alone.
  heads, kv_heads, mlp, experts, vocab, blocks
            -> ``"model"`` (tensor/expert/sequence parallelism inside a
               pod, where ICI is fastest).
  kv_seq    -> ``"model"`` in decode (the cache, not the heads, is the big
               tensor there); replicated in train.
  depth, rows, cols
            -> the SAME-named mesh axis, when present (the stencil grid
               dims of the 2-D domain decomposition: ``lower_sharded``'s
               ``mesh_shape=(R, C)`` meshes name their axes "rows"/"cols",
               so ``spec_for(("depth", "rows", "cols"), ...)`` shards a
               (D, R, C) field the way the halo exchange expects).
  seq, embed, head_dim, None -> replicated.

Two invariants, enforced uniformly:

  * divisibility-aware fallback: a logical axis whose dim does not divide
    the mesh axis size is REPLICATED, never padded (e.g. 24 heads on a
    16-wide model axis).
  * no double assignment: each mesh axis is consumed at most once per
    spec, first (leftmost) logical axis wins.

The *ambient mesh* (set by ``jax.set_mesh`` / :func:`use_mesh`) lets deep
model code annotate intermediates via :func:`constrain` without threading
a mesh argument through every layer.
"""

from __future__ import annotations

from contextlib import contextmanager
from contextvars import ContextVar
from typing import Any, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Mesh axes that carry batch/data parallelism, outermost first.
_DATA_AXES = ("pod", "data")

# Logical axes that ride the model (tensor-parallel) axis unconditionally.
_MODEL_LOGICAL = ("heads", "kv_heads", "mlp", "experts", "vocab", "blocks")

# Logical axes that are always replicated.
_REPLICATED = ("seq", "embed", "head_dim")

# Stencil-grid logical axes: shard over the mesh axis of the SAME name
# (2-D domain decomposition meshes are built with axes ("rows", "cols"),
# optionally ("depth", ...) for plane parallelism).
_GRID_LOGICAL = ("depth", "rows", "cols")


# --- ambient mesh -------------------------------------------------------------

_AMBIENT: ContextVar[Any] = ContextVar("repro_ambient_mesh", default=None)


def _ambient_mesh():
    """The mesh installed by ``jax.set_mesh`` / :func:`use_mesh`, or None.

    On old JAX the ``jax.set_mesh`` backfill (repro.compat) writes the
    ContextVar directly; on JAX new enough to ship a native ``set_mesh``
    the context lives inside JAX, so fall through to its abstract mesh
    (the compat-installed ``get_abstract_mesh`` is skipped — it reads this
    very function)."""
    mesh = _AMBIENT.get()
    if mesh is not None:
        return mesh
    native = getattr(jax.sharding, "get_abstract_mesh", None)
    if native is None or getattr(native, "_repro_compat", False):
        return None
    mesh = native()
    if mesh is None or getattr(mesh, "empty", False):
        return None
    if not tuple(getattr(mesh, "axis_names", ())):
        return None
    return mesh


def _push_mesh(mesh):
    return _AMBIENT.set(mesh)


def _pop_mesh(token) -> None:
    _AMBIENT.reset(token)


@contextmanager
def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    token = _push_mesh(mesh)
    try:
        yield mesh
    finally:
        _pop_mesh(token)


# --- rule engine --------------------------------------------------------------


def _mesh_sizes(mesh) -> dict[str, int]:
    """Duck-typed: ``axis_names`` + ``devices.shape`` (concrete Mesh or the
    FakeMesh of tests), with an ``axis_sizes`` fallback for AbstractMesh."""
    names = tuple(mesh.axis_names)
    devices = getattr(mesh, "devices", None)
    if devices is not None:
        return dict(zip(names, tuple(devices.shape)))
    return dict(zip(names, tuple(mesh.axis_sizes)))


def _fold_data_axes(dim: int, sizes: dict[str, int], used: set[str]):
    """Longest suffix of ("pod", "data") present+unused whose product
    divides ``dim``; pod is dropped first (partial divisibility)."""
    axes = tuple(a for a in _DATA_AXES if a in sizes and a not in used)
    while axes:
        prod = 1
        for a in axes:
            prod *= sizes[a]
        if prod > 0 and dim % prod == 0:
            return axes
        axes = axes[1:]
    return ()


def _take_model(dim: int, sizes: dict[str, int], used: set[str]):
    if "model" in sizes and "model" not in used and dim % sizes["model"] == 0:
        return "model"
    return None


def _assign(name, dim: int, sizes: dict[str, int], used: set[str], mode: str):
    """One PartitionSpec entry for one (logical axis, dim). Mutates used."""
    if name is None or name in _REPLICATED:
        return None
    if name == "batch":
        axes = _fold_data_axes(dim, sizes, used)
        if not axes:
            return None
        used.update(axes)
        return axes if len(axes) > 1 else axes[0]
    if name == "fsdp":
        # Always a tuple entry: fsdp conceptually SPANS the data axes, and
        # the entry shape must not depend on how many survive divisibility.
        axes = _fold_data_axes(dim, sizes, used)
        if not axes:
            return None
        used.update(axes)
        return axes
    if name == "kv_seq":
        if mode != "decode":
            return None
        ax = _take_model(dim, sizes, used)
        if ax:
            used.add(ax)
        return ax
    if name in _MODEL_LOGICAL:
        ax = _take_model(dim, sizes, used)
        if ax:
            used.add(ax)
        return ax
    if name in _GRID_LOGICAL:
        # Divisibility-aware like every other rule: an indivisible grid dim
        # replicates rather than pads.
        if name in sizes and name not in used and dim % sizes[name] == 0:
            used.add(name)
            return name
        return None
    # Unknown logical name: replicate (permissive — new layers can name
    # axes before rules exist for them).
    return None


def spec_for(
    logical_axes: Sequence[str | None],
    mesh,
    shape: Sequence[int],
    mode: str = "train",
) -> P:
    """PartitionSpec for a tensor with the given logical axes and shape.

    ``mesh`` may be a real ``jax.sharding.Mesh`` or anything exposing
    ``axis_names`` and ``devices.shape``.
    """
    if len(logical_axes) != len(shape):
        raise ValueError(
            f"logical axes {tuple(logical_axes)} do not match shape {tuple(shape)}"
        )
    sizes = _mesh_sizes(mesh)
    used: set[str] = set()
    entries = [
        _assign(name, int(dim), sizes, used, mode)
        for name, dim in zip(logical_axes, shape)
    ]
    # Trailing Nones are semantically redundant but kept: specs must have
    # one entry per dim so tests can compare against explicit P(...) forms.
    return P(*entries)


def sharding_for(
    logical_axes: Sequence[str | None],
    mesh,
    shape: Sequence[int],
    mode: str = "train",
) -> NamedSharding:
    """NamedSharding on ``mesh`` from the logical-axis rules."""
    return NamedSharding(mesh, spec_for(logical_axes, mesh, shape, mode=mode))


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def tree_shardings(axes_tree: Any, mesh, shapes_tree: Any, mode: str = "train") -> Any:
    """Maps a pytree of logical-axis tuples (+ matching shapes) to
    NamedShardings. ``axes_tree`` leaves are tuples of str/None; the shape
    subtree at each leaf position is taken whole (a tuple of ints)."""
    return jax.tree.map(
        lambda ax, shp: sharding_for(ax, mesh, tuple(shp), mode=mode),
        axes_tree,
        shapes_tree,
        is_leaf=_is_axes_leaf,
    )


def constrain(x: jax.Array, logical_axes: Sequence[str | None], mode: str = "train"):
    """In-graph sharding annotation: ``with_sharding_constraint`` against
    the ambient mesh. A no-op when no mesh is ambient (single-device tests,
    plain ``jax.jit`` without ``set_mesh``) so model code can call it
    unconditionally."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    spec = spec_for(logical_axes, mesh, x.shape, mode=mode)
    if getattr(mesh, "devices", None) is None:
        # AbstractMesh (native set_mesh): bare specs bind to the ambient mesh.
        return jax.lax.with_sharding_constraint(x, spec)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
