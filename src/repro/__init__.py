"""repro: SPARTA-on-TPU — compound weather-stencil acceleration in JAX/Pallas
plus the multi-arch LM framework substrate (see DESIGN.md)."""

__version__ = "1.0.0"
