"""repro: SPARTA-on-TPU — compound weather-stencil acceleration in JAX/Pallas
plus the multi-arch LM framework substrate (see DESIGN.md)."""

from repro import compat as _compat  # noqa: F401  (backfills jax API names)

__version__ = "1.0.0"
