"""arctic-480b [moe]: 35L, d_model 7168, 56H (GQA kv=8), expert d_ff 4864,
vocab 32000, MoE 128 experts top-2 + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]

Memory posture: 480B params -> Adafactor (factored second moment, no first
moment) so optimizer state stays ~O(params); Adam m/v would not fit 16
GiB/chip on the single-pod mesh."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    # 56 heads don't divide the 16-way model axis; pad to 64 with
    # hard-masked (exactly dead) heads so attention shards (see layers.py).
    pad_heads_to=64,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    moe_dense_residual=True,
    tied_embeddings=False,
    optimizer="adafactor",
    moment_dtype="bfloat16",
    # 480B params: bf16 storage (Adafactor-friendly); f32 master copies
    # would alone exceed a 256-chip pod's HBM.
    param_dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=0,
        d_ff=32,
        vocab_size=256,
        n_experts=8,
        top_k=2,
        remat=False,
    )
