"""Model/run configuration system.

One frozen dataclass describes everything the model zoo needs; each assigned
architecture gets a module in ``repro/configs/<id>.py`` exporting ``CONFIG``
(the exact published shape) and ``smoke_config()`` (a reduced same-family
variant for CPU tests). ``repro.configs.registry`` resolves ``--arch`` names.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["attn", "local_attn", "cross_attn", "rglru", "rwkv6"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (attention blocks)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention ---------------------------------------------------------
    rope: bool = True
    rope_theta: float = 10_000.0
    qkv_bias: bool = False           # qwen1.5
    window: int = 0                  # sliding-window size; 0 = full (starcoder2: 4096)
    causal: bool = True              # hubert: False (encoder-only)
    is_encoder: bool = False
    # Pad Q heads to this count for TP divisibility (zero heads are exact:
    # their wo rows are zero). arctic: 56 -> 64 on a 16-wide model axis.
    pad_heads_to: int = 0

    # --- ffn ----------------------------------------------------------------
    activation: str = "swiglu"       # swiglu | geglu | gelu | squared_relu
    norm: str = "rmsnorm"            # rmsnorm | layernorm

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False  # arctic: dense FFN parallel to MoE

    # --- recurrent mixers ----------------------------------------------------
    rnn_width: int = 0               # RG-LRU width (0 -> d_model)
    conv_width: int = 4              # Griffin temporal conv
    rwkv_head_size: int = 64
    rwkv_chunk: int = 0              # 0 = sequential scan; >0 = chunked form

    # --- block pattern --------------------------------------------------------
    # Repeated cyclically to n_layers; remainder layers appended at the end.
    block_pattern: tuple[str, ...] = ("attn",)

    # --- stub frontends (audio/vlm: precomputed embeddings per the brief) ----
    frontend: str = ""               # "" | "audio" | "vision"
    num_media_tokens: int = 0        # cross-attn memory length (vlm)

    # --- embeddings / numerics ----------------------------------------------
    tied_embeddings: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"       # full (save nothing) | dots (save matmul outputs)
    # dry-run cost-extrapolation knobs (XLA cost analysis ignores `while`
    # trip counts, so small variants are lowered UNROLLED; see launch/dryrun)
    unroll_layers: bool = False
    flash_unroll: bool = False

    # --- training defaults ----------------------------------------------------
    optimizer: str = "adamw"         # adamw | adafactor
    moment_dtype: str = "float32"    # bf16 moments for the giant MoEs
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)
        if self.n_heads and self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    # -- derived -------------------------------------------------------------

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """The per-layer block kinds, pattern cycled to n_layers."""
        pat = self.block_pattern
        reps = self.n_layers // len(pat)
        rem = self.n_layers % len(pat)
        return pat * reps + pat[:rem]

    @property
    def n_super(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is O(1) in context length (window/recurrent),
        i.e. the arch can run the long_500k shape."""
        kinds = set(self.layer_kinds)
        if "attn" in kinds and self.window == 0:
            return False
        if "cross_attn" in kinds:
            return False
        return True

    @property
    def supports_decode(self) -> bool:
        return self.causal and not self.is_encoder

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        total = v * d  # token embedding
        if not self.tied_embeddings:
            total += v * d
        total += d  # final norm
        hd = self.head_dim
        for kind in self.layer_kinds:
            total += 2 * d  # two norms (approx; layernorm bias ignored)
            if kind in ("attn", "local_attn", "cross_attn"):
                total += d * (self.n_heads * hd) + d * (2 * self.n_kv_heads * hd)
                total += (self.n_heads * hd) * d
            elif kind == "rglru":
                w = self.rnn_width
                total += 2 * d * w + self.conv_width * w + 2 * w * (w // 8) + 2 * w + w * d
            elif kind == "rwkv6":
                total += 4 * d * d + d * d  # r,k,v,g,o
                total += 6 * d * 64  # lora mixers (approx)
            if kind == "cross_attn":
                pass
            if self.n_experts and kind != "rwkv6":
                total += d * self.n_experts  # router
                total += self.n_experts * 3 * d * f
                if self.moe_dense_residual:
                    total += 3 * d * f
            elif kind == "rwkv6":
                total += 2 * d * f // 2 + d * d  # channel mix (k, v, r)
            else:
                mult = 3 if self.activation in ("swiglu", "geglu") else 2
                total += mult * d * f
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_layer_all = self.n_experts * 3 * d * f
        per_layer_active = self.top_k * 3 * d * f
        n_moe_layers = sum(1 for k in self.layer_kinds if k != "rwkv6")
        return self.param_count() - n_moe_layers * (per_layer_all - per_layer_active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
