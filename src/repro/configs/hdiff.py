"""The paper's own workload config: COSMO hdiff on a 256 x 256 x 64 grid
(§4.1: "We run all our experiments using a 256x256x64-point domain similar
to the grid domain used by the COSMO weather prediction model"), fp32."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class HdiffConfig:
    rows: int = 256
    cols: int = 256
    depth: int = 64
    coeff: float = 0.025
    dtype: str = "float32"
    n_timesteps: int = 100
    limit: bool = True


CONFIG = HdiffConfig()


def smoke_config() -> HdiffConfig:
    return dataclasses.replace(CONFIG, rows=32, cols=32, depth=4, n_timesteps=3)
