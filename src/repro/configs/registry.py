"""--arch registry: maps assignment ids to configs.

Every assigned architecture exposes:
  * ``CONFIG``        — the exact published shape from the assignment table
  * ``smoke_config()``— reduced same-family variant for CPU smoke tests
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_ARCH_MODULES: dict[str, str] = {
    "llama-3.2-vision-90b": "repro.configs.llama32_vision_90b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "nemotron-4-15b": "repro.configs.nemotron4_15b",
    "glm4-9b": "repro.configs.glm4_9b",
    "qwen1.5-0.5b": "repro.configs.qwen15_05b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "arctic-480b": "repro.configs.arctic_480b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return importlib.import_module(_ARCH_MODULES[arch]).smoke_config()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def applicable_cells(arch: str) -> list[str]:
    """The shape cells this arch runs (skips documented in DESIGN.md)."""
    cfg = get_config(arch)
    cells = ["train_4k", "prefill_32k"]
    if cfg.supports_decode:
        cells.append("decode_32k")
        if cfg.sub_quadratic:
            cells.append("long_500k")
    return cells


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in applicable_cells(a)]


def scale_for_smoke(shape: ShapeConfig, seq: int = 64, batch: int = 2) -> ShapeConfig:
    return dataclasses.replace(shape, seq_len=seq, global_batch=batch)
