"""nemotron-4-15b [dense]: 32L, d_model 6144, 48H (GQA kv=8), d_ff 24576,
vocab 256000 — GQA, squared-ReLU MLP, LayerNorm.
[arXiv:2402.16819; unverified]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    activation="squared_relu",
    norm="layernorm",
    tied_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=0,
        d_ff=128,
        vocab_size=256,
        remat=False,
    )
