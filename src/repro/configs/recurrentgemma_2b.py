"""recurrentgemma-2b [hybrid]: 26L, d_model 2560, 10H (MQA kv=1),
d_ff 7680 (GeGLU), vocab 256000 — RG-LRU + local attention, 1 attn per
2 recurrent (Griffin pattern), window 2048. [arXiv:2402.19427; hf]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    window=2048,
    activation="geglu",
    block_pattern=("rglru", "rglru", "local_attn"),
    rnn_width=2560,
    conv_width=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=3,
        d_model=64,
        n_heads=2,
        n_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab_size=256,
        window=8,
        rnn_width=64,
        remat=False,
    )
