"""Per-architecture configs (one module per assigned arch) + registry."""

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import (
    ARCH_IDS,
    all_cells,
    applicable_cells,
    get_config,
    get_shape,
    get_smoke_config,
)
