"""rwkv6-3b [ssm]: 32L, d_model 2560 (attention-free), d_ff 8960,
vocab 65536 — RWKV-6 "Finch" with data-dependent decay.
[arXiv:2404.05892; hf]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=8960,
    vocab_size=65536,
    block_pattern=("rwkv6",),
    rwkv_head_size=64,
    # Chunked WKV (kernels/wkv6 formulation): 64-step chunks turn the
    # 4096-step sequential recurrence into 64 MXU-dense steps (§Perf rwkv6).
    rwkv_chunk=64,
    norm="layernorm",
    tied_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        rwkv_head_size=16,
        remat=False,
    )
