"""llama-3.2-vision-90b [vlm]: 100L, d_model 8192, 64H (GQA kv=8),
d_ff 28672, vocab 128256 — cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Vision frontend is a STUB per the brief: input_specs provides precomputed
image-patch embeddings as the cross-attention memory."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=("attn", "attn", "attn", "attn", "cross_attn"),
    frontend="vision",
    num_media_tokens=1024,
    tied_embeddings=False,
    rope_theta=500_000.0,
    moment_dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=0,
        d_ff=128,
        vocab_size=256,
        num_media_tokens=8,
        remat=False,
    )
