"""hubert-xlarge [audio]: 48L encoder-only, d_model 1280, 16H, d_ff 5120,
vocab 504 (cluster targets) — same backbone as wav2vec2.
[arXiv:2106.07447; unverified]

Audio frontend (conv feature extractor) is a STUB per the brief:
input_specs provides precomputed frame embeddings (B, S, 1280)."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    is_encoder=True,
    rope=False,
    activation="gelu",
    norm="layernorm",
    frontend="audio",
    tied_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=0,
        d_ff=128,
        vocab_size=64,
        remat=False,
    )
