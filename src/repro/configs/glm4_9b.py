"""glm4-9b [dense]: 40L, d_model 4096, 32H (GQA kv=2), d_ff 13696,
vocab 151552 — RoPE, GQA, SwiGLU. [hf:THUDM/glm-4-9b; hf]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    tied_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=0,
        d_ff=96,
        vocab_size=256,
        remat=False,
    )
