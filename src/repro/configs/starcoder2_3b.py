"""starcoder2-3b [dense]: 30L, d_model 3072, 24H (GQA kv=2), d_ff 12288,
vocab 49152 — GQA, RoPE, sliding-window 4096 attention.
[arXiv:2402.19173; hf]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    window=4096,
    activation="gelu",
    norm="layernorm",
    qkv_bias=True,
    rope_theta=100_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=0,
        d_ff=128,
        vocab_size=256,
        window=8,
        remat=False,
    )
