#!/usr/bin/env python
"""Perf-trajectory gate: current BENCH_<fig>.json vs committed baselines.

``scripts/bench_smoke.py`` produces one machine-readable record per fig;
this script compares those records against the baselines committed under
``benchmarks/baselines/`` and exits nonzero on a per-row regression, so a
PR that slows a kernel down or fattens a wire model fails CI instead of
silently bending the trajectory.

Comparison rules, per row, keyed by the row's ``unit`` tag:

  * ``us``     — wall clock, lower is better, noisy on shared runners: a
                 regression needs BOTH ``cur > base * (1 + --max-us-regression)``
                 AND ``cur - base > --us-floor`` microseconds (the absolute
                 floor stops 20 us -> 45 us interpret-mode jitter from
                 failing a build).
  * ``bytes``  — deterministic traffic models (wire bytes, HBM bytes): ANY
                 drift beyond ``--max-bytes-regression`` in either
                 direction fails, because byte counts only move when the
                 program or the model changed — refresh the baseline
                 deliberately with ``--update`` when that's intended.
  * ``rate``   — deterministic serving ratios (fig14's cache hit rate and
                 warm-path trace count): machine-independent by
                 construction, so they gate with the ``bytes`` rule —
                 drift in either direction beyond
                 ``--max-bytes-regression`` means the admission/caching
                 logic changed, not the hardware. (Wall-clock throughput
                 rows use ``rate_info`` and never gate.)
  * anything else (``x``, ``model_us``, ``bool``, ``info``,
                 ``rate_info``, ...) — informational, never gates.

Rows are matched by name; a gating row present in the baseline but missing
from the current run is a failure (coverage shrank). Records whose
metadata differs on ``backend`` / ``device_kind`` / ``device_count`` are
skipped entirely — a laptop run must not gate against a CI baseline.

``--update`` rewrites the baselines from the current records and exits 0;
CI refreshes the committed baseline artifact this way on main.

Usage:
    PYTHONPATH=src python scripts/bench_compare.py \
        --current-dir bench-artifacts --baseline-dir benchmarks/baselines
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

sys.path.insert(
    0,
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"),
)

from repro.obs import MATCH_KEYS  # noqa: E402

GATED_UNITS = ("us", "bytes", "rate")


def load_records(directory: Path) -> dict[str, dict]:
    """``{fig: record}`` for every BENCH_<fig>.json in ``directory``."""
    records = {}
    for path in sorted(directory.glob("BENCH_*.json")):
        rec = json.loads(path.read_text())
        records[rec.get("fig", path.stem.removeprefix("BENCH_"))] = rec
    return records


def meta_mismatch(cur: dict, base: dict) -> list[str]:
    """The MATCH_KEYS on which the two records' environments differ."""
    cm, bm = cur.get("meta", {}), base.get("meta", {})
    return [k for k in MATCH_KEYS if cm.get(k) != bm.get(k)]


def rows_by_name(record: dict) -> dict[str, dict]:
    return {r["name"]: r for r in record.get("rows", [])}


def compare_fig(
    cur: dict,
    base: dict,
    *,
    max_us_regression: float,
    us_floor: float,
    max_bytes_regression: float,
) -> tuple[list[str], list[str]]:
    """Returns ``(failures, notes)`` for one fig's record pair."""
    failures: list[str] = []
    notes: list[str] = []
    fig = cur.get("fig", "?")

    mismatch = meta_mismatch(cur, base)
    if mismatch:
        cm, bm = cur.get("meta", {}), base.get("meta", {})
        notes.append(
            f"{fig}: SKIPPED (metadata mismatch on "
            + ", ".join(f"{k}: {bm.get(k)!r} -> {cm.get(k)!r}" for k in mismatch)
            + ")"
        )
        return failures, notes

    cur_rows, base_rows = rows_by_name(cur), rows_by_name(base)
    for name, brow in base_rows.items():
        unit = brow.get("unit", "us")
        if unit not in GATED_UNITS:
            continue
        crow = cur_rows.get(name)
        if crow is None:
            failures.append(f"{fig}: {name} [{unit}] present in baseline but "
                            f"missing from the current run")
            continue
        bval, cval = float(brow["value"]), float(crow["value"])
        if unit == "us":
            limit = bval * (1.0 + max_us_regression)
            if cval > limit and cval - bval > us_floor:
                failures.append(
                    f"{fig}: {name} wall-clock regression "
                    f"{bval:.1f}us -> {cval:.1f}us "
                    f"(limit {limit:.1f}us = +{max_us_regression:.0%}, "
                    f"floor +{us_floor:.0f}us)"
                )
        elif unit in ("bytes", "rate"):
            # Both are deterministic by construction (traffic models /
            # serving cache ratios): drift EITHER way is a logic change.
            # A 0-valued baseline (fig14's warm-trace count) therefore
            # tolerates exactly 0 drift — any warm-path retrace fails.
            tol = bval * max_bytes_regression
            what = "byte-model" if unit == "bytes" else "serving-rate"
            if abs(cval - bval) > tol:
                failures.append(
                    f"{fig}: {name} {what} drift {bval:.4g} -> {cval:.4g} "
                    f"(tolerance +/-{max_bytes_regression:.0%}; {unit} rows "
                    f"are deterministic — refresh the baseline with --update "
                    f"if this change is intended)"
                )
    new = [n for n in cur_rows if n not in base_rows]
    if new:
        notes.append(f"{fig}: {len(new)} new row(s) not in baseline: "
                     + ", ".join(sorted(new)[:5])
                     + ("..." if len(new) > 5 else ""))
    return failures, notes


def update_baselines(current: dict[str, dict], baseline_dir: Path) -> None:
    baseline_dir.mkdir(parents=True, exist_ok=True)
    for fig, rec in current.items():
        path = baseline_dir / f"BENCH_{fig}.json"
        path.write_text(json.dumps(rec, indent=2) + "\n")
        print(f"baseline updated: {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current-dir", required=True,
                    help="directory holding the fresh BENCH_<fig>.json records")
    ap.add_argument("--baseline-dir", default="benchmarks/baselines",
                    help="committed baseline records (default: %(default)s)")
    ap.add_argument("--max-us-regression", type=float, default=0.5,
                    help="relative wall-clock regression bound "
                         "(0.5 = +50%%; default: %(default)s)")
    ap.add_argument("--us-floor", type=float, default=200.0,
                    help="absolute wall-clock slack in us — a row must also "
                         "slow by more than this to fail (default: %(default)s)")
    ap.add_argument("--max-bytes-regression", type=float, default=0.02,
                    help="byte-model drift tolerance, either direction "
                         "(default: %(default)s)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baselines from the current records")
    args = ap.parse_args(argv)

    current = load_records(Path(args.current_dir))
    if not current:
        print(f"no BENCH_*.json records in {args.current_dir}", file=sys.stderr)
        return 1
    if args.update:
        update_baselines(current, Path(args.baseline_dir))
        return 0

    baseline = load_records(Path(args.baseline_dir))
    failures: list[str] = []
    for fig, cur in sorted(current.items()):
        base = baseline.get(fig)
        if base is None:
            failures.append(
                f"{fig}: no baseline in {args.baseline_dir} "
                f"(run with --update to create it)"
            )
            continue
        figs_failures, notes = compare_fig(
            cur,
            base,
            max_us_regression=args.max_us_regression,
            us_floor=args.us_floor,
            max_bytes_regression=args.max_bytes_regression,
        )
        failures.extend(figs_failures)
        for n in notes:
            print(n)
        if not figs_failures and not any(n.endswith(")") and "SKIPPED" in n for n in notes):
            gated = sum(
                1 for r in base.get("rows", []) if r.get("unit", "us") in GATED_UNITS
            )
            print(f"{fig}: ok ({gated} gated row(s) within bounds)")

    if failures:
        print(f"\nbench compare FAILED ({len(failures)} problem(s)):",
              file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"bench compare ok: {len(current)} fig(s) vs {args.baseline_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
