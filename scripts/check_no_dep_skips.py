#!/usr/bin/env python
"""CI gate: fail when any test was SKIPPED for a missing dev dependency.

``pytest.importorskip("hypothesis")`` makes property-test modules vanish
silently when the dev extras aren't installed — a green run that quietly
dropped coverage. CI installs ``.[dev]``, so any import-skip there means the
extras list (pyproject ``[project.optional-dependencies].dev``) and the
tests have drifted apart; this script turns that into a hard failure.

Usage: run pytest with ``--junitxml=report.xml``, then
``python scripts/check_no_dep_skips.py report.xml``.
"""

from __future__ import annotations

import sys
import xml.etree.ElementTree as ET

# Messages produced by pytest.importorskip / ImportError-driven skips.
DEP_SKIP_PATTERNS = ("could not import", "no module named")


def find_dependency_skips(junit_xml_path: str) -> list[str]:
    tree = ET.parse(junit_xml_path)
    bad = []
    for case in tree.iter("testcase"):
        for skip in case.iter("skipped"):
            msg = f"{skip.get('message') or ''} {skip.text or ''}".lower()
            if any(pat in msg for pat in DEP_SKIP_PATTERNS):
                bad.append(
                    f"{case.get('classname') or case.get('file')}::"
                    f"{case.get('name')}: {skip.get('message')}"
                )
    return bad


def main(argv: list[str]) -> int:
    if len(argv) != 2:
        print(f"usage: {argv[0]} <junit-report.xml>", file=sys.stderr)
        return 2
    bad = find_dependency_skips(argv[1])
    if bad:
        print("tests skipped for missing dev dependencies (install '.[dev]'):")
        for line in bad:
            print(f"  - {line}")
        return 1
    print("no dependency-driven skips found")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
