#!/usr/bin/env python
"""CI gate: fail when any test was SKIPPED for a missing dev dependency —
and, with ``--fail-on-mesh-skips``, when any multi-device mesh shape was
skipped.

``pytest.importorskip("hypothesis")`` makes property-test modules vanish
silently when the dev extras aren't installed — a green run that quietly
dropped coverage. CI installs ``.[dev]``, so any import-skip there means the
extras list (pyproject ``[project.optional-dependencies].dev``) and the
tests have drifted apart; this script turns that into a hard failure.

The conformance matrix (tests/test_conformance_matrix.py) skips a mesh cell
with a "mesh RxC unavailable" message when the fake-device subprocess cannot
back it. In the tier-1 job that is legitimate (it runs 1-device); in the
multidev-2d job — whose whole point is those meshes — it would be silent
coverage loss, so that job passes ``--fail-on-mesh-skips``.

Usage: run pytest with ``--junitxml=report.xml``, then
``python scripts/check_no_dep_skips.py report.xml [more-reports.xml ...]
[--fail-on-mesh-skips]``. Several reports can be gated in one call (the
bench-smoke CI job produces one junitxml per pytest invocation and gates
them together); the exit code is the OR over all of them.
"""

from __future__ import annotations

import sys
import xml.etree.ElementTree as ET

# Messages produced by pytest.importorskip / ImportError-driven skips.
DEP_SKIP_PATTERNS = ("could not import", "no module named")
# Messages produced when a conformance mesh shape cannot be provided
# (test_conformance_mesh skips with "mesh RxC unavailable: ..."). ALL
# patterns must match, so an unrelated skip that merely mentions a mesh
# does not trip the gate.
MESH_SKIP_PATTERNS = ("mesh", "unavailable")


def _iter_skips(junit_xml_path: str):
    tree = ET.parse(junit_xml_path)
    for case in tree.iter("testcase"):
        for skip in case.iter("skipped"):
            msg = f"{skip.get('message') or ''} {skip.text or ''}".lower()
            yield case, skip, msg


def find_dependency_skips(junit_xml_path: str) -> list[str]:
    return [
        f"{case.get('classname') or case.get('file')}::"
        f"{case.get('name')}: {skip.get('message')}"
        for case, skip, msg in _iter_skips(junit_xml_path)
        if any(pat in msg for pat in DEP_SKIP_PATTERNS)
    ]


def find_mesh_skips(junit_xml_path: str) -> list[str]:
    return [
        f"{case.get('classname') or case.get('file')}::"
        f"{case.get('name')}: {skip.get('message')}"
        for case, skip, msg in _iter_skips(junit_xml_path)
        if all(pat in msg for pat in MESH_SKIP_PATTERNS)
    ]


def main(argv: list[str]) -> int:
    args = list(argv[1:])
    fail_on_mesh = "--fail-on-mesh-skips" in args
    if fail_on_mesh:
        args.remove("--fail-on-mesh-skips")
    unknown = [a for a in args if a.startswith("-")]
    if unknown or not args:
        print(
            f"usage: {argv[0]} <junit-report.xml> [more-reports.xml ...] "
            "[--fail-on-mesh-skips]",
            file=sys.stderr,
        )
        return 2
    rc = 0
    for report in args:
        bad = find_dependency_skips(report)
        if bad:
            print(
                f"{report}: tests skipped for missing dev dependencies "
                "(install '.[dev]'):"
            )
            for line in bad:
                print(f"  - {line}")
            rc = 1
        if fail_on_mesh:
            mesh_bad = find_mesh_skips(report)
            if mesh_bad:
                print(
                    f"{report}: mesh shapes skipped (multi-device coverage "
                    "silently dropped):"
                )
                for line in mesh_bad:
                    print(f"  - {line}")
                rc = 1
    if rc == 0:
        reports = f"{len(args)} report(s)" if len(args) > 1 else args[0]
        print(
            f"no dependency-driven skips found in {reports}"
            + (" (mesh skips also checked)" if fail_on_mesh else "")
        )
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv))
