"""Generates the §Dry-run and §Roofline tables of EXPERIMENTS.md from
artifacts/dryrun/*.json. Rerunnable as cells complete.

  PYTHONPATH=src python scripts/make_experiments.py > artifacts/roofline_tables.md
"""

from __future__ import annotations

import json
from pathlib import Path

ART = Path("artifacts/dryrun")


def fmt_s(x: float | None) -> str:
    if x is None:
        return "-"
    if x == 0:
        return "0"
    return f"{x:.2e}"


def fmt_gib(x) -> str:
    return "-" if x is None else f"{x/2**30:.2f}"


def load(mesh: str) -> list[dict]:
    rows = []
    for p in sorted(ART.glob(f"*__{mesh}.json")):
        try:
            rows.append(json.loads(p.read_text()))
        except Exception:
            pass
    return rows


BASELINE = Path("artifacts/dryrun_baseline")


def comparison_table() -> None:
    """Baseline (paper-faithful lowering) vs optimized, single-pod."""
    print("\n## Baseline vs optimized (single-pod; dominant-term seconds/chip/step)\n")
    print("| cell | base dom term | base s | opt dom term | opt s | speedup | temp GiB base->opt |")
    print("|---|---|---|---|---|---|---|")
    for p in sorted(BASELINE.glob("*__single.json")):
        try:
            b = json.loads(p.read_text())
            o = json.loads((ART / p.name).read_text())
        except Exception:
            continue
        if b.get("status") != "ok" or o.get("status") != "ok":
            continue
        brf, orf = b["roofline"], o["roofline"]
        bdom = max(("compute", "memory", "collective"), key=lambda k: brf[f"{k}_s"])
        odom = max(("compute", "memory", "collective"), key=lambda k: orf[f"{k}_s"])
        bval, oval = brf[f"{bdom}_s"], orf[f"{odom}_s"]
        btmp = b["memory_analysis"]["temp_bytes"] / 2**30
        otmp = o["memory_analysis"]["temp_bytes"] / 2**30
        cell = p.name.replace("__single.json", "")
        print(
            f"| {cell} | {bdom} | {fmt_s(bval)} | {odom} | {fmt_s(oval)} | "
            f"{bval/oval if oval else 0:.1f}x | {btmp:.1f} -> {otmp:.1f} |"
        )
    print()


def main() -> None:
    print("## §Dry-run (single-pod 16x16 = 256 chips; multi-pod 2x16x16 = 512 chips)\n")
    for mesh in ("single", "multi"):
        rows = load(mesh)
        ok = [r for r in rows if r.get("status") == "ok"]
        bad = [r for r in rows if r.get("status") != "ok"]
        print(f"### mesh={mesh}: {len(ok)} ok, {len(bad)} failed\n")
        print("| cell | compile s | args GiB/dev | temp GiB/dev | collectives (counts) |")
        print("|---|---|---|---|---|")
        for r in ok:
            m = r["memory_analysis"]
            cc = r["collectives"].get("counts_variant_b", {})
            cstr = " ".join(f"{k}:{v}" for k, v in sorted(cc.items()))
            print(
                f"| {r['cell']} | {r['timings_s']['compile']} | "
                f"{fmt_gib(m['argument_bytes'])} | {fmt_gib(m['temp_bytes'])} | {cstr} |"
            )
        for r in bad:
            print(f"| {r['cell']} | FAILED: {r.get('error','?')[:60]} | | | |")
        print()

    print("\n## §Roofline (single-pod, per-chip terms; v5e: 197 TF bf16, 819 GB/s HBM, 50 GB/s ICI)\n")
    print("Projected MFU = ideal model-FLOPs time / bottleneck term: "
          "`hlo` uses the compiled-artifact terms (memory term is a CPU-fusion "
          "UPPER bound -> conservative), `ana` replaces compute/memory with the "
          "analytic model (TPU-realistic fused traffic).\n")
    rows = load("single")
    ok = [r for r in rows if r.get("status") == "ok"]
    print("| cell | compute s | memory s | collective s | dominant | useful | MFU(hlo) | MFU(ana) | MFU(tpu) | bottleneck note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    PEAK, HBM = 197e12, 819e9
    mfu_sum = {"hlo": [], "ana": [], "tpu": []}
    for r in ok:
        rf = r["roofline"]
        ana = r.get("analytic", {})
        n_dev = 256
        ideal_s = rf["model_flops"] / (n_dev * PEAK)
        bottleneck = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        mfu_hlo = ideal_s / bottleneck if bottleneck else 0.0
        ana_comp = ana.get("flops_per_device", 0) / PEAK
        ana_mem = ana.get("hbm_bytes_global", 0) / n_dev / HBM
        ana_bottleneck = max(ana_comp, ana_mem, rf["collective_s"])
        mfu_ana = ideal_s / ana_bottleneck if ana_bottleneck else 0.0
        # TPU projection: analytic compute/memory + collectives halved per
        # honesty-box note 3 (CPU legalises bf16 dots -> f32 wire).
        tpu_bottleneck = max(ana_comp, ana_mem, rf["collective_s"] / 2)
        mfu_tpu = ideal_s / tpu_bottleneck if tpu_bottleneck else 0.0
        mfu_sum["hlo"].append(mfu_hlo)
        mfu_sum["ana"].append(mfu_ana)
        mfu_sum["tpu"].append(mfu_tpu)
        note = NOTES.get(r["cell"].rsplit("__", 1)[0], "")
        print(
            f"| {r['cell']} | {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | "
            f"{fmt_s(rf['collective_s'])} | {rf['dominant']} | "
            f"{rf['useful_flops_ratio']:.2f} | {mfu_hlo*100:.0f}% | {mfu_ana*100:.0f}% | "
            f"{mfu_tpu*100:.0f}% | {note} |"
        )
    if ok:
        train = [(m, r) for m, r in zip(mfu_sum["tpu"], ok) if "train" in r["cell"]]
        prefill = [(m, r) for m, r in zip(mfu_sum["tpu"], ok) if "prefill" in r["cell"]]
        print(
            f"\nTPU-projected MFU (the §Perf score): train cells mean "
            f"{100*sum(m for m, _ in train)/max(len(train),1):.0f}% "
            f"(best {100*max((m for m, _ in train), default=0):.0f}%), prefill cells mean "
            f"{100*sum(m for m, _ in prefill)/max(len(prefill),1):.0f}% "
            f"(best {100*max((m for m, _ in prefill), default=0):.0f}%). Decode cells are "
            f"latency-bound (honesty-box note 5); their score is the step-latency term."
        )
    print()
    comparison_table()


# One-sentence "what would move the dominant term down" per cell.
NOTES = {
    "llama-3.2-vision-90b__train_4k": "memory: activation-offload or 2x microbatching; bf16 optimizer moments already on",
    "llama-3.2-vision-90b__prefill_32k": "memory: fuse cross-attn K/V projection into prefill flash pass",
    "llama-3.2-vision-90b__decode_32k": "collective: split cache into frozen seq-sharded prefix + replicated hot ring to kill the per-step cache-update gather",
    "starcoder2-3b__train_4k": "memory: window 4096 == seq 4096 so full flash runs; sub-window blocking would shard attn over model",
    "starcoder2-3b__prefill_32k": "HILLCLIMBED: seq-parallel blocked-local attention (see §Perf)",
    "starcoder2-3b__decode_32k": "collective: 24 heads don't shard 16-way; ring cache is small — pack 2 decode steps per collective round",
    "starcoder2-3b__long_500k": "healthy: 4096-window ring cache keeps all terms micro-scale",
    "nemotron-4-15b__train_4k": "memory: squared-relu FFN h is the largest temp; fuse relu^2 into the w2 matmul epilogue on TPU",
    "nemotron-4-15b__prefill_32k": "memory: 256k-vocab head dominates bytes; shard lse reduction tree deeper",
    "nemotron-4-15b__decode_32k": "collective: kv=8 heads can't shard 16-way; seq-sharded cache psum per layer",
    "glm4-9b__train_4k": "memory: same head/FFN mix as llama; microbatch deeper or offload",
    "glm4-9b__prefill_32k": "memory: flash chunk 512 -> 1024 to halve pipeline overhead once VMEM allows",
    "glm4-9b__decode_32k": "collective: kv=2 forces seq-sharded cache; partial-softmax combine is the cost",
    "qwen1.5-0.5b__train_4k": "memory: model is tiny, vocab head (152k) is ~half the bytes; tie head compute into the last layer",
    "qwen1.5-0.5b__prefill_32k": "memory: as train; 0.5B params make every term small",
    "qwen1.5-0.5b__decode_32k": "memory: kv=16 shards cleanly; batch 128 decode is HBM-bound on cache reads (healthy)",
    "qwen3-moe-235b-a22b__train_4k": "HILLCLIMBED: shard_map expert-parallel MoE (see §Perf)",
    "qwen3-moe-235b-a22b__prefill_32k": "collective: expert-weight FSDP gathers dominate; prefetch next layer's experts during attention",
    "qwen3-moe-235b-a22b__decode_32k": "memory: 8 tokens/device can't amortise 128-expert weight reads; expert-choice routing or wider decode batch",
    "arctic-480b__train_4k": "memory: 56 heads replicate over 16-way model axis (divisibility); head_dim sharding or 8-way TP sub-mesh",
    "arctic-480b__prefill_32k": "collective: dense-residual TP psum + expert gathers; overlap with attention compute",
    "arctic-480b__decode_32k": "collective: as prefill; decode batch 128 keeps experts ~60% utilised",
    "recurrentgemma-2b__train_4k": "memory: RG-LRU gates are full-rank (W,W); block-diagonal gates (as in Griffin) would cut both flops and bytes 4x",
    "recurrentgemma-2b__prefill_32k": "memory: associative_scan materialises log-depth intermediates; the Pallas rglru kernel keeps state in VMEM",
    "recurrentgemma-2b__decode_32k": "collective: 10 heads + kv=1 can't shard; replicate attn, shard RG-LRU width over model",
    "recurrentgemma-2b__long_500k": "healthy: constant state + 2k window",
    "rwkv6-3b__train_4k": "HILLCLIMBED: chunked WKV (see §Perf)",
    "rwkv6-3b__prefill_32k": "memory: chunked WKV + wkv6 Pallas kernel keep state in VMEM; token-shift concat is the residual cost",
    "rwkv6-3b__decode_32k": "collective: heads replicate (40 heads, 64-dim); shard the (H, hs, hs) state over model instead",
    "rwkv6-3b__long_500k": "healthy: O(1) state",
    "hubert-xlarge__train_4k": "collective: 504-way head replicates; grads all-reduce dominates at 1B params — bf16 compression",
    "hubert-xlarge__prefill_32k": "memory: bidirectional flash over 32k frames; chunk 1024 would halve pipeline overhead",
}


if __name__ == "__main__":
    main()
