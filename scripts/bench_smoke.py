#!/usr/bin/env python
"""Benchmark smoke runner: reduced-grid fig runs -> machine-readable
``BENCH_<fig>.json`` records, the perf-trajectory artifacts CI uploads.

Until now the benchmark suite only printed CSV rows to stdout, so the repo
never accumulated a perf trajectory (``BENCH_*.json`` had never been
produced). This script runs fig10-fig15 on a reduced grid
(the paper's 64 x 256 x 256 shrinks to ``--depth/--rows/--cols``, patched
into ``benchmarks.common`` BEFORE the fig modules import it, plus each
fig's ``fast=True`` mode) and writes one JSON record per fig with:

  * ``rows``          — the raw ``(name, value, derived, unit)`` benchmark
                        rows (``unit`` drives scripts/bench_compare.py's
                        per-row comparison rule);
  * ``meta``          — device/platform provenance (jax version, backend,
                        device kind/count, commit SHA) so trajectory
                        comparisons only gate like-for-like rows;
  * ``parity_ok``     — every in-benchmark parity check held (fig10/12/13
                        raise on divergence; fig11 marks rows parity=FAIL);
  * ``wire_ratios``   — every measured-vs-model wire-byte ratio parsed
                        from the rows (fig10/fig13 emit ``ratio=...`` for
                        each real 8-fake-device halo measurement);
  * ``wall_clock_s``  — wall time of the whole fig run;
  * ``error``         — the exception message when the run blew up.

Exit status is nonzero when any fig failed parity, emitted no rows, or
produced a wire ratio outside [0.99, 1.01] — so the CI bench-smoke job is a
real gate, not just an artifact producer.

Usage: PYTHONPATH=src python scripts/bench_smoke.py --out-dir bench
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

# Runnable as `python scripts/bench_smoke.py`: the benchmarks package lives
# at the repo root, which is not on sys.path in that invocation.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

RATIO_RE = re.compile(r"ratio=([0-9]+(?:\.[0-9]+)?|nan)")
RATIO_LO, RATIO_HI = 0.99, 1.01
DEFAULT_FIGS = ("fig10", "fig11", "fig12", "fig13", "fig14", "fig15")


def extract_wire_ratios(rows) -> list[float]:
    """Every measured-vs-model ratio stamped into the rows' derived column.

    Rows are ``(name, value, derived)`` or ``(name, value, derived, unit)``
    — the unit column arrived with the trajectory gate and old callers/tests
    still hand in 3-tuples."""
    return [float(m) for row in rows for m in RATIO_RE.findall(row[2])]


def rows_parity_ok(rows) -> bool:
    """fig11-style rows carry parity=ok / parity=FAIL inline (the other figs
    raise on parity failure, which the caller turns into error != None)."""
    return not any("parity=FAIL" in row[2] for row in rows)


def row_unit(row) -> str:
    """The unit tag of a benchmark row; 3-tuple rows predate tagging = us."""
    return row[3] if len(row) > 3 else "us"


def gate_record(record, lo: float = RATIO_LO, hi: float = RATIO_HI) -> list[str]:
    """The CI gate: returns the reasons this record fails, [] when clean."""
    problems = []
    if record.get("error"):
        problems.append(f"run failed: {record['error']}")
    if not record.get("parity_ok", False):
        problems.append("parity failure")
    if not record.get("rows"):
        problems.append("no benchmark rows emitted")
    for ratio in record.get("wire_ratios", ()):
        if not (lo <= ratio <= hi):
            problems.append(
                f"wire-byte measured/model ratio {ratio} outside [{lo}, {hi}]"
            )
    return problems


def emit_probe_overhead_row(common, fig: str) -> None:
    """One informational row per fig: the cost of a jitted
    ``repro.obs.health.field_stats`` probe on this run's reduced grid, so
    the BENCH_*.json trajectory records what a health probe costs next to
    what the stencils cost. The ``probe_us`` unit is NOT in
    scripts/bench_compare.py's GATED_UNITS — the row never gates."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.obs.health import field_stats

    rng = np.random.default_rng(0)
    x = jnp.asarray(
        rng.standard_normal((common.DEPTH, common.ROWS, common.COLS)).astype(np.float32)
    )
    t = common.time_stats(jax.jit(field_stats), x, warmup=2, iters=5)
    common.emit(
        f"{fig}/health_probe",
        t.median_us,
        f"min={t.min_us:.1f}us grid={common.DEPTH}x{common.ROWS}x{common.COLS}",
        unit="probe_us",
    )


def run_figs(figs, depth: int, rows: int, cols: int):
    """Imports the fig modules against the reduced grid and runs each,
    yielding one record dict per fig. Import happens HERE so the grid patch
    lands before the fig modules read ROWS/COLS/DEPTH at import time."""
    import benchmarks.common as common
    from repro.obs import maybe_trace, runtime_metadata

    common.DEPTH, common.ROWS, common.COLS = depth, rows, cols
    from benchmarks import (  # noqa: E402  (grid must be patched first)
        fig10_scaling,
        fig11_elementary,
        fig12_temporal,
        fig13_multifield,
        fig14_serving,
        fig15_gradients,
    )

    runners = {
        "fig10": fig10_scaling.run,
        "fig11": fig11_elementary.run,
        "fig12": fig12_temporal.run,
        "fig13": fig13_multifield.run,
        "fig14": fig14_serving.run,
        "fig15": fig15_gradients.run,
    }
    unknown = [f for f in figs if f not in runners]
    if unknown:
        raise SystemExit(f"unknown fig(s) {unknown}; choose from {sorted(runners)}")

    meta = runtime_metadata()
    for fig in figs:
        start_rows = len(common.all_rows())
        t0 = time.perf_counter()
        error = None
        try:
            with maybe_trace(fig):
                runners[fig](fast=True)
        except Exception as e:  # parity asserts / subprocess failures land here
            error = f"{type(e).__name__}: {e}"
        wall = time.perf_counter() - t0
        if error is None:
            emit_probe_overhead_row(common, fig)
        rows_out = common.all_rows()[start_rows:]
        yield {
            "fig": fig,
            "grid": {"depth": depth, "rows": rows, "cols": cols},
            "meta": meta,
            "wall_clock_s": round(wall, 3),
            "parity_ok": error is None and rows_parity_ok(rows_out),
            "wire_ratios": extract_wire_ratios(rows_out),
            "error": error,
            "rows": [
                {
                    "name": r[0],
                    "value": r[1],
                    "derived": r[2],
                    "unit": row_unit(r),
                }
                for r in rows_out
            ],
        }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out-dir", default=".", help="where BENCH_<fig>.json land")
    ap.add_argument(
        "--figs", default=",".join(DEFAULT_FIGS),
        help="comma-separated fig subset (default: %(default)s)",
    )
    ap.add_argument("--depth", type=int, default=8, help="reduced grid depth")
    ap.add_argument("--rows", type=int, default=128, help="reduced grid rows")
    ap.add_argument("--cols", type=int, default=128, help="reduced grid cols")
    args = ap.parse_args(argv)

    from pathlib import Path

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    figs = [f for f in args.figs.split(",") if f]

    failures = []
    for record in run_figs(figs, args.depth, args.rows, args.cols):
        path = out_dir / f"BENCH_{record['fig']}.json"
        path.write_text(json.dumps(record, indent=2) + "\n")
        problems = gate_record(record)
        status = "OK" if not problems else "FAIL"
        ratios = record["wire_ratios"]
        print(
            f"{record['fig']}: {status} rows={len(record['rows'])} "
            f"wire_ratios={ratios} wall={record['wall_clock_s']}s -> {path}"
        )
        for p in problems:
            print(f"  - {p}")
        if problems:
            failures.append(record["fig"])
    if failures:
        print(f"bench smoke FAILED for: {', '.join(failures)}", file=sys.stderr)
        return 1
    print(f"bench smoke ok: {len(figs)} fig(s) recorded in {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
