"""repro.obs.health: on-device field stats, the HealthMonitor policies,
and the train-loop SpikeDetector.

Covers the numerics-health contracts:

  * ``field_stats`` counts NaN/Inf exactly and reports finite-only
    min/max/mean/L2 (on-device 0-d arrays; jit-composable);
  * ``HealthMonitor`` probes on cadence only, steps aside under tracers
    (probed jitted steps stay byte-identical), and enforces the three
    policies — ``warn`` keeps running, ``abort`` raises
    :class:`NumericsError`, ``checkpoint-then-abort`` first hands the LAST
    HEALTHY state to ``checkpoint_fn``;
  * probes report through metrics gauges/counters and flight-recorder
    events when those channels are on, and work identically with both off;
  * ``SpikeDetector`` flags non-finite and above-threshold losses through
    the same channels;
  * the mesh-global stats parity + bit-exactness claims on 8 fake devices
    (subprocess, multidev tier).
"""

import math
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.obs import events, metrics
from repro.obs.health import (
    STAT_KEYS,
    HealthMonitor,
    NumericsError,
    field_stats,
    host_stats,
    is_healthy,
)
from repro.train import SpikeDetector

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with both channels disabled (the default)."""
    prev_reg, prev_rec = metrics.current(), events.current()
    metrics.disable()
    events.disable()
    yield
    metrics.enable(prev_reg) if prev_reg is not None else metrics.disable()
    events.enable(prev_rec) if prev_rec is not None else events.disable()


# --- field_stats ----------------------------------------------------------


def test_field_stats_counts_and_finite_moments():
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    x[0, 0] = np.nan
    x[1, 1] = np.inf
    x[2, 2] = -np.inf
    s = host_stats(field_stats(jnp.asarray(x)))
    assert set(s) == set(STAT_KEYS)
    assert s["size"] == 12 and s["nan_count"] == 1 and s["inf_count"] == 2
    finite = x[np.isfinite(x)]
    assert s["min"] == finite.min() and s["max"] == finite.max()
    np.testing.assert_allclose(s["mean"], finite.mean(), rtol=1e-6)
    np.testing.assert_allclose(s["l2"], np.sqrt((finite**2).sum()), rtol=1e-6)


def test_field_stats_all_nonfinite_keeps_counts_as_the_alarm():
    s = host_stats(field_stats(jnp.full((4,), jnp.nan)))
    assert s["nan_count"] == 4
    assert s["mean"] == 0.0 and s["l2"] == 0.0
    assert s["min"] == math.inf and s["max"] == -math.inf
    assert not is_healthy(s)


def test_field_stats_is_jit_safe():
    x = jnp.linspace(-2.0, 2.0, 64).reshape(8, 8)
    jitted = jax.jit(field_stats)
    got, want = host_stats(jitted(x)), host_stats(field_stats(x))
    assert got == want
    # Output leaves are on-device 0-d arrays, not host floats.
    assert all(hasattr(v, "shape") and v.shape == () for v in field_stats(x).values())


def test_field_stats_counts_are_exact_past_float32_precision():
    """Counts accumulate in int32: a field larger than 2^24 elements (where
    float32 integer arithmetic stops being exact) still reports its size —
    and therefore nan/finite counts — exactly."""
    n = 2**24 + 3  # odd excess: not representable in float32
    s = field_stats(jnp.ones((n,), jnp.int8))
    assert s["size"].dtype == jnp.int32
    assert s["nan_count"].dtype == jnp.int32
    assert int(s["size"]) == n
    assert int(s["nan_count"]) == 0 and int(s["inf_count"]) == 0


def test_is_healthy_max_abs_bound():
    s = host_stats(field_stats(jnp.asarray([1.0, -3.0, 2.0])))
    assert is_healthy(s)
    assert is_healthy(s, max_abs=3.0)
    assert not is_healthy(s, max_abs=2.5)


# --- HealthMonitor --------------------------------------------------------


def test_monitor_validates_construction():
    with pytest.raises(ValueError, match="cadence"):
        HealthMonitor(cadence=0)
    with pytest.raises(ValueError, match="policy"):
        HealthMonitor(policy="explode")
    with pytest.raises(ValueError, match="checkpoint_fn"):
        HealthMonitor(policy="checkpoint-then-abort")


def test_monitor_probes_on_cadence_only():
    m = HealthMonitor(cadence=3)
    x = jnp.ones((4,))
    ran = [step for step in range(10) if m.check(step, x) is not None]
    assert ran == [0, 3, 6, 9]
    assert m.probes == 4
    assert m.check(1, x, force=True) is not None  # force overrides cadence
    assert m.last_healthy[0] == 1


def test_monitor_warn_policy_logs_and_continues():
    logged = []
    m = HealthMonitor(cadence=1, policy="warn", log_fn=logged.append)
    bad = jnp.asarray([1.0, jnp.nan])
    stats = m.check(0, bad)
    assert stats["nan_count"] == 1
    assert m.blowups == 1
    assert logged and "blow-up" in logged[0]
    assert m.last_healthy is None  # an unhealthy probe never becomes "healthy"


def test_monitor_abort_policy_raises_with_context():
    m = HealthMonitor(cadence=1, policy="abort", name="psi")
    m.check(0, jnp.ones((3,)))
    with pytest.raises(NumericsError) as ei:
        m.check(1, jnp.asarray([jnp.inf, 0.0]))
    assert ei.value.step == 1 and ei.value.field == "psi"
    assert ei.value.stats["inf_count"] == 1
    assert m.last_healthy[0] == 0


def test_monitor_checkpoint_then_abort_hands_over_last_healthy_state():
    saved = []
    m = HealthMonitor(
        cadence=2, policy="checkpoint-then-abort",
        checkpoint_fn=lambda step, state: saved.append((step, state)),
    )
    good = jnp.arange(4.0)
    m.check(0, good, state={"params": good})
    m.check(2, good * 2, state={"params": good * 2})
    with pytest.raises(NumericsError):
        m.check(4, jnp.asarray([jnp.nan]))
    assert len(saved) == 1
    step, state = saved[0]
    assert step == 2
    np.testing.assert_array_equal(np.asarray(state["params"]), np.arange(4.0) * 2)


def test_monitor_snapshot_state_survives_donated_buffers():
    """A step fn with donate_argnums deletes the buffers a probe retained;
    snapshot_state=True must host-copy last_healthy at probe time so
    checkpoint_fn still reads live arrays after the donation."""
    saved = []
    m = HealthMonitor(
        cadence=1, policy="checkpoint-then-abort", snapshot_state=True,
        checkpoint_fn=lambda s, st: saved.append((s, st)), log_fn=lambda _: None,
    )
    step = jax.jit(lambda p: p * 2.0, donate_argnums=0)
    p = jnp.arange(4.0)
    m.check(0, 1.0, state=p)
    p = step(p)  # donation deletes the retained step-0 buffers
    with pytest.raises(NumericsError):
        m.check(1, float("nan"), state=p)
    ((s, st),) = saved
    assert s == 0
    np.testing.assert_array_equal(np.asarray(st), np.arange(4.0))


def test_monitor_without_snapshot_retains_state_by_reference():
    m = HealthMonitor(cadence=1)
    x = jnp.arange(3.0)
    m.check(0, 1.0, state=x)
    assert m.last_healthy[1] is x  # default: no host copy


def test_monitor_checkpoint_then_abort_without_healthy_probe_still_aborts():
    saved = []
    m = HealthMonitor(
        cadence=1, policy="checkpoint-then-abort",
        checkpoint_fn=lambda s, st: saved.append(s), log_fn=lambda _: None,
    )
    with pytest.raises(NumericsError):
        m.check(0, jnp.asarray([jnp.nan]))
    assert saved == []  # nothing healthy to checkpoint


def test_monitor_steps_aside_under_tracers():
    m = HealthMonitor(cadence=1, policy="abort")

    @jax.jit
    def step(x):
        # Probing a tracer must be a no-op: no probe, no trace pollution.
        assert m.check(0, x) is None
        return x * 2

    bad = jnp.asarray([jnp.nan, 1.0])
    out = step(bad)  # NaN flows through untouched — the probe stepped aside
    assert np.isnan(np.asarray(out)[0])
    assert m.probes == 0


def test_monitor_wrap_probes_outputs_bit_identically():
    calls = []
    m = HealthMonitor(cadence=2, policy="abort", name="out")
    fn = jax.jit(lambda x: x * 1.5)
    wrapped = m.wrap(fn, name="out")
    x = jnp.arange(8.0)
    for _ in range(4):
        calls.append(np.asarray(wrapped(x)))
    assert m.probes == 2  # auto-steps 0 and 2 on cadence 2
    for got in calls:
        np.testing.assert_array_equal(got, np.asarray(fn(x)))


def test_monitor_reports_through_metrics_and_events():
    with metrics.using() as reg, events.using() as rec:
        m = HealthMonitor(cadence=1, policy="warn", name="psi",
                          log_fn=lambda _: None)
        m.check(0, jnp.ones((4,)))
        m.check(1, jnp.asarray([jnp.nan]))
    snap = reg.snapshot()
    assert snap["counters"]["health.probes"] == 2.0
    assert snap["counters"]["health.blowups"] == 1.0
    assert snap["gauges"]["health.psi.nan_count"] == 1.0  # latest probe
    kinds = [e.kind for e in rec.events()]
    assert kinds.count("health.probe") == 2
    assert kinds.count("health.blowup") == 1
    blowup = rec.events("health.blowup")[0]
    assert blowup.data["step"] == 1 and blowup.data["nan_count"] == 1.0


def test_monitor_works_with_both_channels_off():
    assert metrics.current() is None and events.current() is None
    m = HealthMonitor(cadence=1, policy="abort")
    assert m.check(0, jnp.ones((2,)))["nan_count"] == 0
    with pytest.raises(NumericsError):
        m.check(1, jnp.asarray([jnp.inf]))


# --- SpikeDetector --------------------------------------------------------


def _feed_baseline(det, n=8, loss=1.0, start=0):
    for i in range(n):
        assert not det.record(start + i, loss)


def test_spike_detector_flags_above_factor_median():
    det = SpikeDetector(factor=5.0)
    _feed_baseline(det)
    assert det.record(8, 5.1)   # 5.1 > 5.0 * median(1.0)
    assert not det.record(9, 4.9)
    assert det.spikes == [(8, 5.1)]


def test_spike_detector_nonfinite_is_always_a_spike():
    det = SpikeDetector()
    assert det.record(0, float("nan"))  # even during warmup
    assert det.record(1, float("inf"))
    assert len(det.spikes) == 2
    assert det.losses == []  # non-finite never enters the median history


def test_spike_detector_warmup_never_flags_finite_losses():
    det = SpikeDetector(factor=2.0, warmup=5)
    for i, loss in enumerate([100.0, 1.0, 50.0, 2.0, 30.0]):
        assert not det.record(i, loss)


def test_spike_detector_reports_through_metrics_and_events():
    det = SpikeDetector(factor=5.0)
    with metrics.using() as reg, events.using() as rec:
        _feed_baseline(det)
        det.record(8, 99.0)
    assert reg.counters["train.loss_spikes"] == 1.0
    (ev,) = rec.events("train.loss_spike")
    assert ev.data["step"] == 8 and ev.data["loss"] == 99.0
    assert ev.data["threshold"] == 5.0


def test_spike_detector_silent_with_channels_off():
    det = SpikeDetector(factor=5.0)
    _feed_baseline(det)
    assert det.record(8, 99.0)  # still detects; just nothing to report to
    assert det.spikes == [(8, 99.0)]


# --- mesh-global stats + bit-exactness on 8 fake devices ------------------


@pytest.mark.multidev
def test_health_stats_parity_8dev():
    """Sharded field_stats over a 2x4 mesh equals single-device stats to
    1e-6 on the paper grid, and a conformance cell stays bit-exact under
    HealthMonitor.wrap with metrics + flight recorder live."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["REPRO_METRICS"] = "1"
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "multidev" / "_health_check.py")],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "HEALTH_OK" in proc.stdout
