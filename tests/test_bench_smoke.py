"""Unit tests for scripts/bench_smoke.py (the BENCH_*.json producer/gate).

These are the bench-smoke CI job's pytest-collected smoke checks: they pin
the ratio-extraction and gating logic on synthetic records (the heavy fig
runs themselves execute in the job's bench_smoke.py step, not under
pytest). The module imports bench_smoke WITHOUT triggering any benchmark
import — helpers must stay cheap to load.
"""

import importlib.util
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SCRIPT = REPO / "scripts" / "bench_smoke.py"

spec = importlib.util.spec_from_file_location("bench_smoke", SCRIPT)
bench_smoke = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_smoke)


def _record(**overrides):
    base = {
        "fig": "figX",
        "grid": {"depth": 2, "rows": 16, "cols": 16},
        "wall_clock_s": 0.1,
        "parity_ok": True,
        "wire_ratios": [1.0],
        "error": None,
        "rows": [{"name": "figX/a", "value": 1.0, "derived": "ratio=1.000"}],
    }
    base.update(overrides)
    return base


def test_extract_wire_ratios_parses_rows():
    rows = [
        ("fig10/a", 1.0, "model=42 ratio=1.000 permutes=2"),
        ("fig10/b", 2.0, "no ratio here"),
        ("fig13/c", 3.0, "ratio=0.997 and ratio=1.003"),
    ]
    assert bench_smoke.extract_wire_ratios(rows) == [1.0, 0.997, 1.003]


def test_rows_parity_flag():
    ok = [("a", 1.0, "parity=ok(max|d|=0.0e+00)")]
    bad = ok + [("b", 1.0, "parity=FAIL(max|d|=3.1e-02)")]
    assert bench_smoke.rows_parity_ok(ok)
    assert not bench_smoke.rows_parity_ok(bad)


def test_gate_passes_clean_record():
    assert bench_smoke.gate_record(_record()) == []


def test_gate_fails_ratio_outside_band():
    problems = bench_smoke.gate_record(_record(wire_ratios=[1.0, 1.02]))
    assert any("1.02" in p for p in problems)
    assert bench_smoke.gate_record(_record(wire_ratios=[0.989])) != []
    # Boundary values pass.
    assert bench_smoke.gate_record(_record(wire_ratios=[0.99, 1.01])) == []


def test_gate_fails_parity_and_empty_and_error():
    assert any(
        "parity" in p for p in bench_smoke.gate_record(_record(parity_ok=False))
    )
    assert any(
        "no benchmark rows" in p for p in bench_smoke.gate_record(_record(rows=[]))
    )
    assert any(
        "run failed" in p
        for p in bench_smoke.gate_record(
            _record(error="RuntimeError: boom", parity_ok=False)
        )
    )


def test_cli_rejects_unknown_fig(tmp_path):
    proc = subprocess.run(
        [sys.executable, str(SCRIPT), "--figs", "nope", "--out-dir", str(tmp_path)],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert proc.returncode != 0
    assert "unknown fig" in proc.stdout + proc.stderr


def test_probe_overhead_row_is_informational():
    """The per-fig health-probe cost row: emitted with the probe_us unit,
    which the trajectory gate treats as informational (never gates)."""
    import benchmarks.common as common

    start = len(common.all_rows())
    bench_smoke.emit_probe_overhead_row(common, "figX")
    rows = common.all_rows()[start:]
    assert len(rows) == 1
    name, value, derived, unit = rows[0]
    assert name == "figX/health_probe"
    assert value > 0
    assert unit == "probe_us"
    assert "ratio=" not in derived  # must never feed the wire-ratio gate

    compare_spec = importlib.util.spec_from_file_location(
        "bench_compare", REPO / "scripts" / "bench_compare.py"
    )
    bench_compare = importlib.util.module_from_spec(compare_spec)
    compare_spec.loader.exec_module(bench_compare)
    assert "probe_us" not in bench_compare.GATED_UNITS


def test_record_json_roundtrip(tmp_path):
    """The artifact format is plain JSON — what CI uploads must reload."""
    rec = _record()
    path = tmp_path / "BENCH_figX.json"
    path.write_text(json.dumps(rec, indent=2))
    loaded = json.loads(path.read_text())
    assert loaded == rec
    assert bench_smoke.gate_record(loaded) == []
