"""Unit tests for scripts/check_no_dep_skips.py (the CI skip gate)."""

import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SCRIPT = REPO / "scripts" / "check_no_dep_skips.py"

CLEAN = """<?xml version="1.0" encoding="utf-8"?>
<testsuites><testsuite name="pytest" tests="2" skipped="1">
  <testcase classname="tests.test_a" name="test_ok" time="0.01"/>
  <testcase classname="tests.test_a" name="test_platform" time="0.0">
    <skipped type="pytest.skip" message="needs a TPU backend"/>
  </testcase>
</testsuite></testsuites>
"""

DEP_SKIP = """<?xml version="1.0" encoding="utf-8"?>
<testsuites><testsuite name="pytest" tests="1" skipped="1">
  <testcase classname="tests.test_properties" name="test_prop" time="0.0">
    <skipped type="pytest.skip"
             message="could not import 'hypothesis': No module named 'hypothesis'"/>
  </testcase>
</testsuite></testsuites>
"""

MESH_SKIP = """<?xml version="1.0" encoding="utf-8"?>
<testsuites><testsuite name="pytest" tests="2" skipped="1">
  <testcase classname="tests.test_conformance_matrix" name="test_conformance_mesh[2x4]" time="0.0">
    <skipped type="pytest.skip" message="mesh 2x4 unavailable: needs 8 devices, have 1"/>
  </testcase>
  <testcase classname="tests.test_conformance_matrix" name="test_conformance_mesh[1x8]" time="0.1"/>
</testsuite></testsuites>
"""


def _run(xml: str, tmp_path, *flags):
    report = tmp_path / "report.xml"
    report.write_text(xml)
    return subprocess.run(
        [sys.executable, str(SCRIPT), str(report), *flags],
        capture_output=True,
        text=True,
    )


def _run_many(xmls, tmp_path, *flags):
    reports = []
    for i, xml in enumerate(xmls):
        report = tmp_path / f"report{i}.xml"
        report.write_text(xml)
        reports.append(str(report))
    return subprocess.run(
        [sys.executable, str(SCRIPT), *reports, *flags],
        capture_output=True,
        text=True,
    )


def test_passes_on_non_dependency_skips(tmp_path):
    proc = _run(CLEAN, tmp_path)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_fails_on_missing_dependency_skip(tmp_path):
    proc = _run(DEP_SKIP, tmp_path)
    assert proc.returncode == 1
    assert "hypothesis" in proc.stdout


def test_mesh_skips_pass_by_default_fail_with_flag(tmp_path):
    """Tier-1 legitimately skips 8-device meshes; the multidev-2d job must
    not — --fail-on-mesh-skips flips skipped mesh shapes into failures."""
    proc = _run(MESH_SKIP, tmp_path)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    proc = _run(MESH_SKIP, tmp_path, "--fail-on-mesh-skips")
    assert proc.returncode == 1
    assert "2x4" in proc.stdout


def test_dep_skips_still_fail_with_mesh_flag(tmp_path):
    proc = _run(DEP_SKIP, tmp_path, "--fail-on-mesh-skips")
    assert proc.returncode == 1


def test_usage_error_without_report():
    proc = subprocess.run(
        [sys.executable, str(SCRIPT)], capture_output=True, text=True
    )
    assert proc.returncode == 2


def test_multiple_reports_gated_in_one_call(tmp_path):
    """The bench-smoke job passes every junitxml it produced in ONE call;
    one bad report fails the whole gate and names the offending file."""
    proc = _run_many([CLEAN, CLEAN], tmp_path)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "2 report(s)" in proc.stdout
    proc = _run_many([CLEAN, DEP_SKIP], tmp_path)
    assert proc.returncode == 1
    assert "report1.xml" in proc.stdout and "hypothesis" in proc.stdout
    proc = _run_many([MESH_SKIP, CLEAN], tmp_path, "--fail-on-mesh-skips")
    assert proc.returncode == 1
    assert "report0.xml" in proc.stdout and "2x4" in proc.stdout


def test_unknown_flag_is_usage_error(tmp_path):
    proc = _run(CLEAN, tmp_path, "--nope")
    assert proc.returncode == 2
