"""Pallas hdiff kernel vs pure-jnp oracle: shape/dtype/block sweeps
(interpret=True executes the kernel body on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import hdiff, hdiff_simple
from repro.kernels.hdiff import hdiff_fixed, hdiff_fused
from repro.kernels.hdiff.ref import hdiff_fixed_point_ref


def _rand(shape, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


SHAPES = [
    (1, 8, 8),        # minimum viable
    (2, 16, 12),      # non-square, odd-ish cols
    (3, 32, 64),      # multi-tile rows
    (1, 64, 128),     # TPU-aligned lanes
    (2, 256, 256),    # the paper's plane size
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("limit", [True, False])
def test_hdiff_fused_matches_ref(shape, limit):
    x = jnp.asarray(_rand(shape))
    ref_fn = hdiff if limit else hdiff_simple
    want = ref_fn(x, 0.025)
    got = hdiff_fused(x, 0.025, limit=limit, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_rows", [8, 16, 32, 64])
def test_hdiff_fused_block_sweep(block_rows):
    x = jnp.asarray(_rand((2, 64, 48), seed=3))
    want = hdiff(x, 0.05)
    got = hdiff_fused(x, 0.05, block_rows=block_rows, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_hdiff_fused_bf16():
    x = jnp.asarray(_rand((2, 32, 32), seed=5)).astype(jnp.bfloat16)
    want = hdiff(x.astype(jnp.float32), 0.025).astype(jnp.bfloat16)
    got = hdiff_fused(x, 0.025, interpret=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2
    )


def test_hdiff_fused_indivisible_block_raises():
    x = jnp.asarray(_rand((1, 30, 16)))
    with pytest.raises(ValueError):
        hdiff_fused(x, block_rows=8, interpret=True)


@pytest.mark.parametrize("shape", [(1, 8, 8), (2, 32, 24), (1, 64, 64)])
def test_hdiff_fixed_point_matches_ref(shape):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(-1000, 1000, size=shape, dtype=np.int32))
    want = hdiff_fixed_point_ref(x, 26, 10)
    got = hdiff_fixed(x, coeff_num=26, coeff_shift=10, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hdiff_fixed_point_tracks_float():
    """i32 fixed-point should approximate the f32 path (paper §5.1.1)."""
    rng = np.random.default_rng(11)
    xf = rng.uniform(0, 1, size=(2, 32, 32)).astype(np.float32)
    scale = 2**16
    xq = jnp.asarray((xf * scale).astype(np.int32))
    got_q = np.asarray(hdiff_fixed(xq, coeff_num=26, coeff_shift=10, interpret=True)) / scale
    want = np.asarray(hdiff(jnp.asarray(xf), 26 / 1024))
    np.testing.assert_allclose(got_q, want, rtol=0, atol=2e-3)


def test_hdiff_fused_ad_grad_matches_ref():
    """Kernel-forward/ref-backward custom_vjp: gradient must equal the pure
    reference gradient (needed if the stencil is embedded in a learned model)."""
    from repro.kernels.hdiff import hdiff_fused_ad

    x = jnp.asarray(_rand((1, 12, 12)))
    coeff = jnp.float32(0.025)

    g_kernel = jax.grad(lambda p: jnp.sum(hdiff_fused_ad(p, coeff) ** 2))(x)
    g_ref = jax.grad(lambda p: jnp.sum(hdiff(p, coeff) ** 2))(x)
    assert g_kernel.shape == x.shape
    np.testing.assert_allclose(np.asarray(g_kernel), np.asarray(g_ref), rtol=1e-5, atol=1e-5)
