"""Optimizer correctness: AdamW vs a NumPy reference, Adafactor invariants,
schedule shape, clipping."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import (
    OptimizerConfig,
    clip_by_global_norm,
    make_optimizer,
    schedule_lr,
)


def _tree():
    rng = np.random.default_rng(0)
    return {
        "w": jnp.asarray(rng.standard_normal((8, 4)).astype(np.float32)),
        "b": jnp.asarray(rng.standard_normal((4,)).astype(np.float32)),
    }


def test_adamw_matches_numpy_reference():
    cfg = OptimizerConfig(name="adamw", learning_rate=1e-2, b1=0.9, b2=0.99,
                          eps=1e-8, weight_decay=0.01, warmup_steps=0,
                          total_steps=10_000, min_lr_ratio=1.0)
    params = _tree()
    grads = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    init, update = make_optimizer(cfg)
    state = init(params)

    p_np = {k: np.asarray(v, np.float64) for k, v in params.items()}
    m_np = {k: np.zeros_like(v) for k, v in p_np.items()}
    v_np = {k: np.zeros_like(v) for k, v in p_np.items()}

    new_params, new_state = params, state
    for t in range(1, 4):
        new_params, new_state = update(grads, new_state, new_params)
        lr = 1e-2  # constant (warmup 0, no decay because min_lr_ratio=1)
        for k in p_np:
            g = 0.1
            m_np[k] = 0.9 * m_np[k] + 0.1 * g
            v_np[k] = 0.99 * v_np[k] + 0.01 * g * g
            mh = m_np[k] / (1 - 0.9**t)
            vh = v_np[k] / (1 - 0.99**t)
            p_np[k] = p_np[k] - lr * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * p_np[k])
    for k in p_np:
        np.testing.assert_allclose(np.asarray(new_params[k]), p_np[k], rtol=1e-4, atol=1e-6)


def test_adamw_moment_dtype_bf16():
    cfg = OptimizerConfig(name="adamw", moment_dtype="bfloat16")
    params = _tree()
    init, update = make_optimizer(cfg)
    state = init(params)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(state.mu))
    grads = jax.tree.map(jnp.ones_like, params)
    p2, s2 = update(grads, state, params)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(s2.mu))
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32)))) for l in jax.tree.leaves(p2))


def test_adafactor_memory_is_factored():
    cfg = OptimizerConfig(name="adafactor", factored_threshold=16)
    params = {"big": jnp.zeros((64, 32)), "small": jnp.zeros((3,))}
    init, update = make_optimizer(cfg)
    state = init(params)
    assert state.vr["big"].shape == (64,)
    assert state.vc["big"].shape == (32,)
    assert state.vr["small"].shape == (3,)
    grads = jax.tree.map(jnp.ones_like, params)
    p2, s2 = update(grads, state, params)
    assert p2["big"].shape == (64, 32)
    assert bool(jnp.all(jnp.isfinite(p2["big"])))


def test_adafactor_reduces_loss_on_quadratic():
    cfg = OptimizerConfig(name="adafactor", learning_rate=0.1, weight_decay=0.0,
                          warmup_steps=0, min_lr_ratio=1.0, factored_threshold=4)
    target = jnp.asarray(np.random.default_rng(1).standard_normal((16, 8)).astype(np.float32))
    params = {"w": jnp.zeros((16, 8))}
    init, update = make_optimizer(cfg)
    state = init(params)
    loss = lambda p: jnp.mean((p["w"] - target) ** 2)  # noqa: E731
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = update(g, state, params)
    assert float(loss(params)) < 0.3 * l0


def test_schedule_warmup_and_decay():
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    lr0 = float(schedule_lr(cfg, jnp.int32(0)))
    lr5 = float(schedule_lr(cfg, jnp.int32(5)))
    lr10 = float(schedule_lr(cfg, jnp.int32(10)))
    lr_end = float(schedule_lr(cfg, jnp.int32(110)))
    assert lr0 == 0.0
    assert abs(lr5 - 0.5) < 1e-6
    assert abs(lr10 - 1.0) < 1e-6
    assert abs(lr_end - 0.1) < 1e-3


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, gnorm = clip_by_global_norm(grads, 1.0)
    assert abs(float(gnorm) - 20.0) < 1e-4
    norm_after = float(jnp.linalg.norm(clipped["a"]))
    assert abs(norm_after - 1.0) < 1e-4
    # under the limit -> unchanged
    clipped2, _ = clip_by_global_norm(grads, 100.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), np.asarray(grads["a"]))
