"""Fault-tolerance behaviours: straggler watchdog, preemption checkpoint,
restart-resume determinism."""

import os
import signal
import subprocess
import sys
from pathlib import Path

import pytest

from repro.checkpoint import latest_step
from repro.train import StepWatchdog

REPO = Path(__file__).resolve().parent.parent


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=3.0, warmup=3)
    for i in range(10):
        assert not wd.record(i, 0.1)
    assert wd.record(10, 0.5)          # 5x median -> flagged
    assert not wd.record(11, 0.12)
    assert wd.flagged == [(10, 0.5)]


def test_watchdog_adapts_to_regime_change():
    wd = StepWatchdog(factor=3.0, warmup=3)
    for i in range(60):
        wd.record(i, 0.1 if i < 30 else 0.2)  # slow drift, no flags
    assert all(s >= 30 for s, _ in wd.flagged) or not wd.flagged


PREEMPT_SCRIPT = """
import sys, os, signal
sys.path.insert(0, "{src}")
import dataclasses
from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train import TrainConfig, train

cfg = dataclasses.replace(
    get_smoke_config("qwen1.5-0.5b"), n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=2, head_dim=0, d_ff=64, vocab_size=64, remat=False)
ds = SyntheticLM(DataConfig(seq_len=8, global_batch=4, vocab_size=64))
tc = TrainConfig(steps=10_000, ckpt_every=10_000, ckpt_dir="{ckpt}", log_every=1)

def log(msg):
    print(msg, flush=True)
    if "step 3" in msg:          # simulate the preemption notice mid-run
        os.kill(os.getpid(), signal.SIGTERM)

train(cfg, tc, make_host_mesh(), ds, log_fn=log)
print("EXITED_CLEANLY", flush=True)
"""


@pytest.mark.multidev
def test_sigterm_triggers_checkpoint_and_resume(tmp_path):
    script = PREEMPT_SCRIPT.format(src=str(REPO / "src"), ckpt=str(tmp_path))
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "EXITED_CLEANLY" in proc.stdout
    assert "SIGTERM" in proc.stdout
    step = latest_step(tmp_path)
    assert step is not None and step >= 3
