"""Fault-tolerance behaviours: straggler watchdog, preemption checkpoint,
restart-resume determinism, corrupted-checkpoint detection at load."""

import json
import os
import signal
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train import StepWatchdog

REPO = Path(__file__).resolve().parent.parent


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=3.0, warmup=3)
    for i in range(10):
        assert not wd.record(i, 0.1)
    assert wd.record(10, 0.5)          # 5x median -> flagged
    assert not wd.record(11, 0.12)
    assert wd.flagged == [(10, 0.5)]


def test_watchdog_adapts_to_regime_change():
    wd = StepWatchdog(factor=3.0, warmup=3)
    for i in range(60):
        wd.record(i, 0.1 if i < 30 else 0.2)  # slow drift, no flags
    assert all(s >= 30 for s, _ in wd.flagged) or not wd.flagged


# --- corrupted-checkpoint detection ---------------------------------------


def _save_small(root):
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.ones(4, dtype=np.float32)}
    save_checkpoint(root, 5, tree, {"note": "t"})
    return tree


def _restore(root, tree, **kwargs):
    like = {k: np.zeros_like(v) for k, v in tree.items()}
    return restore_checkpoint(root, 5, like, **kwargs)


def test_restore_rejects_wrong_leaf_shape(tmp_path):
    """A payload whose arrays no longer match what meta.json recorded must
    fail AT LOAD with a ValueError naming the leaf, not deep in re-shard."""
    tree = _save_small(tmp_path)
    d = tmp_path / "step_00000005"
    with np.load(d / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}
    arrays["a0"] = arrays["a0"][:1]  # truncate one leaf: bit rot / partial write
    np.savez(d / "arrays.npz", **arrays)
    with pytest.raises(ValueError, match=r"leaf a0.*corrupt"):
        _restore(tmp_path, tree)


def test_restore_rejects_wrong_leaf_dtype(tmp_path):
    tree = _save_small(tmp_path)
    d = tmp_path / "step_00000005"
    with np.load(d / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}
    arrays["a1"] = arrays["a1"].astype(np.float64)
    np.savez(d / "arrays.npz", **arrays)
    with pytest.raises(ValueError, match=r"leaf a1.*dtype"):
        _restore(tmp_path, tree)


def test_restore_rejects_missing_leaf(tmp_path):
    tree = _save_small(tmp_path)
    d = tmp_path / "step_00000005"
    with np.load(d / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files if k != "a1"}
    np.savez(d / "arrays.npz", **arrays)
    with pytest.raises(ValueError, match=r"missing leaves \['a1'\]"):
        _restore(tmp_path, tree)


def test_restore_verifies_health_snapshot(tmp_path):
    """Same shapes/dtypes but different BYTES: the meta.json numerics-health
    snapshot (NaN/Inf counts + global L2) is recomputed at restore and a
    mismatch fails — silent value corruption can't ride a valid schema."""
    tree = _save_small(tmp_path)
    d = tmp_path / "step_00000005"
    with np.load(d / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}
    arrays["a1"] = arrays["a1"] * 2.0  # values changed, schema intact
    np.savez(d / "arrays.npz", **arrays)
    with pytest.raises(ValueError, match="health snapshot mismatch"):
        _restore(tmp_path, tree)
    # Opt-out path still loads (the caller accepted the risk)...
    state, extra = _restore(tmp_path, tree, verify_health=False)
    assert extra == {"note": "t"}
    # ...and a NaN smuggled into the payload trips the count check too.
    arrays["a1"] = np.ones((2, 3), dtype=np.float32)
    arrays["a1"][0, 0] = np.nan
    np.savez(d / "arrays.npz", **arrays)
    with pytest.raises(ValueError, match="health snapshot mismatch"):
        _restore(tmp_path, tree)


def test_save_records_health_snapshot_and_clean_restore_passes(tmp_path):
    tree = _save_small(tmp_path)
    meta = json.loads((tmp_path / "step_00000005" / "meta.json").read_text())
    h = meta["health"]
    assert h["n_elements"] == 10 and h["nan_count"] == 0 and h["inf_count"] == 0
    want_l2 = float(np.sqrt(sum((v.astype(np.float64) ** 2).sum() for v in tree.values())))
    assert np.isclose(h["l2"], want_l2, rtol=1e-12)
    state, _ = _restore(tmp_path, tree)  # verify_health=True is the default
    np.testing.assert_array_equal(np.asarray(state["w"]), tree["w"])


def test_checkpoint_roundtrip_complex_leaves(tmp_path):
    """Complex leaves go through the health snapshot as |z|^2 (np.square
    with a float64 dtype arg rejects complex input) — save, L2, and the
    verify-on-restore path must all work."""
    c = (np.arange(6) + 1j * np.arange(6, 0, -1)).astype(np.complex64).reshape(2, 3)
    c[0, 0] = np.nan + 0j
    tree = {"c": c, "w": np.ones(3, dtype=np.float32)}
    save_checkpoint(tmp_path, 5, tree)
    meta = json.loads((tmp_path / "step_00000005" / "meta.json").read_text())
    h = meta["health"]
    assert h["nan_count"] == 1
    finite = c[np.isfinite(c)]
    want_l2 = float(np.sqrt((np.abs(finite).astype(np.float64) ** 2).sum() + 3.0))
    assert np.isclose(h["l2"], want_l2, rtol=1e-12)
    state, _ = _restore(tmp_path, tree)  # health verification on
    np.testing.assert_array_equal(np.asarray(state["c"]), c)


# --- train-loop health abort under buffer donation -------------------------


def test_train_health_checkpoint_then_abort_survives_donation(tmp_path):
    """train()'s jit_step donates (params, opt_state), so the last-healthy
    state the loss monitor retains is deleted by the very next step unless
    it was host-snapshotted at probe time. A diverging run (lr=1e9 goes
    NaN at step 2) must still COMMIT the step-1 checkpoint before the
    abort — pre-fix this crashed with 'Array has been deleted'."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.data import DataConfig, SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.obs.health import NumericsError
    from repro.train import TrainConfig, train

    cfg = dataclasses.replace(
        get_smoke_config("qwen1.5-0.5b"), n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=0, d_ff=64, vocab_size=64, remat=False,
        learning_rate=1e9)
    ds = SyntheticLM(DataConfig(seq_len=8, global_batch=4, vocab_size=64))
    tc = TrainConfig(steps=6, ckpt_every=10_000, ckpt_dir=str(tmp_path),
                     log_every=100, health_every=1,
                     health_policy="checkpoint-then-abort")
    with pytest.raises(NumericsError) as ei:
        train(cfg, tc, make_host_mesh(), ds, log_fn=lambda *_: None)
    assert ei.value.step == 2 and ei.value.stats["nan_count"] == 1
    # The step-1 (last healthy) checkpoint was written from live buffers.
    assert latest_step(tmp_path) == 1
    meta = json.loads((tmp_path / "step_00000001" / "meta.json").read_text())
    assert meta["extra"]["reason"] == "health-abort"
    assert meta["health"]["nan_count"] == 0 and meta["health"]["inf_count"] == 0


PREEMPT_SCRIPT = """
import sys, os, signal
sys.path.insert(0, "{src}")
import dataclasses
from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train import TrainConfig, train

cfg = dataclasses.replace(
    get_smoke_config("qwen1.5-0.5b"), n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=2, head_dim=0, d_ff=64, vocab_size=64, remat=False)
ds = SyntheticLM(DataConfig(seq_len=8, global_batch=4, vocab_size=64))
tc = TrainConfig(steps=10_000, ckpt_every=10_000, ckpt_dir="{ckpt}", log_every=1)

def log(msg):
    print(msg, flush=True)
    if "step 3" in msg:          # simulate the preemption notice mid-run
        os.kill(os.getpid(), signal.SIGTERM)

train(cfg, tc, make_host_mesh(), ds, log_fn=log)
print("EXITED_CLEANLY", flush=True)
"""


@pytest.mark.multidev
def test_sigterm_triggers_checkpoint_and_resume(tmp_path):
    script = PREEMPT_SCRIPT.format(src=str(REPO / "src"), ckpt=str(tmp_path))
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "EXITED_CLEANLY" in proc.stdout
    assert "SIGTERM" in proc.stdout
    step = latest_step(tmp_path)
    assert step is not None and step >= 3
