"""Smoke test: the IR-driven weather simulation example on a small grid.

The example re-execs itself with fake host devices, so it runs as a
subprocess (multidev tier, like tests/test_dist.py)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run_example(*extra: str, expect_rc: int = 0) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)  # the script sets its own fake-device count
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / "weather_simulation.py"), *extra],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == expect_rc, (
        f"rc={proc.returncode} (wanted {expect_rc})\n"
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    )
    return proc.stdout


@pytest.mark.multidev
def test_weather_example_smoke_small_grid():
    out = _run_example("--steps", "3", "--devices", "2", "--depth", "4", "--size", "24")
    assert "IR program: hdiff radius=2" in out
    assert "distributed result matches single-device reference" in out


@pytest.mark.multidev
def test_weather_example_smoke_pallas_inner():
    out = _run_example(
        "--steps", "2", "--devices", "4", "--depth", "4", "--size", "32",
        "--inner", "pallas",
    )
    assert "distributed result matches single-device reference" in out


@pytest.mark.multidev
def test_weather_example_health_blowup_drill(tmp_path):
    """The end-to-end blow-up drill: a NaN injected after step 7 must be
    caught at the NEXT cadence-3 probe (step 9), the last healthy probed
    state (step 6) must be a COMMITted checkpoint, and the flight-recorder
    JSONL must hold the failing step's field stats."""
    import json

    from repro.checkpoint import latest_step

    ckpt = tmp_path / "ckpt"
    log = tmp_path / "events.jsonl"
    out = _run_example(
        "--steps", "12", "--devices", "2", "--depth", "4", "--size", "24",
        "--health", "--health-every", "3", "--inject-nan", "7",
        "--health-policy", "checkpoint-then-abort",
        "--ckpt-dir", str(ckpt), "--event-log", str(log),
        expect_rc=3,
    )
    # Halted within one probe cadence of the injection.
    assert "BLOWUP_DETECTED step=9" in out
    assert "nan_count=1" in out
    # checkpoint-then-abort left a COMMITted checkpoint of the last
    # healthy probed state.
    assert latest_step(ckpt) == 6
    assert (ckpt / "step_00000006" / "COMMIT").exists()
    # Flight recorder: JSONL sink has healthy probes plus the blow-up
    # event carrying the failing step's stats.
    lines = [json.loads(l) for l in log.read_text().splitlines()]
    kinds = [e["kind"] for e in lines]
    assert "health.probe" in kinds and "health.blowup" in kinds
    blowup = next(e for e in lines if e["kind"] == "health.blowup")
    assert blowup["data"]["step"] == 9
    assert blowup["data"]["nan_count"] >= 1
    # ... and the crash dump flushed the ring next to the sink.
    crash = json.loads((tmp_path / "events.jsonl.crash.json").read_text())
    assert any(e["kind"] == "health.blowup" for e in crash["events"])
    assert "blow" in crash["reason"] or "NaN" in crash["reason"]


@pytest.mark.multidev
def test_weather_example_health_probes_final_partial_chunk(tmp_path):
    """steps=11 with cadence 3 ends on a partial chunk (done=11 is
    off-cadence): the final boundary must still be probed (force=True) so a
    NaN born in the last chunk cannot escape as 'forecast healthy'."""
    out = _run_example(
        "--steps", "11", "--devices", "2", "--depth", "4", "--size", "24",
        "--health", "--health-every", "3", "--inject-nan", "10",
        "--health-policy", "abort",
        "--event-log", str(tmp_path / "events.jsonl"),
        expect_rc=3,
    )
    assert "BLOWUP_DETECTED step=11" in out


@pytest.mark.multidev
def test_weather_example_health_clean_run(tmp_path):
    """--health on a healthy forecast: exits 0, probes on cadence."""
    out = _run_example(
        "--steps", "9", "--devices", "2", "--depth", "4", "--size", "24",
        "--health", "--health-every", "3", "--health-policy", "warn",
        "--event-log", str(tmp_path / "ok.jsonl"),
    )
    assert "forecast healthy" in out
    assert "probes=4" in out and "blowups=0" in out
