"""Smoke test: the IR-driven weather simulation example on a small grid.

The example re-execs itself with fake host devices, so it runs as a
subprocess (multidev tier, like tests/test_dist.py)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run_example(*extra: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)  # the script sets its own fake-device count
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / "weather_simulation.py"), *extra],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.multidev
def test_weather_example_smoke_small_grid():
    out = _run_example("--steps", "3", "--devices", "2", "--depth", "4", "--size", "24")
    assert "IR program: hdiff radius=2" in out
    assert "distributed result matches single-device reference" in out


@pytest.mark.multidev
def test_weather_example_smoke_pallas_inner():
    out = _run_example(
        "--steps", "2", "--devices", "4", "--depth", "4", "--size", "32",
        "--inner", "pallas",
    )
    assert "distributed result matches single-device reference" in out
