"""Smoke test: the coupled-system weather simulation example on a small grid.

The example evolves the shallow-water {u, v, h} state as ONE multi-output
IR program through lower_sharded. It re-execs itself with fake host
devices, so it runs as a subprocess (multidev tier, like tests/test_dist.py)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run_example(*extra: str, expect_rc: int = 0) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)  # the script sets its own fake-device count
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / "weather_simulation.py"), *extra],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == expect_rc, (
        f"rc={proc.returncode} (wanted {expect_rc})\n"
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    )
    return proc.stdout


@pytest.mark.multidev
def test_weather_example_smoke_small_grid():
    out = _run_example("--steps", "3", "--devices", "2", "--depth", "4", "--size", "24")
    assert "IR program: shallow_water radius=1" in out
    assert "outputs=u+v+h" in out
    assert "distributed result matches single-device reference" in out
    assert "(u, v, h)" in out


@pytest.mark.multidev
def test_weather_example_smoke_pallas_inner():
    out = _run_example(
        "--steps", "2", "--devices", "4", "--depth", "4", "--size", "32",
        "--inner", "pallas",
    )
    assert "distributed result matches single-device reference" in out


@pytest.mark.multidev
def test_weather_example_health_blowup_drill(tmp_path):
    """The end-to-end blow-up drill: a NaN injected into the HEIGHT field
    after step 7 must be caught at the NEXT cadence-3 probe (step 9) by
    h's own monitor (u and v probe healthy — the report names the failing
    equation), the last healthy probed {u, v, h} state (step 6) must be a
    COMMITted checkpoint, and the flight-recorder JSONL must hold the
    failing step's per-field stats."""
    import json

    import numpy as np

    from repro.checkpoint import latest_step, restore_checkpoint

    ckpt = tmp_path / "ckpt"
    log = tmp_path / "events.jsonl"
    out = _run_example(
        "--steps", "12", "--devices", "2", "--depth", "4", "--size", "24",
        "--health", "--health-every", "3", "--inject-nan", "7",
        "--health-policy", "checkpoint-then-abort",
        "--ckpt-dir", str(ckpt), "--event-log", str(log),
        expect_rc=3,
    )
    # Halted within one probe cadence of the injection, naming the field.
    assert "BLOWUP_DETECTED step=9 field=h" in out
    assert "nan_count=1" in out
    # checkpoint-then-abort left a COMMITted checkpoint of the last
    # healthy probed FULL state dict.
    assert latest_step(ckpt) == 6
    assert (ckpt / "step_00000006" / "COMMIT").exists()
    like = {f: np.zeros((4, 24, 24), np.float32) for f in ("u", "v", "h")}
    state, extra = restore_checkpoint(ckpt, 6, like)
    assert set(state) == {"u", "v", "h"}
    assert extra["fields"] == ["u", "v", "h"]
    assert all(np.isfinite(a).all() for a in state.values())
    # Flight recorder: JSONL sink has per-field healthy probes plus the
    # blow-up event carrying the failing step's stats.
    lines = [json.loads(l) for l in log.read_text().splitlines()]
    kinds = [e["kind"] for e in lines]
    assert "health.probe" in kinds and "health.blowup" in kinds
    probed_fields = {e["data"]["field"] for e in lines if e["kind"] == "health.probe"}
    assert probed_fields == {"u", "v", "h"}
    blowup = next(e for e in lines if e["kind"] == "health.blowup")
    assert blowup["data"]["step"] == 9
    assert blowup["data"]["field"] == "h"
    assert blowup["data"]["nan_count"] >= 1
    # ... and the crash dump flushed the ring next to the sink.
    crash = json.loads((tmp_path / "events.jsonl.crash.json").read_text())
    assert any(e["kind"] == "health.blowup" for e in crash["events"])
    assert "blow" in crash["reason"] or "NaN" in crash["reason"]


@pytest.mark.multidev
def test_weather_example_health_probes_final_partial_chunk(tmp_path):
    """steps=11 with cadence 3 ends on a partial chunk (done=11 is
    off-cadence): the final boundary must still be probed (force=True) so a
    NaN born in the last chunk cannot escape as 'forecast healthy'."""
    out = _run_example(
        "--steps", "11", "--devices", "2", "--depth", "4", "--size", "24",
        "--health", "--health-every", "3", "--inject-nan", "10",
        "--health-policy", "abort",
        "--event-log", str(tmp_path / "events.jsonl"),
        expect_rc=3,
    )
    assert "BLOWUP_DETECTED step=11 field=h" in out


@pytest.mark.multidev
def test_weather_example_health_clean_run(tmp_path):
    """--health on a healthy forecast: exits 0, probes on cadence for every
    output field (steps 0/3/6/9 x {u, v, h} = 12 probes)."""
    out = _run_example(
        "--steps", "9", "--devices", "2", "--depth", "4", "--size", "24",
        "--health", "--health-every", "3", "--health-policy", "warn",
        "--event-log", str(tmp_path / "ok.jsonl"),
    )
    assert "forecast healthy" in out
    assert "probes=12" in out and "blowups=0" in out
    assert "fields=u+v+h" in out
