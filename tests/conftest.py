

def pytest_configure(config):
    config.addinivalue_line("markers", "multidev: spawns a subprocess with 8 fake devices")
    config.addinivalue_line("markers", "slow: long-running integration test")
