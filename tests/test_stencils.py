"""Elementary stencil correctness vs NumPy loop oracles (§3.5 suite)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    jacobi1d,
    jacobi2d_3pt,
    jacobi2d_5pt,
    jacobi2d_9pt,
    lap_field,
    laplacian,
    seidel2d_exact,
    seidel2d_sweep,
)


@pytest.fixture(scope="module")
def grid2d():
    rng = np.random.default_rng(42)
    return rng.standard_normal((9, 11)).astype(np.float32)


def test_jacobi1d(grid2d):
    x = grid2d[0]
    want = x.copy()
    for i in range(1, len(x) - 1):
        want[i] = (x[i - 1] + x[i] + x[i + 1]) / 3.0
    np.testing.assert_allclose(np.asarray(jacobi1d(jnp.asarray(x))), want, rtol=1e-6)


def test_jacobi1d_batched(grid2d):
    out = np.asarray(jacobi1d(jnp.asarray(grid2d)))
    for r in range(grid2d.shape[0]):
        np.testing.assert_allclose(out[r], np.asarray(jacobi1d(jnp.asarray(grid2d[r]))), rtol=0)


def test_jacobi2d_3pt(grid2d):
    want = grid2d.copy()
    for i in range(1, grid2d.shape[0] - 1):
        for j in range(1, grid2d.shape[1] - 1):
            want[i, j] = (grid2d[i - 1, j] + grid2d[i, j] + grid2d[i + 1, j]) / 3.0
    np.testing.assert_allclose(np.asarray(jacobi2d_3pt(jnp.asarray(grid2d))), want, rtol=1e-5)


def test_laplacian(grid2d):
    want = grid2d.copy()
    for i in range(1, grid2d.shape[0] - 1):
        for j in range(1, grid2d.shape[1] - 1):
            want[i, j] = (
                4 * grid2d[i, j]
                - grid2d[i + 1, j]
                - grid2d[i - 1, j]
                - grid2d[i, j + 1]
                - grid2d[i, j - 1]
            )
    np.testing.assert_allclose(np.asarray(laplacian(jnp.asarray(grid2d))), want, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(lap_field(jnp.asarray(grid2d))), want[1:-1, 1:-1], rtol=1e-5
    )


def test_jacobi2d_5pt(grid2d):
    want = grid2d.copy()
    for i in range(1, grid2d.shape[0] - 1):
        for j in range(1, grid2d.shape[1] - 1):
            want[i, j] = 0.2 * (
                grid2d[i, j] + grid2d[i + 1, j] + grid2d[i - 1, j] + grid2d[i, j + 1] + grid2d[i, j - 1]
            )
    np.testing.assert_allclose(np.asarray(jacobi2d_5pt(jnp.asarray(grid2d))), want, rtol=1e-5)


def test_jacobi2d_9pt(grid2d):
    want = grid2d.copy()
    for i in range(1, grid2d.shape[0] - 1):
        for j in range(1, grid2d.shape[1] - 1):
            want[i, j] = grid2d[i - 1 : i + 2, j - 1 : j + 2].sum() / 9.0
    np.testing.assert_allclose(np.asarray(jacobi2d_9pt(jnp.asarray(grid2d))), want, rtol=1e-5)


def test_seidel2d_exact(grid2d):
    want = grid2d.astype(np.float64).copy()
    for i in range(1, grid2d.shape[0] - 1):
        for j in range(1, grid2d.shape[1] - 1):
            want[i, j] = want[i - 1 : i + 2, j - 1 : j + 2].sum() / 9.0
    got = np.asarray(seidel2d_exact(jnp.asarray(grid2d)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_seidel_sweep_is_9pt(grid2d):
    np.testing.assert_allclose(
        np.asarray(seidel2d_sweep(jnp.asarray(grid2d))),
        np.asarray(jacobi2d_9pt(jnp.asarray(grid2d))),
        rtol=0,
    )
