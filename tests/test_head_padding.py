"""TP head-padding (arctic 56->64): padded model must be EXACTLY the
unpadded model — dead heads contribute nothing and receive zero grads."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import layers as L


def test_pad_heads_exact_and_dead():
    cfg0 = dataclasses.replace(
        get_smoke_config("arctic-480b"), compute_dtype="float32",
        n_heads=6, n_kv_heads=2, head_dim=16, pad_heads_to=0,
    )
    cfg1 = dataclasses.replace(cfg0, pad_heads_to=8)
    p1, _ = L.init_attention(cfg1, jax.random.PRNGKey(0))
    # group-major layout: kv0 -> heads [0,1,2,(3 dead)], kv1 -> [4,5,6,(7 dead)]
    real = jnp.asarray([0, 1, 2, 4, 5, 6])
    p0 = dict(p1)
    p0["wq"] = p1["wq"][:, real]
    p0["wo"] = p1["wo"][real]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg0.d_model), jnp.float32)
    y1, _ = L.attention_apply(cfg1, p1, x)
    y0, _ = L.attention_apply(cfg0, p0, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=1e-5, atol=1e-6)

    g = jax.grad(lambda p: jnp.sum(L.attention_apply(cfg1, p, x)[0] ** 2))(p1)
    dead = jnp.asarray([3, 7])
    assert float(jnp.abs(g["wq"][:, dead]).max()) == 0.0
    assert float(jnp.abs(g["wo"][dead]).max()) == 0.0


def test_arctic_config_pads():
    cfg = get_config("arctic-480b")
    assert cfg.pad_heads_to == 64
    assert cfg.n_heads == 56  # the ARCHITECTURE stays 56 heads
