"""RG-LRU scan kernel vs associative-scan oracle, shape sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.rglru import rglru_scan, rglru_scan_ref


def _inputs(b=2, t=16, w=32, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0.5, 0.999, (b, t, w)).astype(np.float32))
    bb = jnp.asarray(rng.standard_normal((b, t, w)).astype(np.float32))
    h0 = jnp.asarray(rng.standard_normal((b, w)).astype(np.float32))
    return a, bb, h0


@pytest.mark.parametrize("shape", [(1, 4, 8), (2, 16, 32), (3, 64, 128), (1, 128, 64)])
def test_kernel_matches_ref(shape):
    a, b, h0 = _inputs(*shape, seed=shape[1])
    h_ref, last_ref = rglru_scan_ref(a, b, h0)
    h, last = rglru_scan(a, b, h0, block_w=min(32, shape[2]), interpret=True)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(last), np.asarray(last_ref), rtol=1e-5, atol=1e-5)


def test_ref_matches_naive_loop():
    a, b, h0 = _inputs(1, 8, 4)
    h_ref, _ = rglru_scan_ref(a, b, h0)
    h = np.asarray(h0[0], np.float64).copy()
    for t in range(8):
        h = np.asarray(a[0, t]) * h + np.asarray(b[0, t])
        np.testing.assert_allclose(np.asarray(h_ref[0, t]), h, rtol=1e-5)


def test_block_sweep():
    a, b, h0 = _inputs(2, 32, 64, seed=9)
    h_ref, _ = rglru_scan_ref(a, b, h0)
    for bw in (8, 16, 64):
        h, _ = rglru_scan(a, b, h0, block_w=bw, interpret=True)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-5, atol=1e-5)
