"""The serving compile cache: LRU properties (hypothesis).

The deterministic cache tests — the real-builder zero-retrace proof and
the ``cache.{hits,misses,evictions}`` registry trio — live in
tests/test_serve_forecast.py so they run even without the dev extras; this
module is the property side (and so skips wholesale without hypothesis,
which the CI dep-skip gate turns into a failure where extras are
installed).

Property suite (stub builder — no jax, so thousands of driven sequences
are cheap): for ARBITRARY request sequences over a bounded key universe,

  * hit/miss accounting is exact — a request is a hit iff its key is live
    in the cache at request time (model: an ordered dict replayed in
    Python);
  * eviction is LRU — the evicted key is always the least recently USED
    (get counts as use), and live keys never exceed capacity;
  * distinct fingerprints never collide — programs differing structurally
    get distinct entries no matter the request order;
  * fingerprint blindness to display names — structurally-equal programs
    with different names SHARE an entry (second submit is a hit);
  * under a no-eviction capacity, a hit NEVER invokes the builder — the
    stub-level statement of the zero-retrace invariant (builder calls ==
    misses, for any sequence).
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.ir import StencilProgram, affine  # noqa: E402
from repro.obs import metrics  # noqa: E402
from repro.serve.cache import CompileCache, compile_key  # noqa: E402


def _program(weight: float, name: str = "p"):
    """A tiny 2-D program whose fingerprint varies with ``weight`` (a tap
    weight is structural) but NOT with ``name`` (display names are blind)."""
    return StencilProgram(
        name, ["x"], [affine("out", "x", {(0, 0): weight, (1, 0): 1.0})]
    )


# A bounded universe of distinct request shapes: 3 structurally-distinct
# programs x 2 grids x 2 backends x 2 batch sizes.
PROGRAMS = [_program(float(w)) for w in (1.0, 2.0, 3.0)]
GRIDS = [(2, 16, 16), (2, 24, 24)]
BACKENDS = ["reference", "pallas"]
BATCHES = [None, 4]

requests = st.lists(
    st.tuples(
        st.integers(0, len(PROGRAMS) - 1),
        st.integers(0, len(GRIDS) - 1),
        st.integers(0, len(BACKENDS) - 1),
        st.integers(0, len(BATCHES) - 1),
    ),
    min_size=1,
    max_size=60,
)


def _stub_builder(program, key, **kw):
    def fn(x):
        return x

    return fn


def _replay(seq, capacity):
    """Drive a CompileCache and an independent Python LRU model side by
    side; returns (cache, model_hits, model_misses, model_evictions,
    model_keys_in_lru_order)."""
    cache = CompileCache(capacity, builder=_stub_builder, trace_probe=False)
    model: list = []  # keys, least recently used first
    hits = misses = evictions = 0
    for pi, gi, bi, ni in seq:
        key = compile_key(
            PROGRAMS[pi], grid=GRIDS[gi], backend=BACKENDS[bi], batch=BATCHES[ni]
        )
        cache.get(
            PROGRAMS[pi], grid=GRIDS[gi], backend=BACKENDS[bi], batch=BATCHES[ni]
        )
        if key in model:
            hits += 1
            model.remove(key)
            model.append(key)
        else:
            misses += 1
            model.append(key)
            if len(model) > capacity:
                model.pop(0)
                evictions += 1
    return cache, hits, misses, evictions, model


@given(seq=requests, capacity=st.integers(1, 6))
@settings(max_examples=200, deadline=None)
def test_lru_accounting_matches_model(seq, capacity):
    cache, hits, misses, evictions, model = _replay(seq, capacity)
    assert cache.stats() == {
        "hits": hits,
        "misses": misses,
        "evictions": evictions,
        "size": len(model),
        "capacity": capacity,
    }
    # Eviction order is LRU: the live keys, least-recent first, match the
    # model exactly — not just as a set.
    assert cache.keys() == model
    assert len(cache) <= capacity
    total = hits + misses
    assert cache.hit_rate == (hits / total if total else 0.0)


@given(seq=requests)
@settings(max_examples=100, deadline=None)
def test_distinct_fingerprints_never_collide(seq):
    """With capacity >= the key universe nothing evicts, so every distinct
    key must have its own live entry and repeat requests must all hit."""
    cache, hits, misses, evictions, model = _replay(seq, capacity=64)
    distinct = {
        compile_key(
            PROGRAMS[pi], grid=GRIDS[gi], backend=BACKENDS[bi], batch=BATCHES[ni]
        )
        for pi, gi, bi, ni in seq
    }
    assert evictions == 0
    assert misses == len(distinct)
    assert hits == len(seq) - len(distinct)
    assert set(cache.keys()) == distinct


@given(w=st.sampled_from([0.5, 1.0, 2.0]))
@settings(max_examples=10, deadline=None)
def test_equal_programs_different_names_share_entry(w):
    cache = CompileCache(4, builder=_stub_builder, trace_probe=False)
    a = _program(w, name="tenant_a_diffusion")
    b = _program(w, name="tenant_b_diffusion")
    cache.get(a, grid=(2, 16, 16))
    cache.get(b, grid=(2, 16, 16))
    assert cache.stats()["hits"] == 1 and cache.stats()["misses"] == 1
    assert len(cache) == 1


@given(seq=requests)
@settings(max_examples=100, deadline=None)
def test_hits_never_invoke_builder(seq):
    """Builder invocations == misses, for ANY request sequence — the
    stub-level zero-retrace statement (the jax-level proof, per-entry trace
    probes against the real builder, is in test_serve_forecast.py)."""
    calls = []

    def counting_builder(program, key, **kw):
        calls.append(key)
        return lambda x: x

    cache = CompileCache(64, builder=counting_builder, trace_probe=False)
    for pi, gi, bi, ni in seq:
        cache.get(
            PROGRAMS[pi], grid=GRIDS[gi], backend=BACKENDS[bi], batch=BATCHES[ni]
        )
    assert len(calls) == cache.stats()["misses"]
    # ...and each miss built a distinct key (capacity 64 never evicts here).
    assert len(set(calls)) == len(calls)


@given(seq=requests, capacity=st.integers(1, 6))
@settings(max_examples=100, deadline=None)
def test_counter_trio_matches_registry(seq, capacity):
    """cache.{hits,misses,evictions} in the repro.obs registry mirror the
    cache's own accounting exactly, for any sequence."""
    with metrics.using() as reg:
        cache, hits, misses, evictions, _model = _replay(seq, capacity)
        snap = reg.snapshot()["counters"]
    assert snap.get("cache.hits", 0) == hits
    assert snap["cache.misses"] == misses
    assert snap.get("cache.evictions", 0) == evictions
    assert (cache.hits, cache.misses, cache.evictions) == (hits, misses, evictions)


def test_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        CompileCache(0)
