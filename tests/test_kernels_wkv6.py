"""WKV-6 kernel + chunked form vs sequential oracle, shape sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.wkv6 import wkv6, wkv6_chunked_ref, wkv6_ref


def _inputs(b=2, t=32, h=2, n=16, seed=0):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.standard_normal((b, t, h, n)).astype(np.float32)) * 0.5
    k = jnp.asarray(rng.standard_normal((b, t, h, n)).astype(np.float32)) * 0.5
    v = jnp.asarray(rng.standard_normal((b, t, h, n)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.6, 0.999, (b, t, h, n)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((h, n)).astype(np.float32)) * 0.3
    s0 = jnp.asarray(rng.standard_normal((b, h, n, n)).astype(np.float32)) * 0.1
    return r, k, v, w, u, s0


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_chunked_ref_matches_sequential(chunk):
    r, k, v, w, u, s0 = _inputs()
    y_seq, s_seq = wkv6_ref(r, k, v, w, u, s0)
    y_ch, s_ch = wkv6_chunked_ref(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_ch), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_ch), np.asarray(s_seq), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [(1, 16, 1, 8), (2, 64, 3, 16), (1, 128, 2, 32)])
@pytest.mark.parametrize("chunk", [8, 16])
def test_kernel_matches_oracle(shape, chunk):
    b, t, h, n = shape
    r, k, v, w, u, s0 = _inputs(b, t, h, n, seed=shape[1])
    y_ref, s_ref = wkv6_ref(r, k, v, w, u, s0)
    y, s = wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=3e-4, atol=3e-4)


def test_kernel_zero_state_default():
    r, k, v, w, u, _ = _inputs(1, 16, 1, 8)
    y_ref, _ = wkv6_ref(r, k, v, w, u, None)
    y, _ = wkv6(r, k, v, w, u, None, chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=3e-4, atol=3e-4)
