"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step on CPU, asserting output shapes and finite values.

Plus the strongest cache-correctness check we have: token-by-token decode
must reproduce teacher-forced logits for every decodable family (full attn,
sliding window, hybrid RG-LRU+local, RWKV-6, MoE).
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build_cache, build_lm, lm_decode, lm_forward, lm_loss, lm_prefill

B, S = 2, 16


def _make_batch(cfg, key):
    kt, km = jax.random.split(key)
    if cfg.frontend == "audio":
        tokens = jax.random.normal(kt, (B, S, cfg.d_model), jnp.float32)
    else:
        tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(km, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.frontend == "vision":
        batch["memory"] = jax.random.normal(km, (B, cfg.num_media_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    params, axes = build_lm(cfg, jax.random.PRNGKey(0))
    # axes pytree must mirror params exactly
    jax.tree.map(lambda p, a: None, params,
                 jax.tree.map(lambda x: 0, axes,
                              is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)))

    batch = _make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = lm_forward(cfg, params, batch["tokens"], memory=batch.get("memory"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    (total, metrics), grads = jax.value_and_grad(
        lambda p: lm_loss(cfg, p, batch), has_aux=True
    )(params)
    assert bool(jnp.isfinite(total))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize(
    "arch",
    [
        "starcoder2-3b",        # sliding window
        "qwen1.5-0.5b",         # full attn + qkv bias
        "qwen3-moe-235b-a22b",  # MoE
        "recurrentgemma-2b",    # hybrid RG-LRU + local attn
        "rwkv6-3b",             # pure recurrent
        "glm4-9b",              # GQA kv=2
        "llama-3.2-vision-90b", # cross-attn
    ],
)
def test_decode_matches_teacher_forcing(arch):
    """Prefill(t[:p]) then step-by-step decode of t[p:] must produce the
    same logits as one teacher-forced forward pass."""
    cfg = get_smoke_config(arch)
    # f32 for a tight comparison; capacity_factor high enough to be DROPLESS
    # (capacity-based MoE drops tokens at train shapes but not at decode
    # shapes, which is a real train/serve skew, not a cache bug).
    cfg = dataclasses.replace(cfg, compute_dtype="float32", capacity_factor=64.0)
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    memory = None
    if cfg.frontend == "vision":
        memory = jax.random.normal(key, (B, cfg.num_media_tokens, cfg.d_model), jnp.float32)

    full_logits, _ = lm_forward(cfg, params, tokens, memory=memory)  # (B, S, V)

    p = S // 2
    cache, _ = build_cache(cfg, B, S)
    last, cache = lm_prefill(cfg, params, tokens[:, :p], cache, memory=memory)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, p - 1]), rtol=2e-4, atol=2e-4
    )
    for t in range(p, S):
        step_logits, cache = lm_decode(cfg, params, tokens[:, t], cache, jnp.int32(t), memory=memory)
        np.testing.assert_allclose(
            np.asarray(step_logits),
            np.asarray(full_logits[:, t]),
            rtol=2e-4,
            atol=2e-4,
            err_msg=f"{arch} decode step {t}",
        )


def test_window_attention_masks_history():
    """With window=4, token t must be independent of tokens < t-3."""
    cfg = get_smoke_config("starcoder2-3b")
    cfg = dataclasses.replace(cfg, window=4, compute_dtype="float32")
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    t2 = t1.at[:, 0:4].set((t1[:, 0:4] + 7) % cfg.vocab_size)  # perturb far past
    l1, _ = lm_forward(cfg, params, t1)
    l2, _ = lm_forward(cfg, params, t2)
    np.testing.assert_allclose(
        np.asarray(l1[:, -1]), np.asarray(l2[:, -1]), rtol=1e-5, atol=1e-5
    )
    # ...but the near past must matter:
    t3 = t1.at[:, 9].set((t1[:, 9] + 7) % cfg.vocab_size)
    l3, _ = lm_forward(cfg, params, t3)
    assert np.abs(np.asarray(l3[:, -1]) - np.asarray(l1[:, -1])).max() > 1e-6


def test_encoder_is_bidirectional():
    cfg = get_smoke_config("hubert-xlarge")
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model), jnp.float32)
    l1, _ = lm_forward(cfg, params, x)
    # Perturb ONE channel of the LAST frame (a uniform shift of all channels
    # would sit in LayerNorm's null space and legitimately not propagate).
    x2 = x.at[:, -1, 0].add(1.0)
    l2, _ = lm_forward(cfg, params, x2)
    # first-position logits must change (future influences past = bidirectional)
    assert np.abs(np.asarray(l2[:, 0]) - np.asarray(l1[:, 0])).max() > 1e-6


def test_moe_aux_loss_positive_and_routing_varies():
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    batch = _make_batch(cfg, jax.random.PRNGKey(3))
    _, metrics = lm_loss(cfg, params, batch)
    assert float(metrics["aux_loss"]) > 0
