"""Batched-lowering (ensemble) conformance: tests/conformance.py's batched
cells plus the input-validation contract.

The two-sided parity claim under test (see ``assert_batched_case``): for
every (program, backend, k, mesh) batched cell, member i of ONE vmapped
application over the member axis is (a) BIT-identical to an independent
application of the same lowered backend on member i's fields, and (b)
1e-6-close to the reference oracle. (a) is the strong claim — vmap must
not change what any member computes, on any backend, or ensemble serving
silently diverges from single-forecast serving.

Single-device cells (1x1 reference/pallas) run in-process; the sharded
cells run the 2x4 mesh in an 8-fake-device subprocess
(tests/multidev/_batched_check.py), keeping the main process at 1 device.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from conformance import (
    BATCHED_KS,
    BATCHED_MESHES,
    BATCHED_PROGRAMS,
    assert_batched_case,
    make_batched_fields,
    member_slice,
    mesh_id,
    to_host,
)
from repro.ir import BATCHED_BACKENDS, hdiff_program, lower_batched, shallow_water_program
from repro.obs import events, metrics

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _metrics_on():
    # Batched cells run fully instrumented, same contract as the unbatched
    # matrix: observability must never perturb the computation.
    with metrics.using(), events.using():
        yield


SINGLE_DEV_CELLS = [
    pytest.param(name, backend, k, id=f"{name}-{backend}-k{k}")
    for name in BATCHED_PROGRAMS
    for backend in ("reference", "pallas")
    for k in BATCHED_KS
]


@pytest.mark.parametrize("name,backend,k", SINGLE_DEV_CELLS)
def test_batched_conformance_1x1(name, backend, k):
    assert_batched_case(name, backend, k, (1, 1))


def test_batched_member_slice_shapes():
    """The batched result carries (members, *grid) per output field and
    slices back to per-member grids."""
    fields = make_batched_fields("shallow_water", members=2, grid=(2, 16, 16))
    out = to_host(lower_batched(shallow_water_program())(fields))
    assert set(out) == set(shallow_water_program().outputs)
    for f, a in out.items():
        assert a.shape == (2, 2, 16, 16), (f, a.shape)
    m0 = member_slice(out, 0)
    assert all(v.shape == (2, 16, 16) for v in m0.values())


def test_batched_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown batched backend"):
        lower_batched(hdiff_program(), backend="staged")


def test_batched_rejects_mesh_on_single_device_backend():
    with pytest.raises(ValueError, match="single-device"):
        lower_batched(hdiff_program(), backend="pallas", mesh_shape=(1, 1))


def test_batched_sharded_requires_mesh():
    with pytest.raises(ValueError, match="mesh_shape"):
        lower_batched(hdiff_program(), backend="sharded-reference")


def test_batched_rejects_unbatched_input():
    fn = lower_batched(hdiff_program())
    with pytest.raises(ValueError, match="members, depth, rows, cols"):
        fn(jnp.zeros((2, 16, 16), jnp.float32))


def test_batched_rejects_missing_field():
    fn = lower_batched(shallow_water_program())
    with pytest.raises(ValueError, match="missing input"):
        fn({"u": jnp.zeros((2, 2, 16, 16), jnp.float32)})


def test_batched_rejects_ragged_members():
    fn = lower_batched(shallow_water_program())
    fields = make_batched_fields("shallow_water", members=2, grid=(2, 16, 16))
    fields["h"] = jnp.zeros((3, 2, 16, 16), jnp.float32)
    with pytest.raises(ValueError, match="share one"):
        fn(fields)


def test_batched_backends_exports():
    assert set(BATCHED_BACKENDS) == {
        "reference", "pallas", "sharded-reference", "sharded-pallas",
    }


def test_batched_single_member_matches_unbatched():
    """N=1 batching is exactly the unbatched lowering with a length-1
    leading axis — the degenerate case the serving engine hits whenever a
    request has no compatible batchmates."""
    from conformance import GRID, SEED, build, make_fields

    got = to_host(
        lower_batched(hdiff_program())(make_batched_fields("hdiff", members=1))
    )
    want = to_host(build(hdiff_program(), "reference", (1, 1))(
        make_fields("hdiff", GRID, SEED)
    ))
    np.testing.assert_array_equal(member_slice(got, 0), want)


MULTIDEV_BATCHED_MESHES = [m for m in BATCHED_MESHES if m != (1, 1)]


@pytest.mark.multidev
@pytest.mark.parametrize(
    "mesh", [pytest.param(m, id=mesh_id(m)) for m in MULTIDEV_BATCHED_MESHES]
)
def test_batched_conformance_mesh(mesh, tmp_path):
    n_dev = mesh[0] * mesh[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["REPRO_METRICS"] = "1"
    event_log = tmp_path / "events.jsonl"
    env["REPRO_EVENT_LOG"] = str(event_log)
    proc = subprocess.run(
        [
            sys.executable,
            str(REPO / "tests" / "multidev" / "_batched_check.py"),
            "--mesh",
            mesh_id(mesh),
        ],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    if "DEVICES_UNAVAILABLE" in proc.stdout:
        pytest.skip(f"mesh {mesh_id(mesh)} unavailable: {proc.stdout.strip()}")
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL_OK" in proc.stdout
    assert event_log.exists() and event_log.stat().st_size > 0
