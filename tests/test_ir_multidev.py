"""8-fake-device runs of the IR sharded lowering (subprocess, like
tests/test_dist.py — the main pytest process must keep seeing 1 device)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run_subprocess(script: str, n_dev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "multidev" / script)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.multidev
def test_ir_sharded_multidevice():
    out = _run_subprocess("_ir_check.py")
    assert "ALL_OK" in out
    assert "paper-grid sharded ok" in out
    for k in (2, 3):
        assert f"temporal depth-x-rows k={k} ok" in out
    assert "fine-mesh raise ok" in out
    assert "fine-mesh remedy (shard cols) ok" in out
    # ISSUE 4 acceptance: paper grid on the 2x4 rows x cols mesh, k in
    # {1, 2, 3}, both inners, overlap bit-match.
    for k in (1, 2, 3):
        assert f"paper-grid 2x4 k={k} ok (both inners, overlap bit-match)" in out
