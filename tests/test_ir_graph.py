"""Graph analysis unit tests: inferred halo, footprints, derived op counts.

The acceptance anchor: the hdiff program's graph-derived spec must reproduce
the paper's §3.1 accounting (26 MACs-equivalent, 20 other ops, 13 reads,
radius 2) with no hand-written per-kernel constants anywhere in the chain.
"""

import pytest

from repro.core import ELEMENTARY_SPECS, HALO, HDIFF_SPEC, aie_stencil_cycles
from repro.ir import (
    ELEMENTARY_PROGRAMS,
    OpCost,
    Read,
    StencilOp,
    StencilProgram,
    affine,
    hdiff_program,
    scaled_residual,
)


def _star_taps(radius, weight=1.0):
    taps = {(0, 0): weight}
    for k in range(1, radius + 1):
        taps.update({(k, 0): weight, (-k, 0): weight, (0, k): weight, (0, -k): weight})
    return taps


# --- hdiff: the paper's numbers, derived --------------------------------------


def test_hdiff_spec_reproduces_paper_accounting():
    spec = hdiff_program().spec()
    assert spec.macs == 26         # 5 Laplacians x 5 MACs + 1 coeff MAC (Eq. 5-7)
    assert spec.other_ops == 20    # 4 fluxes x 4 ops + 4 output adds (Eq. 6)
    assert spec.reads == 13        # composed star-of-star footprint (Eq. 8-9)
    assert spec.radius == 2        # flux-of-Laplacian halo
    assert spec.flops == 2 * 26 + 20


def test_core_hdiff_spec_is_graph_derived():
    spec = hdiff_program().spec()
    assert (HDIFF_SPEC.macs, HDIFF_SPEC.other_ops, HDIFF_SPEC.reads, HDIFF_SPEC.radius) == (
        spec.macs,
        spec.other_ops,
        spec.reads,
        spec.radius,
    )
    assert HALO == spec.radius == 2


def test_hdiff_footprint_is_13_point_diamond():
    fp = hdiff_program().footprints()
    diamond = {
        (dr, dc)
        for dr in range(-2, 3)
        for dc in range(-2, 3)
        if abs(dr) + abs(dc) <= 2
    }
    assert set(fp["psi"]) == diamond
    assert len(diamond) == 13
    # The Laplacian is consumed at the 5 star offsets => "5 Laplacians" (Eq. 5).
    assert set(fp["lap"]) == {(0, 0), (1, 0), (-1, 0), (0, 1), (0, -1)}


def test_hdiff_unlimited_drops_limiter_ops_only():
    spec = hdiff_program(limit=False).spec()
    assert spec.macs == 26
    assert spec.other_ops == 4 * 1 + 4  # plain differences, no mul/cmp/select
    assert spec.radius == 2


def test_hdiff_flux_margins_are_asymmetric():
    margins = hdiff_program().margins()
    assert margins["lap"] == ((1, 1), (1, 1))
    assert margins["flx_r"] == ((1, 1), (2, 1))    # reads lap one row ahead
    assert margins["flx_rm"] == ((2, 1), (1, 1))   # ... one row behind
    assert margins["out"] == ((2, 2), (2, 2))


# --- elementary suite: derived specs agree with the hand-written table --------


@pytest.mark.parametrize("name", sorted(ELEMENTARY_PROGRAMS))
def test_elementary_specs_agree(name):
    derived = ELEMENTARY_PROGRAMS[name]().spec()
    hand = ELEMENTARY_SPECS[name]
    assert (derived.macs, derived.other_ops, derived.reads, derived.radius, derived.ndim) == (
        hand.macs,
        hand.other_ops,
        hand.reads,
        hand.radius,
        hand.ndim,
    ), name


# --- radius composition (deterministic; the hypothesis version lives in
# --- tests/test_ir_properties.py) ---------------------------------------------


@pytest.mark.parametrize("r1,r2", [(0, 0), (1, 0), (0, 2), (1, 1), (2, 1), (3, 2)])
def test_radius_composition_adds(r1, r2):
    a = affine("a", "x", _star_taps(r1))
    b = affine("b", "a", _star_taps(r2))
    prog = StencilProgram("composed", ["x"], [a, b])
    assert prog.radius == r1 + r2
    spec = prog.spec()
    assert spec.radius == r1 + r2
    # Streaming model: stage `a` is evaluated once per offset `b` reads it at.
    assert prog.evaluations()["a"] == len(_star_taps(r2))


def test_footprint_composition_is_minkowski_sum():
    # Two pure shifts compose into a single shifted read of the source.
    s1 = StencilOp("s1", (Read("x", (2, -1)),), lambda v: v, OpCost())
    s2 = StencilOp("s2", (Read("s1", (-1, 3)),), lambda v: v, OpCost())
    prog = StencilProgram("shift", ["x"], [s1, s2])
    assert set(prog.footprints()["x"]) == {(1, 2)}
    # Materialisation margins accumulate per stage (s1 is materialised on its
    # own maximal region before s2 shifts it), so they can over-approximate
    # the composed footprint — conservative, never unsafe.
    lo, hi = prog.halo()
    assert (lo, hi) == ((1, 1), (2, 3))
    assert prog.radius == 3


# --- accounting helpers --------------------------------------------------------


def test_staged_vs_fused_bytes():
    prog = hdiff_program()
    pts = 100
    # Staged: every op reads its declared accesses + writes once.
    per_point = sum(len(op.reads) + 1 for op in prog.ops)
    assert prog.staged_bytes(pts) == per_point * pts * 4
    # Fused: one input in, one output out.
    assert prog.fused_bytes(pts) == 2 * pts * 4
    assert prog.staged_bytes(pts) > prog.fused_bytes(pts)


def test_aie_stencil_cycles_from_derived_spec():
    spec = hdiff_program().spec()
    cyc = aie_stencil_cycles(spec, 256, 256, 64)
    interior = 252 * 252 * 64
    assert cyc["compute_cycles"] == pytest.approx(interior * 46 / 8)
    assert cyc["memory_cycles"] == pytest.approx(interior * 13 * 32 / 512)
    assert cyc["bound"] == "compute"


# --- validation ---------------------------------------------------------------


def test_program_validation_errors():
    ok = affine("a", "x", {(0, 0): 1.0})
    with pytest.raises(ValueError, match="before it is defined"):
        StencilProgram("p", ["x"], [affine("a", "nope", {(0, 0): 1.0})])
    with pytest.raises(ValueError, match="duplicate"):
        StencilProgram("p", ["x"], [ok, affine("a", "x", {(0, 0): 1.0})])
    with pytest.raises(ValueError, match="not 1-D"):
        StencilProgram("p", ["x"], [ok], ndim=1)
    with pytest.raises(ValueError, match="passthrough"):
        StencilProgram("p", ["x"], [ok], passthrough="y")
    with pytest.raises(ValueError, match="at least one op"):
        StencilProgram("p", ["x"], [])
    with pytest.raises(ValueError, match="sign"):
        scaled_residual("o", "x", [("a", 2)], 0.5)
