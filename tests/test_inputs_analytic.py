"""input_specs + analytic-model sanity for every (arch x shape) cell.

These are pure-Python/abstract checks (no compilation), so the full 32-cell
product runs in CI.
"""

import jax
import pytest

from repro.configs import all_cells, get_config, get_shape
from repro.launch.analytic import cell_flops, cell_hbm_bytes
from repro.launch.inputs import input_specs


def test_cell_count_and_skips():
    cells = all_cells()
    assert len(cells) == 32
    # documented skips
    assert ("hubert-xlarge", "decode_32k") not in cells
    assert ("hubert-xlarge", "long_500k") not in cells
    for arch in ("llama-3.2-vision-90b", "nemotron-4-15b", "glm4-9b",
                 "qwen1.5-0.5b", "qwen3-moe-235b-a22b", "arctic-480b"):
        assert (arch, "long_500k") not in cells
    for arch in ("starcoder2-3b", "recurrentgemma-2b", "rwkv6-3b"):
        assert (arch, "long_500k") in cells


@pytest.mark.parametrize("arch,shape_name", all_cells())
def test_input_specs_abstract(arch, shape_name):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    specs = input_specs(cfg, shape)
    # nothing allocated: every leaf is a ShapeDtypeStruct
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
    if shape.kind == "train":
        t = specs["tokens"]
        assert t.shape[0] == shape.global_batch
        assert t.shape[1] == shape.seq_len
        assert specs["labels"].shape == (shape.global_batch, shape.seq_len)
    if shape.kind == "decode":
        assert specs["token"].shape == (shape.global_batch,)
        # window archs cap their KV cache at the window size
        cache_leaves = jax.tree.leaves(specs["cache"])
        total_cache = sum(l.size for l in cache_leaves)
        if cfg.window:
            # no attention cache axis may exceed the window
            for l in cache_leaves:
                if l.ndim == 4:  # (B, L, K, Dh)
                    assert l.shape[1] <= cfg.window


@pytest.mark.parametrize("arch,shape_name", all_cells())
def test_analytic_model_positive_and_ordered(arch, shape_name):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    fl = cell_flops(cfg, shape)
    assert fl["analytic"] > 0 and fl["reference_nd"] > 0
    # analytic >= the 6ND/2ND reference for train/prefill (it adds
    # attention scores + remat); decode recurrent archs can be below 2ND
    # (windowed/constant-state context), allow a floor of 0.2x.
    ratio = fl["analytic"] / fl["reference_nd"]
    assert ratio > 0.2, ratio
    if shape.kind == "train":
        assert ratio > 1.0, ratio
    assert cell_hbm_bytes(cfg, shape) > 0


def test_analytic_decode_scales_with_batch():
    cfg = get_config("glm4-9b")
    d32 = cell_flops(cfg, get_shape("decode_32k"))
    assert d32["analytic"] > 0
    # decode flops should be ~ batch * (2*N + attention over 32k cache)
    per_seq = d32["analytic"] / 128
    assert per_seq > 2 * cfg.active_param_count()  # cache reads add on top
