"""Forecast-serving stress + fault injection, and the deterministic
compile-cache tests (the jax-level zero-retrace proof lives here, outside
the hypothesis-gated module, so it always runs).

The serving claims under test:

  * interleaved tenants — mixed programs AND mixed grids submitted in one
    arrival order — ALL complete, each batch stays homogeneous, and every
    completed result matches an unbatched oracle (same backend, bit-exact;
    reference oracle, 1e-6);
  * per-request telemetry (queue latency, items/sec) and the server gauges
    (member occupancy incl. idle reset, steps/sec) are stamped;
  * a NaN-injected request (caught post-step by a HealthMonitor) fails
    ALONE: its batchmates complete with results identical to a run where
    the poisoned request never existed;
  * warm serving never re-traces: a second wave of same-shaped requests is
    all cache hits with zero new jax traces (the acceptance invariant).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from conformance import assert_close, assert_equal, to_host
from repro.ir import (
    hdiff_program,
    laplacian_program,
    lower_reference,
    repeat,
    shallow_water_program,
)
from repro.obs import events, metrics
from repro.obs.health import HealthMonitor, NumericsError
from repro.serve import CompileCache, ForecastServer, compile_key

SEED = 99


@pytest.fixture(autouse=True)
def _obs_on():
    with metrics.using(), events.using():
        yield


def _noise(grid, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(grid).astype(np.float32))


def _sw_fields(grid, seed):
    rng = np.random.default_rng(seed)
    return {
        f: jnp.asarray(rng.standard_normal(grid).astype(np.float32))
        for f in shallow_water_program().inputs
    }


# -- deterministic cache tests (real builder, real jax traces) ---------------


def test_cache_hit_performs_zero_retraces():
    """The acceptance-gate invariant: the per-entry probe counts ACTUAL jax
    traces of the cached callable, and a warm cache serving the same
    (program, grid, dtype, backend, batch) key again — on both the batched
    and unbatched paths — never traces again."""
    p = hdiff_program()
    rng = np.random.default_rng(0)
    xb = jnp.asarray(rng.standard_normal((3, 2, 16, 16)), jnp.float32)

    cache = CompileCache(4)
    fn = cache.get(p, grid=(2, 16, 16), batch=3)
    fn(xb)
    entry = cache.lookup(compile_key(p, grid=(2, 16, 16), batch=3))
    assert entry.traces == 1  # the miss paid exactly one trace

    for _ in range(3):  # warm hits: same key, fresh data
        fn = cache.get(p, grid=(2, 16, 16), batch=3)
        fn(jnp.asarray(rng.standard_normal((3, 2, 16, 16)), jnp.float32))
    assert entry.traces == 1, "cache hit re-traced"
    assert cache.stats()["hits"] == 3

    # The unbatched path holds the same invariant via its own entry.
    f1 = cache.get(p, grid=(2, 16, 16))
    f1(xb[0])
    f1 = cache.get(p, grid=(2, 16, 16))
    f1(xb[1])
    assert cache.lookup(compile_key(p, grid=(2, 16, 16))).traces == 1
    assert cache.total_traces() == 2  # one per live entry, ever


def test_rebuild_after_eviction_retraces_once():
    """Evicting and re-requesting a key is a miss and costs exactly one
    fresh trace — the probe distinguishes that from a hit-path retrace."""
    hd, lap = hdiff_program(), laplacian_program()
    x = jnp.zeros((2, 16, 16), jnp.float32)
    cache = CompileCache(1)
    cache.get(hd, grid=(2, 16, 16))(x)
    cache.get(lap, grid=(2, 16, 16))(x)   # evicts hd
    cache.get(hd, grid=(2, 16, 16))(x)    # miss again
    assert cache.stats() == {
        "hits": 0, "misses": 3, "evictions": 2, "size": 1, "capacity": 1,
    }
    assert cache.lookup(compile_key(hd, grid=(2, 16, 16))).traces == 1


def test_cache_counters_reach_registry():
    snap = None
    cache = CompileCache(1)
    x = jnp.zeros((2, 16, 16), jnp.float32)
    cache.get(hdiff_program(), grid=(2, 16, 16))(x)      # miss
    cache.get(hdiff_program(), grid=(2, 16, 16))(x)      # hit
    cache.get(laplacian_program(), grid=(2, 16, 16))(x)  # miss + evict
    snap = metrics.current().snapshot()["counters"]
    assert snap["cache.hits"] == 1
    assert snap["cache.misses"] == 2
    assert snap["cache.evictions"] == 1


# -- the serving stress suite -------------------------------------------------


def _submit_interleaved(srv):
    """Three tenants' worth of traffic in one interleaved arrival order:
    hdiff on two DIFFERENT grids (must not co-batch) and shallow_water
    (multi-output) between them. Returns {rid: (program, fields)}."""
    hd, sw = hdiff_program(), shallow_water_program()
    plan = [
        (hd, _noise((2, 16, 16), SEED + 0)),
        (sw, _sw_fields((2, 12, 12), SEED + 1)),
        (hd, _noise((2, 16, 16), SEED + 2)),
        (hd, _noise((2, 24, 24), SEED + 3)),   # other grid: own batch
        (sw, _sw_fields((2, 12, 12), SEED + 4)),
        (hd, _noise((2, 16, 16), SEED + 5)),
        (hd, _noise((2, 16, 16), SEED + 6)),
        (sw, _sw_fields((2, 12, 12), SEED + 7)),
    ]
    subs = {}
    for prog, fields in plan:
        subs[srv.submit(prog, fields)] = (prog, fields)
    return subs


def test_stress_interleaved_tenants_all_complete_and_match_oracles():
    srv = ForecastServer(max_batch=4)
    subs = _submit_interleaved(srv)
    done = srv.run_until_idle()
    assert len(done) == len(subs) and srv.pending() == 0
    assert all(r.done and not r.failed for r in done)
    # Batches stayed homogeneous: 8 requests can't drain in fewer than 3
    # batches (3 distinct group keys), and FIFO grouping gives exactly 3.
    assert srv.stats["batches"] == 3
    assert srv.stats["members"] == len(subs)
    for r in done:
        prog, fields = subs[r.rid]
        want = to_host(lower_reference(prog)(fields))
        assert_close(to_host(r.result), want, err_msg=f"rid={r.rid} vs oracle")


def test_served_results_bit_match_unbatched_same_backend():
    """Same backend, batched through the server vs directly unbatched:
    bit-exact, including for a k=2 composed program."""
    prog = repeat(hdiff_program(), 2)
    fields = [_noise((2, 16, 16), SEED + i) for i in range(3)]
    srv = ForecastServer(max_batch=4)
    rids = [srv.submit(prog, f) for f in fields]
    done = {r.rid: r for r in srv.run_until_idle()}
    base = srv.cache.get(prog, grid=(2, 16, 16))  # the unbatched twin
    for rid, f in zip(rids, fields):
        assert_equal(
            to_host(done[rid].result), to_host(base(f)),
            err_msg=f"rid={rid} batched vs unbatched",
        )


def test_telemetry_stamped_per_request_and_server():
    srv = ForecastServer(max_batch=4)
    _submit_interleaved(srv)
    done = srv.run_until_idle()
    for r in done:
        assert r.queue_latency_s is not None and r.queue_latency_s >= 0
        assert r.items_per_sec is not None and r.items_per_sec > 0
    snap = metrics.current().snapshot()
    assert snap["gauges"]["serve.forecast.steps_per_sec"] > 0
    assert snap["gauges"]["serve.forecast.members_per_sec"] > 0
    # Occupancy resets to idle after the drain (the staleness rule).
    assert snap["gauges"]["serve.forecast.member_occupancy"] == 0.0
    assert snap["counters"]["serve.forecast.requests_submitted"] == len(done)
    assert snap["counters"]["serve.forecast.completed"] == len(done)
    assert snap["timers"]["serve.forecast.queue_latency"]["count"] == len(done)
    # Retire events carry the per-request telemetry.
    retires = events.current().events("serve.forecast.retire")
    assert len(retires) == len(done)
    assert all(e.data["items_per_sec"] > 0 for e in retires)


def test_member_occupancy_gauge_tracks_last_batch():
    srv = ForecastServer(max_batch=4)
    for i in range(3):
        srv.submit(hdiff_program(), _noise((2, 16, 16), SEED + i))
    assert srv.step() is True
    snap = metrics.current().snapshot()
    assert snap["gauges"]["serve.forecast.member_occupancy"] == 3 / 4
    assert srv.step() is False  # idle → gauge drops to 0
    assert metrics.current().snapshot()["gauges"][
        "serve.forecast.member_occupancy"
    ] == 0.0


def test_nan_injected_request_fails_alone():
    """Fault injection: one member's initial conditions carry a NaN. The
    HealthMonitor (abort policy) catches it post-step; that request retires
    with ``error`` set while its batchmates complete with results
    IDENTICAL to a clean run without the poisoned request."""
    clean = [_noise((2, 16, 16), SEED + i) for i in range(3)]
    poisoned = clean[1].at[0, 5, 5].set(jnp.nan)

    srv = ForecastServer(max_batch=4, monitor=HealthMonitor(policy="abort"))
    rid0 = srv.submit(hdiff_program(), clean[0])
    rid_bad = srv.submit(hdiff_program(), poisoned)
    rid2 = srv.submit(hdiff_program(), clean[2])
    done = {r.rid: r for r in srv.run_until_idle()}

    assert srv.stats == {"batches": 1, "members": 3, "completed": 2, "failed": 1}
    bad = done[rid_bad]
    assert bad.done and bad.failed and bad.result is None
    assert isinstance(bad.error, NumericsError)

    # Batchmates: identical to a server that never saw the poison.
    oracle_srv = ForecastServer(max_batch=4)
    o0 = oracle_srv.submit(hdiff_program(), clean[0])
    o2 = oracle_srv.submit(hdiff_program(), clean[2])
    oracle = {r.rid: r for r in oracle_srv.run_until_idle()}
    assert_equal(to_host(done[rid0].result), to_host(oracle[o0].result))
    assert_equal(to_host(done[rid2].result), to_host(oracle[o2].result))

    snap = metrics.current().snapshot()["counters"]
    assert snap["serve.forecast.failed"] == 1
    fails = events.current().events("serve.forecast.fail")
    assert len(fails) == 1 and fails[0].data["rid"] == rid_bad


def test_nan_isolation_multi_output():
    """Same isolation story for a coupled system: poisoning one member's h
    field fails only that request; surviving members' u/v/h all match."""
    fields = [_sw_fields((2, 12, 12), SEED + i) for i in range(3)]
    bad = dict(fields[0])
    bad["h"] = bad["h"].at[1, 3, 3].set(jnp.inf)

    srv = ForecastServer(max_batch=4, monitor=HealthMonitor(policy="abort"))
    rid_bad = srv.submit(shallow_water_program(), bad)
    rids = [srv.submit(shallow_water_program(), f) for f in fields[1:]]
    done = {r.rid: r for r in srv.run_until_idle()}
    assert done[rid_bad].failed
    ref = lower_reference(shallow_water_program())
    for rid, f in zip(rids, fields[1:]):
        assert not done[rid].failed
        assert_close(to_host(done[rid].result), to_host(ref(f)))


def test_warm_serving_is_all_hits_with_zero_retraces():
    """Two identical waves of traffic: the second wave is 100% cache hits
    and adds ZERO jax traces — the serving-level acceptance invariant."""
    srv = ForecastServer(max_batch=4)

    def wave(seed0):
        for i in range(4):
            srv.submit(hdiff_program(), _noise((2, 16, 16), seed0 + i))
        srv.run_until_idle()

    wave(SEED)
    misses0 = srv.cache.stats()["misses"]
    traces0 = srv.cache.total_traces()
    wave(SEED + 100)  # fresh data, same shapes
    assert srv.cache.stats()["misses"] == misses0, "warm wave missed"
    assert srv.cache.total_traces() == traces0, "warm wave re-traced"
    assert srv.cache.hit_rate > 0


def test_submit_validation():
    srv = ForecastServer()
    with pytest.raises(ValueError, match="pass a mapping"):
        srv.submit(shallow_water_program(), jnp.zeros((2, 8, 8)))
    with pytest.raises(ValueError, match="missing input"):
        srv.submit(shallow_water_program(), {"u": jnp.zeros((2, 8, 8))})
    with pytest.raises(ValueError, match="share a grid"):
        srv.submit(
            shallow_water_program(),
            {"u": jnp.zeros((2, 8, 8)), "v": jnp.zeros((2, 8, 8)),
             "h": jnp.zeros((2, 9, 9))},
        )
    with pytest.raises(ValueError, match="depth, rows, cols"):
        srv.submit(hdiff_program(), jnp.zeros((8, 8)))
    with pytest.raises(ValueError, match="max_batch"):
        ForecastServer(max_batch=0)
