"""Property-based tests (hypothesis) for the derived adjoints.

Invariants (the structural half of repro.ir.autodiff's contract; the
numeric half is the gradient-conformance matrix):
  * the adjoint of a random affine program reads the output seed at
    EXACTLY the negated composed primal offsets — transposition, nothing
    wider (no square-dilation slop);
  * ``adjoint(adjoint(p))`` round-trips: the primal's radius and composed
    input footprint come back exactly (double transposition is identity
    on the access structure);
  * adjoint radii equal primal radii per chain entry under ``repeat(p, k)``
    for the WHOLE conformance roster — the invariant that lets the
    backward halo exchange reuse the primal wire plan byte-for-byte.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conformance import PROGRAMS  # noqa: E402
from repro.ir import adjoint, augmented_forward, repeat, seed_field  # noqa: E402
from repro.ir.graph import StencilProgram  # noqa: E402
from repro.ir.ops import affine  # noqa: E402

# Deliberately asymmetric offset pool: symmetric (star) taps would make
# "negated" indistinguishable from "copied".
offsets = st.tuples(st.integers(-2, 2), st.integers(-2, 2))
taps_sets = st.dictionaries(
    offsets, st.floats(0.5, 2.0), min_size=1, max_size=6
).filter(lambda d: (0, 0) in d or len(d) > 1)


def _affine_chain(taps_list):
    ops, src = [], "x"
    for i, taps in enumerate(taps_list):
        ops.append(affine(f"s{i}", src, taps))
        src = f"s{i}"
    return StencilProgram("p", ["x"], ops)


def _neg(fp):
    return frozenset(tuple(-c for c in o) for o in fp)


@settings(max_examples=40, deadline=None)
@given(st.lists(taps_sets, min_size=1, max_size=3))
def test_adjoint_offsets_are_negated(taps_list):
    p = _affine_chain(taps_list)
    adj = adjoint(p)
    want = _neg(p.footprints()["x"])
    assert adj.footprints()[seed_field("x")] == want
    assert adj.radius == p.radius


@settings(max_examples=40, deadline=None)
@given(st.lists(taps_sets, min_size=1, max_size=3))
def test_double_adjoint_roundtrips(taps_list):
    p = _affine_chain(taps_list)
    aa = adjoint(adjoint(p))
    assert aa.radius == p.radius
    seed2 = seed_field(seed_field("x"))
    assert aa.footprints()[seed2] == p.footprints()["x"]


@settings(max_examples=60, deadline=None)
@given(st.sampled_from(sorted(PROGRAMS)), st.integers(1, 4))
def test_adjoint_radii_match_primal_under_repeat(name, k):
    p = repeat(PROGRAMS[name](), k)
    assert p.radius == PROGRAMS[name]().radius * k
    for q in p.chain:
        assert adjoint(q).radius == q.radius
        assert augmented_forward(q).radius == q.radius
