"""Data pipeline, checkpointing, train loop, and serving-engine tests."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import get_smoke_config
from repro.data import DataConfig, Prefetcher, SyntheticLM, pack_documents
from repro.launch.mesh import make_host_mesh
from repro.models import build_lm
from repro.optim import make_optimizer
from repro.serve import BatchedServer
from repro.train import TrainConfig, make_train_step, train


# --- data --------------------------------------------------------------------


def test_synthetic_deterministic_and_sharded():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab_size=100, seed=3)
    ds = SyntheticLM(cfg)
    b1 = ds.batch_at(5)
    b2 = ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # replayable
    assert b1["tokens"].shape == (8, 16)
    # host shards partition the batch deterministically & disjointly-seeded
    s0 = ds.batch_at(5, shard=0, n_shards=2)
    s1 = ds.batch_at(5, shard=1, n_shards=2)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_labels_are_next_token():
    cfg = DataConfig(seq_len=12, global_batch=2, vocab_size=50)
    b = SyntheticLM(cfg).batch_at(0)
    # label[t] must equal token[t+1] (same underlying stream)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pack_documents_masks_boundaries():
    docs = [np.arange(1, 6), np.arange(10, 13)]
    out = pack_documents(docs, seq_len=5, eos_id=0)
    assert out["tokens"].shape[1] == 5
    # every EOS position's label is masked
    for r in range(out["tokens"].shape[0]):
        for j in range(5):
            if out["tokens"][r, j] == 0:
                assert out["labels"][r, j] == -100


def test_prefetcher_orders_batches():
    cfg = DataConfig(seq_len=4, global_batch=2, vocab_size=10)
    ds = SyntheticLM(cfg)
    pf = Prefetcher(lambda s: ds.batch_at(s), depth=2, start_step=0)
    got = [next(pf) for _ in range(3)]
    pf.close()
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"], ds.batch_at(i)["tokens"])


# --- checkpoint ---------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "s": jnp.int32(7)}
    save_checkpoint(tmp_path, 3, tree, {"step": 3})
    assert latest_step(tmp_path) == 3
    restored, extra = restore_checkpoint(tmp_path, None, tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert extra["step"] == 3


def test_checkpoint_ignores_uncommitted(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": jnp.ones(2)})
    # fake a torn save at step 2
    (tmp_path / "step_00000002").mkdir()
    assert latest_step(tmp_path) == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 0, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, 0, {"w": jnp.ones((3, 3))})


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, {"w": jnp.full((2,), float(s))})
    mgr.wait()
    assert latest_step(tmp_path) == 4
    kept = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step_"))
    assert len(kept) == 2


def test_checkpoint_elastic_resharding(tmp_path):
    """Save unsharded, restore with an explicit sharding on the current mesh."""
    from repro.dist.sharding import sharding_for

    mesh = make_host_mesh()
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(tmp_path, 0, tree)
    sh = {"w": sharding_for(("batch", None), mesh, (4, 4))}
    restored, _ = restore_checkpoint(tmp_path, 0, tree, sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]


# --- train loop ----------------------------------------------------------------


def _tiny_cfg():
    import dataclasses

    return dataclasses.replace(
        get_smoke_config("qwen1.5-0.5b"),
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=0,
        d_ff=64, vocab_size=64, remat=False, learning_rate=3e-3,
    )


def test_train_step_reduces_loss():
    cfg = _tiny_cfg()
    from repro.optim import optimizer_config_from_model

    opt_cfg = optimizer_config_from_model(cfg)
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    init, _ = make_optimizer(opt_cfg)
    opt_state = init(params)
    step = jax.jit(make_train_step(cfg, opt_cfg))

    ds = SyntheticLM(DataConfig(seq_len=16, global_batch=8, vocab_size=cfg.vocab_size))
    batch = jax.tree.map(jnp.asarray, ds.batch_at(0))
    losses = []
    for _ in range(30):
        params, opt_state, m = step(params, opt_state, batch)  # overfit one batch
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::10]


def test_microbatched_grads_match_full_batch():
    cfg = _tiny_cfg()
    from repro.optim import optimizer_config_from_model

    opt_cfg = optimizer_config_from_model(cfg)
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    init, _ = make_optimizer(opt_cfg)

    ds = SyntheticLM(DataConfig(seq_len=8, global_batch=8, vocab_size=cfg.vocab_size))
    batch = jax.tree.map(jnp.asarray, ds.batch_at(0))

    from repro.train import shape_for_microbatches

    s1 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=1))
    s4 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=4))
    p1, _, m1 = s1(params, init(params), batch)
    p4, _, m4 = s4(params, init(params), shape_for_microbatches(batch, 4))
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    # Post-Adam params: at t=1 the update is ~sign(g), so bf16 grad noise on
    # near-zero grads flips update direction; compare with an absolute bound
    # of ~2*lr*ulp-effects instead of relative.
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=3e-4)


def test_train_resume_from_checkpoint(tmp_path):
    cfg = _tiny_cfg()
    mesh = make_host_mesh()
    ds = SyntheticLM(DataConfig(seq_len=8, global_batch=4, vocab_size=cfg.vocab_size))
    tc = TrainConfig(steps=6, ckpt_every=2, ckpt_dir=str(tmp_path), log_every=100)
    _, _, hist1 = train(cfg, tc, mesh, ds, log_fn=lambda *_: None)
    assert latest_step(tmp_path) == 5
    # resume: should start after step 5 -> no further steps executed
    _, _, hist2 = train(cfg, tc, mesh, ds, log_fn=lambda *_: None)
    assert hist2 == []


# --- serving -------------------------------------------------------------------


def test_batched_server_continuous_batching():
    cfg = _tiny_cfg()
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, lanes=2, max_len=64)
    rng = np.random.default_rng(0)
    rids = [srv.submit(rng.integers(0, cfg.vocab_size, size=(5,)), max_new_tokens=4)
            for _ in range(5)]
    done = srv.run_until_idle()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)
    assert srv.stats["prefills"] == 5
    # greedy decode must be deterministic given the same prompt
    srv2 = BatchedServer(cfg, params, lanes=1, max_len=64)
    p = np.arange(5) % cfg.vocab_size
    r1 = srv2.submit(p, 4)
    out1 = [r for r in srv2.run_until_idle() if r.rid == r1][0].out_tokens
    srv3 = BatchedServer(cfg, params, lanes=1, max_len=64)
    r2 = srv3.submit(p, 4)
    out2 = [r for r in srv3.run_until_idle() if r.rid == r2][0].out_tokens
    assert out1 == out2
