"""Unit tests for scripts/bench_compare.py (the perf-trajectory gate).

Synthetic BENCH_<fig>.json pairs drive every gate rule: wall-clock
regressions (relative bound AND absolute floor), deterministic byte-model
drift (both directions), missing baselines, metadata-mismatch skips,
missing rows, informational units, and ``--update``.
"""

import importlib.util
import json
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SCRIPT = REPO / "scripts" / "bench_compare.py"

spec = importlib.util.spec_from_file_location("bench_compare", SCRIPT)
bench_compare = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_compare)

META = {"backend": "cpu", "device_kind": "cpu", "device_count": 1}


def _record(fig="figX", rows=None, meta=None):
    return {
        "fig": fig,
        "grid": {"depth": 8, "rows": 128, "cols": 128},
        "meta": dict(META if meta is None else meta),
        "wall_clock_s": 1.0,
        "parity_ok": True,
        "wire_ratios": [],
        "error": None,
        "rows": rows if rows is not None else [
            {"name": f"{fig}/t", "value": 1000.0, "derived": "", "unit": "us"},
            {"name": f"{fig}/b", "value": 4096.0, "derived": "", "unit": "bytes"},
            {"name": f"{fig}/i", "value": 3.0, "derived": "", "unit": "x"},
        ],
    }


def _write(directory: Path, *records):
    directory.mkdir(parents=True, exist_ok=True)
    for rec in records:
        (directory / f"BENCH_{rec['fig']}.json").write_text(
            json.dumps(rec, indent=2)
        )


def _run(cur_dir, base_dir, *extra):
    return bench_compare.main(
        ["--current-dir", str(cur_dir), "--baseline-dir", str(base_dir), *extra]
    )


def _rows(**values):
    units = {"t": "us", "b": "bytes", "i": "x"}
    return [
        {"name": f"figX/{n}", "value": v, "derived": "", "unit": units[n]}
        for n, v in values.items()
    ]


def test_identical_records_pass(tmp_path):
    _write(tmp_path / "base", _record())
    _write(tmp_path / "cur", _record())
    assert _run(tmp_path / "cur", tmp_path / "base") == 0


def test_wall_clock_regression_fails(tmp_path):
    _write(tmp_path / "base", _record())
    # 1000us -> 3500us: past +50% default AND the 200us floor.
    _write(tmp_path / "cur", _record(rows=_rows(t=3500.0, b=4096.0, i=3.0)))
    assert _run(tmp_path / "cur", tmp_path / "base") == 1


def test_wall_clock_within_absolute_floor_passes(tmp_path):
    """A big relative but tiny absolute slowdown is runner noise, not a
    regression: 50us -> 120us is +140% but under the 200us floor."""
    _write(tmp_path / "base", _record(rows=_rows(t=50.0, b=4096.0, i=3.0)))
    _write(tmp_path / "cur", _record(rows=_rows(t=120.0, b=4096.0, i=3.0)))
    assert _run(tmp_path / "cur", tmp_path / "base") == 0


def test_wall_clock_bound_is_configurable(tmp_path):
    _write(tmp_path / "base", _record())
    _write(tmp_path / "cur", _record(rows=_rows(t=1400.0, b=4096.0, i=3.0)))
    # +40%: inside the default +50%...
    assert _run(tmp_path / "cur", tmp_path / "base") == 0
    # ...but outside a tightened +20% with a lowered floor.
    assert _run(tmp_path / "cur", tmp_path / "base",
                "--max-us-regression", "0.2", "--us-floor", "100") == 1


def test_byte_drift_fails_both_directions(tmp_path):
    _write(tmp_path / "base", _record())
    _write(tmp_path / "cur", _record(rows=_rows(t=1000.0, b=5000.0, i=3.0)))
    assert _run(tmp_path / "cur", tmp_path / "base") == 1
    # Byte models are deterministic: a DECREASE is drift too.
    _write(tmp_path / "cur", _record(rows=_rows(t=1000.0, b=3000.0, i=3.0)))
    assert _run(tmp_path / "cur", tmp_path / "base") == 1


def test_informational_units_never_gate(tmp_path):
    _write(tmp_path / "base", _record())
    # The "x" row blows up 100x: not gated.
    _write(tmp_path / "cur", _record(rows=_rows(t=1000.0, b=4096.0, i=300.0)))
    assert _run(tmp_path / "cur", tmp_path / "base") == 0


def test_missing_baseline_fails(tmp_path):
    (tmp_path / "base").mkdir()
    _write(tmp_path / "cur", _record())
    assert _run(tmp_path / "cur", tmp_path / "base") == 1


def test_missing_gated_row_fails(tmp_path):
    _write(tmp_path / "base", _record())
    _write(tmp_path / "cur", _record(rows=_rows(t=1000.0, i=3.0)))
    assert _run(tmp_path / "cur", tmp_path / "base") == 1


def test_new_rows_do_not_gate(tmp_path):
    _write(tmp_path / "base", _record(rows=_rows(t=1000.0)))
    _write(tmp_path / "cur", _record())
    assert _run(tmp_path / "cur", tmp_path / "base") == 0


def test_metadata_mismatch_skips_rows(tmp_path):
    """A record from a different device must not gate: same rows would fail
    hard, but the backend differs so the fig is skipped wholesale."""
    _write(tmp_path / "base", _record())
    other = dict(META, device_kind="TPU v5e", backend="tpu")
    _write(tmp_path / "cur",
           _record(rows=_rows(t=9000.0, b=9999.0, i=3.0), meta=other))
    assert _run(tmp_path / "cur", tmp_path / "base") == 0


def test_update_writes_baselines_then_passes(tmp_path):
    _write(tmp_path / "cur", _record())
    assert _run(tmp_path / "cur", tmp_path / "base") == 1  # no baseline yet
    assert _run(tmp_path / "cur", tmp_path / "base", "--update") == 0
    assert (tmp_path / "base" / "BENCH_figX.json").is_file()
    assert _run(tmp_path / "cur", tmp_path / "base") == 0


def test_empty_current_dir_fails(tmp_path):
    (tmp_path / "cur").mkdir()
    _write(tmp_path / "base", _record())
    assert _run(tmp_path / "cur", tmp_path / "base") == 1


def test_legacy_rows_without_unit_default_to_us(tmp_path):
    rows = [{"name": "figX/t", "value": 1000.0, "derived": ""}]
    _write(tmp_path / "base", _record(rows=rows))
    cur = [{"name": "figX/t", "value": 5000.0, "derived": ""}]
    _write(tmp_path / "cur", _record(rows=cur))
    assert _run(tmp_path / "cur", tmp_path / "base") == 1


def test_compare_fig_reports_reasons():
    cur = _record(rows=_rows(t=9000.0, b=9999.0, i=3.0))
    base = _record()
    failures, _notes = bench_compare.compare_fig(
        cur, base, max_us_regression=0.5, us_floor=200.0,
        max_bytes_regression=0.02,
    )
    assert len(failures) == 2
    assert any("wall-clock regression" in f for f in failures)
    assert any("byte-model drift" in f for f in failures)


def _rate_rows(hit_rate, traces):
    return [
        {"name": "figX/cache_hit_rate", "value": hit_rate, "derived": "",
         "unit": "rate"},
        {"name": "figX/warm_traces", "value": traces, "derived": "",
         "unit": "rate"},
        {"name": "figX/batch8", "value": 3000.0, "derived": "",
         "unit": "rate_info"},
    ]


def test_rate_rows_gate_deterministically(tmp_path):
    """fig14-style serving rows: ``rate`` gates with the bytes rule (drift
    either way fails), ``rate_info`` throughput never gates."""
    _write(tmp_path / "base", _record(rows=_rate_rows(0.5, 0.0)))
    _write(tmp_path / "cur", _record(rows=_rate_rows(0.5, 0.0)))
    assert _run(tmp_path / "cur", tmp_path / "base") == 0
    # Hit rate drifted: the admission/caching logic changed -> fail.
    _write(tmp_path / "cur", _record(rows=_rate_rows(0.25, 0.0)))
    assert _run(tmp_path / "cur", tmp_path / "base") == 1
    # Throughput rows may swing freely (rate_info is informational).
    rows = _rate_rows(0.5, 0.0)
    rows[2]["value"] = 1.0
    _write(tmp_path / "cur", _record(rows=rows))
    assert _run(tmp_path / "cur", tmp_path / "base") == 0


def test_zero_rate_baseline_tolerates_no_drift(tmp_path):
    """The warm-trace row's baseline is 0: ANY warm-path retrace (value
    > 0) must fail — a 0 baseline means 0 tolerance."""
    _write(tmp_path / "base", _record(rows=_rate_rows(0.5, 0.0)))
    _write(tmp_path / "cur", _record(rows=_rate_rows(0.5, 1.0)))
    assert _run(tmp_path / "cur", tmp_path / "base") == 1


def test_rate_failure_message_names_serving():
    failures, _ = bench_compare.compare_fig(
        _record(rows=_rate_rows(0.25, 0.0)),
        _record(rows=_rate_rows(0.5, 0.0)),
        max_us_regression=0.5, us_floor=200.0, max_bytes_regression=0.02,
    )
    assert len(failures) == 1
    assert "serving-rate drift" in failures[0]
