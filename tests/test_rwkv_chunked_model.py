"""rwkv6 model with chunked WKV must equal the sequential-scan model."""

import dataclasses

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.models import build_lm, lm_forward


def test_chunked_model_matches_sequential():
    cfg = dataclasses.replace(get_smoke_config("rwkv6-3b"), compute_dtype="float32")
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    l_seq, _ = lm_forward(cfg, params, tokens)
    cfg_c = dataclasses.replace(cfg, rwkv_chunk=8)
    l_ch, _ = lm_forward(cfg_c, params, tokens)
    np.testing.assert_allclose(np.asarray(l_ch), np.asarray(l_seq), rtol=2e-4, atol=2e-4)
