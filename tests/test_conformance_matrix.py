"""The cross-backend conformance matrix (see tests/conformance.py).

Three layers:

  * ``test_oracle_matches_handwritten`` anchors the matrix oracle
    (``lower_reference`` of the composed program) against k composed
    applications of the hand-written ``repro.core`` kernels.
  * ``test_conformance_1x1`` runs every (program, backend, k) cell on the
    1x1 mesh in-process — the tier-1 parity sweep.
  * ``test_conformance_mesh`` runs the sharded cells of one multi-device
    mesh in an 8-fake-device subprocess (the main pytest process must keep
    seeing 1 device — the dry-run contract), including overlap=True
    bit-match checks. If the subprocess cannot provide the mesh it SKIPS
    with a "mesh ... unavailable" message, which
    ``scripts/check_no_dep_skips.py --fail-on-mesh-skips`` turns into a
    hard failure in the CI multidev-2d job.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from conformance import (
    KS,
    MESHES,
    PROGRAMS,
    assert_case,
    assert_close,
    iter_cases,
    make_fields,
    mesh_id,
    oracle,
)
from repro.core import ELEMENTARY_FNS, hdiff, hdiff_simple
from repro.obs import events, metrics

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _metrics_on():
    """Every cell runs fully instrumented (per-call timers, named scopes,
    halo model counters AND the flight recorder live): parity must hold
    with both observability channels ON — the instrumentation contract is
    that it never perturbs the computation."""
    with metrics.using(), events.using():
        yield


def _hdiff_coupled_ref(arrs):
    """Direct jnp hdiff with a coefficient FIELD (no IR involved): interior
    update ``u - coeff * div(limited fluxes)``, radius-2 ring passthrough."""
    import jax.numpy as jnp

    u, coeff = arrs["u"], arrs["coeff"]
    lap = (
        4.0 * u[..., 1:-1, 1:-1]
        - u[..., 2:, 1:-1]
        - u[..., :-2, 1:-1]
        - u[..., 1:-1, 2:]
        - u[..., 1:-1, :-2]
    )

    def limit(dlap, du):
        return jnp.where(dlap * du <= 0, dlap, jnp.zeros_like(dlap))

    # Fluxes on the radius-2 interior (lap is radius-1 inset already).
    flx_r = limit(lap[..., 2:, 1:-1] - lap[..., 1:-1, 1:-1],
                  u[..., 3:-1, 2:-2] - u[..., 2:-2, 2:-2])
    flx_rm = limit(lap[..., 1:-1, 1:-1] - lap[..., :-2, 1:-1],
                   u[..., 2:-2, 2:-2] - u[..., 1:-3, 2:-2])
    flx_c = limit(lap[..., 1:-1, 2:] - lap[..., 1:-1, 1:-1],
                  u[..., 2:-2, 3:-1] - u[..., 2:-2, 2:-2])
    flx_cm = limit(lap[..., 1:-1, 1:-1] - lap[..., 1:-1, :-2],
                   u[..., 2:-2, 2:-2] - u[..., 2:-2, 1:-3])
    interior = u[..., 2:-2, 2:-2] - coeff[..., 2:-2, 2:-2] * (
        (flx_r - flx_rm) + (flx_c - flx_cm)
    )
    return u.at[..., 2:-2, 2:-2].set(interior)


def _vadvc_ref(arrs):
    """Direct jnp vertical-advection fragment (levels along rows): interior
    ``s - dt * wbar * grad`` with a radius-1 ring passthrough."""
    s, w = arrs["s"], arrs["w"]
    dt = 0.25
    wbar = 0.5 * (w[..., 1:-1, 1:-1] + w[..., 2:, 1:-1])
    grad = 0.5 * (s[..., 2:, 1:-1] - s[..., :-2, 1:-1])
    interior = s[..., 1:-1, 1:-1] - dt * (wbar * grad)
    return s.at[..., 1:-1, 1:-1].set(interior)


def _shallow_water_ref(arrs):
    """Direct jnp linearized shallow-water sweep (no IR involved): centered
    gravity-wave coupling ``u -= g*dt*dh/dx, v -= g*dt*dh/dy, h -= h*dt*
    (du/dx + dv/dy)``, radius-1 ring passthrough on every evolving field."""
    u, v, h = arrs["u"], arrs["v"], arrs["h"]
    g_dt = h_dt = 0.2

    def ddx(a):
        return 0.5 * a[..., 2:, 1:-1] + (-0.5) * a[..., :-2, 1:-1]

    def ddy(a):
        return 0.5 * a[..., 1:-1, 2:] + (-0.5) * a[..., 1:-1, :-2]

    u_new = u[..., 1:-1, 1:-1] - g_dt * ddx(h)
    v_new = v[..., 1:-1, 1:-1] - g_dt * ddy(h)
    h_new = h[..., 1:-1, 1:-1] - h_dt * (ddx(u) + ddy(v))
    return {
        "u": u.at[..., 1:-1, 1:-1].set(u_new),
        "v": v.at[..., 1:-1, 1:-1].set(v_new),
        "h": h.at[..., 1:-1, 1:-1].set(h_new),
    }


def _advection_diffusion_ref(arrs):
    """Direct jnp advection-diffusion sweep (no IR involved): the tracer c
    is advected by (u, v) and diffused, u itself diffuses; v is a frozen
    velocity component. Radius-1 ring passthrough on the evolving {c, u}."""
    c, u, v = arrs["c"], arrs["u"], arrs["v"]
    nu, dt, kappa = 0.05, 0.1, 0.05

    def lap(a):
        return (
            4.0 * a[..., 1:-1, 1:-1]
            - a[..., 2:, 1:-1]
            - a[..., :-2, 1:-1]
            - a[..., 1:-1, 2:]
            - a[..., 1:-1, :-2]
        )

    def ddx(a):
        return 0.5 * a[..., 2:, 1:-1] + (-0.5) * a[..., :-2, 1:-1]

    def ddy(a):
        return 0.5 * a[..., 1:-1, 2:] + (-0.5) * a[..., 1:-1, :-2]

    u_new = u[..., 1:-1, 1:-1] - nu * lap(u)
    cadv = c[..., 1:-1, 1:-1] - dt * (
        u[..., 1:-1, 1:-1] * ddx(c) + v[..., 1:-1, 1:-1] * ddy(c)
    )
    c_new = cadv - kappa * lap(c)
    return {
        "c": c.at[..., 1:-1, 1:-1].set(c_new),
        "u": u.at[..., 1:-1, 1:-1].set(u_new),
    }


HANDWRITTEN = dict(ELEMENTARY_FNS)
HANDWRITTEN.update(
    {"hdiff": lambda x: hdiff(x, 0.025), "hdiff_simple": lambda x: hdiff_simple(x, 0.025)}
)
# Multi-field anchors: fn(mapping) -> next state field.
HANDWRITTEN_MULTI = {"hdiff_coupled": _hdiff_coupled_ref, "vadvc": _vadvc_ref}
# Multi-OUTPUT anchors: fn(mapping) -> {field: next state} for every
# evolving field of the coupled system.
HANDWRITTEN_MULTIOUT = {
    "shallow_water": _shallow_water_ref,
    "advection_diffusion": _advection_diffusion_ref,
}


@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_oracle_matches_handwritten(name):
    x = make_fields(name)
    prog = PROGRAMS[name]()
    for k in KS:
        if len(prog.inputs) == 1:
            want = x
            for _ in range(k):
                want = HANDWRITTEN[name](want)
        elif name in HANDWRITTEN_MULTIOUT:
            arrs = dict(x)
            for _ in range(k):
                arrs.update(HANDWRITTEN_MULTIOUT[name](arrs))
            want = {f: np.asarray(arrs[f]) for f in prog.outputs}
            assert_close(oracle(name, k), want, err_msg=f"{name} k={k}")
            continue
        else:
            arrs = dict(x)
            for _ in range(k):
                arrs[prog.passthrough] = HANDWRITTEN_MULTI[name](arrs)
            want = arrs[prog.passthrough]
        np.testing.assert_allclose(
            oracle(name, k), np.asarray(want), rtol=1e-6, atol=1e-6,
            err_msg=f"{name} k={k}",
        )


CASES_1X1 = [
    pytest.param(name, backend, k, id=f"{name}-{backend}-k{k}")
    for name, backend, k, _mesh in iter_cases(((1, 1),))
]


@pytest.mark.parametrize("name,backend,k", CASES_1X1)
def test_conformance_1x1(name, backend, k):
    assert_case(name, backend, k, (1, 1))


MULTIDEV_MESHES = [m for m in MESHES if m != (1, 1)]


@pytest.mark.multidev
@pytest.mark.parametrize("mesh", [pytest.param(m, id=mesh_id(m)) for m in MULTIDEV_MESHES])
def test_conformance_mesh(mesh, tmp_path):
    n_dev = mesh[0] * mesh[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    # The sharded cells must also hold fully instrumented (see _metrics_on):
    # metrics registry AND flight recorder both live via env auto-enable.
    env["REPRO_METRICS"] = "1"
    event_log = tmp_path / "events.jsonl"
    env["REPRO_EVENT_LOG"] = str(event_log)
    proc = subprocess.run(
        [
            sys.executable,
            str(REPO / "tests" / "multidev" / "_conformance_check.py"),
            "--mesh",
            mesh_id(mesh),
        ],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    if "DEVICES_UNAVAILABLE" in proc.stdout:
        pytest.skip(f"mesh {mesh_id(mesh)} unavailable: {proc.stdout.strip()}")
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL_OK" in proc.stdout
    # The instrumented run must actually have recorded events (at minimum
    # the meta header + per-call halo.exchange events from lower_sharded).
    assert event_log.exists() and event_log.stat().st_size > 0
