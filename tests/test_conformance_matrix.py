"""The cross-backend conformance matrix (see tests/conformance.py).

Three layers:

  * ``test_oracle_matches_handwritten`` anchors the matrix oracle
    (``lower_reference`` of the composed program) against k composed
    applications of the hand-written ``repro.core`` kernels.
  * ``test_conformance_1x1`` runs every (program, backend, k) cell on the
    1x1 mesh in-process — the tier-1 parity sweep.
  * ``test_conformance_mesh`` runs the sharded cells of one multi-device
    mesh in an 8-fake-device subprocess (the main pytest process must keep
    seeing 1 device — the dry-run contract), including overlap=True
    bit-match checks. If the subprocess cannot provide the mesh it SKIPS
    with a "mesh ... unavailable" message, which
    ``scripts/check_no_dep_skips.py --fail-on-mesh-skips`` turns into a
    hard failure in the CI multidev-2d job.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from conformance import (
    KS,
    MESHES,
    PROGRAMS,
    assert_case,
    iter_cases,
    make_input,
    mesh_id,
    oracle,
)
from repro.core import ELEMENTARY_FNS, hdiff, hdiff_simple

REPO = Path(__file__).resolve().parent.parent

HANDWRITTEN = dict(ELEMENTARY_FNS)
HANDWRITTEN.update(
    {"hdiff": lambda x: hdiff(x, 0.025), "hdiff_simple": lambda x: hdiff_simple(x, 0.025)}
)


@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_oracle_matches_handwritten(name):
    x = make_input()
    for k in KS:
        want = x
        for _ in range(k):
            want = HANDWRITTEN[name](want)
        np.testing.assert_allclose(
            oracle(name, k), np.asarray(want), rtol=1e-6, atol=1e-6,
            err_msg=f"{name} k={k}",
        )


CASES_1X1 = [
    pytest.param(name, backend, k, id=f"{name}-{backend}-k{k}")
    for name, backend, k, _mesh in iter_cases(((1, 1),))
]


@pytest.mark.parametrize("name,backend,k", CASES_1X1)
def test_conformance_1x1(name, backend, k):
    assert_case(name, backend, k, (1, 1))


MULTIDEV_MESHES = [m for m in MESHES if m != (1, 1)]


@pytest.mark.multidev
@pytest.mark.parametrize("mesh", [pytest.param(m, id=mesh_id(m)) for m in MULTIDEV_MESHES])
def test_conformance_mesh(mesh):
    n_dev = mesh[0] * mesh[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [
            sys.executable,
            str(REPO / "tests" / "multidev" / "_conformance_check.py"),
            "--mesh",
            mesh_id(mesh),
        ],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    if "DEVICES_UNAVAILABLE" in proc.stdout:
        pytest.skip(f"mesh {mesh_id(mesh)} unavailable: {proc.stdout.strip()}")
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL_OK" in proc.stdout
