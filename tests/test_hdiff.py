"""hdiff correctness vs a NumPy loop oracle (Alg. 1 / Eq. 1-4, verbatim)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import hdiff, hdiff_simple, hdiff_staged, make_hdiff_compound


def hdiff_oracle(src: np.ndarray, coeff, limit: bool) -> np.ndarray:
    """Direct transcription of the paper's Algorithm 1 (plus the Eq. 2-3
    limiter when ``limit``). Triple loop; small grids only."""
    src = np.asarray(src, dtype=np.float64)
    depth, rows, cols = src.shape
    coeff_arr = np.broadcast_to(np.asarray(coeff, dtype=np.float64), src.shape)
    dst = src.copy()

    def lap(d, r, c):
        return (
            4.0 * src[d, r, c]
            - src[d, r + 1, c]
            - src[d, r - 1, c]
            - src[d, r, c + 1]
            - src[d, r, c - 1]
        )

    def limited(dlap, dpsi):
        if not limit:
            return dlap
        return dlap if dlap * dpsi <= 0 else 0.0

    for d in range(depth):
        for r in range(2, rows - 2):
            for c in range(2, cols - 2):
                lap_cr = lap(d, r, c)
                lap_rp = lap(d, r + 1, c)
                lap_rm = lap(d, r - 1, c)
                lap_cp = lap(d, r, c + 1)
                lap_cm = lap(d, r, c - 1)
                flx_r = limited(lap_rp - lap_cr, src[d, r + 1, c] - src[d, r, c])
                flx_rm = limited(lap_cr - lap_rm, src[d, r, c] - src[d, r - 1, c])
                flx_c = limited(lap_cp - lap_cr, src[d, r, c + 1] - src[d, r, c])
                flx_cm = limited(lap_cr - lap_cm, src[d, r, c] - src[d, r, c - 1])
                dst[d, r, c] = src[d, r, c] - coeff_arr[d, r, c] * (
                    (flx_r - flx_rm) + (flx_c - flx_cm)
                )
    return dst


@pytest.fixture(scope="module")
def small_grid():
    rng = np.random.default_rng(0)
    return rng.standard_normal((3, 12, 10)).astype(np.float32)


@pytest.mark.parametrize("limit", [True, False])
def test_hdiff_matches_loop_oracle(small_grid, limit):
    coeff = 0.025
    want = hdiff_oracle(small_grid, coeff, limit)
    fn = hdiff if limit else hdiff_simple
    got = np.asarray(fn(jnp.asarray(small_grid), coeff))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_hdiff_per_point_coeff(small_grid):
    rng = np.random.default_rng(1)
    coeff = rng.uniform(0.0, 0.1, size=small_grid.shape).astype(np.float32)
    want = hdiff_oracle(small_grid, coeff, True)
    got = np.asarray(hdiff(jnp.asarray(small_grid), jnp.asarray(coeff)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_hdiff_boundary_passthrough(small_grid):
    out = np.asarray(hdiff(jnp.asarray(small_grid)))
    np.testing.assert_array_equal(out[:, :2, :], small_grid[:, :2, :])
    np.testing.assert_array_equal(out[:, -2:, :], small_grid[:, -2:, :])
    np.testing.assert_array_equal(out[:, :, :2], small_grid[:, :, :2])
    np.testing.assert_array_equal(out[:, :, -2:], small_grid[:, :, -2:])


def test_staged_equals_fused(small_grid):
    x = jnp.asarray(small_grid)
    np.testing.assert_allclose(
        np.asarray(hdiff_staged(x, 0.025, limit=True)),
        np.asarray(hdiff(x, 0.025)),
        rtol=1e-6,
        atol=1e-6,
    )


def test_compound_dag_equals_hdiff(small_grid):
    x = jnp.asarray(small_grid)
    comp = make_hdiff_compound(coeff=0.025, limit=True)
    for policy in ("fused-xla", "staged"):
        np.testing.assert_allclose(
            np.asarray(comp.apply(x, policy=policy)),
            np.asarray(hdiff(x, 0.025)),
            rtol=1e-6,
            atol=1e-6,
        )


def test_hdiff_constant_field_is_fixed_point():
    x = jnp.full((2, 10, 10), 3.25, jnp.float32)
    np.testing.assert_allclose(np.asarray(hdiff(x)), np.asarray(x), rtol=0, atol=0)


def test_hdiff_depth_is_batch_dim(small_grid):
    """Planes must be independent (the paper parallelises over depth)."""
    x = jnp.asarray(small_grid)
    whole = hdiff(x, 0.025)
    per_plane = jnp.stack([hdiff(x[d], 0.025) for d in range(x.shape[0])])
    np.testing.assert_allclose(np.asarray(whole), np.asarray(per_plane), rtol=0, atol=0)
