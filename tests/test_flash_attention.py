"""Chunked online-softmax attention == full-softmax reference."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import layers as L


def _setup(arch="qwen1.5-0.5b", **over):
    cfg = dataclasses.replace(get_smoke_config(arch), compute_dtype="float32", **over)
    p, _ = L.init_attention(cfg, jax.random.PRNGKey(0))
    return cfg, p


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 8])
def test_flash_matches_full(causal, window):
    cfg, p = _setup()
    cfg = dataclasses.replace(cfg, causal=causal)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.float32)
    full, _ = L.attention_apply(cfg, p, x, window=window, force_flash=False)
    flash, _ = L.attention_apply(cfg, p, x, window=window, force_flash=True)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(full), rtol=2e-5, atol=2e-5)


def test_flash_gqa_groups():
    cfg, p = _setup("glm4-9b")  # kv=2 < heads=4
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, cfg.d_model), jnp.float32)
    full, _ = L.attention_apply(cfg, p, x, force_flash=False)
    flash, _ = L.attention_apply(cfg, p, x, force_flash=True)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(full), rtol=2e-5, atol=2e-5)


def test_flash_grads_match():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, cfg.d_model), jnp.float32)

    def loss(p, flash):
        y, _ = L.attention_apply(cfg, p, x, force_flash=flash)
        return jnp.sum(y * y)

    g_full = jax.grad(loss)(p, False)
    g_flash = jax.grad(loss)(p, True)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_flash)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-5)


def test_blocked_local_matches_full_mask():
    """Sliding-window blocked path (S >> window) == masked full softmax."""
    cfg, p = _setup()
    cfg = dataclasses.replace(cfg, causal=True)
    window = 8
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 64, cfg.d_model), jnp.float32)
    full, _ = L.attention_apply(cfg, p, x, window=window, force_flash=False)
    # force_flash=True with S%window==0 and S//window>=2 -> blocked path
    blocked, _ = L.attention_apply(cfg, p, x, window=window, force_flash=True)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(full), rtol=2e-5, atol=2e-5)


def test_blocked_local_grads_match():
    cfg, p = _setup()
    window = 8
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 32, cfg.d_model), jnp.float32)

    def loss(p, flash):
        y, _ = L.attention_apply(cfg, p, x, window=window, force_flash=flash)
        return jnp.sum(y * y)

    g_full = jax.grad(loss)(p, False)
    g_blocked = jax.grad(loss)(p, True)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_blocked)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-5)
