"""IR-lowering tests: paper-grid acceptance, compound policies, validation.

Per-backend/per-program parity cells live in the cross-backend conformance
matrix (tests/conformance.py + tests/test_conformance_matrix.py) — this
file keeps only what the matrix does not cover: the paper-grid acceptance
run, the CompoundStencil policy wrappers, the planners, and the lowering
argument validation (including the 2-D mesh arguments).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    hdiff,
    make_hdiff_compound,
    plan_partition,
)
from repro.ir import (
    ELEMENTARY_PROGRAMS,
    StencilProgram,
    affine,
    hdiff_program,
    lower_pallas,
    lower_reference,
    lower_sharded,
)
from repro.ir import plan_partition as plan_partition_2d
from repro.launch.mesh import make_mesh

RNG = np.random.default_rng(11)


def _grid(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


# --- paper-grid acceptance ----------------------------------------------------


def test_hdiff_all_backends_on_paper_grid():
    """Acceptance: IR-lowered hdiff matches core.hdiff to 1e-6 on the
    paper's 64x256x256 domain (reference + Pallas interpret here; the
    8-device sharded run lives in tests/multidev/_ir_check.py)."""
    x = _grid(64, 256, 256)
    want = np.asarray(hdiff(x, 0.025))
    prog = hdiff_program()
    got_ref = np.asarray(lower_reference(prog)(x))
    np.testing.assert_allclose(got_ref, want, rtol=1e-6, atol=1e-6)
    got_pl = np.asarray(lower_pallas(prog, interpret=True)(x))
    np.testing.assert_allclose(got_pl, want, rtol=1e-6, atol=1e-6)


# --- 1-D programs (outside the 2-D conformance matrix) ------------------------


def test_jacobi1d_program_matches_handwritten():
    from repro.core import ELEMENTARY_FNS

    prog = ELEMENTARY_PROGRAMS["jacobi1d"]()
    x = _grid(4, 16)
    want = np.asarray(ELEMENTARY_FNS["jacobi1d"](x))
    for tag, fn in [
        ("fused", lower_reference(prog)),
        ("staged", lower_reference(prog, mode="staged")),
        ("pallas", lower_pallas(prog, interpret=True)),
    ]:
        got = np.asarray(fn(x))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6, err_msg=tag)


# --- compound policies are thin wrappers over the lowerings -------------------


def test_compound_fused_pallas_policy_now_works():
    x = _grid(2, 16, 12)
    comp = make_hdiff_compound(coeff=0.025, limit=True)
    want = np.asarray(hdiff(x, 0.025))
    for policy in ("fused-xla", "staged", "fused-pallas"):
        got = np.asarray(comp.apply(x, policy=policy))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6, err_msg=policy)
    with pytest.raises(ValueError, match="unknown policy"):
        comp.apply(x, policy="nope")


def test_compound_multi_input_program_runs_all_policies():
    """Multi-input DAGs run every policy now that lower_pallas takes a
    field mapping — including fused-pallas (one ref per field)."""
    from repro.core.compound import CompoundStencil

    prog = StencilProgram(
        "sum2", ["a", "b"],
        [affine("s_a", "a", {(0, 0): 1.0}),
         affine("out", "s_a", {(0, 0): 1.0})],
    )
    comp = CompoundStencil("sum2", prog)
    x = {"a": _grid(2, 8, 8), "b": _grid(2, 8, 8)}
    for policy in ("fused-xla", "staged", "fused-pallas"):
        got = np.asarray(comp.apply(x, policy=policy))
        np.testing.assert_allclose(
            got, np.asarray(x["a"]), rtol=0, atol=0, err_msg=policy
        )


def test_compound_accounting_is_graph_derived():
    comp = make_hdiff_compound()
    assert comp.radius == 2
    assert comp.total_flops(10) == 10 * 72  # 2*26 + 20 per point
    lap = next(s for s in comp.stages if s.name == "lap")
    assert (lap.macs, lap.evaluations) == (5, 5)


def test_plan_partition_accepts_program():
    prog = hdiff_program()
    plan = plan_partition(64, 256, 256, 8, program=prog)
    default = plan_partition(64, 256, 256, 8)
    assert plan == default  # hdiff defaults ARE the derived program numbers
    assert plan.halo == 2
    # A radius-1 program plans with a thinner halo.
    plan1 = plan_partition(64, 256, 256, 8, program=ELEMENTARY_PROGRAMS["laplacian"]())
    assert plan1.halo == 1


def test_plan_partition_2d_minimizes_wire_bytes():
    from repro.dist import halo_exchange_bytes
    from repro.ir import repeat

    prog = hdiff_program()
    plan = plan_partition_2d(prog, 64, 256, 256, 8)
    assert plan.row_shards * plan.col_shards == 8
    assert plan.halo == prog.radius == 2
    # Never worse than the 1-D row baseline; on the square paper grid the
    # balanced split strictly beats it (less boundary surface).
    baseline = halo_exchange_bytes(64, 256, 256, 8, halo=2)
    assert plan.wire_bytes < baseline
    assert plan.mesh_shape == (plan.row_shards, plan.col_shards)
    # Chain radius drives the feasibility floor and the band depth.
    plan3 = plan_partition_2d(repeat(prog, 3), 64, 256, 256, 8)
    assert plan3.halo == 6


def test_plan_partition_2d_rescues_fine_row_mesh():
    """rows/n < halo makes the 1-D row split infeasible — the planner
    routes the excess shards to columns (the fine-mesh error's remedy)."""
    prog = hdiff_program()
    plan = plan_partition_2d(prog, 8, 16, 256, 16)
    assert plan.col_shards > 1
    assert plan.row_shards * plan.col_shards == 16
    with pytest.raises(ValueError, match="factorization"):
        plan_partition_2d(hdiff_program(), 8, 4, 4, 64)


# --- lowering validation ------------------------------------------------------


def test_lower_pallas_rejects_bad_inputs():
    prog = hdiff_program()
    fn = lower_pallas(prog, interpret=True)
    with pytest.raises(ValueError, match="depth, rows, cols"):
        fn(_grid(8, 8))
    with pytest.raises(ValueError, match="not divisible"):
        lower_pallas(prog, block_rows=5, interpret=True)(_grid(2, 16, 12))
    two_in = StencilProgram(
        "two", ["a", "b"], [affine("out", "a", {(0, 0): 1.0})]
    )
    # Multi-input programs lower fine now, but demand a complete mapping.
    fn2 = lower_pallas(two_in, interpret=True)
    with pytest.raises(ValueError, match="pass a mapping"):
        fn2(_grid(2, 8, 8))
    with pytest.raises(ValueError, match="missing"):
        fn2({"a": _grid(2, 8, 8)})
    with pytest.raises(ValueError, match="share one grid"):
        fn2({"a": _grid(2, 8, 8), "b": _grid(2, 8, 16)})


def test_lower_sharded_validates_axes_and_shapes():
    mesh = make_mesh((1, 1), ("data", "model"))
    prog = hdiff_program()
    with pytest.raises(ValueError, match="no axis"):
        lower_sharded(prog, mesh, depth_axis="nope")
    with pytest.raises(ValueError, match="distinct"):
        lower_sharded(prog, mesh, depth_axis="data", row_axis="data")
    with pytest.raises(ValueError, match="distinct"):
        lower_sharded(prog, mesh, depth_axis=None, row_axis="data", col_axis="data")
    with pytest.raises(ValueError, match="inner backend"):
        lower_sharded(prog, mesh, inner="cuda")
    fn = lower_sharded(prog, mesh)
    with pytest.raises(ValueError, match="depth, rows, cols"):
        fn(_grid(4, 4))


def test_lower_sharded_mesh_shape_argument():
    prog = hdiff_program()
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="not both"):
        lower_sharded(prog, mesh, mesh_shape=(1, 1))
    with pytest.raises(ValueError, match="mesh"):
        lower_sharded(prog)
    # mesh_shape fixes the axis names: explicit axis args are a conflict,
    # not silently ignored.
    with pytest.raises(ValueError, match="don't pass"):
        lower_sharded(prog, mesh_shape=(1, 1), row_axis="model")
    with pytest.raises(ValueError, match="don't pass"):
        lower_sharded(prog, mesh_shape=(1, 1), depth_axis="data")
    # mesh_shape builds its own ("rows", "cols") mesh; 1x1 runs anywhere.
    x = _grid(2, 12, 12)
    fn = lower_sharded(prog, mesh_shape=(1, 1), inner="reference")
    np.testing.assert_allclose(
        np.asarray(fn(x)), np.asarray(hdiff(x, 0.025)), rtol=1e-6, atol=1e-6
    )


def test_exchange_band_checks_name_the_remedy():
    """The fine-mesh halo errors (rows/shard or cols/shard < halo) point at
    sharding the OTHER grid axis — the remedy the README documents. The
    checks are static shape checks, so no multi-device mesh is needed here;
    the in-shard_map raises are covered by tests/multidev/_ir_check.py."""
    import jax.numpy as jnp2

    from repro.dist import exchange_halos_2d, exchange_row_halos

    with pytest.raises(ValueError, match="shard the other grid axis"):
        exchange_row_halos(jnp2.zeros((2, 1, 8)), "rows", 4, halo=2)
    with pytest.raises(ValueError, match="cols/shard 2 < halo 4"):
        exchange_halos_2d(jnp2.zeros((2, 8, 2)), "rows", "cols", 1, 4, halo=4)
