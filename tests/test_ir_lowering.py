"""Parity tests: one IR program, three backends, vs the hand-written paths.

Mirrors tests/test_dist_halo_unit.py for the sharded backend: the 1-device
mesh runs in the fast tier-1 path here; 8-fake-device behaviour is covered
by tests/multidev/_ir_check.py via tests/test_ir_multidev.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    ELEMENTARY_FNS,
    hdiff,
    hdiff_simple,
    make_hdiff_compound,
    plan_partition,
)
from repro.ir import (
    ELEMENTARY_PROGRAMS,
    StencilProgram,
    affine,
    hdiff_program,
    lower_pallas,
    lower_reference,
    lower_sharded,
)
from repro.launch.mesh import make_mesh

RNG = np.random.default_rng(11)


def _grid(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


# --- hdiff: all three backends ------------------------------------------------


@pytest.mark.parametrize("limit", [True, False])
def test_hdiff_reference_and_staged_match(limit):
    x = _grid(3, 18, 14)
    prog = hdiff_program(limit=limit)
    want = np.asarray((hdiff if limit else hdiff_simple)(x, 0.025))
    for mode in ("fused", "staged"):
        got = np.asarray(lower_reference(prog, mode=mode)(x))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("limit", [True, False])
def test_hdiff_pallas_matches(limit):
    x = _grid(2, 16, 12)
    prog = hdiff_program(limit=limit)
    want = np.asarray((hdiff if limit else hdiff_simple)(x, 0.025))
    got = np.asarray(lower_pallas(prog, interpret=True)(x))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_hdiff_all_backends_on_paper_grid():
    """Acceptance: IR-lowered hdiff matches core.hdiff to 1e-6 on the
    paper's 64x256x256 domain (reference + Pallas interpret here; the
    8-device sharded run lives in tests/multidev/_ir_check.py)."""
    x = _grid(64, 256, 256)
    want = np.asarray(hdiff(x, 0.025))
    prog = hdiff_program()
    got_ref = np.asarray(lower_reference(prog)(x))
    np.testing.assert_allclose(got_ref, want, rtol=1e-6, atol=1e-6)
    got_pl = np.asarray(lower_pallas(prog, interpret=True)(x))
    np.testing.assert_allclose(got_pl, want, rtol=1e-6, atol=1e-6)


def test_hdiff_sharded_on_host_mesh_matches():
    mesh = make_mesh((1, 1), ("data", "model"))
    x = _grid(3, 16, 12)
    want = np.asarray(hdiff(x, 0.025))
    for inner in ("reference", "pallas"):
        fn = lower_sharded(
            hdiff_program(), mesh, depth_axis="data", row_axis="model", inner=inner
        )
        np.testing.assert_allclose(np.asarray(fn(x)), want, rtol=1e-6, atol=1e-6)


# --- elementary suite ---------------------------------------------------------


@pytest.mark.parametrize("name", sorted(ELEMENTARY_PROGRAMS))
def test_elementary_programs_match_handwritten(name):
    prog = ELEMENTARY_PROGRAMS[name]()
    x = _grid(3, 14, 12) if prog.ndim == 2 else _grid(4, 16)
    want = np.asarray(ELEMENTARY_FNS[name](x))
    for tag, fn in [
        ("fused", lower_reference(prog)),
        ("staged", lower_reference(prog, mode="staged")),
        ("pallas", lower_pallas(prog, interpret=True)),
    ]:
        got = np.asarray(fn(x))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6, err_msg=f"{name}/{tag}")


# --- compound policies are thin wrappers over the lowerings -------------------


def test_compound_fused_pallas_policy_now_works():
    x = _grid(2, 16, 12)
    comp = make_hdiff_compound(coeff=0.025, limit=True)
    want = np.asarray(hdiff(x, 0.025))
    for policy in ("fused-xla", "staged", "fused-pallas"):
        got = np.asarray(comp.apply(x, policy=policy))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6, err_msg=policy)
    with pytest.raises(ValueError, match="unknown policy"):
        comp.apply(x, policy="nope")


def test_compound_multi_input_program_keeps_reference_policies():
    """lower_pallas is single-input only; CompoundStencil must not build it
    eagerly, so staged/fused-xla keep working for multi-input DAGs."""
    from repro.core.compound import CompoundStencil

    prog = StencilProgram(
        "sum2", ["a", "b"],
        [affine("s_a", "a", {(0, 0): 1.0}),
         affine("out", "s_a", {(0, 0): 1.0})],
    )
    comp = CompoundStencil("sum2", prog)  # must not raise
    x = {"a": _grid(2, 8, 8), "b": _grid(2, 8, 8)}
    got = np.asarray(comp.apply(x, policy="fused-xla"))
    np.testing.assert_allclose(got, np.asarray(x["a"]), rtol=0, atol=0)
    with pytest.raises(ValueError, match="single-input"):
        comp.apply(x, policy="fused-pallas")


def test_compound_accounting_is_graph_derived():
    comp = make_hdiff_compound()
    assert comp.radius == 2
    assert comp.total_flops(10) == 10 * 72  # 2*26 + 20 per point
    lap = next(s for s in comp.stages if s.name == "lap")
    assert (lap.macs, lap.evaluations) == (5, 5)


def test_plan_partition_accepts_program():
    prog = hdiff_program()
    plan = plan_partition(64, 256, 256, 8, program=prog)
    default = plan_partition(64, 256, 256, 8)
    assert plan == default  # hdiff defaults ARE the derived program numbers
    assert plan.halo == 2
    # A radius-1 program plans with a thinner halo.
    plan1 = plan_partition(64, 256, 256, 8, program=ELEMENTARY_PROGRAMS["laplacian"]())
    assert plan1.halo == 1


# --- lowering validation ------------------------------------------------------


def test_lower_pallas_rejects_bad_inputs():
    prog = hdiff_program()
    fn = lower_pallas(prog, interpret=True)
    with pytest.raises(ValueError, match="depth, rows, cols"):
        fn(_grid(8, 8))
    with pytest.raises(ValueError, match="not divisible"):
        lower_pallas(prog, block_rows=5, interpret=True)(_grid(2, 16, 12))
    two_in = StencilProgram(
        "two", ["a", "b"], [affine("out", "a", {(0, 0): 1.0})]
    )
    with pytest.raises(ValueError, match="single-input"):
        lower_pallas(two_in)


def test_lower_sharded_validates_axes_and_shapes():
    mesh = make_mesh((1, 1), ("data", "model"))
    prog = hdiff_program()
    with pytest.raises(ValueError, match="no axis"):
        lower_sharded(prog, mesh, depth_axis="nope")
    with pytest.raises(ValueError, match="distinct"):
        lower_sharded(prog, mesh, depth_axis="data", row_axis="data")
    with pytest.raises(ValueError, match="inner backend"):
        lower_sharded(prog, mesh, inner="cuda")
    fn = lower_sharded(prog, mesh)
    with pytest.raises(ValueError, match="depth, rows, cols"):
        fn(_grid(4, 4))
