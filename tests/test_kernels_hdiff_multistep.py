"""Temporal-blocked hdiff kernel == hdiff(hdiff(x)) composed oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import hdiff, hdiff_simple
from repro.kernels.hdiff.multistep import hdiff_twostep


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


@pytest.mark.parametrize("shape", [(1, 16, 12), (2, 32, 32), (1, 64, 48)])
@pytest.mark.parametrize("limit", [True, False])
def test_twostep_matches_composed(shape, limit):
    x = _rand(shape, seed=shape[1])
    ref = hdiff if limit else hdiff_simple
    want = ref(ref(x, 0.025), 0.025)
    got = hdiff_twostep(x, 0.025, limit=limit, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_rows", [8, 16, 32])
def test_twostep_block_sweep(block_rows):
    x = _rand((1, 32, 24), seed=5)
    want = hdiff(hdiff(x, 0.05), 0.05)
    got = hdiff_twostep(x, 0.05, block_rows=block_rows, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_twostep_boundary_ring_preserved():
    x = _rand((1, 20, 20), seed=7)
    got = np.asarray(hdiff_twostep(x, interpret=True))
    np.testing.assert_array_equal(got[:, :2, :], np.asarray(x[:, :2, :]))
    np.testing.assert_array_equal(got[:, -2:, :], np.asarray(x[:, -2:, :]))


def test_twostep_rejects_tiny_blocks():
    x = _rand((1, 16, 16))
    with pytest.raises(ValueError):
        hdiff_twostep(x, block_rows=4, interpret=True)


def test_twostep_block_rows_not_silently_clamped():
    """block_rows used to be clamped by min(block_rows, rows) BEFORE the
    divisibility check, so a passing call could flip to an error when rows
    changed; an explicit block_rows is now validated as given."""
    x = _rand((1, 16, 16))
    with pytest.raises(ValueError, match="not divisible"):
        hdiff_twostep(x, block_rows=128, interpret=True)


def test_twostep_default_resolves_via_shared_planner():
    """Default block_rows goes through the shared VMEM planner like
    hdiff_fused / hdiff_fixed, honouring the vmem_budget kwarg."""
    x = _rand((1, 32, 16), seed=9)
    want = hdiff(hdiff(x, 0.025), 0.025)
    # 16-row tiles: 32*16*4 B budget => 16 rows of 16 f32 cols.
    got = hdiff_twostep(x, 0.025, interpret=True, vmem_budget=16 * 16 * 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    # The planner respects the two-step structural floor (4*HALO = 8).
    got = hdiff_twostep(x, 0.025, interpret=True, vmem_budget=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_twostep_is_a_repeat_wrapper():
    """The kernel is now repeat(hdiff_program(), 2) through the generic
    k-step Pallas lowering — parity with that path is exact."""
    from repro.ir import hdiff_program, lower_pallas, repeat

    x = _rand((2, 32, 24), seed=3)
    via_ir = lower_pallas(repeat(hdiff_program(0.05), 2), interpret=True)(x)
    via_wrapper = hdiff_twostep(x, 0.05, interpret=True)
    np.testing.assert_array_equal(np.asarray(via_wrapper), np.asarray(via_ir))
