"""Property-based tests (hypothesis) for the 2-axis halo wire model and the
2-D partition planner.

Invariants:
  * ``halo_exchange_bytes`` is symmetric under (rows, R) <-> (cols, C)
    transpose of grid + mesh;
  * it is linear in grid depth and itemsize, and linear in ``steps`` when
    only ONE axis is sharded (row-only reduces exactly to the PR 1
    formula); with BOTH axes sharded the diagonal corner patches are
    (halo * steps)^2, so the steps-superlinearity is exactly the closed
    corner term — deep temporal-blocked halos pay a quadratic (but tiny)
    corner tax;
  * ``plan_partition`` never models more wire traffic than the 1-D row
    baseline (R = n_devices, C = 1) whenever that baseline is feasible,
    and always returns a true factorization of the device count.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.dist import halo_exchange_bytes  # noqa: E402
from repro.ir import StencilProgram, affine, plan_partition  # noqa: E402

meshes = st.tuples(st.integers(1, 8), st.integers(1, 8))
dims = st.tuples(st.integers(1, 64), st.integers(8, 512), st.integers(8, 512))
halos = st.integers(1, 4)
steps = st.integers(1, 4)


@settings(max_examples=100, deadline=None)
@given(dims, meshes, halos, steps, st.sampled_from([2, 4, 8]))
def test_wire_model_transpose_symmetric(dim, mesh, halo, k, itemsize):
    depth, rows, cols = dim
    r_sh, c_sh = mesh
    fwd = halo_exchange_bytes(
        depth, rows, cols, r_sh, itemsize=itemsize, halo=halo, steps=k, col_shards=c_sh
    )
    swapped = halo_exchange_bytes(
        depth, cols, rows, c_sh, itemsize=itemsize, halo=halo, steps=k, col_shards=r_sh
    )
    assert fwd == swapped


@settings(max_examples=100, deadline=None)
@given(dims, meshes, halos, steps, st.integers(2, 5))
def test_wire_model_linear_in_depth_and_itemsize(dim, mesh, halo, k, m):
    depth, rows, cols = dim
    r_sh, c_sh = mesh
    one = halo_exchange_bytes(depth, rows, cols, r_sh, halo=halo, steps=k, col_shards=c_sh)
    assert halo_exchange_bytes(
        m * depth, rows, cols, r_sh, halo=halo, steps=k, col_shards=c_sh
    ) == m * one
    assert halo_exchange_bytes(
        depth, rows, cols, r_sh, itemsize=4 * m, halo=halo, steps=k, col_shards=c_sh
    ) == m * one


@settings(max_examples=100, deadline=None)
@given(dims, st.integers(2, 8), halos, steps)
def test_wire_model_single_axis_linear_in_steps_and_reduces_to_1d(dim, n, halo, k):
    """With one sharded axis there are no corners: bytes are k-linear and
    the row-only form IS the PR 1 formula (col-only is its transpose)."""
    depth, rows, cols = dim
    row_only = halo_exchange_bytes(depth, rows, cols, n, halo=halo, steps=k)
    assert row_only == 2 * (n - 1) * depth * halo * k * cols * 4
    assert row_only == k * halo_exchange_bytes(depth, rows, cols, n, halo=halo)
    col_only = halo_exchange_bytes(depth, rows, cols, 1, halo=halo, steps=k, col_shards=n)
    assert col_only == 2 * (n - 1) * depth * halo * k * rows * 4
    assert col_only == k * halo_exchange_bytes(
        depth, rows, cols, 1, halo=halo, col_shards=n
    )


@settings(max_examples=100, deadline=None)
@given(dims, st.tuples(st.integers(2, 8), st.integers(2, 8)), halos, steps)
def test_wire_model_steps_superlinearity_is_exactly_the_corners(dim, mesh, halo, k):
    depth, rows, cols = dim
    r_sh, c_sh = mesh
    per_k = halo_exchange_bytes(depth, rows, cols, r_sh, halo=halo, steps=k, col_shards=c_sh)
    per_1 = halo_exchange_bytes(depth, rows, cols, r_sh, halo=halo, col_shards=c_sh)
    corner_excess = 4 * (r_sh - 1) * (c_sh - 1) * depth * (k * k - k) * halo * halo * 4
    assert per_k - k * per_1 == corner_excess


def _radius_r_program(r: int) -> StencilProgram:
    taps = {(0, 0): 1.0}
    for d in range(1, r + 1):
        taps.update({(d, 0): 1.0, (-d, 0): 1.0, (0, d): 1.0, (0, -d): 1.0})
    return StencilProgram("star", ["x"], [affine("out", "x", taps)])


@settings(max_examples=60, deadline=None)
@given(
    st.integers(1, 3),                      # program radius
    st.sampled_from([2, 4, 8, 16]),         # n_devices
    st.integers(1, 8),                      # rows per shard, scaled to >= halo
    st.integers(1, 64),                     # depth
    st.integers(1, 16),                     # cols scale
)
def test_plan_partition_never_beaten_by_1d_baseline(r, n, rows_scale, depth, cols_scale):
    prog = _radius_r_program(r)
    halo = prog.radius
    rows = n * max(rows_scale, halo)        # (n, 1) baseline is feasible
    cols = cols_scale * halo
    plan = plan_partition(prog, depth, rows, cols, n)
    assert plan.row_shards * plan.col_shards == n
    baseline = halo_exchange_bytes(depth, rows, cols, n, halo=halo)
    assert plan.wire_bytes <= baseline
    # The planner's choice is feasible by its own floor rules.
    if plan.row_shards > 1:
        assert rows // plan.row_shards >= halo
    if plan.col_shards > 1:
        assert cols // plan.col_shards >= halo
