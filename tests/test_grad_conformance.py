"""Gradient conformance on multi-device meshes (the autodiff CI lane).

Each cell is jax.grad through a ``build_backend(..., differentiable=True)``
sharded lowering — the derived adjoint of :mod:`repro.ir.autodiff` running
its backward through ``lower_sharded(..., boundary="zero")`` and the real
``ppermute`` halo exchange — checked against jax.grad of ``lower_reference``
plus the EXACT backward wire model
(:func:`repro.dist.halo.gradient_halo_exchange_bytes_per_shard`).

Same subprocess idiom as test_conformance_matrix.py: one forked interpreter
per mesh with ``XLA_FLAGS=--xla_force_host_platform_device_count`` (fake
devices must be set before jax imports). The body lives in
``tests/multidev/_grad_check.py``; DEVICES_UNAVAILABLE becomes a pytest
skip that ``scripts/check_no_dep_skips.py --fail-on-mesh-skips`` converts
to a hard CI failure. The single-device cells of the same grad matrix run
in tier-1 (test_ir_autodiff.py).
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

from conformance import MESHES, mesh_id

REPO = Path(__file__).resolve().parent.parent

MULTIDEV_MESHES = [m for m in MESHES if m != (1, 1)]


@pytest.mark.multidev
@pytest.mark.parametrize(
    "mesh", [pytest.param(m, id=mesh_id(m)) for m in MULTIDEV_MESHES]
)
def test_grad_conformance_mesh(mesh):
    n_dev = mesh[0] * mesh[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [
            sys.executable,
            str(REPO / "tests" / "multidev" / "_grad_check.py"),
            "--mesh",
            mesh_id(mesh),
        ],
        capture_output=True,
        text=True,
        env=env,
        timeout=1200,
    )
    if "DEVICES_UNAVAILABLE" in proc.stdout:
        pytest.skip(f"mesh {mesh_id(mesh)} unavailable: {proc.stdout.strip()}")
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL_OK" in proc.stdout
