"""Multi-output StencilPrograms: unit tests for the coupled-system schema.

Covers what the conformance matrix (parity) and the property file
(analysis invariants) do not:

  * ``fingerprint()`` / ``__eq__`` / ``__hash__`` — structural identity is
    content-addressed (coefficients, offsets, outputs all included; the
    display name excluded), and programs are usable as dict/set keys;
  * the op-name / input-name collision diagnostic names BOTH colliding
    sides (regression: it used to report a generic duplicate);
  * multi-output graph analysis (per-output radii, exchange radii, §3.1
    fused-byte accounting counting inputs + outputs);
  * compose binding rules for multi-output programs (name-matched, with
    mismatched evolving sets rejected);
  * single-device lowering parity smoke for both shipped coupled systems.

The sharded merged-exchange behaviour (one exchange per k sweeps,
measured == model, merge_exchange=False baseline) lives in the multidev
subprocess checks (tests/multidev/_ir_check.py) — it needs 8 devices.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.ir import (
    StencilProgram,
    advection_diffusion_program,
    affine,
    interior_eval_multi,
    lower_pallas,
    lower_reference,
    lower_sharded,
    repeat,
    scaled_residual,
    shallow_water_program,
)


def _fields(prog, shape=(2, 12, 12), seed=7):
    rng = np.random.default_rng(seed)
    return {f: jnp.asarray(rng.standard_normal(shape).astype(np.float32))
            for f in prog.inputs}


# ---------------------------------------------------------------------------
# fingerprint / __eq__ / __hash__ (satellite: structural identity)
# ---------------------------------------------------------------------------


def test_fingerprint_is_deterministic_and_name_blind():
    a, b = shallow_water_program(), shallow_water_program()
    assert a.fingerprint() == b.fingerprint()
    assert a == b and hash(a) == hash(b)
    # The display name is NOT part of the structure.
    renamed = StencilProgram(
        "not_shallow_water", a.inputs, a.ops, ndim=a.ndim,
        passthrough=a.passthrough, outputs=dict(a.outputs),
    )
    assert renamed.fingerprint() == a.fingerprint()
    assert renamed == a


def test_fingerprint_sees_coefficients_offsets_and_outputs():
    base = shallow_water_program()
    # A closure-baked scalar coefficient changes the fingerprint.
    assert shallow_water_program(g_dt=0.3) != base
    assert shallow_water_program(g_dt=0.3).fingerprint() != base.fingerprint()
    # An offset change (same op names, same costs) changes the fingerprint.
    p1 = StencilProgram("p", ["x"], [affine("o", "x", {(1, 0): 1.0})])
    p2 = StencilProgram("p", ["x"], [affine("o", "x", {(0, 1): 1.0})])
    assert p1 != p2 and p1.fingerprint() != p2.fingerprint()
    # Same ops, different outputs declaration -> different program.
    ops = [
        affine("a_new", "a", {(0, 0): 1.0, (1, 0): -1.0}),
        affine("b_new", "b", {(0, 0): 1.0, (0, 1): -1.0}),
    ]
    both = StencilProgram("p", ["a", "b"], ops,
                          outputs={"a": "a_new", "b": "b_new"})
    only_a = StencilProgram("p", ["a", "b"], ops, outputs={"a": "a_new"})
    assert both != only_a and both.fingerprint() != only_a.fingerprint()


def test_programs_are_hashable_keys():
    cache = {shallow_water_program(): "sw", advection_diffusion_program(): "ad"}
    assert cache[shallow_water_program()] == "sw"
    assert cache[advection_diffusion_program()] == "ad"
    assert len({shallow_water_program(), shallow_water_program()}) == 1
    # repeat() changes the chain, hence the identity.
    assert repeat(shallow_water_program(), 2) != shallow_water_program()


# ---------------------------------------------------------------------------
# construction diagnostics (satellite: op/input collision names both)
# ---------------------------------------------------------------------------


def test_op_input_collision_names_both_sides():
    with pytest.raises(ValueError) as e:
        StencilProgram("p", ["u", "h"], [affine("h", "u", {(0, 0): 1.0})])
    msg = str(e.value)
    assert "op 'h' collides with source input 'h'" in msg
    assert "rename the op" in msg
    # Op-op duplicates keep the distinct classic diagnostic.
    with pytest.raises(ValueError, match="duplicate field name 'o'"):
        StencilProgram("p", ["u"], [
            affine("o", "u", {(0, 0): 1.0}),
            affine("o", "u", {(1, 0): 1.0}),
        ])


def test_outputs_validation_errors():
    ops = [affine("u_new", "u", {(0, 0): 1.0})]
    with pytest.raises(ValueError, match="are not program inputs"):
        StencilProgram("p", ["u"], ops, outputs={"w": "u_new"})
    with pytest.raises(ValueError, match="names no op"):
        StencilProgram("p", ["u"], ops, outputs={"u": "nope"})
    with pytest.raises(ValueError, match="must not be empty"):
        StencilProgram("p", ["u"], ops, outputs={})
    ops2 = ops + [affine("v_new", "v", {(0, 0): 1.0})]
    with pytest.raises(ValueError, match="map two evolving fields to one"):
        StencilProgram("p", ["u", "v"], ops2,
                       outputs={"u": "u_new", "v": "u_new"})
    with pytest.raises(ValueError, match="must be one of the evolving"):
        StencilProgram("p", ["u", "v"], ops2, passthrough="v",
                       outputs={"u": "u_new"})


# ---------------------------------------------------------------------------
# graph analysis
# ---------------------------------------------------------------------------


def test_shallow_water_analysis():
    sw = shallow_water_program()
    assert tuple(sw.outputs) == ("u", "v", "h")
    assert sw.output_radii() == {"u": 1, "v": 1, "h": 1}
    assert sw.exchange_radii() == {"u": 1, "v": 1, "h": 1}
    assert sw.radius == 1
    pk = repeat(sw, 3)
    assert pk.output_radii() == {"u": 3, "v": 3, "h": 3}
    assert pk.exchange_radii() == {"u": 3, "v": 3, "h": 3}
    # Fused bytes count every input once and every output once.
    assert sw.fused_bytes(100) == (3 + 3) * 100 * 4


def test_advection_diffusion_analysis():
    ad = advection_diffusion_program()
    assert tuple(ad.outputs) == ("c", "u")
    assert ad.output_radii() == {"c": 1, "u": 1}
    # v is read at offset zero only: radius 0, NO exchange at k=1 ...
    assert ad.field_radius("v") == 0
    assert ad.exchange_radii() == {"c": 1, "u": 1, "v": 0}
    # ... and a (k-1)-deep exchange under temporal blocking (the downstream
    # sweeps read v inside regions the upstream sweeps shrank).
    p3 = repeat(ad, 3)
    assert p3.exchange_radii() == {"c": 3, "u": 3, "v": 2}
    assert ad.fused_bytes(100) == (3 + 2) * 100 * 4


def test_interior_eval_multi_returns_every_output():
    sw = shallow_water_program()
    arrs = _fields(sw)
    interiors = interior_eval_multi(sw, arrs)
    assert set(interiors) == {"u", "v", "h"}
    # Each output is evaluated on its OWN margins (u_new insets rows only,
    # v_new cols only, h_new both) — the per-output footprint accounting.
    for f, v in interiors.items():
        lows, highs = sw.output_margins(f)
        assert v.shape == (
            2, 12 - lows[0] - highs[0], 12 - lows[1] - highs[1]
        ), f


# ---------------------------------------------------------------------------
# compose binding
# ---------------------------------------------------------------------------


def test_compose_rejects_mismatched_evolving_sets():
    sw = shallow_water_program()
    ad = advection_diffusion_program()
    # Downstream evolves {u} only: no name-matched binding for {u, v, h}.
    down = StencilProgram("down", ["u"], [affine("u_new", "u", {(0, 0): 1.0})])
    with pytest.raises(ValueError, match="bind outputs by FIELD NAME"):
        sw.compose(down)
    with pytest.raises(ValueError):
        sw.compose(ad)


def test_compose_rejects_evolved_field_read_as_shared():
    """A downstream sweep that reads one of our evolving fields as a
    frozen shared input would silently see the UPDATED state."""
    ad = advection_diffusion_program()  # evolves {c, u}, shares v
    downstream = StencilProgram(
        "uses_u_frozen", ["c", "u"],
        [affine("c_new", "c", {(0, 0): 1.0}),
         scaled_residual("c2", "c_new", [("u", 1)], 0.5)],
        outputs={"c": "c2"},
    )
    with pytest.raises(ValueError, match="evolving field"):
        ad.compose(downstream)


def test_repeat_preserves_output_schema():
    for prog in (shallow_water_program(), advection_diffusion_program()):
        pk = repeat(prog, 2)
        assert tuple(pk.outputs) == tuple(prog.outputs)
        assert pk.passthrough == prog.passthrough
        assert pk.steps == 2


# ---------------------------------------------------------------------------
# single-device lowering parity smoke (full matrix: tests/conformance.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("factory", [shallow_water_program,
                                     advection_diffusion_program])
def test_lowerings_agree_on_dict_results(factory):
    prog = repeat(factory(), 2)
    arrs = _fields(prog)
    want = lower_reference(prog)(arrs)
    assert set(want) == set(prog.outputs)
    for build in (
        lambda p: lower_reference(p, mode="staged"),
        lambda p: lower_pallas(p, interpret=True),
        lambda p: lower_sharded(p, mesh_shape=(1, 1)),
    ):
        got = build(prog)(arrs)
        assert set(got) == set(want)
        for f in want:
            np.testing.assert_allclose(
                np.asarray(got[f]), np.asarray(want[f]),
                rtol=1e-6, atol=1e-6, err_msg=f,
            )
    # The chain applies the ring passthrough PER SWEEP, so the outermost
    # single-sweep ring (radius 1 here) is unchanged after any k.
    r = factory().radius
    for f in want:
        ring = np.ones(arrs[f].shape[-2:], bool)
        ring[r:-r, r:-r] = False
        np.testing.assert_array_equal(
            np.asarray(want[f])[..., ring], np.asarray(arrs[f])[..., ring]
        )
