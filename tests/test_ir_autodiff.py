"""Tier-1 tests for the derived adjoints (repro.ir.autodiff).

Four layers:
  * structure — the adjoint program's radii equal the primal's (the load-
    bearing invariant: backward halo exchange reuses the primal wire plan),
    nonlinear programs stash caches, affine ones do not;
  * gradient conformance, single device — jax.grad through every
    ``build_backend(..., differentiable=True)`` lowering (reference,
    staged, pallas-interpret) vs jax.grad of ``lower_reference``, drawn
    from the same matrix as the forward cells (tests/conformance.py); the
    multi-device meshes run in test_grad_conformance.py;
  * the zero-extension boundary mode of ``lower_sharded`` that the sharded
    backward builds on, plus its validation errors;
  * consumers — ``hdiff_fused_ad`` (the Pallas kernel's custom_vjp is now
    the derived adjoint; regression vs the jax.vjp-of-reference oracle it
    used to hand-wire) and the data-assimilation fit
    (``repro.train.assimilate``), whose >=10x loss drop is the end-to-end
    gradient-quality acceptance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conformance import (
    GRID,
    KS,
    PROGRAMS,
    SEED,
    assert_close,
    assert_grad_case,
    make_fields,
    to_host,
)
from repro.core.hdiff import hdiff as hdiff_ref
from repro.core.hdiff import hdiff_simple as hdiff_simple_ref
from repro.ir import (
    adjoint,
    augmented_forward,
    cache_fields,
    lower_reference,
    lower_sharded,
    pad_widths,
    repeat,
)
from repro.ir import programs as P
from repro.kernels.hdiff.ops import hdiff_fused, hdiff_fused_ad
from repro.train import AssimilationConfig, fit_coefficient_field
from repro.train.assimilate import synthetic_observations, true_coefficients

ROSTER = sorted(PROGRAMS)


# -- structure ----------------------------------------------------------------


@pytest.mark.parametrize("name", ROSTER)
@pytest.mark.parametrize("k", KS)
def test_adjoint_radii_match_primal(name, k):
    """Adjoint sweeps move halos at exactly the primal's radii — per chain
    entry, so the backward's exchange schedule is the forward's mirrored."""
    p = repeat(PROGRAMS[name](), k)
    for q in p.chain:
        assert adjoint(q).radius == q.radius
        assert augmented_forward(q).radius == q.radius


def test_cache_fields_only_for_nonlinear():
    """Affine programs linearize to themselves (no primal saved); the flux
    limiter and the vadvc products must stash their linearization points."""
    assert cache_fields(P.hdiff_program()) == ("lap",)
    assert cache_fields(P.hdiff_program(limit=False)) == ()
    assert cache_fields(P.vadvc_program()) == ("wbar", "grad")
    assert cache_fields(P.laplacian_program()) == ()
    assert cache_fields(P.shallow_water_program()) == ()


def test_pad_widths_cover_both_sweeps():
    p = P.hdiff_program()
    pads = pad_widths(p, GRID)
    assert len(pads) == len(GRID)
    r = max(p.radius, adjoint(p).radius)
    assert all(pw == (r, r) for pw in pads)


# -- gradient conformance, single device --------------------------------------
# reference and staged grads are cheap: full roster x k. pallas-interpret
# compiles both the fused forward kernel and the fused adjoint kernel per
# cell, so it runs the same representative subset the batched matrix uses
# (single-input chain, coupled multi-output system, multi-field workload);
# the full pallas roster runs on the fake-device meshes in the multidev lane.

GRAD_CELLS = [
    pytest.param(name, backend, k, id=f"{name}-{backend}-k{k}")
    for backend in ("reference", "staged")
    for name in ROSTER
    for k in KS
] + [
    pytest.param(name, "pallas", k, id=f"{name}-pallas-k{k}")
    for name in ("hdiff", "shallow_water", "hdiff_coupled")
    for k in (1, 2)
]


@pytest.mark.parametrize("name,backend,k", GRAD_CELLS)
def test_grad_conformance_1x1(name, backend, k):
    assert_grad_case(name, backend, k, (1, 1))


def test_grad_zero_on_ring():
    """The primal passes the boundary ring through untouched, so seed
    cotangents landing on interior outputs must pull back zero onto the
    ring — and coeff (which only enters at interior points) gets an
    exactly-zero ring gradient."""
    from conformance import build_grad, grad_loss, make_loss_weights

    p = P.hdiff_coupled_program()
    fn = build_grad(p, "reference", (1, 1))
    w = make_loss_weights("hdiff_coupled", 1)
    g = jax.grad(grad_loss(fn, w))(make_fields("hdiff_coupled"))
    r = p.radius
    ring = np.ones(GRID[-2:], bool)
    ring[r:-r, r:-r] = False
    gc = np.asarray(g["coeff"])
    assert np.all(gc[..., ring] == 0.0)
    assert np.abs(gc[..., ~ring]).max() > 0.0


# -- lower_sharded boundary="zero" --------------------------------------------


@pytest.mark.parametrize("name", ["hdiff", "vadvc", "shallow_water"])
def test_lower_sharded_zero_boundary_single_device(name):
    """boundary="zero" evaluates the merged DAG over the zero-extended
    grid (no passthrough ring). Oracle: zero-pad every input by the
    program radius, run the ring lowering, crop — the ring of the padded
    problem falls entirely in the sliced-off frame."""
    p = PROGRAMS[name]()
    r = p.radius
    x = make_fields(name)
    fn = lower_sharded(p, mesh_shape=(1, 1), boundary="zero")
    got = to_host(fn(x))

    def padz(a):
        return jnp.pad(a, [(0, 0)] * (a.ndim - 2) + [(r, r), (r, r)])

    xp = {f: padz(a) for f, a in x.items()} if isinstance(x, dict) else padz(x)
    ref = lower_reference(p)(xp)

    def crop(a):
        return np.asarray(a)[..., r:-r, r:-r]

    want = (
        {f: crop(a) for f, a in ref.items()} if isinstance(ref, dict) else crop(ref)
    )
    assert_close(got, want, err_msg=f"zero-boundary {name}")


def test_lower_sharded_zero_boundary_rejects_chains():
    p = repeat(P.hdiff_program(), 2)
    with pytest.raises(ValueError, match="zero"):
        lower_sharded(p, mesh_shape=(1, 1), boundary="zero")


def test_lower_sharded_rejects_unknown_boundary():
    with pytest.raises(ValueError, match="boundary"):
        lower_sharded(P.hdiff_program(), mesh_shape=(1, 1), boundary="mirror")


# -- hdiff_fused_ad: kernel forward + derived-adjoint backward ----------------


@pytest.mark.parametrize("limit", [True, False], ids=["limit", "simple"])
def test_hdiff_fused_ad_matches_reference_vjp(limit):
    """The kernel wrapper's derived-adjoint backward must match the
    jax.vjp-of-reference backward it replaced (scalar coefficient, the
    only form the Pallas forward accepts). Not bit-equal — the adjoint
    associates its sums differently — but well inside GRAD_TOL."""
    rng = np.random.default_rng(SEED)
    psi = jnp.asarray(rng.standard_normal((2, 24, 24)).astype(np.float32))
    coeff = jnp.float32(0.03)
    g = jnp.asarray(rng.standard_normal(psi.shape).astype(np.float32))

    primal = hdiff_fused_ad(psi, coeff, limit)
    np.testing.assert_array_equal(
        np.asarray(primal), np.asarray(hdiff_fused(psi, coeff, limit=limit))
    )

    _, pull = jax.vjp(lambda p, c: hdiff_fused_ad(p, c, limit), psi, coeff)
    dpsi, dcoeff = pull(g)
    ref = hdiff_ref if limit else hdiff_simple_ref
    _, pull_ref = jax.vjp(lambda p, c: ref(p, c), psi, coeff)
    dpsi_ref, dcoeff_ref = pull_ref(g)

    rel = float(jnp.abs(dpsi - dpsi_ref).max()) / float(jnp.abs(dpsi_ref).max())
    assert rel < 1e-5, f"dpsi relative error {rel:.3e}"
    denom = max(abs(float(dcoeff_ref)), 1e-30)
    assert abs(float(dcoeff) - float(dcoeff_ref)) / denom < 1e-5


# -- data assimilation: the first gradient consumer ---------------------------


def test_fit_coefficient_field_converges():
    """3D-Var-style twin experiment on a small grid: recover the true
    Smagorinsky coefficient field from noise-free observations. The >=10x
    first-to-best loss drop is the PR's end-to-end acceptance; it only
    happens if the coeff cotangents of the derived adjoint are right."""
    grid = (2, 16, 16)
    cfg = AssimilationConfig(steps=40)
    u0 = jnp.asarray(
        np.random.default_rng(SEED).standard_normal(grid).astype(np.float32)
    )
    coeff_true = true_coefficients(grid, seed=1)
    obs = synthetic_observations(u0, coeff_true, cfg)
    res = fit_coefficient_field(u0, obs, cfg)
    assert res.loss_ratio >= 10.0, f"loss only improved {res.loss_ratio:.1f}x"
    assert res.losses[-1] < res.losses[0]
    # The boundary ring never receives gradient; it keeps the first guess.
    ring = np.ones(grid[-2:], bool)
    ring[2:-2, 2:-2] = False
    np.testing.assert_array_equal(
        np.asarray(res.coeff)[..., ring], np.float32(0.025)
    )
