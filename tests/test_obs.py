"""repro.obs: metrics registry, drift detector, run reports, profiler hooks,
and the instrumented layers that report through them.

Covers the observability contracts:

  * zero-overhead disabled path (module hooks are no-ops, the timer is the
    shared null singleton, nothing is recorded);
  * timer nesting records under the joined ``outer/inner`` path;
  * counter/gauge/timer reset;
  * ``instrument_call``: records when enabled, passes through when disabled,
    steps aside on tracer arguments (and never changes the result);
  * drift detector inside/outside tolerance + registry side channel;
  * run-report metadata (the BENCH_*.json provenance block);
  * ``maybe_trace`` env gating;
  * ``BatchedServer`` telemetry (queue latency, occupancy, tokens/sec) on
    the result objects AND in the registry;
  * the full instrumented stack on 8 fake devices (subprocess, multidev):
    measured collective bytes == per-field model with metrics on.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.obs import (
    DriftResult,
    MATCH_KEYS,
    MetricsRegistry,
    RunReport,
    check_drift,
    maybe_trace,
    metrics,
    runtime_metadata,
)

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _metrics_off():
    """Every test starts and ends with metrics disabled (the default)."""
    prev = metrics.current()
    metrics.disable()
    yield
    if prev is not None:
        metrics.enable(prev)
    else:
        metrics.disable()


# --- registry core --------------------------------------------------------


def test_counters_gauges_and_snapshot_roundtrip():
    reg = MetricsRegistry()
    assert reg.inc("a") == 1.0
    assert reg.inc("a", 2.5) == 3.5
    reg.set_gauge("g", 7)
    with reg.timer("t"):
        pass
    snap = reg.snapshot()
    assert snap["counters"] == {"a": 3.5}
    assert snap["gauges"] == {"g": 7.0}
    assert snap["timers"]["t"]["count"] == 1
    json.dumps(snap)  # must be JSON-serialisable as-is


def test_timer_nesting_records_joined_path():
    reg = MetricsRegistry()
    with reg.timer("outer"):
        with reg.timer("inner"):
            pass
        with reg.timer("inner"):
            pass
    assert sorted(reg.timers) == ["outer", "outer/inner"]
    assert reg.timers["outer/inner"].count == 2
    assert reg.timers["outer"].count == 1
    assert reg.timers["outer"].total_s >= reg.timers["outer/inner"].total_s


def test_observe_records_external_duration():
    reg = MetricsRegistry()
    reg.observe("lat", 0.25)
    reg.observe("lat", 0.75)
    stat = reg.timers["lat"].as_dict()
    assert stat["count"] == 2
    assert stat["min_s"] == 0.25
    assert stat["max_s"] == 0.75
    assert stat["mean_s"] == 0.5


def test_reset_clears_everything():
    reg = MetricsRegistry()
    reg.inc("c")
    reg.set_gauge("g", 1)
    with reg.timer("t"):
        pass
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "timers": {}}


# --- disabled path: zero overhead -----------------------------------------


def test_disabled_hooks_are_noops():
    assert metrics.current() is None
    metrics.inc("never")
    metrics.set_gauge("never", 1)
    metrics.observe("never", 1.0)
    # The disabled timer is the SHARED null singleton — no allocation.
    t1, t2 = metrics.timer("a"), metrics.timer("b")
    assert t1 is t2 is metrics._NULL_TIMER
    with t1:
        pass
    # Enabling afterwards starts empty: nothing leaked through.
    reg = metrics.enable()
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "timers": {}}


def test_using_scopes_and_restores():
    with metrics.using() as reg:
        assert metrics.current() is reg
        metrics.inc("x")
        assert reg.counters["x"] == 1.0
    assert metrics.current() is None


# --- instrument_call ------------------------------------------------------


def test_instrument_call_records_when_enabled():
    fn = metrics.instrument_call(lambda a: a + 1, "test.fn")
    assert fn(1) == 2  # disabled: pure passthrough, nothing recorded
    with metrics.using() as reg:
        assert fn(jnp.float32(2)) == 3
        assert fn(jnp.float32(3)) == 4
        assert reg.counters["test.fn.calls"] == 2.0
        assert reg.timers["test.fn"].count == 2
    assert fn.metric_name == "test.fn"


def test_instrument_call_steps_aside_under_trace():
    fn = metrics.instrument_call(lambda a: a * 2, "test.traced")
    with metrics.using() as reg:
        out = jax.jit(fn)(jnp.arange(4.0))
        np.testing.assert_array_equal(np.asarray(out), [0.0, 2.0, 4.0, 6.0])
        # Trace-time execution must NOT pollute wall-clock stats.
        assert "test.traced" not in reg.timers
        assert "test.traced.calls" not in reg.counters


# --- drift detector -------------------------------------------------------


def test_drift_inside_tolerance():
    reg = MetricsRegistry()
    d = check_drift("wire", measured=1005, model=1000, tolerance=0.01, registry=reg)
    assert isinstance(d, DriftResult)
    assert d.ok and abs(d.ratio - 1.005) < 1e-12
    assert reg.counters["wire.measured_bytes"] == 1005
    assert reg.counters["wire.model_bytes"] == 1000
    assert reg.gauges["wire.ratio"] == d.ratio
    assert "wire.drift_flags" not in reg.counters


def test_drift_outside_tolerance_flags():
    reg = MetricsRegistry()
    d = check_drift("wire", measured=1100, model=1000, tolerance=0.01, registry=reg)
    assert not d.ok
    assert reg.counters["wire.drift_flags"] == 1.0
    assert "ratio=1.1" in d.describe()


def test_drift_zero_model_edge():
    assert check_drift("z", measured=0, model=0).ok
    assert not check_drift("z", measured=8, model=0).ok


# --- run report / metadata ------------------------------------------------


def test_runtime_metadata_has_match_keys():
    meta = runtime_metadata()
    for key in MATCH_KEYS:
        assert key in meta, meta
    assert meta["backend"] == jax.default_backend()
    assert meta["device_count"] == jax.device_count()
    assert meta["jax_version"] == jax.__version__


def test_run_report_roundtrip(tmp_path):
    rep = RunReport.begin("unit")
    rep.add_section("rows", [{"name": "a", "value": 1.0}])
    with metrics.using() as reg:
        reg.inc("c")
        rep.attach_metrics(reg)
    path = rep.write(tmp_path / "report.json")
    loaded = json.loads(path.read_text())
    assert loaded["name"] == "unit"
    assert loaded["sections"]["rows"][0]["value"] == 1.0
    assert loaded["metrics"]["counters"] == {"c": 1.0}
    assert all(k in loaded["metadata"] for k in MATCH_KEYS)


# --- profiler hooks -------------------------------------------------------


def test_maybe_trace_noop_without_env(monkeypatch):
    monkeypatch.delenv("REPRO_TRACE_DIR", raising=False)
    with maybe_trace("label") as d:
        assert d is None


def test_maybe_trace_captures_into_env_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_DIR", str(tmp_path))
    with maybe_trace("unit"):
        jax.block_until_ready(jnp.arange(8.0) * 2)
    # Degrades to a no-op on profiler failure, but the label dir must exist.
    assert (tmp_path / "unit").is_dir()


# --- BatchedServer telemetry ----------------------------------------------


def _tiny_cfg():
    import dataclasses

    from repro.configs import get_smoke_config

    return dataclasses.replace(
        get_smoke_config("qwen1.5-0.5b"),
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=0,
        d_ff=64, vocab_size=64, remat=False,
    )


def test_batched_server_telemetry():
    from repro.models import build_lm
    from repro.serve.engine import BatchedServer

    cfg = _tiny_cfg()
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    with metrics.using() as reg:
        srv = BatchedServer(cfg, params, lanes=2, max_len=64)
        for p in range(3):
            srv.submit(np.arange(4 + p) % 64, max_new_tokens=4)
        done = srv.run_until_idle()
    assert len(done) == 3
    for r in done:
        assert r.queue_latency_s is not None and r.queue_latency_s >= 0
        assert r.tokens_per_sec is not None and r.tokens_per_sec > 0
        # tokens_per_sec is the back-compat alias of the workload-neutral
        # items_per_sec field — same value through either name.
        assert r.items_per_sec == r.tokens_per_sec
    snap = reg.snapshot()
    assert snap["counters"]["serve.requests_submitted"] == 3.0
    assert snap["counters"]["serve.prefills"] == 3.0
    # max_new_tokens=4 = 1 prefill-argmax token + 3 decode tokens/request.
    assert snap["counters"]["serve.tokens_out"] == 9.0
    assert snap["counters"]["serve.decode_steps"] == 9.0
    # A drained server is idle: the occupancy gauge must read 0.0, not the
    # last busy step's value (regression for the staleness bug where it
    # froze at the pre-retire occupancy).
    assert snap["gauges"]["serve.batch_occupancy"] == 0.0
    assert snap["gauges"]["serve.tokens_per_sec"] > 0
    assert snap["gauges"]["serve.items_per_sec"] == snap["gauges"]["serve.tokens_per_sec"]
    assert snap["timers"]["serve.queue_latency"]["count"] == 3
    assert snap["timers"]["serve.prefill"]["count"] == 3
    assert snap["timers"]["serve.decode_step"]["count"] >= 3
    # Old-style stats dict keeps working (backward compatibility).
    assert srv.stats == {"prefills": 3, "decode_steps": 9, "tokens_out": 9}


def test_batch_occupancy_gauge_reflects_retires():
    """Single-stepped server: the occupancy gauge is restated AFTER each
    step's retires (a scrape between steps must not read the pre-retire
    value) and drops to 0.0 the moment the server goes idle."""
    from repro.models import build_lm
    from repro.serve.engine import BatchedServer

    cfg = _tiny_cfg()
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    with metrics.using() as reg:
        srv = BatchedServer(cfg, params, lanes=2, max_len=64)
        srv.submit(np.arange(4) % 64, max_new_tokens=2)  # retires in 1 step
        srv.submit(np.arange(5) % 64, max_new_tokens=4)
        assert srv.step() is True
        # The short request retired inside this step: post-retire occupancy
        # is 1/2, not the in-flight 2/2.
        assert reg.snapshot()["gauges"]["serve.batch_occupancy"] == 0.5
        while srv.step():
            pass
        assert reg.snapshot()["gauges"]["serve.batch_occupancy"] == 0.0


def test_batched_server_result_fields_without_metrics():
    """Per-request telemetry rides on the result objects even when no
    registry is installed — callers should not need to enable metrics."""
    from repro.models import build_lm
    from repro.serve.engine import BatchedServer

    cfg = _tiny_cfg()
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, lanes=1, max_len=64)
    srv.submit(np.arange(4) % 64, max_new_tokens=3)
    (req,) = srv.run_until_idle()
    assert req.queue_latency_s is not None
    assert req.tokens_per_sec is not None and req.tokens_per_sec > 0


# --- the instrumented stack on 8 fake devices -----------------------------


@pytest.mark.multidev
def test_obs_instrumented_stack_8dev():
    """REPRO_METRICS=1 auto-enables in the child; measured collective bytes
    match the per-field model (ratio exactly 1.0 in practice) and the
    instrumented results bit-match the uninstrumented ones."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["REPRO_METRICS"] = "1"
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "multidev" / "_obs_check.py")],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL_OK" in proc.stdout
