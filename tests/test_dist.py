"""Distribution-layer tests.

Multi-device correctness runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main pytest
process must keep seeing exactly 1 device, per the dry-run contract).
Single-process tests cover the sharding-rule logic, which is pure.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest
import jax
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import spec_for
from repro.launch.mesh import make_host_mesh

REPO = Path(__file__).resolve().parent.parent


def _run_subprocess(script: str, n_dev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "multidev" / script)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.multidev
def test_halo_and_compression_multidevice():
    out = _run_subprocess("_halo_check.py")
    assert "ALL_OK" in out


# --- sharding rules (pure logic, fake mesh via the real 1-device mesh) -------


class FakeMesh:
    """Duck-typed mesh: only axis_names + devices.shape are consulted."""

    def __init__(self, shape, axes):
        import numpy as np

        self.axis_names = axes
        self.devices = np.empty(shape, dtype=object)


def test_spec_batch_folds_pod():
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    spec = spec_for(("batch", "seq", "embed"), mesh, (256, 4096, 1024))
    assert spec == P(("pod", "data"), None, None)


def test_spec_divisibility_fallback():
    mesh = FakeMesh((16, 16), ("data", "model"))
    # 24 heads don't divide 16 -> replicate that dim.
    spec = spec_for(("batch", "heads", "head_dim"), mesh, (256, 24, 128))
    assert spec == P("data", None, None)
    # 64 heads divide 16 -> sharded.
    spec = spec_for(("batch", "heads", "head_dim"), mesh, (256, 64, 128))
    assert spec == P("data", "model", None)


def test_spec_no_double_assignment():
    mesh = FakeMesh((16, 16), ("data", "model"))
    # Both logical axes want "model"; only the first gets it.
    spec = spec_for(("heads", "mlp"), mesh, (64, 12288))
    assert spec == P("model", None)


def test_spec_decode_kv_seq():
    mesh = FakeMesh((16, 16), ("data", "model"))
    spec = spec_for(("batch", "kv_seq", "kv_heads", "head_dim"), mesh, (128, 32768, 8, 128), mode="decode")
    assert spec == P("data", "model", None, None)
    # In train mode kv_seq is replicated; 8 kv heads can't shard 16-way so
    # they fall back to replication too.
    spec = spec_for(("batch", "kv_seq", "kv_heads", "head_dim"), mesh, (128, 32768, 8, 128), mode="train")
    assert spec == P("data", None, None, None)
    # With 16 kv heads the head dim shards.
    spec = spec_for(("batch", "kv_seq", "kv_heads", "head_dim"), mesh, (128, 32768, 16, 128), mode="train")
    assert spec == P("data", None, "model", None)


def test_spec_grid_axes_map_to_same_named_mesh_axes():
    """The stencil-grid logical axes (depth, rows, cols) shard over the
    mesh axis of the SAME name — the rule lower_sharded's mesh_shape
    meshes rely on — with the usual divisibility fallback."""
    mesh = FakeMesh((2, 4), ("rows", "cols"))
    assert spec_for(("depth", "rows", "cols"), mesh, (64, 256, 256)) == P(
        None, "rows", "cols"
    )
    # Indivisible dims replicate, never pad.
    assert spec_for(("depth", "rows", "cols"), mesh, (64, 255, 256)) == P(
        None, None, "cols"
    )
    mesh3 = FakeMesh((2, 2, 2), ("depth", "rows", "cols"))
    assert spec_for(("depth", "rows", "cols"), mesh3, (8, 16, 16)) == P(
        "depth", "rows", "cols"
    )
    # No same-named axis present -> replicated (e.g. the data/model mesh).
    mesh_dm = FakeMesh((2, 4), ("data", "model"))
    assert spec_for(("depth", "rows", "cols"), mesh_dm, (8, 16, 16)) == P(
        None, None, None
    )


def test_spec_fsdp_partial_divisibility():
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    # dim 32 divides 32 (pod*data) -> both axes; dim 16 only divides data.
    assert spec_for(("fsdp",), mesh, (32,)) == P(("pod", "data"))
    assert spec_for(("fsdp",), mesh, (16,)) == P(("data",))


def test_host_mesh_single_device():
    mesh = make_host_mesh()
    assert mesh.devices.size == len(jax.devices())


@pytest.mark.multidev
def test_moe_sharded_multidevice():
    out = _run_subprocess("_moe_check.py")
    assert "ALL_OK" in out


@pytest.mark.multidev
def test_dryrun_machinery_multidevice():
    out = _run_subprocess("_dryrun_check.py")
    assert "ALL_OK" in out
