"""repro.obs.events + repro.obs.export: flight recorder and the
Prometheus exposition.

Covers the event-log contracts:

  * the ring is bounded (oldest events dropped + counted), filterable, and
    ordered by a recorder-local sequence number;
  * with a sink every event lands in the JSONL file as recorded, behind a
    ``meta`` header line carrying the runtime stamp;
  * ``span`` records one event with the measured duration;
  * ``crash_dump`` flushes the whole ring (+ reason + metadata) to a JSON
    document, defaulting next to the sink;
  * the module switchboard mirrors ``repro.obs.metrics`` exactly —
    zero-overhead no-ops when disabled, env auto-enable via
    ``REPRO_EVENT_LOG``;
  * ``prometheus_text`` renders counters/gauges/timers in the exposition
    format (sanitised names, ``_total`` counters, timer summaries);
  * ``BatchedServer`` records request-lifecycle events
    (submit/prefill/decode/retire) and serves the exposition via
    ``metrics_text()``.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.obs import events, metrics
from repro.obs.events import EVENT_LOG_ENV, FlightRecorder
from repro.obs.export import prometheus_text, sanitize_metric_name

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _obs_off():
    prev_reg, prev_rec = metrics.current(), events.current()
    metrics.disable()
    events.disable()
    yield
    metrics.enable(prev_reg) if prev_reg is not None else metrics.disable()
    events.enable(prev_rec) if prev_rec is not None else events.disable()


# --- ring semantics -------------------------------------------------------


def test_ring_is_bounded_and_counts_drops():
    rec = FlightRecorder(capacity=3)
    for i in range(5):
        rec.record("tick", i=i)
    assert len(rec) == 3
    assert rec.dropped == 2
    assert [e.data["i"] for e in rec.events()] == [2, 3, 4]
    # Sequence numbers keep the total order even after drops.
    assert [e.seq for e in rec.events()] == [2, 3, 4]


def test_events_filter_by_kind():
    rec = FlightRecorder()
    rec.record("a", n=1)
    rec.record("b", n=2)
    rec.record("a", n=3)
    assert [e.data["n"] for e in rec.events("a")] == [1, 3]
    assert rec.events("missing") == []


def test_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0)


def test_span_records_one_event_with_duration():
    rec = FlightRecorder()
    with rec.span("phase", label="x"):
        pass
    (ev,) = rec.events("phase")
    assert ev.data["label"] == "x"
    assert ev.data["duration_s"] >= 0.0


# --- JSONL sink + crash dump ----------------------------------------------


def test_sink_writes_meta_header_then_events(tmp_path):
    sink = tmp_path / "run" / "events.jsonl"  # parent dir auto-created
    rec = FlightRecorder(sink=sink)
    rec.record("alpha", v=1)
    rec.record("beta", v=2)
    rec.close()
    lines = [json.loads(l) for l in sink.read_text().splitlines()]
    assert lines[0]["kind"] == "meta" and lines[0]["seq"] == -1
    assert "jax_version" in lines[0]["data"]
    assert [l["kind"] for l in lines[1:]] == ["alpha", "beta"]
    assert lines[1]["data"] == {"v": 1}


def test_crash_dump_defaults_next_to_sink(tmp_path):
    sink = tmp_path / "events.jsonl"
    rec = FlightRecorder(capacity=2, sink=sink)
    for i in range(3):
        rec.record("step", i=i)
    out = rec.crash_dump(reason="blew up")
    assert out == tmp_path / "events.jsonl.crash.json"
    dump = json.loads(out.read_text())
    assert dump["reason"] == "blew up"
    assert dump["dropped"] == 1
    assert [e["data"]["i"] for e in dump["events"]] == [1, 2]


def test_crash_dump_explicit_path_and_sinkless_noop(tmp_path):
    rec = FlightRecorder()
    rec.record("x")
    assert rec.crash_dump() is None  # no sink, no path: in-memory only
    out = rec.crash_dump(tmp_path / "dump.json", reason="r")
    assert json.loads(out.read_text())["events"][0]["kind"] == "x"


# --- switchboard ----------------------------------------------------------


def test_disabled_hooks_are_noops():
    assert events.current() is None
    assert events.record("never", x=1) is None
    with events.span("never"):
        pass
    assert events.crash_dump(reason="never") is None


def test_using_scopes_and_restores():
    with events.using() as rec:
        assert events.current() is rec
        events.record("inside")
        assert len(rec) == 1
    assert events.current() is None


def test_enable_disable_roundtrip():
    rec = events.enable(FlightRecorder(capacity=8))
    assert events.enabled() and events.current() is rec
    events.disable()
    assert not events.enabled()


def test_disable_closes_sink_and_reenable_reopens_without_second_header(tmp_path):
    """The switchboard owns the fd of whatever it installed: disable()
    must close it (no leak across repeated scopes), and re-enabling the
    same recorder lazily reopens the sink WITHOUT duplicating the meta
    header."""
    sink = tmp_path / "e.jsonl"
    rec = events.enable(FlightRecorder(sink=sink))
    events.record("a")
    assert rec._file is not None
    events.disable()
    assert rec._file is None  # handle released
    events.enable(rec)
    events.record("b")
    events.disable()
    kinds = [json.loads(l)["kind"] for l in sink.read_text().splitlines()]
    assert kinds == ["meta", "a", "b"]


def test_using_closes_scoped_recorder_sink(tmp_path):
    with events.using(FlightRecorder(sink=tmp_path / "s.jsonl")) as rec:
        events.record("inside")
    assert rec._file is None   # fd released on scope exit...
    assert len(rec) == 1       # ...ring still inspectable


def test_enable_replacement_closes_previous_recorder(tmp_path):
    prev = events.enable(FlightRecorder(sink=tmp_path / "a.jsonl"))
    events.record("x")
    assert prev._file is not None
    events.enable(FlightRecorder())  # replaces prev -> closes its sink
    assert prev._file is None
    events.disable()


def test_env_auto_enable_in_subprocess(tmp_path):
    """REPRO_EVENT_LOG=path installs a sink-backed recorder at import."""
    sink = tmp_path / "auto.jsonl"
    code = (
        "from repro.obs import events\n"
        "assert events.enabled()\n"
        "events.record('auto.test', ok=True)\n"
    )
    env = dict(os.environ)
    env[EVENT_LOG_ENV] = str(sink)
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    kinds = [json.loads(l)["kind"] for l in sink.read_text().splitlines()]
    assert kinds == ["meta", "auto.test"]


# --- prometheus exposition ------------------------------------------------


def test_sanitize_metric_name():
    assert sanitize_metric_name("serve.decode_step") == "serve_decode_step"
    assert sanitize_metric_name("a-b c/d") == "a_b_c_d"
    assert sanitize_metric_name("9lives") == "_9lives"


def test_prometheus_text_disabled_is_one_comment_line():
    assert metrics.current() is None
    text = prometheus_text()
    assert text.startswith("#") and text.endswith("\n")


def test_prometheus_text_renders_all_metric_kinds():
    reg = metrics.MetricsRegistry()
    reg.inc("serve.prefills", 3)
    reg.set_gauge("health.psi.nan_count", 0)
    reg.observe("serve.decode_step", 0.25)
    reg.observe("serve.decode_step", 0.75)
    text = prometheus_text(reg)
    assert "repro_serve_prefills_total 3.0" in text
    assert "# TYPE repro_serve_prefills_total counter" in text
    assert "repro_health_psi_nan_count 0.0" in text
    assert "# TYPE repro_serve_decode_step_seconds summary" in text
    assert "repro_serve_decode_step_seconds_count 2" in text
    assert "repro_serve_decode_step_seconds_sum 1.0" in text
    assert "repro_serve_decode_step_seconds_min 0.25" in text
    assert "repro_serve_decode_step_seconds_max 0.75" in text


def test_prometheus_text_accepts_snapshot_and_formats_nonfinite():
    snap = {"counters": {}, "gauges": {"g.nan": float("nan"),
                                       "g.inf": float("inf")}, "timers": {}}
    text = prometheus_text(snap, prefix="x")
    assert "x_g_nan NaN" in text
    assert "x_g_inf +Inf" in text


def test_prometheus_text_uses_active_registry():
    with metrics.using() as reg:
        reg.inc("live.counter")
        assert "repro_live_counter_total 1.0" in prometheus_text()


# --- BatchedServer lifecycle events + exposition --------------------------


def _tiny_cfg():
    import dataclasses

    from repro.configs import get_smoke_config

    return dataclasses.replace(
        get_smoke_config("qwen1.5-0.5b"),
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=0,
        d_ff=64, vocab_size=64, remat=False,
    )


def test_batched_server_lifecycle_events_and_metrics_text():
    from repro.models import build_lm
    from repro.serve.engine import BatchedServer

    cfg = _tiny_cfg()
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    with metrics.using(), events.using() as rec:
        srv = BatchedServer(cfg, params, lanes=2, max_len=64)
        for p in range(2):
            srv.submit(np.arange(4 + p) % 64, max_new_tokens=3)
        done = srv.run_until_idle()
        text = srv.metrics_text()
    assert len(done) == 2
    kinds = [e.kind for e in rec.events()]
    assert kinds.count("serve.submit") == 2
    assert kinds.count("serve.prefill") == 2
    assert kinds.count("serve.retire") == 2
    assert kinds.count("serve.decode") >= 1
    retire = rec.events("serve.retire")[0]
    assert retire.data["tokens_out"] == 3
    assert retire.data["tokens_per_sec"] > 0
    # The engine's scrape body is the live registry's exposition.
    assert "repro_serve_prefills_total 2.0" in text
    assert "repro_serve_tokens_out_total" in text


def test_batched_server_metrics_text_without_registry():
    from repro.models import build_lm
    from repro.serve.engine import BatchedServer

    cfg = _tiny_cfg()
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, lanes=1, max_len=64)
    assert srv.metrics_text().startswith("#")  # well-formed even disabled
