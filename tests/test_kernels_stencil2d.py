"""Generic stencil2d + jacobi1d Pallas kernels vs oracles, shape/dtype sweep."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ELEMENTARY_FNS
from repro.kernels.stencil2d import jacobi1d, jacobi1d_ref, stencil2d, stencil2d_ref, weights_for

NAMES = ["jacobi2d_3pt", "laplacian", "jacobi2d_5pt", "jacobi2d_9pt"]
SHAPES = [(1, 8, 8), (2, 16, 24), (3, 64, 64), (1, 128, 256)]


def _rand(shape, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


@pytest.mark.parametrize("name", NAMES)
@pytest.mark.parametrize("shape", SHAPES)
def test_stencil2d_matches_ref(name, shape):
    x = jnp.asarray(_rand(shape))
    want = stencil2d_ref(x, jnp.asarray(weights_for(name)))
    got = stencil2d(x, name, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", NAMES)
def test_stencil2d_ref_matches_core(name):
    """The mask-based oracle must agree with the hand-written core stencils."""
    x = jnp.asarray(_rand((2, 16, 16), seed=2))
    want = ELEMENTARY_FNS[name](x)
    got = stencil2d_ref(x, jnp.asarray(weights_for(name)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_rows", [8, 16, 64])
def test_stencil2d_block_sweep(block_rows):
    x = jnp.asarray(_rand((1, 64, 32), seed=4))
    want = stencil2d_ref(x, jnp.asarray(weights_for("jacobi2d_9pt")))
    got = stencil2d(x, "jacobi2d_9pt", block_rows=block_rows, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_stencil2d_bf16():
    x = jnp.asarray(_rand((1, 32, 32), seed=6)).astype(jnp.bfloat16)
    want = stencil2d_ref(x, jnp.asarray(weights_for("jacobi2d_5pt")))
    got = stencil2d(x, "jacobi2d_5pt", interpret=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2
    )


@pytest.mark.parametrize("n", [8, 33, 256])
def test_jacobi1d_matches_ref(n):
    x = jnp.asarray(_rand((4, n), seed=8))
    want = jacobi1d_ref(x)
    got = jacobi1d(x, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_jacobi1d_1d_input():
    x = jnp.asarray(_rand((17,), seed=9))
    np.testing.assert_allclose(
        np.asarray(jacobi1d(x, interpret=True)), np.asarray(jacobi1d_ref(x)), rtol=1e-5
    )
