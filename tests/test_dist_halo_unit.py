"""Single-process unit tests for repro.dist internals.

Multi-device behaviour is covered by tests/multidev/_halo_check.py (8 fake
devices, subprocess); these tests exercise the pure pieces — halo padding,
absolute-row ownership masks, the analytical wire model, and the bf16
compression round trip — on the 1-device mesh so the halo logic runs in
the fast tier-1 path.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import HALO, hdiff, hdiff_simple
from repro.dist import (
    compress_bf16,
    decompress_bf16,
    exchange_halos_2d,
    exchange_row_halos,
    halo_exchange_bytes,
    halo_exchange_bytes_per_shard,
    make_sharded_hdiff,
    owned_rows_mask,
    reduce_gradients,
)
from repro.launch.dryrun import parse_collective_bytes
from repro.launch.mesh import make_mesh

BF16_REL = 2.0 ** -8  # half-ulp of bfloat16's 7-bit mantissa


# --- ownership masks (pure) ---------------------------------------------------


def test_owned_rows_mask_edges_and_interior():
    # 4 shards x 8 local rows = 32 global rows; global ring is 2 rows wide.
    first = np.asarray(owned_rows_mask(0, 8, 32))
    assert first.tolist() == [False, False] + [True] * 6
    last = np.asarray(owned_rows_mask(3, 8, 32))
    assert last.tolist() == [True] * 6 + [False, False]
    assert np.asarray(owned_rows_mask(1, 8, 32)).all()
    assert np.asarray(owned_rows_mask(2, 8, 32)).all()


def test_owned_rows_mask_ring_inside_one_shard():
    # 1 shard owns everything except the ring (the row_shards=1 degenerate).
    m = np.asarray(owned_rows_mask(0, 8, 8))
    assert m.tolist() == [False, False, True, True, True, True, False, False]


# --- analytical halo-wire model -----------------------------------------------


def test_halo_exchange_bytes_model():
    assert halo_exchange_bytes(64, 256, 256, row_shards=1) == 0
    # (n-1) internal boundaries x 2 directions x (depth * HALO * cols) * 4B
    assert halo_exchange_bytes(64, 256, 256, row_shards=4) == 2 * 3 * 64 * HALO * 256 * 4
    assert halo_exchange_bytes(64, 256, 256, row_shards=8) == 2 * 7 * 64 * HALO * 256 * 4
    # scales linearly in depth and cols, with itemsize
    assert halo_exchange_bytes(1, 16, 8, row_shards=2, itemsize=2) == 2 * 1 * HALO * 8 * 2


def test_halo_exchange_bytes_temporal_steps():
    """One k-step exchange round moves a k-times-deeper band; bytes per
    SIMULATED step are flat while exchange rounds (latency) divide by k."""
    one = halo_exchange_bytes(64, 256, 256, row_shards=4)
    for k in (2, 3, 4):
        per_round = halo_exchange_bytes(64, 256, 256, row_shards=4, steps=k)
        assert per_round == k * one
        assert per_round / k == one
    assert halo_exchange_bytes(64, 256, 256, row_shards=1, steps=4) == 0


def test_halo_exchange_bytes_2d_model():
    """Row bands + col bands + 4 diagonal corners; 1-shard axes free."""
    # col-only is the row formula transposed
    assert halo_exchange_bytes(64, 256, 128, 1, col_shards=4) == 2 * 3 * 64 * HALO * 256 * 4
    # full 2-D: rows + cols + corners
    got = halo_exchange_bytes(8, 64, 32, 2, halo=3, col_shards=4)
    want = (2 * 1 * 8 * 3 * 32 + 2 * 3 * 8 * 3 * 64 + 4 * 1 * 3 * 8 * 3 * 3) * 4
    assert got == want
    assert halo_exchange_bytes(8, 64, 32, 1, halo=3, col_shards=1) == 0


def test_halo_exchange_bytes_per_shard_model():
    """Per-chip permute result bytes: what parse_collective_bytes sees."""
    assert halo_exchange_bytes_per_shard(4, 16, 8, halo=2) == 2 * 4 * 2 * 8 * 4
    both = halo_exchange_bytes_per_shard(4, 16, 8, halo=2, col_sharded=True)
    assert both == (2 * 4 * 2 * 8 + 2 * 4 * 16 * 2 + 4 * 4 * 2 * 2) * 4
    assert halo_exchange_bytes_per_shard(
        4, 16, 8, row_sharded=False, col_sharded=False
    ) == 0


def test_single_shard_axes_emit_zero_collective_bytes():
    """An axis with 1 shard must SKIP its ppermutes (zero pad) instead of
    sending zero-filled halos to itself: the compiled HLO of a 1x1 mesh
    contains no collectives at all (regression for the ppermute-to-self
    fast path)."""
    mesh = make_mesh((1, 1), ("rows", "cols"))
    x = jnp.arange(2 * 6 * 6, dtype=jnp.float32).reshape(2, 6, 6)

    def exch_1d(b):
        return exchange_row_halos(b, "rows", 1)

    def exch_2d(b):
        return exchange_halos_2d(b, "rows", "cols", 1, 1)

    for fn in (exch_1d, exch_2d):
        mapped = jax.jit(
            jax.shard_map(
                fn,
                mesh=mesh,
                in_specs=(P(None, "rows", "cols"),),
                out_specs=P(None, "rows", "cols"),
                check_vma=False,
            )
        )
        coll = parse_collective_bytes(mapped.lower(x).compile().as_text())
        assert coll["bytes"]["total"] == 0, coll
        assert not coll["counts"], coll
    # The padded result itself is the zero-rimmed block.
    out = np.asarray(
        jax.shard_map(
            exch_2d,
            mesh=mesh,
            in_specs=(P(None, "rows", "cols"),),
            out_specs=P(None, "rows", "cols"),
            check_vma=False,
        )(x)
    )
    assert out.shape == (2, 6 + 2 * HALO, 6 + 2 * HALO)
    np.testing.assert_array_equal(out[:, HALO:-HALO, HALO:-HALO], np.asarray(x))
    rim = np.ones(out.shape[1:], bool)
    rim[HALO:-HALO, HALO:-HALO] = False
    np.testing.assert_array_equal(out[:, rim], 0.0)


def test_unsharded_axes_allow_extents_thinner_than_halo():
    """A 1-shard axis sources no neighbour band — its zero pads are built at
    full halo width even when the axis extent is thinner than the halo, so
    configurations plan_partition reports feasible (e.g. 4 rows, halo 6,
    1 row shard x N col shards) lower cleanly. Only SHARDED axes enforce
    the extent >= halo band-sourcing floor."""
    out = exchange_halos_2d(jnp.ones((2, 2, 3)), None, None, 1, 1, halo=4)
    assert out.shape == (2, 2 + 8, 3 + 8)
    np.testing.assert_array_equal(np.asarray(out[:, :4]), 0.0)
    np.testing.assert_array_equal(np.asarray(out[:, 4:6, 4:7]), 1.0)
    thin = exchange_row_halos(jnp.ones((2, 1, 8)), None, 1, halo=2)
    assert thin.shape == (2, 5, 8)


def test_exchange_row_halos_rejects_fine_mesh():
    """rows/shard < halo used to silently deliver a short halo band (the
    slice clamps); it must raise instead — the single-neighbour ppermute
    cannot source a deeper band. Shape check is static: no mesh needed."""
    block = jnp.zeros((2, 1, 8))  # 1 local row
    with pytest.raises(ValueError, match="rows/shard 1 < halo"):
        exchange_row_halos(block, "row", 256)
    with pytest.raises(ValueError, match="halo"):
        exchange_row_halos(jnp.zeros((2, 3, 8)), "row", 4, halo=4)
    # boundary case rows/shard == halo is legal (shape check only here;
    # the collective itself needs a real mesh, covered in tests/multidev).


# --- halo padding semantics on the 1-device mesh ------------------------------


def test_exchange_row_halos_zero_fill_at_grid_edges():
    """With a single row shard both halos are grid edges: ppermute has no
    source, so the pads must be exactly zero (the masking contract)."""
    mesh = make_mesh((1,), ("row",))
    x = jnp.arange(2 * 4 * 3, dtype=jnp.float32).reshape(2, 4, 3)
    fn = jax.shard_map(
        lambda b: exchange_row_halos(b, "row", 1),
        mesh=mesh,
        in_specs=(P(None, "row", None),),
        out_specs=P(None, "row", None),
        check_vma=False,
    )
    out = np.asarray(fn(x))
    assert out.shape == (2, 4 + 2 * HALO, 3)
    np.testing.assert_array_equal(out[:, :HALO], 0.0)
    np.testing.assert_array_equal(out[:, -HALO:], 0.0)
    np.testing.assert_array_equal(out[:, HALO:-HALO], np.asarray(x))


def test_sharded_hdiff_on_host_mesh_matches_single_device():
    mesh = make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(7)
    psi = jnp.asarray(rng.standard_normal((3, 16, 12)).astype(np.float32))
    for limit, ref_fn in ((True, hdiff), (False, hdiff_simple)):
        fn = make_sharded_hdiff(mesh, depth_axis="data", row_axis="model", limit=limit)
        np.testing.assert_allclose(
            np.asarray(fn(psi)), np.asarray(ref_fn(psi, 0.025)), rtol=1e-6, atol=1e-6
        )


def test_sharded_hdiff_validates_axes_and_shapes():
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError):
        make_sharded_hdiff(mesh, depth_axis="nope")
    with pytest.raises(ValueError):
        make_sharded_hdiff(mesh, depth_axis="data", row_axis="data")
    fn = make_sharded_hdiff(mesh)
    with pytest.raises(ValueError):
        fn(jnp.zeros((4, 4)))  # rank-2: no depth dim


# --- bf16 compression ---------------------------------------------------------


def test_bf16_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    # magnitudes across 12 decades, both signs — bf16 keeps f32's exponent
    # range so the bound is purely relative, never an overflow.
    mag = 10.0 ** rng.uniform(-6, 6, size=4096)
    x = (mag * rng.choice([-1.0, 1.0], size=mag.shape)).astype(np.float32)
    y = np.asarray(decompress_bf16(compress_bf16(jnp.asarray(x)), jnp.float32))
    rel = np.abs(y - x) / np.abs(x)
    assert rel.max() <= BF16_REL * 1.001, rel.max()


def test_reduce_gradients_identity_on_one_shard():
    mesh = make_mesh((1,), ("data",))
    grads = {
        "w": jnp.linspace(-3.0, 3.0, 64, dtype=jnp.float32).reshape(8, 8),
        "steps": jnp.int32(12),
    }

    def run(method):
        return jax.shard_map(
            lambda g: reduce_gradients(g, ("data",), method=method),
            mesh=mesh,
            in_specs=({"w": P(), "steps": P()},),
            out_specs={"w": P(), "steps": P()},
            check_vma=False,
        )(grads)

    exact = run("none")
    np.testing.assert_array_equal(np.asarray(exact["w"]), np.asarray(grads["w"]))
    assert int(exact["steps"]) == 12

    lossy = run("bf16")
    err = np.abs(np.asarray(lossy["w"]) - np.asarray(grads["w"]))
    bound = BF16_REL * np.abs(np.asarray(grads["w"])) + 1e-7
    assert (err <= bound).all(), err.max()
    # integer leaves bypass compression entirely
    assert int(lossy["steps"]) == 12


def test_reduce_gradients_rejects_unknown_method_and_empty_axes():
    g = {"w": jnp.ones((2, 2))}
    with pytest.raises(ValueError):
        reduce_gradients(g, ("data",), method="fp8")
    # no axes -> no collective context needed, grads pass through
    out = reduce_gradients(g, ())
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))
