"""Property-based attention invariants + chunked-prefill/decode handoff."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.models import build_cache, build_lm, lm_decode, lm_forward, lm_prefill  # noqa: E402
from repro.models import layers as L  # noqa: E402


def _attn_cfg(**over):
    base = dict(compute_dtype="float32")
    base.update(over)
    return dataclasses.replace(get_smoke_config("qwen1.5-0.5b"), **base)


@settings(max_examples=20, deadline=None)
@given(
    s=st.sampled_from([16, 32, 48, 64]),
    window=st.sampled_from([0, 4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_flash_equals_full_softmax(s, window, causal, seed):
    """The chunked online-softmax path must equal masked full softmax for
    every (seq, window, causality) combination hypothesis throws at it."""
    cfg = dataclasses.replace(_attn_cfg(), causal=causal)
    p, _ = L.init_attention(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, s, cfg.d_model), jnp.float32)
    full, _ = L.attention_apply(cfg, p, x, window=window, force_flash=False)
    flash, _ = L.attention_apply(cfg, p, x, window=window, force_flash=True)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(full), rtol=3e-5, atol=3e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_attention_permutation_of_batch(seed):
    """Batch rows are independent: permuting inputs permutes outputs."""
    cfg = _attn_cfg()
    p, _ = L.init_attention(cfg, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 16, cfg.d_model), jnp.float32)
    perm = jnp.asarray([2, 0, 3, 1])
    y, _ = L.attention_apply(cfg, p, x)
    y_perm, _ = L.attention_apply(cfg, p, x[perm])
    np.testing.assert_allclose(np.asarray(y_perm), np.asarray(y[perm]), rtol=1e-5, atol=1e-6)


def test_rwkv_chunked_prefill_decode_handoff():
    """Chunked-WKV prefill must hand its final recurrent state to decode
    such that continued decoding matches the teacher-forced forward."""
    cfg = dataclasses.replace(
        get_smoke_config("rwkv6-3b"), compute_dtype="float32", rwkv_chunk=8
    )
    params, _ = build_lm(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 24), 0, cfg.vocab_size)
    full_logits, _ = lm_forward(cfg, params, tokens)

    cache, _ = build_cache(cfg, 2, 24)
    last, cache = lm_prefill(cfg, params, tokens[:, :16], cache)  # 16 = 2 chunks
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, 15]), rtol=2e-4, atol=2e-4
    )
    for t in range(16, 24):
        logits, cache = lm_decode(cfg, params, tokens[:, t], cache, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            rtol=2e-4, atol=2e-4, err_msg=f"step {t}",
        )


def test_blocked_window_radius_sweep():
    """Sub-block radius selection must stay correct across window/seq combos
    (radius 1, 2, 4, 8 all hit by these pairs)."""
    cfg = _attn_cfg()
    p, _ = L.init_attention(cfg, jax.random.PRNGKey(2))
    for s, window in [(64, 32), (64, 16), (128, 16), (128, 8)]:
        x = jax.random.normal(jax.random.PRNGKey(s + window), (1, s, cfg.d_model), jnp.float32)
        full, _ = L.attention_apply(cfg, p, x, window=window, force_flash=False)
        blocked, _ = L.attention_apply(cfg, p, x, window=window, force_flash=True)
        np.testing.assert_allclose(
            np.asarray(blocked), np.asarray(full), rtol=3e-5, atol=3e-5,
            err_msg=f"s={s} window={window}",
        )
