"""Multi-field IR programs: per-field analysis, lowering contracts, and the
per-field wire model (ISSUE 5 tentpole).

Backend parity for vadvc / hdiff_coupled lives in the conformance matrix
(tests/conformance.py registers both); this module keeps the multi-field
*contracts* that the matrix cells don't spell out: composed per-field radii,
per-field reads summing to the program total, the degenerate
constant-coefficient bit-match, missing-field errors, and the per-field
halo-exchange byte model.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.dist import (
    halo_exchange_bytes,
    program_halo_exchange_bytes,
    program_halo_exchange_bytes_per_shard,
)
from repro.ir import (
    hdiff_coupled_program,
    hdiff_program,
    lower_pallas,
    lower_reference,
    lower_sharded,
    plan_partition,
    repeat,
    smagorinsky_coeff,
    vadvc_program,
)
from repro.ir.evaluate import apply_program


def _grid(*shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def _coupled_inputs(shape=(2, 16, 16)):
    return {
        "u": _grid(*shape, seed=1),
        "coeff": jnp.asarray(smagorinsky_coeff(np.asarray(_grid(*shape, seed=2)))),
    }


def test_field_radii_compose_per_field():
    """The state's radius grows by r per sweep; a zero-offset coefficient is
    read through k-1 downstream sweeps, so its radius is 2(k-1); vadvc's
    velocity (read through the destagger at every sweep) tracks the state."""
    p = hdiff_coupled_program()
    assert p.field_radii() == {"u": 2, "coeff": 0}
    for k in (1, 2, 3):
        pk = repeat(p, k)
        assert pk.field_radii() == {"u": 2 * k, "coeff": 2 * (k - 1)}
        assert pk.radius == 2 * k

    v = vadvc_program()
    assert v.field_radii() == {"s": 1, "w": 1}
    for k in (1, 2, 3):
        assert repeat(v, k).field_radii() == {"s": k, "w": k}


def test_reads_by_field_sums_to_spec():
    for prog in (hdiff_program(), hdiff_coupled_program(), vadvc_program(),
                 repeat(hdiff_coupled_program(), 2), repeat(vadvc_program(), 3)):
        per_field = prog.reads_by_field()
        assert sum(per_field.values()) == prog.spec().reads
        assert max(prog.field_radii().values()) == prog.radius
    # Single-input programs degenerate to the scalar accounting exactly.
    p = hdiff_program()
    assert p.reads_by_field() == {"psi": p.spec().reads}
    assert p.field_radius("psi") == p.radius


def test_coupled_constant_coeff_matches_scalar_hdiff_bitwise():
    """weighted_residual with a constant coeff field must reproduce the
    scalar scaled_residual kernel bit-for-bit (same term grouping)."""
    x = _grid(2, 20, 20, seed=3)
    coeff = jnp.full(x.shape, 0.025, jnp.float32)
    for k in (1, 2):
        want = np.asarray(apply_program(repeat(hdiff_program(), k), x))
        got = np.asarray(
            apply_program(repeat(hdiff_coupled_program(), k), {"u": x, "coeff": coeff})
        )
        np.testing.assert_array_equal(got, want, err_msg=f"k={k}")


def test_pallas_multifield_parity_and_field_order_independence():
    arrs = _coupled_inputs()
    pk = repeat(hdiff_coupled_program(), 2)
    want = np.asarray(lower_reference(pk)(arrs))
    got = np.asarray(lower_pallas(pk, interpret=True)(arrs))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    # Mapping insertion order must not matter (fields resolve by name).
    flipped = {"coeff": arrs["coeff"], "u": arrs["u"]}
    np.testing.assert_array_equal(
        np.asarray(lower_pallas(pk, interpret=True)(flipped)), got
    )


def test_lower_sharded_missing_field_raises_clearly():
    fn = lower_sharded(vadvc_program(), mesh_shape=(1, 1), inner="reference")
    with pytest.raises(ValueError, match=r"missing\s+input\(s\) \['w'\]"):
        fn({"s": _grid(2, 8, 8)})
    with pytest.raises(ValueError, match="pass a mapping"):
        fn(_grid(2, 8, 8))
    with pytest.raises(ValueError, match="share one grid"):
        fn({"s": _grid(2, 8, 8), "w": _grid(2, 8, 16)})


def test_composed_chain_missing_field_raises_value_error():
    """Regression: the k>1 chain paths used to die with a bare KeyError when
    the mapping omitted a shared field; they now share the k=1 validation
    (thread_chain -> resolve_field_arrays) and name the missing input."""
    pk = repeat(hdiff_coupled_program(), 2)
    u = _grid(2, 16, 16)
    for fn in (lower_reference(pk), lower_reference(pk, mode="staged")):
        with pytest.raises(ValueError, match=r"missing\s+input\(s\) \['coeff'\]"):
            fn({"u": u})
        with pytest.raises(ValueError, match="pass a mapping"):
            fn(u)


def test_compose_shared_name_shadowing_chain_entry_passthrough():
    """Regression: compose renames the merged DAG but the chain keeps the
    ORIGINAL per-sweep programs, so a downstream sweep whose input name
    collides with an upstream shared field used to make slab_step run the
    sweep on the shared array instead of the evolving state — Pallas and
    sharded silently diverged from the reference. State must win the name
    collision on every backend."""
    from repro.ir import StencilProgram, affine, product

    a = StencilProgram(
        "a", ["s", "w"],
        [affine("sbar", "s", {(1, 0): 0.5, (-1, 0): 0.5}),
         product("out", "sbar", "w")],
        passthrough="s",
    )
    b = StencilProgram("b", ["w"], [affine("out", "w", {(0, 0): 2.0})])
    c = a.compose(b)  # b's input name "w" shadows a's shared field "w"
    arrs = {"s": _grid(2, 16, 16, seed=7), "w": _grid(2, 16, 16, seed=8)}
    want = np.asarray(lower_reference(c)(arrs))
    staged = np.asarray(lower_reference(c, mode="staged")(arrs))
    np.testing.assert_allclose(staged, want, rtol=1e-6, atol=1e-6)
    got = np.asarray(lower_pallas(c, interpret=True)(arrs))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    sharded = np.asarray(
        lower_sharded(c, mesh_shape=(1, 1), inner="reference")(arrs)
    )
    np.testing.assert_allclose(sharded, want, rtol=1e-6, atol=1e-6)


def test_compose_rejects_reading_evolving_field_as_shared():
    """A downstream sweep only ever sees the UPDATED state: reading the
    evolving field as a shared (non-evolving) input must be rejected at
    graph construction — the slab lowerings cannot supply pre-sweep
    values, and a silent backend split is worse than an error."""
    from repro.ir import StencilProgram, affine, product

    a = StencilProgram(
        "a", ["x", "c"], [affine("out", "x", {(0, 0): 1.0})], passthrough="x"
    )
    # b evolves "c" and reads "x" — a's evolving field — as a SHARED input:
    # after a's sweep there is no original "x" left to feed it.
    b = StencilProgram(
        "b", ["c", "x"], [product("out", "c", "x")], passthrough="c"
    )
    with pytest.raises(ValueError, match="evolving field"):
        a.compose(b)


def test_lower_pallas_default_tile_budget_scales_with_field_count():
    """The VMEM planner models one resident tile; an N-field kernel keeps
    N slabs live, so the default block_rows must shrink accordingly."""
    from repro.ir import StencilProgram, affine, scaled_residual

    one = StencilProgram("one", ["a"], [affine("out", "a", {(0, 0): 1.0})])
    two = StencilProgram(
        "two", ["a", "b"],
        [affine("s", "a", {(0, 0): 1.0}),
         scaled_residual("out", "s", [("b", 1)], 1.0)],
    )
    rows, cols = 16, 8
    budget = 640  # fits a 16-row single-field tile (512 B), not two of them
    xs = {"a": _grid(2, rows, cols, seed=5), "b": _grid(2, rows, cols, seed=6)}
    # Probe the chosen tile via the divisibility error on a bad override vs
    # the accepted default: run both and compare numerics instead — the
    # two-field default must still be correct, just smaller-tiled.
    got1 = np.asarray(lower_pallas(one, vmem_budget=budget, interpret=True)(xs["a"]))
    np.testing.assert_array_equal(got1, np.asarray(xs["a"]))
    got2 = np.asarray(lower_pallas(two, vmem_budget=budget, interpret=True)(xs))
    want2 = np.asarray(lower_reference(two)(xs))
    np.testing.assert_allclose(got2, want2, rtol=1e-6, atol=1e-6)


def test_lower_pallas_1d_stays_single_input():
    from repro.ir import StencilProgram, affine

    two = StencilProgram(
        "two1d", ["a", "b"], [affine("out", "a", {(0,): 1.0})], ndim=1
    )
    with pytest.raises(ValueError, match="single-input"):
        lower_pallas(two, interpret=True)


def test_program_halo_exchange_bytes_is_per_field_sum():
    D, R, C = 4, 48, 48
    # Single-input: reduces exactly to the halo_exchange_bytes formula.
    p = hdiff_program()
    assert program_halo_exchange_bytes(p, D, R, C, 4, col_shards=2) == (
        halo_exchange_bytes(D, R, C, 4, halo=p.radius, col_shards=2)
    )
    # hdiff_coupled at k=1: coeff radius 0 contributes ZERO bytes.
    pc = hdiff_coupled_program()
    assert program_halo_exchange_bytes(pc, D, R, C, 4, col_shards=2) == (
        program_halo_exchange_bytes(p, D, R, C, 4, col_shards=2)
    )
    # At k=2 the coeff field adds its own radius-2 band on top of the
    # state's radius-4 band.
    pc2 = repeat(pc, 2)
    assert program_halo_exchange_bytes(pc2, D, R, C, 4, col_shards=2) == (
        halo_exchange_bytes(D, R, C, 4, halo=4, col_shards=2)
        + halo_exchange_bytes(D, R, C, 4, halo=2, col_shards=2)
    )
    # vadvc: both fields move a radius-k band.
    for k in (1, 2):
        vk = repeat(vadvc_program(), k)
        assert program_halo_exchange_bytes(vk, D, R, C, 8) == (
            2 * halo_exchange_bytes(D, R, C, 8, halo=k)
        )
    # Per-shard variant mirrors the same per-field sum.
    assert program_halo_exchange_bytes_per_shard(
        pc2, D, R // 2, C // 4, row_sharded=True, col_sharded=True
    ) == sum(
        2 * D * h * (C // 4) * 4 + 2 * D * (R // 2) * h * 4 + 4 * D * h * h * 4
        for h in (4, 2)
    )


def test_plan_partition_accounts_multifield_wire():
    """The planner's wire objective sums per field: vadvc (two radius-1
    fields) models exactly twice the single-field laplacian traffic, and
    planning still returns a feasible factorization."""
    from repro.ir import laplacian_program

    D, R, C = 8, 64, 64
    plan_v = plan_partition(vadvc_program(), D, R, C, 8)
    plan_l = plan_partition(laplacian_program(), D, R, C, 8)
    assert plan_v.mesh_shape == plan_l.mesh_shape
    assert plan_v.wire_bytes == 2 * plan_l.wire_bytes
