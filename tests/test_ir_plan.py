"""VMEM tile-budget planner tests: shared between kernels and the IR."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.ir.plan import (
    DEFAULT_VMEM_TILE_BUDGET,
    VMEM_BUDGET_ENV,
    pick_block_rows,
    vmem_tile_budget,
)
from repro.kernels.hdiff import hdiff_fused
from repro.kernels.hdiff.ops import _pick_block_rows
from repro.core import hdiff


def test_budget_resolution_order(monkeypatch):
    monkeypatch.delenv(VMEM_BUDGET_ENV, raising=False)
    assert vmem_tile_budget() == DEFAULT_VMEM_TILE_BUDGET
    monkeypatch.setenv(VMEM_BUDGET_ENV, str(1 << 20))
    assert vmem_tile_budget() == 1 << 20
    # explicit argument wins over the env var
    assert vmem_tile_budget(2048) == 2048
    monkeypatch.setenv(VMEM_BUDGET_ENV, "not-a-number")
    with pytest.raises(ValueError, match=VMEM_BUDGET_ENV):
        vmem_tile_budget()


def test_budget_rejects_non_positive(monkeypatch):
    """REPRO_VMEM_BUDGET=0 (or negative) used to degrade every kernel to
    1-row tiles; it is a configuration error and must raise."""
    monkeypatch.delenv(VMEM_BUDGET_ENV, raising=False)
    with pytest.raises(ValueError, match="positive"):
        vmem_tile_budget(0)
    with pytest.raises(ValueError, match="positive"):
        vmem_tile_budget(-4096)
    monkeypatch.setenv(VMEM_BUDGET_ENV, "0")
    with pytest.raises(ValueError, match=VMEM_BUDGET_ENV):
        vmem_tile_budget()
    monkeypatch.setenv(VMEM_BUDGET_ENV, "-1")
    with pytest.raises(ValueError, match=VMEM_BUDGET_ENV):
        vmem_tile_budget()
    with pytest.raises(ValueError):
        pick_block_rows(256, 256, budget_bytes=0)


def test_pick_block_rows_rejects_unsatisfiable_floor():
    """min_rows above every divisor of rows (rows itself) must raise, not
    silently fall back to an undersized tile."""
    with pytest.raises(ValueError, match="min_rows"):
        pick_block_rows(4, 128, min_rows=8)
    # rows == min_rows stays legal
    assert pick_block_rows(8, 128, min_rows=8) == 8


def test_pick_block_rows_budget_and_floor():
    # 256x256 f32 tile is 256 KiB: fits the 4 MiB default whole.
    assert pick_block_rows(256, 256) == 256
    # A 64 KiB budget allows 64 rows of 256 f32 cols.
    assert pick_block_rows(256, 256, budget_bytes=64 * 1024) == 64
    # The structural floor is respected even when smaller tiles would fit.
    assert pick_block_rows(256, 256, budget_bytes=1024, min_rows=4) == 4
    # Nothing fits: smallest divisor >= min_rows (correctness over budget).
    assert pick_block_rows(12, 1 << 20, budget_bytes=1024, min_rows=4) == 4
    assert pick_block_rows(7, 1 << 20, budget_bytes=1024, min_rows=2) == 7


def test_pick_block_rows_env_override(monkeypatch):
    monkeypatch.setenv(VMEM_BUDGET_ENV, str(64 * 1024))
    assert pick_block_rows(256, 256) == 64
    # kernels/hdiff's picker goes through the same budget resolution
    assert _pick_block_rows((1, 256, 256)) == 64


def test_hdiff_fused_respects_vmem_budget_argument():
    rng = np.random.default_rng(3)
    psi = jnp.asarray(rng.standard_normal((2, 32, 16)).astype(np.float32))
    want = np.asarray(hdiff(psi, 0.025))
    # 8-row tiles (32*16*4 = 2 KiB budget => 8 rows of 16 cols at 512 B/row).
    got = hdiff_fused(psi, 0.025, interpret=True, vmem_budget=512 * 8)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-6)
