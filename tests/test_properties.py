"""Property-based tests (hypothesis) for system invariants.

Invariants tested:
  * hdiff_simple is LINEAR in the input (it is a polynomial stencil).
  * hdiff (limited) is translation-equivariant in the grid interior.
  * the flux limiter only ever removes diffusion: |out - in|(limited)
    <= |out - in|(unlimited) pointwise... (not true in general because the
    four flux terms can cancel; instead we check the limiter's defining
    property directly on random inputs).
  * adding a constant to the field shifts hdiff output by that constant
    (diffusion acts on gradients only).
  * elementary averaging stencils (jacobi family) obey a maximum principle:
    interior outputs lie within [min(x), max(x)].
  * the partition planner always returns a plan whose shards cover the grid.
"""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import hdiff, hdiff_simple, jacobi2d_5pt, jacobi2d_9pt, plan_partition  # noqa: E402


def grids(min_side=6, max_side=16):
    return st.tuples(
        st.integers(1, 3), st.integers(min_side, max_side), st.integers(min_side, max_side)
    ).flatmap(
        lambda shp: st.lists(
            st.floats(-10, 10, allow_nan=False, width=32),
            min_size=shp[0] * shp[1] * shp[2],
            max_size=shp[0] * shp[1] * shp[2],
        ).map(lambda vals: np.asarray(vals, np.float32).reshape(shp))
    )


@settings(max_examples=25, deadline=None)
@given(grids(), st.floats(0.01, 0.2), st.floats(-3, 3), st.floats(-3, 3))
def test_hdiff_simple_is_linear(x, coeff, a, b):
    x = jnp.asarray(x)
    y = jnp.flip(x, axis=-1)
    lhs = hdiff_simple(a * x + b * y, coeff)
    rhs = a * hdiff_simple(x, coeff) + b * hdiff_simple(y, coeff)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=2e-3, atol=2e-3)


def int_grids(min_side=6, max_side=16):
    """Integer-valued f32 grids: all stencil sums are exact, so the flux
    limiter's compare never sits on a rounding boundary."""
    return st.tuples(
        st.integers(1, 3), st.integers(min_side, max_side), st.integers(min_side, max_side)
    ).flatmap(
        lambda shp: st.lists(
            st.integers(-64, 64),
            min_size=shp[0] * shp[1] * shp[2],
            max_size=shp[0] * shp[1] * shp[2],
        ).map(lambda vals: np.asarray(vals, np.float32).reshape(shp))
    )


@settings(max_examples=25, deadline=None)
@given(int_grids(), st.floats(0.01, 0.2), st.integers(-5, 5))
def test_hdiff_constant_shift_equivariance(x, coeff, c):
    """hdiff(x + c) == hdiff(x) + c — diffusion sees only gradients.

    Integer-valued fields keep the limiter decisions exact on both sides;
    with generic floats an epsilon change in rounding can flip a limiter
    branch at isolated points (a genuine property of the discontinuous
    limiter, not a bug)."""
    x = jnp.asarray(x)
    lhs = hdiff(x + float(c), coeff)
    rhs = hdiff(x, coeff) + float(c)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-5, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(grids(min_side=8, max_side=14), st.floats(0.01, 0.2))
def test_hdiff_translation_equivariance(x, coeff):
    """Shifting the field by one column shifts the output (deep interior)."""
    x = jnp.asarray(x)
    shifted = jnp.roll(x, 1, axis=-1)
    out = hdiff(x, coeff)
    out_shifted = hdiff(shifted, coeff)
    # Compare deep interior where neither halo nor the roll wraparound reach.
    np.testing.assert_allclose(
        np.asarray(out_shifted[..., 2:-2, 4:-2]),
        np.asarray(jnp.roll(out, 1, axis=-1)[..., 2:-2, 4:-2]),
        rtol=1e-4,
        atol=1e-4,
    )


@settings(max_examples=25, deadline=None)
@given(grids())
def test_jacobi_maximum_principle(x):
    x = jnp.asarray(x)
    lo, hi = float(x.min()), float(x.max())
    for fn in (jacobi2d_5pt, jacobi2d_9pt):
        out = np.asarray(fn(x))
        assert out.min() >= lo - 1e-4
        assert out.max() <= hi + 1e-4


@settings(max_examples=50, deadline=None)
@given(
    st.sampled_from([16, 32, 64, 128]),
    st.sampled_from([64, 128, 256]),
    st.sampled_from([1, 2, 4, 8, 16, 32, 64, 256]),
)
def test_plan_partition_valid(depth, size, n_devices):
    plan = plan_partition(depth, size, size, n_devices)
    if plan.kind == "depth-underfilled":
        # grid too small for the mesh: uses a subset of devices, never fails
        assert plan.depth_shards * plan.row_shards <= n_devices
    else:
        assert plan.depth_shards * plan.row_shards == n_devices
    assert depth % plan.depth_shards == 0
    assert plan.step_s > 0
    # Depth-parallel must be chosen whenever it fits: it has zero ICI cost
    # and no halo redundancy (the paper's plane-per-B-block argument).
    if depth % n_devices == 0:
        assert plan.kind == "depth"
        assert plan.ici_s == 0
