"""Subprocess body: the dry-run machinery on an 8-device mesh with smoke
configs — lower + compile + cost/memory/collective extraction end-to-end
for one train, one prefill, one decode cell across model families.
"""

import os

assert "--xla_force_host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")

import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.launch.dryrun import parse_collective_bytes
from repro.launch.inputs import make_lowering_spec
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))

CASES = [
    ("qwen3-moe-235b-a22b", ShapeConfig("t", 64, 4, "train")),
    ("starcoder2-3b", ShapeConfig("p", 64, 4, "prefill")),
    ("recurrentgemma-2b", ShapeConfig("d", 64, 4, "decode")),
    ("rwkv6-3b", ShapeConfig("d", 64, 4, "decode")),
    ("llama-3.2-vision-90b", ShapeConfig("t", 64, 4, "train")),
]

for arch, shape in CASES:
    cfg = dataclasses.replace(get_smoke_config(arch), remat=False)
    spec = make_lowering_spec(cfg, shape, mesh)
    jt = jax.jit(spec.fn, in_shardings=spec.in_shardings, out_shardings=spec.out_shardings)
    with jax.set_mesh(mesh):
        compiled = jt.lower(*spec.args).compile()
    cost = compiled.cost_analysis()
    assert cost.get("flops", 0) > 0, (arch, shape.kind)
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
    coll = parse_collective_bytes(compiled.as_text())
    print(f"{arch} {shape.kind}: flops={cost.get('flops'):.2e} "
          f"coll_bytes={coll['bytes']['total']:.2e} counts={coll['counts']}")

print("ALL_OK")
