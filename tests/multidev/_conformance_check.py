"""Subprocess body: the sharded slice of the conformance matrix on ONE
multi-device mesh (rows x cols fake devices; run by
tests/test_conformance_matrix.py with XLA_FLAGS forcing the device count).

Also asserts the async-overlap contract on every mesh: ``overlap=True``
must BIT-match ``overlap=False`` (all k for the reference inner; k=2 for
the Pallas inner to bound compile time).

Prints DEVICES_UNAVAILABLE (exit 3) when the device count cannot back the
mesh — the caller converts that into a pytest skip, which the CI
multidev-2d job's skip gate turns into a failure.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--mesh", required=True, help="RxC, e.g. 2x4")
args = ap.parse_args()
R, C = (int(s) for s in args.mesh.split("x"))

if len(jax.devices()) < R * C:
    print(f"DEVICES_UNAVAILABLE mesh {args.mesh} needs {R * C} devices, "
          f"have {len(jax.devices())}")
    sys.exit(3)

import numpy as np  # noqa: E402

from conformance import (  # noqa: E402
    KS,
    SHARDED_BACKENDS,
    assert_case,
    assert_equal,
    iter_cases,
    run_case,
)

OVERLAP_KS = {"sharded-reference": set(KS), "sharded-pallas": {2}}

# Non-f32 overlap contract: the Pallas inner upcasts to f32 in-kernel, and
# the overlap edge bands must mirror that — regression for the bf16 case.
import jax.numpy as jnp  # noqa: E402

from conformance import make_input  # noqa: E402
from repro.ir import hdiff_program, lower_sharded  # noqa: E402

xb = make_input().astype(jnp.bfloat16)
for inner in ("pallas", "reference"):
    base = lower_sharded(hdiff_program(), mesh_shape=(R, C), inner=inner)
    over = lower_sharded(hdiff_program(), mesh_shape=(R, C), inner=inner, overlap=True)
    np.testing.assert_array_equal(
        np.asarray(over(xb)).astype(np.float32),
        np.asarray(base(xb)).astype(np.float32),
        err_msg=f"bf16 overlap!=no-overlap inner={inner} mesh={args.mesh}",
    )
print(f"bf16 overlap bit-match ok mesh={args.mesh}")

n_cells = 0
for name, backend, k, mesh_shape in iter_cases(((R, C),)):
    if backend not in SHARDED_BACKENDS:
        continue
    got = assert_case(name, backend, k, mesh_shape)
    if k in OVERLAP_KS[backend]:
        got_overlap, _ = run_case(name, backend, k, mesh_shape, overlap=True)
        assert_equal(
            got_overlap, got,
            err_msg=f"overlap!=no-overlap: {name}/{backend}/k={k}/{args.mesh}",
        )
    n_cells += 1
    print(f"{name} {backend} k={k} mesh={args.mesh} ok")

print(f"ALL_OK {n_cells} cells")
