"""Subprocess body: the batched (ensemble) conformance cells on ONE
multi-device mesh (rows x cols fake devices; run by
tests/test_ir_batched.py with XLA_FLAGS forcing the device count).

Every cell asserts the two-sided batched contract from tests/conformance.py:
member i of the vmapped result is BIT-identical to an independent
application on the same sharded backend, and 1e-6-close to the reference
oracle. Prints DEVICES_UNAVAILABLE (exit 3) when the device count cannot
back the mesh — the caller converts that into a pytest skip, which the CI
multidev job's skip gate turns into a failure.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--mesh", required=True, help="RxC, e.g. 2x4")
args = ap.parse_args()
R, C = (int(s) for s in args.mesh.split("x"))

if len(jax.devices()) < R * C:
    print(f"DEVICES_UNAVAILABLE mesh {args.mesh} needs {R * C} devices, "
          f"have {len(jax.devices())}")
    sys.exit(3)

from conformance import (  # noqa: E402
    BATCHED_KS,
    BATCHED_PROGRAMS,
    SHARDED_BACKENDS,
    assert_batched_case,
)

n_cells = 0
for name in BATCHED_PROGRAMS:
    for backend in SHARDED_BACKENDS:
        for k in BATCHED_KS:
            assert_batched_case(name, backend, k, (R, C))
            n_cells += 1
            print(f"{name} {backend} k={k} mesh={args.mesh} batched ok")

print(f"ALL_OK {n_cells} cells")
