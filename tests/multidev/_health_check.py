"""Subprocess body: mesh-global field_stats on 8 fake devices.

Run by tests/test_obs_health.py with XLA_FLAGS forcing 8 host devices.
Asserts the two mesh-level health claims:

  * ``field_stats(block, axis_names=("rows", "cols"))`` inside a
    ``shard_map`` over a 2x4 mesh returns GLOBAL statistics of the sharded
    field that match the single-device ``field_stats`` of the unsharded
    array to 1e-6 — on the paper's evaluation grid (64 x 256 x 256), with
    NaN/Inf poison points planted so the counts exercise the psum path;
  * a conformance cell (hdiff, k=2, sharded-reference on the 2x4 mesh)
    stays BIT-identical when run under ``HealthMonitor.wrap`` with metrics
    and the flight recorder enabled — probes must not perturb the numbers.

Prints HEALTH_OK on success.
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

assert len(jax.devices()) == 8, jax.devices()

import numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.compat  # noqa: F401  (jax.shard_map on older jax)
from repro.ir import hdiff_program, lower_sharded, repeat
from repro.launch.mesh import make_mesh
from repro.obs import FlightRecorder, HealthMonitor, events, field_stats, host_stats, metrics

# --- 1. sharded-vs-single-device stats parity on the paper grid ------------

depth, rows, cols = 64, 256, 256  # the paper's evaluation domain (§4.1)
rng = np.random.default_rng(7)
host = rng.standard_normal((depth, rows, cols)).astype(np.float32)
host[0, 10, 20] = np.nan          # poison points: counts must psum globally
host[1, 200, 30] = np.inf
host[2, 5, 250] = -np.inf
host[3, 100, 100] = 37.5          # a known global max on one shard only
x = jnp.asarray(host)

single = host_stats(field_stats(x))

mesh = make_mesh((2, 4), ("rows", "cols"))
spec = P(None, "rows", "cols")
sharded_fn = jax.jit(
    jax.shard_map(
        lambda block: field_stats(block, axis_names=("rows", "cols")),
        mesh=mesh,
        in_specs=(spec,),
        out_specs=P(),
        check_vma=False,
    )
)
sharded = host_stats(sharded_fn(x))

for key in ("size", "nan_count", "inf_count"):
    assert sharded[key] == single[key], (key, sharded[key], single[key])
for key in ("min", "max", "mean", "l2"):
    np.testing.assert_allclose(
        sharded[key], single[key], rtol=1e-6, atol=1e-6,
        err_msg=f"sharded {key} diverged from single-device",
    )
assert single["nan_count"] == 1 and single["inf_count"] == 2
assert single["max"] == 37.5
print(f"stats parity: l2 sharded={sharded['l2']:.6f} single={single['l2']:.6f}")

# --- 2. probes must not perturb a conformance cell -------------------------

import conformance  # noqa: E402  (tests/ is on sys.path)

prog = repeat(hdiff_program(), 2)
cell_in = conformance.make_fields("hdiff")
fn = lower_sharded(prog, mesh_shape=(2, 4), inner="reference")

prev = metrics.current()
metrics.disable()
try:
    baseline = np.asarray(fn(cell_in))
finally:
    if prev is not None:
        metrics.enable(prev)

with tempfile.TemporaryDirectory() as td:
    sink = os.path.join(td, "events.jsonl")
    with metrics.using() as reg, events.using(FlightRecorder(sink=sink)) as rec:
        monitor = HealthMonitor(cadence=1, policy="abort", name="hdiff_out")
        probed = np.asarray(monitor.wrap(fn)(cell_in))
        assert monitor.probes == 1 and monitor.blowups == 0
        assert rec.events("health.probe"), "probe event missing from the ring"
        assert reg.counters.get("health.probes") == 1.0
        assert reg.gauges["health.hdiff_out.nan_count"] == 0.0
        assert os.path.getsize(sink) > 0, "JSONL sink not written"

assert (probed == baseline).all(), "health probe perturbed the conformance cell"
print("conformance cell bit-exact under probes")

print("HEALTH_OK")
